"""Serve a small model with batched requests: prefill + KV-cache decode.

  PYTHONPATH=src python examples/serve_decode.py --arch tinyllama-1.1b

Uses the reduced config (CPU container); the full configs serve through
the identical code path on the production mesh (launch/dryrun.py proves
the decode_32k / long_500k lowerings).
"""
import argparse
import time

import jax

from repro.configs import get
from repro.models import api as mapi
from repro.serve.engine import ServeConfig, generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=48)
    args = ap.parse_args()

    cfg = get(args.arch).reduced(dtype="float32", remat=False)
    model = mapi.build(cfg)
    params = model.init(jax.random.PRNGKey(0))

    batch = {"tokens": jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab)}
    if cfg.family == "encdec":
        from repro.models.whisper import enc_len_for
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(2),
            (args.batch, enc_len_for(cfg, args.prompt_len), cfg.d_model))
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (args.batch, cfg.vlm_prefix, cfg.d_model))

    t0 = time.time()
    out, steps = generate(model, params, batch,
                          ServeConfig(max_new_tokens=args.new_tokens))
    dt = time.time() - t0
    print(f"arch={args.arch} batch={args.batch} prompt={args.prompt_len} "
          f"new={steps}")
    print(f"decoded {args.batch * steps} tokens in {dt:.2f}s "
          f"({args.batch * steps / dt:,.0f} tok/s)")
    print("sample token ids:", out[0, :16].tolist())


if __name__ == "__main__":
    main()
