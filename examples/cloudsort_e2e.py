"""CloudSort end-to-end (the paper's benchmark, §3): generate -> sort ->
validate -> cost report.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/cloudsort_e2e.py [--records 262144]

Follows the paper's protocol exactly at container scale: gensort input
with checksum, two-stage streaming exoshuffle sort with whole-record
payload movement, per-worker R1 reducer partitions, valsort ordering +
checksum gates, and the Table-2 cost model for both the paper's cluster
and the adapted TPU pod.
"""
import argparse
import os
import time

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
    )

import jax
import jax.numpy as jnp

from repro.configs.cloudsort import SMOKE
from repro.core.cost_model import cloudsort_tco, tpu_cloudsort_tco
from repro.core.exoshuffle import ShuffleConfig, distributed_sort_payload, reduce_partitions
from repro.data import gensort, valsort


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--records", type=int, default=SMOKE.total_records)
    ap.add_argument("--payload-mode", default="through",
                    choices=["through", "late"])
    args = ap.parse_args()

    w = len(jax.devices())
    from repro.core.compat import make_mesh
    mesh = make_mesh((w,), ("w",))
    cfg = ShuffleConfig(num_workers=w, reducers_per_worker=SMOKE.reducers_per_worker,
                        impl="ref")

    # --- generate input (paper §3.2, gensort) ---
    t0 = time.time()
    keys, ids = gensort.gen_keys(0, args.records)
    payload = gensort.gen_payload(ids, 8)  # 32-byte payload at smoke scale
    in_ck = tuple(int(c) for c in gensort.checksum(keys, ids, payload))
    print(f"[gen] {args.records} records in {time.time()-t0:.2f}s "
          f"checksum={in_ck}")

    # --- sort (map + shuffle + merge, then reduce) ---
    t0 = time.time()
    sk, si, sp, counts, ovf = jax.jit(
        lambda k, i, p: distributed_sort_payload(
            k, i, p, mesh=mesh, axis_names="w", mode=args.payload_mode, cfg=cfg)
    )(keys, ids, payload)
    jax.block_until_ready(sk)
    sort_s = time.time() - t0
    assert not bool(ovf), "fixed-capacity block overflow"
    print(f"[sort] {args.records} records in {sort_s:.2f}s "
          f"({args.records/sort_s:,.0f} rec/s, payload={args.payload_mode})")

    # --- reducer output partitions (paper §2.4: R1 per worker) ---
    seg = sk.shape[0] // w
    r1_counts = []
    for wid in range(w):
        seg_k = sk[wid * seg : (wid + 1) * seg]
        _, cnts = reduce_partitions(seg_k, cfg, jnp.int32(wid))
        r1_counts.append(int(jnp.sum(cnts[: cfg.reducers_per_worker])))
    print(f"[reduce] {w * cfg.reducers_per_worker} output partitions "
          f"(R1={cfg.reducers_per_worker}/worker)")

    # --- validate (paper §3.2, valsort) ---
    ks, iss, ps = valsort.slice_segments(sk, si, counts, sp)
    rep = valsort.validate(ks, iss, in_ck, ps)
    print(f"[valsort] within={rep.sorted_within} across={rep.sorted_across} "
          f"checksum={rep.checksum_match} records={rep.total_records}")
    assert rep.ok

    # --- cost model (paper §3.3.2, Table 2) ---
    paper = cloudsort_tco()
    tpu = tpu_cloudsort_tco(payload_mode=args.payload_mode)
    print(f"[cost] paper 100TB TCO  = ${paper.total:.4f} (Table 2: $96.6728)")
    print(f"[cost] TPU-256 100TB TCO (modeled, {args.payload_mode}) = "
          f"${tpu.total:.2f}")


if __name__ == "__main__":
    main()
