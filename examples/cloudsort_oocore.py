"""CloudSort out-of-core (paper §2.3–§2.5): the dataset lives in an object
store, device memory holds only one map wave.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/cloudsort_oocore.py [--records 131072]

The full paper loop, with real byte movement through the store:
gensort writes input partitions to the (filesystem-emulated S3) store;
the external-sort driver streams them through map waves with chunked
GETs, spills each worker's merged runs back to the store, and the reduce
pass ranged-GETs every run slice, k-way merges, and multipart-uploads
the final partitions; valsort streams the output back out of the store
for the ordering + checksum gates. The Table-2 TCO is then priced from
the store's *measured* GET/PUT counters — not the paper's hardcoded
6M/1M request constants.
"""
import argparse
import dataclasses
import os
import tempfile
import time

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
    )

import jax

from repro.configs.cloudsort import ooc_smoke_plan
from repro.core.cost_model import cloudsort_tco, measured_cloudsort_tco
from repro.core.external_sort import external_sort
from repro.data import gensort, valsort
from repro.io.object_store import ObjectStore


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--records", type=int, default=1 << 17)
    ap.add_argument("--store", default=None,
                    help="store root dir (default: fresh tempdir)")
    ap.add_argument("--waves", type=int, default=None,
                    help="map waves (default: from the smoke plan)")
    args = ap.parse_args()

    w = len(jax.devices())
    from repro.core.compat import make_mesh
    mesh = make_mesh((w,), ("w",))
    plan = ooc_smoke_plan()
    if args.waves:
        assert args.records % args.waves == 0, (
            f"--records {args.records} must be divisible by --waves {args.waves}")
        plan = dataclasses.replace(plan, records_per_wave=args.records // args.waves)

    root = args.store or tempfile.mkdtemp(prefix="cloudsort-store-")
    store = ObjectStore(root)
    store.create_bucket("cloudsort")
    data_bytes = args.records * plan.record_bytes

    # --- generate into the store (paper §3.2, gensort -> S3) ---
    t0 = time.time()
    in_ck, nparts = gensort.write_to_store(
        store, "cloudsort", plan.input_prefix, args.records,
        plan.input_records_per_partition, plan.payload_words,
    )
    print(f"[gen] {args.records} records -> {nparts} partitions "
          f"({data_bytes/1e6:.1f} MB) in {time.time()-t0:.2f}s checksum={in_ck}")

    # --- out-of-core sort: store -> map waves -> spill -> reduce -> store ---
    rep = external_sort(store, "cloudsort", mesh=mesh, axis_names="w", plan=plan)
    sort_s = rep.map_seconds + rep.reduce_seconds
    print(f"[sort] {rep.total_records} records in {sort_s:.2f}s "
          f"({rep.total_records/sort_s:,.0f} rec/s) — {rep.num_waves} waves, "
          f"working set {rep.working_set_records} records "
          f"({rep.oversubscription:.1f}x out-of-core)")
    print(f"[spill] {rep.spill_objects} run objects; "
          f"[reduce] {rep.output_objects} output partitions")
    assert rep.oversubscription >= 4.0, "demo must be genuinely out-of-core"

    # --- validate from the store (paper §3.2, valsort over S3 output) ---
    val = valsort.validate_from_store(
        store, "cloudsort", plan.output_prefix, in_ck)
    print(f"[valsort] within={val.sorted_within} across={val.sorted_across} "
          f"checksum={val.checksum_match} records={val.total_records}")
    assert val.ok and val.total_records == args.records

    # --- cost (paper §3.3.2): measured requests, not Table-1 constants ---
    print(f"[requests] GET={rep.stats.get_requests} PUT={rep.stats.put_requests} "
          f"read={rep.stats.bytes_read/1e6:.1f}MB "
          f"written={rep.stats.bytes_written/1e6:.1f}MB")
    paper = cloudsort_tco()
    measured = measured_cloudsort_tco(
        rep.stats, job_hours=rep.job_hours, reduce_hours=rep.reduce_hours,
        data_bytes=data_bytes,
    )
    print(f"[cost] paper 100TB TCO = ${paper.total:.4f} (Table 2: $96.6728)")
    print(f"[cost] this run (measured {rep.stats.get_requests} GETs / "
          f"{rep.stats.put_requests} PUTs, {data_bytes/1e12:.6f} TB):")
    for name, val_ in measured.rows():
        print(f"         {name:<24s} ${val_:.6f}")


if __name__ == "__main__":
    main()
