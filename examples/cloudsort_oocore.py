"""CloudSort out-of-core (paper §2.3–§2.5): the dataset lives in an object
store, device memory holds only one map wave — and the store behaves like
S3, not like a filesystem.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/cloudsort_oocore.py [--records 131072]

The full paper loop, with real byte movement through a TIERED store
(io/tiered.py): input/output live on a durable tier wrapped in the
latency + bandwidth + 503-throttling + retry middleware stack
(io/middleware.py), while spilled runs route to a fast local-SSD tier —
the paper's storage split. gensort writes input partitions through the
throttled tier; the external-sort driver streams them through map waves
with chunked GETs (decoded zero-copy into one preallocated wave buffer),
spills each worker's merged runs to the SSD tier, and the reduce
scheduler runs PARALLEL streaming merges under a global memory budget,
each fanning part-indexed multipart part uploads out of order; valsort
streams the output back out of the durable tier for the ordering +
checksum gates. The Table-2 TCO is then
priced from the durable tier's *measured*, retry-inflated GET/PUT
counters — spill traffic is free, like the paper's i4i NVMe.

Pass --no-faults for the PR-1 behaviour (clean store, no injection).
Pass --workers N to run the same job through the multi-worker cluster
executor (core/cluster.py) — N emulated workers, each with its own map
loop and reduce scheduler over its partition range; output is
byte-identical to the single-host run. Add --kill-worker I:K to inject a
worker death (worker I dies after K tasks) and watch the driver
re-execute its unfinished tasks on the survivors.
"""
import argparse
import dataclasses
import os
import tempfile
import time

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
    )

import jax

from repro.configs.cloudsort import ooc_smoke_plan, smoke_fault_profile
from repro.core.cost_model import (cloudsort_tco, measured_cloudsort_tco,
                                   measured_tiered_cloudsort_tco)
from repro.core.external_sort import external_sort
from repro.data import gensort, valsort
from repro.io.middleware import RetryPolicy
from repro.io.tiered import tiered_cloudsort_store
from repro.obs import Tracer, render_report, write_chrome_trace


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--records", type=int, default=1 << 17)
    ap.add_argument("--store", default=None,
                    help="store root dir (default: fresh tempdir)")
    ap.add_argument("--waves", type=int, default=None,
                    help="map waves (default: from the smoke plan)")
    ap.add_argument("--no-faults", action="store_true",
                    help="clean durable tier: no latency/throttle injection")
    ap.add_argument("--latency-ms", type=float, default=None,
                    help="override injected per-request latency")
    ap.add_argument("--get-rate", type=float, default=None,
                    help="override durable-tier GET tokens/s")
    ap.add_argument("--workers", type=int, default=0,
                    help="emulated cluster workers (0 = single-host driver)")
    ap.add_argument("--kill-worker", default=None, metavar="I:K",
                    help="with --workers: worker I dies after K tasks")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome trace-event JSON of the run "
                         "(load in chrome://tracing or ui.perfetto.dev)")
    args = ap.parse_args()

    w = len(jax.devices())
    from repro.core.compat import make_mesh
    mesh = make_mesh((w,), ("w",))
    plan = ooc_smoke_plan()
    if args.waves:
        assert args.records % args.waves == 0, (
            f"--records {args.records} must be divisible by --waves {args.waves}")
        plan = dataclasses.replace(plan, records_per_wave=args.records // args.waves)
    # Scale the global reduce budget with the dataset so the demo
    # invariant (budget < one output partition) holds at any --records,
    # floored at one record per run per active reducer so the governor
    # can always apportion something.
    num_reducers = w * plan.reducers_per_worker
    partition_bytes = args.records // num_reducers * plan.record_bytes
    n_waves = max(args.records // plan.records_per_wave, 1)
    budget = max(min(plan.reduce_memory_budget_bytes, partition_bytes // 2),
                 plan.parallel_reducers * n_waves * plan.record_bytes)
    plan = dataclasses.replace(plan, reduce_memory_budget_bytes=budget)

    faults = None if args.no_faults else smoke_fault_profile()
    if faults is not None:
        if args.latency_ms is not None:
            faults = dataclasses.replace(faults, latency_s=args.latency_ms / 1e3)
        if args.get_rate is not None:
            faults = dataclasses.replace(faults, get_rate=args.get_rate)

    root = args.store or tempfile.mkdtemp(prefix="cloudsort-store-")
    # One tracer shared by the job and the store stack: store request
    # attempts become tier-tagged child events of the issuing task.
    tracer = Tracer(job="cloudsort")
    store = tiered_cloudsort_store(
        root, spill_prefixes=(plan.spill_prefix,), faults=faults,
        retry=RetryPolicy(max_attempts=10, base_delay_s=0.01, max_delay_s=0.5),
        tracer=tracer,
    )
    store.create_bucket("cloudsort")
    data_bytes = args.records * plan.record_bytes
    mode = "clean" if faults is None else (
        f"faults: latency={faults.latency_s*1e3:.1f}ms "
        f"bw={faults.bandwidth_bps/1e6:.0f}MB/s "
        f"throttle={faults.get_rate:.0f}G/{faults.put_rate:.0f}P req/s")
    print(f"[store] tiered (durable + ssd spill) at {root} — {mode}")

    # --- generate into the store (paper §3.2, gensort -> S3) ---
    t0 = time.time()
    in_ck, nparts = gensort.write_to_store(
        store, "cloudsort", plan.input_prefix, args.records,
        plan.input_records_per_partition, plan.payload_words,
    )
    print(f"[gen] {args.records} records -> {nparts} partitions "
          f"({data_bytes/1e6:.1f} MB) in {time.time()-t0:.2f}s checksum={in_ck}")

    # --- out-of-core sort: store -> map waves -> spill -> reduce -> store ---
    if args.workers > 0:
        from repro.configs.cloudsort import cluster_smoke_plan
        from repro.core.cluster import ClusterExecutor

        # Widen the budget to the cluster-wide merge concurrency (every
        # worker's scheduler draws on the one global budget), still under
        # the demo's one-partition bound when possible.
        plan, cplan = cluster_smoke_plan(args.workers, base=plan,
                                         runs=n_waves)
        if args.kill_worker:
            idx, _, k = args.kill_worker.partition(":")
            cplan = dataclasses.replace(
                cplan, fail_after_tasks={int(idx): int(k or 1)})
        crep = ClusterExecutor(
            store, "cloudsort", mesh=mesh, axis_names="w", plan=plan,
            cluster=cplan, tracer=tracer,
        ).sort()
        rep = crep.sort
        print(f"[cluster] {crep.num_cluster_workers} workers, "
              f"{crep.map_tasks} map + {crep.reduce_tasks} reduce tasks; "
              f"confirmed per worker: {crep.per_worker_tasks}")
        if crep.failed_workers or crep.reexecuted_tasks:
            print(f"[cluster] failed workers: {crep.failed_workers} — "
                  f"{crep.reexecuted_map_tasks} map / "
                  f"{crep.reexecuted_reduce_tasks} reduce tasks "
                  "re-executed on survivors")
    else:
        rep = external_sort(store, "cloudsort", mesh=mesh, axis_names="w",
                            plan=plan, tracer=tracer)
    sort_s = rep.map_seconds + rep.reduce_seconds
    print(f"[sort] {rep.total_records} records in {sort_s:.2f}s "
          f"({rep.total_records/sort_s:,.0f} rec/s) — {rep.num_waves} waves, "
          f"working set {rep.working_set_records} records "
          f"({rep.oversubscription:.1f}x out-of-core)")
    print(f"[spill] {rep.spill_objects} run objects -> ssd tier; "
          f"[reduce] {rep.output_objects} output partitions, "
          f"{rep.runs_per_reducer}-way streaming merges x "
          f"{rep.parallel_reducers} concurrent, part fan-out "
          f"{plan.part_upload_fanout}")
    assert rep.oversubscription >= 4.0, "demo must be genuinely out-of-core"

    # --- bounded-memory reduce: measured peak vs the global budget ------
    bound = rep.reduce_memory_bound_bytes
    partition_bytes = rep.total_records // rep.num_reducers * plan.record_bytes
    print(f"[reduce-mem] peak merge buffer {rep.reduce_peak_merge_bytes/1e3:.1f} KB "
          f"across {rep.parallel_reducers} concurrent merges <= "
          f"budget {bound/1e3:.1f} KB (per-run chunk "
          f"{rep.reduce_chunk_bytes/1e3:.1f} KB; one partition would be "
          f"{partition_bytes/1e3:.1f} KB)")
    assert rep.reduce_peak_merge_bytes <= bound, (
        rep.reduce_peak_merge_bytes, bound)
    assert bound < partition_bytes, "bound must beat materializing a partition"
    if rep.reduce_chunk_bytes_max > rep.reduce_chunk_bytes:
        print(f"[reduce-mem] adaptive governor: per-run chunk grew "
              f"{rep.reduce_chunk_bytes/1e3:.1f} KB -> "
              f"{rep.reduce_chunk_bytes_max/1e3:.1f} KB as reducers "
              "retired (budget re-apportioned to the tail)")

    # --- spans / per-tier traffic / requests: the obs renderer ----------
    for line in render_report(rep):
        print(line)

    # --- validate from the store (paper §3.2, valsort over S3 output) ---
    val = valsort.validate_from_store(
        store, "cloudsort", plan.output_prefix, in_ck)
    print(f"[valsort] within={val.sorted_within} across={val.sorted_across} "
          f"checksum={val.checksum_match} records={val.total_records}")
    assert val.ok and val.total_records == args.records

    # --- cost (paper §3.3.2): measured requests, not Table-1 constants ---
    paper = cloudsort_tco()
    if rep.tier_stats is not None:
        measured = measured_tiered_cloudsort_tco(
            rep.tier_stats, job_hours=rep.job_hours,
            reduce_hours=rep.reduce_hours, data_bytes=data_bytes)
        billed = rep.tier_stats["durable"]
    else:
        measured = measured_cloudsort_tco(
            rep.stats, job_hours=rep.job_hours, reduce_hours=rep.reduce_hours,
            data_bytes=data_bytes)
        billed = rep.stats
    print(f"[cost] paper 100TB TCO = ${paper.total:.4f} (Table 2: $96.6728)")
    print(f"[cost] this run (billed durable tier: {billed.get_requests} GETs / "
          f"{billed.put_requests} PUTs incl. retries, "
          f"{data_bytes/1e12:.6f} TB; ssd spill free):")
    for name, val_ in measured.rows():
        print(f"         {name:<24s} ${val_:.6f}")

    if args.trace_out:
        tr = write_chrome_trace(args.trace_out, tracer)
        print(f"[trace] {len(tr['traceEvents'])} events -> {args.trace_out} "
              "(open in chrome://tracing or ui.perfetto.dev)")


if __name__ == "__main__":
    main()
