"""Train a ~100M-class MoE with exoshuffle sort-dispatch for a few hundred
steps — the paper's technique inside a real training loop.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/train_moe.py --steps 300

Uses a scaled qwen2-moe family config (8 experts, top-2, sort dispatch over
the model axis of a 2x4 mesh) with the exoshuffle epoch-shuffled data
pipeline, checkpointing every 100 steps.
"""
import argparse
import dataclasses
import os
import time

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
    )

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.launch import sharding as shd
from repro.launch.dryrun import block_specs_of
from repro.models import api as mapi
from repro.train import checkpoint as ckpt
from repro.train.optimizer import OptConfig
from repro.train.train_step import TrainConfig, init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/exoshuffle_moe_ckpt")
    args = ap.parse_args()

    from repro.core.compat import make_mesh
    mesh = make_mesh((2, 4), ("data", "model"))
    # ~100M-class MoE of the qwen2-moe family, exoshuffle sort dispatch
    cfg = dataclasses.replace(
        get("qwen2-moe-a2.7b"),
        n_layers=4, d_model=256, n_heads=8, n_kv_heads=8, d_head=32,
        vocab=8192, n_experts=8, top_k=2, d_ff_expert=512, shared_d_ff=512,
        dispatch_impl="sort", moe_capacity_factor=2.0, dtype="float32",
        remat=False, attn_chunk=64, train_microbatches=1,
    )
    model0 = mapi.build(cfg, mesh=mesh, dp_axes=("data",))
    p_specs = shd.param_pspecs(cfg, model0.abstract_params(), mesh)
    model = mapi.build(cfg, mesh=mesh, dp_axes=("data",),
                       block_specs=block_specs_of(cfg, p_specs))

    tcfg = TrainConfig(opt=OptConfig(peak_lr=1e-3, warmup_steps=20,
                                     total_steps=args.steps))
    state = init_train_state(model, jax.random.PRNGKey(0), tcfg)
    state_specs = {"params": p_specs,
                   "opt": {"mu": p_specs, "nu": p_specs, "step": P()}}
    sh = jax.tree.map(lambda s: NamedSharding(mesh, s), state_specs,
                      is_leaf=lambda x: isinstance(x, P))
    state = {k: jax.device_put(state[k], sh[k]) for k in ("params", "opt")}

    step_fn = jax.jit(make_train_step(model, tcfg, mesh=mesh), donate_argnums=0)
    data = TokenPipeline(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                    global_batch=args.batch,
                                    num_samples=args.batch * 64))
    t0 = time.time()
    for step in range(args.steps):
        with mesh:
            state, m = step_fn(state, data.batch_at(step))
        if step % 25 == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss {float(m['loss']):.4f} "
                  f"({args.batch*args.seq*(step+1)/(time.time()-t0):,.0f} tok/s)")
        if (step + 1) % 100 == 0:
            ckpt.save(state, args.ckpt_dir, step + 1)
            print(f"checkpointed at {step + 1}")
    n_params = sum(x.size for x in jax.tree.leaves(state["params"]))
    print(f"done: {n_params/1e6:.1f}M params, final loss {float(m['loss']):.4f}")


if __name__ == "__main__":
    main()
