"""Bring-your-own-workload: streaming group-by aggregation on the shuffle
library (the Exoshuffle generality claim, runnable).

  PYTHONPATH=src python examples/groupby_shuffle.py [--records 131072]

Word-count in object-store clothing: skewed keyed records (group key,
value) live on a TIERED store whose durable tier injects S3 behaviour —
latency, bandwidth, 503 throttling, retries — while spills route to a
fast local-SSD tier. The job hash-partitions keys (uniform routing under
skew), pre-aggregates map-side with a combiner (repeated keys collapse
before they are spilled and shuffled), streams each output partition's
runs through the library's budget-governed cursors, and multipart-
uploads aggregated (key, count, sum) records — the record-count header
is only known at the end, so it uploads as out-of-order part 0.

None of that machinery is group-by code: staging, scheduling, the
AdaptiveBudgetGovernor, span timelines, and fault recovery are the same
library calls CloudSort uses (examples/cloudsort_oocore.py). The
operators fit in ~150 lines (src/repro/shuffle/groupby.py).

Pass --workers N for the multi-worker executor, --kill-worker I:K to
inject a worker death and watch re-execution, --no-combine to measure
what the combiner saves, --no-faults for a clean store.
"""
import argparse
import dataclasses
import tempfile
import time


def main():
    from repro.configs.cloudsort import smoke_fault_profile
    from repro.configs.groupby import SMOKE, groupby_smoke_plan
    from repro.io.middleware import RetryPolicy
    from repro.io.tiered import tiered_cloudsort_store
    from repro.obs import Tracer, render_report, write_chrome_trace
    from repro.shuffle.executor import ClusterPlan
    from repro.shuffle.groupby import (groupby_job,
                                       validate_groupby_from_store,
                                       write_groupby_input)

    ap = argparse.ArgumentParser()
    ap.add_argument("--records", type=int, default=SMOKE.records)
    ap.add_argument("--groups", type=int, default=SMOKE.num_groups)
    ap.add_argument("--skew", type=float, default=SMOKE.skew)
    ap.add_argument("--partitions", type=int, default=SMOKE.num_partitions)
    ap.add_argument("--store", default=None,
                    help="store root dir (default: fresh tempdir)")
    ap.add_argument("--no-faults", action="store_true")
    ap.add_argument("--no-combine", action="store_true",
                    help="disable the map-side combiner")
    ap.add_argument("--workers", type=int, default=0,
                    help="emulated cluster workers (0 = single-host)")
    ap.add_argument("--kill-worker", default=None, metavar="I:K",
                    help="with --workers: worker I dies after K tasks")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome trace-event JSON of the run "
                         "(load in chrome://tracing or ui.perfetto.dev)")
    args = ap.parse_args()

    plan = groupby_smoke_plan()
    faults = None if args.no_faults else smoke_fault_profile()
    root = args.store or tempfile.mkdtemp(prefix="groupby-store-")
    tracer = Tracer(job="groupby")
    store = tiered_cloudsort_store(
        root, spill_prefixes=(plan.spill_prefix,), faults=faults,
        retry=RetryPolicy(max_attempts=10, base_delay_s=0.01,
                          max_delay_s=0.5),
        tracer=tracer,
    )
    store.create_bucket("agg")
    mode = "clean" if faults is None else (
        f"faults: latency={faults.latency_s*1e3:.1f}ms "
        f"throttle={faults.get_rate:.0f}G/{faults.put_rate:.0f}P req/s")
    print(f"[store] tiered (durable + ssd spill) at {root} — {mode}")

    t0 = time.time()
    expected_counts, expected_sums = write_groupby_input(
        store, "agg", plan.input_prefix, args.records,
        SMOKE.records_per_partition, num_groups=args.groups,
        skew=args.skew, value_range=SMOKE.value_range)
    print(f"[gen] {args.records} records over {args.groups} groups "
          f"(skew {args.skew}) in {time.time()-t0:.2f}s; hottest group "
          f"holds {int(expected_counts.max())} records "
          f"({100.0 * int(expected_counts.max()) / args.records:.1f}%)")

    job = groupby_job(store, "agg", plan=plan,
                      num_partitions=args.partitions,
                      combine=not args.no_combine, tracer=tracer)
    if args.workers > 0:
        cplan = ClusterPlan(num_workers=args.workers)
        if args.kill_worker:
            idx, _, k = args.kill_worker.partition(":")
            cplan = dataclasses.replace(
                cplan, fail_after_tasks={int(idx): int(k or 1)})
        crep = job.run(cluster=cplan)
        rep = crep.report
        print(f"[cluster] {crep.num_cluster_workers} workers, "
              f"{crep.map_tasks} map + {crep.reduce_tasks} reduce tasks; "
              f"confirmed per worker: {crep.per_worker_tasks}")
        if crep.failed_workers or crep.reexecuted_tasks:
            print(f"[cluster] failed workers: {crep.failed_workers} — "
                  f"{crep.reexecuted_map_tasks} map / "
                  f"{crep.reexecuted_reduce_tasks} reduce tasks "
                  "re-executed on survivors")
    else:
        rep = job.run()

    secs = rep.map_seconds + rep.reduce_seconds
    print(f"[agg] {rep.total_records} records -> {rep.num_partitions} "
          f"partitions in {secs:.2f}s ({rep.total_records/secs:,.0f} rec/s); "
          f"{rep.num_map_tasks} map tasks, combiner "
          f"{'off' if args.no_combine else 'on'}")
    print(f"[reduce-mem] peak merge buffer "
          f"{rep.reduce_peak_merge_bytes/1e3:.1f} KB across "
          f"{rep.parallel_reducers} concurrent merges <= budget "
          f"{rep.reduce_memory_bound_bytes/1e3:.1f} KB")
    assert rep.reduce_peak_merge_bytes <= rep.reduce_memory_bound_bytes

    for line in render_report(rep):
        print(line)

    val = validate_groupby_from_store(
        store, "agg", plan.output_prefix, job.partitioner,
        expected_counts, expected_sums)
    print(f"[validate] groups={val.total_groups} "
          f"counts={val.counts_match} sums={val.sums_match} "
          f"sorted={val.keys_sorted_unique} routing={val.routing_ok}")
    assert val.ok, val

    spill = (rep.tier_stats or {}).get("ssd")
    if spill is not None:
        print(f"[combine] shuffled {spill.bytes_written/1e6:.2f} MB of "
              f"spill for {rep.total_records * plan.record_bytes/1e6:.2f} MB "
              "of input (re-run with --no-combine to compare)")

    if args.trace_out:
        tr = write_chrome_trace(args.trace_out, tracer)
        print(f"[trace] {len(tr['traceEvents'])} events -> {args.trace_out} "
              "(open in chrome://tracing or ui.perfetto.dev)")


if __name__ == "__main__":
    main()
