"""Quickstart: the exoshuffle distributed sort in ~30 lines.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/quickstart.py

Sorts 32k gensort records across an 8-worker mesh with the paper's
two-stage pipeline and validates the result with the valsort gate.
"""
import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
    )

import jax

from repro.core.streaming import streaming_sort
from repro.data import gensort, valsort


def main():
    from repro.core.compat import make_mesh
    mesh = make_mesh((len(jax.devices()),), ("w",))
    n = 8 * 4096
    keys, ids = gensort.gen_keys(0, n)
    input_checksum = tuple(int(c) for c in gensort.checksum(keys, ids))

    sorted_keys, sorted_ids, counts, overflow = jax.jit(
        lambda k, i: streaming_sort(k, i, mesh=mesh, axis_names="w",
                                    num_rounds=4, impl="pallas")
    )(keys, ids)
    assert not bool(overflow)

    segs_k, segs_i, _ = valsort.slice_segments(sorted_keys, sorted_ids, counts)
    report = valsort.validate(segs_k, segs_i, input_checksum)
    print(f"sorted {report.total_records} records on {len(jax.devices())} workers")
    print(f"valsort: within={report.sorted_within} across={report.sorted_across} "
          f"checksum={report.checksum_match}")
    assert report.ok


if __name__ == "__main__":
    main()
