"""End-to-end behaviour tests for the paper's system: the CloudSort smoke
benchmark (generate -> two-stage streaming sort -> valsort gate) and the
dry-run machinery on a small mesh covering every architecture family.
"""
import pytest

from helpers import run_with_devices


def test_cloudsort_smoke_end_to_end():
    """The paper's full pipeline at SMOKE scale (§2): gensort input, R1
    reducer ranges, streaming two-stage sort, valsort total-order +
    checksum validation."""
    run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs.cloudsort import SMOKE
from repro.core.exoshuffle import ShuffleConfig
from repro.core.streaming import streaming_sort
from repro.data import gensort, valsort

from repro.core.compat import make_mesh
mesh = make_mesh((8,), ("w",))
cfg = ShuffleConfig(num_workers=SMOKE.num_workers,
                    reducers_per_worker=SMOKE.reducers_per_worker,
                    num_rounds=SMOKE.num_rounds, impl=SMOKE.impl)
keys, ids = gensort.gen_keys(0, SMOKE.total_records)
in_ck = tuple(int(c) for c in gensort.checksum(keys, ids))
sk, si, counts, ovf = jax.jit(lambda k, i: streaming_sort(
    k, i, mesh=mesh, axis_names="w", num_rounds=cfg.num_rounds, cfg=cfg))(keys, ids)
assert not bool(ovf), "block overflow at smoke scale"
ks, iss, _ = valsort.slice_segments(sk, si, counts)
rep = valsort.validate(ks, iss, in_ck)
assert rep.ok, rep
assert rep.total_records == SMOKE.total_records
print("CloudSort smoke OK:", rep.total_records, "records")
""", timeout=900)


@pytest.mark.parametrize("arch_id", [
    "tinyllama-1.1b",      # dense / tp
    "granite-3-8b",        # dense / fsdp
    "minicpm3-4b",         # mla
    "qwen2-moe-a2.7b",     # moe / sort dispatch
    "xlstm-125m",          # ssm
    "whisper-base",        # encdec
    "hymba-1.5b",          # hybrid
])
def test_dryrun_machinery_small_mesh(arch_id):
    """lower+compile a reduced config through the real dryrun cell builders
    on a 2x4 mesh — exercises sharding rules for every family."""
    run_with_devices(f"""
import dataclasses, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get
from repro.launch import sharding as shd
from repro.launch.dryrun import block_specs_of
from repro.models import api as mapi
from repro.train.optimizer import OptConfig
from repro.train.train_step import TrainConfig, make_train_step
from repro.models.whisper import enc_len_for

from repro.core.compat import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
cfg = get("{arch_id}").reduced(d_model=128, n_heads=8, n_kv_heads=4, d_head=16,
                               vocab=512)
if cfg.is_moe:
    cfg = dataclasses.replace(cfg, dispatch_impl="sort", n_experts=16, top_k=2)
model0 = mapi.build(cfg, mesh=mesh, dp_axes=("data",))
ap = model0.abstract_params()
p_specs = shd.param_pspecs(cfg, ap, mesh)
bspecs = block_specs_of(cfg, p_specs)
model = mapi.build(cfg, mesh=mesh, dp_axes=("data",), block_specs=bspecs)
B, S = 4, 64
sd = jax.ShapeDtypeStruct
specs = {{"tokens": sd((B, S), jnp.int32), "labels": sd((B, S), jnp.int32)}}
if cfg.family == "vlm":
    specs["patch_embeds"] = sd((B, cfg.vlm_prefix, cfg.d_model), jnp.float32)
    specs["labels"] = sd((B, S + cfg.vlm_prefix), jnp.int32)
if cfg.family == "encdec":
    specs["frames"] = sd((B, enc_len_for(cfg, S), cfg.d_model), jnp.float32)
b_specs = shd.batch_pspecs(cfg, specs, mesh)
tcfg = TrainConfig(opt=OptConfig())
step = make_train_step(model, tcfg, mesh=mesh)
from repro.train.optimizer import init_opt_state
abstract = jax.eval_shape(lambda k: (lambda p: {{"params": p, "opt": init_opt_state(p)}})(model.init(k)), jax.random.PRNGKey(0))
state_specs = {{"params": p_specs, "opt": {{"mu": p_specs, "nu": p_specs, "step": P()}}}}
in_sh = (jax.tree.map(lambda s: NamedSharding(mesh, s), state_specs, is_leaf=lambda x: isinstance(x, P)),
         jax.tree.map(lambda s: NamedSharding(mesh, s), b_specs, is_leaf=lambda x: isinstance(x, P)))
c = jax.jit(step, in_shardings=in_sh, out_shardings=(in_sh[0], None),
            donate_argnums=(0,)).lower(abstract, specs).compile()
from repro.core.compat import cost_analysis
ca = cost_analysis(c)
assert ca.get("flops", 0) > 0
print("OK", "{arch_id}", int(ca.get("flops", 0)))
""", timeout=900)
