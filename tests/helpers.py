"""Test helpers: run code in a subprocess with N host devices.

jax fixes the device count at first backend init, so multi-device tests
(shard_map, all_to_all) run in fresh subprocesses with
--xla_force_host_platform_device_count set. Single-device tests run
in-process and see 1 device, as required.
"""
from __future__ import annotations

import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode})\n"
            f"--- stdout ---\n{proc.stdout[-3000:]}\n"
            f"--- stderr ---\n{proc.stderr[-3000:]}"
        )
    return proc.stdout
