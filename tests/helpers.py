"""Test helpers: run code in a subprocess with N host devices.

jax fixes the device count at first backend init, so multi-device tests
(shard_map, all_to_all) run in fresh subprocesses with
--xla_force_host_platform_device_count set. Single-device tests run
in-process and see 1 device, as required.

The driver script is piped over stdin and compiled with ``optimize=0``,
NOT passed to ``python -c``: under CI's PYTHONOPTIMIZE=1 job a ``-c``
script's ``assert`` statements (the byte-identity / valsort acceptance
gates) would be stripped and the end-to-end checks silently vacuous.
Compiling the driver at optimize=0 keeps its asserts alive while every
*imported* product module still compiles under -O — which is exactly
the split that job exists to test.
"""
from __future__ import annotations

import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# Keeps the driver's asserts even when the interpreter runs with -O.
_WRAPPER = ("import sys; _src = sys.stdin.read(); "
            "exec(compile(_src, '<run_with_devices>', 'exec', optimize=0))")


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, "-c", _WRAPPER],
        input=code,
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode})\n"
            f"--- stdout ---\n{proc.stdout[-3000:]}\n"
            f"--- stderr ---\n{proc.stderr[-3000:]}"
        )
    return proc.stdout
