"""Backends, middleware stack, and tiered store (io/backends, io/middleware,
io/tiered).

Host-only: backends are filesystem/dict + numpy-free, middlewares are
exercised with injected clocks/sleeps so throttle/retry behaviour is
deterministic (no real sleeping, no timing flakes).
"""
import os

import pytest

from store_compliance import (BACKEND_KINDS, StoreBackendCompliance,
                              make_backend)

from repro.io.backends import (FilesystemBackend, IntegrityError,
                               MemoryBackend, ObjectNotFound, SlowDown,
                               StoreStats)
from repro.io.middleware import (FaultProfile, KillSwitchMiddleware,
                                 LatencyBandwidthMiddleware,
                                 MetricsMiddleware, RetryMiddleware,
                                 RetryPolicy, ThrottlingMiddleware,
                                 fault_injected)
from repro.io.tiered import TieredStore, tiered_cloudsort_store


# ---------------------------------------------------------------------------
# backends: the same S3 contract from every data plane, pinned by ONE
# suite (tests/store_compliance.py) run against fs, mem, and the
# in-process S3 double the cloud code paths use.
# ---------------------------------------------------------------------------


@pytest.fixture(params=BACKEND_KINDS)
def backend(request, tmp_path):
    return make_backend(request.param, tmp_path)


class TestBackendCompliance(StoreBackendCompliance):
    """fs / mem / fake_s3 all speak the identical contract."""


def test_out_of_order_parts_through_middleware_stack(tmp_path):
    # The same contract through the full Retry(Metrics(Throttle(Latency)))
    # stack: each part crosses as its own billed PUT attempt; assembly and
    # etag still match a sequential upload on a bare backend.
    bare = MemoryBackend(chunk_size=64)
    bare.create_bucket("b")
    parts = [bytes([i]) * (10 + i) for i in range(4)]
    want = bare.put_multipart("b", "ref", parts)

    stacked = fault_injected(
        FilesystemBackend(str(tmp_path / "fs"), chunk_size=64),
        profile=FaultProfile(), seed=3)
    stacked.create_bucket("b")
    mp = stacked.multipart("b", "out")
    for idx in (3, 1, 2, 0):
        mp.put_part(idx, parts[idx])
    meta = mp.complete()
    assert meta.etag == want.etag and meta.size == want.size
    assert stacked.get("b", "out") == b"".join(parts)
    d = stacked.stats_snapshot()
    assert d.put_requests == 4 and d.bytes_written == sum(map(len, parts))


def test_fs_abort_sweeps_unregistered_part_files(tmp_path):
    # Filesystem-plane specific (the generic abort atomicity lives in
    # the compliance suite): an in-flight put_part that wrote its tmp
    # file but had not yet registered it when abort ran must be swept
    # by the tmp-prefix glob, not leak on disk.
    b = FilesystemBackend(str(tmp_path / "fs"), chunk_size=64)
    b.create_bucket("b")
    objdir = os.path.join(b.root, "b", "objects", "out")
    mp = b.multipart("b", "out/doomed")
    mp.put_part(0, b"registered")
    straggler = mp._part_path(9)
    with open(straggler, "wb") as f:
        f.write(b"written-but-unregistered")
    mp.abort()
    with pytest.raises(ObjectNotFound):
        b.head("b", "out/doomed")
    leftovers = os.listdir(objdir) if os.path.isdir(objdir) else []
    assert leftovers == [], leftovers


def test_integrity_error_on_corruption(tmp_path):
    b = FilesystemBackend(str(tmp_path / "fs"))
    b.create_bucket("b")
    b.put("b", "k", b"payload-bytes")
    path = b._object_path("b", "k")
    # same size, flipped byte -> CRC etag mismatch (a real exception, not
    # an assert: the check must survive python -O)
    with open(path, "r+b") as f:
        f.write(b"Xayload-bytes")
    with pytest.raises(IntegrityError, match="CRC"):
        b.get("b", "k")
    # truncation -> size mismatch
    with open(path, "wb") as f:
        f.write(b"short")
    with pytest.raises(IntegrityError, match="size"):
        b.get("b", "k")


# ---------------------------------------------------------------------------
# metrics middleware
# ---------------------------------------------------------------------------


def _metered():
    s = MetricsMiddleware(MemoryBackend(chunk_size=64))
    s.create_bucket("b")
    return s


def test_metrics_counts_deletes():
    s = _metered()
    s.put("b", "k", b"d")
    s.delete("b", "k")
    d = s.stats_snapshot()
    assert d.delete_requests == 1 and d.put_requests == 1


def test_zero_length_get_chunks_issues_no_request():
    s = _metered()
    s.put("b", "empty", b"")
    before = s.stats_snapshot()
    assert list(s.get_chunks("b", "empty")) == []
    d = s.stats_snapshot() - before
    assert d.get_requests == 0 and d.bytes_read == 0  # S3: no ranged GET
    assert d.head_requests == 1  # sizing is metadata, free in Table 2


def test_metrics_multipart_counts_per_part():
    s = _metered()
    mp = s.multipart("b", "out")
    mp.put_part(0, b"x" * 10)
    mp.put_part(1, b"y" * 20)
    mp.complete()
    d = s.stats_snapshot()
    assert d.put_requests == 2 and d.bytes_written == 30


# ---------------------------------------------------------------------------
# latency / throttle / retry (injected clocks: deterministic)
# ---------------------------------------------------------------------------


def test_latency_middleware_accounts_stall():
    sleeps = []
    s = LatencyBandwidthMiddleware(
        MemoryBackend(), FaultProfile(latency_s=0.25, bandwidth_bps=100.0),
        sleep=sleeps.append)
    s.create_bucket("b")
    s.put("b", "k", b"x" * 50)  # upload: latency + 50B/100Bps = 0.75
    s.get("b", "k")  # download: latency pre + 0.5 post
    assert sleeps == [0.25 + 0.5, 0.25, 0.5]
    assert s.stats.stall_seconds == pytest.approx(1.5)


def test_throttle_raises_slowdown_and_refills():
    clock = [0.0]
    s = ThrottlingMiddleware(
        MemoryBackend(), FaultProfile(get_rate=1.0, put_rate=0.0, burst=1.0),
        clock=lambda: clock[0])
    s.create_bucket("b")
    s.put("b", "k", b"d")  # put_rate=0: writes unlimited
    assert s.get("b", "k") == b"d"  # burst token
    with pytest.raises(SlowDown):
        s.get("b", "k")
    clock[0] += 1.0  # 1 req/s refill
    assert s.get("b", "k") == b"d"


def test_retry_recovers_from_throttle_and_counts():
    # The retry sleep *advances the throttle's clock*, so backoff is what
    # refills the token bucket — a closed deterministic loop.
    clock = [0.0]

    def sleep(seconds):
        clock[0] += seconds

    stats = StoreStats()
    inner = ThrottlingMiddleware(
        MemoryBackend(), FaultProfile(get_rate=5.0, burst=1.0),
        clock=lambda: clock[0])
    s = RetryMiddleware(
        MetricsMiddleware(inner, stats=stats),
        RetryPolicy(max_attempts=8, base_delay_s=0.1, max_delay_s=1.0, jitter=0.0),
        stats=stats, sleep=sleep)
    s.create_bucket("b")
    s.put("b", "k", b"data")
    for _ in range(4):
        assert s.get("b", "k") == b"data"
    d = s.stats_snapshot()
    assert d.retries > 0 and d.throttled == d.retries
    # retry-inflated: every throttled attempt was an issued GET request
    assert d.get_requests == 4 + d.throttled


def test_retry_exhaustion_reraises_slowdown():
    clock = [0.0]
    s = RetryMiddleware(
        ThrottlingMiddleware(MemoryBackend(),
                             FaultProfile(get_rate=1e-9, burst=1.0),
                             clock=lambda: clock[0]),
        RetryPolicy(max_attempts=3, base_delay_s=0.01, jitter=0.0),
        sleep=lambda s_: None)
    s.create_bucket("b")
    s.put("b", "k", b"d")
    assert s.get("b", "k") == b"d"  # burst token
    with pytest.raises(SlowDown):
        s.get("b", "k")
    assert s.stats.retries == 2  # max_attempts - 1 re-issues


def test_fault_injected_without_retry_exposes_slowdown():
    s = fault_injected(MemoryBackend(),
                       profile=FaultProfile(get_rate=1e-9, burst=1.0),
                       retry=None)
    s.create_bucket("b")
    s.put("b", "k", b"d")
    assert s.get("b", "k") == b"d"
    with pytest.raises(SlowDown):
        s.get("b", "k")
    assert s.stats_snapshot().throttled == 1


# ---------------------------------------------------------------------------
# tiered store
# ---------------------------------------------------------------------------


def _tiered():
    durable = MetricsMiddleware(MemoryBackend(chunk_size=64))
    ssd = MetricsMiddleware(MemoryBackend(chunk_size=64))
    t = TieredStore(durable, ssd, ssd_prefixes=("spill/",))
    t.create_bucket("b")
    return t, durable, ssd


def test_tiered_routes_spill_vs_durable_keys():
    t, durable, ssd = _tiered()
    t.put("b", "spill/wave-0/w-0", b"run-bytes")
    t.put("b", "input/p0", b"in-bytes")
    t.put("b", "output/p0", b"out-bytes")
    # physical placement: spill only in the ssd tier, the rest durable
    assert ssd.inner.head("b", "spill/wave-0/w-0").size == 9
    assert durable.inner.head("b", "input/p0").size == 8
    with pytest.raises(ObjectNotFound):
        durable.inner.head("b", "spill/wave-0/w-0")
    with pytest.raises(ObjectNotFound):
        ssd.inner.head("b", "input/p0")
    # reads route back transparently
    assert t.get("b", "spill/wave-0/w-0") == b"run-bytes"
    assert t.get_range("b", "input/p0", 0, 2) == b"in"
    per = t.per_tier_stats()
    assert per["ssd"].put_requests == 1 and per["durable"].put_requests == 2
    assert per["ssd"].get_requests == 1 and per["durable"].get_requests == 1


def test_tiered_list_merges_namespaces_key_sorted():
    t, _, _ = _tiered()
    for k in ["spill/w0", "input/p1", "input/p0", "output/p0"]:
        t.put("b", k, b"d")
    assert [m.key for m in t.list_objects("b")] == [
        "input/p0", "input/p1", "output/p0", "spill/w0"]
    assert [m.key for m in t.list_objects("b", "spill/")] == ["spill/w0"]
    assert [m.key for m in t.list_objects("b", "input/")] == ["input/p0", "input/p1"]


def test_tiered_delete_routes_and_sums_stats():
    t, _, _ = _tiered()
    t.put("b", "spill/w0", b"d")
    t.put("b", "input/p0", b"d")
    t.delete("b", "spill/w0")
    t.delete("b", "input/p0")
    per = t.per_tier_stats()
    assert per["ssd"].delete_requests == 1
    assert per["durable"].delete_requests == 1
    total = t.stats_snapshot()
    assert total.delete_requests == 2 and total.put_requests == 2


def test_tiered_builder_places_tiers_on_disk(tmp_path):
    t = tiered_cloudsort_store(str(tmp_path), faults=None)
    t.create_bucket("b")
    t.put("b", "spill/w0", b"run")
    t.put("b", "input/p0", b"in")
    assert os.path.isfile(os.path.join(str(tmp_path), "ssd", "b",
                                       "objects", "spill", "w0"))
    assert os.path.isfile(os.path.join(str(tmp_path), "durable", "b",
                                       "objects", "input", "p0"))


def test_tiered_builder_fault_stack_only_on_durable_tier(tmp_path):
    # Tight write bucket: durable puts throttle (and get retried away),
    # spill puts never do — local SSD has no 503s.
    store = tiered_cloudsort_store(
        str(tmp_path),
        faults=FaultProfile(put_rate=5.0, burst=1.0),
        retry=RetryPolicy(max_attempts=10, base_delay_s=0.005,
                          max_delay_s=0.05, jitter=0.0))
    store.create_bucket("b")
    for i in range(4):
        store.put("b", f"input/p{i}", b"x")
        store.put("b", f"spill/w{i}", b"y")
    per = store.per_tier_stats()
    assert per["durable"].retries > 0  # real sleeps, but ~tens of ms total
    assert per["ssd"].retries == 0 and per["ssd"].throttled == 0
    assert per["ssd"].put_requests == 4
    assert per["durable"].put_requests == 4 + per["durable"].throttled


# ---------------------------------------------------------------------------
# Kill switch: request-budget kills are pre-commit-deterministic
# ---------------------------------------------------------------------------


def test_kill_switch_refuses_commits_after_trip(backend):
    dead = KillSwitchMiddleware(
        backend, exc_factory=lambda: RuntimeError("host dead"))
    mp = dead.multipart("b", "out/p0", metadata={"reducer": 0})
    mp.put_part(0, b"aaaa")
    mp.put_part(1, b"bb")
    dead.trip()
    # A commit that BEGINS after the trip can never land: the task will
    # be re-executed elsewhere, and a late duplicate commit from this
    # host would race it.
    with pytest.raises(RuntimeError, match="host dead"):
        mp.complete()
    with pytest.raises(ObjectNotFound):
        backend.head("b", "out/p0")
    mp.abort()  # cleanup outlives the host — no stray sessions


def test_kill_switch_budget_trip_fences_open_sessions(backend):
    # The request BUDGET (FaultyWorker's fail_after_requests) must give
    # the same guarantee as an explicit trip(): once the budget request
    # raises, a commit through an already-open session is refused, so a
    # "worker died after N requests" schedule can never half-land — the
    # kill point is strictly before or strictly after the durable commit.
    view = KillSwitchMiddleware(
        backend, exc_factory=lambda: RuntimeError("host dead"),
        fail_after_requests=3)
    mp = view.multipart("b", "out/p1")
    mp.put_part(0, b"cccc")          # budget 3 -> 2
    view.put("b", "scratch/x", b"s")  # 2 -> 1
    view.get("b", "scratch/x")        # 1 -> 0
    with pytest.raises(RuntimeError, match="host dead"):
        view.get("b", "scratch/x")    # trips
    assert view.tripped
    with pytest.raises(RuntimeError, match="host dead"):
        mp.complete()
    with pytest.raises(ObjectNotFound):
        backend.head("b", "out/p1")


def test_kill_switch_commit_before_trip_is_durable(backend):
    view = KillSwitchMiddleware(
        backend, exc_factory=lambda: RuntimeError("host dead"))
    mp = view.multipart("b", "out/p2")
    mp.put_part(0, b"dddd")
    meta = mp.complete()  # commit strictly before the kill: durable
    view.trip()
    assert backend.head("b", "out/p2").etag == meta.etag
    assert backend.get("b", "out/p2") == b"dddd"


def test_kill_switch_fences_sessions_opened_above_it(backend):
    # The gate chains DOWN the middleware stack: a session opened through
    # an outer metrics layer is still refused when the kill switch
    # beneath it trips.
    stats = StoreStats()
    inner = KillSwitchMiddleware(
        backend, exc_factory=lambda: RuntimeError("host dead"))
    outer = MetricsMiddleware(inner, stats=stats)
    mp = outer.multipart("b", "out/p3")
    mp.put_part(0, b"eeee")
    inner.trip()
    with pytest.raises(RuntimeError, match="host dead"):
        mp.complete()
    with pytest.raises(ObjectNotFound):
        backend.head("b", "out/p3")
