"""Out-of-core external sort, end-to-end through the object store.

The CloudSort loop of examples/cloudsort_oocore.py at test scale, on 8
subprocess host devices: gensort -> store -> map waves (chunked GETs) ->
spill -> ranged-GET reduce merge -> multipart upload -> valsort from the
store, with request-accounting assertions on every leg.
"""
import pytest

from helpers import run_with_devices

SETUP = """
import tempfile
import numpy as np
import jax, jax.numpy as jnp
from repro.core.external_sort import ExternalSortPlan, external_sort
from repro.data import gensort, valsort
from repro.io.object_store import ObjectStore

from repro.core.compat import make_mesh
mesh = make_mesh((8,), ("w",))
plan = ExternalSortPlan(
    records_per_wave=1 << 13,
    num_rounds=2,
    reducers_per_worker=2,
    payload_words=2,
    impl="ref",
    input_records_per_partition=1 << 12,
    output_part_records=1 << 11,
    store_chunk_bytes=16 << 10,
)
N = 1 << 15  # 4 waves -> 4x out-of-core oversubscription
store = ObjectStore(tempfile.mkdtemp(prefix="extsort-test-"))
store.create_bucket("sort")
in_ck, nparts = gensort.write_to_store(
    store, "sort", plan.input_prefix, N,
    plan.input_records_per_partition, plan.payload_words)
"""


def test_external_sort_valsort_gate():
    run_with_devices(SETUP + """
rep = external_sort(store, "sort", mesh=mesh, axis_names="w", plan=plan)
assert rep.total_records == N
assert rep.num_waves == 4 and rep.num_workers == 8
assert rep.oversubscription >= 4.0  # dataset never fits one wave
assert rep.spill_objects == 4 * 8 and rep.output_objects == 16

# the paper's three valsort gates, streamed back out of the store
val = valsort.validate_from_store(store, "sort", plan.output_prefix, in_ck)
assert val.ok, val
assert val.total_records == N
print("OK")
""")


def test_request_accounting_matches_protocol():
    run_with_devices(SETUP + """
gen_stats = store.stats_snapshot()
assert gen_stats.put_requests == nparts  # one PUT per input partition

rep = external_sort(store, "sort", mesh=mesh, axis_names="w", plan=plan)
s = rep.stats
# map downloads: every input byte re-read in store_chunk_bytes ranged GETs
part_bytes = 16 + N // nparts * plan.record_bytes
chunks_per_part = -(-part_bytes // plan.store_chunk_bytes)
# reduce fetches: <= one ranged GET per (wave, reducer) run slice
reduce_gets_max = rep.num_waves * rep.num_reducers
assert s.get_requests >= nparts * chunks_per_part
assert s.get_requests <= nparts * chunks_per_part + reduce_gets_max
# writes: one PUT per spilled run + >= one per multipart output part
assert s.put_requests >= rep.spill_objects + rep.output_objects
assert s.bytes_written >= 2 * N * plan.record_bytes  # spill + output legs
assert s.bytes_read >= 2 * N * plan.record_bytes     # map + reduce legs

# measured requests flow into the TCO (not the paper's 6M/1M constants)
from repro.core.cost_model import measured_cloudsort_tco
tco = measured_cloudsort_tco(s, job_hours=rep.job_hours,
                             reduce_hours=rep.reduce_hours,
                             data_bytes=N * plan.record_bytes)
from repro.core.cost_model import Ec2CostParams
p = Ec2CostParams()
assert tco.access_get == p.get_per_1000 * s.get_requests / 1000
assert tco.access_put == p.put_per_1000 * s.put_requests / 1000
print("OK")
""")


def test_single_round_and_wide_reducers():
    # num_rounds=1 degenerates to one-shot waves; R1=4 exercises ranged
    # reduce GETs over sub-worker slices.
    run_with_devices(SETUP.replace("num_rounds=2", "num_rounds=1")
                           .replace("reducers_per_worker=2",
                                    "reducers_per_worker=4") + """
rep = external_sort(store, "sort", mesh=mesh, axis_names="w", plan=plan)
assert rep.output_objects == 8 * 4
val = valsort.validate_from_store(store, "sort", plan.output_prefix, in_ck)
assert val.ok, val
print("OK")
""")


def test_throttled_tiered_store_completes_with_retries():
    # The acceptance run of ISSUE 2: latency + 503 throttling on the
    # durable tier, spill routed to the SSD tier, streaming reduce — and
    # the sort must still validate clean, with the absorbed faults visible
    # in the stats.
    run_with_devices("""
import tempfile
import jax
from repro.core.external_sort import ExternalSortPlan, external_sort
from repro.data import gensort, valsort
from repro.io.middleware import FaultProfile, RetryPolicy
from repro.io.tiered import tiered_cloudsort_store

from repro.core.compat import make_mesh
mesh = make_mesh((8,), ("w",))
plan = ExternalSortPlan(
    records_per_wave=1 << 13,
    num_rounds=2,
    reducers_per_worker=2,
    payload_words=2,
    impl="ref",
    input_records_per_partition=1 << 12,
    output_part_records=1 << 11,
    store_chunk_bytes=8 << 10,
    merge_chunk_bytes=4 << 10,
)
N = 1 << 15
store = tiered_cloudsort_store(
    tempfile.mkdtemp(prefix="extsort-faulty-"),
    spill_prefixes=(plan.spill_prefix,),
    faults=FaultProfile(latency_s=0.001, bandwidth_bps=400e6,
                        get_rate=60.0, put_rate=40.0, burst=8.0),
    retry=RetryPolicy(max_attempts=12, base_delay_s=0.01, max_delay_s=0.25),
)
store.create_bucket("sort")
in_ck, nparts = gensort.write_to_store(
    store, "sort", plan.input_prefix, N,
    plan.input_records_per_partition, plan.payload_words)

rep = external_sort(store, "sort", mesh=mesh, axis_names="w", plan=plan)
val = valsort.validate_from_store(store, "sort", plan.output_prefix, in_ck)
assert val.ok and val.total_records == N, val

# faults were really injected and really absorbed: retries show in stats
s = rep.stats
assert s.retries > 0 and s.throttled > 0, s
# every throttle came from a (re-)issued attempt; >= covers the rare case
# where one op exhausts the store-level budget and staging re-reads it
assert s.throttled >= s.retries
assert s.stall_seconds > 0
# retry inflation: durable GET attempts > the billed-clean count would be
d = rep.tier_stats["durable"]
assert d.get_requests > nparts  # at least the map chunk GETs, inflated
assert d.retries == s.retries  # only the durable tier has a fault stack

# tier routing: all spill traffic on the SSD tier, none durable
ssd = rep.tier_stats["ssd"]
assert ssd.put_requests == rep.spill_objects
assert ssd.throttled == 0 and ssd.retries == 0
assert ssd.get_requests > 0  # streaming reduce fetches run chunks from ssd
assert d.bytes_written > 0   # output partitions land durable
print("OK", s.retries, "retries absorbed")
""", timeout=900)


def test_streaming_reduce_peak_memory_bounded_by_chunk_sweep():
    # Peak merge memory must scale with merge_chunk_bytes (runs x chunk),
    # not with partition size: sweep the chunk size on the same dataset.
    # parallel_reducers=1 isolates the per-merge contract; the global
    # budget governor has its own test below.
    run_with_devices(SETUP + """
import dataclasses
partition_bytes = N // (8 * plan.reducers_per_worker) * plan.record_bytes
peaks = {}
for chunk in (1 << 12, 1 << 14):
    p = dataclasses.replace(plan, merge_chunk_bytes=chunk, parallel_reducers=1)
    rep = external_sort(store, "sort", mesh=mesh, axis_names="w", plan=p)
    val = valsort.validate_from_store(store, "sort", p.output_prefix, in_ck)
    assert val.ok, (chunk, val)
    assert rep.runs_per_reducer == rep.num_waves == 4
    # the contract: peak <= runs x chunk, and the bound is real (nonzero)
    assert 0 < rep.reduce_peak_merge_bytes <= rep.runs_per_reducer * chunk, rep
    peaks[chunk] = rep.reduce_peak_merge_bytes
# the bound binds: a smaller chunk budget means a smaller measured peak,
# and the small-chunk peak sits well under one output partition
assert peaks[1 << 12] < peaks[1 << 14]
assert peaks[1 << 12] < partition_bytes, (peaks, partition_bytes)
print("OK", peaks)
""")


def test_parallel_reduce_deterministic_and_budget_bounded():
    # The scheduler contract (ISSUE 3): parallel_reducers=4 must produce
    # output objects byte-identical (same CRC etag, size, part count) to
    # parallel_reducers=1, and the measured all-reducer peak merge memory
    # must respect the global reduce_memory_budget_bytes.
    run_with_devices(SETUP + """
import dataclasses
budget = 16 << 10  # < one output partition (32 KiB at these parameters)
partition_bytes = N // (8 * plan.reducers_per_worker) * plan.record_bytes
assert budget < partition_bytes
etags = {}
for par in (1, 4):
    p = dataclasses.replace(plan, parallel_reducers=par,
                            reduce_memory_budget_bytes=budget,
                            part_upload_fanout=1 if par == 1 else 3)
    rep = external_sort(store, "sort", mesh=mesh, axis_names="w", plan=p)
    assert rep.parallel_reducers == par
    assert 0 < rep.reduce_peak_merge_bytes <= budget, rep
    assert rep.reduce_memory_bound_bytes == budget
    val = valsort.validate_from_store(store, "sort", p.output_prefix, in_ck)
    assert val.ok, (par, val)
    etags[par] = [(m.key, m.etag, m.size, m.parts)
                  for m in store.list_objects("sort", p.output_prefix)]
    assert len(etags[par]) == 16
# byte-identical partitions: same keys, same CRC etags, same part layout
assert etags[1] == etags[4], (etags[1], etags[4])
# the span timeline measured real overlapped reduce work
assert rep.phase_seconds.get("reduce.merge", 0) > 0
assert rep.phase_seconds.get("reduce.upload", 0) > 0
assert rep.phase_seconds.get("map.compute", 0) > 0
print("OK", etags[4][:2])
""")


def test_adaptive_governor_grows_chunks_and_never_exceeds_budget():
    # The ISSUE-4 governor contract: reducers retire at staggered times
    # (16 partitions on a width-3 scheduler — one straggler always runs
    # alone at the tail), freed budget is re-apportioned so live merges'
    # chunks GROW mid-merge, and the measured all-reducer peak still
    # never exceeds the global budget. Bytes must not change vs. the
    # uncapped run (chunking is invisible in the output).
    run_with_devices(SETUP + """
import dataclasses
rep0 = external_sort(store, "sort", mesh=mesh, axis_names="w", plan=plan)
want = [(m.key, m.etag, m.size, m.parts)
        for m in store.list_objects("sort", plan.output_prefix)]

budget = 16 << 10
p = dataclasses.replace(plan, parallel_reducers=3,
                        reduce_memory_budget_bytes=budget,
                        merge_chunk_bytes=16 << 10)
rep = external_sort(store, "sort", mesh=mesh, axis_names="w", plan=p)
val = valsort.validate_from_store(store, "sort", p.output_prefix, in_ck)
assert val.ok, val
got = [(m.key, m.etag, m.size, m.parts)
       for m in store.list_objects("sort", p.output_prefix)]
assert got == want, "budget governance changed output bytes"
# the hard bound: measured peak under the budget at every instant
assert 0 < rep.reduce_peak_merge_bytes <= budget, rep
assert rep.reduce_memory_bound_bytes == budget
# adaptivity observed: the governor granted a bigger chunk than the
# static split once siblings retired (static would pin chunk_bytes)
assert rep.reduce_chunk_bytes == (budget // 3) // rep.num_waves
assert rep.reduce_chunk_bytes_max > rep.reduce_chunk_bytes, rep
# and the growth is still capped by the plan's merge_chunk_bytes
assert rep.reduce_chunk_bytes_max <= p.merge_chunk_bytes
print("OK", rep.reduce_chunk_bytes, "->", rep.reduce_chunk_bytes_max)
""")


def test_validate_from_store_catches_corruption():
    run_with_devices(SETUP + """
rep = external_sort(store, "sort", mesh=mesh, axis_names="w", plan=plan)
# flip one payload word of one output partition, re-upload, re-validate
key = store.list_objects("sort", plan.output_prefix)[3].key
from repro.io import records as rec
k, i, p = rec.decode_records(store.get("sort", key))
p = p.copy(); p[7, 1] ^= 1
store.put("sort", key, rec.encode_records(k, i, p))
val = valsort.validate_from_store(store, "sort", plan.output_prefix, in_ck)
assert not val.checksum_match and not val.ok
# and an ordering violation in a different partition is caught too
key2 = store.list_objects("sort", plan.output_prefix)[5].key
k, i, p = rec.decode_records(store.get("sort", key2))
k = k.copy(); k[0], k[-1] = k[-1], k[0]
store.put("sort", key2, rec.encode_records(k, i, p))
val = valsort.validate_from_store(store, "sort", plan.output_prefix, in_ck)
assert not val.sorted_within
print("OK")
""")
