"""flash_attention vs naive softmax reference: causal, windowed, sinks,
non-causal, GQA, distinct v-dim, ragged lengths, causal_skip."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import decode_attention, flash_attention

RNG = np.random.default_rng(7)


def naive(q, k, v, *, causal=True, window=0, n_sink=0):
    b, sq, h, dh = q.shape
    kv = k.shape[2]
    g = h // kv
    qf = q.astype(np.float32).reshape(b, sq, kv, g, dh)
    s = np.einsum("bqkgd,bskd->bkgqs", qf, np.asarray(k, np.float32)) * dh**-0.5
    qpos = np.arange(sq)[:, None]
    kpos = np.arange(k.shape[1])[None, :]
    mask = np.ones((sq, k.shape[1]), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        in_w = kpos > qpos - window
        if n_sink:
            in_w |= kpos < n_sink
        mask &= in_w
    s = np.where(mask, s, -1e30)
    p = np.exp(s - s.max(-1, keepdims=True))
    p = p / np.maximum(p.sum(-1, keepdims=True), 1e-30)
    out = np.einsum("bkgqs,bskd->bqkgd", p, np.asarray(v, np.float32))
    return out.reshape(b, sq, h, v.shape[-1])


def make(b=2, sq=64, sk=64, h=4, kv=2, dh=16, dv=None):
    dv = dv or dh
    q = jnp.asarray(RNG.normal(size=(b, sq, h, dh)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, sk, kv, dh)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, sk, kv, dv)), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_causal_matches_naive(chunk):
    q, k, v = make()
    out = flash_attention(q, k, v, causal=True, chunk=chunk)
    np.testing.assert_allclose(out, naive(q, k, v), rtol=2e-5, atol=2e-5)


def test_gqa_and_vdim():
    q, k, v = make(h=8, kv=2, dh=24, dv=16)
    out = flash_attention(q, k, v, causal=True, chunk=16)
    np.testing.assert_allclose(out, naive(q, k, v), rtol=2e-5, atol=2e-5)


def test_window_and_sink():
    q, k, v = make(sq=96, sk=96)
    out = flash_attention(q, k, v, causal=True, window=24, chunk=16, n_sink=4)
    np.testing.assert_allclose(
        out, naive(q, k, v, window=24, n_sink=4), rtol=2e-5, atol=2e-5
    )


def test_noncausal_cross():
    q, k, v = make(sq=48, sk=80)
    out = flash_attention(q, k, v, causal=False, chunk=16)
    np.testing.assert_allclose(out, naive(q, k, v, causal=False), rtol=2e-5,
                               atol=2e-5)


@pytest.mark.parametrize("sq", [3, 17, 33, 50])
def test_ragged_lengths(sq):
    q, k, v = make(sq=sq, sk=sq)
    out = flash_attention(q, k, v, causal=True, chunk=16)
    np.testing.assert_allclose(out, naive(q, k, v), rtol=2e-5, atol=2e-5)


def test_ragged_noncausal():
    q, k, v = make(sq=10, sk=37)
    out = flash_attention(q, k, v, causal=False, chunk=16)
    np.testing.assert_allclose(out, naive(q, k, v, causal=False), rtol=2e-5,
                               atol=2e-5)


def test_causal_skip_identical():
    q, k, v = make(sq=64, sk=64)
    base = flash_attention(q, k, v, causal=True, chunk=16)
    skip = flash_attention(q, k, v, causal=True, chunk=16, causal_skip=True)
    np.testing.assert_allclose(base, skip, rtol=1e-6, atol=1e-6)


def test_decode_matches_last_row():
    q, k, v = make(sq=32, sk=32)
    full = flash_attention(q, k, v, causal=True, chunk=16)
    out = decode_attention(q[:, -1:], k, v, cache_len=jnp.int32(32))
    np.testing.assert_allclose(out[:, 0], full[:, -1], rtol=2e-5, atol=2e-5)


def test_grad_finite():
    q, k, v = make(sq=32, sk=32)

    def f(q, k, v):
        return jnp.sum(flash_attention(q, k, v, causal=True, chunk=8) ** 2)

    g = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    for t in g:
        assert bool(jnp.isfinite(t).all())


@pytest.mark.parametrize("window", [0, 24])
def test_qfull_mode_matches_naive(window):
    """q_chunk=0 (no global q-chunk loop — the attn_sharding='qfull' path)
    must be numerically identical to the chunked grid and the reference."""
    q, k, v = make(sq=50, sk=50)
    out = flash_attention(q, k, v, causal=True, window=window, chunk=16,
                          q_chunk=0)
    ref = naive(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)
    grid = flash_attention(q, k, v, causal=True, window=window, chunk=16)
    np.testing.assert_allclose(out, grid, rtol=1e-6, atol=1e-6)


def test_qfull_with_sink_tokens():
    q, k, v = make(sq=64, sk=64)
    out = flash_attention(q, k, v, causal=True, window=24, n_sink=8,
                          chunk=16, q_chunk=0)
    ref = naive(q, k, v, causal=True, window=24, n_sink=8)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


def test_additive_bias_fully_masked_chunk_is_zero():
    """A chunk whose every key is masked (e.g. a strictly-future kv chunk
    under causal masking) must leave acc/l at 0 and produce no NaN — the
    alpha/row_live guards in _attn_chunk_step."""
    from repro.models.attention import NEG_INF, _attn_chunk_step

    b, cq, ck, kv, g, dh = 1, 4, 4, 1, 2, 8
    q = jnp.asarray(RNG.normal(size=(b, cq, kv, g, dh)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, ck, kv, dh)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, ck, kv, dh)), jnp.float32)
    acc = jnp.zeros((b, kv, g, cq, dh), jnp.float32)
    m = jnp.full((b, kv, g, cq), NEG_INF, jnp.float32)
    l = jnp.zeros((b, kv, g, cq), jnp.float32)
    q_pos = jnp.arange(4, dtype=jnp.int32)          # rows 0..3
    k_pos = jnp.arange(100, 104, dtype=jnp.int32)   # all keys in the future
    acc2, m2, l2 = _attn_chunk_step(acc, m, l, q, k, v, q_pos, k_pos,
                                    causal=True, window=0, scale=1.0)
    assert not bool(jnp.isnan(acc2).any())
    np.testing.assert_array_equal(np.asarray(acc2), 0.0)
    np.testing.assert_array_equal(np.asarray(l2), 0.0)
