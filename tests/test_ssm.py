"""Property tests for the gated-linear-attention engine: the chunked
(parallel, training) form must equal the step (recurrent, decode) form for
arbitrary shapes, chunk sizes and gate values — the system invariant that
makes long_500k decode trustworthy."""
import jax.numpy as jnp
import numpy as np
import pytest

hp = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")

from repro.models.ssm import causal_conv1d, gla_chunked, gla_step


@hp.given(
    t=st.integers(1, 70),
    chunk=st.sampled_from([4, 8, 16, 32]),
    dk=st.sampled_from([4, 8]),
    dv=st.sampled_from([4, 16]),
    normalize=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
@hp.settings(max_examples=30, deadline=None)
def test_chunked_equals_stepwise(t, chunk, dk, dv, normalize, seed):
    rng = np.random.default_rng(seed)
    B, H = 2, 3
    q = jnp.asarray(rng.normal(size=(B, H, t, dk)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, H, t, dk)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, H, t, dv)), jnp.float32)
    a = jnp.asarray(-np.abs(rng.normal(size=(B, H, t))), jnp.float32)
    b = jnp.asarray(-np.abs(rng.normal(size=(B, H, t))), jnp.float32)

    y_chunk, (s_f, n_f) = gla_chunked(q, k, v, a, b, chunk=chunk,
                                      normalize=normalize)
    state = (jnp.zeros((B, H, dk, dv)), jnp.zeros((B, H, dk)))
    ys = []
    for i in range(t):
        y, state = gla_step(q[:, :, i], k[:, :, i], v[:, :, i],
                            a[:, :, i], b[:, :, i], state, normalize=normalize)
        ys.append(y)
    y_seq = jnp.stack(ys, axis=2)
    np.testing.assert_allclose(y_chunk, y_seq, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(s_f, state[0], rtol=2e-4, atol=2e-4)


@hp.given(
    t=st.integers(1, 50),
    split=st.integers(0, 50),
    kk=st.sampled_from([2, 4]),
    seed=st.integers(0, 2**31 - 1),
)
@hp.settings(max_examples=20, deadline=None)
def test_conv_segment_invariance(t, split, kk, seed):
    split = min(split, t)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(2, t, 5)), jnp.float32)
    kern = jnp.asarray(rng.normal(size=(kk, 5)), jnp.float32)
    full, _ = causal_conv1d(x, kern)
    y1, st1 = causal_conv1d(x[:, :split], kern)
    y2, _ = causal_conv1d(x[:, split:], kern, state=st1)
    np.testing.assert_allclose(
        jnp.concatenate([y1, y2], axis=1), full, rtol=1e-5, atol=1e-5
    )


def test_state_decay_bound():
    """With all-zero input gates the state never grows (stability)."""
    rng = np.random.default_rng(0)
    B, H, t, dk, dv = 1, 1, 100, 4, 4
    q = jnp.asarray(rng.normal(size=(B, H, t, dk)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, H, t, dk)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, H, t, dv)), jnp.float32)
    a = jnp.full((B, H, t), -0.1)
    b = jnp.full((B, H, t), -1e30)  # no input
    y, (s, n) = gla_chunked(q, k, v, a, b, chunk=16)
    assert float(jnp.abs(s).max()) == 0.0
    assert float(jnp.abs(y).max()) == 0.0
