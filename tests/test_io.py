"""io/ subsystem: S3-contract emulation, request accounting, codec, staging.

Host-only (no mesh needed): the store and codec are pure filesystem/numpy;
the staging layer is exercised for ordering, backpressure and error
propagation.
"""
import threading
import time

import numpy as np
import pytest

from repro.io import records as rec
from repro.io import staging
from repro.io.object_store import (IntegrityError, ObjectNotFound,
                                   ObjectStore, RetryableError, StoreStats)


@pytest.fixture
def store(tmp_path):
    s = ObjectStore(str(tmp_path / "store"), chunk_size=64)
    s.create_bucket("b")
    return s


# ---------------------------------------------------------------------------
# object store: request accounting on top of the S3 contract. (The
# contract itself — roundtrip/ranged-GET/multipart/key semantics — is
# pinned once for every data plane by tests/store_compliance.py; this
# module keeps only what's specific to the metered ObjectStore facade.)
# ---------------------------------------------------------------------------


def test_chunked_get_counts_one_request_per_chunk(store):
    store.put("b", "k", b"x" * 1000)
    before = store.stats_snapshot()
    chunks = list(store.get_chunks("b", "k", 256))
    d = store.stats_snapshot() - before
    assert b"".join(chunks) == b"x" * 1000
    assert d.get_requests == 4  # ceil(1000/256) — the paper's map download
    assert d.bytes_read == 1000


def test_multipart_counts_one_put_per_part(store):
    parts = [b"a" * 10, b"b" * 10, b"c" * 5]
    before = store.stats_snapshot()
    meta = store.put_multipart("b", "out/p0", parts)
    d = store.stats_snapshot() - before
    assert d.put_requests == 3  # the paper's "40 chunks" reduce upload
    assert meta.parts == 3 and meta.size == 25
    assert store.get("b", "out/p0") == b"".join(parts)


def test_manifest_persists_across_reopen(store):
    store.put("b", "k", b"payload", metadata={"wave": 3})
    reopened = ObjectStore(store.root)
    m = reopened.head("b", "k")
    assert m.size == 7 and m.metadata == {"wave": 3}
    assert reopened.get("b", "k") == b"payload"


def test_delete_removes_object_and_is_counted(store):
    store.put("b", "k", b"d")
    before = store.stats_snapshot()
    store.delete("b", "k")
    with pytest.raises(ObjectNotFound):
        store.head("b", "k")
    d = store.stats_snapshot() - before
    assert d.delete_requests == 1  # free-tier priced, but tracked


def test_zero_length_object_chunks_cost_nothing(store):
    store.put("b", "empty", b"")
    before = store.stats_snapshot()
    assert list(store.get_chunks("b", "empty")) == []
    d = store.stats_snapshot() - before
    assert d.get_requests == 0 and d.bytes_read == 0  # no billed ranged GET


def test_get_raises_integrity_error_on_disk_corruption(store):
    store.put("b", "k", b"precious-bytes")
    path = store.inner._object_path("b", "k")  # facade wraps FilesystemBackend
    with open(path, "r+b") as f:
        f.write(b"Precious-bytes")  # same length, different CRC
    with pytest.raises(IntegrityError):
        store.get("b", "k")


def test_stats_delta_arithmetic():
    a = StoreStats(get_requests=5, put_requests=3, bytes_read=100)
    b = StoreStats(get_requests=2, put_requests=1, bytes_read=40)
    d = a - b
    assert (d.get_requests, d.put_requests, d.bytes_read) == (3, 2, 60)


# ---------------------------------------------------------------------------
# record codec
# ---------------------------------------------------------------------------


def test_records_roundtrip_with_payload():
    rng = np.random.default_rng(0)
    k = rng.integers(0, 2**32, 100, dtype=np.uint32)
    i = rng.integers(0, 2**32, 100, dtype=np.uint32)
    p = rng.integers(0, 2**32, (100, 5), dtype=np.uint32)
    k2, i2, p2 = rec.decode_records(rec.encode_records(k, i, p))
    np.testing.assert_array_equal(k, k2)
    np.testing.assert_array_equal(i, i2)
    np.testing.assert_array_equal(p, p2)


def test_records_roundtrip_header_only():
    k = np.arange(10, dtype=np.uint32)
    k2, i2, p2 = rec.decode_records(rec.encode_records(k, k))
    np.testing.assert_array_equal(k, k2)
    assert p2 is None


def test_body_range_slices_match_full_decode(store):
    rng = np.random.default_rng(1)
    n, pw = 64, 3
    k = rng.integers(0, 2**32, n, dtype=np.uint32)
    i = rng.integers(0, 2**32, n, dtype=np.uint32)
    p = rng.integers(0, 2**32, (n, pw), dtype=np.uint32)
    store.put("b", "obj", rec.encode_records(k, i, p))
    # a ranged GET of records [17, 41) decodes to exactly that slice
    start, length = rec.body_range(17, 24, pw)
    ks, is_, ps = rec.decode_body(store.get_range("b", "obj", start, length), pw)
    np.testing.assert_array_equal(ks, k[17:41])
    np.testing.assert_array_equal(is_, i[17:41])
    np.testing.assert_array_equal(ps, p[17:41])


def test_empty_object_roundtrip():
    k = np.empty((0,), np.uint32)
    data = rec.encode_records(k, k, np.empty((0, 4), np.uint32))
    k2, i2, p2 = rec.decode_records(data)
    assert len(k2) == 0 and len(i2) == 0 and p2.shape == (0, 4)


def test_stream_decoder_assembles_wave_zero_copy():
    # Two encoded objects fed as awkwardly-sized chunks (boundaries inside
    # headers and records) into ONE preallocated rows buffer must equal
    # the copy-happy decode+concatenate path.
    rng = np.random.default_rng(2)
    pw, sizes = 3, [37, 19]
    objs, ref = [], []
    for n in sizes:
        k = rng.integers(0, 2**32, n, dtype=np.uint32)
        i = rng.integers(0, 2**32, n, dtype=np.uint32)
        p = rng.integers(0, 2**32, (n, pw), dtype=np.uint32)
        objs.append(rec.encode_records(k, i, p))
        ref.append((k, i, p))
    rows = rec.alloc_rows(sum(sizes), pw)
    at = 0
    for data in objs:
        dec = rec.StreamDecoder(rows, at)
        for off in range(0, len(data), 13):  # 13 splits header AND records
            dec.feed(data[off : off + 13])
        at += dec.finish()
    keys, ids, payload = rec.split_rows(rows)
    np.testing.assert_array_equal(keys, np.concatenate([r[0] for r in ref]))
    np.testing.assert_array_equal(ids, np.concatenate([r[1] for r in ref]))
    np.testing.assert_array_equal(payload, np.concatenate([r[2] for r in ref]))
    # the views alias the rows storage — no copy happened
    assert keys.base is rows and payload.base is rows


def test_stream_decoder_validates_header_and_counts():
    k = np.arange(8, dtype=np.uint32)
    data = rec.encode_records(k, k, None)
    rows = rec.alloc_rows(8, 0)
    dec = rec.StreamDecoder(rows)
    dec.feed(data)
    assert dec.finish() == 8

    # header promises more records than the body delivers
    dec = rec.StreamDecoder(rec.alloc_rows(8, 0))
    dec.feed(data[: rec.HEADER_BYTES + 4 * rec.record_bytes(0)])
    with pytest.raises(ValueError, match="promises"):
        dec.finish()

    # wrong payload width for the buffer
    dec = rec.StreamDecoder(rec.alloc_rows(8, 2))
    with pytest.raises(ValueError):
        dec.feed(data)  # body bytes for pw=0 overflow... or mismatch later
        dec.finish()

    # truncated header
    dec = rec.StreamDecoder(rec.alloc_rows(8, 0))
    dec.feed(data[:7])
    with pytest.raises(ValueError, match="header"):
        dec.finish()

    # body overflowing the rows buffer is caught at feed time
    dec = rec.StreamDecoder(rec.alloc_rows(4, 0))
    with pytest.raises(ValueError, match="overflows"):
        dec.feed(data)

    # garbage magic survives python -O (ValueError, not assert)
    dec = rec.StreamDecoder(rec.alloc_rows(8, 0))
    dec.feed(b"\x00" * rec.HEADER_BYTES)
    with pytest.raises(ValueError, match="XSRT"):
        dec.finish()


# ---------------------------------------------------------------------------
# staging
# ---------------------------------------------------------------------------


def test_prefetch_preserves_order_and_overlaps():
    started = []

    def make(i):
        def thunk():
            started.append(i)
            time.sleep(0.01)
            return i
        return thunk

    out = []
    for i, v in enumerate(staging.prefetch([make(j) for j in range(6)], depth=2)):
        if i == 0:
            # double buffering: thunk 1 went in flight before result 0 consumed
            assert 1 in started
        out.append(v)
    assert out == list(range(6))


def test_prefetch_propagates_exceptions():
    def boom():
        raise ValueError("read failed")

    gen = staging.prefetch([lambda: 1, boom, lambda: 3], depth=2)
    assert next(gen) == 1
    with pytest.raises(ValueError, match="read failed"):
        list(gen)


def test_async_writer_backpressure_and_drain():
    gate = threading.Event()
    done = []

    def slow_write(i):
        gate.wait(timeout=5)
        done.append(i)

    with staging.AsyncWriter(max_inflight=2) as w:
        t0 = time.perf_counter()
        w.submit(slow_write, 0)
        w.submit(slow_write, 1)
        assert time.perf_counter() - t0 < 1.0  # both fit in flight
        blocker = threading.Thread(target=w.submit, args=(slow_write, 2))
        blocker.start()
        blocker.join(timeout=0.2)
        assert blocker.is_alive()  # third submit blocked: backpressure
        gate.set()
        blocker.join(timeout=5)
        w.drain()
    assert sorted(done) == [0, 1, 2]


def test_async_writer_drain_reraises():
    def fail():
        raise RuntimeError("spill failed")

    w = staging.AsyncWriter(max_inflight=1)
    w.submit(fail)
    with pytest.raises(RuntimeError, match="spill failed"):
        w.drain()


def test_async_writer_reports_chronologically_first_failure():
    # Upload A is submitted first but fails LAST; upload B fails first and
    # is the root cause. drain must raise B (failure order), not A
    # (submission order), with B's original traceback.
    gate = threading.Event()

    def slow_then_fail():
        gate.wait(timeout=5)
        raise RuntimeError("fallout failure (A)")

    def fast_fail():
        raise ValueError("root cause (B)")

    w = staging.AsyncWriter(max_inflight=2)
    w.submit(slow_then_fail)
    fb = w.submit(fast_fail)
    fb.exception(timeout=5)  # B has definitely failed; A still blocked
    gate.set()
    with pytest.raises(ValueError, match="root cause") as ei:
        w.drain()
    assert ei.traceback[-1].name == "fast_fail"  # original traceback kept


def test_async_writer_failed_flag_and_leakless_close():
    w = staging.AsyncWriter(max_inflight=1)
    assert not w.failed

    def boom():
        raise RuntimeError("upload died")

    w.submit(boom).exception(timeout=5)
    assert w.failed
    with pytest.raises(RuntimeError, match="upload died"):
        w.close()  # still shuts the pool down — no orphan worker thread
    assert w._ex._shutdown


def test_failed_part_upload_aborts_instead_of_committing():
    # The external-sort reduce pattern: part uploads + a finisher queued on
    # one ordered writer. If any part failed, the finisher must abort the
    # multipart session — a truncated commit would carry a self-consistent
    # CRC etag that no integrity check could ever catch.
    from repro.io.object_store import MemoryBackend

    backend = MemoryBackend()
    backend.create_bucket("b")
    mp = backend.multipart("b", "out/p0")

    def failing_part():
        raise IOError("503 mid-upload")

    def finish():
        if w.failed:
            mp.abort()
        else:
            mp.complete()

    w = staging.AsyncWriter(max_inflight=2, max_workers=1)
    w.submit(mp.put_part, 0, b"part-0")
    w.submit(failing_part)
    w.submit(finish)
    with pytest.raises(IOError, match="503 mid-upload"):
        w.close()
    with pytest.raises(ObjectNotFound):  # nothing committed
        backend.head("b", "out/p0")


def test_prefetch_retries_transient_failures():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RetryableError("503 Slow Down")
        return "ok"

    out = list(staging.prefetch([flaky], depth=1, retries=3,
                                retry_on=(RetryableError,),
                                retry_delay_s=0.001))
    assert out == ["ok"] and calls["n"] == 3

    # without the retry budget the same error surfaces to the consumer
    calls["n"] = 0
    with pytest.raises(RetryableError):
        list(staging.prefetch([flaky], depth=1))
    # and a non-listed exception type is never retried
    calls["n"] = 0
    with pytest.raises(RetryableError):
        list(staging.prefetch([flaky], depth=1, retries=5,
                              retry_on=(KeyError,), retry_delay_s=0.001))
