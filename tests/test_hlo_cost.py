"""Validate the trip-count-weighted HLO cost analyzer against XLA's own
cost_analysis() on loop-free programs, and check the while-loop weighting
that XLA's analysis lacks (scan bodies counted once)."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import analyze, parse_module


def _compile(f, *specs, **jit_kw):
    return jax.jit(f, **jit_kw).lower(*specs).compile()


def test_matmul_flops_match_xla():
    x = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    w = jax.ShapeDtypeStruct((512, 384), jnp.float32)
    c = _compile(lambda a, b: a @ b, x, w)
    ours = analyze(c.as_text())
    from repro.core.compat import cost_analysis
    theirs = cost_analysis(c)
    assert ours["flops"] == pytest.approx(2 * 256 * 512 * 384, rel=0.01)
    assert ours["flops"] == pytest.approx(theirs["flops"], rel=0.05)


def test_loop_free_bytes_close_to_xla():
    x = jax.ShapeDtypeStruct((1024, 1024), jnp.bfloat16)

    def f(a):
        return (jnp.tanh(a @ a) * 2.0).sum()

    c = _compile(f, x)
    ours = analyze(c.as_text())
    from repro.core.compat import cost_analysis
    theirs = cost_analysis(c)
    # conventions differ on fusion internals; agree within 2x and never
    # undercount by more than 50%
    assert ours["bytes"] >= 0.5 * theirs["bytes accessed"]
    assert ours["bytes"] <= 3.0 * theirs["bytes accessed"]


@pytest.mark.parametrize("length", [4, 22])
def test_scan_flops_scale_with_trip_count(length):
    n = 128

    def body(c, w):
        return jnp.tanh(c @ w), None

    def f(x, ws):
        y, _ = jax.lax.scan(body, x, ws)
        return y

    x = jax.ShapeDtypeStruct((n, n), jnp.float32)
    ws = jax.ShapeDtypeStruct((length, n, n), jnp.float32)
    c = _compile(f, x, ws)
    ours = analyze(c.as_text())
    from repro.core.compat import cost_analysis
    theirs = cost_analysis(c)
    per_iter = 2 * n * n * n
    # XLA counts the body once; we count it trip times.
    assert theirs["flops"] == pytest.approx(per_iter, rel=0.15)
    assert ours["flops"] == pytest.approx(length * per_iter, rel=0.15)
    assert ours["while_trips"] and max(
        ours["while_trips"].values()) == length
    assert not ours["unknown_trip_loops"]


def test_scan_matches_unrolled_reference():
    """Weighted scan cost == XLA's cost of the fully unrolled program."""
    n, length = 64, 8

    def body(c, w):
        return jnp.tanh(c @ w), None

    def scanned(x, ws):
        y, _ = jax.lax.scan(body, x, ws)
        return y

    def unrolled(x, ws):
        for i in range(length):
            x, _ = body(x, ws[i])
        return x

    x = jax.ShapeDtypeStruct((n, n), jnp.float32)
    ws = jax.ShapeDtypeStruct((length, n, n), jnp.float32)
    ours = analyze(_compile(scanned, x, ws).as_text())["flops"]
    from repro.core.compat import cost_analysis
    ref = cost_analysis(_compile(unrolled, x, ws))["flops"]
    assert ours == pytest.approx(ref, rel=0.1)


def test_nested_scan_trip_product():
    n, inner, outer = 32, 5, 7

    def f(x):
        def obody(c, _):
            def ibody(d, _):
                return jnp.tanh(d @ d), None
            d, _ = jax.lax.scan(ibody, c, None, length=inner)
            return d, None
        y, _ = jax.lax.scan(obody, x, None, length=outer)
        return y

    c = _compile(f, jax.ShapeDtypeStruct((n, n), jnp.float32))
    ours = analyze(c.as_text())
    per = 2 * n ** 3
    assert ours["flops"] == pytest.approx(inner * outer * per, rel=0.2)


def test_collectives_weighted_by_trip(run_in_subprocess=None):
    # needs >1 device; exercised via tests/test_exoshuffle-style subprocess
    from helpers import run_with_devices

    run_with_devices("""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.launch.hlo_cost import analyze
from repro.core.compat import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
L, n = 6, 128

def f(x, ws):
    def body(c, w):
        y = c @ w      # sharded contraction -> all-reduce per iter
        return jnp.tanh(y), None
    y, _ = jax.lax.scan(body, x, ws)
    return y.sum()

x = jax.ShapeDtypeStruct((n, n), jnp.float32)
ws = jax.ShapeDtypeStruct((L, n, n), jnp.float32)
with mesh:
    c = jax.jit(f, in_shardings=(
        NamedSharding(mesh, P("data", "model")),
        NamedSharding(mesh, P(None, "model", None)),
    )).lower(x, ws).compile()
res = analyze(c.as_text())
ar = res["collective_bytes"].get("all-reduce", 0)
# one all-reduce of a (n/2, n) f32 slab per scan iteration, plus the
# final scalar loss reduction; weighting must multiply by L
per_iter = (n // 2) * n * 4
assert ar >= L * per_iter, (ar, L * per_iter, res["collective_bytes"])
assert max(res["while_trips"].values()) == L
print("OK")
""")


def test_scan_param_slice_bytes_not_quadratic():
    """Scan bodies dynamic-slice per-layer params out of the (L, ...) stack;
    bytes must charge the slice (slab), not the whole stack, per iteration —
    total ~= one pass over the stack, not L passes."""
    n, length = 256, 16

    def body(c, w):
        return jnp.tanh(c @ w), None

    def f(x, ws):
        y, _ = jax.lax.scan(body, x, ws)
        return y

    x = jax.ShapeDtypeStruct((n, n), jnp.float32)
    ws = jax.ShapeDtypeStruct((length, n, n), jnp.float32)
    res = analyze(_compile(f, x, ws).as_text())
    stack_bytes = length * n * n * 4
    # one pass over the stack + per-iter activations (handful of n*n slabs)
    assert res["bytes"] < 3 * stack_bytes + length * 8 * n * n * 4
    assert res["bytes"] > stack_bytes  # at least reads every param once


def test_parse_module_structure():
    txt = """
HloModule m
%comp.1 (p: f32[2]) -> f32[2] {
  %p = f32[2]{0} parameter(0)
  ROOT %t = f32[2]{0} tanh(%p)
}
ENTRY %main (a: f32[2]) -> f32[2] {
  %a = f32[2]{0} parameter(0)
  ROOT %c = f32[2]{0} call(%a), to_apply=%comp.1
}
"""
    comps, entry = parse_module(txt)
    assert entry == "main"
    assert set(comps) == {"comp.1", "main"}
    assert comps["main"].instrs[-1].opcode == "call"
