"""Elastic fleet driver: claims, speculation, spill loss, membership.

The ISSUE-8 acceptance contract: the elastic driver must keep output
byte- and etag-identical to the single-host sort under every schedule it
introduces — process-backed workers, mid-job admission/retirement,
heartbeat deaths, straggler speculation with loser-abort commits, and
correlated spill-tier loss recovered by lineage-tracked map
re-execution. ClaimPool (the shared-claim scheduler underneath) is unit
tested in-process with an injected clock; end-to-end schedules run in
subprocesses with 8 host devices like the rest of the cluster suite.
"""
import pytest

from helpers import run_with_devices
from repro.shuffle.elastic import ClaimPool, FleetPlan
from repro.shuffle.executor import WorkerFailure


# ---------------------------------------------------------------------------
# FleetPlan validation
# ---------------------------------------------------------------------------


def test_fleet_plan_validates_knobs():
    FleetPlan()  # defaults are valid
    with pytest.raises(ValueError, match="heartbeat_timeout_s"):
        FleetPlan(heartbeat_timeout_s=0)
    with pytest.raises(ValueError, match="speculation_quantile"):
        FleetPlan(speculation_quantile=1.5)
    with pytest.raises(ValueError, match="speculation_factor"):
        FleetPlan(speculation_factor=0.5)
    with pytest.raises(ValueError, match="max_duplicates"):
        FleetPlan(max_duplicates=1)


# ---------------------------------------------------------------------------
# ClaimPool: the shared-claim scheduler (injected clock, no devices)
# ---------------------------------------------------------------------------


class _Clock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _pool(tasks=4, clock=None, **plan_kw):
    return ClaimPool(range(tasks), plan=FleetPlan(**plan_kw), phase="map",
                     clock=clock or _Clock())


def test_claim_pool_lifecycle_and_dedup():
    pool = _pool(2)
    assert pool.pop("a") == 0 and pool.pop("b") == 1
    assert pool.confirm(0, "a") and pool.confirm(1, "b")
    assert pool.all_confirmed()
    # terminal: further pops end the phase, duplicate confirms lose
    assert pool.pop("a") is None
    assert not pool.confirm(0, "b")
    assert pool.confirmed_by("a") == [0]


def test_claim_pool_release_worker_repends_unconfirmed():
    pool = _pool(3)
    assert pool.pop("a") == 0 and pool.pop("b") == 1
    freed = pool.release_worker("a")
    assert freed == [0]
    # recovery work beats fresh work (front of the queue)...
    assert pool.pop("b") == 0
    # ...and the dead worker is fenced out of the pool entirely
    with pytest.raises(WorkerFailure):
        pool.pop("a")
    assert pool.reexecutions == 1


def test_claim_pool_retire_drains_gracefully():
    pool = _pool(2)
    assert pool.pop("a") == 0
    pool.retire_worker("a")
    assert pool.pop("a") is None  # handed nothing new
    assert pool.confirm(0, "a")  # but its in-flight attempt still counts


def test_claim_pool_yield_when_busy_never_blocks_inflight_worker():
    pool = _pool(1)
    assert pool.pop("a") == 0
    # "a" holds an unconfirmed claim and the queue is empty: a blocking
    # pop would deadlock the map pipeline's pull-ahead loop, so the
    # yielding pop returns None for the caller to drain its own work.
    assert pool.pop("a", yield_when_busy=True) is None


def test_claim_pool_block_unblock_unconfirm_roundtrip():
    pool = _pool(3)
    for t, w in ((0, "a"), (1, "a")):
        assert pool.pop(w) == t
        assert pool.confirm(t, w)
    # correlated loss: roll back a's outputs and park everything else
    assert pool.block_unconfirmed() == 1  # task 2
    assert pool.unconfirm([0, 1]) == [0, 1]
    assert sorted(pool.unconfirmed()) == [0, 1, 2]
    assert pool.blocked() == {2}
    assert pool.unblock_all() == 1
    assert not pool.blocked()


def test_claim_pool_speculation_duplicates_laggard_and_first_commit_wins():
    clock = _Clock()
    pool = _pool(4, clock=clock, speculation=True, speculation_min_samples=2,
                 speculation_quantile=0.5, speculation_factor=2.0,
                 speculation_min_s=0.1)
    # two confirmed 1s tasks seed the duration sample
    assert pool.pop("fast") == 0
    clock.t = 1.0
    assert pool.confirm(0, "fast")
    assert pool.pop("fast") == 1
    clock.t = 2.0
    assert pool.confirm(1, "fast")
    # the straggler claims task 2; nothing is speculated before the
    # deadline (2x the median = 2s)...
    assert pool.pop("slow") == 2
    assert pool.pop("fast") == 3
    assert pool.confirm(3, "fast")
    clock.t = 3.9
    assert pool._claim_speculative("fast") is None
    # ...and past it, an idle worker duplicates the in-flight laggard
    clock.t = 4.1
    assert pool.pop("fast") == 2
    assert pool.speculated == 1
    # first durable commit wins; the straggler's late commit is refused
    assert pool.may_commit(2, "fast") and pool.may_commit(2, "slow")
    assert pool.confirm(2, "fast")
    assert not pool.may_commit(2, "slow")
    assert not pool.confirm(2, "slow")
    assert pool.spec_wins == 1 and pool.spec_losses == 1


def test_claim_pool_speculation_respects_duplicate_cap():
    clock = _Clock()
    pool = _pool(2, clock=clock, speculation=True, speculation_min_samples=1,
                 speculation_min_s=0.0, max_duplicates=2)
    assert pool.pop("a") == 0
    clock.t = 1.0
    assert pool.confirm(0, "a")
    assert pool.pop("slow") == 1
    clock.t = 10.0
    assert pool.pop("b") == 1  # duplicate 1 of the laggard
    # cap reached: a third worker must not pile on, and a worker never
    # duplicates its own claim
    assert pool._claim_speculative("c") is None
    assert pool._claim_speculative("slow") is None
    assert pool.speculated == 1


# ---------------------------------------------------------------------------
# End-to-end schedules (subprocess: 8 host devices)
# ---------------------------------------------------------------------------

ELASTIC_SETUP = """
import tempfile
import threading
import time
import jax
from repro.core.external_sort import ExternalSortPlan, external_sort
from repro.core.compat import make_mesh
from repro.data import gensort, valsort
from repro.io.object_store import ObjectStore
from repro.shuffle.elastic import FleetPlan
from repro.shuffle.executor import (ClusterFailure, FaultyWorker,
                                    ThreadWorker)
from repro.shuffle.sort import sort_shuffle_job

mesh = make_mesh((8,), ("w",))
plan = ExternalSortPlan(
    records_per_wave=1 << 13,
    num_rounds=2,
    reducers_per_worker=2,
    payload_words=2,
    impl="ref",
    input_records_per_partition=1 << 12,
    output_part_records=1 << 11,
    store_chunk_bytes=16 << 10,
    parallel_reducers=2,
    reduce_memory_budget_bytes=64 << 10,
)
N = 1 << 15  # 4 map tasks; 16 output partitions
root = tempfile.mkdtemp(prefix="elastic-test-")
store = ObjectStore(root)
store.create_bucket("sort")
in_ck, nparts = gensort.write_to_store(
    store, "sort", plan.input_prefix, N,
    plan.input_records_per_partition, plan.payload_words)

def layout():
    return [(m.key, m.etag, m.size, m.parts)
            for m in store.list_objects("sort", plan.output_prefix)]

def job():
    return sort_shuffle_job(store, "sort", mesh=mesh, axis_names="w",
                            plan=plan)

rep0 = external_sort(store, "sort", mesh=mesh, axis_names="w", plan=plan)
want = layout()
assert len(want) == 16

def check_bytes(tag):
    assert layout() == want, f"{tag} changed output bytes"
    val = valsort.validate_from_store(store, "sort", plan.output_prefix,
                                      in_ck)
    assert val.ok and val.total_records == N, (tag, val)
"""


def test_elastic_thread_fleet_identity_and_membership():
    # Clean elastic run: byte-identical, no failures — then a run where
    # a worker joins mid-job and another is retired at the start, with
    # the late joiner doing real confirmed work.
    run_with_devices(ELASTIC_SETUP + """
crew = [ThreadWorker(f"w{i}", store) for i in range(3)]
crep = job().run(worker_list=crew, fleet=FleetPlan())
check_bytes("elastic W=3")
assert not crep.failed_workers and crep.recovery_rounds == 0
assert crep.heartbeat_misses == 0 and crep.spill_lost_map_tasks == 0
assert sum(crep.per_worker_tasks.values()) == 20
assert sum(s.get_requests for s in crep.per_worker_stats.values()) > 0

# membership: retire w1 up front, admit "late" as soon as the driver
# exists — both take effect inside the running job
jb = job()
session = jb.prepare(schedulers=2)
crew = [ThreadWorker(f"w{i}", store) for i in range(2)]
late = ThreadWorker("late", store)

def membership():
    while getattr(session, "driver", None) is None:
        time.sleep(0.005)
    session.driver.retire("w1")
    session.driver.admit(late)

t = threading.Thread(target=membership, daemon=True)
t.start()
crep = session.run_elastic(crew, FleetPlan())
t.join()
check_bytes("elastic admit/retire")
assert crep.workers_admitted == 1 and crep.workers_retired == 1
assert crep.per_worker_tasks.get("late", 0) >= 1, crep.per_worker_tasks
assert not crep.failed_workers
print("OK")
""", timeout=900)


def test_elastic_spill_loss_reexecutes_map_lineage():
    # w0 dies mid-job and takes its local spill tier with it: every map
    # task it had confirmed must be rolled back and re-executed on the
    # survivor (lineage via MapOp.spill_keys), parked reduce partitions
    # resume after the recovery pass, and the output stays
    # byte-identical. fail_after_tasks=6 places the death inside the
    # reduce phase (4 map tasks + 16 partitions).
    run_with_devices(ELASTIC_SETUP + """
crew = [FaultyWorker(ThreadWorker("w0", store), fail_after_tasks=6),
        ThreadWorker("w1", store)]
crep = job().run(worker_list=crew, fleet=FleetPlan())
check_bytes("spill-loss run")
assert crep.failed_workers == ["w0"], crep.failed_workers
# the dead worker had confirmed map work, so its spill loss forced a
# lineage re-execution (spill_lost counts rolled-back map tasks)
assert crep.spill_lost_map_tasks >= 1, crep
assert crep.reexecuted_map_tasks >= crep.spill_lost_map_tasks
assert sum(crep.per_worker_tasks.values()) >= 20
print("OK", crep.spill_lost_map_tasks, crep.recovery_rounds,
      crep.requeued_reduce_tasks)
""", timeout=900)


def test_elastic_speculation_beats_straggler():
    # One worker's store view is latency-injected (a straggler host, not
    # straggler data): with speculation on, idle fast workers duplicate
    # its in-flight laggards past the quantile deadline and win the
    # commit race — output unchanged, loser commits aborted.
    run_with_devices(ELASTIC_SETUP + """
from repro.io.middleware import FaultProfile, LatencyBandwidthMiddleware

slow_view = LatencyBandwidthMiddleware(store, FaultProfile(latency_s=0.25))
crew = [ThreadWorker("w0", store), ThreadWorker("w1", store),
        ThreadWorker("slow", slow_view)]
fleet = FleetPlan(speculation=True, speculation_min_samples=3,
                  speculation_quantile=0.5, speculation_factor=2.0,
                  speculation_min_s=0.1)
crep = job().run(worker_list=crew, fleet=fleet)
check_bytes("speculation run")
assert not crep.failed_workers
assert crep.speculated_tasks >= 1, crep
assert crep.speculation_wins >= 1, crep
# the straggler was outrun, not killed: it still confirmed its share
assert "slow" in crep.per_worker_tasks or crep.speculation_wins >= 1
print("OK", crep.speculated_tasks, crep.speculation_wins)
""", timeout=900)


def test_elastic_last_survivor_death_mid_reduce_fails_cleanly():
    # Satellite: when the LAST surviving worker dies mid-reduce the job
    # must raise ClusterFailure — and fail *cleanly*: every partition
    # that did commit is byte-identical to the reference, and no
    # in-flight multipart session leaves tmp parts behind (they are
    # aborted, not leaked, when the store view dies).
    run_with_devices(ELASTIC_SETUP + """
import os

crew = [FaultyWorker(ThreadWorker("w0", store), fail_after_tasks=2),
        FaultyWorker(ThreadWorker("w1", store), fail_after_tasks=8)]
try:
    job().run(worker_list=crew, fleet=FleetPlan())
except ClusterFailure as e:
    assert "workers dead" in str(e), e
else:
    raise AssertionError("expected ClusterFailure when the whole fleet dies")

# committed partitions are a byte-identical subset of the reference
want_by_key = {k: (etag, size, parts) for k, etag, size, parts in want}
got = layout()
assert len(got) < 16, "a dead fleet cannot have finished the job"
for k, etag, size, parts in got:
    assert want_by_key[k] == (etag, size, parts), f"partial output {k} diverged"

# no leaked multipart staging files anywhere under the store root
stray = [os.path.join(d, f) for d, _, fs in os.walk(root)
         for f in fs if ".mp" in f]
assert not stray, f"leaked multipart tmp files: {stray}"
print("OK", len(got))
""", timeout=900)


def test_elastic_process_fleet_identity_and_kill_recovery():
    # ProcessWorkers: real subprocesses with their own JAX runtimes,
    # talking the same Worker protocol over pipes. A clean W=2 run is
    # byte-identical with per-PROCESS store attribution; a run where p0
    # dies at its 5th task pop (os._exit, no goodbye) is detected by the
    # reader/heartbeat path, loses p0's spill tier, re-executes the lost
    # map lineage, and still lands byte-identical.
    run_with_devices(ELASTIC_SETUP + """
from repro.shuffle.procworker import ProcessWorker

def pworker(name, **kw):
    return ProcessWorker(name, store=store, bucket="sort", plan=plan, **kw)

crew = [pworker("p0"), pworker("p1")]
try:
    crep = job().run(worker_list=crew, fleet=FleetPlan())
finally:
    for wk in crew:
        wk.close()
check_bytes("process W=2")
assert not crep.failed_workers
assert sum(crep.per_worker_tasks.values()) == 20
for name in ("p0", "p1"):
    assert crep.per_worker_stats[name].get_requests > 0, (
        "per-process store attribution missing")

crew = [pworker("p0", die_after_tasks=4), pworker("p1")]
try:
    crep = job().run(worker_list=crew, fleet=FleetPlan())
finally:
    for wk in crew:
        wk.close()
check_bytes("process kill")
assert crep.failed_workers == ["p0"], crep.failed_workers
assert crep.spill_lost_map_tasks >= 1, crep
assert crep.recovery_rounds >= 1, crep
assert crep.reexecuted_map_tasks >= 1, crep
print("OK", crep.spill_lost_map_tasks, crep.recovery_rounds)
""", timeout=900)
