"""Per-arch smoke tests (assignment requirement): every assigned
architecture instantiates a REDUCED same-family config and runs one
forward + one train step on CPU, asserting output shapes and no NaNs.
Decode parity (prefill + decode_step == forward) is checked per family.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get
from repro.models import api as mapi
from repro.models.whisper import enc_len_for
from repro.train.optimizer import OptConfig
from repro.train.train_step import TrainConfig, init_train_state, make_train_step


def reduced_batch(cfg, B=2, S=24, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 4)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab),
    }
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            ks[2], (B, cfg.vlm_prefix, cfg.d_model)
        )
        batch["labels"] = jax.random.randint(
            ks[1], (B, S + cfg.vlm_prefix), 0, cfg.vocab
        )
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            ks[2], (B, enc_len_for(cfg, S), cfg.d_model)
        )
    return batch


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_forward_and_train_step(arch_id):
    cfg = get(arch_id).reduced(dtype="float32", remat=False)
    model = mapi.build(cfg)
    batch = reduced_batch(cfg)
    tcfg = TrainConfig(opt=OptConfig(peak_lr=1e-3, warmup_steps=1))
    state = init_train_state(model, jax.random.PRNGKey(0), tcfg)

    logits = model.forward(state["params"], batch)
    assert logits.shape[-1] == cfg.padded_vocab
    assert logits.shape[0] == 2
    assert not bool(jnp.isnan(logits).any()), f"{arch_id}: NaN logits"

    step = make_train_step(model, tcfg)
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"])), f"{arch_id}: non-finite loss"
    assert int(new_state["opt"]["step"]) == 1
    # params actually changed
    delta = sum(
        float(jnp.abs(a - b).sum())
        for a, b in zip(jax.tree.leaves(state["params"]),
                        jax.tree.leaves(new_state["params"]))
    )
    assert delta > 0, f"{arch_id}: train step was a no-op"


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_decode_parity(arch_id):
    cfg = get(arch_id).reduced(dtype="float32", remat=False)
    model = mapi.build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 17
    batch = reduced_batch(cfg, B=B, S=S)
    logits_full = model.forward(params, batch)

    pre_batch = dict(batch)
    pre_batch["tokens"] = batch["tokens"][:, : S - 1]
    lg_pre, cache = model.prefill(params, pre_batch, max_len=S + 4)
    lg_dec, _ = model.decode_step(
        params, cache, batch["tokens"][:, S - 1 : S],
        jnp.int32(S - 1 + (cfg.vlm_prefix if cfg.family == "vlm" else 0)),
    )
    err = float(jnp.max(jnp.abs(lg_dec[:, 0] - logits_full[:, -1])))
    assert err < 5e-3, f"{arch_id}: decode/forward mismatch {err}"


def test_loss_decreases_tinyllama():
    """A few steps of real training on one arch must reduce the loss."""
    cfg = get("tinyllama-1.1b").reduced(dtype="float32", remat=False,
                                        n_layers=2, vocab=128)
    model = mapi.build(cfg)
    tcfg = TrainConfig(opt=OptConfig(peak_lr=3e-3, warmup_steps=2,
                                     total_steps=40))
    state = init_train_state(model, jax.random.PRNGKey(0), tcfg)
    step = jax.jit(make_train_step(model, tcfg))
    batch = reduced_batch(cfg, B=4, S=32)  # overfit one batch
    losses = []
    for _ in range(15):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.9, losses


def test_microbatched_grad_matches_full():
    cfg = get("tinyllama-1.1b").reduced(dtype="float32", remat=False,
                                        n_layers=2, vocab=64)
    model = mapi.build(cfg)
    batch = reduced_batch(cfg, B=4, S=16)
    params = model.init(jax.random.PRNGKey(0))

    t1 = TrainConfig(opt=OptConfig())
    t4 = TrainConfig(opt=OptConfig(), microbatches=4)
    s1 = {"params": params, "opt": __import__(
        "repro.train.optimizer", fromlist=["init_opt_state"]
    ).init_opt_state(params)}
    import copy

    s4 = jax.tree.map(jnp.copy, s1)
    n1, m1 = make_train_step(model, t1)(s1, batch)
    n4, m4 = make_train_step(model, t4)(s4, batch)
    # same data, same global batch: loss and updated params must agree
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-4
    for a, b in zip(jax.tree.leaves(n1["params"]), jax.tree.leaves(n4["params"])):
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-5)


def test_hymba_ssm_pad_heads_exact():
    """Padded SSM heads (zero input gate) must not change the output."""
    import dataclasses
    import numpy as np
    from repro.configs import get
    from repro.models import api as mapi

    cfg = get("hymba_1_5b").reduced(n_layers=2)
    tokens = jnp.asarray(
        np.random.default_rng(3).integers(0, cfg.vocab, (2, 32)), jnp.int32)
    base = mapi.build(cfg)
    ref = base.forward(base.init(jax.random.PRNGKey(0)), {"tokens": tokens})

    cfgp = dataclasses.replace(cfg, ssm_pad_heads=8)
    padded = mapi.build(cfgp)
    out = padded.forward(padded.init(jax.random.PRNGKey(0)), {"tokens": tokens})
    np.testing.assert_allclose(np.asarray(ref), np.asarray(out),
                               rtol=1e-5, atol=1e-5)
