"""Observability layer: contexts, event log, tracing middleware, export.

The ISSUE-6 acceptance contract: trace contexts flow job -> phase ->
task -> worker -> store request, so every GET/PUT attempt (including
retried and throttled ones) is attributed to the task that issued it;
the TracingMiddleware's counts agree with MetricsMiddleware's billed
counts bit-for-bit; the Chrome export is structurally deterministic at
W=1/P=1; and a W=4 cluster sort with an injected worker death exports a
trace whose re-executed map tasks appear on the surviving workers'
tracks.
"""
import threading

from helpers import run_with_devices

from repro.io.backends import MemoryBackend
from repro.io.middleware import (FaultProfile, MetricsMiddleware,
                                 RetryPolicy, TracingMiddleware,
                                 fault_injected)
from repro.obs import (EventLog, Tracer, TraceContext, bind_context,
                       chrome_trace, current_context, use_context)

# ---------------------------------------------------------------------------
# TraceContext propagation
# ---------------------------------------------------------------------------


def test_trace_context_derivation_and_scoping():
    assert current_context() is None
    root = TraceContext(job="j")
    ctx = root.with_phase("map").with_task(3).with_worker("w1")
    assert (ctx.job, ctx.phase, ctx.task, ctx.worker) == ("j", "map", "3", "w1")
    with use_context(ctx):
        assert current_context() is ctx
        inner = ctx.with_task("g9")
        with use_context(inner):
            assert current_context().task == "g9"
        assert current_context() is ctx
    assert current_context() is None
    # use_context(None) is a no-op scope, not an error
    with use_context(None):
        assert current_context() is None


def test_bind_context_carries_context_to_pool_threads():
    # contextvars don't propagate into pre-existing pool threads; the
    # runtime binds the submitting task's context onto the callable.
    ctx = TraceContext(job="j", phase="reduce", task="r4", worker="w2")
    seen = {}

    def probe():
        seen["ctx"] = current_context()

    with use_context(ctx):
        bound = bind_context(probe)
    t = threading.Thread(target=bound)
    t.start()
    t.join()
    assert seen["ctx"] is ctx
    # without a bound/ambient context the callable is returned unchanged
    assert bind_context(probe) is probe


# ---------------------------------------------------------------------------
# EventLog bounds
# ---------------------------------------------------------------------------


def test_event_log_keeps_first_events_and_counts_drops():
    log = EventLog(max_events=3)
    for i in range(5):
        log.emit({"name": f"e{i}"})
    assert len(log) == 3
    assert [e["name"] for e in log.events()] == ["e0", "e1", "e2"]
    assert log.dropped == 2


def test_tracer_cap_surfaces_in_chrome_export():
    tracer = Tracer(job="capped", max_events=2)
    for i in range(4):
        tracer.instant(f"e{i}")
    trace = chrome_trace(tracer)
    assert trace["otherData"]["events_dropped"] == 2


# ---------------------------------------------------------------------------
# TracingMiddleware: attribution + parity with MetricsMiddleware
# ---------------------------------------------------------------------------


def _throttled_store(tracer):
    # burst=2 at 50 req/s: back-to-back GETs throttle quickly and the
    # retry layer recovers within a few 20 ms backoffs.
    return fault_injected(
        MemoryBackend(),
        profile=FaultProfile(get_rate=50.0, put_rate=50.0, burst=2.0),
        retry=RetryPolicy(max_attempts=10, base_delay_s=0.02,
                          max_delay_s=0.1),
        seed=7, tracer=tracer)


def test_retried_and_throttled_attempts_attributed_to_issuing_task():
    tracer = Tracer(job="attr")
    store = _throttled_store(tracer)
    store.create_bucket("b")
    store.put("b", "k", b"x" * 64)
    ctx = TraceContext(job="attr", phase="reduce", task="r7", worker="w0")
    with use_context(ctx):
        for _ in range(8):  # exhausts the burst -> SlowDowns -> retries
            store.get("b", "k")

    reg = tracer.registry
    slow = reg.total("store.requests", kind="get", outcome="slowdown")
    assert slow >= 1, "throttle never fired; the test store is miswired"
    assert reg.total("store.retries", kind="get") >= slow

    gets = [e for e in tracer.log.events() if e["name"] == "store.get"]
    assert gets and all(e["task"] == "r7" and e["worker"] == "w0"
                        for e in gets)
    # the throttled attempts specifically carry the issuing task too
    assert any(e["outcome"] == "slowdown" for e in gets)
    retries = [e for e in tracer.log.events() if e["name"] == "store.retry"]
    assert retries and all(e["task"] == "r7" for e in retries)


def test_tracing_counts_match_metrics_middleware_bit_for_bit():
    tracer = Tracer(job="parity")
    store = _throttled_store(tracer)
    store.create_bucket("b")
    for i in range(6):
        store.put("b", f"k{i}", bytes(range(32)) * (i + 1))
    for i in range(6):
        store.get("b", f"k{i}")
    store.get_range("b", "k3", 8, 16)
    store.head("b", "k0")
    store.list_objects("b", "")
    mp = store.multipart("b", "mp")
    mp.put_part(1, b"b" * 10)
    mp.put_part(0, b"a" * 10)
    mp.complete()
    store.delete("b", "k5")

    stats = store.stats_snapshot()
    reg = tracer.registry
    # Attempt counts: retry-inflated on both sides, per request kind.
    assert reg.total("store.requests", kind="get") == stats.get_requests
    assert reg.total("store.requests", kind="put") == stats.put_requests
    assert reg.total("store.requests", kind="head") == stats.head_requests
    assert reg.total("store.requests", kind="list") == stats.list_requests
    assert reg.total("store.requests",
                     kind="delete") == stats.delete_requests
    # SlowDowns and re-issues.
    assert reg.total("store.requests",
                     outcome="slowdown") == stats.throttled
    assert reg.total("store.retries") == stats.retries
    # Bytes move only on successful attempts.
    assert reg.total("store.bytes_read") == stats.bytes_read
    assert reg.total("store.bytes_written") == stats.bytes_written
    assert stats.throttled >= 1  # the parity must cover the retry path


# ---------------------------------------------------------------------------
# Job-level wiring: report metrics + deterministic export
# ---------------------------------------------------------------------------


def _tiny_groupby(tracer, *, partitions=1):
    from repro.shuffle.api import ShufflePlan
    from repro.shuffle.groupby import groupby_job, write_groupby_input

    store = TracingMiddleware(MetricsMiddleware(MemoryBackend()), tracer)
    store.create_bucket("b")
    plan = ShufflePlan(payload_words=1, output_part_records=256)
    write_groupby_input(store, "b", plan.input_prefix, 2048, 2048,
                        num_groups=32, skew=1.5)
    return groupby_job(store, "b", plan=plan, num_partitions=partitions,
                       tracer=tracer)


def test_report_carries_metrics_snapshot_and_spans():
    tracer = Tracer(job="report")
    rep = _tiny_groupby(tracer, partitions=2).run(workers=0)
    assert rep.spans_dropped == 0
    gauges = rep.metrics["gauges"]
    assert "phase.seconds{phase=map}" in gauges
    assert "phase.seconds{phase=reduce}" in gauges
    counters = rep.metrics["counters"]
    assert any(k.startswith("store.requests{") for k in counters)
    # the store byte counters carry phase labels for the bytes/s gauges
    assert any(k.startswith("store.bytes_read{") for k in counters)


def _canonical_structure(trace):
    """Timing-free shape of a Chrome trace: track metadata plus sorted
    (worker-track, phase, task, name, outcome) event counts."""
    meta = sorted((e["name"], e["tid"], e["args"]["name"])
                  for e in trace["traceEvents"] if e["ph"] == "M")
    counts = {}
    for e in trace["traceEvents"]:
        if e["ph"] == "M":
            continue
        key = (e["tid"], e["cat"], e["args"].get("task"), e["name"],
               e["args"].get("outcome"))
        counts[key] = counts.get(key, 0) + 1
    return meta, sorted(counts.items())


def test_trace_export_deterministic_at_w1_p1():
    # Same job, fresh store + tracer each run: the span tree (who did
    # what, attributed to which task) must be identical even though the
    # timings differ. W=1/P=1 pins scheduling; MemoryBackend pins I/O.
    shapes = []
    for _ in range(2):
        tracer = Tracer(job="det")
        rep = _tiny_groupby(tracer, partitions=1).run(workers=0)
        assert rep.spans_dropped == 0
        shapes.append(_canonical_structure(chrome_trace(tracer)))
    assert shapes[0] == shapes[1]
    meta, counts = shapes[0]
    # single-host: everything lives on the one "host" track
    assert [m[2] for m in meta] == ["det", "host"]
    tasks = {k[2] for k, _ in counts}
    assert "g0" in tasks and "r0" in tasks  # both phases attributed


# ---------------------------------------------------------------------------
# Acceptance: failover trace of a W=4 cluster sort
# ---------------------------------------------------------------------------

_FAILOVER = """
import collections
import tempfile
import jax
from repro.core.external_sort import ExternalSortPlan, external_sort
from repro.core.cluster import ClusterExecutor, ClusterPlan
from repro.data import gensort, valsort
from repro.io.middleware import TracingMiddleware
from repro.io.object_store import ObjectStore
from repro.obs import Tracer, chrome_trace

from repro.core.compat import make_mesh
mesh = make_mesh((8,), ("w",))
plan = ExternalSortPlan(
    records_per_wave=1 << 13,
    num_rounds=2,
    reducers_per_worker=2,
    payload_words=2,
    impl="ref",
    input_records_per_partition=1 << 12,
    output_part_records=1 << 11,
    store_chunk_bytes=16 << 10,
    parallel_reducers=2,
    reduce_memory_budget_bytes=64 << 10,
)
N = 1 << 15  # 4 map tasks; 16 output partitions
tracer = Tracer(job="failover")
store = TracingMiddleware(ObjectStore(tempfile.mkdtemp(prefix="obs-test-")),
                          tracer)
store.create_bucket("sort")
in_ck, _ = gensort.write_to_store(
    store, "sort", plan.input_prefix, N,
    plan.input_records_per_partition, plan.payload_words)

# w1's store view dies mid-way through its first map task, so at least
# one map task must be re-executed by a survivor.
crep = ClusterExecutor(
    store, "sort", mesh=mesh, axis_names="w", plan=plan,
    cluster=ClusterPlan(num_workers=4, fail_after_requests={1: 10}),
    tracer=tracer).sort()
assert crep.failed_workers == ["w1"], crep.failed_workers
assert crep.reexecuted_map_tasks >= 1, crep
val = valsort.validate_from_store(store, "sort", plan.output_prefix, in_ck)
assert val.ok and val.total_records == N, val

trace = chrome_trace(tracer)
tracks = {e["args"]["name"]: e["tid"] for e in trace["traceEvents"]
          if e["ph"] == "M" and e["name"] == "thread_name"}
assert {"w0", "w1", "w2", "w3"} <= set(tracks), tracks

# A re-executed map task shows up as map-phase spans on >= 2 tracks,
# at least one of them a survivor's.
by_task = collections.defaultdict(set)
for e in trace["traceEvents"]:
    if e.get("ph") == "X" and e.get("cat") == "map":
        task = e["args"].get("task")
        if task:
            by_task[task].add(e["tid"])
survivors = {tracks[w] for w in ("w0", "w2", "w3")}
reexec = {t for t, tids in by_task.items()
          if len(tids) >= 2 and tids & survivors}
assert reexec, by_task

# Store request attempts are attributed to worker tracks (not all
# lumped on the host track), and the death is marked on w1's track.
store_tids = {e["tid"] for e in trace["traceEvents"]
              if e.get("ph") == "X" and e["name"].startswith("store.")}
assert store_tids & survivors, store_tids
dead = [e for e in trace["traceEvents"]
        if e["name"] == "cluster.worker_dead"]
assert len(dead) == 1 and dead[0]["tid"] == tracks["w1"], dead
assert crep.spans_dropped == 0
assert crep.metrics["counters"].get("cluster.workers_dead") == 1
assert crep.metrics["counters"].get(
    "cluster.tasks_reexecuted{phase=map}", 0) >= 1
print("OK", sorted(reexec))
"""


def test_failover_cluster_sort_exports_attributed_chrome_trace():
    run_with_devices(_FAILOVER, timeout=900)
