"""Training substrate: optimizer math, checkpoint/restart (fault
tolerance), elastic re-shard, gradient compression error feedback."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ckpt
from repro.train import grad_compress as gc
from repro.train.optimizer import OptConfig, adamw_update, init_opt_state, lr_at


def test_lr_schedule_shape():
    cfg = OptConfig(peak_lr=1.0, warmup_steps=10, total_steps=100,
                    min_lr_frac=0.1)
    assert float(lr_at(jnp.int32(0), cfg)) == 0.0
    assert abs(float(lr_at(jnp.int32(10), cfg)) - 1.0) < 1e-6
    assert float(lr_at(jnp.int32(100), cfg)) == pytest.approx(0.1, rel=1e-5)
    # monotone decay after warmup
    vals = [float(lr_at(jnp.int32(s), cfg)) for s in range(10, 101, 10)]
    assert all(a >= b for a, b in zip(vals, vals[1:]))


def test_adamw_reduces_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    state = init_opt_state(params)
    cfg = OptConfig(peak_lr=0.1, warmup_steps=0, total_steps=1000,
                    weight_decay=0.0)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.3


def test_grad_clip_applies():
    params = {"w": jnp.zeros(3)}
    state = init_opt_state(params)
    cfg = OptConfig(clip_norm=1.0, warmup_steps=0)
    _, _, info = adamw_update(params, {"w": jnp.full(3, 100.0)}, state, cfg)
    assert float(info["grad_norm"]) > 1.0  # reported pre-clip


def test_checkpoint_roundtrip(tmp_path):
    state = {
        "params": {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
                   "blocks": [{"w": jnp.ones((2, 2))}, {"w": jnp.zeros((2, 2))}]},
        "step": jnp.int32(7),
    }
    d = str(tmp_path / "ck")
    ckpt.save(state, d, step=7)
    assert ckpt.latest_step(d) == 7
    abstract = jax.eval_shape(lambda: state)
    loaded, step = ckpt.load(abstract, d)
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(loaded)):
        np.testing.assert_array_equal(a, b)


def test_checkpoint_checksum_gate(tmp_path):
    state = {"w": jnp.ones((4, 4))}
    d = str(tmp_path / "ck")
    ckpt.save(state, d, step=1)
    # corrupt a byte
    f = os.path.join(d, "step_00000001", "w.npy")
    raw = bytearray(open(f, "rb").read())
    raw[-1] ^= 0xFF
    open(f, "wb").write(raw)
    with pytest.raises(AssertionError, match="checksum"):
        ckpt.load(jax.eval_shape(lambda: state), d)


def test_checkpoint_atomic_overwrite(tmp_path):
    d = str(tmp_path / "ck")
    ckpt.save({"w": jnp.zeros(2)}, d, step=1)
    ckpt.save({"w": jnp.ones(2)}, d, step=2)
    loaded, step = ckpt.load(jax.eval_shape(lambda: {"w": jnp.zeros(2)}), d)
    assert step == 2 and float(loaded["w"][0]) == 1.0


def test_elastic_reshard_subprocess(tmp_path):
    """Save on a 1-device 'mesh', restore sharded onto 8 devices."""
    from helpers import run_with_devices

    d = str(tmp_path / "ck")
    ckpt.save({"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}, d, step=3)
    run_with_devices(f"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.train import checkpoint as ckpt
from repro.core.compat import make_mesh
mesh = make_mesh((8,), ("data",))
target = jax.eval_shape(lambda: {{"w": jnp.zeros((8, 8), jnp.float32)}})
sh = {{"w": NamedSharding(mesh, P("data", None))}}
loaded, step = ckpt.load(target, {d!r}, shardings=sh)
assert step == 3
assert len(loaded["w"].sharding.device_set) == 8
np.testing.assert_array_equal(np.asarray(loaded["w"]),
                              np.arange(64, dtype=np.float32).reshape(8, 8))
print("OK")
""")


def test_quantize_error_feedback_converges():
    """EF residual re-injects quantization error: the running sum of
    compressed grads tracks the true sum (EF-SGD property)."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(size=(256,)), jnp.float32)
    residual = {"g": jnp.zeros(256)}
    total = jnp.zeros(256)
    for _ in range(50):
        comp, residual_tree = gc.ef_compress_grads({"g": g_true}, residual)
        residual = residual_tree
        total = total + comp["g"]
    # average compressed grad ~= true grad
    np.testing.assert_allclose(total / 50, g_true, atol=2e-3)


def test_quantize_int8_range():
    x = jnp.asarray([[-3.0, 0.0, 3.0]])
    q, s = gc.quantize_int8(x)
    assert q.dtype == jnp.int8
    np.testing.assert_allclose(gc.dequantize(q, s), x, atol=3.0 / 127 + 1e-6)


def test_compressed_pod_mean_subprocess():
    from helpers import run_with_devices

    run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.train.grad_compress import compressed_pod_mean
from repro.core.compat import make_mesh
mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
x = jnp.linspace(-1, 1, 64).reshape(8, 8)
out = jax.jit(lambda t: compressed_pod_mean({"g": t}, mesh))(x)["g"]
# values replicated across pods -> mean == identity (within int8 error)
np.testing.assert_allclose(np.asarray(out), np.asarray(x), atol=2/127)
print("OK")
""")
