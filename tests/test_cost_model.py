"""The paper's TCO model (Table 2) must reproduce to the cent."""
import dataclasses

import pytest

from repro.core.cost_model import (CostBreakdown, Ec2CostParams, JobProfile,
                                   cloudsort_tco, measured_tiered_cloudsort_tco,
                                   tpu_cloudsort_tco, tpu_sort_time_model)


def test_equation_1_hourly_cost():
    p = Ec2CostParams()
    # paper: $55.6044/hr
    assert p.cluster_hourly == pytest.approx(55.6044, abs=1e-3)


def test_table2_compute():
    b = cloudsort_tco()
    assert b.compute == pytest.approx(83.0674, abs=1e-3)


def test_table2_storage():
    b = cloudsort_tco()
    assert b.storage_input == pytest.approx(4.6045, abs=1e-3)
    assert b.storage_output == pytest.approx(1.6009, abs=1e-3)


def test_table2_access():
    b = cloudsort_tco()
    assert b.access_get == pytest.approx(2.4000, abs=1e-6)
    assert b.access_put == pytest.approx(5.0000, abs=1e-6)


def test_table2_total():
    assert cloudsort_tco().total == pytest.approx(96.6728, abs=5e-3)


def test_s3_hourly_rate():
    # paper: $3.0822/hr per 100 TB
    assert Ec2CostParams().s3_hourly_per_100tb() == pytest.approx(3.0822, abs=1e-3)


def test_tiered_tco_bills_only_the_durable_tier():
    from repro.io.backends import StoreStats

    tiers = {
        # durable counters are retry-inflated by construction (metrics
        # middleware counts throttled attempts) — billed as-is
        "durable": StoreStats(get_requests=10_000, put_requests=2_000,
                              retries=500, throttled=500),
        # spill traffic is huge but local: never touches the access legs
        "ssd": StoreStats(get_requests=10**9, put_requests=10**9,
                          bytes_written=10**12),
    }
    p = Ec2CostParams()
    tco = measured_tiered_cloudsort_tco(
        tiers, job_hours=1.0, reduce_hours=0.5, data_bytes=1e12)
    assert tco.access_get == pytest.approx(p.get_per_1000 * 10_000 / 1000)
    assert tco.access_put == pytest.approx(p.put_per_1000 * 2_000 / 1000)
    assert tco.storage_spill == 0.0  # i4i NVMe is bundled into compute


def test_tiered_tco_prices_attached_volume_spill_when_configured():
    from repro.io.backends import StoreStats

    tiers = {"durable": StoreStats(), "ssd": StoreStats(bytes_written=500e9)}
    p = dataclasses.replace(Ec2CostParams(), ssd_gb_month=0.08)  # gp3-like
    tco = measured_tiered_cloudsort_tco(
        tiers, job_hours=2.0, reduce_hours=1.0, data_bytes=1e12, params=p)
    assert tco.storage_spill == pytest.approx(0.08 / p.hours_per_month * 500 * 2.0)
    assert tco.total >= tco.storage_spill > 0


def test_paper_breakdown_has_zero_spill_leg():
    b = cloudsort_tco()
    assert b.storage_spill == 0.0
    assert dict(b.rows())["data_storage_spill_ssd"] == 0.0


# ---------------------------------------------------------------------------
# serverless: the per-invocation GB-second leg (ISSUE 10)
# ---------------------------------------------------------------------------


def test_gb_seconds_price_from_measured_peak_and_wall_clock():
    from repro.core.cost_model import (InvocationProfile, ServerlessCostParams,
                                       billed_gb_seconds,
                                       serverless_compute_cost)

    p = ServerlessCostParams()
    # 512 MiB measured peak for 2.0 s: exactly 0.5 GB x 2 s = 1 GB-s
    prof = InvocationProfile(seconds=2.0, peak_bytes=512 << 20)
    assert billed_gb_seconds(prof, p) == pytest.approx(1.0)
    assert serverless_compute_cost([prof], p) == pytest.approx(
        1.0 * p.gb_second + p.per_invocation)

    # one byte over 512 MiB rounds UP to the next memory step (513 MiB)
    over = InvocationProfile(seconds=2.0, peak_bytes=(512 << 20) + 1)
    assert billed_gb_seconds(over, p) == pytest.approx(513 / 1024 * 2.0)

    # tiny invocations hit both floors: 128 MiB and one duration step
    tiny = InvocationProfile(seconds=0.0, peak_bytes=1)
    assert billed_gb_seconds(tiny, p) == pytest.approx(
        (128 / 1024) * (p.duration_step_ms / 1000.0))


def test_measured_serverless_tco_uses_retry_inflated_requests():
    from repro.io.backends import StoreStats

    from repro.core.cost_model import (InvocationProfile, ServerlessCostParams,
                                       measured_serverless_tco)

    p = ServerlessCostParams()
    invs = [InvocationProfile(seconds=1.0, peak_bytes=1 << 30)
            for _ in range(4)]
    # counters are attempt counts: 500 of these GETs were throttled
    # re-issues, and they bill exactly like the logical ones
    stats = StoreStats(get_requests=10_500, put_requests=2_000,
                       retries=500, throttled=500)
    tco = measured_serverless_tco(
        invs, stats, job_hours=1.0, reduce_hours=0.5, data_bytes=1e12)
    assert tco.access_get == pytest.approx(p.s3.get_per_1000 * 10_500 / 1000)
    assert tco.access_put == pytest.approx(p.s3.put_per_1000 * 2_000 / 1000)
    # compute leg = measured GB-seconds, not any VM hourly rate
    assert tco.compute == pytest.approx(
        4 * (1.0 * p.gb_second) + 4 * p.per_invocation)
    # storage legs follow the same arithmetic as the VM model
    assert tco.storage_input == pytest.approx(
        p.s3.s3_hourly_per_100tb() * 0.01 * 1.0)


def test_serverless_crossover_sits_just_above_one_tb():
    from repro.core.cost_model import (cluster_tco_at, serverless_crossover_tb,
                                       serverless_tco_at)

    x = serverless_crossover_tb()
    assert x == pytest.approx(1.01, rel=0.05)
    # at the crossover the two totals agree ...
    gap = serverless_tco_at(x).total - cluster_tco_at(x).total
    assert abs(gap) < 1e-6
    # ... and the bracket property holds: serverless wins small datasets
    # (the cluster pays its provisioning floor), loses big ones (the
    # GB-second premium)
    assert serverless_tco_at(0.1).total < cluster_tco_at(0.1).total
    assert serverless_tco_at(10.0).total > cluster_tco_at(10.0).total


def test_serverless_pricing_knob_validation():
    from repro.core.cost_model import (InvocationProfile, ServerlessCostParams,
                                       cluster_tco_at, serverless_tco_at)

    ServerlessCostParams()  # defaults are valid
    for knob, bad in [("gb_second", 0.0), ("per_invocation", -1.0),
                      ("memory_floor_mib", 0), ("memory_step_mib", 0),
                      ("duration_step_ms", 0.0),
                      ("equivalent_worker_memory_gb", 0.0),
                      ("invocations_per_100tb", -1)]:
        with pytest.raises(ValueError, match=knob):
            dataclasses.replace(ServerlessCostParams(), **{knob: bad})
    with pytest.raises(ValueError, match="seconds"):
        InvocationProfile(seconds=-1.0, peak_bytes=0)
    with pytest.raises(ValueError, match="peak_bytes"):
        InvocationProfile(seconds=0.0, peak_bytes=-1)
    with pytest.raises(ValueError, match="data_tb"):
        cluster_tco_at(0.0)
    with pytest.raises(ValueError, match="provision_hours"):
        cluster_tco_at(1.0, provision_hours=-1.0)
    with pytest.raises(ValueError, match="data_tb"):
        serverless_tco_at(-1.0)


def test_serverless_crossover_requires_a_sign_change():
    from repro.core.cost_model import (ServerlessCostParams,
                                       serverless_crossover_tb)

    # a free function fleet never crosses the cluster's cost: no root
    free = dataclasses.replace(ServerlessCostParams(),
                               gb_second=1e-12, per_invocation=0.0)
    with pytest.raises(ValueError, match="crossover_bracket"):
        serverless_crossover_tb(fn=free)


def test_tpu_model_late_beats_through_on_memory():
    t_through = tpu_sort_time_model(100e12, payload_mode="through")
    t_late = tpu_sort_time_model(100e12, payload_mode="late")
    assert t_late["t_memory_s"] < t_through["t_memory_s"]


def test_tpu_tco_has_all_legs():
    b = tpu_cloudsort_tco()
    assert b.total > 0
    assert b.compute > 0 and b.access_put == pytest.approx(5.0)
