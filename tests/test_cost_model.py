"""The paper's TCO model (Table 2) must reproduce to the cent."""
import dataclasses

import pytest

from repro.core.cost_model import (CostBreakdown, Ec2CostParams, JobProfile,
                                   cloudsort_tco, measured_tiered_cloudsort_tco,
                                   tpu_cloudsort_tco, tpu_sort_time_model)


def test_equation_1_hourly_cost():
    p = Ec2CostParams()
    # paper: $55.6044/hr
    assert p.cluster_hourly == pytest.approx(55.6044, abs=1e-3)


def test_table2_compute():
    b = cloudsort_tco()
    assert b.compute == pytest.approx(83.0674, abs=1e-3)


def test_table2_storage():
    b = cloudsort_tco()
    assert b.storage_input == pytest.approx(4.6045, abs=1e-3)
    assert b.storage_output == pytest.approx(1.6009, abs=1e-3)


def test_table2_access():
    b = cloudsort_tco()
    assert b.access_get == pytest.approx(2.4000, abs=1e-6)
    assert b.access_put == pytest.approx(5.0000, abs=1e-6)


def test_table2_total():
    assert cloudsort_tco().total == pytest.approx(96.6728, abs=5e-3)


def test_s3_hourly_rate():
    # paper: $3.0822/hr per 100 TB
    assert Ec2CostParams().s3_hourly_per_100tb() == pytest.approx(3.0822, abs=1e-3)


def test_tiered_tco_bills_only_the_durable_tier():
    from repro.io.backends import StoreStats

    tiers = {
        # durable counters are retry-inflated by construction (metrics
        # middleware counts throttled attempts) — billed as-is
        "durable": StoreStats(get_requests=10_000, put_requests=2_000,
                              retries=500, throttled=500),
        # spill traffic is huge but local: never touches the access legs
        "ssd": StoreStats(get_requests=10**9, put_requests=10**9,
                          bytes_written=10**12),
    }
    p = Ec2CostParams()
    tco = measured_tiered_cloudsort_tco(
        tiers, job_hours=1.0, reduce_hours=0.5, data_bytes=1e12)
    assert tco.access_get == pytest.approx(p.get_per_1000 * 10_000 / 1000)
    assert tco.access_put == pytest.approx(p.put_per_1000 * 2_000 / 1000)
    assert tco.storage_spill == 0.0  # i4i NVMe is bundled into compute


def test_tiered_tco_prices_attached_volume_spill_when_configured():
    from repro.io.backends import StoreStats

    tiers = {"durable": StoreStats(), "ssd": StoreStats(bytes_written=500e9)}
    p = dataclasses.replace(Ec2CostParams(), ssd_gb_month=0.08)  # gp3-like
    tco = measured_tiered_cloudsort_tco(
        tiers, job_hours=2.0, reduce_hours=1.0, data_bytes=1e12, params=p)
    assert tco.storage_spill == pytest.approx(0.08 / p.hours_per_month * 500 * 2.0)
    assert tco.total >= tco.storage_spill > 0


def test_paper_breakdown_has_zero_spill_leg():
    b = cloudsort_tco()
    assert b.storage_spill == 0.0
    assert dict(b.rows())["data_storage_spill_ssd"] == 0.0


def test_tpu_model_late_beats_through_on_memory():
    t_through = tpu_sort_time_model(100e12, payload_mode="through")
    t_late = tpu_sort_time_model(100e12, payload_mode="late")
    assert t_late["t_memory_s"] < t_through["t_memory_s"]


def test_tpu_tco_has_all_legs():
    b = tpu_cloudsort_tco()
    assert b.total > 0
    assert b.compute > 0 and b.access_put == pytest.approx(5.0)
