"""The paper's TCO model (Table 2) must reproduce to the cent."""
import pytest

from repro.core.cost_model import (CostBreakdown, Ec2CostParams, JobProfile,
                                   cloudsort_tco, tpu_cloudsort_tco,
                                   tpu_sort_time_model)


def test_equation_1_hourly_cost():
    p = Ec2CostParams()
    # paper: $55.6044/hr
    assert p.cluster_hourly == pytest.approx(55.6044, abs=1e-3)


def test_table2_compute():
    b = cloudsort_tco()
    assert b.compute == pytest.approx(83.0674, abs=1e-3)


def test_table2_storage():
    b = cloudsort_tco()
    assert b.storage_input == pytest.approx(4.6045, abs=1e-3)
    assert b.storage_output == pytest.approx(1.6009, abs=1e-3)


def test_table2_access():
    b = cloudsort_tco()
    assert b.access_get == pytest.approx(2.4000, abs=1e-6)
    assert b.access_put == pytest.approx(5.0000, abs=1e-6)


def test_table2_total():
    assert cloudsort_tco().total == pytest.approx(96.6728, abs=5e-3)


def test_s3_hourly_rate():
    # paper: $3.0822/hr per 100 TB
    assert Ec2CostParams().s3_hourly_per_100tb() == pytest.approx(3.0822, abs=1e-3)


def test_tpu_model_late_beats_through_on_memory():
    t_through = tpu_sort_time_model(100e12, payload_mode="through")
    t_late = tpu_sort_time_model(100e12, payload_mode="late")
    assert t_late["t_memory_s"] < t_through["t_memory_s"]


def test_tpu_tco_has_all_legs():
    b = tpu_cloudsort_tco()
    assert b.total > 0
    assert b.compute > 0 and b.access_put == pytest.approx(5.0)
