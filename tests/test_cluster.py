"""Cluster executor: byte-identity, failure recovery, budget governance.

The ISSUE-4 acceptance contract: the multi-worker executor must be a
pure re-scheduling of the single-host sort — byte- and etag-identical
output at any worker count, under injected worker deaths (task-counted
and mid-request), with every unfinished task of a dead worker re-executed
on survivors; and the cluster-wide adaptive reduce budget must hold.
"""
from helpers import run_with_devices

SETUP = """
import tempfile
import jax
from repro.core.external_sort import ExternalSortPlan, external_sort
from repro.core.cluster import (ClusterExecutor, ClusterFailure, ClusterPlan)
from repro.data import gensort, valsort
from repro.io.object_store import ObjectStore

from repro.core.compat import make_mesh
mesh = make_mesh((8,), ("w",))
plan = ExternalSortPlan(
    records_per_wave=1 << 13,
    num_rounds=2,
    reducers_per_worker=2,
    payload_words=2,
    impl="ref",
    input_records_per_partition=1 << 12,
    output_part_records=1 << 11,
    store_chunk_bytes=16 << 10,
    parallel_reducers=2,
    reduce_memory_budget_bytes=64 << 10,
)
N = 1 << 15  # 4 waves x 8 mesh workers; 16 output partitions
store = ObjectStore(tempfile.mkdtemp(prefix="cluster-test-"))
store.create_bucket("sort")
in_ck, nparts = gensort.write_to_store(
    store, "sort", plan.input_prefix, N,
    plan.input_records_per_partition, plan.payload_words)

def layout():
    return [(m.key, m.etag, m.size, m.parts)
            for m in store.list_objects("sort", plan.output_prefix)]
"""


def test_cluster_byte_identical_to_single_host_at_worker_counts():
    # W in {1, 2, 4}: same keys, CRC etags, sizes, and part layout as the
    # single-host driver — the executor is a re-scheduling, not a rewrite.
    run_with_devices(SETUP + """
rep0 = external_sort(store, "sort", mesh=mesh, axis_names="w", plan=plan)
want = layout()
assert len(want) == 16

for W in (1, 2, 4):
    crep = ClusterExecutor(
        store, "sort", mesh=mesh, axis_names="w", plan=plan,
        cluster=ClusterPlan(num_workers=W)).sort()
    assert layout() == want, f"W={W} changed output bytes"
    val = valsort.validate_from_store(store, "sort", plan.output_prefix, in_ck)
    assert val.ok and val.total_records == N, (W, val)
    assert crep.num_cluster_workers == W
    assert not crep.failed_workers and crep.reexecuted_tasks == 0
    assert crep.map_tasks == 4 and crep.reduce_tasks == 16
    # every task was confirmed by somebody, and the budget held globally
    assert sum(crep.per_worker_tasks.values()) == 20
    assert crep.sort.reduce_peak_merge_bytes <= plan.reduce_memory_budget_bytes
    # per-worker store views really attribute traffic
    assert sum(s.get_requests for s in crep.per_worker_stats.values()) > 0
print("OK")
""", timeout=900)


def test_killed_workers_tasks_reexecuted_and_valsort_clean():
    # Two failure modes: w1 dies at its 3rd task pop (its in-flight
    # sibling merges are severed mid-stream by the store kill switch),
    # and in a second run w2's store view dies mid-request. Both must
    # re-execute the unconfirmed tasks on survivors and keep the output
    # byte-identical to a clean run.
    run_with_devices(SETUP + """
rep0 = external_sort(store, "sort", mesh=mesh, axis_names="w", plan=plan)
want = layout()

crep = ClusterExecutor(
    store, "sort", mesh=mesh, axis_names="w", plan=plan,
    cluster=ClusterPlan(num_workers=4, fail_after_tasks={1: 2})).sort()
assert layout() == want, "task-kill run changed output bytes"
val = valsort.validate_from_store(store, "sort", plan.output_prefix, in_ck)
assert val.ok and val.total_records == N, val
assert crep.failed_workers == ["w1"], crep.failed_workers
assert crep.reexecuted_tasks >= 1, crep
# the dead worker confirmed at most its task budget; survivors covered
# the rest, and every partition is durably accounted for
assert crep.per_worker_tasks.get("w1", 0) <= 2
assert sum(crep.per_worker_tasks.values()) >= 20

crep = ClusterExecutor(
    store, "sort", mesh=mesh, axis_names="w", plan=plan,
    cluster=ClusterPlan(num_workers=4, fail_after_requests={2: 30})).sort()
assert layout() == want, "request-kill run changed output bytes"
val = valsort.validate_from_store(store, "sort", plan.output_prefix, in_ck)
assert val.ok, val
assert crep.failed_workers == ["w2"], crep.failed_workers
assert crep.reexecuted_tasks >= 1, crep
print("OK", crep.reexecuted_map_tasks, crep.reexecuted_reduce_tasks)
""", timeout=900)


def test_all_workers_dead_raises_cluster_failure():
    run_with_devices(SETUP + """
try:
    ClusterExecutor(
        store, "sort", mesh=mesh, axis_names="w", plan=plan,
        cluster=ClusterPlan(num_workers=2,
                            fail_after_tasks={0: 0, 1: 0})).sort()
except ClusterFailure as e:
    assert "workers dead" in str(e), e
else:
    raise AssertionError("expected ClusterFailure when every worker dies")
print("OK")
""")
