"""Cloud subsystem: FakeS3 wire semantics, gated real backends, and the
serverless FunctionWorker execution mode.

The acceptance contract (ISSUE 10): a FunctionWorker fleet on
FakeS3Backend — one task per invocation, no shared state except the
store — produces output byte/etag-identical to the thread fleet at
W∈{1,4}, including under a mid-phase invocation kill, with recovery via
durable multipart commit ONLY (the elastic driver is reused unchanged).
End-to-end runs need the 8-device host mesh, so they go through
helpers.run_with_devices subprocesses like the rest of the cluster
suite; the handler-level event test runs in-process on a 1-device mesh.
"""
import dataclasses
import json

import pytest

from helpers import run_with_devices
from repro.cloud import FakeS3Backend, GCSBackend, S3Backend, invoke, register_endpoint
from repro.cloud.remote import _require_dep
from repro.io.backends import ObjectNotFound, SlowDown, StoreStats
from repro.io.middleware import (MetricsMiddleware, RetryMiddleware,
                                 RetryPolicy)


# ---------------------------------------------------------------------------
# gated optional dependencies
# ---------------------------------------------------------------------------


def test_missing_dependency_gate_names_the_extra():
    # The mechanism, independent of what this container happens to have
    # installed: a missing module raises ValueError naming the pip extra
    # and pointing at the hermetic double.
    with pytest.raises(ValueError, match="boto3"):
        _require_dep("a_module_that_does_not_exist", "S3Backend", "boto3")
    with pytest.raises(ValueError, match="FakeS3Backend"):
        _require_dep("a_module_that_does_not_exist", "GCSBackend", "gcsfs")


def test_s3_backend_gates_on_boto3():
    try:
        import boto3  # noqa: F401
    except ImportError:
        with pytest.raises(ValueError, match="boto3"):
            S3Backend()
    else:
        pytest.skip("boto3 installed here: the import gate is unreachable")


def test_gcs_backend_gates_on_gcsfs():
    try:
        import gcsfs  # noqa: F401
    except ImportError:
        with pytest.raises(ValueError, match="gcsfs"):
            GCSBackend()
    else:
        pytest.skip("gcsfs installed here: the import gate is unreachable")


# ---------------------------------------------------------------------------
# FakeS3Backend: the S3-only wire behaviours (the shared contract is
# covered by tests/store_compliance.py via test_store_middleware.py)
# ---------------------------------------------------------------------------


def test_fake_s3_validates_knobs():
    with pytest.raises(ValueError, match="slowdown_every"):
        FakeS3Backend(slowdown_every=-1)
    with pytest.raises(ValueError, match="min_part_bytes"):
        FakeS3Backend(min_part_bytes=-1)


def test_fake_s3_min_part_bytes_entity_too_small():
    b = FakeS3Backend(min_part_bytes=5)
    b.create_bucket("b")
    # only the last (highest-indexed) part may be short
    mp = b.multipart("b", "ok")
    mp.put_part(0, b"x" * 10)
    mp.put_part(1, b"y" * 3)
    assert mp.complete().size == 13

    mp = b.multipart("b", "bad")
    mp.put_part(0, b"x" * 3)
    mp.put_part(1, b"y" * 10)
    with pytest.raises(ValueError, match="min_part_bytes"):
        mp.complete()
    with pytest.raises(ObjectNotFound):
        b.head("b", "bad")  # rejected completes commit nothing

    # a single small part is its own last part: fine
    mp = b.multipart("b", "single")
    mp.put_part(0, b"z")
    assert mp.complete().size == 1


def test_fake_s3_slowdown_is_deterministic_under_retry():
    stats = StoreStats()
    backend = FakeS3Backend(slowdown_every=3)
    s = RetryMiddleware(
        MetricsMiddleware(backend, stats=stats),
        RetryPolicy(max_attempts=8, base_delay_s=0.001, max_delay_s=0.01,
                    jitter=0.0),
        stats=stats, sleep=lambda _: None)
    s.create_bucket("b")
    payload = bytes(range(256))
    for i in range(8):
        s.put("b", f"k{i}", payload)  # puts throttle only via UploadPart
    for i in range(8):
        assert s.get("b", f"k{i}") == payload  # retried to completion
    assert backend.throttled > 0
    # The fixed point: every Nth data-plane attempt 503'd, regardless of
    # interleaving — attempts = logical + throttled, throttled = ⌊attempts/N⌋.
    assert backend.throttled == backend._data_attempts // 3
    d = s.stats_snapshot()
    assert d.throttled == backend.throttled  # billed attempts include 503s
    assert d.retries == d.throttled


def test_fake_s3_slowdown_without_retry_surfaces():
    b = FakeS3Backend(slowdown_every=2)
    b.create_bucket("b")
    b.put("b", "k", b"d")  # UploadPart attempt 1: allowed
    with pytest.raises(SlowDown):
        b.get("b", "k")  # attempt 2: every Nth attempt 503s
    assert b.get("b", "k") == b"d"  # attempt 3: allowed again
    assert b.throttled == 1


# ---------------------------------------------------------------------------
# the handler: one task from one JSON event, nothing else
# ---------------------------------------------------------------------------


def _tiny_plan():
    from repro.core.external_sort import ExternalSortPlan

    return ExternalSortPlan(
        records_per_wave=1 << 12,
        num_rounds=2,
        reducers_per_worker=2,
        payload_words=2,
        impl="ref",
        input_records_per_partition=1 << 11,
        output_part_records=1 << 10,
        store_chunk_bytes=16 << 10,
        parallel_reducers=1,
        reduce_memory_budget_bytes=64 << 10,
    )


def test_invoke_rebuilds_world_from_event_alone():
    # Hand-built JSON events — no Worker, no driver, no shared Python
    # state except the endpoint-registered store — must sort end to end:
    # 1 map invocation + one reduce invocation per partition, valsort-
    # accepted output. This is the statelessness thesis at handler level.
    from repro.data import gensort, valsort

    plan = _tiny_plan()
    store = MetricsMiddleware(FakeS3Backend(chunk_size=16 << 10))
    store.create_bucket("sort")
    n = 1 << 12
    in_ck, _ = gensort.write_to_store(
        store, "sort", plan.input_prefix, n,
        plan.input_records_per_partition, plan.payload_words)
    token = register_endpoint(store)

    def event(phase, task):
        e = {
            "version": 1, "worker": "hand", "phase": phase, "task": task,
            "bucket": "sort", "plan": dataclasses.asdict(plan),
            "mesh_devices": 1, "axis": "w", "boundaries": None,
            "store": {"kind": "endpoint", "token": token},
            "memory_limit_bytes": 1 << 20,
        }
        return json.loads(json.dumps(e))  # the wire: pure JSON only

    res = invoke(event("map", 0))
    assert res["committed"] and res["phase"] == "map"
    assert res["seconds"] >= 0 and res["stats"]["get_requests"] > 0

    num_partitions = 1 * plan.reducers_per_worker  # w=1 on a 1-device mesh
    peaks = []
    for r in range(num_partitions):
        res = invoke(event("reduce", r))
        assert res["committed"], r
        peaks.append(res["peak_bytes"])
    assert all(0 < p <= (1 << 20) for p in peaks)

    val = valsort.validate_from_store(store, "sort", plan.output_prefix,
                                      in_ck)
    assert val.ok and val.total_records == n


def test_invoke_requires_a_memory_bound():
    plan = dataclasses.replace(_tiny_plan(), reduce_memory_budget_bytes=0)
    store = FakeS3Backend()
    store.create_bucket("sort")
    token = register_endpoint(store)
    ev = {"version": 1, "worker": "w", "phase": "map", "task": 0,
          "bucket": "sort", "plan": dataclasses.asdict(plan),
          "mesh_devices": 1, "axis": "w", "boundaries": None,
          "store": {"kind": "endpoint", "token": token}}
    with pytest.raises(ValueError, match="memory_limit_bytes"):
        invoke(json.loads(json.dumps(ev)))


def test_invoke_rejects_unknown_store_spec_and_stale_token():
    plan = _tiny_plan()
    ev = {"version": 1, "worker": "w", "phase": "map", "task": 0,
          "bucket": "sort", "plan": dataclasses.asdict(plan),
          "mesh_devices": 1, "axis": "w", "boundaries": None,
          "memory_limit_bytes": 1 << 20,
          "store": {"kind": "endpoint", "token": "ep-never-registered"}}
    with pytest.raises(ValueError, match="endpoint"):
        invoke(json.loads(json.dumps(ev)))
    ev["store"] = {"kind": "martian"}
    with pytest.raises(ValueError, match="store"):
        invoke(json.loads(json.dumps(ev)))


def test_function_worker_validates_knobs():
    from repro.cloud import FunctionWorker, InvocationDriver

    store = FakeS3Backend()
    with pytest.raises(ValueError, match="cold_start_s"):
        FunctionWorker("f", store=store, bucket="b", plan=_tiny_plan(),
                       cold_start_s=-1.0)
    with pytest.raises(ValueError, match="memory_limit_bytes"):
        FunctionWorker("f", store=store, bucket="b", plan=_tiny_plan(),
                       memory_limit_bytes=0)
    with pytest.raises(ValueError, match="workers"):
        InvocationDriver(store, "b", plan=_tiny_plan(), workers=0)


# ---------------------------------------------------------------------------
# End-to-end: FunctionWorker fleet vs thread fleet (subprocess, 8 devices)
# ---------------------------------------------------------------------------

CLOUD_SETUP = """
import tempfile
from repro.cloud import FakeS3Backend, InvocationDriver
from repro.core.external_sort import ExternalSortPlan, external_sort
from repro.core.compat import make_mesh
from repro.data import gensort, valsort
from repro.io.middleware import MetricsMiddleware
from repro.shuffle.elastic import FleetPlan

mesh = make_mesh((8,), ("w",))
plan = ExternalSortPlan(
    records_per_wave=1 << 13,
    num_rounds=2,
    reducers_per_worker=2,
    payload_words=2,
    impl="ref",
    input_records_per_partition=1 << 12,
    output_part_records=1 << 11,
    store_chunk_bytes=16 << 10,
    parallel_reducers=2,
    reduce_memory_budget_bytes=64 << 10,
)
N = 1 << 15  # 4 map tasks; 16 output partitions
store = MetricsMiddleware(FakeS3Backend(chunk_size=16 << 10))
store.create_bucket("sort")
in_ck, nparts = gensort.write_to_store(
    store, "sort", plan.input_prefix, N,
    plan.input_records_per_partition, plan.payload_words)

def layout():
    return [(m.key, m.etag, m.size, m.parts)
            for m in store.list_objects("sort", plan.output_prefix)]

# The reference bytes come from the THREAD fleet path (single host):
# byte/etag-identity across execution substrates is the claim.
rep0 = external_sort(store, "sort", mesh=mesh, axis_names="w", plan=plan)
want = layout()
assert len(want) == 16

def check_bytes(tag):
    assert layout() == want, f"{tag} changed output bytes"
    val = valsort.validate_from_store(store, "sort", plan.output_prefix,
                                      in_ck)
    assert val.ok and val.total_records == N, (tag, val)

def drive(**kw):
    drv = InvocationDriver(store, "sort", plan=plan, workers=kw.pop("W"),
                           mesh_devices=8, axis="w", **kw)
    crep = drv.run()
    return drv, crep
"""


def test_function_worker_sort_matches_thread_fleet():
    # Clean serverless runs at W=1 (with an injected cold start) and
    # W=4: byte/etag-identical output, exactly one committed invocation
    # per task, every reduce invocation's measured peak within the
    # per-invocation budget, no heartbeat machinery involved.
    run_with_devices(CLOUD_SETUP + """
drv, crep = drive(W=1, cold_start_s=0.005)
check_bytes("serverless W=1")
assert not crep.failed_workers and crep.heartbeat_misses == 0
inv = drv.invocations()
assert sum(1 for r in inv if r.committed) == 4 + 16
assert inv[0].cold_start_s == 0.005  # first invocation paid the cold start
assert all(r.cold_start_s == 0.0 for r in inv[1:])  # warm sandbox after
assert all(r.peak_bytes <= plan.reduce_memory_budget_bytes
           for r in inv if r.phase == "reduce"), "invocation memory bound"
assert all(r.stats.get_requests > 0 for r in inv)  # each billed its own I/O

drv, crep = drive(W=4)
check_bytes("serverless W=4")
assert not crep.failed_workers
inv = drv.invocations()
assert sum(1 for r in inv if r.committed) == 4 + 16
assert len({r.worker for r in inv}) == 4  # the fleet actually fanned out

# per-invocation GB-second accounting feeds a positive, finite TCO
tco = drv.tco(data_bytes=N * plan.record_bytes)
assert tco.compute > 0 and tco.total > tco.compute
print("OK")
""", timeout=900)


def test_function_worker_recovers_from_invocation_kills():
    # (a) fn0's platform stops granting invocations after 3 (dies at the
    # 4th pop); (b) fn1's store view dies mid-invocation after 40
    # requests, stranding an open multipart session. Both recover purely
    # through the elastic driver's durable-commit accounting — the
    # output must stay byte-identical with real re-executed work.
    run_with_devices(CLOUD_SETUP + """
drv, crep = drive(W=4, die_after_invocations={0: 3})
check_bytes("serverless kill at pop")
assert "fn0" in crep.failed_workers
# fn0's commits were durable before it died and a function loses no
# spill tier with it, so exactly one commit per task still lands.
assert sum(1 for r in drv.invocations() if r.committed) == 4 + 16

drv, crep = drive(W=4, fail_after_requests={1: 40})
check_bytes("serverless kill mid-invocation")
assert "fn1" in crep.failed_workers
# the invocation died mid-task: that task re-ran on a survivor
assert crep.reexecuted_map_tasks + crep.reexecuted_reduce_tasks >= 1
inv = drv.invocations()
assert sum(1 for r in inv if r.committed) == 4 + 16
print("OK")
""", timeout=900)


def test_function_worker_sorts_through_slowdown_regime():
    # Hermetic cloud-path CI: the same serverless sort through a FakeS3
    # that 503s every 40th data-plane attempt, with the store-level
    # retry layer absorbing them — bytes identical, throttles observed.
    run_with_devices(CLOUD_SETUP + """
from repro.io.middleware import RetryMiddleware, RetryPolicy
throttled = FakeS3Backend(chunk_size=16 << 10, slowdown_every=40)
flaky = RetryMiddleware(
    MetricsMiddleware(throttled),
    RetryPolicy(max_attempts=8, base_delay_s=0.001, max_delay_s=0.01,
                jitter=0.0),
    sleep=lambda _: None)
flaky.create_bucket("sort")
gensort.write_to_store(flaky, "sort", plan.input_prefix, N,
                       plan.input_records_per_partition, plan.payload_words)
drv = InvocationDriver(flaky, "sort", plan=plan, workers=4,
                       mesh_devices=8, axis="w")
crep = drv.run()
assert not crep.failed_workers
got = [(m.key, m.etag, m.size, m.parts)
       for m in flaky.list_objects("sort", plan.output_prefix)]
assert got == want, "slowdown regime changed output bytes"
assert throttled.throttled > 0  # the regime actually fired
val = valsort.validate_from_store(flaky, "sort", plan.output_prefix, in_ck)
assert val.ok and val.total_records == N
print("OK")
""", timeout=900)
