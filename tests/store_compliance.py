"""Reusable StoreBackend protocol-compliance suite.

Every data plane that claims the repo's S3 contract —
io/backends.FilesystemBackend, io/backends.MemoryBackend,
cloud/fake_s3.FakeS3Backend, and (run manually against real buckets)
cloud/remote.S3Backend / GCSBackend — must pass the SAME suite, so the
contract is pinned once instead of re-asserted ad hoc per plane.

Usage: subclass `StoreBackendCompliance` in a test module and provide a
`backend` fixture returning a fresh backend with bucket "b" created
(see tests/test_store_middleware.py, which parameterizes over
`BACKEND_KINDS` via `make_backend`). This module deliberately has no
`test_` prefix so pytest collects the suite only through subclasses.

What the suite pins (and what it doesn't): byte semantics of ranged
GETs, multipart assembly/atomicity/abort, key hygiene, listing order,
and that the etag is a DETERMINISTIC, part-order-independent function
of the object bytes. It does NOT pin the etag algorithm itself — the
local planes use crc32, real S3 uses md5-of-md5s — because the shuffle
only ever compares etags from the same plane.
"""
import threading

import pytest

from repro.cloud.fake_s3 import FakeS3Backend
from repro.io.backends import FilesystemBackend, MemoryBackend, ObjectNotFound

BACKEND_KINDS = ("fs", "mem", "fake_s3")


def make_backend(kind: str, tmp_path, *, chunk_size: int = 64):
    """A fresh backend of `kind` with bucket "b" created."""
    if kind == "fs":
        b = FilesystemBackend(str(tmp_path / "fs"), chunk_size=chunk_size)
    elif kind == "mem":
        b = MemoryBackend(chunk_size=chunk_size)
    elif kind == "fake_s3":
        b = FakeS3Backend(chunk_size=chunk_size)
    else:
        raise ValueError(f"kind={kind!r}: unknown backend kind")
    b.create_bucket("b")
    return b


class StoreBackendCompliance:
    """The contract. Subclass + provide a `backend` fixture to run."""

    # -- objects ----------------------------------------------------------

    def test_roundtrip_and_head(self, backend):
        meta = backend.put("b", "in/p0", b"0123456789",
                           metadata={"records": 1})
        assert backend.get("b", "in/p0") == b"0123456789"
        h = backend.head("b", "in/p0")
        assert h.size == 10 and h.parts == 1
        assert h.etag == meta.etag and h.metadata == {"records": 1}
        backend.delete("b", "in/p0")
        with pytest.raises(ObjectNotFound):
            backend.get("b", "in/p0")

    def test_get_range_truncates_like_s3(self, backend):
        backend.put("b", "k", b"0123456789")
        assert backend.get_range("b", "k", 2, 4) == b"2345"
        assert backend.get_range("b", "k", 8, 100) == b"89"  # EOF truncation
        assert backend.get_range("b", "k", 20, 4) == b""

    def test_list_by_prefix_in_key_order(self, backend):
        for k in ["out/p-2", "in/p-1", "in/p-0", "spill/x"]:
            backend.put("b", k, b"d")
        assert [m.key for m in backend.list_objects("b", "in/")] == [
            "in/p-0", "in/p-1"]
        assert len(backend.list_objects("b")) == 4

    def test_missing_key_and_bucket_raise(self, backend):
        with pytest.raises(ObjectNotFound):
            backend.get("b", "nope")
        with pytest.raises(ObjectNotFound):
            backend.list_objects("no-bucket")
        with pytest.raises(ObjectNotFound):
            backend.put("no-bucket", "k", b"")

    def test_bad_keys_rejected(self, backend):
        # ValueError, not AssertionError: the guard must survive python -O
        for bad in ["/abs", "../up", "a/../b", ".hidden", ""]:
            with pytest.raises(ValueError):
                backend.put("b", bad, b"")

    def test_zero_length_get_chunks_issues_no_get(self, backend):
        from repro.io.middleware import MetricsMiddleware

        s = MetricsMiddleware(backend)
        s.put("b", "empty", b"")
        before = s.stats_snapshot()
        assert list(s.get_chunks("b", "empty")) == []
        d = s.stats_snapshot() - before
        assert d.get_requests == 0 and d.bytes_read == 0  # S3: no ranged GET
        assert d.head_requests == 1  # sizing is metadata

    def test_etag_deterministic_function_of_bytes(self, backend):
        # Same bytes -> same etag wherever/whenever written; different
        # bytes -> different etag. (The algorithm itself is per-plane.)
        a = backend.put("b", "e/a", b"identical-bytes")
        c = backend.put("b", "e/c", b"identical-bytes")
        d = backend.put("b", "e/d", b"different-bytes!")
        assert a.etag == c.etag
        assert a.etag != d.etag

    # -- multipart --------------------------------------------------------

    def test_multipart_session_streams(self, backend):
        mp = backend.multipart("b", "out/p0", metadata={"reducer": 3})
        mp.put_part(0, b"aaaa")
        mp.put_part(1, b"bb")
        # parts invisible until complete
        with pytest.raises(ObjectNotFound):
            backend.head("b", "out/p0")
        meta = mp.complete()
        assert meta.parts == 2 and meta.size == 6
        assert backend.get("b", "out/p0") == b"aaaabb"
        assert backend.head("b", "out/p0").metadata == {"reducer": 3}

        aborted = backend.multipart("b", "out/p1")
        aborted.put_part(0, b"zzz")
        aborted.abort()
        with pytest.raises(ObjectNotFound):
            backend.head("b", "out/p1")

    def test_multipart_on_missing_bucket_raises(self, backend):
        with pytest.raises(ObjectNotFound):
            backend.multipart("no-bucket", "k")

    def test_out_of_order_parts_byte_and_etag_identical(self, backend):
        # S3 UploadPart semantics: part numbers decide assembly order,
        # wire order is free. 3,1,2 must complete to an object byte- AND
        # etag-identical to the same parts uploaded sequentially.
        parts = [b"alpha-" * 7, b"bravo!" * 5, b"charlie" * 3]
        seq = backend.put_multipart("b", "seq", parts)

        mp = backend.multipart("b", "ooo")
        mp.put_part(2, parts[2])
        mp.put_part(0, parts[0])
        mp.put_part(1, parts[1])
        ooo = mp.complete()
        assert backend.get("b", "ooo") == b"".join(parts)
        assert backend.get("b", "ooo") == backend.get("b", "seq")
        assert ooo.etag == seq.etag and ooo.size == seq.size
        assert ooo.parts == seq.parts == 3

    def test_same_index_reupload_is_last_write_wins(self, backend):
        mp = backend.multipart("b", "k")
        mp.put_part(0, b"stale-part")
        mp.put_part(1, b"-tail")
        mp.put_part(0, b"fresh")  # re-uploading a part number replaces it
        meta = mp.complete()
        assert backend.get("b", "k") == b"fresh-tail"
        assert meta.parts == 2

    def test_parallel_part_uploads_complete_exact(self, backend):
        # 16 parts uploaded from racing threads complete to the exact
        # sequential byte string — the reduce path's part fan-out.
        parts = [bytes([40 + i]) * (64 + i) for i in range(16)]
        mp = backend.multipart("b", "out/wide")
        order = [11, 3, 15, 0, 7, 12, 1, 9, 14, 2, 10, 5, 13, 4, 8, 6]
        threads = [threading.Thread(target=mp.put_part, args=(i, parts[i]))
                   for i in order]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        meta = mp.complete()
        assert meta.parts == 16
        assert backend.get("b", "out/wide") == b"".join(parts)

    def test_abort_with_racing_parts_leaves_no_object(self, backend):
        mp = backend.multipart("b", "out/doomed")
        threads = [threading.Thread(target=mp.put_part,
                                    args=(i, bytes([i]) * 512))
                   for i in (3, 0, 2, 1)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        mp.abort()
        with pytest.raises(ObjectNotFound):
            backend.head("b", "out/doomed")
        assert backend.list_objects("b", "out/") == []
