"""Chaos harness: adversarial schedules against the elastic fleet.

Not a pytest module (no `test_` prefix — run it directly):

    PYTHONPATH=src python tests/chaos.py --smoke   # CI: the short set
    PYTHONPATH=src python tests/chaos.py           # every schedule

Each schedule runs the CloudSort job under one injected failure mode —
task-budget kills, request-budget kills, a worker that keeps working but
goes HEARTBEAT-SILENT, a straggler store with speculation racing it,
mid-job admission/retirement, multi-worker kills, and process-fleet
kills (`os._exit`, no goodbye) — and then asserts the two invariants the
whole design hangs on:

  * the output layout (keys, CRC etags, sizes, part counts) is
    byte-identical to a clean single-host reference run, and
  * valsort accepts the result (globally sorted, checksum preserved).

Schedules also pin the OBSERVABILITY of each failure: the tracer must
carry the matching `cluster.*` events (heartbeat_miss, speculate,
spill_lost, worker_dead, ...) so operators can see what the recovery
machinery did, not just that bytes came out right.
"""
import os

# Before the first jax import: the schedules need an 8-device host mesh.
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import argparse  # noqa: E402
import tempfile  # noqa: E402
import threading  # noqa: E402
import time  # noqa: E402

from repro.core.external_sort import ExternalSortPlan  # noqa: E402
from repro.core.compat import make_mesh  # noqa: E402
from repro.data import gensort, valsort  # noqa: E402
from repro.io.middleware import (FaultProfile, KillSwitchMiddleware,  # noqa: E402
                                 LatencyBandwidthMiddleware)
from repro.io.object_store import ObjectStore  # noqa: E402
from repro.obs.events import Tracer  # noqa: E402
from repro.shuffle.elastic import FleetPlan  # noqa: E402
from repro.shuffle.executor import (FaultyWorker, ThreadWorker,  # noqa: E402
                                    Worker, WorkerFailure)
from repro.shuffle.sort import sort_shuffle_job  # noqa: E402

PLAN = ExternalSortPlan(
    records_per_wave=1 << 13,
    num_rounds=2,
    reducers_per_worker=2,
    payload_words=2,
    impl="ref",
    input_records_per_partition=1 << 12,
    output_part_records=1 << 11,
    store_chunk_bytes=16 << 10,
    parallel_reducers=2,
    reduce_memory_budget_bytes=64 << 10,
)
N = 1 << 15  # 4 map tasks; 16 output partitions


class MuteWorker(Worker):
    """A worker that keeps WORKING but stops heartbeating after
    `mute_after_tasks` pops — the failure mode only the monitor can
    catch (the store keeps answering, so no request ever fails). The
    driver's `fence()` must then sever its store view so its in-flight
    attempts cannot reach a durable commit after it was declared dead.
    """

    def __init__(self, inner: Worker, *, mute_after_tasks: int):
        self.inner = inner
        self.name = inner.name
        self._kill = KillSwitchMiddleware(
            inner.store,
            exc_factory=lambda: WorkerFailure(
                f"{self.name}: fenced after heartbeat loss"))
        self.store = inner.store = self._kill
        self._lock = threading.Lock()
        self._remaining = mute_after_tasks
        self._muted = threading.Event()
        self._frozen = time.monotonic()

    def _gated(self, pop_next):
        def pop():
            task = pop_next()
            if task is not None:
                with self._lock:
                    self._remaining -= 1
                    if self._remaining <= 0 and not self._muted.is_set():
                        self._frozen = time.monotonic()
                        self._muted.set()
            return task
        return pop

    def run_map_phase(self, ctx, pop_next, on_done):
        self.inner.run_map_phase(ctx, self._gated(pop_next), on_done)

    def run_reduce_phase(self, ctx, pop_next, on_done):
        self.inner.run_reduce_phase(ctx, self._gated(pop_next), on_done)

    def last_beat(self):
        return self._frozen if self._muted.is_set() else time.monotonic()

    def fence(self):
        self._kill.trip()


class Harness:
    """One store + reference layout, many adversarial schedules."""

    def __init__(self):
        self.mesh = make_mesh((8,), ("w",))
        self.root = tempfile.mkdtemp(prefix="chaos-")
        self.store = ObjectStore(self.root)
        self.store.create_bucket("sort")
        self.in_ck, _ = gensort.write_to_store(
            self.store, "sort", PLAN.input_prefix, N,
            PLAN.input_records_per_partition, PLAN.payload_words)
        print("chaos: computing clean reference layout ...")
        sort_shuffle_job(self.store, "sort", mesh=self.mesh, axis_names="w",
                         plan=PLAN).run(workers=0)
        self.want = self.layout()
        assert len(self.want) == 16

    def layout(self):
        return [(m.key, m.etag, m.size, m.parts)
                for m in self.store.list_objects("sort", PLAN.output_prefix)]

    def run(self, crew, fleet, tracer):
        job = sort_shuffle_job(self.store, "sort", mesh=self.mesh,
                               axis_names="w", plan=PLAN, tracer=tracer)
        return job.run(worker_list=crew, fleet=fleet)

    def check_bytes(self, tag):
        assert self.layout() == self.want, f"{tag}: output bytes diverged"
        val = valsort.validate_from_store(self.store, "sort",
                                          PLAN.output_prefix, self.in_ck)
        assert val.ok and val.total_records == N, (tag, val)

    @staticmethod
    def events(tracer, name):
        return [e for e in tracer.log.events() if e["name"] == name]


# -- schedules (each: run, byte-check, event-check) -------------------------


def schedule_clean(h: Harness):
    """Baseline: the elastic driver with nothing injected."""
    tr = Tracer("chaos-clean")
    crew = [ThreadWorker(f"w{i}", h.store) for i in range(3)]
    crep = h.run(crew, FleetPlan(), tr)
    h.check_bytes("clean")
    assert not crep.failed_workers and crep.recovery_rounds == 0
    assert not h.events(tr, "cluster.worker_dead")


def schedule_task_kill(h: Harness):
    """w0 dies at its 7th task pop (inside reduce): spill-tier loss,
    lineage re-execution, reduce resumption."""
    tr = Tracer("chaos-task-kill")
    crew = [FaultyWorker(ThreadWorker("w0", h.store), fail_after_tasks=6),
            ThreadWorker("w1", h.store)]
    crep = h.run(crew, FleetPlan(), tr)
    h.check_bytes("task_kill")
    assert crep.failed_workers == ["w0"]
    assert crep.spill_lost_map_tasks >= 1, crep
    assert h.events(tr, "cluster.worker_dead")
    assert h.events(tr, "cluster.spill_lost")


def schedule_request_kill(h: Harness):
    """w1's store view dies mid-request-stream: in-flight sibling merges
    are severed with partial multipart sessions behind them."""
    tr = Tracer("chaos-request-kill")
    crew = [ThreadWorker("w0", h.store),
            FaultyWorker(ThreadWorker("w1", h.store), fail_after_requests=40),
            ThreadWorker("w2", h.store)]
    crep = h.run(crew, FleetPlan(), tr)
    h.check_bytes("request_kill")
    assert crep.failed_workers == ["w1"]
    assert h.events(tr, "cluster.worker_dead")


def schedule_heartbeat_mute(h: Harness):
    """w0 keeps working but goes silent: only the heartbeat monitor can
    declare it dead; the fence must stop its in-flight commits."""
    tr = Tracer("chaos-mute")
    crew = [MuteWorker(ThreadWorker("w0", h.store), mute_after_tasks=2),
            ThreadWorker("w1", h.store)]
    fleet = FleetPlan(heartbeat_timeout_s=0.5, monitor_interval_s=0.05)
    crep = h.run(crew, fleet, tr)
    h.check_bytes("heartbeat_mute")
    assert "w0" in crep.failed_workers, crep.failed_workers
    assert crep.heartbeat_misses >= 1, crep
    misses = h.events(tr, "cluster.heartbeat_miss")
    assert misses and misses[0]["worker"] == "w0"


def schedule_speculation(h: Harness):
    """One straggler HOST (latency-injected store view): speculation
    duplicates its laggards and the fast copy wins the commit race."""
    tr = Tracer("chaos-speculation")
    slow = LatencyBandwidthMiddleware(h.store, FaultProfile(latency_s=0.25))
    crew = [ThreadWorker("w0", h.store), ThreadWorker("w1", h.store),
            ThreadWorker("slow", slow)]
    fleet = FleetPlan(speculation=True, speculation_min_samples=3,
                      speculation_quantile=0.5, speculation_factor=2.0,
                      speculation_min_s=0.1)
    crep = h.run(crew, fleet, tr)
    h.check_bytes("speculation")
    assert not crep.failed_workers
    assert crep.speculated_tasks >= 1 and crep.speculation_wins >= 1, crep
    assert h.events(tr, "cluster.speculate")


def schedule_membership(h: Harness):
    """Scale events mid-job: retire w1 at the start, admit a late joiner
    while the phases run."""
    tr = Tracer("chaos-membership")
    job = sort_shuffle_job(h.store, "sort", mesh=h.mesh, axis_names="w",
                           plan=PLAN, tracer=tr)
    session = job.prepare(schedulers=2)
    crew = [ThreadWorker(f"w{i}", h.store) for i in range(2)]
    late = ThreadWorker("late", h.store)

    def membership():
        while getattr(session, "driver", None) is None:
            time.sleep(0.005)
        session.driver.retire("w1")
        session.driver.admit(late)

    t = threading.Thread(target=membership, daemon=True)
    t.start()
    crep = session.run_elastic(crew, FleetPlan())
    t.join()
    h.check_bytes("membership")
    assert crep.workers_admitted == 1 and crep.workers_retired == 1
    assert crep.per_worker_tasks.get("late", 0) >= 1, crep.per_worker_tasks
    assert h.events(tr, "cluster.worker_admitted")
    assert h.events(tr, "cluster.worker_retired")


def schedule_multi_kill(h: Harness):
    """Half the fleet dies (2 of 4, staggered): survivors absorb both
    spill losses and every re-executed wave."""
    tr = Tracer("chaos-multi-kill")
    crew = [FaultyWorker(ThreadWorker("w0", h.store), fail_after_tasks=3),
            ThreadWorker("w1", h.store),
            FaultyWorker(ThreadWorker("w2", h.store), fail_after_tasks=4),
            ThreadWorker("w3", h.store)]
    crep = h.run(crew, FleetPlan(), tr)
    h.check_bytes("multi_kill")
    assert sorted(crep.failed_workers) == ["w0", "w2"], crep.failed_workers
    assert len(h.events(tr, "cluster.worker_dead")) == 2


def schedule_process_kill(h: Harness):
    """Real process fleet; p0 os._exit(3)s at its 5th pop — no goodbye
    message, just EOF on the pipe — and its spill tier goes with it."""
    from repro.shuffle.procworker import ProcessWorker

    tr = Tracer("chaos-process-kill")
    crew = [ProcessWorker("p0", store=h.store, bucket="sort", plan=PLAN,
                          die_after_tasks=4),
            ProcessWorker("p1", store=h.store, bucket="sort", plan=PLAN)]
    try:
        crep = h.run(crew, FleetPlan(), tr)
    finally:
        for wk in crew:
            wk.close()
    h.check_bytes("process_kill")
    assert crep.failed_workers == ["p0"], crep.failed_workers
    assert crep.spill_lost_map_tasks >= 1 and crep.recovery_rounds >= 1, crep
    assert h.events(tr, "cluster.spill_lost")


def schedule_process_map_gate(h: Harness):
    """PR-8 pin: the process child's MAP chunk loop must poll the
    speculation commit gate per fetched chunk. An always-False gate
    (every attempt already lost its race) must make the child abandon
    every task at its FIRST gated read — zero confirmations, zero spill
    bytes — and the gate RPC must have been consulted for every task."""
    import dataclasses

    from repro.shuffle import executor as ex
    from repro.shuffle.procworker import ProcessWorker

    plan = dataclasses.replace(PLAN, spill_prefix="gate-spill/",
                               output_prefix="gate-output/")
    session = sort_shuffle_job(h.store, "sort", mesh=h.mesh, axis_names="w",
                               plan=plan).prepare()
    calls = []
    lock = threading.Lock()

    def gate(worker, g):
        with lock:
            calls.append((worker, g))
        return False  # every attempt has already lost: must abandon

    ctx = ex.WorkerContext(
        plan=plan, bucket="sort", map_op=session.job.map_op,
        reduce_shared=session.shared, timeline=session.timeline,
        control=session.control, num_map_tasks=session.num_tasks,
        map_commit_gate=gate)
    tasks = iter(range(session.num_tasks))
    done = []
    wk = ProcessWorker("pg0", store=h.store, bucket="sort", plan=plan)
    try:
        wk.run_map_phase(ctx, lambda: next(tasks, None), done.append)
    finally:
        wk.close()
    assert not done, f"lost attempts confirmed map tasks: {done}"
    assert {g for _, g in calls} == set(range(session.num_tasks)), calls
    spills = h.store.list_objects("sort", plan.spill_prefix)
    assert not list(spills), "abandoned map attempts spilled bytes"


def schedule_process_map_speculation(h: Harness):
    """End-to-end flavour of the same pin: a straggling PROCESS worker's
    map task is speculated, the fast copy commits first, and the
    straggler's in-flight attempt abandons mid-fetch via the commit RPC
    instead of streaming its whole wave."""
    from repro.shuffle.procworker import ProcessWorker

    tr = Tracer("chaos-process-map-speculation")
    crew = [ProcessWorker("p0", store=h.store, bucket="sort", plan=PLAN,
                          fault={"latency_s": 0.3}),
            ProcessWorker("p1", store=h.store, bucket="sort", plan=PLAN)]
    fleet = FleetPlan(speculation=True, speculation_min_samples=2,
                      speculation_quantile=0.5, speculation_factor=1.5,
                      speculation_min_s=0.1)
    try:
        crep = h.run(crew, fleet, tr)
    finally:
        for wk in crew:
            wk.close()
    h.check_bytes("process_map_speculation")
    assert not crep.failed_workers, crep.failed_workers
    assert crep.speculated_tasks >= 1 and crep.speculation_wins >= 1, crep
    spec = h.events(tr, "cluster.speculate")
    assert any(e.get("phase") == "map" for e in spec), spec


def schedule_recursive_kill(h: Harness):
    """A worker dies mid-round of a RECURSIVE shuffle: duplicate-heavy
    input whose hot partition exceeds the reduce budget, so the sort
    runs sampled boundaries + multi-round recursion (shuffle/recursive)
    — and w0's death must leave every round's output byte/etag-identical
    to the clean reference, with the recovery AND the recursion both
    visible on the tracer."""
    import dataclasses

    from repro.shuffle.recursive import recursive_sort

    plan = dataclasses.replace(
        PLAN,
        input_prefix="rec-input/", spill_prefix="rec-spill/",
        output_prefix="rec-output/",
        capacity_factor=4.0, sample_fraction=1 / 16, max_rounds=3)
    in_ck, _ = gensort.write_to_store(
        h.store, "sort", plan.input_prefix, N,
        plan.input_records_per_partition, plan.payload_words,
        skew="dup", skew_seed=3)

    def rec_layout():
        return [(m.key, m.etag, m.size, m.parts)
                for m in h.store.list_objects("sort", plan.output_prefix)]

    clean = recursive_sort(h.store, "sort", mesh=h.mesh, axis_names="w",
                           plan=plan, workers=0)
    assert clean.num_rounds >= 3 and clean.recursed, clean.rounds
    want = rec_layout()
    val = valsort.validate_from_store(h.store, "sort", plan.output_prefix,
                                      in_ck)
    assert val.ok and val.total_records == N, val

    tr = Tracer("chaos-recursive-kill")
    crew = [FaultyWorker(ThreadWorker("w0", h.store), fail_after_tasks=4),
            ThreadWorker("w1", h.store)]
    crep = recursive_sort(h.store, "sort", mesh=h.mesh, axis_names="w",
                          plan=plan, worker_list=crew, fleet=FleetPlan(),
                          tracer=tr)
    assert rec_layout() == want, "recursive_kill: output bytes diverged"
    val = valsort.validate_from_store(h.store, "sort", plan.output_prefix,
                                      in_ck)
    assert val.ok and val.total_records == N, val
    assert any("w0" in getattr(r, "failed_workers", [])
               for _, _, r in crep.rounds), "w0 never died"
    assert h.events(tr, "cluster.worker_dead")
    rounds = h.events(tr, "recursive.round")
    assert len(rounds) == len(crep.rounds) >= 3, rounds
    assert h.events(tr, "recursive.redirect")


SMOKE = [schedule_clean, schedule_task_kill, schedule_heartbeat_mute,
         schedule_speculation, schedule_process_map_gate,
         schedule_recursive_kill]
FULL = SMOKE + [schedule_request_kill, schedule_membership,
                schedule_multi_kill, schedule_process_kill,
                schedule_process_map_speculation]


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="run the short CI set only")
    args = ap.parse_args(argv)
    schedules = SMOKE if args.smoke else FULL
    h = Harness()
    for sched in schedules:
        t0 = time.perf_counter()
        sched(h)
        print(f"chaos: {sched.__name__} OK "
              f"({time.perf_counter() - t0:.1f}s)")
    print(f"chaos: {len(schedules)} schedules passed, output byte-identical "
          "under every one")


if __name__ == "__main__":
    main()
