"""gensort/valsort ports + data pipeline determinism and restartability."""
import jax.numpy as jnp
import numpy as np

from repro.data import gensort
from repro.data.pipeline import (DataConfig, TokenPipeline, sample_tokens,
                                 shuffled_indices, length_sorted_batches)


def test_gensort_deterministic():
    k1, i1 = gensort.gen_keys(100, 50)
    k2, i2 = gensort.gen_keys(100, 50)
    np.testing.assert_array_equal(k1, k2)
    p1 = gensort.gen_payload(i1, 4)
    p2 = gensort.gen_payload(i2, 4)
    np.testing.assert_array_equal(p1, p2)


def test_gensort_keys_uniformish():
    k, _ = gensort.gen_keys(0, 1 << 16)
    buckets = np.bincount(np.asarray(k) >> 28, minlength=16)
    assert buckets.min() > (1 << 16) / 16 * 0.9  # Indy-uniform keys


def test_checksum_order_independent():
    k, i = gensort.gen_keys(0, 1000)
    perm = np.random.default_rng(0).permutation(1000)
    c1 = gensort.checksum(k, i)
    c2 = gensort.checksum(jnp.asarray(np.asarray(k)[perm]),
                          jnp.asarray(np.asarray(i)[perm]))
    assert tuple(map(int, c1)) == tuple(map(int, c2))


def test_checksum_sensitive_to_payload():
    k, i = gensort.gen_keys(0, 100)
    p = gensort.gen_payload(i, 4)
    c1 = gensort.checksum(k, i, p)
    p2 = jnp.asarray(np.asarray(p).copy())
    p2 = p2.at[5, 2].add(1)
    c2 = gensort.checksum(k, i, p2)
    assert tuple(map(int, c1)) != tuple(map(int, c2))


def test_epoch_shuffle_permutation_and_determinism():
    a = shuffled_indices(0, 4096)
    b = shuffled_indices(0, 4096)
    c = shuffled_indices(1, 4096)
    np.testing.assert_array_equal(a, b)
    assert not (a == c).all()
    np.testing.assert_array_equal(np.sort(a), np.arange(4096))


def test_pipeline_restart_resumes_stream():
    cfg = DataConfig(vocab=100, seq_len=16, global_batch=8, num_samples=64)
    p1 = TokenPipeline(cfg)
    seq = [np.asarray(p1.batch_at(s)["tokens"]) for s in range(20)]
    p2 = TokenPipeline(cfg)  # "restarted" trainer
    for s in (5, 13, 19):
        np.testing.assert_array_equal(np.asarray(p2.batch_at(s)["tokens"]),
                                      seq[s])


def test_labels_are_shifted_tokens():
    cfg = DataConfig(vocab=50, seq_len=8, global_batch=4, num_samples=16)
    b = TokenPipeline(cfg).batch_at(0)
    toks = sample_tokens(np.asarray(shuffled_indices(0, 16)[:4]), 8, 50)
    np.testing.assert_array_equal(np.asarray(b["tokens"]), toks[:, :-1])
    np.testing.assert_array_equal(np.asarray(b["labels"]), toks[:, 1:])


def test_length_sorted_batches():
    lengths = np.array([5, 1, 9, 3, 7, 2, 8, 4])
    batches = length_sorted_batches(lengths, 2)
    flat = lengths[batches.reshape(-1)]
    assert (np.diff(flat) >= 0).all()
