"""The shuffle library: partitioner contracts, plan validation, the
ShuffleJob sort path, and the group-by workload.

The ISSUE-5 acceptance contract: CloudSort through the new ShuffleJob
API must be byte- and etag-identical to the pre-refactor drivers at
W in {1, 4} and under a worker kill (the deprecated shims' own tests in
test_external_sort.py / test_cluster.py pin the shim side); any
Partitioner implementation must yield exhaustive, non-overlapping
ranges; skewed key distributions must still sort byte-identically at
any schedule; and the group-by workload must run end-to-end on the
throttled+latency tiered store with no sort-specific code in its
operators.
"""
import numpy as np
import pytest

from helpers import run_with_devices


# ---------------------------------------------------------------------------
# Partitioner properties (pure numpy — no devices needed)
# ---------------------------------------------------------------------------


def _all_partitioners():
    from repro.shuffle.partition import HashPartitioner, RangePartitioner

    parts = []
    for p in (1, 2, 3, 7, 16, 1000):
        parts.append(RangePartitioner(p))
        parts.append(HashPartitioner(p))
    # Sampled (explicit, deliberately lopsided) boundaries, duplicates
    # included — degenerate empty ranges are legal, overlap is not.
    parts.append(RangePartitioner(
        5, boundaries=np.array([10, 10, 1 << 20, 1 << 31], np.uint32)))
    return parts


def _probe_keys(rng):
    """Adversarial key sample: dense sweep + uniform draw + boundary
    neighbourhoods get appended per-partitioner by the caller."""
    dense = np.linspace(0, (1 << 32) - 1, 4096).astype(np.uint32)
    uniform = rng.integers(0, 1 << 32, size=4096, dtype=np.uint64)
    edges = np.array([0, 1, (1 << 32) - 1], np.uint64)
    return np.concatenate([dense.astype(np.uint64), uniform, edges])


def test_partitioner_ranges_exhaustive_and_non_overlapping():
    # The property, for EVERY implementation: boundaries are ascending
    # (non-overlap), every routed key lands in exactly one partition id
    # within range (exhaustive), and partition_of agrees with the
    # boundary definition b[j-1] <= route(k) < b[j] on keys sitting
    # directly on and around every boundary.
    rng = np.random.default_rng(7)
    for part in _all_partitioners():
        bounds = np.asarray(part.boundaries(), np.uint64)
        assert bounds.shape == (part.num_partitions - 1,), part
        assert bool(np.all(bounds[1:] >= bounds[:-1])), (part, bounds)

        keys = _probe_keys(rng)
        if bounds.size:  # boundary neighbourhoods, clipped to u32
            near = np.concatenate([bounds - 1, bounds, bounds + 1])
            keys = np.concatenate([keys, near & 0xFFFFFFFF])
        keys = keys.astype(np.uint32)
        got = part.partition_of(keys)
        assert got.min() >= 0 and got.max() < part.num_partitions, part
        # exactly the searchsorted contract over the routed domain
        want = np.searchsorted(bounds.astype(np.uint32),
                               part.route(keys), side="right")
        assert np.array_equal(got, want), part
        # monotone in the routed domain (ranges, not interleaving)
        routed = part.route(keys)
        order = np.argsort(routed, kind="stable")
        assert bool(np.all(np.diff(got[order]) >= 0)), part


def test_equal_range_partitioner_covers_every_partition():
    from repro.shuffle.partition import RangePartitioner

    # Equal split: a dense sweep must populate every partition (no empty
    # range can hide in an equal split of a dense domain).
    for p in (2, 3, 16, 255):
        part = RangePartitioner(p)
        keys = np.linspace(0, (1 << 32) - 1, 64 * p).astype(np.uint32)
        assert len(np.unique(part.partition_of(keys))) == p


def test_range_partitioner_matches_device_keyspace():
    # The host-side RangePartitioner and the device-side KeySpace must
    # route identically, or map (device) and reduce (host) would
    # disagree about partition ownership.
    from repro.core.keyspace import KeySpace
    from repro.shuffle.partition import RangePartitioner

    for r, w in ((16, 8), (24, 8), (625, 5)):
        ks = KeySpace(num_reducers=r, num_workers=w)
        part = RangePartitioner(r)
        assert np.array_equal(np.asarray(ks.reducer_boundaries()),
                              part.boundaries()), (r, w)
        rng = np.random.default_rng(r)
        keys = rng.integers(0, 1 << 32, size=2048, dtype=np.uint64)
        keys = keys.astype(np.uint32)
        assert np.array_equal(np.asarray(ks.reducer_of_key(keys)),
                              part.partition_of(keys)), (r, w)


def test_partitioner_validation_errors_name_knob_and_value():
    from repro.shuffle.partition import HashPartitioner, RangePartitioner

    with pytest.raises(ValueError, match="num_partitions=0"):
        RangePartitioner(0)
    with pytest.raises(ValueError, match="num_partitions=-3"):
        HashPartitioner(-3)
    with pytest.raises(ValueError, match="boundaries"):
        RangePartitioner(3, boundaries=np.array([5], np.uint32))
    with pytest.raises(ValueError, match="ascending"):
        RangePartitioner(3, boundaries=np.array([9, 4], np.uint32))


# ---------------------------------------------------------------------------
# Unified plan validation: ValueError with knob name + value everywhere
# ---------------------------------------------------------------------------


def test_shuffle_plan_validation_names_knob_and_value():
    import dataclasses

    from repro.shuffle.api import ShufflePlan

    ShufflePlan().validate()  # defaults are feasible
    bad = {
        "parallel_reducers": 0,
        "part_upload_fanout": 0,
        "prefetch_depth": 0,
        "max_inflight_writes": 0,
        "io_retries": -1,
        "output_part_records": 0,
        "store_chunk_bytes": 0,
        "merge_chunk_bytes": 3,  # < one record
        "reduce_memory_budget_bytes": -1,
        "input_prefix": "",
    }
    for knob, value in bad.items():
        plan = dataclasses.replace(ShufflePlan(), **{knob: value})
        with pytest.raises(ValueError, match=f"{knob}={value!r}"):
            plan.validate()
    # spill/output prefix collision is a layout error, not a typo
    with pytest.raises(ValueError, match="spill_prefix"):
        dataclasses.replace(ShufflePlan(), spill_prefix="out/",
                            output_prefix="out/").validate()
    # and ANY overlap with input_prefix must fail validation: session
    # preflight deletes spill/output prefixes, so an overlap would
    # destroy the input before the map phase runs
    for knob in ("spill_prefix", "output_prefix"):
        for value in ("input/", "in", "input/sub/"):
            with pytest.raises(ValueError, match="overlaps"):
                dataclasses.replace(
                    ShufflePlan(), **{knob: value}).validate()


def test_overlapping_prefixes_rejected_before_any_delete():
    # The destructive case end-to-end: a spill prefix shadowing the
    # input prefix must fail in preflight with the input intact.
    from repro.io.backends import MemoryBackend
    from repro.shuffle.api import ShufflePlan
    from repro.shuffle.groupby import groupby_job, write_groupby_input

    store = MemoryBackend()
    store.create_bucket("b")
    plan = ShufflePlan(payload_words=1, spill_prefix="input/")
    write_groupby_input(store, "b", "input/", 1 << 10, 1 << 9,
                        num_groups=16)
    with pytest.raises(ValueError, match="spill_prefix='input/'"):
        groupby_job(store, "b", plan=plan, num_partitions=4).run()
    assert len(store.list_objects("b", "input/")) == 2, (
        "preflight must not have deleted the input")


def test_external_sort_and_cluster_plan_validation():
    import dataclasses

    from repro.core.cluster import ClusterPlan
    from repro.core.external_sort import ExternalSortPlan

    ExternalSortPlan(records_per_wave=1 << 12).validate()
    for knob, value in (("records_per_wave", 0), ("num_rounds", 0),
                        ("reducers_per_worker", 0),
                        ("capacity_factor", 0.0),
                        ("parallel_reducers", 0)):
        plan = dataclasses.replace(
            ExternalSortPlan(records_per_wave=1 << 12), **{knob: value})
        with pytest.raises(ValueError, match=f"{knob}="):
            plan.validate()

    with pytest.raises(ValueError, match="num_workers=0"):
        ClusterPlan(num_workers=0)
    with pytest.raises(ValueError, match="fail_after_tasks"):
        ClusterPlan(num_workers=2, fail_after_tasks={5: 1})
    with pytest.raises(ValueError, match="fail_after_requests"):
        ClusterPlan(num_workers=2, fail_after_requests={0: -1})


def test_budget_feasibility_raises_before_any_request():
    # An infeasible budget must fail in session preflight — before any
    # input byte is fetched (and billed).
    from repro.io.backends import MemoryBackend
    from repro.io.middleware import MetricsMiddleware
    from repro.shuffle.api import ShufflePlan
    from repro.shuffle.groupby import groupby_job, write_groupby_input

    store = MetricsMiddleware(MemoryBackend())
    store.create_bucket("b")
    plan = ShufflePlan(payload_words=1, merge_chunk_bytes=1 << 10,
                       parallel_reducers=4,
                       reduce_memory_budget_bytes=64)  # < 1 record/run
    write_groupby_input(store, "b", plan.input_prefix, 1 << 10, 1 << 9,
                        num_groups=32)
    base = store.stats_snapshot()
    with pytest.raises(ValueError, match="reduce_memory_budget_bytes=64"):
        groupby_job(store, "b", plan=plan, num_partitions=4).run()
    delta = store.stats_snapshot() - base
    assert delta.get_requests == 0 and delta.put_requests == 0


# ---------------------------------------------------------------------------
# Group-by: the second workload, end-to-end on the faulty tiered store
# ---------------------------------------------------------------------------


def test_groupby_end_to_end_on_throttled_tiered_store():
    # The generality acceptance gate: keyed aggregation with a map-side
    # combiner on the same latency+throttle+retry tiered stack the sort
    # uses, reusing staging / budget governor / fault recovery — and
    # byte-identical output across combiner on/off, worker counts, and
    # an injected worker death.
    import tempfile

    from repro.io.middleware import FaultProfile, RetryPolicy
    from repro.io.tiered import tiered_cloudsort_store
    from repro.shuffle.api import ShufflePlan
    from repro.shuffle.executor import ClusterPlan
    from repro.shuffle.groupby import (groupby_job, validate_groupby_from_store,
                                       write_groupby_input)

    plan = ShufflePlan(payload_words=1, store_chunk_bytes=8 << 10,
                       merge_chunk_bytes=2 << 10, output_part_records=1 << 9,
                       parallel_reducers=3,
                       reduce_memory_budget_bytes=64 << 10)
    store = tiered_cloudsort_store(
        tempfile.mkdtemp(prefix="groupby-faulty-"),
        spill_prefixes=(plan.spill_prefix,),
        faults=FaultProfile(latency_s=0.001, bandwidth_bps=400e6,
                            get_rate=80.0, put_rate=50.0, burst=8.0),
        retry=RetryPolicy(max_attempts=12, base_delay_s=0.01,
                          max_delay_s=0.25),
    )
    store.create_bucket("agg")
    N = 1 << 14
    expected_counts, expected_sums = write_groupby_input(
        store, "agg", plan.input_prefix, N, 1 << 11,
        num_groups=700, skew=2.5)  # word-frequency-shaped skew

    job = groupby_job(store, "agg", plan=plan, num_partitions=8)
    rep = job.run()
    assert rep.total_records == N and rep.num_map_tasks == 8
    assert rep.num_partitions == 8 and rep.output_objects == 8
    # the library machinery really engaged: budget held, spans recorded
    assert 0 < rep.reduce_peak_merge_bytes <= plan.reduce_memory_budget_bytes
    assert rep.phase_seconds.get("map.compute", 0) > 0
    assert rep.phase_seconds.get("reduce.merge", 0) > 0
    # faults were really injected and absorbed
    assert rep.stats.retries > 0 and rep.stats.throttled > 0
    # spill traffic routed to the (free) ssd tier
    assert rep.tier_stats["ssd"].put_requests == rep.spill_objects
    assert rep.tier_stats["durable"].bytes_written > 0

    val = validate_groupby_from_store(
        store, "agg", plan.output_prefix, job.partitioner,
        expected_counts, expected_sums)
    assert val.ok and val.total_groups == 700, val

    def layout():
        return [(m.key, m.etag, m.size, m.parts)
                for m in store.list_objects("agg", plan.output_prefix)]

    want = layout()

    # combiner off: more spilled bytes, identical output bytes
    rep_raw = groupby_job(store, "agg", plan=plan, num_partitions=8,
                          combine=False).run()
    assert layout() == want, "combiner changed output bytes"
    assert rep_raw.tier_stats["ssd"].bytes_written > \
        rep.tier_stats["ssd"].bytes_written, "combiner did not shrink spill"

    # cluster mode with one injected death: recovered, byte-identical
    crep = groupby_job(store, "agg", plan=plan, num_partitions=8).run(
        cluster=ClusterPlan(num_workers=4, fail_after_tasks={1: 2}))
    assert layout() == want, "worker failure changed output bytes"
    assert crep.failed_workers == ["w1"] and crep.reexecuted_tasks >= 1
    val = validate_groupby_from_store(
        store, "agg", plan.output_prefix, job.partitioner,
        expected_counts, expected_sums)
    assert val.ok, val


def test_groupby_deferred_header_and_carry_at_tiny_chunks():
    # merge_chunk_bytes at the one-record floor forces maximal emit
    # cycles (every group straddles windows -> the carry path), and a
    # partition count above the group count forces empty partitions
    # (header-only part-0 objects).
    from repro.io.backends import MemoryBackend
    from repro.shuffle.api import ShufflePlan
    from repro.shuffle.groupby import (groupby_job, validate_groupby_from_store,
                                       write_groupby_input)

    plan = ShufflePlan(payload_words=1, merge_chunk_bytes=12,  # one record
                       output_part_records=4, parallel_reducers=2)
    store = MemoryBackend()
    store.create_bucket("b")
    expected_counts, expected_sums = write_groupby_input(
        store, "b", plan.input_prefix, 1 << 10, 1 << 8, num_groups=5,
        skew=3.0)
    job = groupby_job(store, "b", plan=plan, num_partitions=16)
    job.run()
    val = validate_groupby_from_store(
        store, "b", plan.output_prefix, job.partitioner,
        expected_counts, expected_sums)
    assert val.ok and val.total_groups == 5, val
    metas = store.list_objects("b", plan.output_prefix)
    assert len(metas) == 16
    assert any(m.size == 16 for m in metas), "expected empty partitions"


# ---------------------------------------------------------------------------
# The sort through the ShuffleJob API (subprocess: needs 8 host devices)
# ---------------------------------------------------------------------------

SORT_SETUP = """
import dataclasses
import tempfile
import numpy as np
import jax
from repro.core.external_sort import ExternalSortPlan, external_sort
from repro.core.compat import make_mesh
from repro.data import gensort, valsort
from repro.io.object_store import ObjectStore
from repro.shuffle.executor import ClusterPlan
from repro.shuffle.sort import sort_shuffle_job

mesh = make_mesh((8,), ("w",))
plan = ExternalSortPlan(
    records_per_wave=1 << 13,
    num_rounds=2,
    reducers_per_worker=2,
    payload_words=2,
    impl="ref",
    input_records_per_partition=1 << 12,
    output_part_records=1 << 11,
    store_chunk_bytes=16 << 10,
    parallel_reducers=2,
    reduce_memory_budget_bytes=64 << 10,
)
N = 1 << 15
store = ObjectStore(tempfile.mkdtemp(prefix="shuffle-sort-test-"))
store.create_bucket("sort")

def layout():
    return [(m.key, m.etag, m.size, m.parts)
            for m in store.list_objects("sort", plan.output_prefix)]

def job():
    return sort_shuffle_job(store, "sort", mesh=mesh, axis_names="w",
                            plan=plan)
"""


def test_shuffle_job_sort_identical_to_deprecated_shims():
    # The acceptance gate: CloudSort through ShuffleJob.run must be
    # byte- and etag-identical to the deprecated external_sort() driver
    # at W in {1, 4} and under a worker kill — and still valsort-clean.
    run_with_devices(SORT_SETUP + """
import warnings
in_ck, nparts = gensort.write_to_store(
    store, "sort", plan.input_prefix, N,
    plan.input_records_per_partition, plan.payload_words)

with warnings.catch_warnings(record=True) as caught:
    warnings.simplefilter("always")
    rep0 = external_sort(store, "sort", mesh=mesh, axis_names="w", plan=plan)
assert any(issubclass(w.category, DeprecationWarning) for w in caught), (
    "the shim must announce its deprecation")
want = layout()
assert len(want) == 16

rep = job().run(workers=0)
assert layout() == want, "ShuffleJob single-host changed output bytes"
assert rep.total_records == N and rep.num_map_tasks == 4
assert rep.num_partitions == 16

for W in (1, 4):
    crep = job().run(workers=W)
    assert layout() == want, f"ShuffleJob W={W} changed output bytes"
    assert crep.num_cluster_workers == W and not crep.failed_workers

crep = job().run(cluster=ClusterPlan(num_workers=4,
                                     fail_after_tasks={1: 2}))
assert layout() == want, "ShuffleJob worker kill changed output bytes"
assert crep.failed_workers == ["w1"] and crep.reexecuted_tasks >= 1

val = valsort.validate_from_store(store, "sort", plan.output_prefix, in_ck)
assert val.ok and val.total_records == N, val
print("OK")
""", timeout=900)


def test_skewed_keys_sort_byte_identical_across_schedules():
    # Satellite gate: a skewed (non-uniform) key distribution — most
    # keys crammed into a narrow low band, plus heavy duplicates — must
    # produce byte-identical sorted output at every parallelism and
    # worker count, even though partition sizes are wildly unbalanced.
    run_with_devices(SORT_SETUP + """
from repro.io import records as rec

# Equal key ranges + heavy skew means one mesh worker absorbs most of
# every wave: capacity_factor is exactly the knob that buys that slack
# (the Daytona-style alternative is sampled boundaries — see
# shuffle/partition.RangePartitioner(boundaries=...)).
plan = dataclasses.replace(plan, capacity_factor=8.0)

def job():
    return sort_shuffle_job(store, "sort", mesh=mesh, axis_names="w",
                            plan=plan)

rpp = plan.input_records_per_partition
ids = np.arange(N, dtype=np.uint32)
u = np.asarray(gensort.splitmix32(ids))
# 7/8 of keys land in [0, 2^24); the rest spread uniformly; every 5th
# key is a duplicate of a fixed hot key (ties broken by id).
keys = np.where(u % 8 < 7, u >> np.uint32(8), u).astype(np.uint32)
keys[::5] = 12345
in_ck = (0, 0)
for p in range(N // rpp):
    sl = slice(p * rpp, (p + 1) * rpp)
    payload = np.asarray(gensort.gen_payload(ids[sl], plan.payload_words))
    ck = gensort.checksum(keys[sl], ids[sl], payload)
    in_ck = gensort.combine_checksums(in_ck, (int(ck[0]), int(ck[1])))
    store.put("sort", f"{plan.input_prefix}part-{p:05d}",
              rec.encode_records(keys[sl], ids[sl], payload),
              metadata={"records": rpp})

rep0 = job().run(workers=0)
want = layout()
val = valsort.validate_from_store(store, "sort", plan.output_prefix, in_ck)
assert val.ok and val.total_records == N, val
# skew is real: partition sizes differ by >= 8x
sizes = [m.size for m in store.list_objects("sort", plan.output_prefix)]
assert max(sizes) >= 8 * min(sizes), sizes

for par in (1, 4):
    p2 = dataclasses.replace(plan, parallel_reducers=par,
                             capacity_factor=8.0)
    sort_shuffle_job(store, "sort", mesh=mesh, axis_names="w",
                     plan=p2).run(workers=0)
    assert layout() == want, f"parallel_reducers={par} changed skewed bytes"
for W in (1, 2):
    job().run(workers=W)
    assert layout() == want, f"W={W} changed skewed bytes"
val = valsort.validate_from_store(store, "sort", plan.output_prefix, in_ck)
assert val.ok, val
print("OK", max(sizes), min(sizes))
""", timeout=900)
