"""The shuffle library: partitioner contracts, plan validation, the
ShuffleJob sort path, and the group-by workload.

The ISSUE-5 acceptance contract: CloudSort through the new ShuffleJob
API must be byte- and etag-identical to the pre-refactor drivers at
W in {1, 4} and under a worker kill (the deprecated shims' own tests in
test_external_sort.py / test_cluster.py pin the shim side); any
Partitioner implementation must yield exhaustive, non-overlapping
ranges; skewed key distributions must still sort byte-identically at
any schedule; and the group-by workload must run end-to-end on the
throttled+latency tiered store with no sort-specific code in its
operators.
"""
import numpy as np
import pytest

from helpers import run_with_devices


# ---------------------------------------------------------------------------
# Partitioner properties (pure numpy — no devices needed)
# ---------------------------------------------------------------------------


def _all_partitioners():
    from repro.shuffle.partition import HashPartitioner, RangePartitioner
    from repro.shuffle.recursive import KeyRoute, SubrangePartitioner

    parts = []
    for p in (1, 2, 3, 7, 16, 1000):
        parts.append(RangePartitioner(p))
        parts.append(HashPartitioner(p))
    # Sampled (explicit, deliberately lopsided) boundaries, duplicates
    # included — degenerate empty ranges are legal, overlap is not.
    parts.append(RangePartitioner(
        5, boundaries=np.array([10, 10, 1 << 20, 1 << 31], np.uint32)))
    # Recursive sub-range partitioners: a wide parent range (routing by
    # the next key bits) and a single-duplicated-key range (routing by
    # the record id — the only split a key boundary can't make).
    wide = KeyRoute(lo64=1000 << 32, hi64=(1 << 24) << 32)
    parts.append(SubrangePartitioner(4, wide, wide.equal_bounds(4)))
    one_key = KeyRoute(lo64=77 << 32, hi64=78 << 32)
    parts.append(SubrangePartitioner(
        3, one_key, np.array([100, 5000], np.uint32)))
    return parts


def _probe_keys(rng):
    """Adversarial key sample: dense sweep + uniform draw + boundary
    neighbourhoods get appended per-partitioner by the caller."""
    dense = np.linspace(0, (1 << 32) - 1, 4096).astype(np.uint32)
    uniform = rng.integers(0, 1 << 32, size=4096, dtype=np.uint64)
    edges = np.array([0, 1, (1 << 32) - 1], np.uint64)
    return np.concatenate([dense.astype(np.uint64), uniform, edges])


def test_partitioner_ranges_exhaustive_and_non_overlapping():
    # The property, for EVERY implementation: boundaries are ascending
    # (non-overlap), every routed key lands in exactly one partition id
    # within range (exhaustive), and partition_of agrees with the
    # boundary definition b[j-1] <= route(k) < b[j] on keys sitting
    # directly on and around every boundary.
    rng = np.random.default_rng(7)
    for part in _all_partitioners():
        bounds = np.asarray(part.boundaries(), np.uint64)
        assert bounds.shape == (part.num_partitions - 1,), part
        assert bool(np.all(bounds[1:] >= bounds[:-1])), (part, bounds)

        keys = _probe_keys(rng)
        if bounds.size:  # boundary neighbourhoods, clipped to u32
            near = np.concatenate([bounds - 1, bounds, bounds + 1])
            keys = np.concatenate([keys, near & 0xFFFFFFFF])
        keys = keys.astype(np.uint32)
        got = part.partition_of(keys)
        assert got.min() >= 0 and got.max() < part.num_partitions, part
        # exactly the searchsorted contract over the routed domain
        want = np.searchsorted(bounds.astype(np.uint32),
                               part.route(keys), side="right")
        assert np.array_equal(got, want), part
        # monotone in the routed domain (ranges, not interleaving)
        routed = part.route(keys)
        order = np.argsort(routed, kind="stable")
        assert bool(np.all(np.diff(got[order]) >= 0)), part


def test_equal_range_partitioner_covers_every_partition():
    from repro.shuffle.partition import RangePartitioner

    # Equal split: a dense sweep must populate every partition (no empty
    # range can hide in an equal split of a dense domain).
    for p in (2, 3, 16, 255):
        part = RangePartitioner(p)
        keys = np.linspace(0, (1 << 32) - 1, 64 * p).astype(np.uint32)
        assert len(np.unique(part.partition_of(keys))) == p


def test_range_partitioner_matches_device_keyspace():
    # The host-side RangePartitioner and the device-side KeySpace must
    # route identically, or map (device) and reduce (host) would
    # disagree about partition ownership.
    from repro.core.keyspace import KeySpace
    from repro.shuffle.partition import RangePartitioner

    for r, w in ((16, 8), (24, 8), (625, 5)):
        ks = KeySpace(num_reducers=r, num_workers=w)
        part = RangePartitioner(r)
        assert np.array_equal(np.asarray(ks.reducer_boundaries()),
                              part.boundaries()), (r, w)
        rng = np.random.default_rng(r)
        keys = rng.integers(0, 1 << 32, size=2048, dtype=np.uint64)
        keys = keys.astype(np.uint32)
        assert np.array_equal(np.asarray(ks.reducer_of_key(keys)),
                              part.partition_of(keys)), (r, w)


def test_sampled_boundaries_host_and_device_bit_identical():
    # The Daytona-style splitter estimation exists twice — host-side
    # (shuffle/partition.quantile_boundaries feeds RangePartitioner) and
    # device-side (core/keyspace.sampled_boundaries feeds the shuffle
    # kernel) — and they MUST agree bit-for-bit, or the map (device) and
    # reduce (host) halves route by different splitters. Pinned on
    # adversarial samples: all-equal, one key, tiny samples, heavy
    # duplicates, parts > sample size.
    import jax.numpy as jnp

    from repro.core import keyspace
    from repro.shuffle.partition import quantile_boundaries

    rng = np.random.default_rng(11)
    samples = [
        np.array([42], np.uint32),  # one key is legal
        np.full(1000, 7, np.uint32),  # all-equal: every splitter collapses
        np.array([3, 1, 2], np.uint32),  # tiny, unsorted
        rng.integers(0, 1 << 32, size=4097, dtype=np.uint64).astype(np.uint32),
        np.repeat(rng.integers(0, 100, size=64, dtype=np.uint64)
                  .astype(np.uint32), 33),  # duplicate-heavy
    ]
    for sample in samples:
        for parts in (1, 2, 3, 16, 255):
            host = quantile_boundaries(sample, parts)
            dev = np.asarray(
                keyspace.sampled_boundaries(jnp.asarray(sample), parts))
            assert host.dtype == np.uint32 and host.shape == (parts - 1,)
            assert np.array_equal(host, dev), (sample[:8], parts)
            # quantile splitters are ascending by construction
            assert bool(np.all(host[1:] >= host[:-1]))

    # both reject the degenerate inputs, naming the offending knob
    with pytest.raises(ValueError, match="sample"):
        quantile_boundaries(np.empty(0, np.uint32), 4)
    with pytest.raises(ValueError, match="sample_keys"):
        keyspace.sampled_boundaries(jnp.zeros((0,), jnp.uint32), 4)
    with pytest.raises(ValueError, match="parts=0"):
        quantile_boundaries(np.array([1], np.uint32), 0)
    with pytest.raises(ValueError, match="parts=0"):
        keyspace.sampled_boundaries(jnp.array([1], jnp.uint32), 0)


def test_partition_kernel_matches_searchsorted_oracle_bit_for_bit():
    # The device kernel's routing contract (offsets[j] = #{k < b_j},
    # searchsorted side="left") against the numpy oracle, on adversarial
    # boundaries: duplicates, zeros, boundary-equal keys, all-equal
    # rows, the extremes of the key space. Bit-for-bit — an off-by-one
    # here silently misroutes a slice boundary's records.
    import jax.numpy as jnp

    from repro.kernels.range_partition import (partition_offsets_blocks,
                                               searchsorted_reference)
    from repro.shuffle.partition import RangePartitioner

    rng = np.random.default_rng(23)
    B = 256
    rows = [
        np.sort(rng.integers(0, 1 << 32, size=B, dtype=np.uint64)
                .astype(np.uint32)),
        np.full(B, 12345, np.uint32),  # all-equal row
        np.zeros(B, np.uint32),
        np.full(B, 0xFFFFFFFF, np.uint32),
        np.sort(np.repeat(rng.integers(0, 1 << 10, size=B // 8,
                                       dtype=np.uint64), 8)
                .astype(np.uint32)),  # duplicate-heavy low band
    ]
    sorted_keys = np.stack(rows)
    bounds_cases = [
        np.array([0, 12345, 12345, 1 << 20, 0xFFFFFFFF], np.uint32),
        np.sort(rng.integers(0, 1 << 32, size=16, dtype=np.uint64)
                .astype(np.uint32)),
        np.zeros(3, np.uint32),
        # sampled quantiles of the probe rows themselves: boundary
        # values that EQUAL keys, the side="left"/"right" razor's edge
        np.sort(sorted_keys.reshape(-1))[:: sorted_keys.size // 8][1:8],
    ]
    for bounds in bounds_cases:
        got = np.asarray(partition_offsets_blocks(
            jnp.asarray(sorted_keys), jnp.asarray(bounds), interpret=True))
        want = searchsorted_reference(sorted_keys, bounds)
        assert got.dtype == want.dtype and np.array_equal(got, want), bounds

        # and the kernel's slices agree with the HOST membership rule:
        # slice j of a sorted row holds exactly the keys RangePartitioner
        # (searchsorted side="right") routes to partition j.
        part = RangePartitioner(len(bounds) + 1, boundaries=bounds)
        for i, row in enumerate(sorted_keys):
            slice_sizes = np.diff(
                np.concatenate(([0], got[i], [len(row)])))
            member_counts = np.bincount(part.partition_of(row),
                                        minlength=len(bounds) + 1)
            assert np.array_equal(slice_sizes, member_counts), (i, bounds)


def test_keyspace_explicit_boundaries_route_like_partitioner():
    # KeySpace(boundaries=...) must route by the sampled splitters, not
    # the equal-split shift fast path — including power-of-two R and W,
    # where the fast path would otherwise silently ignore them.
    from repro.core.keyspace import KeySpace
    from repro.shuffle.partition import RangePartitioner

    rng = np.random.default_rng(5)
    for r, w in ((16, 8), (24, 8), (8, 2)):
        bounds = np.sort(rng.integers(0, 1 << 28, size=r - 1,
                                      dtype=np.uint64).astype(np.uint32))
        ks = KeySpace(num_reducers=r, num_workers=w,
                      boundaries=tuple(int(b) for b in bounds))
        part = RangePartitioner(r, boundaries=bounds)
        assert np.array_equal(np.asarray(ks.reducer_boundaries()), bounds)
        # worker boundaries are every R1-th reducer boundary
        r1 = r // w
        assert np.array_equal(np.asarray(ks.worker_boundaries()),
                              bounds[r1 - 1::r1])
        keys = rng.integers(0, 1 << 32, size=4096,
                            dtype=np.uint64).astype(np.uint32)
        keys[:r - 1] = bounds  # boundary-equal keys included
        assert np.array_equal(np.asarray(ks.reducer_of_key(keys)),
                              part.partition_of(keys)), (r, w)
        assert np.array_equal(np.asarray(ks.worker_of_key(keys)),
                              part.partition_of(keys) // r1), (r, w)

    with pytest.raises(ValueError, match="boundaries"):
        KeySpace(num_reducers=4, num_workers=2, boundaries=(1, 2))
    with pytest.raises(ValueError, match="ascending"):
        KeySpace(num_reducers=4, num_workers=2, boundaries=(9, 4, 10))


def test_subrange_route_splits_what_no_key_boundary_can():
    # The recursion's "next key bits" routing: order-preserving over the
    # parent sub-range's packed (key<<32|id) domain, tiling preimages,
    # and — for a single duplicated key — a pure id split.
    from repro.shuffle.recursive import KeyRoute, SubrangePartitioner

    # Single-key parent range: span = 2^32, shift = 0, routed == id.
    one = KeyRoute(lo64=77 << 32, hi64=78 << 32)
    assert one.shift == 0 and one.routed_span == 1 << 32
    ids = np.array([0, 99, 100, 5000, 1 << 20], np.uint32)
    keys = np.full(ids.shape, 77, np.uint32)
    assert np.array_equal(one.routed(keys, ids), ids)
    sub = SubrangePartitioner(3, one, np.array([100, 5000], np.uint32))
    assert np.array_equal(sub.partition_of64(keys, ids),
                          [0, 0, 1, 2, 2])  # identical keys, split by id

    # Wide parent range: shift > 0, routing is monotone in k64 and the
    # sub-range preimages tile [lo64, hi64) exactly.
    rng = np.random.default_rng(13)
    wide = KeyRoute(lo64=1000 << 32, hi64=(1 << 24) << 32)
    assert wide.shift > 0
    keys = np.sort(rng.integers(1000, 1 << 24, size=2048,
                                dtype=np.uint64)).astype(np.uint32)
    ids = rng.integers(0, 1 << 16, size=2048, dtype=np.uint64).astype(np.uint32)
    k64 = keys.astype(np.uint64) << np.uint64(32) | ids
    order = np.argsort(k64, kind="stable")
    routed = wide.routed(keys[order], ids[order])
    assert bool(np.all(routed[1:] >= routed[:-1])), "routing must be monotone"
    bounds = wide.equal_bounds(5)
    assert bounds.shape == (4,) and bool(np.all(bounds[1:] >= bounds[:-1]))
    lo = wide.lo64
    for j in range(5):
        slo, shi = wide.sub_range64(bounds, j)
        assert slo == lo, f"sub-range {j} must start where {j-1} ended"
        lo = shi
    assert lo == wide.hi64, "sub-ranges must tile the parent range"


def test_partitioner_validation_errors_name_knob_and_value():
    from repro.shuffle.partition import HashPartitioner, RangePartitioner

    with pytest.raises(ValueError, match="num_partitions=0"):
        RangePartitioner(0)
    with pytest.raises(ValueError, match="num_partitions=-3"):
        HashPartitioner(-3)
    with pytest.raises(ValueError, match="boundaries"):
        RangePartitioner(3, boundaries=np.array([5], np.uint32))
    with pytest.raises(ValueError, match="ascending"):
        RangePartitioner(3, boundaries=np.array([9, 4], np.uint32))


# ---------------------------------------------------------------------------
# Unified plan validation: ValueError with knob name + value everywhere
# ---------------------------------------------------------------------------


def test_shuffle_plan_validation_names_knob_and_value():
    import dataclasses

    from repro.shuffle.api import ShufflePlan

    ShufflePlan().validate()  # defaults are feasible
    bad = {
        "parallel_reducers": 0,
        "part_upload_fanout": 0,
        "prefetch_depth": 0,
        "max_inflight_writes": 0,
        "io_retries": -1,
        "output_part_records": 0,
        "store_chunk_bytes": 0,
        "merge_chunk_bytes": 3,  # < one record
        "reduce_memory_budget_bytes": -1,
        "input_prefix": "",
    }
    for knob, value in bad.items():
        plan = dataclasses.replace(ShufflePlan(), **{knob: value})
        with pytest.raises(ValueError, match=f"{knob}={value!r}"):
            plan.validate()
    # spill/output prefix collision is a layout error, not a typo
    with pytest.raises(ValueError, match="spill_prefix"):
        dataclasses.replace(ShufflePlan(), spill_prefix="out/",
                            output_prefix="out/").validate()
    # and ANY overlap with input_prefix must fail validation: session
    # preflight deletes spill/output prefixes, so an overlap would
    # destroy the input before the map phase runs
    for knob in ("spill_prefix", "output_prefix"):
        for value in ("input/", "in", "input/sub/"):
            with pytest.raises(ValueError, match="overlaps"):
                dataclasses.replace(
                    ShufflePlan(), **{knob: value}).validate()


def test_overlapping_prefixes_rejected_before_any_delete():
    # The destructive case end-to-end: a spill prefix shadowing the
    # input prefix must fail in preflight with the input intact.
    from repro.io.backends import MemoryBackend
    from repro.shuffle.api import ShufflePlan
    from repro.shuffle.groupby import groupby_job, write_groupby_input

    store = MemoryBackend()
    store.create_bucket("b")
    plan = ShufflePlan(payload_words=1, spill_prefix="input/")
    write_groupby_input(store, "b", "input/", 1 << 10, 1 << 9,
                        num_groups=16)
    with pytest.raises(ValueError, match="spill_prefix='input/'"):
        groupby_job(store, "b", plan=plan, num_partitions=4).run()
    assert len(store.list_objects("b", "input/")) == 2, (
        "preflight must not have deleted the input")


def test_external_sort_and_cluster_plan_validation():
    import dataclasses

    from repro.core.cluster import ClusterPlan
    from repro.core.external_sort import ExternalSortPlan

    ExternalSortPlan(records_per_wave=1 << 12).validate()
    for knob, value in (("records_per_wave", 0), ("num_rounds", 0),
                        ("reducers_per_worker", 0),
                        ("capacity_factor", 0.0),
                        ("parallel_reducers", 0),
                        ("sample_fraction", -0.1),
                        ("sample_fraction", 1.5),
                        ("max_rounds", 0)):
        plan = dataclasses.replace(
            ExternalSortPlan(records_per_wave=1 << 12), **{knob: value})
        with pytest.raises(ValueError, match=f"{knob}="):
            plan.validate()
    # recursion needs a budget to define "oversized": max_rounds > 1
    # with an uncapped reduce budget is a contradiction, not a default
    with pytest.raises(ValueError, match="max_rounds=2"):
        dataclasses.replace(ExternalSortPlan(records_per_wave=1 << 12),
                            max_rounds=2,
                            reduce_memory_budget_bytes=0).validate()

    with pytest.raises(ValueError, match="num_workers=0"):
        ClusterPlan(num_workers=0)
    with pytest.raises(ValueError, match="fail_after_tasks"):
        ClusterPlan(num_workers=2, fail_after_tasks={5: 1})
    with pytest.raises(ValueError, match="fail_after_requests"):
        ClusterPlan(num_workers=2, fail_after_requests={0: -1})


def test_budget_feasibility_raises_before_any_request():
    # An infeasible budget must fail in session preflight — before any
    # input byte is fetched (and billed).
    from repro.io.backends import MemoryBackend
    from repro.io.middleware import MetricsMiddleware
    from repro.shuffle.api import ShufflePlan
    from repro.shuffle.groupby import groupby_job, write_groupby_input

    store = MetricsMiddleware(MemoryBackend())
    store.create_bucket("b")
    plan = ShufflePlan(payload_words=1, merge_chunk_bytes=1 << 10,
                       parallel_reducers=4,
                       reduce_memory_budget_bytes=64)  # < 1 record/run
    write_groupby_input(store, "b", plan.input_prefix, 1 << 10, 1 << 9,
                        num_groups=32)
    base = store.stats_snapshot()
    with pytest.raises(ValueError, match="reduce_memory_budget_bytes=64"):
        groupby_job(store, "b", plan=plan, num_partitions=4).run()
    delta = store.stats_snapshot() - base
    assert delta.get_requests == 0 and delta.put_requests == 0


# ---------------------------------------------------------------------------
# Group-by: the second workload, end-to-end on the faulty tiered store
# ---------------------------------------------------------------------------


def test_groupby_end_to_end_on_throttled_tiered_store():
    # The generality acceptance gate: keyed aggregation with a map-side
    # combiner on the same latency+throttle+retry tiered stack the sort
    # uses, reusing staging / budget governor / fault recovery — and
    # byte-identical output across combiner on/off, worker counts, and
    # an injected worker death.
    import tempfile

    from repro.io.middleware import FaultProfile, RetryPolicy
    from repro.io.tiered import tiered_cloudsort_store
    from repro.shuffle.api import ShufflePlan
    from repro.shuffle.executor import ClusterPlan
    from repro.shuffle.groupby import (groupby_job, validate_groupby_from_store,
                                       write_groupby_input)

    plan = ShufflePlan(payload_words=1, store_chunk_bytes=8 << 10,
                       merge_chunk_bytes=2 << 10, output_part_records=1 << 9,
                       parallel_reducers=3,
                       reduce_memory_budget_bytes=64 << 10)
    store = tiered_cloudsort_store(
        tempfile.mkdtemp(prefix="groupby-faulty-"),
        spill_prefixes=(plan.spill_prefix,),
        faults=FaultProfile(latency_s=0.001, bandwidth_bps=400e6,
                            get_rate=80.0, put_rate=50.0, burst=8.0),
        retry=RetryPolicy(max_attempts=12, base_delay_s=0.01,
                          max_delay_s=0.25),
    )
    store.create_bucket("agg")
    N = 1 << 14
    expected_counts, expected_sums = write_groupby_input(
        store, "agg", plan.input_prefix, N, 1 << 11,
        num_groups=700, skew=2.5)  # word-frequency-shaped skew

    job = groupby_job(store, "agg", plan=plan, num_partitions=8)
    rep = job.run()
    assert rep.total_records == N and rep.num_map_tasks == 8
    assert rep.num_partitions == 8 and rep.output_objects == 8
    # the library machinery really engaged: budget held, spans recorded
    assert 0 < rep.reduce_peak_merge_bytes <= plan.reduce_memory_budget_bytes
    assert rep.phase_seconds.get("map.compute", 0) > 0
    assert rep.phase_seconds.get("reduce.merge", 0) > 0
    # faults were really injected and absorbed
    assert rep.stats.retries > 0 and rep.stats.throttled > 0
    # spill traffic routed to the (free) ssd tier
    assert rep.tier_stats["ssd"].put_requests == rep.spill_objects
    assert rep.tier_stats["durable"].bytes_written > 0

    val = validate_groupby_from_store(
        store, "agg", plan.output_prefix, job.partitioner,
        expected_counts, expected_sums)
    assert val.ok and val.total_groups == 700, val

    def layout():
        return [(m.key, m.etag, m.size, m.parts)
                for m in store.list_objects("agg", plan.output_prefix)]

    want = layout()

    # combiner off: more spilled bytes, identical output bytes
    rep_raw = groupby_job(store, "agg", plan=plan, num_partitions=8,
                          combine=False).run()
    assert layout() == want, "combiner changed output bytes"
    assert rep_raw.tier_stats["ssd"].bytes_written > \
        rep.tier_stats["ssd"].bytes_written, "combiner did not shrink spill"

    # cluster mode with one injected death: recovered, byte-identical
    crep = groupby_job(store, "agg", plan=plan, num_partitions=8).run(
        cluster=ClusterPlan(num_workers=4, fail_after_tasks={1: 2}))
    assert layout() == want, "worker failure changed output bytes"
    assert crep.failed_workers == ["w1"] and crep.reexecuted_tasks >= 1
    val = validate_groupby_from_store(
        store, "agg", plan.output_prefix, job.partitioner,
        expected_counts, expected_sums)
    assert val.ok, val


def test_groupby_deferred_header_and_carry_at_tiny_chunks():
    # merge_chunk_bytes at the one-record floor forces maximal emit
    # cycles (every group straddles windows -> the carry path), and a
    # partition count above the group count forces empty partitions
    # (header-only part-0 objects).
    from repro.io.backends import MemoryBackend
    from repro.shuffle.api import ShufflePlan
    from repro.shuffle.groupby import (groupby_job, validate_groupby_from_store,
                                       write_groupby_input)

    plan = ShufflePlan(payload_words=1, merge_chunk_bytes=12,  # one record
                       output_part_records=4, parallel_reducers=2)
    store = MemoryBackend()
    store.create_bucket("b")
    expected_counts, expected_sums = write_groupby_input(
        store, "b", plan.input_prefix, 1 << 10, 1 << 8, num_groups=5,
        skew=3.0)
    job = groupby_job(store, "b", plan=plan, num_partitions=16)
    job.run()
    val = validate_groupby_from_store(
        store, "b", plan.output_prefix, job.partitioner,
        expected_counts, expected_sums)
    assert val.ok and val.total_groups == 5, val
    metas = store.list_objects("b", plan.output_prefix)
    assert len(metas) == 16
    assert any(m.size == 16 for m in metas), "expected empty partitions"


# ---------------------------------------------------------------------------
# The sort through the ShuffleJob API (subprocess: needs 8 host devices)
# ---------------------------------------------------------------------------

SORT_SETUP = """
import dataclasses
import tempfile
import numpy as np
import jax
from repro.core.external_sort import ExternalSortPlan, external_sort
from repro.core.compat import make_mesh
from repro.data import gensort, valsort
from repro.io.object_store import ObjectStore
from repro.shuffle.executor import ClusterPlan
from repro.shuffle.sort import sort_shuffle_job

mesh = make_mesh((8,), ("w",))
plan = ExternalSortPlan(
    records_per_wave=1 << 13,
    num_rounds=2,
    reducers_per_worker=2,
    payload_words=2,
    impl="ref",
    input_records_per_partition=1 << 12,
    output_part_records=1 << 11,
    store_chunk_bytes=16 << 10,
    parallel_reducers=2,
    reduce_memory_budget_bytes=64 << 10,
)
N = 1 << 15
store = ObjectStore(tempfile.mkdtemp(prefix="shuffle-sort-test-"))
store.create_bucket("sort")

def layout():
    return [(m.key, m.etag, m.size, m.parts)
            for m in store.list_objects("sort", plan.output_prefix)]

def job():
    return sort_shuffle_job(store, "sort", mesh=mesh, axis_names="w",
                            plan=plan)
"""


def test_shuffle_job_sort_identical_to_deprecated_shims():
    # The acceptance gate: CloudSort through ShuffleJob.run must be
    # byte- and etag-identical to the deprecated external_sort() driver
    # at W in {1, 4} and under a worker kill — and still valsort-clean.
    run_with_devices(SORT_SETUP + """
import warnings
in_ck, nparts = gensort.write_to_store(
    store, "sort", plan.input_prefix, N,
    plan.input_records_per_partition, plan.payload_words)

with warnings.catch_warnings(record=True) as caught:
    warnings.simplefilter("always")
    rep0 = external_sort(store, "sort", mesh=mesh, axis_names="w", plan=plan)
assert any(issubclass(w.category, DeprecationWarning) for w in caught), (
    "the shim must announce its deprecation")
want = layout()
assert len(want) == 16

rep = job().run(workers=0)
assert layout() == want, "ShuffleJob single-host changed output bytes"
assert rep.total_records == N and rep.num_map_tasks == 4
assert rep.num_partitions == 16

for W in (1, 4):
    crep = job().run(workers=W)
    assert layout() == want, f"ShuffleJob W={W} changed output bytes"
    assert crep.num_cluster_workers == W and not crep.failed_workers

crep = job().run(cluster=ClusterPlan(num_workers=4,
                                     fail_after_tasks={1: 2}))
assert layout() == want, "ShuffleJob worker kill changed output bytes"
assert crep.failed_workers == ["w1"] and crep.reexecuted_tasks >= 1

val = valsort.validate_from_store(store, "sort", plan.output_prefix, in_ck)
assert val.ok and val.total_records == N, val
print("OK")
""", timeout=900)


def test_skewed_keys_sort_with_sampled_boundaries_at_default_capacity():
    # Satellite gate: skew is handled by MEASURING the distribution, not
    # by buying headroom. The equal Indy split on a hot-band + duplicate
    # distribution overflows the all-to-all capacity at the DEFAULT
    # capacity_factor; the sampling pre-pass feeds quantile splitters
    # end-to-end (device keyspace + host partitioner) and the same plan
    # then sorts clean — byte-identical at every parallelism and worker
    # count.
    run_with_devices(SORT_SETUP + """
from repro.io import records as rec
from repro.shuffle.job import sample_boundaries

rpp = plan.input_records_per_partition
ids = np.arange(N, dtype=np.uint32)
u = np.asarray(gensort.splitmix32(ids))
# 7/8 of keys land in [0, 2^24); the rest spread uniformly; every 16th
# key is a duplicate of a fixed hot key (ties broken by id).
keys = np.where(u % 8 < 7, u >> np.uint32(8), u).astype(np.uint32)
keys[::16] = 12345
in_ck = (0, 0)
for p in range(N // rpp):
    sl = slice(p * rpp, (p + 1) * rpp)
    payload = np.asarray(gensort.gen_payload(ids[sl], plan.payload_words))
    ck = gensort.checksum(keys[sl], ids[sl], payload)
    in_ck = gensort.combine_checksums(in_ck, (int(ck[0]), int(ck[1])))
    store.put("sort", f"{plan.input_prefix}part-{p:05d}",
              rec.encode_records(keys[sl], ids[sl], payload),
              metadata={"records": rpp})

# Equal split: ~7/8 of every wave converges on one mesh worker — the
# shuffle block overflows at the default capacity_factor.
try:
    job().run(workers=0)
    raise AssertionError("equal split must overflow on this distribution")
except RuntimeError as e:
    assert "shuffle block overflow" in str(e), e

samp = sample_boundaries(store, "sort", input_prefix=plan.input_prefix,
                         payload_words=plan.payload_words,
                         sample_fraction=1 / 16, parts=16)
assert samp.get_requests > 0 and samp.records_total == N

def sampled_job(p=None):
    return sort_shuffle_job(store, "sort", mesh=mesh, axis_names="w",
                            plan=p or plan, boundaries=samp.boundaries)

rep0 = sampled_job().run(workers=0)
want = layout()
val = valsort.validate_from_store(store, "sort", plan.output_prefix, in_ck)
assert val.ok and val.total_records == N, val
# the duplicate key still skews OUTPUT partition sizes (quantiles can't
# split equal keys) — but no longer the per-worker wave capacity
sizes = [m.size for m in store.list_objects("sort", plan.output_prefix)]
assert max(sizes) >= 8 * min(sizes), sizes

for par in (1, 4):
    p2 = dataclasses.replace(plan, parallel_reducers=par)
    sampled_job(p2).run(workers=0)
    assert layout() == want, f"parallel_reducers={par} changed skewed bytes"
for W in (1, 2):
    sampled_job().run(workers=W)
    assert layout() == want, f"W={W} changed skewed bytes"
val = valsort.validate_from_store(store, "sort", plan.output_prefix, in_ck)
assert val.ok, val
print("OK", max(sizes), min(sizes))
""", timeout=900)


def test_recursive_sort_dup_heavy_end_to_end():
    # The ISSUE-9 acceptance gate: a duplicate-heavy gensort input whose
    # hottest partition would exceed reduce_memory_budget_bytes under
    # any single-round split sorts valsort-clean through sampled
    # boundaries + recursive rounds — byte-identical at W in {1, 4} and
    # under a mid-round worker kill, with the sampling pre-pass visible
    # as its own traced/billed phase and >= 2 recursive rounds actually
    # executed.
    run_with_devices("""
import dataclasses
import tempfile
import numpy as np
from repro.core.external_sort import ExternalSortPlan
from repro.core.compat import make_mesh
from repro.data import gensort, valsort
from repro.io.object_store import ObjectStore
from repro.obs.events import Tracer
from repro.shuffle.executor import ClusterPlan
from repro.shuffle.recursive import recurse_prefix, recursive_sort

mesh = make_mesh((8,), ("w",))
# capacity_factor buys MAP-side all-to-all slack for the 25% duplicate
# mass (no boundary choice can move equal keys apart in one round —
# that is the point of this fixture); the REDUCE-side ceiling is what
# the recursion removes.
plan = ExternalSortPlan(
    records_per_wave=1 << 13,
    num_rounds=2,
    reducers_per_worker=2,
    payload_words=2,
    impl="ref",
    input_records_per_partition=1 << 12,
    output_part_records=1 << 11,
    store_chunk_bytes=16 << 10,
    parallel_reducers=2,
    reduce_memory_budget_bytes=64 << 10,
    capacity_factor=4.0,
    sample_fraction=1 / 16,
    max_rounds=3,
)
N = 1 << 15
store = ObjectStore(tempfile.mkdtemp(prefix="recursive-sort-test-"))
store.create_bucket("sort")
# "dup" skew: every 4th record shares ONE key -> the hot partition holds
# >= N/4 records = 128 KiB, twice the 64 KiB reduce budget. A
# single-round sort cannot keep that partition's merge under budget.
in_ck, _ = gensort.write_to_store(
    store, "sort", plan.input_prefix, N,
    plan.input_records_per_partition, plan.payload_words,
    skew="dup", skew_seed=3)
assert (N // 4) * plan.record_bytes > plan.reduce_memory_budget_bytes

tracer = Tracer(job="recursive")
rep = recursive_sort(store, "sort", mesh=mesh, axis_names="w", plan=plan,
                     tracer=tracer)

# >= 2 recursive rounds really ran (the id-split of the duplicated key)
child_rounds = [(d, p) for d, p, _ in rep.rounds if d >= 2]
assert len(child_rounds) >= 2, rep.rounds
assert rep.num_rounds >= 3, rep.rounds
assert rep.recursed, "the hot partition must have been redirected"

# the sampling pre-pass is its own traced/billed phase
assert rep.sample is not None and rep.sample.get_requests > 0
evs = tracer.log.events()
sample_evs = [e for e in evs if e["phase"] == "sample"]
assert any(e["name"] == "sample.fetch" for e in sample_evs)
assert any(e["name"] == "sample.boundaries" for e in sample_evs)
rounds_evs = [e for e in evs if e["name"] == "recursive.round"]
assert len(rounds_evs) == len(rep.rounds)
assert any(e["name"] == "recursive.redirect" for e in evs)
gauges = tracer.registry.snapshot()["gauges"]
assert "phase.seconds{phase=sample}" in gauges

val = valsort.validate_from_store(store, "sort", plan.output_prefix, in_ck)
assert val.ok and val.total_records == N, val

def layout():
    return [(m.key, m.etag, m.size, m.parts)
            for m in store.list_objects("sort", plan.output_prefix)]
want = layout()
# recursion staged nothing permanent: the .rounds/ prefix is gone
assert not store.list_objects("sort", recurse_prefix(plan))
# recursed partitions exist only as their sub-objects, in list order
assert any("/sub-" in k for k, _, _, _ in want), want

for W in (1, 4):
    recursive_sort(store, "sort", mesh=mesh, axis_names="w", plan=plan,
                   workers=W)
    assert layout() == want, f"W={W} changed recursive output bytes"

# mid-round worker kill (every round's fleet loses w1 after 2 tasks)
crep = recursive_sort(store, "sort", mesh=mesh, axis_names="w", plan=plan,
                      cluster=ClusterPlan(num_workers=4,
                                          fail_after_tasks={1: 2}))
assert layout() == want, "worker kill changed recursive output bytes"
assert any(getattr(r, "failed_workers", []) for _, _, r in crep.rounds)
val = valsort.validate_from_store(store, "sort", plan.output_prefix, in_ck)
assert val.ok and val.total_records == N, val
print("OK", len(rep.rounds), rep.recursed)
""", timeout=900)
