"""Distributed-sort system tests (8 host devices, subprocess-isolated) and
the full valsort gate — the paper's own validation protocol (§3.2).
"""
import pytest

from helpers import run_with_devices

COMMON = """
import jax, jax.numpy as jnp, numpy as np
from repro.core.exoshuffle import distributed_sort, distributed_sort_payload
from repro.core.streaming import streaming_sort
from repro.data import gensort, valsort
from repro.core.compat import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
N = 8 * 4096
keys, ids = gensort.gen_keys(0, N)
"""


@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_one_shot_sort_valsort_gate(impl):
    run_with_devices(COMMON + f"""
sk, si, counts, ovf = jax.jit(lambda k, i: distributed_sort(
    k, i, mesh=mesh, axis_names=("data", "model"), impl="{impl}"))(keys, ids)
assert not bool(ovf)
ks, iss, _ = valsort.slice_segments(sk, si, counts)
in_ck = tuple(int(c) for c in gensort.checksum(keys, ids))
rep = valsort.validate(ks, iss, in_ck)
assert rep.ok, rep
assert rep.total_records == N
print("OK")
""")


@pytest.mark.parametrize("rounds", [2, 8])
def test_streaming_two_stage_sort(rounds):
    run_with_devices(COMMON + f"""
sk, si, counts, ovf = jax.jit(lambda k, i: streaming_sort(
    k, i, mesh=mesh, axis_names=("data", "model"), num_rounds={rounds},
    impl="ref"))(keys, ids)
assert not bool(ovf)
ks, iss, _ = valsort.slice_segments(sk, si, counts)
in_ck = tuple(int(c) for c in gensort.checksum(keys, ids))
rep = valsort.validate(ks, iss, in_ck)
assert rep.ok, rep
print("OK")
""")


@pytest.mark.parametrize("mode", ["through", "late"])
def test_payload_modes_checksum(mode):
    run_with_devices(COMMON + f"""
payload = gensort.gen_payload(ids, 8)
in_ck = tuple(int(c) for c in gensort.checksum(keys, ids, payload))
sk, si, sp, counts, ovf = jax.jit(lambda k, i, p: distributed_sort_payload(
    k, i, p, mesh=mesh, axis_names=("data", "model"), mode="{mode}",
    impl="ref"))(keys, ids, payload)
assert not bool(ovf)
ks, iss, ps = valsort.slice_segments(sk, si, counts, sp)
rep = valsort.validate(ks, iss, in_ck, ps)
assert rep.ok, rep
print("OK")
""")


def test_checksum_detects_corruption():
    # No mesh needed — run with a single device and a mesh-free preamble.
    run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.data import gensort, valsort
N = 8 * 4096
keys, ids = gensort.gen_keys(0, N)
""" + """
in_ck = tuple(int(c) for c in gensort.checksum(keys, ids))
bad_keys = np.asarray(keys).copy(); bad_keys[123] ^= 1
rep = valsort.validate(
    [np.sort(bad_keys)], [np.asarray(ids)[np.argsort(np.asarray(keys))]], in_ck)
assert not rep.checksum_match
print("OK")
""", n_devices=1)


def test_reduce_partitions_r1():
    run_with_devices(COMMON + """
from repro.core.exoshuffle import ShuffleConfig, reduce_partitions
cfg = ShuffleConfig(num_workers=8, reducers_per_worker=4, impl="ref")
sk, si, counts, ovf = jax.jit(lambda k, i: distributed_sort(
    k, i, mesh=mesh, axis_names=("data", "model"), cfg=cfg))(keys, ids)
# per-worker: R1 reducer slices tile the worker's valid records
seg = sk.shape[0] // 8
for w in range(8):
    seg_k = sk[w*seg:(w+1)*seg]
    starts, cnts = reduce_partitions(seg_k, cfg, jnp.int32(w))
    assert int(jnp.sum(cnts)) >= int(counts[w])  # pads in last range
    # slices are sorted and within the worker range
print("OK")
""")


def test_epoch_shuffle_is_permutation():
    run_with_devices(COMMON + """
from repro.data.pipeline import device_epoch_shuffle
ids32 = jnp.arange(N, dtype=jnp.uint32)
perm = device_epoch_shuffle(ids32, epoch=3, mesh=mesh,
                            axis_names=("data", "model"))
assert len(perm) == N
assert (np.sort(perm) == np.arange(N)).all()  # a true permutation
# different epochs give different orders
perm2 = device_epoch_shuffle(ids32, epoch=4, mesh=mesh,
                             axis_names=("data", "model"))
assert not (perm2 == perm).all()
print("OK")
""")


def test_moe_sort_dispatch_matches_dense():
    run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core.moe_dispatch import MoeDispatchConfig, make_sort_dispatch, route_topk
from repro.core.compat import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
E, K, d, ff, T = 16, 2, 32, 64, 512
rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=(T, d)), jnp.float32)
weights, ids = route_topk(jnp.asarray(rng.normal(size=(T, E)), jnp.float32), K)
w1 = jnp.asarray(rng.normal(size=(E, d, ff)) * 0.1, jnp.float32)
w2 = jnp.asarray(rng.normal(size=(E, ff, d)) * 0.1, jnp.float32)
def expert_fn(params, xin):
    p1, p2 = params
    return jnp.einsum("ecf,efd->ecd", jax.nn.gelu(jnp.einsum("ecd,edf->ecf", xin, p1)), p2)
cfg = MoeDispatchConfig(num_experts=E, top_k=K, capacity_factor=4.0)
dispatch = make_sort_dispatch(mesh, cfg, expert_fn,
    token_spec=P(("data","model"), None),
    param_spec=(P("model", None, None), P("model", None, None)))
y = jax.jit(dispatch)(x, weights, ids, (w1, w2))
h = jax.nn.gelu(jnp.einsum("td,edf->tef", x, w1))
sel = jnp.take_along_axis(jnp.einsum("tef,efd->ted", h, w2), ids[..., None], axis=1)
y_ref = jnp.sum(sel * weights[..., None], axis=1)
assert float(jnp.max(jnp.abs(y - y_ref))) < 1e-4
print("OK")
""")


def test_moe_ep_decode_dispatch_matches_dense():
    """Decode-time EP dispatch (tokens replicated over the EP axis, psum
    combine) must equal the single-device dense dispatch."""
    run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core import moe_dispatch as md
from repro.core.compat import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
E, K, T, D, F = 8, 2, 16, 32, 64
rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=(T, D)), jnp.float32)
logits = jnp.asarray(rng.normal(size=(T, E)), jnp.float32)
w, ids = md.route_topk(logits, K)
prm = {
  "w_gate": jnp.asarray(rng.normal(size=(E, D, F)) * 0.1, jnp.float32),
  "w_up": jnp.asarray(rng.normal(size=(E, D, F)) * 0.1, jnp.float32),
  "w_down": jnp.asarray(rng.normal(size=(E, F, D)) * 0.1, jnp.float32),
}
def expert_fn(p, xin):
    g = jnp.einsum("ecd,edf->ecf", xin, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", xin, p["w_up"])
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, p["w_down"])

# reference: dense one-hot over all experts, capacity >= T*K (no drops)
ref = md.onehot_dispatch_combine(
    x, w, ids, num_experts=E, capacity=T * K,
    expert_fn=lambda xin: expert_fn(prm, xin))

cfg = md.MoeDispatchConfig(num_experts=E, top_k=K, ep_axis="model")
from repro.core import compat
fn = compat.shard_map(
    lambda t, ww, ii, ep: md.ep_replicated_shard(
        t, ww, ii, ep, cfg=cfg, ep_size=4, expert_fn=expert_fn),
    mesh=mesh,
    in_specs=(P("data", None), P("data", None), P("data", None),
              {k: P("model", None, None) for k in prm}),
    out_specs=P("data", None),
    check_vma=False,
)
out = fn(x, w, ids, prm)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                           rtol=2e-5, atol=2e-5)
print("OK")
""")
