"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle.

Every kernel sweeps shapes and is compared bit-exactly (integer data) to
kernels/ref.py. Hypothesis drives the property tests on arbitrary inputs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hp = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


def rand_u32(shape):
    return jnp.asarray(RNG.integers(0, 2**32, shape, dtype=np.uint32))


# ---------------------------------------------------------------------------
# bitonic sort
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [1, 2, 8, 100, 128, 1000, 4096, 5000])
def test_sort_matches_ref(n):
    k, v = rand_u32(n), rand_u32(n)
    sk, sv = ops.sort_kv(k, v)
    rk, rv = ref.sort_kv_ref(k, v)
    np.testing.assert_array_equal(sk, rk)
    np.testing.assert_array_equal(sv, rv)


@pytest.mark.parametrize("nb,n", [(1, 256), (4, 256), (16, 64)])
def test_sort_blocks(nb, n):
    k, v = rand_u32((nb, n)), rand_u32((nb, n))
    sk, sv = ops.sort_kv(k, v)
    rk, rv = ref.sort_kv_ref(k, v)
    np.testing.assert_array_equal(sk, rk)
    np.testing.assert_array_equal(sv, rv)


def test_sort_duplicate_keys_lexicographic():
    k = jnp.asarray(np.repeat(RNG.integers(0, 16, 64, dtype=np.uint32), 4))
    v = rand_u32(k.shape[0])
    sk, sv = ops.sort_kv(k, v)
    rk, rv = ref.sort_kv_ref(k, v)
    np.testing.assert_array_equal(sk, rk)
    np.testing.assert_array_equal(sv, rv)


@hp.given(
    st.lists(st.integers(0, 2**32 - 1), min_size=1, max_size=300),
    st.integers(0, 2**32 - 1),
)
@hp.settings(max_examples=25, deadline=None)
def test_sort_properties(keys, seed):
    k = jnp.asarray(np.array(keys, dtype=np.uint32))
    v = jnp.asarray(
        np.random.default_rng(seed).integers(0, 2**32, len(keys), dtype=np.uint32)
    )
    sk, sv = ops.sort_kv(k, v)
    sk_np, sv_np = np.asarray(sk), np.asarray(sv)
    # sorted ascending by (key, val)
    pairs = sk_np.astype(np.uint64) << np.uint64(32) | sv_np.astype(np.uint64)
    assert (np.diff(pairs) >= 0).all()
    # permutation: multiset of pairs preserved
    inp = np.asarray(k).astype(np.uint64) << np.uint64(32) | np.asarray(v)
    np.testing.assert_array_equal(np.sort(inp), np.sort(pairs))


# ---------------------------------------------------------------------------
# merge
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,run", [(1, 64), (4, 128), (8, 256)])
def test_merge_pairs(n, run):
    a = np.sort(RNG.integers(0, 2**32, (n, run), dtype=np.uint32), axis=-1)
    b = np.sort(RNG.integers(0, 2**32, (n, run), dtype=np.uint32), axis=-1)
    av = np.zeros_like(a)
    bv = np.ones_like(b)
    mk, mv = ops.merge_kv(jnp.asarray(a), jnp.asarray(av), jnp.asarray(b),
                          jnp.asarray(bv))
    rk, rv = ref.merge_kv_ref(jnp.asarray(a), jnp.asarray(av), jnp.asarray(b),
                              jnp.asarray(bv))
    np.testing.assert_array_equal(mk, rk)
    np.testing.assert_array_equal(mv, rv)


@pytest.mark.parametrize("k,run", [(2, 64), (4, 64), (8, 128), (16, 32)])
def test_kway_merge(k, run):
    runs_k = np.sort(RNG.integers(0, 2**32, (k, run), dtype=np.uint32), axis=-1)
    runs_v = np.zeros_like(runs_k)
    mk, mv = ops.kway_merge(jnp.asarray(runs_k), jnp.asarray(runs_v))
    assert mk.shape == (k * run,)
    np.testing.assert_array_equal(mk, np.sort(runs_k.reshape(-1)))


# ---------------------------------------------------------------------------
# range partition
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,r", [(2048, 8), (4096, 64), (2048, 100)])
def test_partition_offsets(n, r):
    sk = jnp.sort(rand_u32((2, n)), axis=-1)
    bounds = jnp.asarray(np.sort(RNG.integers(0, 2**32, r, dtype=np.uint32)))
    po = ops.partition_offsets(sk, bounds)
    pr = ref.partition_offsets_ref(sk, bounds)
    np.testing.assert_array_equal(po, pr)


@hp.given(st.integers(2, 64))
@hp.settings(max_examples=10, deadline=None)
def test_partition_counts_sum(parts):
    from repro.core.keyspace import KeySpace

    ks = KeySpace(num_reducers=parts * 4, num_workers=parts)
    keys = jnp.sort(rand_u32(2048))
    from repro.core.sortlib import partition_sorted

    starts, counts = partition_sorted(keys, ks.worker_boundaries(), impl="ref")
    assert int(jnp.sum(counts)) == 2048
    # routing consistency: partition bucket == worker_of_key
    owners = np.asarray(ks.worker_of_key(keys))
    for w in range(parts):
        lo, c = int(starts[w]), int(counts[w])
        assert (owners[lo : lo + c] == w).all()


# ---------------------------------------------------------------------------
# dtype sweeps (the contract is uint32; confirm refusal-free behaviour on
# aliased int32 views, which some callers use)
# ---------------------------------------------------------------------------


def test_sort_int32_view():
    k = rand_u32(512)
    v = rand_u32(512)
    sk, sv = ops.sort_kv(k, v, impl="ref")
    sk2, sv2 = ops.sort_kv(k, v, impl="pallas")
    np.testing.assert_array_equal(sk, sk2)
    np.testing.assert_array_equal(sv, sv2)
