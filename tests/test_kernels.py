"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle.

Every kernel sweeps shapes and is compared bit-exactly (integer data) to
kernels/ref.py. Hypothesis drives the property tests on arbitrary inputs
when installed; the deterministic sweeps (including the adversarial
sort/merge cases and the indexed-merge lowering pins) run regardless, so
the kernel contract is still exercised on a bare environment.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis as hp
    import hypothesis.strategies as st
except ImportError:  # pragma: no cover - property tests skip without it
    import unittest.mock

    class _SkipGiven:
        """Stand-in so @hp.given/@hp.settings decorations still import:
        decorated tests turn into pytest skips."""

        @staticmethod
        def given(*_a, **_k):
            return lambda f: pytest.mark.skip(
                reason="hypothesis not installed")(f)

        @staticmethod
        def settings(*_a, **_k):
            return lambda f: f

    hp = _SkipGiven()
    st = unittest.mock.MagicMock(name="hypothesis.strategies")

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


def rand_u32(shape):
    return jnp.asarray(RNG.integers(0, 2**32, shape, dtype=np.uint32))


# ---------------------------------------------------------------------------
# bitonic sort
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [1, 2, 8, 100, 128, 1000, 4096, 5000])
def test_sort_matches_ref(n):
    k, v = rand_u32(n), rand_u32(n)
    sk, sv = ops.sort_kv(k, v)
    rk, rv = ref.sort_kv_ref(k, v)
    np.testing.assert_array_equal(sk, rk)
    np.testing.assert_array_equal(sv, rv)


@pytest.mark.parametrize("nb,n", [(1, 256), (4, 256), (16, 64)])
def test_sort_blocks(nb, n):
    k, v = rand_u32((nb, n)), rand_u32((nb, n))
    sk, sv = ops.sort_kv(k, v)
    rk, rv = ref.sort_kv_ref(k, v)
    np.testing.assert_array_equal(sk, rk)
    np.testing.assert_array_equal(sv, rv)


def test_sort_duplicate_keys_lexicographic():
    k = jnp.asarray(np.repeat(RNG.integers(0, 16, 64, dtype=np.uint32), 4))
    v = rand_u32(k.shape[0])
    sk, sv = ops.sort_kv(k, v)
    rk, rv = ref.sort_kv_ref(k, v)
    np.testing.assert_array_equal(sk, rk)
    np.testing.assert_array_equal(sv, rv)


@hp.given(
    st.lists(st.integers(0, 2**32 - 1), min_size=1, max_size=300),
    st.integers(0, 2**32 - 1),
)
@hp.settings(max_examples=25, deadline=None)
def test_sort_properties(keys, seed):
    k = jnp.asarray(np.array(keys, dtype=np.uint32))
    v = jnp.asarray(
        np.random.default_rng(seed).integers(0, 2**32, len(keys), dtype=np.uint32)
    )
    sk, sv = ops.sort_kv(k, v)
    sk_np, sv_np = np.asarray(sk), np.asarray(sv)
    # sorted ascending by (key, val)
    pairs = sk_np.astype(np.uint64) << np.uint64(32) | sv_np.astype(np.uint64)
    assert (np.diff(pairs) >= 0).all()
    # permutation: multiset of pairs preserved
    inp = np.asarray(k).astype(np.uint64) << np.uint64(32) | np.asarray(v)
    np.testing.assert_array_equal(np.sort(inp), np.sort(pairs))


# ---------------------------------------------------------------------------
# merge
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,run", [(1, 64), (4, 128), (8, 256)])
def test_merge_pairs(n, run):
    a = np.sort(RNG.integers(0, 2**32, (n, run), dtype=np.uint32), axis=-1)
    b = np.sort(RNG.integers(0, 2**32, (n, run), dtype=np.uint32), axis=-1)
    av = np.zeros_like(a)
    bv = np.ones_like(b)
    mk, mv = ops.merge_kv(jnp.asarray(a), jnp.asarray(av), jnp.asarray(b),
                          jnp.asarray(bv))
    rk, rv = ref.merge_kv_ref(jnp.asarray(a), jnp.asarray(av), jnp.asarray(b),
                              jnp.asarray(bv))
    np.testing.assert_array_equal(mk, rk)
    np.testing.assert_array_equal(mv, rv)


@pytest.mark.parametrize("k,run", [(2, 64), (4, 64), (8, 128), (16, 32)])
def test_kway_merge(k, run):
    runs_k = np.sort(RNG.integers(0, 2**32, (k, run), dtype=np.uint32), axis=-1)
    runs_v = np.zeros_like(runs_k)
    mk, mv = ops.kway_merge(jnp.asarray(runs_k), jnp.asarray(runs_v))
    assert mk.shape == (k * run,)
    np.testing.assert_array_equal(mk, np.sort(runs_k.reshape(-1)))


# ---------------------------------------------------------------------------
# adversarial inputs: sort + merge vs oracle
#
# bitonic_sort_blocks / merge_sorted_pairs are jax.jit'd with a static
# `interpret` flag, so calling them on CPU exercises BOTH paths the
# satellite asks for at once: the Pallas kernel body (interpret=True)
# inside an XLA jit-on-CPU trace. The indexed kernel additionally pins
# its plain-jnp "network" lowering (the production CPU path) below.
# ---------------------------------------------------------------------------

ADVERSARIAL = ["duplicates", "sorted", "reverse", "minmax"]


def _adversarial_keys(case, shape):
    n = int(np.prod(shape))
    if case == "duplicates":
        k = RNG.integers(0, 7, n, dtype=np.uint32)
    elif case == "sorted":
        k = np.sort(RNG.integers(0, 2**32, n, dtype=np.uint32))
    elif case == "reverse":
        k = np.sort(RNG.integers(0, 2**32, n, dtype=np.uint32))[::-1].copy()
    else:  # minmax: only the two u32 extremes
        k = np.where(RNG.integers(0, 2, n) == 0, np.uint32(0),
                     np.uint32(0xFFFFFFFF)).astype(np.uint32)
    return k.reshape(shape)


@pytest.mark.parametrize("case", ADVERSARIAL)
def test_sort_blocks_adversarial(case):
    from repro.kernels.bitonic_sort import bitonic_sort_blocks

    k = jnp.asarray(_adversarial_keys(case, (4, 256)))
    # duplicate vals too, so (key, val) ties hit the network's tiebreak
    v = jnp.asarray(RNG.integers(0, 3, (4, 256), dtype=np.uint32))
    sk, sv = bitonic_sort_blocks(k, v, interpret=True)
    rk, rv = ref.sort_kv_ref(k, v)
    np.testing.assert_array_equal(sk, rk)
    np.testing.assert_array_equal(sv, rv)


@pytest.mark.parametrize("case", ADVERSARIAL)
def test_merge_pairs_adversarial(case):
    from repro.kernels.merge_sorted import merge_sorted_pairs

    ak = jnp.asarray(np.sort(_adversarial_keys(case, (4, 128)), axis=-1))
    bk = jnp.asarray(np.sort(_adversarial_keys(case, (4, 128)), axis=-1))
    av = jnp.zeros_like(ak)
    bv = jnp.ones_like(bk)
    mk, mv = merge_sorted_pairs(ak, av, bk, bv, interpret=True)
    rk, rv = ref.merge_kv_ref(ak, av, bk, bv)
    np.testing.assert_array_equal(mk, rk)
    np.testing.assert_array_equal(mv, rv)


@hp.given(
    st.lists(st.integers(0, 2**32 - 1), min_size=128, max_size=128),
    st.integers(0, 2**32 - 1),
)
@hp.settings(max_examples=25, deadline=None)
def test_merge_pairs_properties(keys, seed):
    from repro.kernels.merge_sorted import merge_sorted_pairs

    k = np.array(keys, dtype=np.uint32)
    v = np.random.default_rng(seed).integers(0, 4, 128, dtype=np.uint32)
    ak, av = np.sort(k[:64]), np.sort(v[:64])
    bk, bv = np.sort(k[64:]), np.sort(v[64:])
    mk, mv = merge_sorted_pairs(
        jnp.asarray(ak[None]), jnp.asarray(av[None]),
        jnp.asarray(bk[None]), jnp.asarray(bv[None]), interpret=True)
    rk, rv = ref.merge_kv_ref(
        jnp.asarray(ak[None]), jnp.asarray(av[None]),
        jnp.asarray(bk[None]), jnp.asarray(bv[None]))
    np.testing.assert_array_equal(mk, rk)
    np.testing.assert_array_equal(mv, rv)


# ---------------------------------------------------------------------------
# indexed merge (kernels/kway_merge.py): the three lowerings must agree
# bit-for-bit with each other and with the lax.sort oracle
# ---------------------------------------------------------------------------


def _sorted_triples(case, shape):
    """Rows sorted lexicographically on (key, val, idx) — valid kernel
    input by construction."""
    import jax.lax

    k = jnp.asarray(_adversarial_keys(case, shape))
    v = jnp.asarray(RNG.integers(0, 3, shape, dtype=np.uint32))
    i = jnp.asarray(RNG.integers(0, 2**20, shape, dtype=np.int32))
    return jax.lax.sort((k, v, i), dimension=-1, num_keys=3)


@pytest.mark.parametrize("case", ADVERSARIAL)
def test_merge_pairs_indexed_matches_ref(case):
    from repro.kernels.kway_merge import merge_sorted_pairs_indexed

    a = _sorted_triples(case, (4, 64))
    b = _sorted_triples(case, (4, 64))
    got = merge_sorted_pairs_indexed(*a, *b, interpret=True)
    want = ref.merge_kvi_ref(*a, *b)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)


@pytest.mark.parametrize("case", ADVERSARIAL + ["random"])
@pytest.mark.parametrize("k,run", [(2, 64), (8, 32)])
def test_kway_merge_indexed_impls_agree(case, k, run):
    from repro.kernels.kway_merge import kway_merge_indexed

    if case == "random":
        keys = jnp.asarray(RNG.integers(0, 2**32, (k, run), dtype=np.uint32))
        vals = jnp.asarray(RNG.integers(0, 2**32, (k, run), dtype=np.uint32))
    else:
        keys = jnp.asarray(_adversarial_keys(case, (k, run)))
        vals = jnp.asarray(RNG.integers(0, 3, (k, run), dtype=np.uint32))
    idx = jnp.asarray(RNG.integers(0, 2**20, (k, run), dtype=np.int32))
    import jax.lax
    keys, vals, idx = jax.lax.sort((keys, vals, idx), dimension=-1,
                                   num_keys=3)
    outs = {impl: kway_merge_indexed(keys, vals, idx, impl=impl)
            for impl in ("pallas", "network", "ref")}
    for impl in ("network", "ref"):
        for a, b in zip(outs["pallas"], outs[impl]):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=f"pallas vs {impl}")


def _make_frags(sizes, *, pw, key_pool=None, seed=0):
    """Build merge_fragments-style [(keys, ids, payload, k64), ...]
    windows: each fragment sorted by packed (key<<32|id)."""
    rng = np.random.default_rng(seed)
    frags = []
    for n in sizes:
        if key_pool is None:
            k = rng.integers(0, 2**32, n, dtype=np.uint32)
        else:
            k = rng.choice(np.asarray(key_pool, np.uint32), size=n)
        i = rng.integers(0, 2**32, n, dtype=np.uint32)
        k64 = k.astype(np.uint64) << np.uint64(32) | i.astype(np.uint64)
        order = np.argsort(k64, kind="stable")
        p = (rng.integers(0, 2**32, (n, pw), dtype=np.uint32)
             if pw else None)
        frags.append((k[order], i[order],
                      p[order] if pw else None, k64[order]))
    return frags


@pytest.mark.parametrize("pw", [0, 2])
@pytest.mark.parametrize("pool", [
    None,  # unique-ish random packed keys
    [0, 1, 0xFFFFFFFF],  # heavy duplicates incl. records == PAD key
    [0xFFFFFFFF],  # EVERY record equals the pad key (worst case)
])
def test_merge_fragments_device_bit_identical(pw, pool):
    from repro.kernels.kway_merge import merge_fragments_device
    from repro.shuffle.runtime import merge_fragments

    frags = _make_frags([97, 1, 256, 33, 0, 128], pw=pw, key_pool=pool,
                        seed=3)
    want = merge_fragments(frags, pw)
    for impl in ("network", "ref", "pallas"):
        got = merge_fragments_device(frags, pw, impl=impl)
        np.testing.assert_array_equal(got[0], want[0], err_msg=impl)
        np.testing.assert_array_equal(got[1], want[1], err_msg=impl)
        if pw:
            np.testing.assert_array_equal(got[2], want[2], err_msg=impl)
        else:
            assert got[2] is None and want[2] is None


def test_merge_fragments_device_degenerate_windows():
    from repro.kernels.kway_merge import merge_fragments_device
    from repro.shuffle.runtime import merge_fragments

    for sizes in ([], [0, 0], [5], [0, 7, 0]):
        frags = _make_frags(sizes, pw=1, seed=9)
        want = merge_fragments(frags, 1)
        got = merge_fragments_device(frags, 1)
        np.testing.assert_array_equal(got[0], want[0])
        np.testing.assert_array_equal(got[1], want[1])
        if want[0].size:
            np.testing.assert_array_equal(got[2], want[2])


# ---------------------------------------------------------------------------
# range partition
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,r", [(2048, 8), (4096, 64), (2048, 100)])
def test_partition_offsets(n, r):
    sk = jnp.sort(rand_u32((2, n)), axis=-1)
    bounds = jnp.asarray(np.sort(RNG.integers(0, 2**32, r, dtype=np.uint32)))
    po = ops.partition_offsets(sk, bounds)
    pr = ref.partition_offsets_ref(sk, bounds)
    np.testing.assert_array_equal(po, pr)


@hp.given(st.integers(2, 64))
@hp.settings(max_examples=10, deadline=None)
def test_partition_counts_sum(parts):
    from repro.core.keyspace import KeySpace

    ks = KeySpace(num_reducers=parts * 4, num_workers=parts)
    keys = jnp.sort(rand_u32(2048))
    from repro.core.sortlib import partition_sorted

    starts, counts = partition_sorted(keys, ks.worker_boundaries(), impl="ref")
    assert int(jnp.sum(counts)) == 2048
    # routing consistency: partition bucket == worker_of_key
    owners = np.asarray(ks.worker_of_key(keys))
    for w in range(parts):
        lo, c = int(starts[w]), int(counts[w])
        assert (owners[lo : lo + c] == w).all()


# ---------------------------------------------------------------------------
# dtype sweeps (the contract is uint32; confirm refusal-free behaviour on
# aliased int32 views, which some callers use)
# ---------------------------------------------------------------------------


def test_sort_int32_view():
    k = rand_u32(512)
    v = rand_u32(512)
    sk, sv = ops.sort_kv(k, v, impl="ref")
    sk2, sv2 = ops.sort_kv(k, v, impl="pallas")
    np.testing.assert_array_equal(sk, sk2)
    np.testing.assert_array_equal(sv, sv2)
