"""ISSUE-7 acceptance gates: device-resident reduce merge + pipelined map.

The tentpole contract, pinned end to end:

  * DeviceMergeReduceOp (plan.reduce_merge_impl="device") must produce
    output byte- and etag-identical to the numpy merge backend at
    W in {1, 4} and parallel_reducers in {1, 4}, including under an
    injected worker kill — the merge kernel swap must be invisible in
    the bytes.
  * The pipelined map executor (plan.map_pipeline, on by default) must
    also be byte-invisible, while its staged spans (map.decode /
    map.device_sort / map.encode) and the reduce.device_merge span show
    up in phase_seconds so the overlap is observable.
  * runtime.merge_fragments' ordered fast path (no live interleave ->
    concatenation IS the merge) must be bit-identical to the argsort
    path, boundary ties included.

Sort runs execute in subprocesses (8 host devices) via helpers.
"""
import numpy as np

from helpers import run_with_devices


# ---------------------------------------------------------------------------
# merge_fragments ordered fast path (pure numpy — no devices)
# ---------------------------------------------------------------------------


def _frag(keys, ids, pw=1):
    keys = np.asarray(keys, np.uint32)
    ids = np.asarray(ids, np.uint32)
    k64 = keys.astype(np.uint64) << np.uint64(32) | ids.astype(np.uint64)
    order = np.argsort(k64, kind="stable")
    payload = (ids.reshape(-1, 1).repeat(pw, axis=1).astype(np.uint32)
               if pw else None)
    return (keys[order], ids[order],
            payload[order] if pw else None, k64[order])


def _argsort_merge(frags, pw):
    """The pre-fast-path body, verbatim: the oracle the fast path must
    reproduce bit-for-bit."""
    frags = [f for f in frags if f[3].size]
    k64 = np.concatenate([f[3] for f in frags])
    order = np.argsort(k64, kind="stable")
    keys = np.concatenate([f[0] for f in frags])[order]
    ids = np.concatenate([f[1] for f in frags])[order]
    payload = (np.concatenate([f[2] for f in frags])[order] if pw else None)
    return keys, ids, payload


def _check_identical(frags, pw=1):
    from repro.shuffle.runtime import merge_fragments

    got = merge_fragments(frags, pw)
    want = _argsort_merge(frags, pw)
    np.testing.assert_array_equal(got[0], want[0])
    np.testing.assert_array_equal(got[1], want[1])
    if pw:
        np.testing.assert_array_equal(got[2], want[2])
    return got


def test_merge_fragments_single_live_fragment_copies_through():
    # Emit windows where every fragment but one is drained: the common
    # tail of a skewed partition. Empty fragments are filtered, leaving
    # one live run -> the len==1 copy-through.
    frags = [_frag([], []), _frag([5, 9, 9], [1, 0, 2]), _frag([], [])]
    k, i, p = _check_identical(frags)
    np.testing.assert_array_equal(k, [5, 9, 9])


def test_merge_fragments_non_interleaving_fast_path():
    # Live fragments whose key ranges do not interleave: concatenation
    # is the merge. Includes a boundary TIE on the packed (key, id)
    # between fragment ends — fragment order must win, exactly as the
    # stable argsort orders it.
    frags = [
        _frag([1, 2, 3], [7, 7, 7]),
        _frag([3, 4], [7, 9]),   # head (3, 7) ties frag 0's tail (3, 7)
        _frag([4, 10], [9, 0]),  # head (4, 9) ties frag 1's tail
    ]
    k, i, p = _check_identical(frags)
    np.testing.assert_array_equal(k, [1, 2, 3, 3, 4, 4, 10])


def test_merge_fragments_interleaved_still_argsorts():
    # Control: genuinely interleaved fragments must NOT take the fast
    # path's concatenation order (which would be wrong) — output equals
    # the stable argsort merge.
    rng = np.random.default_rng(11)
    frags = [_frag(rng.integers(0, 50, 40, dtype=np.uint32),
                   rng.integers(0, 4, 40, dtype=np.uint32))
             for _ in range(3)]
    got = _check_identical(frags)
    pairs = got[0].astype(np.uint64) << np.uint64(32) | got[1]
    assert (np.diff(pairs.astype(np.int64)) >= 0).all()


def test_merge_fragments_fast_path_no_payload():
    frags = [_frag([1], [1], pw=0), _frag([2], [2], pw=0)]
    k, i, p = _check_identical(frags, pw=0)
    assert p is None


# ---------------------------------------------------------------------------
# end-to-end: device merge + pipelined map (subprocess, 8 host devices)
# ---------------------------------------------------------------------------

SETUP = """
import dataclasses
import tempfile
import numpy as np
from repro.core.external_sort import ExternalSortPlan
from repro.core.compat import make_mesh
from repro.data import gensort, valsort
from repro.io.object_store import ObjectStore
from repro.shuffle.executor import ClusterPlan
from repro.shuffle.sort import sort_shuffle_job

mesh = make_mesh((8,), ("w",))
plan = ExternalSortPlan(
    records_per_wave=1 << 13,
    num_rounds=2,
    reducers_per_worker=2,
    payload_words=2,
    impl="ref",
    input_records_per_partition=1 << 12,
    output_part_records=1 << 11,
    store_chunk_bytes=16 << 10,
    parallel_reducers=2,
    reduce_memory_budget_bytes=64 << 10,
)
N = 1 << 15
store = ObjectStore(tempfile.mkdtemp(prefix="device-merge-test-"))
store.create_bucket("sort")
in_ck, _ = gensort.write_to_store(
    store, "sort", plan.input_prefix, N,
    plan.input_records_per_partition, plan.payload_words)

def layout():
    return [(m.key, m.etag, m.size, m.parts)
            for m in store.list_objects("sort", plan.output_prefix)]

def run(p, **kw):
    return sort_shuffle_job(store, "sort", mesh=mesh, axis_names="w",
                            plan=p).run(**kw)
"""


def test_device_merge_byte_identical_across_schedules():
    # The acceptance gate: reduce_merge_impl="device" output is byte-
    # and etag-identical to the numpy merge at parallel_reducers in
    # {1, 4}, W in {1, 4}, and under a worker kill — and valsort-clean.
    run_with_devices(SETUP + """
rep0 = run(plan, workers=0)  # numpy merge baseline
want = layout()
assert len(want) == 16

for par in (1, 4):
    p_dev = dataclasses.replace(plan, reduce_merge_impl="device",
                                parallel_reducers=par)
    rep = run(p_dev, workers=0)
    assert layout() == want, f"device merge P={par} changed output bytes"
    assert rep.phase_seconds.get("reduce.device_merge", 0) > 0, (
        rep.phase_seconds)

p_dev = dataclasses.replace(plan, reduce_merge_impl="device")
for W in (1, 4):
    crep = run(p_dev, workers=W)
    assert layout() == want, f"device merge W={W} changed output bytes"
    assert crep.num_cluster_workers == W and not crep.failed_workers

crep = run(p_dev, cluster=ClusterPlan(num_workers=4,
                                      fail_after_tasks={1: 2}))
assert layout() == want, "device merge under worker kill changed bytes"
assert crep.failed_workers == ["w1"] and crep.reexecuted_tasks >= 1

val = valsort.validate_from_store(store, "sort", plan.output_prefix, in_ck)
assert val.ok and val.total_records == N, val
print("OK")
""", timeout=900)


def test_map_pipeline_byte_identical_and_staged_spans():
    # The pipelined map executor (default-on) must not change a byte vs
    # the monolithic path, and must surface the staged spans. The
    # monolithic path keeps its original span shape (map.compute, no
    # map.decode/device_sort/encode).
    run_with_devices(SETUP + """
rep_mono = run(dataclasses.replace(plan, map_pipeline=False), workers=0)
want = layout()
ps = rep_mono.phase_seconds
assert ps.get("map.compute", 0) > 0
for k in ("map.decode", "map.device_sort", "map.encode"):
    assert k not in ps, (k, ps)

rep_pipe = run(plan, workers=0)
assert layout() == want, "map_pipeline changed output bytes"
ps = rep_pipe.phase_seconds
for k in ("map.decode", "map.device_sort", "map.encode", "map.compute",
          "map.spill"):
    assert ps.get(k, 0) > 0, (k, ps)
# device_sort is recorded under map.compute too (phase-total compat):
# the same interval, re-stamped — so equal up to the add() overhead.
assert ps["map.compute"] >= ps["map.device_sort"], ps
assert ps["map.compute"] - ps["map.device_sort"] < 0.01, ps

# pipelined + device merge together, on a cluster
p_both = dataclasses.replace(plan, reduce_merge_impl="device")
crep = run(p_both, workers=2)
assert layout() == want, "pipeline+device cluster run changed bytes"
ps = crep.report.phase_seconds
for k in ("map.decode", "map.device_sort", "map.encode",
          "reduce.device_merge"):
    assert ps.get(k, 0) > 0, (k, ps)
assert crep.spans_dropped == 0
print("OK")
""", timeout=900)
