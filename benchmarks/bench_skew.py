"""Skew-adaptive partitioning: sampled splitters vs the equal split,
plus the recursive multi-round sort on duplicate-heavy input.

The Indy assumption (uniform keys -> equal key-space ranges balance
themselves) breaks on skewed data: with the "zipf" gensort variant the
low octaves carry exponentially more mass, so the equal split funnels
most records into partition 0. The Daytona-style fallback (ISSUE-9) is
a sampling pre-pass — evenly spaced ranged GETs over a
`sample_fraction` of the input, billed and traced like any other
phase — whose quantiles become the partition boundaries.

Both imbalance rows route the SAME host-regenerated key population
(zero extra GETs for either — the comparison is at equal GET counts by
construction); the sampling pre-pass's own ranged GETs are the gated
`sample_gets` row. On top, `recursive_rounds` runs the full recursive
driver on the "dup" variant — a hot partition that NO key boundary can
split (25% of records share one key) and that exceeds the reduce
memory budget, so only the next-key-bits re-shuffle rounds can sort
it — and asserts valsort cleanliness.

Rows (name, us, derived):

  skew/imbalance_equal   — max/mean partition bytes, equal key-space
                           split (derived = the ratio; 1.0 is perfect)
  skew/imbalance_sampled — same keys, sampled-quantile boundaries
  skew/balance_gain      — equal/sampled imbalance ratio (gated,
                           >= 2x is the acceptance bar)
  skew/sample_gets       — ranged GETs billed to the sampling pre-pass
                           (gated, deterministic: positions are pure
                           arithmetic, no RNG)
  skew/recursive_rounds  — rounds the dup-heavy recursive sort
                           executed (>= 3: root + the id-split rounds),
                           us = end-to-end wall time

Standalone: PYTHONPATH=src python benchmarks/bench_skew.py [--smoke|--full]
`run()` (the benchmarks/run.py entry) always uses smoke scale.
"""
from __future__ import annotations

import time

#: CI gate declarations (tools/bench_diff.py). sample_gets is a pure
#: function of the input layout + knobs; balance_gain is data-derived
#: but deterministic — the wide band tolerates legitimate sampling
#: changes while catching the splitters collapsing back to equal-split
#: behaviour.
GATES = {
    "skew/balance_gain": {"direction": "higher", "tolerance": 0.25},
    "skew/sample_gets": {"direction": "lower", "tolerance": 0.02},
}


def _imbalance(counts) -> float:
    """max/mean partition load (records and bytes give the same ratio —
    every record is plan.record_bytes wide)."""
    return float(counts.max() / counts.mean())


def run(full: bool = False):
    import dataclasses
    import tempfile

    import jax
    import numpy as np

    from repro.core.compat import make_mesh
    from repro.core.external_sort import ExternalSortPlan
    from repro.data import gensort, valsort
    from repro.io.object_store import ObjectStore
    from repro.obs.events import Tracer
    from repro.shuffle.job import sample_boundaries
    from repro.shuffle.partition import RangePartitioner
    from repro.shuffle.recursive import recursive_sort

    w = len(jax.devices())
    mesh = make_mesh((w,), ("w",))
    parts = 32 if full else 16
    n = 1 << (17 if full else 15)
    pw = 2
    plan = ExternalSortPlan(
        records_per_wave=1 << 13,
        num_rounds=2,
        reducers_per_worker=max(2, parts // w),
        payload_words=pw,
        impl="ref",
        input_records_per_partition=1 << 12,
        output_part_records=1 << 11,
        store_chunk_bytes=16 << 10,
        parallel_reducers=2,
        reduce_memory_budget_bytes=64 << 10,
        # MAP-side all-to-all slack for the 25% duplicate mass in the
        # recursive case — no boundary choice can move equal keys apart
        # in one round; the REDUCE-side ceiling is what the recursion
        # removes (see tests/test_shuffle.py for the same fixture).
        capacity_factor=4.0,
        sample_fraction=1 / 16,
        max_rounds=3,
    )

    store = ObjectStore(tempfile.mkdtemp(prefix="bench-skew-"))
    store.create_bucket("bench")

    # --- splitter quality on the "zipf" variant ------------------------
    in_ck, _ = gensort.write_to_store(
        store, "bench", plan.input_prefix, n,
        plan.input_records_per_partition, pw, skew="zipf", skew_seed=7)
    samp = sample_boundaries(
        store, "bench", input_prefix=plan.input_prefix, payload_words=pw,
        sample_fraction=plan.sample_fraction, parts=parts)
    assert samp.records_total == n, samp

    # The full key population, regenerated host-side (keys are a pure
    # function of the record id): both routings see identical data and
    # spend identical GETs — zero — so the rows isolate splitter
    # quality, not I/O strategy.
    keys = gensort.skewed_keys(np.arange(n, dtype=np.uint32), "zipf", 7)
    rows = []
    imb = {}
    for name, part in (
            ("equal", RangePartitioner(parts)),
            ("sampled", RangePartitioner(parts, boundaries=samp.boundaries))):
        t0 = time.perf_counter()
        dest = part.partition_of(keys)
        us = (time.perf_counter() - t0) * 1e6
        imb[name] = _imbalance(np.bincount(dest, minlength=parts))
        rows.append((f"skew/imbalance_{name}", us, imb[name]))

    gain = imb["equal"] / imb["sampled"]
    assert gain >= 2.0, (
        f"sampled boundaries balanced only {gain:.2f}x better than the "
        f"equal split (bar: 2x; equal={imb['equal']:.2f}, "
        f"sampled={imb['sampled']:.2f})")
    rows.append(("skew/balance_gain", 0.0, gain))
    rows.append(("skew/sample_gets", samp.seconds * 1e6,
                 float(samp.get_requests)))

    # --- recursive multi-round sort on the "dup" variant ---------------
    in_ck, _ = gensort.write_to_store(
        store, "bench", plan.input_prefix, n,
        plan.input_records_per_partition, pw, skew="dup", skew_seed=3)
    # The hot partition alone exceeds the reduce budget: recursion, not
    # headroom, is what sorts this.
    assert (n // 4) * plan.record_bytes > plan.reduce_memory_budget_bytes
    tracer = Tracer(job="bench-skew")
    t0 = time.perf_counter()
    rep = recursive_sort(store, "bench", mesh=mesh, axis_names="w",
                         plan=plan, tracer=tracer)
    sort_us = (time.perf_counter() - t0) * 1e6
    val = valsort.validate_from_store(store, "bench", plan.output_prefix,
                                      in_ck)
    assert val.ok and val.total_records == n, val
    assert rep.num_rounds >= 3 and rep.recursed, rep.rounds
    gauges = tracer.registry.snapshot()["gauges"]
    assert "phase.seconds{phase=sample}" in gauges, sorted(gauges)
    rows.append(("skew/recursive_rounds", sort_us, float(rep.num_rounds)))
    return rows


def main():
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--smoke", action="store_true",
                      help="2^15 records, 16 partitions (the default)")
    mode.add_argument("--full", action="store_true",
                      help="2^17 records, 32 partitions")
    args = ap.parse_args()
    t0 = time.perf_counter()
    print("name,us_per_call,derived")
    for name, us, derived in run(full=args.full):
        print(f"{name},{us:.3f},{derived:.6g}")
    print(f"# total {time.perf_counter() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
