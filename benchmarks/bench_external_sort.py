"""Out-of-core external sort through the object store (paper §2.3–§2.5).

Tracks, from this PR onward: end-to-end sorted records/s at a fixed
out-of-core oversubscription, the measured GET/PUT request counts (the
Table-2 access legs), the measured-TCO total for the run, and the
per-phase span timeline (map wait/compute/spill, reduce
fetch/merge/upload) so stage overlap is a number, not a narrative. Runs
on however many devices the harness process has (typically 1) — the
point is the store path, not the collective.

Standalone: PYTHONPATH=src python benchmarks/bench_external_sort.py [--smoke|--full]
`run()` (the benchmarks/run.py entry) always uses smoke scale, parity
with bench_store_faults; --full sorts 4x the records.
"""
from __future__ import annotations

import tempfile
import time

#: Regression gates for tools/bench_diff.py: only machine-independent
#: rows are gated (request counts are exact functions of the plan, not
#: of runner speed); timings — and the measured TCO, whose compute-VM
#: leg is priced off wall-clock runtime — stay informational because CI
#: runners are noisy.
GATES = {
    "extsort_get_requests": {"tolerance": 0.25, "direction": "lower"},
    "extsort_put_requests": {"tolerance": 0.25, "direction": "lower"},
}


def run(full: bool = False):
    import jax

    from repro.core.cost_model import measured_cloudsort_tco
    from repro.core.external_sort import ExternalSortPlan, external_sort
    from repro.data import gensort, valsort
    from repro.io.object_store import ObjectStore

    w = len(jax.devices())
    from repro.core.compat import make_mesh
    mesh = make_mesh((w,), ("w",))
    plan = ExternalSortPlan(
        records_per_wave=(1 << (13 if full else 12)) * w,
        num_rounds=2,
        reducers_per_worker=4,
        payload_words=4,
        impl="ref",
        input_records_per_partition=(1 << (12 if full else 11)) * w,
        output_part_records=1 << 12,
        store_chunk_bytes=32 << 10,
    )
    total = plan.records_per_wave * 4  # 4x out-of-core
    root = tempfile.mkdtemp(prefix="bench-extsort-")
    store = ObjectStore(root)
    store.create_bucket("bench")

    in_ck, _ = gensort.write_to_store(
        store, "bench", plan.input_prefix, total,
        plan.input_records_per_partition, plan.payload_words)

    t0 = time.perf_counter()
    rep = external_sort(store, "bench", mesh=mesh, axis_names="w", plan=plan)
    wall = time.perf_counter() - t0
    val = valsort.validate_from_store(store, "bench", plan.output_prefix, in_ck)
    assert val.ok, val

    tco = measured_cloudsort_tco(
        rep.stats, job_hours=rep.job_hours, reduce_hours=rep.reduce_hours,
        data_bytes=total * plan.record_bytes)
    us = wall * 1e6
    rows = [
        ("extsort_total", us, total / wall),  # derived: records/s
        ("extsort_map", rep.map_seconds * 1e6, rep.oversubscription),
        ("extsort_reduce", rep.reduce_seconds * 1e6, rep.num_reducers),
        ("extsort_get_requests", us, rep.stats.get_requests),
        ("extsort_put_requests", us, rep.stats.put_requests),
        # streaming-reduce working set: measured peak vs the global bound
        ("extsort_reduce_peak_bytes", rep.reduce_seconds * 1e6,
         rep.reduce_peak_merge_bytes),
        ("extsort_measured_tco_usd", us, tco.total),
    ]
    # Span timeline: us = summed span seconds of the phase; derived = that
    # work as a fraction of its stage's wall time (>1 means the phase ran
    # overlapped across threads — the §2.5 claim, measured).
    ph = rep.phase_seconds
    stage_wall = {"map": rep.map_seconds, "reduce": rep.reduce_seconds}
    for phase in ("map.wait", "map.compute", "map.spill",
                  "reduce.fetch", "reduce.merge", "reduce.upload"):
        secs = ph.get(phase, 0.0)
        denom = stage_wall[phase.split(".", 1)[0]]
        rows.append((f"extsort_span_{phase.replace('.', '_')}",
                     secs * 1e6, secs / denom if denom > 0 else 0.0))
    return rows


def main():
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--smoke", action="store_true",
                      help="small dataset (the default)")
    mode.add_argument("--full", action="store_true",
                      help="4x the records per wave and per partition")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for name, us, derived in run(full=args.full):
        print(f"{name},{us:.3f},{derived:.6g}")


if __name__ == "__main__":
    main()
