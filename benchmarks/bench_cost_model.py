"""Paper Table 2: total cost of ownership — exact reproduction plus the
TPU re-parameterization for both payload modes."""
from __future__ import annotations

from repro.core.cost_model import cloudsort_tco, tpu_cloudsort_tco


def run():
    rows = []
    b = cloudsort_tco()
    for name, val in b.rows():
        rows.append((f"paper_{name}", val * 1e6, val))
    for mode in ("through", "late"):
        tb = tpu_cloudsort_tco(payload_mode=mode)
        rows.append((f"tpu256_{mode}_total", tb.total * 1e6, tb.total))
    return rows
