"""Group-by shuffle: the library's generality claim, measured.

Exoshuffle argues a shuffle library serves workloads beyond sorting with
the same machinery; BlobShuffle shows object-storage shuffle carrying
repartitioning/aggregation jobs. This benchmark runs the word-count-
shaped group-by (shuffle/groupby.py) against a latency-injected store
and measures what the library delivers without any sort-specific code
in the operators:

  * end-to-end throughput with the map-side combiner on vs off — the
    combiner collapses repeated keys before they are spilled, so the
    shuffled spill bytes must SHRINK (skewed keys guarantee repeats);
  * the cluster executor: a W=4 run with one worker killed mid-job must
    recover on survivors with byte-identical output.

Invariants asserted on every case: output objects byte-identical (keys,
CRC etags, sizes, part layout) across combiner on/off, worker counts,
and failure; aggregates exactly match the generation-time reference;
measured all-reducer peak merge memory <= the global budget.

Rows (name, us = end-to-end wall time, derived):

  groupby/e2e                 — derived = records/s (combiner on)
  groupby/no_combine          — derived = records/s (combiner off)
  groupby/combine_spill_ratio — derived = spill bytes off / on (> 1)
  groupby/failover_w4_kill1   — derived = re-executed task count

Standalone: PYTHONPATH=src python benchmarks/bench_groupby.py [--smoke|--full]
`run()` (the benchmarks/run.py entry) always uses smoke scale.
"""
from __future__ import annotations

import time


def _build_store(latency_s: float, bandwidth_bps: float):
    # Deterministic stall injection (no jitter/throttle randomness): the
    # byte-identity assertions compare runs on identical data, and the
    # memory data plane keeps the bench latency-dominated anywhere.
    from repro.io.backends import MemoryBackend
    from repro.io.middleware import (FaultProfile, LatencyBandwidthMiddleware,
                                     MetricsMiddleware)

    profile = FaultProfile(latency_s=latency_s, bandwidth_bps=bandwidth_bps)
    return MetricsMiddleware(
        LatencyBandwidthMiddleware(MemoryBackend(chunk_size=64 << 10),
                                   profile))


def run(full: bool = False):
    import dataclasses

    from repro.configs.groupby import SMOKE, groupby_smoke_plan
    from repro.shuffle.executor import ClusterPlan
    from repro.shuffle.groupby import (groupby_job,
                                       validate_groupby_from_store,
                                       write_groupby_input)

    cfg = dataclasses.replace(
        SMOKE, records=1 << (17 if full else 15),
        records_per_partition=1 << (13 if full else 12))
    plan = groupby_smoke_plan()
    store = _build_store(latency_s=0.004, bandwidth_bps=200e6)
    store.create_bucket("bench")
    expected_counts, expected_sums = write_groupby_input(
        store, "bench", plan.input_prefix, cfg.records,
        cfg.records_per_partition, num_groups=cfg.num_groups,
        skew=cfg.skew, value_range=cfg.value_range)

    def layout():
        return [(m.key, m.etag, m.size, m.parts)
                for m in store.list_objects("bench", plan.output_prefix)]

    def run_one(combine: bool, cluster=None):
        job = groupby_job(store, "bench", plan=plan,
                          num_partitions=cfg.num_partitions, combine=combine)
        t0 = time.perf_counter()
        out = job.run(cluster=cluster) if cluster is not None else job.run()
        secs = time.perf_counter() - t0
        rep = out.report if cluster is not None else out
        assert rep.reduce_peak_merge_bytes <= plan.reduce_memory_budget_bytes
        val = validate_groupby_from_store(
            store, "bench", plan.output_prefix, job.partitioner,
            expected_counts, expected_sums)
        assert val.ok, val
        return out, rep, secs

    rows = []
    _, rep_on, secs_on = run_one(combine=True)
    want = layout()
    spill_on = rep_on.stats.bytes_written - _output_bytes(store, plan)
    rows.append(("groupby/e2e", secs_on * 1e6, cfg.records / secs_on))

    _, rep_off, secs_off = run_one(combine=False)
    assert layout() == want, "combiner changed output bytes"
    spill_off = rep_off.stats.bytes_written - _output_bytes(store, plan)
    rows.append(("groupby/no_combine", secs_off * 1e6,
                 cfg.records / secs_off))
    ratio = spill_off / max(spill_on, 1)
    assert ratio > 1.0, (
        f"combiner saved nothing (spill {spill_off} -> {spill_on})")
    rows.append(("groupby/combine_spill_ratio", 0.0, ratio))

    crep, _, secs = run_one(
        combine=True,
        cluster=ClusterPlan(num_workers=4, fail_after_tasks={1: 2}))
    assert layout() == want, "worker failure changed output bytes"
    assert crep.failed_workers == ["w1"], crep.failed_workers
    rows.append(("groupby/failover_w4_kill1", secs * 1e6,
                 float(crep.reexecuted_tasks)))
    return rows


def _output_bytes(store, plan) -> int:
    return sum(m.size for m in store.list_objects("bench",
                                                  plan.output_prefix))


def main():
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--smoke", action="store_true",
                      help="small dataset (the default)")
    mode.add_argument("--full", action="store_true",
                      help="larger dataset")
    args = ap.parse_args()
    t0 = time.perf_counter()
    print("name,us_per_call,derived")
    for name, us, derived in run(full=args.full):
        print(f"{name},{us:.3f},{derived:.6g}")
    print(f"# total {time.perf_counter() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
