"""Serverless FunctionWorker: per-invocation billing and the TCO crossover.

The paper's §2.6 deployment is a provisioned VM cluster billed by the
hour; the serverless execution mode trades that provisioning floor for
per-invocation GB-second billing. This bench runs the same CloudSort job
through the FunctionWorker fleet (one task per invocation, world rebuilt
from a JSON event, FakeS3 as the only shared state) and prices the run
two ways:

  * measured: every invocation's wall-clock and peak memory feed the
    GB-second leg; the fleet's retry-inflated request counters feed the
    access legs (exactly like the VM cost model — retries are billed);
  * modeled: the closed-form serverless-vs-cluster sweep scaled from
    the paper's 100 TB profile, bisected for the dataset size where the
    two totals cross (the cluster's 5-minute provisioning floor loses
    below it, the GB-second premium loses above it).

Invariants: output byte/etag-identical to the single-host reference,
valsort-clean, exactly one task per invocation (no warm-state reuse
across tasks beyond the compiled-kernel sandbox).

Rows (name, us = end-to-end wall time, derived):

  serverless/fn_w{W}               — derived = end-to-end records/s
  serverless/fn_invocations        — derived = invocation count (exact)
  serverless/fn_get_requests       — derived = fleet GET attempts (W=1)
  serverless/fn_put_requests       — derived = fleet PUT attempts (W=1)
  serverless/fn_gb_seconds         — derived = billed GB-seconds (timing)
  serverless/fn_tco_usd            — derived = measured run TCO (timing)
  serverless/crossover_tb          — derived = modeled crossover dataset
  serverless/model_fn_total_at_1tb — derived = modeled serverless $ @1TB
  serverless/model_vm_total_at_1tb — derived = modeled cluster $ @1TB

The modeled rows and the request/invocation counts are deterministic
(pure arithmetic; memory-plane store, no faults) and GATED; the timing
rows are informational.

Standalone: PYTHONPATH=src python benchmarks/bench_serverless.py [--smoke|--full]
`run()` (the benchmarks/run.py entry) always uses smoke scale.
"""
from __future__ import annotations

import time

#: Regression gates for tools/bench_diff.py. All five are deterministic:
#: the model rows are closed-form arithmetic from pinned pricing
#: constants, and the count rows come from a fault-free run on the
#: in-memory FakeS3 plane (request totals are a function of the plan,
#: not of scheduling).
GATES = {
    "serverless/fn_invocations": {"tolerance": 0.0, "direction": "lower"},
    "serverless/fn_get_requests": {"tolerance": 0.02, "direction": "lower"},
    "serverless/fn_put_requests": {"tolerance": 0.02, "direction": "lower"},
    "serverless/crossover_tb": {"tolerance": 0.02, "direction": "lower"},
    "serverless/model_fn_total_at_1tb": {"tolerance": 0.02,
                                         "direction": "lower"},
    "serverless/model_vm_total_at_1tb": {"tolerance": 0.02,
                                         "direction": "lower"},
}


def run(full: bool = False):
    from repro.cloud import FakeS3Backend, InvocationDriver
    from repro.core.cost_model import (billed_gb_seconds, cluster_tco_at,
                                       serverless_crossover_tb,
                                       serverless_tco_at)
    from repro.core.external_sort import ExternalSortPlan
    from repro.data import gensort, valsort
    from repro.io.middleware import MetricsMiddleware

    # Geometry is PINNED to a 1-device mesh so the gated counts do not
    # depend on the ambient XLA device count: 4 map tasks x 16 output
    # partitions = 20 invocations at any worker count.
    plan = ExternalSortPlan(
        records_per_wave=1 << (14 if full else 13),
        num_rounds=2,
        reducers_per_worker=16,
        payload_words=2,
        impl="ref",
        input_records_per_partition=1 << (13 if full else 12),
        output_part_records=1 << 11,
        store_chunk_bytes=16 << 10,
        parallel_reducers=1,
        reduce_memory_budget_bytes=64 << 10,
    )
    total = plan.records_per_wave * 4  # 4 map waves
    store = MetricsMiddleware(FakeS3Backend(chunk_size=16 << 10))
    store.create_bucket("bench")
    in_ck, _ = gensort.write_to_store(
        store, "bench", plan.input_prefix, total,
        plan.input_records_per_partition, plan.payload_words)

    def layout():
        return [(m.key, m.etag, m.size, m.parts)
                for m in store.list_objects("bench", plan.output_prefix)]

    # Single-host reference layout: the byte-identity bar for every run.
    from repro.core.compat import make_mesh
    from repro.shuffle.sort import sort_shuffle_job
    mesh = make_mesh((1,), ("w",))
    sort_shuffle_job(store, "bench", mesh=mesh, axis_names="w",
                     plan=plan).run(workers=0)
    want = layout()
    num_invocations = 4 + len(want)

    def check(tag):
        assert layout() == want, f"{tag} changed output bytes"
        val = valsort.validate_from_store(store, "bench", plan.output_prefix,
                                          in_ck)
        assert val.ok and val.total_records == total, (tag, val)

    rows = []
    stats = gbs = tco = None
    for W in (1, 4):
        drv = InvocationDriver(store, "bench", plan=plan, workers=W,
                               mesh_devices=1)
        t0 = time.perf_counter()
        crep = drv.run()
        secs = time.perf_counter() - t0
        check(f"fn W={W}")
        assert not crep.failed_workers, crep.failed_workers
        invs = drv.invocations()
        assert len(invs) == num_invocations, (W, len(invs))
        rows.append((f"serverless/fn_w{W}", secs * 1e6, total / secs))
        if W == 1:
            stats = drv.request_stats()
            gbs = sum(billed_gb_seconds(p) for p in drv.profiles())
            tco = drv.tco(data_bytes=total * plan.record_bytes)
    rows.append(("serverless/fn_invocations", 0.0, float(num_invocations)))
    rows.append(("serverless/fn_get_requests", 0.0,
                 float(stats.get_requests)))
    rows.append(("serverless/fn_put_requests", 0.0,
                 float(stats.put_requests)))
    rows.append(("serverless/fn_gb_seconds", 0.0, gbs))
    rows.append(("serverless/fn_tco_usd", 0.0, tco.total))

    # -- the modeled crossover: where GB-seconds beat the hourly floor ----
    x = serverless_crossover_tb()
    fn1 = serverless_tco_at(1.0).total
    vm1 = cluster_tco_at(1.0).total
    # The bracket property IS the claim: serverless wins small datasets
    # (the cluster pays its provisioning floor regardless), the cluster
    # wins big ones (the GB-second premium compounds).
    assert serverless_tco_at(x / 4).total < cluster_tco_at(x / 4).total
    assert serverless_tco_at(x * 4).total > cluster_tco_at(x * 4).total
    rows.append(("serverless/crossover_tb", 0.0, x))
    rows.append(("serverless/model_fn_total_at_1tb", 0.0, fn1))
    rows.append(("serverless/model_vm_total_at_1tb", 0.0, vm1))
    return rows


def main():
    import argparse
    import os

    # The bench pins its own 1-device geometry; this only quiets jax on
    # hosts where XLA_FLAGS is already set for more.
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=1")

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--smoke", action="store_true",
                      help="small dataset (the default)")
    mode.add_argument("--full", action="store_true",
                      help="4x dataset; same invariants")
    args = ap.parse_args()
    t0 = time.perf_counter()
    print("name,us_per_call,derived")
    for name, us, derived in run(full=args.full):
        print(f"{name},{us:.3f},{derived:.6g}")
    print(f"# total {time.perf_counter() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
