"""Benchmark harness: one module per paper table/figure.

  bench_sort_stages     — Table 1 (job completion / stage breakdown)
  bench_cost_model      — Table 2 (TCO, reproduced to the cent)
  bench_pipeline_overlap— Figure 1 (stage overlap factor)
  bench_kernels         — §2.6 C++ sort/merge component as Pallas kernels
  bench_external_sort   — §2.3–2.5 out-of-core sort via the object store
  bench_store_faults    — §2.5 overlap efficiency under injected S3 faults
  bench_reduce_scaling  — §2.4 parallel-reduce scheduler x part fan-out
  bench_cluster_scaling — §2.6 cluster executor: worker count x failures
  bench_groupby         — shuffle-as-a-library generality: group-by
                          aggregation with a map-side combiner
  roofline              — §Roofline rows from the dry-run artifacts

Prints ``name,us_per_call,derived`` CSV.
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (bench_cluster_scaling, bench_cost_model,
                            bench_external_sort, bench_groupby,
                            bench_kernels, bench_pipeline_overlap,
                            bench_reduce_scaling, bench_sort_stages,
                            bench_store_faults, roofline)

    print("name,us_per_call,derived")
    for mod in (bench_cost_model, bench_sort_stages, bench_pipeline_overlap,
                bench_kernels, bench_external_sort, bench_store_faults,
                bench_reduce_scaling, bench_cluster_scaling, bench_groupby,
                roofline):
        try:
            for name, us, derived in mod.run():
                print(f"{name},{us:.3f},{derived:.6g}")
        except Exception:  # noqa: BLE001 — keep the harness running
            print(f"{mod.__name__},error,0", file=sys.stderr)
            traceback.print_exc()


if __name__ == "__main__":
    main()
