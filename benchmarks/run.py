"""Benchmark harness: one module per paper table/figure.

  bench_sort_stages     — Table 1 (job completion / stage breakdown)
  bench_cost_model      — Table 2 (TCO, reproduced to the cent)
  bench_pipeline_overlap— Figure 1 (stage overlap factor)
  bench_kernels         — §2.6 C++ sort/merge component as Pallas kernels
  bench_external_sort   — §2.3–2.5 out-of-core sort via the object store
  bench_store_faults    — §2.5 overlap efficiency under injected S3 faults
  bench_reduce_scaling  — §2.4 parallel-reduce scheduler x part fan-out
  bench_device_merge    — §2.4–2.5 device-resident merge sink + pipelined
                          map: critical-path merge rate vs numpy
  bench_cluster_scaling — §2.6 cluster executor: worker count x failures
  bench_skew            — skew-adaptive partitioning: sampled splitters
                          vs equal split, recursive dup-heavy sort
  bench_elastic         — §2.6 elastic fleet: process parallelism,
                          25%-kill recovery, straggler speculation
  bench_serverless      — serverless FunctionWorker mode: per-invocation
                          GB-second billing, TCO crossover vs the cluster
  bench_groupby         — shuffle-as-a-library generality: group-by
                          aggregation with a map-side combiner
  roofline              — §Roofline rows from the dry-run artifacts

Prints ``name,us_per_call,derived`` CSV, then a ``#``-prefixed summary
that distinguishes *skipped* benches (environment can't run them — raise
SkipBench, or an ImportError for an optional dependency) from *failed*
ones (the bench ran and broke). Only failures exit non-zero.

With ``--artifact DIR`` each bench also writes ``DIR/BENCH_<name>.json``:
the rows keyed by name, the bench's gate declarations (its module-level
``GATES`` dict, if any), and the ok/skip/fail status. The artifacts are
the persisted benchmark trajectory — tools/bench_diff.py compares a run's
artifacts against the committed baselines under benchmarks/baselines/
and fails CI on gated regressions. ``--only a,b`` restricts the run to
the named benches (short names, without the ``bench_`` prefix).
"""
from __future__ import annotations

import argparse
import importlib
import json
import os
import sys
import traceback

# Runnable as `python benchmarks/run.py` from anywhere: the bench
# modules import as `benchmarks.<name>`, which needs the repo root (this
# file's parent's parent) on sys.path.
_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _REPO_ROOT not in sys.path:
    sys.path.insert(0, _REPO_ROOT)

#: (short name, module) in execution order. Short names are what --only,
#: artifact filenames (BENCH_<short>.json), and the summary use.
BENCHES = [
    ("cost_model", "benchmarks.bench_cost_model"),
    ("sort_stages", "benchmarks.bench_sort_stages"),
    ("pipeline_overlap", "benchmarks.bench_pipeline_overlap"),
    ("kernels", "benchmarks.bench_kernels"),
    ("external_sort", "benchmarks.bench_external_sort"),
    ("store_faults", "benchmarks.bench_store_faults"),
    ("reduce_scaling", "benchmarks.bench_reduce_scaling"),
    ("device_merge", "benchmarks.bench_device_merge"),
    ("cluster_scaling", "benchmarks.bench_cluster_scaling"),
    ("skew", "benchmarks.bench_skew"),
    ("elastic", "benchmarks.bench_elastic"),
    ("serverless", "benchmarks.bench_serverless"),
    ("groupby", "benchmarks.bench_groupby"),
    ("roofline", "benchmarks.roofline"),
]


class SkipBench(Exception):
    """Raised by a bench that cannot run in this environment (missing
    accelerator, optional dependency, too few devices). A skip is not a
    failure: the summary reports it separately and the exit code stays 0."""


def run_one(short: str, module: str):
    """Execute one bench; returns (status, rows, gates, error_text)."""
    try:
        mod = importlib.import_module(module)
        rows = list(mod.run())
        return "ok", rows, dict(getattr(mod, "GATES", {})), None
    except SkipBench as e:
        return "skip", [], {}, str(e)
    except ImportError as e:  # optional dependency absent → environment
        return "skip", [], {}, f"import failed: {e}"
    except Exception as e:  # noqa: BLE001 — keep the harness running
        traceback.print_exc()
        return "fail", [], {}, f"{type(e).__name__}: {e}"


def write_artifact(outdir: str, short: str, status: str, rows, gates,
                   error: str | None) -> str:
    payload = {
        "schema": 1,
        "bench": short,
        "status": status,
        "rows": {name: {"us": us, "derived": derived}
                 for name, us, derived in rows},
        "gates": gates,
        "error": error,
    }
    path = os.path.join(outdir, f"BENCH_{short}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--only", default="",
                    help="comma-separated short bench names to run "
                         "(default: all)")
    ap.add_argument("--artifact", metavar="DIR", default=None,
                    help="write one BENCH_<name>.json per bench into DIR")
    args = ap.parse_args(argv)

    selected = [s for s in (p.strip() for p in args.only.split(",")) if s]
    known = {short for short, _ in BENCHES}
    unknown = [s for s in selected if s not in known]
    if unknown:
        ap.error(f"unknown bench(es) {unknown}; known: {sorted(known)}")
    todo = [(s, m) for s, m in BENCHES if not selected or s in selected]

    if args.artifact:
        os.makedirs(args.artifact, exist_ok=True)

    print("name,us_per_call,derived")
    summary: list[tuple[str, str, str]] = []  # (short, status, note)
    for short, module in todo:
        status, rows, gates, error = run_one(short, module)
        for name, us, derived in rows:
            print(f"{name},{us:.3f},{derived:.6g}")
        if args.artifact:
            write_artifact(args.artifact, short, status, rows, gates, error)
        summary.append((short, status, error or f"{len(rows)} rows"))

    # Summary: '#'-prefixed so CSV consumers keep parsing the stream.
    counts = {"ok": 0, "skip": 0, "fail": 0}
    print("#")
    print("# bench summary:")
    for short, status, note in summary:
        counts[status] += 1
        print(f"#   {status:<4} {short:<18} {note}")
    print(f"# {counts['ok']} ok, {counts['skip']} skipped, "
          f"{counts['fail']} failed")
    return 1 if counts["fail"] else 0


if __name__ == "__main__":
    sys.exit(main())
