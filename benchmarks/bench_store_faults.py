"""Overlap efficiency under injected store faults (paper §2.5's claim).

The paper asserts that pipelined task execution absorbs S3 latency and
throttling; PR 1 could only assert it too, because the emulated store
returned instantly. With the middleware stack the claim is *measurable*:
run the same out-of-core sort against a clean tiered store and against
latency/throttle-injected ones, and compare the wall-clock increase to
the stall time actually injected (StoreStats.stall_seconds sums injected
latency, bandwidth time, and retry backoff across threads).

  hidden fraction = 1 - (wall_faulty - wall_clean) / stall_injected

1.0 means the staging/pipelining layer hid every injected stall behind
compute or other I/O; 0.0 means every stall landed on the critical path.
(Run noise at smoke scale can push the fraction below 0 or above 1.)

Rows (name, us = wall time, derived):
  store_faults/<case>          — derived = hidden fraction
  store_faults/<case>_retries  — derived = retry count (throttle cases)

Standalone: PYTHONPATH=src python benchmarks/bench_store_faults.py [--smoke]
`run()` (the benchmarks/run.py entry) always uses smoke scale so the
whole harness stays inside the tier-1 time budget; --full sweeps more
records and a denser fault grid.
"""
from __future__ import annotations

import tempfile
import time


def _cases(full: bool):
    from repro.io.middleware import FaultProfile

    cases = [
        ("clean", None),
        ("latency", FaultProfile(latency_s=0.004, bandwidth_bps=150e6)),
        ("throttle", FaultProfile(get_rate=25.0, put_rate=20.0, burst=4.0)),
        ("latency+throttle", FaultProfile(
            latency_s=0.004, bandwidth_bps=150e6,
            get_rate=25.0, put_rate=20.0, burst=4.0)),
    ]
    if full:
        cases += [
            ("latency_10ms", FaultProfile(latency_s=0.010, bandwidth_bps=90e6)),
            ("throttle_tight", FaultProfile(get_rate=12.0, put_rate=10.0, burst=2.0)),
        ]
    return cases


def run(full: bool = False):
    import jax

    from repro.core.compat import make_mesh
    from repro.core.external_sort import ExternalSortPlan, external_sort
    from repro.data import gensort, valsort
    from repro.io.middleware import RetryPolicy
    from repro.io.tiered import tiered_cloudsort_store

    w = len(jax.devices())
    mesh = make_mesh((w,), ("w",))
    plan = ExternalSortPlan(
        records_per_wave=(1 << (13 if full else 12)) * w,
        num_rounds=2,
        reducers_per_worker=2,
        payload_words=4,
        impl="ref",
        input_records_per_partition=(1 << (12 if full else 11)) * w,
        output_part_records=1 << 12,
        # Small map chunks on purpose: enough ranged GETs that the token
        # bucket actually empties and per-request latency actually adds up
        # at smoke scale — otherwise every case degenerates to "clean".
        store_chunk_bytes=8 << 10,
        merge_chunk_bytes=8 << 10,
    )
    total = plan.records_per_wave * 4  # 4x out-of-core
    retry = RetryPolicy(max_attempts=10, base_delay_s=0.01, max_delay_s=0.5)

    rows = []
    wall_clean = None
    for name, faults in _cases(full):
        store = tiered_cloudsort_store(
            tempfile.mkdtemp(prefix=f"bench-faults-{name.replace('+', '_')}-"),
            spill_prefixes=(plan.spill_prefix,), faults=faults, retry=retry)
        store.create_bucket("bench")
        in_ck, _ = gensort.write_to_store(
            store, "bench", plan.input_prefix, total,
            plan.input_records_per_partition, plan.payload_words)

        t0 = time.perf_counter()
        rep = external_sort(store, "bench", mesh=mesh, axis_names="w", plan=plan)
        wall = time.perf_counter() - t0
        val = valsort.validate_from_store(store, "bench", plan.output_prefix, in_ck)
        assert val.ok, (name, val)

        # rep.tier_stats is a delta over the sort itself, so gensort's and
        # valsort's stall time is already excluded.
        durable = rep.tier_stats["durable"]
        stall = durable.stall_seconds
        if faults is None:
            wall_clean = wall
            hidden = 1.0
        else:
            hidden = (1.0 - (wall - wall_clean) / stall) if stall > 1e-9 else 1.0
        rows.append((f"store_faults/{name}", wall * 1e6, hidden))
        if faults is not None and (faults.get_rate or faults.put_rate):
            rows.append((f"store_faults/{name}_retries", wall * 1e6,
                         float(durable.retries)))
    return rows


def main():
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--smoke", action="store_true",
                      help="small dataset, 4 fault cases (the default)")
    mode.add_argument("--full", action="store_true",
                      help="larger dataset and a denser fault grid")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for name, us, derived in run(full=args.full):
        print(f"{name},{us:.3f},{derived:.6g}")


if __name__ == "__main__":
    main()
