"""Device-resident reduce merge + pipelined map: the ISSUE-7 proof.

The tentpole claim: the compute legs of the shuffle should be hidden
behind the storage legs (paper §2.4–§2.5 — overlap, not kernel speed,
is what makes the job I/O-bound). Two measurements against a
latency-injected store:

  * Reduce: the same sort with the numpy window merge
    (runtime.merge_fragments, on the scheduler thread between fetches)
    vs the device merge sink (shuffle/sort.DeviceMergeReduceOp —
    kernels/kway_merge on a one-thread stage, double-buffered so window
    i's merge+encode overlaps window i+1's ranged-GET round trip).
    The gated metric is merge-records/s ON THE CRITICAL PATH: records
    over the scheduler-visible reduce.merge span (window consume + the
    finalize tail — the time merging blocks the fetch loop). The numpy
    backend pays the full merge there; the sink leaves only the
    submit/handoff cost, so the merge leg nearly vanishes from the
    critical path — which is the paper's end state, a bandwidth-bound
    reduce. The merge MATH is honestly slower on the CPU backend (numpy
    stable argsort exploits the concatenated-runs structure; an
    oblivious merge network cannot — the micro rows record this), so
    end-to-end wall gains are modest and asserted only not to regress;
    on accelerators the stage math is fast too, and the same
    critical-path metric applies. Output bytes are asserted identical,
    and both backends issue the identical ranged-GET sequence (the
    gated `get_requests` row).
  * Map: plan.map_pipeline staggers decode -> device sort -> encode
    across waves; the staged span totals must exceed the map wall time
    (overlap evidence: the serialized sum would be the wall time of the
    monolithic schedule), and wave wait time must sit strictly below
    that serialized sum.

Rows (name, us, derived):

  device_merge/micro_numpy       — host argsort window merge, records/s
  device_merge/micro_network     — jit'd jnp merge network, records/s
  device_merge/reduce_numpy      — reduce wall us; derived = records/s
  device_merge/reduce_device     — reduce wall us; derived = records/s
  device_merge/merge_crit_numpy  — scheduler-visible merge us; records/s
  device_merge/merge_crit_device — scheduler-visible merge us; records/s
  device_merge/merge_stage_wall  — stage-thread merge+encode us (the
                                   overlapped work; informational)
  device_merge/device_speedup    — derived = critical-path merge
                                   records/s ratio, device over numpy
                                   (gated; acceptance bar >= 1.3x)
  device_merge/get_requests      — derived = GETs per sort (gated,
                                   deterministic, identical across backends)
  device_merge/map_overlap       — derived = staged-span serialized sum /
                                   map wall (> 1 means overlap)
  roofline rows (informational)  — achieved store bytes/s per phase as a
                                   fraction of the injected bandwidth
                                   (benchmarks/roofline.shuffle_phase_rows)

Standalone: PYTHONPATH=src python benchmarks/bench_device_merge.py [--smoke|--full]
`run()` (the benchmarks/run.py entry) always uses smoke scale.
"""
from __future__ import annotations

import os
import sys
import time

# Runnable standalone from anywhere: the roofline import below needs the
# repo root on sys.path (same bootstrap as benchmarks/run.py).
_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _REPO_ROOT not in sys.path:
    sys.path.insert(0, _REPO_ROOT)

#: CI gate declarations (tools/bench_diff.py). Only plan-deterministic or
#: generously-toleranced rows: get_requests is a pure function of the
#: plan; the critical-path speedup is timing-derived, so it gets a wide
#: band — the committed baseline documents the reference machine's
#: >= 1.3x and the gate catches the overlap collapsing entirely.
GATES = {
    "device_merge/device_speedup": {"direction": "higher",
                                    "tolerance": 0.25},
    "device_merge/get_requests": {"direction": "lower", "tolerance": 0.02},
}

#: Store injection: one ranged-GET round trip per emit cycle (the refill
#: pool issues the k GETs concurrently) sized to cover the stage's
#: window merge+encode, so the double-buffered sink can hide it.
LATENCY_S = 0.008
BANDWIDTH_BPS = 500e6


def _build_plan(full: bool):
    from repro.core.external_sort import ExternalSortPlan

    return ExternalSortPlan(
        # 4 waves x 2 partitions: each partition streams 4 runs; window
        # = 4 runs x 16384-record chunks = 64k records per emit cycle,
        # sized so the window merge+encode fits inside the injected GET
        # round trip — the regime where hiding it matters. Two long
        # partitions (not more, shorter ones) amortize the per-partition
        # open/finalize edges that no pipeline can hide.
        records_per_wave=1 << (19 if full else 18),
        num_rounds=2,
        reducers_per_worker=2,
        payload_words=2,
        impl="ref",
        input_records_per_partition=1 << 16,
        output_part_records=1 << 15,
        store_chunk_bytes=256 << 10,
        merge_chunk_bytes=256 << 10,  # 16384 records/run/cycle
        parallel_reducers=1,  # per-partition pipelining is the only overlap
        reduce_memory_budget_bytes=0,  # fixed chunks: identical GET sequence
    )


def _micro_rows(rows):
    """Window-merge microbench: the same (4 x 16384)-record emit window
    through the host argsort and the jit'd jnp network. On CPU the
    network is *slower* per window (the stable argsort exploits the
    sorted-runs structure; the oblivious network cannot) — recorded so
    the e2e speedup below is legible as overlap, not kernel speed."""
    import numpy as np

    from repro.kernels.kway_merge import merge_fragments_device
    from repro.shuffle.runtime import merge_fragments

    rng = np.random.default_rng(0)
    pw, frags = 2, []
    for _ in range(4):
        k = rng.integers(0, 2**32, 16384, dtype=np.uint32)
        i = rng.integers(0, 2**32, 16384, dtype=np.uint32)
        k64 = k.astype(np.uint64) << np.uint64(32) | i.astype(np.uint64)
        order = np.argsort(k64, kind="stable")
        p = rng.integers(0, 2**32, (16384, pw), dtype=np.uint32)
        frags.append((k[order], i[order], p[order], k64[order]))
    total = sum(f[0].size for f in frags)

    def timed(fn):
        fn()  # warm (jit compile / cache touch)
        t0 = time.perf_counter()
        for _ in range(5):
            fn()
        return (time.perf_counter() - t0) / 5

    t_np = timed(lambda: merge_fragments(frags, pw))
    t_net = timed(lambda: merge_fragments_device(frags, pw, impl="network"))
    rows.append(("device_merge/micro_numpy", t_np * 1e6, total / t_np))
    rows.append(("device_merge/micro_network", t_net * 1e6, total / t_net))


def run(full: bool = False):
    import dataclasses

    from benchmarks.roofline import shuffle_phase_rows
    from repro.core.compat import make_mesh
    from repro.data import gensort, valsort
    from repro.io.backends import MemoryBackend
    from repro.io.middleware import (FaultProfile, LatencyBandwidthMiddleware,
                                     MetricsMiddleware, TracingMiddleware)
    from repro.obs.events import Tracer
    from repro.shuffle.sort import sort_shuffle_job

    rows = []
    _micro_rows(rows)

    plan = _build_plan(full)
    mesh = make_mesh((1,), ("w",))
    total = plan.records_per_wave * 4  # 4 waves = 4 runs per partition

    # Deterministic stall injection, no jitter: byte-identity across
    # backends must be exact and the GET sequence reproducible.
    profile = FaultProfile(latency_s=LATENCY_S, bandwidth_bps=BANDWIDTH_BPS)
    base = LatencyBandwidthMiddleware(MemoryBackend(chunk_size=64 << 10),
                                      profile)
    base.create_bucket("bench")
    in_ck, _ = gensort.write_to_store(
        base.inner, "bench", plan.input_prefix, total,
        plan.input_records_per_partition, plan.payload_words)

    def sort_once(p):
        # Fresh tracer + middleware per run: per-phase byte counters and
        # request stats stay per-run (counters accumulate, and the
        # bytes/s gauges divide by THIS run's wall time).
        tracer = Tracer()
        store = MetricsMiddleware(TracingMiddleware(base, tracer))
        rep = sort_shuffle_job(store, "bench", mesh=mesh, axis_names="w",
                               plan=p, tracer=tracer).run(workers=0)
        val = valsort.validate_from_store(store, "bench", p.output_prefix,
                                          in_ck)
        assert val.ok, val
        layout = [(m.key, m.etag, m.size, m.parts)
                  for m in store.list_objects("bench", p.output_prefix)]
        return rep, layout

    # -- map pipelining: monolithic vs staged -----------------------------
    rep_mono, want = sort_once(dataclasses.replace(plan, map_pipeline=False))
    rep_pipe, layout = sort_once(plan)
    assert layout == want, "map_pipeline changed output bytes"
    ps = rep_pipe.phase_seconds
    serialized = (ps["map.decode"] + ps["map.device_sort"] + ps["map.encode"])
    wall = rep_pipe.map_seconds
    assert ps["map.wait"] < serialized, (
        f"wave wait {ps['map.wait']:.3f}s not below the serialized "
        f"stage sum {serialized:.3f}s — no pipelining evidence")
    assert wall < serialized, (
        f"map wall {wall:.3f}s >= serialized stage sum {serialized:.3f}s "
        "— decode/sort/encode did not overlap")
    rows.append(("device_merge/map_overlap", wall * 1e6, serialized / wall))

    # -- reduce: numpy merge vs device merge sink -------------------------
    # The pipelined numpy run above is the timed numpy baseline. Warm the
    # device path once untimed (jit-compiles every window shape the
    # tournament sees), then time it on identical data.
    p_dev = dataclasses.replace(plan, reduce_merge_impl="device")
    _, layout = sort_once(p_dev)
    assert layout == want, "device merge changed output bytes"
    rep_dev, layout = sort_once(p_dev)
    assert layout == want, "device merge changed output bytes (timed run)"
    stage_wall = rep_dev.phase_seconds.get("reduce.device_merge", 0)
    assert stage_wall > 0, rep_dev.phase_seconds

    # Critical-path merge rate: records over the scheduler-visible
    # reduce.merge span (consume + finalize tail). This is the gated
    # tentpole metric — the sink's whole point is taking the merge off
    # this path.
    crit_np = rep_pipe.phase_seconds["reduce.merge"]
    crit_dev = rep_dev.phase_seconds["reduce.merge"]
    rate_crit_np = total / crit_np
    rate_crit_dev = total / crit_dev
    speedup = rate_crit_dev / rate_crit_np
    rate_np = total / rep_pipe.reduce_seconds
    rate_dev = total / rep_dev.reduce_seconds
    gets_np = rep_pipe.stats.get_requests
    gets_dev = rep_dev.stats.get_requests
    assert gets_np == gets_dev, (
        f"device merge changed the request sequence: {gets_np} GETs "
        f"(numpy) vs {gets_dev} (device)")
    assert speedup >= 1.3, (
        f"critical-path merge rate gained only {speedup:.2f}x over the "
        "numpy merge (acceptance bar: 1.3x)")
    # Overlap must not LOSE end-to-end: the stage work the critical path
    # shed has to fit under the fetch stalls, not reappear as wall time.
    assert rep_dev.reduce_seconds <= rep_pipe.reduce_seconds * 1.05, (
        f"device merge reduce wall {rep_dev.reduce_seconds:.3f}s regressed "
        f"vs numpy {rep_pipe.reduce_seconds:.3f}s")
    rows.append(("device_merge/reduce_numpy",
                 rep_pipe.reduce_seconds * 1e6, rate_np))
    rows.append(("device_merge/reduce_device",
                 rep_dev.reduce_seconds * 1e6, rate_dev))
    rows.append(("device_merge/merge_crit_numpy", crit_np * 1e6,
                 rate_crit_np))
    rows.append(("device_merge/merge_crit_device", crit_dev * 1e6,
                 rate_crit_dev))
    rows.append(("device_merge/merge_stage_wall", stage_wall * 1e6,
                 total / stage_wall))
    rows.append(("device_merge/device_speedup", 0.0, speedup))
    rows.append(("device_merge/get_requests", 0.0, float(gets_np)))
    rows.extend(shuffle_phase_rows(rep_dev.metrics,
                                   store_bw_bps=BANDWIDTH_BPS,
                                   prefix="device_merge/device"))
    return rows


def main():
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--smoke", action="store_true",
                      help="small dataset (the default; what run() uses)")
    mode.add_argument("--full", action="store_true",
                      help="2x dataset, same 1.3x acceptance bar")
    args = ap.parse_args()
    t0 = time.perf_counter()
    print("name,us_per_call,derived")
    for name, us, derived in run(full=args.full):
        print(f"{name},{us:.3f},{derived:.6g}")
    print(f"# total {time.perf_counter() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
