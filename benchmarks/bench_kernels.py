"""Kernel microbenchmarks: the paper's C++ sort/merge component (§2.6)
re-benchmarked as Pallas kernels (interpret on CPU; Mosaic on real TPU)
against the XLA-native reference path.

Standalone: PYTHONPATH=src python benchmarks/bench_kernels.py [--smoke]
(the CI kernels-smoke job runs this; same rows as the benchmarks/run.py
entry — the flag only documents intent, the bench has one scale).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.kernels import ops


def _time(fn, *args):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    out = fn(*args)
    jax.block_until_ready(out)
    return time.perf_counter() - t0


def run():
    rng = np.random.default_rng(0)
    rows = []
    for n in (1 << 12, 1 << 15):
        k = rng.integers(0, 2**32, n, dtype=np.uint32)
        v = rng.integers(0, 2**32, n, dtype=np.uint32)
        for impl in ("ref", "pallas"):
            t = _time(jax.jit(lambda a, b, i=impl: ops.sort_kv(a, b, impl=i)),
                      k, v)
            rows.append((f"sort_{impl}_n{n}", t * 1e6, n / t))
    # merge tournament
    runs_k = np.sort(rng.integers(0, 2**32, (8, 1 << 12), dtype=np.uint32), -1)
    runs_v = np.zeros_like(runs_k)
    for impl in ("ref", "pallas"):
        t = _time(jax.jit(lambda a, b, i=impl: ops.kway_merge(a, b, impl=i)),
                  runs_k, runs_v)
        rows.append((f"kway8_{impl}", t * 1e6, runs_k.size / t))
    # partition
    sk = np.sort(rng.integers(0, 2**32, (4, 1 << 14), dtype=np.uint32), -1)
    bounds = np.sort(rng.integers(0, 2**32, 255, dtype=np.uint32))
    for impl in ("ref", "pallas"):
        t = _time(jax.jit(lambda a, b, i=impl: ops.partition_offsets(a, b, impl=i)),
                  sk, bounds)
        rows.append((f"partition_{impl}", t * 1e6, sk.size / t))
    return rows


def main():
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="single-scale run (the only scale; for CI symmetry "
                         "with the other benches)")
    ap.parse_args()
    t0 = time.perf_counter()
    print("name,us_per_call,derived")
    for name, us, derived in run():
        print(f"{name},{us:.3f},{derived:.6g}")
    print(f"# total {time.perf_counter() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
