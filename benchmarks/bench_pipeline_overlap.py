"""Paper Figure 1: pipelined utilization.

The paper's claim: network transfer, disk I/O and CPU work overlap — total
time ~= max(stage times), not their sum. We verify the SPMD analogue: the
round-pipelined streaming sort's wall time versus running its stages
serially (sort all, exchange all, merge all). Measured on the 8-device
host mesh; the ratio (serial / pipelined) is the overlap factor.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.exoshuffle import ShuffleConfig
from repro.core.sortlib import merge_runs, sort_records
from repro.core.streaming import streaming_sort
from repro.data import gensort


def run(n_records: int = 1 << 17, rounds: int = 8):
    if len(jax.devices()) < 8:
        # the overlap measurement needs the 8-device mesh; report the
        # single-device stage sum instead (still one row per figure line)
        keys, ids = gensort.gen_keys(0, n_records)
        t0 = time.perf_counter()
        sk, sv = jax.block_until_ready(sort_records(keys, ids, impl="ref"))
        t_sort = time.perf_counter() - t0
        runs_k = jnp.sort(sk.reshape(rounds, -1), axis=-1)
        t0 = time.perf_counter()
        jax.block_until_ready(merge_runs(runs_k, sv.reshape(rounds, -1),
                                         impl="ref"))
        t_merge = time.perf_counter() - t0
        return [
            ("stage_sort", t_sort * 1e6, n_records / max(t_sort, 1e-9)),
            ("stage_merge", t_merge * 1e6, n_records / max(t_merge, 1e-9)),
            ("overlap_factor", 0.0, 1.0),
        ]

    from repro.core.compat import make_mesh

    mesh = make_mesh((8,), ("w",))
    keys, ids = gensort.gen_keys(0, n_records)
    cfg = ShuffleConfig(num_workers=8, impl="ref", num_rounds=rounds)

    pipelined = jax.jit(
        lambda k, i: streaming_sort(k, i, mesh=mesh, axis_names="w",
                                    num_rounds=rounds, cfg=cfg)
    )
    jax.block_until_ready(pipelined(keys, ids))
    t0 = time.perf_counter()
    jax.block_until_ready(pipelined(keys, ids))
    t_pipe = time.perf_counter() - t0

    one_round = jax.jit(
        lambda k, i: streaming_sort(k, i, mesh=mesh, axis_names="w",
                                    num_rounds=1,
                                    cfg=ShuffleConfig(num_workers=8,
                                                      impl="ref"))
    )
    jax.block_until_ready(one_round(keys, ids))
    t0 = time.perf_counter()
    jax.block_until_ready(one_round(keys, ids))
    t_one = time.perf_counter() - t0

    return [
        ("pipelined_rounds", t_pipe * 1e6, n_records / t_pipe),
        ("single_round", t_one * 1e6, n_records / t_one),
        ("overlap_factor", 0.0, t_one / t_pipe),
    ]
