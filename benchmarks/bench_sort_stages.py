"""Paper Table 1: job-completion-time breakdown (map+shuffle vs reduce).

On this CPU container we measure the CPU-scale smoke sort's per-stage
wall time and throughput (records/s), then project the paper's 100 TB /
40-node setting with the TPU time model (core/cost_model.py) — reported
side by side with the paper's measured 3508 s / 1870 s / 5378 s.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.cost_model import TpuPodCostParams, tpu_sort_time_model
from repro.core.exoshuffle import ShuffleConfig, _shuffle_round
from repro.core.sortlib import merge_runs, partition_sorted, sort_records
from repro.data import gensort


def _time(fn, *args, repeats=3):
    fn(*args)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeats


def run(n_records: int = 1 << 17, impl: str = "ref"):
    rows = []
    keys, ids = gensort.gen_keys(0, n_records)
    cfg = ShuffleConfig(num_workers=8, impl=impl)

    # stage timings on a single worker's share (the paper reports per-task
    # averages: map 24 s, shuffle 7 s, merge 17 s, reduce 22 s)
    sort_t = _time(jax.jit(lambda k, v: sort_records(k, v, impl=impl)), keys, ids)
    rows.append(("map_sort", sort_t * 1e6, n_records / sort_t))

    sk, sv = sort_records(keys, ids, impl=impl)
    wb = cfg.keyspace.worker_boundaries()
    part_t = _time(
        jax.jit(lambda k: partition_sorted(k, wb, impl=impl)), sk
    )
    rows.append(("map_partition", part_t * 1e6, n_records / part_t))

    runs_k = sk.reshape(8, -1)
    runs_v = sv.reshape(8, -1)
    # rows of reshape are each sorted slices? build sorted runs properly
    runs_k = jnp.sort(runs_k, axis=-1)
    merge_t = _time(
        jax.jit(lambda k, v: merge_runs(k, v, impl=impl)), runs_k, runs_v
    )
    rows.append(("merge_8way", merge_t * 1e6, n_records / merge_t))

    # TPU-pod projection of the 100 TB job vs the paper's Table 1
    for mode in ("through", "late"):
        t = tpu_sort_time_model(100e12, TpuPodCostParams(), payload_mode=mode)
        rows.append((f"tpu100tb_{mode}_total_s", t["t_total_s"] * 1e6,
                     t["job_hours"]))
    rows.append(("paper_total_s", 5378 * 1e6, 5378 / 3600))
    return rows
