"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from
results/dryrun.jsonl. Usage:

  PYTHONPATH=src python -m benchmarks.make_experiments [results/dryrun.jsonl]

Prints markdown to stdout; EXPERIMENTS.md embeds the output.
"""
from __future__ import annotations

import json
import sys

from benchmarks import roofline


def _fmt_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB"):
        if abs(n) < 1024:
            return f"{n:.1f}{unit}"
        n /= 1024
    return f"{n:.1f}TiB"


def _fmt_ms(s: float) -> str:
    return f"{s * 1e3:.2f}"


def dryrun_table(path: str, mesh: str, tag: str = "baseline") -> str:
    rows = []
    for line in open(path):
        r = json.loads(line)
        if r.get("mesh") != mesh or r.get("tag", "baseline") != tag:
            continue
        rows.append(r)
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    out = [
        "| arch | shape | ok | peak GiB/dev | flops/dev | HLO bytes/dev |"
        " collective bytes/dev | compile s |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        coll = (r.get("collective_bytes") or {}).get("total", 0)
        out.append(
            f"| {r['arch']} | {r['shape']} | {'Y' if r.get('ok') else 'FAIL'}"
            f" | {r.get('peak_bytes_per_dev', 0) / 2**30:.2f}"
            f" | {r.get('flops', 0):.3e} | {r.get('bytes_accessed', 0):.3e}"
            f" | {_fmt_bytes(coll)} | {r.get('compile_s', 0):.0f} |"
        )
    return "\n".join(out)


def roofline_table(path: str, mesh: str = "16x16", tag: str = "baseline") -> str:
    rows = roofline.table(path, tag=tag, mesh=mesh)
    out = [
        "| arch | shape | compute ms | memory ms | collective ms | dominant |"
        " useful flops ratio | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_ms(r['t_compute_s'])}"
            f" | {_fmt_ms(r['t_memory_s'])} | {_fmt_ms(r['t_collective_s'])}"
            f" | **{r['dominant']}** | {r['useful_flop_ratio']:.3f}"
            f" | {r['roofline_fraction']:.3f} |"
        )
    return "\n".join(out)


def collective_breakdown(path: str, mesh: str, tag: str = "baseline") -> str:
    rows = []
    for line in open(path):
        r = json.loads(line)
        if r.get("mesh") != mesh or r.get("tag", "baseline") != tag:
            continue
        if not r.get("ok"):
            continue
        rows.append(r)
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    ops = ["all-gather", "all-reduce", "reduce-scatter", "all-to-all",
           "collective-permute"]
    out = [
        "| arch | shape | " + " | ".join(ops) + " |",
        "|---|---|" + "---|" * len(ops),
    ]
    for r in rows:
        cb = r.get("collective_bytes") or {}
        cells = " | ".join(_fmt_bytes(cb.get(o, 0)) for o in ops)
        out.append(f"| {r['arch']} | {r['shape']} | {cells} |")
    return "\n".join(out)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun.jsonl"
    print("### Dry-run — single pod (16x16 = 256 chips)\n")
    print(dryrun_table(path, "16x16"))
    print("\n### Dry-run — multi-pod (2x16x16 = 512 chips)\n")
    print(dryrun_table(path, "2x16x16"))
    print("\n### Roofline — single pod baseline\n")
    print(roofline_table(path))
    print("\n### Collective-bytes breakdown (per device, 16x16)\n")
    print(collective_breakdown(path, "16x16"))


if __name__ == "__main__":
    main()
