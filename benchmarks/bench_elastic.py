"""Elastic fleet: process parallelism, recovery cost, speculation win.

The thread fleet shares one JAX runtime — one device mesh, one GIL — so
its scaling win is I/O overlap only. The elastic driver's ProcessWorker
gives each worker its own interpreter and runtime, which is the paper's
actual deployment shape (§2.6: one worker process per node). This bench
measures the three claims the elastic layer makes:

  * process parallelism: the same dataset sorted by a thread fleet and
    a PROCESS fleet, each at W in {1, 4} (workers pre-spawned and
    warmed — child runtime up, mesh built — before the clock starts).
    The acceptance bar compares SPEEDUPS, not absolute times: at --full
    the process fleet's W=4-over-W=1 speedup must beat the thread
    fleet's — four interpreters scale where four threads time-slice one
    GIL. (Absolute process time carries per-child IPC + protocol cost
    that says nothing about scaling.) The bar is enforced only when
    os.cpu_count() >= 4: a single-core host time-slices BOTH fleets on
    one core, so neither can scale and the ratio measures IPC overhead
    only (elastic/speedup_gate_enforced records which mode ran). Smoke
    only reports the ratios;
  * recovery: a W=4 process run with 25% of the fleet killed mid-job
    (die_after_tasks, then spill-tier loss + lineage re-execution) must
    complete byte-identical; derived = recovery overhead ratio vs the
    clean process run;
  * speculation: one straggler worker (latency-injected store view),
    speculation off vs on; at --full speculation must win >= 1.2x
    end-to-end (smoke: must not lose by more than noise, reported).

Invariants on every case: output byte/etag-identical to the single-host
reference, valsort-clean.

Rows (name, us = end-to-end wall time, derived):

  elastic/thread_w{W}             — derived = end-to-end records/s
  elastic/process_w{W}            — derived = end-to-end records/s
  elastic/speedup_thread_w4       — derived = thread W=1 / W=4 wall ratio
  elastic/speedup_process_w4      — derived = process W=1 / W=4 wall ratio
  elastic/speedup_process_vs_thread_w4 — derived = speedup ratio
  elastic/speedup_gate_enforced   — derived = 1 iff the host had >= 4 cores
  elastic/recovery_kill25pct      — derived = overhead ratio vs clean
  elastic/speculation_off|on      — derived = end-to-end records/s
  elastic/speculation_speedup     — derived = off/on wall-time ratio

All rows are timing-dependent — no GATES; the asserts below are the
acceptance contract.

Standalone: PYTHONPATH=src python benchmarks/bench_elastic.py [--smoke|--full]
`run()` (the benchmarks/run.py entry) always uses smoke scale.
"""
from __future__ import annotations

import os
import tempfile
import time


def run(full: bool = False):
    import jax

    from repro.core.compat import make_mesh
    from repro.core.external_sort import ExternalSortPlan
    from repro.data import gensort, valsort
    from repro.io.middleware import FaultProfile, LatencyBandwidthMiddleware
    from repro.io.object_store import ObjectStore
    from repro.shuffle.elastic import FleetPlan
    from repro.shuffle.executor import ThreadWorker
    from repro.shuffle.procworker import ProcessWorker
    from repro.shuffle.sort import sort_shuffle_job

    w = len(jax.devices())
    mesh = make_mesh((w,), ("w",))
    plan = ExternalSortPlan(
        records_per_wave=(1 << (13 if full else 12)) * w,
        num_rounds=2,
        reducers_per_worker=8,  # >= 8 partitions even on one device
        payload_words=2,
        impl="ref",
        input_records_per_partition=(1 << (12 if full else 11)) * w,
        output_part_records=1 << 10,
        store_chunk_bytes=16 << 10,
        parallel_reducers=2,
        reduce_memory_budget_bytes=256 << 10,
    )
    total = plan.records_per_wave * 8  # 8 map waves at any device count
    # Process workers need a store both sides can open: filesystem plane.
    root = tempfile.mkdtemp(prefix="bench-elastic-")
    store = ObjectStore(root)
    store.create_bucket("bench")
    in_ck, _ = gensort.write_to_store(
        store, "bench", plan.input_prefix, total,
        plan.input_records_per_partition, plan.payload_words)

    def layout():
        return [(m.key, m.etag, m.size, m.parts)
                for m in store.list_objects("bench", plan.output_prefix)]

    def job(st=None):
        return sort_shuffle_job(st or store, "bench", mesh=mesh,
                                axis_names="w", plan=plan)

    job().run(workers=0)  # single-host reference layout
    want = layout()

    def check(tag):
        assert layout() == want, f"{tag} changed output bytes"
        val = valsort.validate_from_store(store, "bench", plan.output_prefix,
                                          in_ck)
        assert val.ok and val.total_records == total, (tag, val)

    rows = []

    # -- thread fleet: the shared-runtime baseline -------------------------
    thread_secs = {}
    for W in (1, 4):
        crew = [ThreadWorker(f"w{i}", store) for i in range(W)]
        t0 = time.perf_counter()
        crep = job().run(worker_list=crew, fleet=FleetPlan())
        thread_secs[W] = time.perf_counter() - t0
        check(f"thread W={W}")
        assert not crep.failed_workers
        rows.append((f"elastic/thread_w{W}", thread_secs[W] * 1e6,
                     total / thread_secs[W]))

    # -- process fleet: own runtimes, spawned + warmed before timing ------
    def pworkers(n, **kw_by_name):
        # mesh_devices=w: the children must build the SAME partition
        # geometry as the parent's reference run.
        return [ProcessWorker(f"p{i}", store=store, bucket="bench",
                              plan=plan, mesh_devices=w,
                              **kw_by_name.get(f"p{i}", {}))
                for i in range(n)]

    proc_secs = {}
    for W in (1, 4):
        crew = pworkers(W)
        try:
            t0 = time.perf_counter()
            crep = job().run(worker_list=crew, fleet=FleetPlan())
            proc_secs[W] = time.perf_counter() - t0
        finally:
            for wk in crew:
                wk.close()
        check(f"process W={W}")
        assert not crep.failed_workers
        rows.append((f"elastic/process_w{W}", proc_secs[W] * 1e6,
                     total / proc_secs[W]))
    thread_speedup = thread_secs[1] / thread_secs[4]
    proc_speedup = proc_secs[1] / proc_secs[4]
    ratio = proc_speedup / thread_speedup
    # The scaling bar is physical: four interpreters can only beat four
    # threads time-slicing one GIL when the host HAS cores to scale
    # onto. On a single-core runner both fleets time-slice the same
    # core (thread speedup pins to ~1.0) and the ratio measures pure
    # IPC overhead, so enforcing it there gates noise, not scaling.
    cores = os.cpu_count() or 1
    if full and cores >= 4:
        # The acceptance bar: four interpreters must SCALE better than
        # four threads time-slicing one GIL-bound runtime.
        assert ratio > 1.0, (
            f"process W=4 speedup {proc_speedup:.2f}x <= thread W=4 "
            f"speedup {thread_speedup:.2f}x at --full ({cores} cores)")
    rows.append(("elastic/speedup_thread_w4", 0.0, thread_speedup))
    rows.append(("elastic/speedup_process_w4", 0.0, proc_speedup))
    rows.append(("elastic/speedup_process_vs_thread_w4", 0.0, ratio))
    rows.append(("elastic/speedup_gate_enforced", 0.0,
                 1.0 if cores >= 4 else 0.0))

    # -- recovery: kill 25% of the process fleet mid-job ------------------
    crew = pworkers(4, p0={"die_after_tasks": 3})
    try:
        t0 = time.perf_counter()
        crep = job().run(worker_list=crew, fleet=FleetPlan())
        kill_secs = time.perf_counter() - t0
    finally:
        for wk in crew:
            wk.close()
    check("process W=4 kill 25%")
    assert crep.failed_workers == ["p0"], crep.failed_workers
    assert crep.reexecuted_tasks >= 1, crep
    rows.append(("elastic/recovery_kill25pct", kill_secs * 1e6,
                 kill_secs / proc_secs[4]))

    # -- speculation: one straggler host, off vs on -----------------------
    slow = LatencyBandwidthMiddleware(store,
                                      FaultProfile(latency_s=0.25))

    def straggler_crew():
        return [ThreadWorker("w0", store), ThreadWorker("w1", store),
                ThreadWorker("slow", slow)]

    spec_secs = {}
    for mode, fleet in (
            ("off", FleetPlan()),
            ("on", FleetPlan(speculation=True, speculation_min_samples=3,
                             speculation_quantile=0.5,
                             speculation_factor=2.0,
                             speculation_min_s=0.1))):
        t0 = time.perf_counter()
        crep = job().run(worker_list=straggler_crew(), fleet=fleet)
        spec_secs[mode] = time.perf_counter() - t0
        check(f"speculation {mode}")
        assert not crep.failed_workers
        if mode == "on":
            assert crep.speculated_tasks >= 1, crep
        rows.append((f"elastic/speculation_{mode}", spec_secs[mode] * 1e6,
                     total / spec_secs[mode]))
    spec_ratio = spec_secs["off"] / spec_secs["on"]
    if full:
        assert spec_ratio >= 1.2, (
            f"speculation won only {spec_ratio:.2f}x at --full (bar: 1.2x)")
    rows.append(("elastic/speculation_speedup", 0.0, spec_ratio))
    return rows


def main():
    import argparse

    # Standalone runs get the 8-device host mesh (must precede the first
    # jax import); under benchmarks/run.py the ambient device count wins.
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--smoke", action="store_true",
                      help="small dataset, ratios reported not gated "
                           "(the default)")
    mode.add_argument("--full", action="store_true",
                      help="larger dataset; enforce process > thread and "
                           "speculation >= 1.2x")
    args = ap.parse_args()
    t0 = time.perf_counter()
    print("name,us_per_call,derived")
    for name, us, derived in run(full=args.full):
        print(f"{name},{us:.3f},{derived:.6g}")
    print(f"# total {time.perf_counter() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
