"""Reduce-scheduler scaling: parallel merges x multipart part fan-out.

The paper's reduce pass (§2.4) runs all output partitions concurrently
and keeps every core and the S3 uplink busy; this benchmark measures how
much of that the driver's scheduler actually recovers. The same dataset
is sorted with a sweep over plan.parallel_reducers (concurrent streaming
k-way merges) and plan.part_upload_fanout (out-of-order part-indexed
multipart uploads per partition) against a latency-injected store — the
regime where scheduling freedom pays, since a sequential reduce
serializes every request RTT onto the critical path.

Invariants asserted on every case (the ISSUE-3 acceptance contract):
  * output partitions are byte-identical across all schedules (same CRC
    etags, sizes, and part counts — parallelism never changes bytes);
  * measured all-reducer peak merge memory <= reduce_memory_budget_bytes.

The merge-chunk cap is pinned below the budget share so every case issues
the IDENTICAL ranged-GET sequence — the sweep isolates scheduling, not
chunking. Rows (name, us = reduce-phase wall time, derived):

  reduce_scaling/p{P}_f{F}        — derived = reduce-phase records/s
  reduce_scaling/speedup_p4_vs_p1 — derived = records/s ratio (>= 1.5 is
                                    the acceptance bar; gated)
  reduce_scaling/peak_over_budget — derived = measured peak / budget (<= 1)
  reduce_scaling/get_requests     — derived = GETs per sort (gated,
                                    deterministic, identical across cases)

Standalone: PYTHONPATH=src python benchmarks/bench_reduce_scaling.py [--smoke|--full]
`run()` (the benchmarks/run.py entry) always uses smoke scale.
"""
from __future__ import annotations

import time

#: CI gate declarations (tools/bench_diff.py). get_requests is a pure
#: function of the plan; the scheduling speedup is timing-derived and
#: gets a wide band — the gate catches parallelism collapsing, not
#: runner noise.
GATES = {
    "reduce_scaling/speedup_p4_vs_p1": {"direction": "higher",
                                        "tolerance": 0.4},
    "reduce_scaling/get_requests": {"direction": "lower", "tolerance": 0.02},
}


def _build_store(latency_s: float, bandwidth_bps: float):
    # Deterministic stall injection (no jitter, no throttle/retry
    # randomness): byte-identity across schedules must be exact, and the
    # memory data plane keeps the bench latency-dominated on any machine.
    from repro.io.backends import MemoryBackend
    from repro.io.middleware import (FaultProfile, LatencyBandwidthMiddleware,
                                     MetricsMiddleware)

    profile = FaultProfile(latency_s=latency_s, bandwidth_bps=bandwidth_bps)
    return MetricsMiddleware(
        LatencyBandwidthMiddleware(MemoryBackend(chunk_size=64 << 10), profile))


def run(full: bool = False):
    import dataclasses

    import jax

    from repro.core.compat import make_mesh
    from repro.core.external_sort import ExternalSortPlan, external_sort
    from repro.data import gensort, valsort

    w = len(jax.devices())
    mesh = make_mesh((w,), ("w",))
    # Budget sized so budget / (P_max x runs) never drops below the
    # merge-chunk cap for any swept P — every case then issues the
    # identical ranged-GET sequence (--full sweeps P=8, hence 2x).
    budget = (128 if full else 64) << 10
    plan = ExternalSortPlan(
        records_per_wave=(1 << (13 if full else 12)) * w,
        num_rounds=2,
        reducers_per_worker=8,  # >= 8 partitions even on one device
        payload_words=4,
        impl="ref",
        input_records_per_partition=(1 << (12 if full else 11)) * w,
        output_part_records=1 << 10,  # several parts per partition
        store_chunk_bytes=32 << 10,
        # Chunk cap below budget/(P_max x runs): every case fetches the
        # same chunks, so the sweep measures scheduling alone.
        merge_chunk_bytes=4 << 10,
        reduce_memory_budget_bytes=budget,
    )
    total = plan.records_per_wave * 4  # 4 waves = 4 runs per reducer
    cases = [(1, 2), (2, 2), (4, 2), (4, 1), (4, 4)]
    if full:
        cases.append((8, 4))

    store = _build_store(latency_s=0.002, bandwidth_bps=200e6)
    store.create_bucket("bench")
    in_ck, _ = gensort.write_to_store(
        store, "bench", plan.input_prefix, total,
        plan.input_records_per_partition, plan.payload_words)

    rows, rates, layouts, worst_peak_frac = [], {}, {}, 0.0
    gets = {}
    for par, fanout in cases:
        p = dataclasses.replace(plan, parallel_reducers=par,
                                part_upload_fanout=fanout)
        gets0 = store.stats.get_requests
        rep = external_sort(store, "bench", mesh=mesh, axis_names="w", plan=p)
        gets[(par, fanout)] = store.stats.get_requests - gets0
        val = valsort.validate_from_store(
            store, "bench", p.output_prefix, in_ck)
        assert val.ok, ((par, fanout), val)
        assert rep.reduce_peak_merge_bytes <= budget, (rep, budget)
        worst_peak_frac = max(worst_peak_frac,
                              rep.reduce_peak_merge_bytes / budget)
        layouts[(par, fanout)] = [
            (m.key, m.etag, m.size, m.parts)
            for m in store.list_objects("bench", p.output_prefix)]
        rate = total / rep.reduce_seconds
        rates[(par, fanout)] = rate
        rows.append((f"reduce_scaling/p{par}_f{fanout}",
                     rep.reduce_seconds * 1e6, rate))

    # Byte-identity across every schedule: same keys, etags, part layout.
    want = layouts[cases[0]]
    for case, got in layouts.items():
        assert got == want, f"schedule {case} changed output bytes"

    speedup = rates[(4, 2)] / rates[(1, 2)]
    # The acceptance bar (1.5x) is part of the benchmark's contract under
    # --full; the smoke run — which CI executes on shared, noisy runners —
    # asserts only the direction (parallelism must not lose) and reports
    # the ratio, so timing noise can't fail a push with no regression.
    bar = 1.5 if full else 1.05
    assert speedup >= bar, (
        f"parallel_reducers=4 gained only {speedup:.2f}x over sequential "
        f"reduce (bar: {bar}x)")
    rows.append(("reduce_scaling/speedup_p4_vs_p1", 0.0, speedup))
    rows.append(("reduce_scaling/peak_over_budget", 0.0, worst_peak_frac))
    # The identical-GET-sequence contract, as a gated row: validation
    # reads vary with valsort sampling, but the sort's own request count
    # is a pure function of the plan — any drift is a chunking change.
    want_gets = gets[cases[0]]
    assert all(g == want_gets for g in gets.values()), gets
    rows.append(("reduce_scaling/get_requests", 0.0, float(want_gets)))
    return rows


def main():
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--smoke", action="store_true",
                      help="small dataset, 5 schedule cases (the default)")
    mode.add_argument("--full", action="store_true",
                      help="larger dataset, adds the p8_f4 case")
    args = ap.parse_args()
    t0 = time.perf_counter()
    print("name,us_per_call,derived")
    for name, us, derived in run(full=args.full):
        print(f"{name},{us:.3f},{derived:.6g}")
    print(f"# total {time.perf_counter() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
