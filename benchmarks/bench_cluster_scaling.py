"""Cluster-executor scaling: worker count x injected worker failures.

The paper's headline run is a 40-worker cluster whose fault tolerance
comes from the application rescheduling its own map/reduce tasks (§2.4,
§2.6); core/cluster.py emulates that executor on one host. This benchmark
measures what the emulation actually buys against a latency-injected
store — the regime where per-worker I/O overlap pays, since the device
mesh itself is one shared (lock-serialized) resource:

  * scaling: the same dataset sorted at W in {1, 2, 4} emulated workers.
    More workers overlap more map downloads/spills and run more
    concurrent reduce merges, so end-to-end records/s must IMPROVE from
    W=1 to W=4 (>= 1.05x smoke / >= 1.4x --full: CI runners are noisy,
    the full bar is the real claim);
  * fault recovery: a W=4 run with one worker killed mid-job
    (FaultyWorker) must still complete, report how many tasks were
    re-executed on the survivors, and produce BYTE-IDENTICAL output.

Invariants asserted on every case:
  * output partitions byte-identical (keys, CRC etags, sizes, part
    layout) across every worker count, under failure, and vs. the
    single-host driver;
  * valsort-clean (ordering + order-independent checksum);
  * measured all-reducer peak merge memory <= the global budget (the
    adaptive governor's cluster-wide guarantee).

Rows (name, us = end-to-end wall time, derived):

  cluster_scaling/w{W}             — derived = end-to-end records/s
  cluster_scaling/speedup_w4_vs_w1 — derived = records/s ratio
  cluster_scaling/failover_w4_kill1— derived = re-executed task count

Standalone: PYTHONPATH=src python benchmarks/bench_cluster_scaling.py [--smoke|--full]
`run()` (the benchmarks/run.py entry) always uses smoke scale.
"""
from __future__ import annotations

import time

#: Regression gates for tools/bench_diff.py. The single-host reference
#: run's request counts are deterministic (memory backend, no retries);
#: the failover re-execution count and throughputs depend on timing and
#: runner load, so they stay informational.
GATES = {
    "cluster_scaling/ref_get_requests": {"tolerance": 0.25,
                                         "direction": "lower"},
    "cluster_scaling/ref_put_requests": {"tolerance": 0.25,
                                         "direction": "lower"},
}


def _build_store(latency_s: float, bandwidth_bps: float):
    # Deterministic stall injection (no jitter/throttle randomness): the
    # byte-identity assertions must compare runs on identical data, and
    # the memory data plane keeps the bench latency-dominated anywhere.
    from repro.io.backends import MemoryBackend
    from repro.io.middleware import (FaultProfile, LatencyBandwidthMiddleware,
                                     MetricsMiddleware)

    profile = FaultProfile(latency_s=latency_s, bandwidth_bps=bandwidth_bps)
    return MetricsMiddleware(
        LatencyBandwidthMiddleware(MemoryBackend(chunk_size=64 << 10), profile))


def run(full: bool = False):
    import jax

    from repro.core.cluster import ClusterExecutor, ClusterPlan
    from repro.core.compat import make_mesh
    from repro.core.external_sort import ExternalSortPlan, external_sort
    from repro.data import gensort, valsort

    w = len(jax.devices())
    mesh = make_mesh((w,), ("w",))
    plan = ExternalSortPlan(
        records_per_wave=(1 << (13 if full else 12)) * w,
        num_rounds=2,
        reducers_per_worker=8,  # >= 8 partitions even on one device
        payload_words=4,
        impl="ref",
        input_records_per_partition=(1 << (12 if full else 11)) * w,
        output_part_records=1 << 10,
        store_chunk_bytes=8 << 10,  # several latency-paying GETs per wave
        # Chunk cap pinned below budget / (slots_max x runs): every worker
        # count fetches the same chunk sequence, so the sweep measures
        # scheduling (I/O overlap across workers), not chunk-size effects.
        merge_chunk_bytes=4 << 10,
        parallel_reducers=2,  # per worker; cluster-wide = W x this
        reduce_memory_budget_bytes=256 << 10,  # slots_max(8) x runs(8) x cap
    )
    total = plan.records_per_wave * 8  # 8 waves = 8 runs per reducer
    budget = plan.reduce_memory_budget_bytes

    store = _build_store(latency_s=0.004, bandwidth_bps=200e6)
    store.create_bucket("bench")
    in_ck, _ = gensort.write_to_store(
        store, "bench", plan.input_prefix, total,
        plan.input_records_per_partition, plan.payload_words)

    def layout():
        return [(m.key, m.etag, m.size, m.parts)
                for m in store.list_objects("bench", plan.output_prefix)]

    # Single-host reference: the byte ground truth every cluster run
    # (and the failure run) must reproduce exactly.
    ref = external_sort(store, "bench", mesh=mesh, axis_names="w", plan=plan)
    want = layout()
    val = valsort.validate_from_store(store, "bench", plan.output_prefix, in_ck)
    assert val.ok, val

    rows, rates = [], {}
    # The reference run's store traffic: deterministic on the memory
    # backend, so these two rows are the gated regression canaries.
    rows.append(("cluster_scaling/ref_get_requests", 0.0,
                 float(ref.stats.get_requests)))
    rows.append(("cluster_scaling/ref_put_requests", 0.0,
                 float(ref.stats.put_requests)))
    for workers in (1, 2, 4):
        t0 = time.perf_counter()
        crep = ClusterExecutor(
            store, "bench", mesh=mesh, axis_names="w", plan=plan,
            cluster=ClusterPlan(num_workers=workers)).sort()
        secs = time.perf_counter() - t0
        assert layout() == want, f"W={workers} changed output bytes"
        val = valsort.validate_from_store(
            store, "bench", plan.output_prefix, in_ck)
        assert val.ok, (workers, val)
        assert crep.sort.reduce_peak_merge_bytes <= budget, (crep.sort, budget)
        assert not crep.failed_workers and crep.reexecuted_tasks == 0
        rates[workers] = total / secs
        rows.append((f"cluster_scaling/w{workers}", secs * 1e6,
                     rates[workers]))

    speedup = rates[4] / rates[1]
    # The acceptance bar (1.4x) is the --full contract; the smoke run —
    # which CI executes on shared, noisy runners — asserts only the
    # direction (more workers must not lose) and reports the ratio.
    bar = 1.4 if full else 1.05
    assert speedup >= bar, (
        f"W=4 gained only {speedup:.2f}x over W=1 (bar: {bar}x)")
    rows.append(("cluster_scaling/speedup_w4_vs_w1", 0.0, speedup))

    # One injected worker death mid-job: w1 completes 3 tasks, then dies;
    # the driver must finish on survivors with byte-identical output and
    # report the re-executed tasks.
    t0 = time.perf_counter()
    crep = ClusterExecutor(
        store, "bench", mesh=mesh, axis_names="w", plan=plan,
        cluster=ClusterPlan(num_workers=4, fail_after_tasks={1: 3})).sort()
    secs = time.perf_counter() - t0
    assert layout() == want, "worker failure changed output bytes"
    val = valsort.validate_from_store(store, "bench", plan.output_prefix, in_ck)
    assert val.ok, val
    assert crep.failed_workers == ["w1"], crep.failed_workers
    assert crep.sort.reduce_peak_merge_bytes <= budget
    rows.append(("cluster_scaling/failover_w4_kill1", secs * 1e6,
                 float(crep.reexecuted_tasks)))
    return rows


def main():
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--smoke", action="store_true",
                      help="small dataset, lenient speedup bar (the default)")
    mode.add_argument("--full", action="store_true",
                      help="larger dataset, 1.4x speedup bar")
    args = ap.parse_args()
    t0 = time.perf_counter()
    print("name,us_per_call,derived")
    for name, us, derived in run(full=args.full):
        print(f"{name},{us:.3f},{derived:.6g}")
    print(f"# total {time.perf_counter() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
