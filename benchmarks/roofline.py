"""§Roofline: derive the three roofline terms per (arch x shape x mesh)
from the dry-run's compiled artifacts (results/dryrun.jsonl).

  compute term    = HLO_FLOPs / (chips x 197e12 bf16 FLOP/s)
  memory term     = HLO_bytes / (chips x 819e9 B/s HBM)
  collective term = collective_bytes / (chips x 50e9 B/s ICI link)

cost_analysis() reports whole-program FLOPs/bytes; collective bytes are
parsed from the optimized HLO (launch/dryrun.py:collective_bytes). The
dominant term is the bottleneck the §Perf loop iterates on. MODEL_FLOPS
(6·N·D forward+backward, or 2·N·D for inference) over HLO_FLOPs measures
how much compiled compute is 'useful'.
"""
from __future__ import annotations

import json
import os

from repro.configs import get
from repro.models import api as mapi

PEAK_FLOPS = 197e12  # bf16 per chip (v5e)
HBM_BW = 819e9  # B/s per chip
ICI_BW = 50e9  # B/s per link


def model_flops(arch_id: str, shape_name: str) -> float:
    cfg = get(arch_id)
    spec = mapi.SHAPES[shape_name]
    n_active = cfg.params_active()
    tokens = spec["batch"] * spec["seq"]
    if spec["kind"] == "train":
        return 6.0 * n_active * tokens
    if spec["kind"] == "prefill":
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * spec["batch"]


def derive(rec: dict) -> dict:
    chips = rec["devices"]
    flops = rec.get("flops") or 0.0
    byts = rec.get("bytes_accessed") or 0.0
    coll = (rec.get("collective_bytes") or {}).get("total", 0)
    # cost_analysis flops on the CPU backend are per-device post-SPMD.
    t_compute = flops / PEAK_FLOPS
    t_memory = byts / HBM_BW
    t_coll = coll / ICI_BW
    dominant = max(
        ("compute", t_compute), ("memory", t_memory), ("collective", t_coll),
        key=lambda kv: kv[1],
    )[0]
    mf = model_flops(rec["arch"], rec["shape"])
    mf_per_chip = mf / chips
    useful = mf_per_chip / flops if flops else 0.0
    # roofline fraction: useful work at peak over the modeled step time
    t_step = max(t_compute, t_memory, t_coll)
    frac = (mf_per_chip / PEAK_FLOPS) / t_step if t_step else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "tag": rec.get("tag", "baseline"),
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops_per_chip": mf_per_chip, "hlo_flops": flops,
        "useful_flop_ratio": useful, "roofline_fraction": frac,
        "peak_gib_per_dev": rec.get("peak_bytes_per_dev", 0) / 2**30,
    }


def load(path: str = "results/dryrun.jsonl", tag: str | None = None):
    rows = []
    if not os.path.exists(path):
        return rows
    for line in open(path):
        rec = json.loads(line)
        if not rec.get("ok"):
            continue
        if tag and rec.get("tag") != tag:
            continue
        rows.append(derive(rec))
    return rows


def shuffle_phase_rows(metrics: dict, *, store_bw_bps: float,
                       prefix: str = "roofline"):
    """Achieved store bytes/s per shuffle phase vs a bandwidth roof.

    `metrics` is a ShuffleReport.metrics snapshot (obs/metrics.py): the
    job derives `store.bytes_read_per_s{phase=...}` /
    `store.bytes_written_per_s{phase=...}` gauges when a
    TracingMiddleware shares the job's tracer. Each gauge becomes one
    row whose derived value is the achieved fraction of `store_bw_bps`
    (the injected store's bandwidth, or a real NIC/S3 roof) — 1.0 means
    that phase's transfer leg runs at the roofline, which is the
    Exoshuffle end state: compute hidden, I/O bound. Phases with no
    traffic (or no tracing store wired in) produce no row.
    """
    gauges = (metrics or {}).get("gauges", {})
    rows = []
    for phase in ("map", "reduce"):
        for metric, short in (("store.bytes_read_per_s", "read"),
                              ("store.bytes_written_per_s", "write")):
            v = gauges.get(f"{metric}{{phase={phase}}}", 0.0)
            if v:
                rows.append((f"{prefix}/{phase}_{short}_of_roof", 0.0,
                             v / store_bw_bps))
    return rows


def run():
    """benchmarks.run hook: one CSV row per dry-run cell."""
    rows = []
    for r in load():
        if r["mesh"] != "16x16" or r["tag"] != "baseline":
            continue
        name = f"roofline_{r['arch']}_{r['shape']}"
        t_us = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"]) * 1e6
        rows.append((name, t_us, r["roofline_fraction"]))
    return rows


def table(path: str = "results/dryrun.jsonl", tag: str = "baseline",
          mesh: str = "16x16"):
    rows = load(path, tag=tag)
    out = [r for r in rows if r["mesh"] == mesh]
    out.sort(key=lambda r: (r["arch"], r["shape"]))
    return out


if __name__ == "__main__":
    import sys

    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun.jsonl"
    for r in table(path):
        print(
            f"{r['arch']:22s} {r['shape']:12s} {r['mesh']:8s} "
            f"C={r['t_compute_s']*1e3:9.3f}ms M={r['t_memory_s']*1e3:9.3f}ms "
            f"X={r['t_collective_s']*1e3:9.3f}ms dom={r['dominant']:10s} "
            f"useful={r['useful_flop_ratio']:.2f} "
            f"roofline={r['roofline_fraction']:.3f}"
        )
