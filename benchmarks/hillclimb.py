"""§Perf hillclimb driver: re-lower one (arch x shape) cell with config
overrides and print the roofline-term delta vs the recorded baseline.

  PYTHONPATH=src python -m benchmarks.hillclimb --arch tinyllama-1.1b \
      --shape train_4k --set attn_sharding=heads --tag heads \
      [--multi-pod] [--record]

--record appends the run to results/dryrun.jsonl under --tag so
EXPERIMENTS.md §Perf can cite it; without it the run is printed only.
Override values are parsed as python literals (attn_sharding=heads stays
a string, train_microbatches=4 becomes an int).
"""
from __future__ import annotations

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import ast
import json


def _parse_set(items):
    out = {}
    for it in items or []:
        k, _, v = it.partition("=")
        try:
            out[k] = ast.literal_eval(v)
        except (ValueError, SyntaxError):
            out[k] = v
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override, e.g. attn_sharding=heads")
    ap.add_argument("--tag", default="hillclimb")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--record", action="store_true")
    ap.add_argument("--baseline", default="results/dryrun.jsonl")
    args = ap.parse_args()

    from benchmarks import roofline
    from repro.launch.dryrun import run_cell

    overrides = _parse_set(args.set)
    out_path = args.baseline if args.record else None
    rec = run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                   out_path=out_path, overrides=overrides, tag=args.tag)
    if not rec.get("ok"):
        print(f"FAILED: {rec.get('error')}")
        print(rec.get("traceback", ""))
        return

    mesh = rec["mesh"]
    base = None
    if os.path.exists(args.baseline):
        for line in open(args.baseline):
            r = json.loads(line)
            if (r.get("arch"), r.get("shape"), r.get("mesh"),
                    r.get("tag")) == (args.arch, args.shape, mesh, "baseline"):
                base = r  # keep the last matching baseline
    new = roofline.derive(rec)

    def row(name, rec_d):
        print(f"  {name:10s} C={rec_d['t_compute_s']*1e3:10.3f}ms "
              f"M={rec_d['t_memory_s']*1e3:10.3f}ms "
              f"X={rec_d['t_collective_s']*1e3:10.3f}ms "
              f"dom={rec_d['dominant']:10s} useful={rec_d['useful_flop_ratio']:.3f} "
              f"roofline={rec_d['roofline_fraction']:.3f}")

    print(f"\n{args.arch} x {args.shape} [{mesh}] overrides={overrides}")
    if base is not None:
        bd = roofline.derive(base)
        row("baseline", bd)
        row(args.tag, new)
        dom = bd["dominant"]
        key = {"compute": "t_compute_s", "memory": "t_memory_s",
               "collective": "t_collective_s"}[dom]
        if bd[key] > 0:
            print(f"  dominant term ({dom}): {bd[key]*1e3:.3f} -> "
                  f"{new[key]*1e3:.3f} ms  "
                  f"({(1 - new[key]/bd[key])*100:+.1f}% better)")
        print(f"  peak GiB/dev: {base.get('peak_bytes_per_dev',0)/2**30:.2f}"
              f" -> {rec.get('peak_bytes_per_dev',0)/2**30:.2f}")
    else:
        row(args.tag, new)
    print(f"  while trips: {rec.get('while_trips')}  "
          f"collectives: { {k: f'{v:.3g}' for k, v in rec['collective_bytes'].items()} }")


if __name__ == "__main__":
    main()
