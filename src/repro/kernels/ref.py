"""Pure-jnp oracles for every Pallas kernel in this package.

Every kernel in kernels/ must agree exactly (bit-for-bit for integer data)
with the reference implementation here. The references define the semantic
contract; the kernels are TPU-tiled implementations of the same contract.

Record model (see DESIGN.md §2, key-width adaptation): a record is a
(key: uint32, val: uint32) pair. `val` usually carries a rank/row-index into
a payload table. All sorts are *lexicographic* on (key, val) so that outputs
are bit-deterministic and kernel-vs-ref comparisons can be exact.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

UINT32_MAX = jnp.uint32(0xFFFFFFFF)


def sort_kv_ref(keys: jax.Array, vals: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Lexicographic sort of (key, val) pairs along the last axis.

    keys, vals: uint32 arrays of identical shape (..., n).
    Returns (sorted_keys, sorted_vals), ascending by key then val.
    """
    # jax.lax.sort with two operands sorts lexicographically on the operand
    # sequence: primary = first operand, tiebreak = second.
    sk, sv = jax.lax.sort((keys, vals), dimension=-1, num_keys=2)
    return sk, sv


def merge_kv_ref(
    a_keys: jax.Array, a_vals: jax.Array, b_keys: jax.Array, b_vals: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Merge two lex-sorted (key, val) runs along the last axis.

    a_*, b_*: uint32 arrays (..., n). Returns (..., 2n) merged sorted run.
    """
    keys = jnp.concatenate([a_keys, b_keys], axis=-1)
    vals = jnp.concatenate([a_vals, b_vals], axis=-1)
    return sort_kv_ref(keys, vals)


def sort_kvi_ref(
    keys: jax.Array, vals: jax.Array, idx: jax.Array
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Lexicographic sort of (key, val, idx) triples along the last axis.

    keys, vals: uint32; idx: int32 ordinal (all num_keys=3, so ties in
    (key, val) resolve by ordinal — the stable-merge order the indexed
    merge kernel (kernels/kway_merge.py) reproduces).
    """
    return jax.lax.sort((keys, vals, idx), dimension=-1, num_keys=3)


def merge_kvi_ref(
    a_keys: jax.Array, a_vals: jax.Array, a_idx: jax.Array,
    b_keys: jax.Array, b_vals: jax.Array, b_idx: jax.Array,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Merge two triple-lex-sorted indexed runs along the last axis.

    a_*, b_*: (..., n). Returns the (..., 2n) merged sorted run — the
    oracle for kway_merge.merge_sorted_pairs_indexed.
    """
    keys = jnp.concatenate([a_keys, b_keys], axis=-1)
    vals = jnp.concatenate([a_vals, b_vals], axis=-1)
    idx = jnp.concatenate([a_idx, b_idx], axis=-1)
    return sort_kvi_ref(keys, vals, idx)


def partition_offsets_ref(sorted_keys: jax.Array, boundaries: jax.Array) -> jax.Array:
    """For each boundary b, the number of keys strictly below b.

    sorted_keys: (..., n) uint32, ascending. boundaries: (r,) uint32.
    Returns (..., r) int32 offsets: offsets[..., j] = #{i : keys[..., i] < b_j}.
    Bucket j of an ascending partition with boundaries b_1..b_{r} (b_r often
    2**32 sentinel) is keys[offsets[j-1]:offsets[j]].
    """
    # Compare in uint32 domain; jnp.searchsorted requires matching dtypes.
    def one(row):
        return jnp.searchsorted(row, boundaries, side="left").astype(jnp.int32)

    flat = sorted_keys.reshape((-1, sorted_keys.shape[-1]))
    out = jax.vmap(one)(flat)
    return out.reshape(sorted_keys.shape[:-1] + (boundaries.shape[0],))


def histogram_ref(keys: jax.Array, boundaries: jax.Array) -> jax.Array:
    """Counts per bucket for *unsorted* keys.

    bucket j covers [boundaries[j-1], boundaries[j]) with boundaries[-1]
    implicit 0. Returns (..., r) int32 counts summing to n (if boundaries
    cover the key space).
    """
    srt, _ = sort_kv_ref(keys, jnp.zeros_like(keys))
    off = partition_offsets_ref(srt, boundaries)
    prev = jnp.concatenate(
        [jnp.zeros(off.shape[:-1] + (1,), off.dtype), off[..., :-1]], axis=-1
    )
    return off - prev
