"""Pallas TPU kernel: indexed k-way merge — the reduce merge on the device.

The paper's reduce task (§2.4) merges R1 spilled runs per output
partition; our streaming reduce fetches bounded chunk windows of each
run and merges one window per emit cycle (shuffle/runtime). Until this
kernel, that merge was host numpy (`merge_fragments`: a stable argsort
over the concatenated packed keys). This module puts the window merge on
the device as a *tournament of pairwise bitonic merges* — the same
network as kernels/merge_sorted.py, extended to carry a third operand:

  keys: uint32   vals: uint32   idx: int32 (window ordinal)

The network compares LEXICOGRAPHICALLY on (key, val, idx). Because `idx`
is each record's position in the concatenated fragment window, the full
triple order is exactly the stable argsort order of the packed
(key<<32|val) keys — ties between equal (key, val) records keep fragment
order, then within-fragment order. That makes the device merge
bit-identical to `merge_fragments` for ANY input (duplicate packed keys
included), and `idx` doubles as the gather index for host-side payload
rows. Padding to power-of-two shapes uses the lex-max record
(0xFFFFFFFF, 0xFFFFFFFF) with idx = window size: real records that
happen to equal the pad key/val still sort BEFORE the pads (smaller
idx), so no fallback path is needed.

Three lowerings of the same network, pinned bit-identical by
tests/test_kernels.py:

  * `merge_sorted_pairs_indexed` — the pallas_call kernel (grid over row
    pairs; Mosaic on a real TPU, interpret mode on CPU);
  * the jit'd plain-jnp network — identical math without the pallas_call
    wrapper; on CPU this is the production lowering (XLA-compiled rather
    than Python-interpreted kernel bodies, ~100x faster than interpret
    mode);
  * `kernels/ref.py:merge_kvi_ref` — the lax.sort oracle.

`merge_fragments_device` is the host entry the reduce sink
(shuffle/sort.DeviceMergeReduceOp) calls: it pads the emit window's
fragments to a (K, L) power-of-two grid, runs the tournament, slices the
true count, and gathers payload rows by the merged ordinals.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels import ref as _ref

PAD_KEY = 0xFFFFFFFF
PAD_VAL = 0xFFFFFFFF


def _triple_swap_needed(k0, v0, i0, k1, v1, i1):
    """True where (k0, v0, i0) > (k1, v1, i1) lexicographically."""
    return (k0 > k1) | ((k0 == k1) & ((v0 > v1) | ((v0 == v1) & (i0 > i1))))


def _compare_exchange_idx(keys, vals, idx, dist: int, window: int):
    """One bitonic substage at compare distance `dist` within stage
    `window`, carrying the int32 ordinal as the last lex operand.

    keys/vals/idx: (..., B). Static dist/window (powers of two); leading
    dims broadcast (the jnp lowering batches rows, the Pallas kernel
    passes 1-D blocks).
    """
    shape = keys.shape
    b = shape[-1]
    groups = b // (2 * dist)
    grouped = shape[:-1] + (groups, 2, dist)
    kr = keys.reshape(grouped)
    vr = vals.reshape(grouped)
    ir = idx.reshape(grouped)
    k0, k1 = kr[..., 0, :], kr[..., 1, :]
    v0, v1 = vr[..., 0, :], vr[..., 1, :]
    i0, i1 = ir[..., 0, :], ir[..., 1, :]

    # Ascending iff the stage window this group falls in has even index
    # (same direction rule as bitonic_sort._compare_exchange).
    g = jax.lax.broadcasted_iota(jnp.int32, (groups, 1), 0)
    asc = ((g * (2 * dist)) // window) % 2 == 0

    swap = _triple_swap_needed(k0, v0, i0, k1, v1, i1)
    do = jnp.where(asc, swap, ~swap)

    def weave(a0, a1):
        lo = jnp.where(do, a1, a0)
        hi = jnp.where(do, a0, a1)
        return jnp.stack([lo, hi], axis=-2).reshape(shape)

    return weave(k0, k1), weave(v0, v1), weave(i0, i1)


def _merge_network_idx(keys, vals, idx):
    """Sort a bitonic (..., B) sequence: substages at distance B/2 ... 1,
    one ascending window covering the whole block."""
    b = keys.shape[-1]
    dist = b // 2
    while dist >= 1:
        keys, vals, idx = _compare_exchange_idx(keys, vals, idx, dist, b)
        dist //= 2
    return keys, vals, idx


def _merge_pair_indexed_kernel(ak_ref, av_ref, ai_ref, bk_ref, bv_ref,
                               bi_ref, ok_ref, ov_ref, oi_ref):
    ak = ak_ref[...].reshape(-1)
    av = av_ref[...].reshape(-1)
    ai = ai_ref[...].reshape(-1)
    # Reverse the second run: ascending ++ descending == bitonic.
    bk = bk_ref[...].reshape(-1)[::-1]
    bv = bv_ref[...].reshape(-1)[::-1]
    bi = bi_ref[...].reshape(-1)[::-1]
    keys = jnp.concatenate([ak, bk])
    vals = jnp.concatenate([av, bv])
    idx = jnp.concatenate([ai, bi])
    keys, vals, idx = _merge_network_idx(keys, vals, idx)
    ok_ref[...] = keys.reshape(ok_ref.shape)
    ov_ref[...] = vals.reshape(ov_ref.shape)
    oi_ref[...] = idx.reshape(oi_ref.shape)


@functools.partial(jax.jit, static_argnames=("interpret",))
def merge_sorted_pairs_indexed(
    a_keys: jax.Array, a_vals: jax.Array, a_idx: jax.Array,
    b_keys: jax.Array, b_vals: jax.Array, b_idx: jax.Array,
    *, interpret: bool = True,
):
    """Merge row i of a_* with row i of b_* (each (n, L), rows sorted
    lexicographically on (key, val, idx)). Returns (keys, vals, idx) of
    shape (n, 2L), each row triple-lex sorted. L must be a power of two.
    """
    assert a_keys.shape == a_vals.shape == a_idx.shape
    assert a_keys.shape == b_keys.shape == b_vals.shape == b_idx.shape
    n, run = a_keys.shape
    assert run & (run - 1) == 0, f"run length {run} must be a power of two"
    in_blk = pl.BlockSpec((1, run), lambda i: (i, 0))
    out_blk = pl.BlockSpec((1, 2 * run), lambda i: (i, 0))
    out_sd = (
        jax.ShapeDtypeStruct((n, 2 * run), a_keys.dtype),
        jax.ShapeDtypeStruct((n, 2 * run), a_vals.dtype),
        jax.ShapeDtypeStruct((n, 2 * run), a_idx.dtype),
    )
    return pl.pallas_call(
        _merge_pair_indexed_kernel,
        grid=(n,),
        in_specs=[in_blk] * 6,
        out_specs=(out_blk, out_blk, out_blk),
        out_shape=out_sd,
        interpret=interpret,
    )(a_keys, a_vals, a_idx, b_keys, b_vals, b_idx)


def _merge_pairs_body(ak, av, ai, bk, bv, bi):
    """The kernel body as plain batched jnp: concat a ++ reversed(b) per
    row, then one bitonic merge network pass. (n, L) -> (n, 2L)."""
    keys = jnp.concatenate([ak, bk[..., ::-1]], axis=-1)
    vals = jnp.concatenate([av, bv[..., ::-1]], axis=-1)
    idx = jnp.concatenate([ai, bi[..., ::-1]], axis=-1)
    return _merge_network_idx(keys, vals, idx)


def _tournament_body(keys, vals, idx, merge_pairs):
    """(K, L) sorted rows -> one (K*L,) sorted run via log2(K) rounds of
    pairwise merges. K, L static powers of two."""
    k = keys.shape[0]
    while k > 1:
        keys, vals, idx = merge_pairs(keys[0::2], vals[0::2], idx[0::2],
                                      keys[1::2], vals[1::2], idx[1::2])
        k //= 2
    return keys.reshape(-1), vals.reshape(-1), idx.reshape(-1)


@jax.jit
def _tournament_network(keys, vals, idx):
    return _tournament_body(keys, vals, idx, _merge_pairs_body)


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def kway_merge_indexed(keys, vals, idx, *, impl: str = "pallas"):
    """Merge K triple-lex-sorted runs -> one sorted run of K*L triples.

    keys, vals: (K, L) uint32; idx: (K, L) int32. K and L powers of two.
    impl:
      "pallas"  — the Pallas kernel tournament (interpret mode on CPU);
      "network" — the identical merge network, jit'd as plain jnp (the
                  fast CPU lowering; bit-identical to "pallas");
      "ref"     — the lax.sort oracle (kernels/ref.merge_kvi_ref).
    """
    k, run = keys.shape
    assert k & (k - 1) == 0, "K must be a power of two"
    if impl == "ref":
        mk, mv, mi = _ref.sort_kvi_ref(keys.reshape(1, -1),
                                       vals.reshape(1, -1),
                                       idx.reshape(1, -1))
        return mk.reshape(-1), mv.reshape(-1), mi.reshape(-1)
    if impl == "network":
        return _tournament_network(jnp.asarray(keys), jnp.asarray(vals),
                                   jnp.asarray(idx))
    assert impl == "pallas", f"unknown impl {impl!r}"
    interp = _on_cpu()

    def merge_pairs(ak, av, ai, bk, bv, bi):
        return merge_sorted_pairs_indexed(ak, av, ai, bk, bv, bi,
                                          interpret=interp)

    return _tournament_body(jnp.asarray(keys), jnp.asarray(vals),
                            jnp.asarray(idx), merge_pairs)


def _pad_window(frags, total: int):
    """Pack an emit window's fragments into (K, L) power-of-two arrays
    padded with lex-max records whose ordinal is `total` (past every real
    record, so pads always sort last)."""
    kp = _next_pow2(len(frags))
    lp = _next_pow2(max(max(f[0].size for f in frags), 1))
    keys = np.full((kp, lp), PAD_KEY, np.uint32)
    vals = np.full((kp, lp), PAD_VAL, np.uint32)
    idx = np.full((kp, lp), total, np.int32)
    base = 0
    for r, f in enumerate(frags):
        n = f[0].size
        keys[r, :n] = f[0]
        vals[r, :n] = f[1]
        idx[r, :n] = np.arange(base, base + n, dtype=np.int32)
        base += n
    return keys, vals, idx


def merge_fragments_device(frags, payload_words: int, *,
                           impl: str = "pallas"):
    """Device-backed drop-in for shuffle/runtime.merge_fragments.

    Same contract, bit-identical output: merge already-sorted fragments
    [(keys, ids, payload, k64), ...] into one sorted (keys, ids, payload)
    batch, ties resolved in stable concatenation order (the ordinal
    operand — see module docstring). Payload rows are gathered on the
    host by the merged ordinals. impl "pallas" lowers through the jit'd
    jnp network on CPU (same math as the kernel, XLA-compiled) and the
    real pallas_call elsewhere; "network"/"ref" force those lowerings.
    """
    frags = [f for f in frags if f[3].size]
    if not frags:
        empty = np.empty((0,), np.uint32)
        pw = int(payload_words)
        return empty, empty, (np.empty((0, pw), np.uint32) if pw else None)
    if len(frags) == 1:
        k, i, p, _ = frags[0]
        return k, i, p
    total = sum(f[0].size for f in frags)
    assert total < 2**31, "emit window exceeds int32 ordinal range"
    keys, vals, idx = _pad_window(frags, total)
    if impl == "pallas" and _on_cpu():
        impl = "network"  # identical math, XLA-compiled (see docstring)
    mk, mv, mi = kway_merge_indexed(keys, vals, idx, impl=impl)
    mk = np.asarray(mk)[:total]
    mv = np.asarray(mv)[:total]
    payload = None
    if payload_words:
        mi = np.asarray(mi)[:total]
        payload = np.concatenate([f[2] for f in frags])[mi]
    return mk, mv, payload


__all__ = [
    "kway_merge_indexed",
    "merge_fragments_device",
    "merge_sorted_pairs_indexed",
]
