"""Pallas TPU kernel: in-VMEM bitonic sort of (key, val) uint32 records.

This is the TPU adaptation of the paper's map-task sort (§2.3: "we first
... sort the input data in memory"). The paper uses a serial comparison sort
in C++ on a CPU core; a serial quicksort cannot use the TPU's 8x128 vector
lanes, so we replace it with a *bitonic sorting network*: O(n log^2 n)
compare-exchanges, but every compare-exchange step is a full-width vector
op over VMEM-resident data, and the whole network runs with zero HBM
traffic after the initial block load.

Layout: records are blocks of B (power of two) (key, val) pairs. The grid
iterates over independent blocks; each block is sorted entirely in VMEM.
The compare-exchange at distance d pairs element i with element i^d; we
express that without gathers by reshaping (B,) -> (B/2d, 2, d): the two rows
of axis 1 are exactly the (i, i^d) partners. Direction (ascending vs
descending) alternates with the stage window so the array forms bitonic
sequences of doubling length. All reshapes are static powers of two, which
Mosaic lowers to sublane/lane reindexing without data movement.

VMEM budget: 2 arrays x B x 4 bytes (keys, vals) plus double-buffering —
B = 64k gives 512 KiB working set, comfortably inside the ~16 MiB VMEM of a
TPU v5e core. Default B below is kept smaller for fast interpret-mode tests.

Sorting is LEXICOGRAPHIC on (key, val): deterministic output, exact-match
testable against kernels/ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 4096


def _pair_swap_needed(k0, v0, k1, v1):
    """True where (k0, v0) > (k1, v1) lexicographically."""
    return (k0 > k1) | ((k0 == k1) & (v0 > v1))


def _compare_exchange(keys, vals, dist: int, window: int):
    """One bitonic substage at compare distance `dist` within stage `window`.

    keys, vals: (B,) uint32. Static dist/window (powers of two).
    """
    b = keys.shape[0]
    groups = b // (2 * dist)
    kr = keys.reshape(groups, 2, dist)
    vr = vals.reshape(groups, 2, dist)
    k0, k1 = kr[:, 0, :], kr[:, 1, :]
    v0, v1 = vr[:, 0, :], vr[:, 1, :]

    # Ascending iff the stage-window this group falls in has even index.
    # group g covers flat indices [g*2d, (g+1)*2d); window index = floor(g*2d / window).
    g = jax.lax.broadcasted_iota(jnp.int32, (groups, 1), 0)
    asc = ((g * (2 * dist)) // window) % 2 == 0

    swap = _pair_swap_needed(k0, v0, k1, v1)
    do = jnp.where(asc, swap, ~swap)

    nk0 = jnp.where(do, k1, k0)
    nk1 = jnp.where(do, k0, k1)
    nv0 = jnp.where(do, v1, v0)
    nv1 = jnp.where(do, v0, v1)

    nk = jnp.stack([nk0, nk1], axis=1).reshape(b)
    nv = jnp.stack([nv0, nv1], axis=1).reshape(b)
    return nk, nv


def _bitonic_network(keys, vals):
    """Full bitonic sort network over a (B,) block. B static power of two."""
    b = keys.shape[0]
    assert b & (b - 1) == 0, "block must be a power of two"
    window = 2
    while window <= b:
        dist = window // 2
        while dist >= 1:
            keys, vals = _compare_exchange(keys, vals, dist, window)
            dist //= 2
        window *= 2
    return keys, vals


def _sort_block_kernel(k_ref, v_ref, ok_ref, ov_ref):
    """Sort one (1, B) block resident in VMEM."""
    keys = k_ref[...].reshape(-1)
    vals = v_ref[...].reshape(-1)
    keys, vals = _bitonic_network(keys, vals)
    ok_ref[...] = keys.reshape(ok_ref.shape)
    ov_ref[...] = vals.reshape(ov_ref.shape)


@functools.partial(jax.jit, static_argnames=("interpret",))
def bitonic_sort_blocks(keys: jax.Array, vals: jax.Array, *, interpret: bool = True):
    """Sort each row of (num_blocks, B) (key, val) pairs independently.

    B must be a power of two. Returns (sorted_keys, sorted_vals), each row
    lexicographically ascending.
    """
    assert keys.ndim == 2 and keys.shape == vals.shape
    nb, b = keys.shape
    assert b & (b - 1) == 0, f"block size {b} must be a power of two"
    blk = pl.BlockSpec((1, b), lambda i: (i, 0))
    out_sd = (
        jax.ShapeDtypeStruct((nb, b), keys.dtype),
        jax.ShapeDtypeStruct((nb, b), vals.dtype),
    )
    return pl.pallas_call(
        _sort_block_kernel,
        grid=(nb,),
        in_specs=[blk, blk],
        out_specs=(blk, blk),
        out_shape=out_sd,
        interpret=interpret,
    )(keys, vals)
