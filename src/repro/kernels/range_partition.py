"""Pallas TPU kernel: range-partition offsets of sorted keys.

TPU adaptation of the paper's range partitioner (§2.2): the key space
[0, 2^64) is split into R ranges and every record is routed to the
range owner. On TPU the records are already sorted when partitioning happens
(the map task sorts first, §2.3), so partitioning reduces to finding, for
each boundary b_j, the offset of the first key >= b_j — i.e. a vectorized
searchsorted. The slice [offsets[j-1], offsets[j]) of the sorted block is
then exactly the paper's "slice sent to worker j".

The kernel is boundary-generic: it never assumes the equal Indy split.
Sampled quantile boundaries (core/keyspace.sampled_boundaries — the
Daytona-style skew fallback, wired end-to-end by shuffle/recursive) flow
through unchanged, including duplicate boundary values, which simply
yield empty slices. The routing contract — offsets[j] = #{k < b_j}
(searchsorted side="left"), so slice j holds exactly the keys with
b_{j-1} <= k < b_j, the same membership the host-side
RangePartitioner.partition_of computes with side="right" — is pinned
bit-for-bit against `searchsorted_reference` below by
tests/test_shuffle.py's property tests.

Instead of a branchy binary search (log n dependent steps), the kernel
computes offsets[j] = sum_i [key_i < b_j] by streaming the sorted block
through VMEM in tiles and accumulating a (R,) counter vector — a pure
vector-compare + reduce pipeline at 8x128 lane width, O(n*R/8/128) VPU
cycles with perfect utilization and no data-dependent control flow.

Grid: one program per key block; boundaries are broadcast to every program.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

KEY_TILE = 2048  # keys compared per inner step; R x KEY_TILE bools in flight


def searchsorted_reference(sorted_keys, boundaries):
    """Host oracle for the kernel's contract: (num_blocks, R) int32 with
    out[i, j] = #{k in row i : k < boundaries[j]} — numpy searchsorted
    side="left" per row. The property tests pin the Pallas kernel to this
    bit-for-bit on adversarial boundaries (duplicates, 0, boundary-equal
    keys, all-equal rows)."""
    import numpy as np

    sk = np.asarray(sorted_keys, dtype=np.uint32)
    bs = np.asarray(boundaries, dtype=np.uint32)
    return np.stack([
        np.searchsorted(row, bs, side="left") for row in sk
    ]).astype(np.int32)


def _partition_kernel(keys_ref, bounds_ref, out_ref, *, key_tile: int):
    b = keys_ref.shape[-1]
    r = bounds_ref.shape[-1]
    bounds = bounds_ref[...].reshape(r)

    def body(t, acc):
        tile = jax.lax.dynamic_slice(
            keys_ref[...].reshape(-1), (t * key_tile,), (key_tile,)
        )
        # (r, key_tile) compare, reduce over keys.
        lt = (tile[None, :] < bounds[:, None]).astype(jnp.int32)
        return acc + jnp.sum(lt, axis=1)

    steps = b // key_tile
    acc = jnp.zeros((r,), jnp.int32)
    acc = jax.lax.fori_loop(0, steps, body, acc)
    out_ref[...] = acc.reshape(out_ref.shape)


@functools.partial(jax.jit, static_argnames=("interpret",))
def partition_offsets_blocks(
    sorted_keys: jax.Array, boundaries: jax.Array, *, interpret: bool = True
):
    """offsets[i, j] = #{k in row i : k < boundaries[j]}.

    sorted_keys: (num_blocks, B) uint32, rows ascending (sortedness is not
    required for correctness of the count, only for the offsets-as-slices
    interpretation). boundaries: (R,) uint32 ascending.
    Returns (num_blocks, R) int32.
    """
    nb, b = sorted_keys.shape
    (r,) = boundaries.shape
    key_tile = min(KEY_TILE, b)
    assert b % key_tile == 0
    kernel = functools.partial(_partition_kernel, key_tile=key_tile)
    return pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, b), lambda i: (i, 0)),
            pl.BlockSpec((r,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, r), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, r), jnp.int32),
        interpret=interpret,
    )(sorted_keys, boundaries)
