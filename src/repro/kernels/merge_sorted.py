"""Pallas TPU kernel: bitonic merge of two lex-sorted (key, val) runs.

TPU adaptation of the paper's merge tasks (§2.3: a merge task merges W
already-sorted map blocks; §2.4: a reduce task merges R1 spilled runs). The
paper's C++ merger is a serial k-way heap merge — O(n log k) comparisons but
fully sequential and branchy, which is hostile to the TPU VPU. We instead
use the classic *bitonic merge network*: concatenating an ascending run with
a reversed (descending) run yields a bitonic sequence, which one log2(2L)
pass of compare-exchanges sorts completely. k-way merging becomes a
tournament of pairwise merges (log2 k rounds), each round fully
data-parallel — see kernels/ops.py:kway_merge.

Grid: one program per pair of runs. Each program loads both runs (2L
records) into VMEM, reverses the second, runs the merge network, and writes
the merged 2L run. Static power-of-two shapes throughout.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.bitonic_sort import _compare_exchange


def _merge_network(keys, vals):
    """Sort a bitonic (B,) sequence: substages at distance B/2 ... 1, all ascending."""
    b = keys.shape[0]
    dist = b // 2
    while dist >= 1:
        # window == b: a single ascending window covering the whole block.
        keys, vals = _compare_exchange(keys, vals, dist, b)
        dist //= 2
    return keys, vals


def _merge_pair_kernel(ak_ref, av_ref, bk_ref, bv_ref, ok_ref, ov_ref):
    ak = ak_ref[...].reshape(-1)
    av = av_ref[...].reshape(-1)
    # Reverse the second run: ascending ++ descending == bitonic.
    bk = bk_ref[...].reshape(-1)[::-1]
    bv = bv_ref[...].reshape(-1)[::-1]
    keys = jnp.concatenate([ak, bk])
    vals = jnp.concatenate([av, bv])
    keys, vals = _merge_network(keys, vals)
    ok_ref[...] = keys.reshape(ok_ref.shape)
    ov_ref[...] = vals.reshape(ov_ref.shape)


@functools.partial(jax.jit, static_argnames=("interpret",))
def merge_sorted_pairs(
    a_keys: jax.Array,
    a_vals: jax.Array,
    b_keys: jax.Array,
    b_vals: jax.Array,
    *,
    interpret: bool = True,
):
    """Merge row i of a_* with row i of b_* (each (n, L), rows lex-sorted).

    Returns (keys, vals) of shape (n, 2L), each row lex-sorted ascending.
    L must be a power of two.
    """
    assert a_keys.shape == a_vals.shape == b_keys.shape == b_vals.shape
    n, run = a_keys.shape
    assert run & (run - 1) == 0, f"run length {run} must be a power of two"
    in_blk = pl.BlockSpec((1, run), lambda i: (i, 0))
    out_blk = pl.BlockSpec((1, 2 * run), lambda i: (i, 0))
    out_sd = (
        jax.ShapeDtypeStruct((n, 2 * run), a_keys.dtype),
        jax.ShapeDtypeStruct((n, 2 * run), a_vals.dtype),
    )
    return pl.pallas_call(
        _merge_pair_kernel,
        grid=(n,),
        in_specs=[in_blk, in_blk, in_blk, in_blk],
        out_specs=(out_blk, out_blk),
        out_shape=out_sd,
        interpret=interpret,
    )(a_keys, a_vals, b_keys, b_vals)
