"""Public jit'd wrappers over the Pallas sort/merge/partition kernels.

These are the single-device primitives the exoshuffle library composes
(core/sortlib.py). Each op takes `impl`:

  - "pallas":  the TPU kernel (interpret=True on CPU — executes the kernel
               body in Python for bit-exact validation; compiled Mosaic on
               real TPU).
  - "ref":     the pure-jnp oracle from kernels/ref.py (XLA-native sort).
               Used for fast lowering in the 512-device dry-run and as the
               test oracle.

Padding convention: variable-length inputs are padded with the lex-maximal
record (0xFFFFFFFF, 0xFFFFFFFF), which sorts to the tail; callers track true
counts and slice. This mirrors the paper's fixed-size block protocol (map
output slices are padded to the merge-controller block size).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.bitonic_sort import bitonic_sort_blocks
from repro.kernels.merge_sorted import merge_sorted_pairs
from repro.kernels.range_partition import partition_offsets_blocks

PAD_KEY = jnp.uint32(0xFFFFFFFF)
PAD_VAL = jnp.uint32(0xFFFFFFFF)


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def pad_to_pow2(keys: jax.Array, vals: jax.Array):
    """Pad trailing axis to the next power of two with lex-max records."""
    n = keys.shape[-1]
    p = next_pow2(n)
    if p == n:
        return keys, vals, n
    pad = [(0, 0)] * (keys.ndim - 1) + [(0, p - n)]
    keys = jnp.pad(keys, pad, constant_values=PAD_KEY)
    vals = jnp.pad(vals, pad, constant_values=PAD_VAL)
    return keys, vals, n


def sort_kv(keys: jax.Array, vals: jax.Array, *, impl: str = "pallas"):
    """Lexicographic sort along the last axis. Any length; any leading dims.

    Returns arrays of the input shape.
    """
    if impl == "ref":
        return _ref.sort_kv_ref(keys, vals)
    shape = keys.shape
    keys2 = keys.reshape((-1, shape[-1]))
    vals2 = vals.reshape((-1, shape[-1]))
    pk, pv, n = pad_to_pow2(keys2, vals2)
    sk, sv = bitonic_sort_blocks(pk, pv, interpret=_on_cpu())
    return sk[:, :n].reshape(shape), sv[:, :n].reshape(shape)


def merge_kv(a_keys, a_vals, b_keys, b_vals, *, impl: str = "pallas"):
    """Merge two sorted runs (leading dims broadcast over rows)."""
    if impl == "ref":
        return _ref.merge_kv_ref(a_keys, a_vals, b_keys, b_vals)
    shape = a_keys.shape
    run = shape[-1]
    assert run & (run - 1) == 0, "pallas merge needs power-of-two runs"
    ak = a_keys.reshape((-1, run))
    av = a_vals.reshape((-1, run))
    bk = b_keys.reshape((-1, run))
    bv = b_vals.reshape((-1, run))
    mk, mv = merge_sorted_pairs(ak, av, bk, bv, interpret=_on_cpu())
    out_shape = shape[:-1] + (2 * run,)
    return mk.reshape(out_shape), mv.reshape(out_shape)


def kway_merge(keys: jax.Array, vals: jax.Array, *, impl: str = "pallas"):
    """Merge K sorted runs -> one sorted run.

    keys, vals: (..., K, L) with each (..., k, :) row lex-sorted. K, L powers
    of two. Returns (..., K*L). This is the paper's merge/reduce task: a
    tournament of pairwise bitonic merges, log2(K) rounds.
    """
    shape = keys.shape
    k, run = shape[-2], shape[-1]
    assert k & (k - 1) == 0, "K must be a power of two"
    keys = keys.reshape((-1, k, run))
    vals = vals.reshape((-1, k, run))
    while k > 1:
        a_k, b_k = keys[:, 0::2], keys[:, 1::2]
        a_v, b_v = vals[:, 0::2], vals[:, 1::2]
        nb = a_k.shape[0] * a_k.shape[1]
        mk, mv = merge_kv(
            a_k.reshape(nb, run),
            a_v.reshape(nb, run),
            b_k.reshape(nb, run),
            b_v.reshape(nb, run),
            impl=impl,
        )
        k //= 2
        run *= 2
        keys = mk.reshape((-1, k, run))
        vals = mv.reshape((-1, k, run))
    out_shape = shape[:-2] + (shape[-2] * shape[-1],)
    return keys.reshape(out_shape), vals.reshape(out_shape)


def partition_offsets(sorted_keys: jax.Array, boundaries: jax.Array, *, impl: str = "pallas"):
    """offsets[..., j] = #{keys < boundaries[j]} along the last axis."""
    if impl == "ref":
        return _ref.partition_offsets_ref(sorted_keys, boundaries)
    shape = sorted_keys.shape
    keys2 = sorted_keys.reshape((-1, shape[-1]))
    out = partition_offsets_blocks(keys2, boundaries, interpret=_on_cpu())
    return out.reshape(shape[:-1] + (boundaries.shape[0],))


@functools.partial(jax.jit, static_argnames=("impl",))
def sort_kv_jit(keys, vals, impl: str = "pallas"):
    return sort_kv(keys, vals, impl=impl)
