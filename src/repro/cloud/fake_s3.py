"""FakeS3Backend: an in-process S3 double for the cloud code paths.

The real backends (cloud/remote.py) need credentials, a network, and
optional dependencies the CI container doesn't have — so CI exercises
the cloud-facing code against THIS backend instead: a dict-backed store
that speaks the same wire-level semantics the repo's S3 contract pins
(ranged GETs with past-EOF truncation, part-indexed multipart with
out-of-order assembly and last-write-wins slots, crc32 etags computed
in ascending-part order, atomic complete / sweeping abort) plus the two
S3 behaviours the local planes deliberately don't model:

  * SlowDown 503s — `slowdown_every=N` raises io.backends.SlowDown on
    every Nth data-plane attempt (GET, ranged GET, UploadPart), counted
    by a global attempt counter so the total throttle count for a run
    is a deterministic function of the attempt count, independent of
    thread interleaving (a throttled attempt that gets retried is
    itself an attempt, exactly like a real 503 regime). Metadata
    requests (HEAD/LIST/DELETE) are never throttled here — per-request
    injection for those is io/middleware.ThrottlingMiddleware's job.

  * multipart minimum-part-size — `min_part_bytes=B` rejects
    `complete()` when any part except the highest-indexed one is
    smaller than B (the S3 EntityTooSmall rule: only the last part may
    be short). The default 0 disables the check, matching the local
    planes the shuffle's spill traffic already runs against.

Knob validation raises ValueError naming the knob (the repo-wide
convention), never an assert — it must survive python -O.
"""
from __future__ import annotations

import threading

from repro.io.backends import (MemoryBackend, ObjectNotFound, SlowDown,
                               _check_key, _MemMultipart)


class FakeS3Backend(MemoryBackend):
    """In-process S3 double (see module docstring).

    Subclasses MemoryBackend so the storage semantics (etag rules,
    multipart assembly, atomicity) are the contract implementation
    itself — the fake can never drift from the plane the compliance
    suite pins — and layers the S3-only behaviours on top.
    """

    def __init__(self, *, chunk_size: int = 4 << 20,
                 slowdown_every: int = 0, min_part_bytes: int = 0):
        if int(slowdown_every) < 0:
            raise ValueError(
                f"slowdown_every={slowdown_every!r}: must be >= 0 "
                "(0 disables SlowDown injection)")
        if int(min_part_bytes) < 0:
            raise ValueError(
                f"min_part_bytes={min_part_bytes!r}: must be >= 0 "
                "(0 disables the EntityTooSmall check)")
        super().__init__(chunk_size=chunk_size)
        self.slowdown_every = int(slowdown_every)
        self.min_part_bytes = int(min_part_bytes)
        self._attempt_lock = threading.Lock()
        self._data_attempts = 0
        self.throttled = 0

    def _throttle(self, what: str) -> None:
        """Every Nth data-plane attempt 503s, deterministically: the
        attempt counter is global, so for L logical requests retried to
        completion the totals satisfy attempts = L + throttled and
        throttled = floor(attempts / N) — a fixed point independent of
        the interleaving that produced it."""
        if not self.slowdown_every:
            return
        with self._attempt_lock:
            self._data_attempts += 1
            if self._data_attempts % self.slowdown_every == 0:
                self.throttled += 1
                raise SlowDown(f"503 Slow Down ({what})")

    # -- data plane (throttled) ---------------------------------------------

    def get(self, bucket: str, key: str) -> bytes:
        self._throttle(f"GET {bucket}/{key}")
        return super().get(bucket, key)

    def get_range(self, bucket: str, key: str, start: int, length: int) -> bytes:
        self._throttle(f"GET(range) {bucket}/{key}")
        return super().get_range(bucket, key, start, length)

    def multipart(self, bucket: str, key: str,
                  metadata: dict | None = None) -> "_FakeS3Multipart":
        if bucket not in self._buckets:
            raise ObjectNotFound(bucket)
        return _FakeS3Multipart(self, bucket, _check_key(key), metadata)


class _FakeS3Multipart(_MemMultipart):
    """_MemMultipart plus the S3-only wire rules: each UploadPart is a
    throttleable data-plane attempt, and complete() enforces the
    minimum-part-size constraint (every part but the highest-indexed
    must meet `min_part_bytes` — S3's EntityTooSmall)."""

    def put_part(self, index: int, data: bytes) -> None:
        self._b._throttle(f"UploadPart {self._bucket}/{self._key}")
        super().put_part(index, data)

    def complete(self):
        floor = self._b.min_part_bytes
        if floor:
            with self._lock:
                parts = sorted(self._parts.items())
            for idx, part in parts[:-1]:
                if len(part) < floor:
                    raise ValueError(
                        f"min_part_bytes={floor}: part {idx} is "
                        f"{len(part)} bytes — EntityTooSmall (every part "
                        "except the last must meet the minimum)")
        return super().complete()


__all__ = ["FakeS3Backend"]
