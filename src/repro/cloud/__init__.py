"""Cloud substrates: real object-store backends + serverless execution.

Two rungs above the in-process emulation (ROADMAP item 2):

  * `S3Backend` / `GCSBackend` — the repo's `StoreBackend` protocol over
    boto3 / gcsfs, behind gated imports (the deps are optional; missing
    ones raise ValueError naming the pip extra). `FakeS3Backend` speaks
    the same wire-level semantics in-process so CI exercises the cloud
    code paths hermetically.
  * `FunctionWorker` / `InvocationDriver` — a serverless execution mode
    running exactly one task per invocation with no shared state except
    the store, composed with the existing elastic driver so recovery,
    speculation, and byte-identity transfer with zero new code.
"""
from repro.cloud.fake_s3 import FakeS3Backend
from repro.cloud.function_worker import (FunctionWorker, InvocationDriver,
                                         InvocationRecord, invoke,
                                         register_endpoint)
from repro.cloud.remote import GCSBackend, S3Backend

__all__ = [
    "FakeS3Backend",
    "S3Backend",
    "GCSBackend",
    "FunctionWorker",
    "InvocationDriver",
    "InvocationRecord",
    "invoke",
    "register_endpoint",
]
