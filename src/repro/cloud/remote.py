"""Real object-store backends behind the repo's StoreBackend protocol.

`S3Backend` (boto3) and `GCSBackend` (gcsfs) put the shuffle on actual
cloud storage: same seven primitives, same part-indexed multipart with
out-of-order / last-write-wins parts, same ranged-GET truncation at
EOF. The optional dependencies are NOT baked into the CI container, so
the imports are gated: constructing a backend without its client
library raises `ValueError` naming the pip extra (the repo's
knob-naming convention — the dependency is just another knob the caller
got wrong), and points at `FakeS3Backend` for hermetic runs. Only that
gating path is exercised in CI; every network-touching method is
`# pragma: no cover` by construction and validated against the same
compliance suite (tests/store_compliance.py) when run out-of-container
with credentials.

Contract notes where the real services diverge from the local planes:

  * etag — local planes define etag = crc32 of the assembled bytes.
    S3's multipart ETag is md5-of-part-md5s + "-N"; GCS reports crc32c.
    Both are deterministic functions of (part bytes, part order), which
    is what the compliance contract actually relies on (out-of-order
    uploads of identical parts produce identical etags); cross-PLANE
    etag equality is not promised for real backends, and the shuffle
    never compares etags across stores.
  * custom metadata — JSON-encoded into one user-metadata entry
    (`repro-meta`) because S3/GCS metadata values must be strings; part
    counts ride along as `repro-parts` where the service API cannot
    report them (GCS compose).
  * multipart minimums — S3 rejects non-final parts < 5 MiB
    (EntityTooSmall). The shuffle's spill/output parts are sized by
    `output_part_records`/`merge_chunk_bytes`, which the caller must
    keep >= 5 MiB on real S3; `FakeS3Backend(min_part_bytes=...)`
    exists precisely so CI can pin the failure mode.
"""
from __future__ import annotations

import json
import threading

from repro.io.backends import (MultipartUpload, ObjectMeta, ObjectNotFound,
                               StoreBackend, _check_key)

_META_KEY = "repro-meta"
_PARTS_KEY = "repro-parts"


def _require_dep(module: str, backend: str, extra: str):
    """Gated import: a missing optional dependency is a configuration
    error named like any other bad knob, not an ImportError at some
    arbitrary call depth."""
    try:
        return __import__(module)
    except ImportError as exc:
        raise ValueError(
            f"{backend} requires the optional dependency {module!r} which is "
            f"not installed: pip install {extra} (or use "
            "repro.cloud.FakeS3Backend, which speaks the same wire-level "
            "semantics in-process)") from exc


def _encode_meta(metadata: dict | None) -> dict:
    return {_META_KEY: json.dumps(dict(metadata or {}), sort_keys=True)}


def _decode_meta(raw: dict | None) -> dict:
    try:
        return json.loads((raw or {}).get(_META_KEY, "{}"))
    except (TypeError, json.JSONDecodeError):  # pragma: no cover
        return {}


# ---------------------------------------------------------------------------
# S3 (boto3)
# ---------------------------------------------------------------------------


class S3Backend(StoreBackend):
    """Amazon S3 (or any S3-compatible endpoint) via boto3.

    `client` may be injected (a stubbed/moto client, or one configured
    with custom retries); otherwise a default `boto3.client("s3")` is
    built — which requires boto3, credentials, and a network.
    """

    def __init__(self, *, region_name: str | None = None,
                 endpoint_url: str | None = None,
                 chunk_size: int = 4 << 20, client=None):
        if client is None:
            boto3 = _require_dep("boto3", "S3Backend", "boto3")
            client = boto3.client(  # pragma: no cover - needs network/creds
                "s3", region_name=region_name, endpoint_url=endpoint_url)
        self._s3 = client
        self.chunk_size = int(chunk_size)

    # -- namespace ----------------------------------------------------- #

    def create_bucket(self, bucket: str) -> None:  # pragma: no cover
        try:
            self._s3.create_bucket(Bucket=bucket)
        except (self._s3.exceptions.BucketAlreadyOwnedByYou,
                self._s3.exceptions.BucketAlreadyExists):
            pass

    # -- writes -------------------------------------------------------- #

    def multipart(self, bucket: str, key: str,
                  metadata: dict | None = None) -> "_S3Multipart":  # pragma: no cover
        return _S3Multipart(self, bucket, _check_key(key), metadata)

    # -- reads --------------------------------------------------------- #

    def get(self, bucket: str, key: str) -> bytes:  # pragma: no cover
        try:
            return self._s3.get_object(Bucket=bucket, Key=key)["Body"].read()
        except self._s3.exceptions.NoSuchKey:
            raise ObjectNotFound(f"{bucket}/{key}") from None

    def get_range(self, bucket: str, key: str,
                  start: int, length: int) -> bytes:  # pragma: no cover
        start = max(int(start), 0)
        if int(length) <= 0:
            return b""
        try:
            resp = self._s3.get_object(
                Bucket=bucket, Key=key,
                Range=f"bytes={start}-{start + int(length) - 1}")
        except self._s3.exceptions.ClientError as exc:
            code = exc.response.get("Error", {}).get("Code", "")
            if code in ("InvalidRange", "416"):
                return b""  # whole range past EOF truncates to empty
            if code in ("NoSuchKey", "404"):
                raise ObjectNotFound(f"{bucket}/{key}") from None
            raise
        return resp["Body"].read()

    # -- metadata ------------------------------------------------------ #

    def head(self, bucket: str, key: str) -> ObjectMeta:  # pragma: no cover
        try:
            resp = self._s3.head_object(Bucket=bucket, Key=key)
        except self._s3.exceptions.ClientError as exc:
            if exc.response.get("Error", {}).get("Code") in ("404", "NoSuchKey"):
                raise ObjectNotFound(f"{bucket}/{key}") from None
            raise
        return self._meta(key, resp)

    @staticmethod
    def _meta(key: str, resp: dict) -> ObjectMeta:  # pragma: no cover
        etag = resp.get("ETag", "").strip('"')
        # Multipart ETags carry the part count as an "-N" suffix.
        parts = int(etag.rsplit("-", 1)[1]) if "-" in etag else 1
        return ObjectMeta(key=key, size=int(resp["ContentLength"]), etag=etag,
                          parts=parts, metadata=_decode_meta(resp.get("Metadata")))

    def list_objects(self, bucket: str,
                     prefix: str = "") -> list[ObjectMeta]:  # pragma: no cover
        out = []
        paginator = self._s3.get_paginator("list_objects_v2")
        for page in paginator.paginate(Bucket=bucket, Prefix=prefix):
            for obj in page.get("Contents", []):
                # One HEAD per key: ListObjectsV2 carries no user
                # metadata, and the shuffle's manifest scan (spill
                # offsets) lives there. Billed accordingly.
                out.append(self.head(bucket, obj["Key"]))
        return sorted(out, key=lambda m: m.key)

    def delete(self, bucket: str, key: str) -> None:  # pragma: no cover
        self.head(bucket, key)  # repo contract: deleting a missing key raises
        self._s3.delete_object(Bucket=bucket, Key=key)


class _S3Multipart(MultipartUpload):  # pragma: no cover - needs network
    """S3 CreateMultipartUpload session. Repo part index i is S3 part
    number i+1 (S3 numbers from 1); same-slot re-uploads are last-write-
    wins by keeping only the newest ETag per index."""

    def __init__(self, backend: S3Backend, bucket: str, key: str,
                 metadata: dict | None):
        self._b = backend
        self._bucket = bucket
        self._key = key
        resp = backend._s3.create_multipart_upload(
            Bucket=bucket, Key=key, Metadata=_encode_meta(metadata))
        self._upload_id = resp["UploadId"]
        self._lock = threading.Lock()
        self._etags: dict[int, str] = {}

    def put_part(self, index: int, data: bytes) -> None:
        index = int(index)
        if index < 0:
            raise ValueError(f"part index must be >= 0, got {index}")
        resp = self._b._s3.upload_part(
            Bucket=self._bucket, Key=self._key, UploadId=self._upload_id,
            PartNumber=index + 1, Body=bytes(data))
        with self._lock:
            self._etags[index] = resp["ETag"]

    def complete(self) -> ObjectMeta:
        with self._lock:
            parts = sorted(self._etags.items())
        self._b._s3.complete_multipart_upload(
            Bucket=self._bucket, Key=self._key, UploadId=self._upload_id,
            MultipartUpload={"Parts": [
                {"PartNumber": i + 1, "ETag": e} for i, e in parts]})
        return self._b.head(self._bucket, self._key)

    def abort(self) -> None:
        self._b._s3.abort_multipart_upload(
            Bucket=self._bucket, Key=self._key, UploadId=self._upload_id)


# ---------------------------------------------------------------------------
# GCS (gcsfs)
# ---------------------------------------------------------------------------


class GCSBackend(StoreBackend):
    """Google Cloud Storage via gcsfs.

    GCS has no part-numbered multipart API; the session stages each part
    as `<key>.__mp-<nonce>/part-<index:09d>` and `complete()` folds them
    into the destination with chained 32-way compose calls (GCS's
    compose limit), ascending by zero-padded index — the same assembly
    order as every other plane. `fs` may be injected for testing.
    """

    _NONCE = 0
    _NONCE_LOCK = threading.Lock()

    def __init__(self, *, project: str | None = None,
                 chunk_size: int = 4 << 20, fs=None):
        if fs is None:
            gcsfs = _require_dep("gcsfs", "GCSBackend", "gcsfs")
            fs = gcsfs.GCSFileSystem(project=project)  # pragma: no cover
        self._fs = fs
        self.chunk_size = int(chunk_size)

    @staticmethod
    def _path(bucket: str, key: str) -> str:  # pragma: no cover
        return f"{bucket}/{_check_key(key)}"

    def create_bucket(self, bucket: str) -> None:  # pragma: no cover
        try:
            self._fs.mkdir(bucket)
        except FileExistsError:
            pass

    def multipart(self, bucket: str, key: str,
                  metadata: dict | None = None) -> "_GcsMultipart":  # pragma: no cover
        return _GcsMultipart(self, bucket, _check_key(key), metadata)

    def get(self, bucket: str, key: str) -> bytes:  # pragma: no cover
        try:
            return self._fs.cat_file(self._path(bucket, key))
        except FileNotFoundError:
            raise ObjectNotFound(f"{bucket}/{key}") from None

    def get_range(self, bucket: str, key: str,
                  start: int, length: int) -> bytes:  # pragma: no cover
        if int(length) <= 0:
            return b""
        start = max(int(start), 0)
        try:
            size = self._fs.info(self._path(bucket, key))["size"]
            end = min(start + int(length), size)
            if start >= end:
                return b""
            return self._fs.cat_file(self._path(bucket, key),
                                     start=start, end=end)
        except FileNotFoundError:
            raise ObjectNotFound(f"{bucket}/{key}") from None

    def head(self, bucket: str, key: str) -> ObjectMeta:  # pragma: no cover
        try:
            info = self._fs.info(self._path(bucket, key))
        except FileNotFoundError:
            raise ObjectNotFound(f"{bucket}/{key}") from None
        raw = info.get("metadata") or {}
        return ObjectMeta(
            key=key, size=int(info["size"]),
            etag=str(info.get("crc32c") or info.get("etag") or ""),
            parts=int(raw.get(_PARTS_KEY, 1)), metadata=_decode_meta(raw))

    def list_objects(self, bucket: str,
                     prefix: str = "") -> list[ObjectMeta]:  # pragma: no cover
        try:
            paths = self._fs.find(f"{bucket}/{prefix}" if prefix else bucket)
        except FileNotFoundError:
            raise ObjectNotFound(bucket) from None
        keys = sorted(p.split("/", 1)[1] for p in paths)
        return [self.head(bucket, k) for k in keys]

    def delete(self, bucket: str, key: str) -> None:  # pragma: no cover
        try:
            self._fs.rm_file(self._path(bucket, key))
        except FileNotFoundError:
            raise ObjectNotFound(f"{bucket}/{key}") from None


class _GcsMultipart(MultipartUpload):  # pragma: no cover - needs network
    """Staged-object multipart for GCS (see GCSBackend docstring)."""

    def __init__(self, backend: GCSBackend, bucket: str, key: str,
                 metadata: dict | None):
        self._b = backend
        self._bucket = bucket
        self._key = key
        self._metadata = dict(metadata or {})
        with GCSBackend._NONCE_LOCK:
            nonce = GCSBackend._NONCE
            GCSBackend._NONCE += 1
        self._stage = f"{key}.__mp-{nonce}"
        self._lock = threading.Lock()
        self._indices: set[int] = set()

    def _part_path(self, index: int) -> str:
        return f"{self._bucket}/{self._stage}/part-{int(index):09d}"

    def put_part(self, index: int, data: bytes) -> None:
        index = int(index)
        if index < 0:
            raise ValueError(f"part index must be >= 0, got {index}")
        # GCS object writes are atomic: a same-index re-upload replaces
        # the staged object wholesale — last-write-wins for free.
        self._b._fs.pipe_file(self._part_path(index), bytes(data))
        with self._lock:
            self._indices.add(index)

    def complete(self) -> ObjectMeta:
        fs = self._b._fs
        with self._lock:
            parts = [self._part_path(i) for i in sorted(self._indices)]
        nparts = max(len(parts), 1)
        dest = f"{self._bucket}/{self._key}"
        # Chained compose: fold 32 at a time until one object remains.
        rank = 0
        while len(parts) > 32:
            folded = []
            for i in range(0, len(parts), 32):
                batch = parts[i:i + 32]
                if len(batch) == 1:
                    folded.append(batch[0])
                    continue
                out = f"{self._bucket}/{self._stage}/fold-{rank:04d}-{i:09d}"
                fs.merge(out, batch)
                folded.append(out)
            parts, rank = folded, rank + 1
        if len(parts) == 1:
            fs.mv(parts[0], dest)
        else:
            fs.merge(dest, parts)
        meta = dict(_encode_meta(self._metadata))
        meta[_PARTS_KEY] = str(nparts)
        fs.setxattrs(dest, metadata=meta)
        self.abort()  # sweep any remaining staged parts/folds
        return self._b.head(self._bucket, self._key)

    def abort(self) -> None:
        try:
            self._b._fs.rm(f"{self._bucket}/{self._stage}", recursive=True)
        except FileNotFoundError:
            pass


__all__ = ["S3Backend", "GCSBackend"]
