"""Serverless execution: one task per invocation, no state but the store.

The library's thesis (paper §2.6, Exoshuffle's portability claim) is
that shuffle-as-a-library runs on whatever execution substrate the
application already has, because everything recovery needs lives in the
store: spill offsets ride in object metadata, commits are atomic +
idempotent multipart completes, and output bytes are deterministic
functions of (task, plan, input). This module cashes that claim in on
the most hostile substrate there is — a FaaS platform where an executor
is *one function invocation*: no warm process to heartbeat, no local
spill tier, no shared offsets dict, a hard memory bound, and a billing
meter that charges GB-seconds per invocation.

Three pieces:

  * `invoke(event)` — the function handler. A single JSON event (the
    Lambda payload) carries everything: store endpoint, bucket, plan,
    phase, ONE task id, memory limit. The handler rebuilds its world
    from the event alone — à la shuffle/worker_main's subprocess spec —
    runs exactly that task, and returns a JSON-able result with the
    billed duration, measured peak memory, and per-invocation
    (retry-inflated) request counts. Reduce-side run offsets are
    re-read from spill-object metadata on every invocation; nothing
    survives between calls except what the store holds.
  * `FunctionWorker` — the unchanged `Worker` protocol over a loop of
    invocations, so the existing ElasticPhaseDriver/ClaimPool drive the
    fleet: durable-multipart-commit recovery, speculation loser-abort
    gates, and byte/etag-identity all transfer with ZERO new recovery
    code. The driver's gates/requeue hooks are passed to `invoke` as
    the out-of-band control plane (on a real platform: a claim table
    the function consults before CompleteMultipartUpload).
  * `InvocationDriver` — convenience front end building the fleet and
    running the sort job, plus the per-invocation accounting feeding
    core/cost_model's GB-second pricing leg.

Emulation honesty notes. A "container" (the memo below) models FaaS
warm starts: per (worker, job-config) we keep exactly the state a real
platform keeps between invocations of one sandbox — the loaded runtime,
here the compiled per-instance XLA sort — and nothing
correctness-relevant; cold starts are modeled as injectable latency
(`cold_start_s`) charged to the first invocation of each worker's
sandbox, excluded from the billed duration (Lambda does not bill
managed cold-start init). All in-process invocations share this host's
device mesh, so map compute serializes on a module lock exactly as the
thread fleet serializes on its shared WaveSorter lock; a real
deployment gives every function its own runtime, making the map phase
embarrassingly parallel — which is the point of the sweep in
benchmarks/bench_serverless.py.
"""
from __future__ import annotations

import dataclasses
import json
import threading
import time
import weakref
from typing import Callable, Mapping

from repro.io.backends import ObjectNotFound, StoreStats
from repro.io.middleware import KillSwitchMiddleware, MetricsMiddleware
from repro.shuffle.executor import Worker, WorkerFailure


def _require(cond: bool, knob: str, value, why: str) -> None:
    if not cond:
        raise ValueError(f"{knob}={value!r}: {why}")


# ---------------------------------------------------------------------------
# Endpoint registry: the in-process stand-in for a store endpoint URL
# ---------------------------------------------------------------------------

# Token -> live store object. A real event names an endpoint + creds;
# in-process the event carries an opaque token resolved here. Weak so a
# finished job's store doesn't outlive its owner.
_ENDPOINTS: "weakref.WeakValueDictionary[str, object]" = (
    weakref.WeakValueDictionary())
_ENDPOINT_LOCK = threading.Lock()
_ENDPOINT_SEQ = 0


def register_endpoint(store) -> str:
    """Register a live store object; returns the token an invocation
    event's store spec (`{"kind": "endpoint", "token": ...}`) resolves."""
    global _ENDPOINT_SEQ
    with _ENDPOINT_LOCK:
        _ENDPOINT_SEQ += 1
        token = f"ep-{_ENDPOINT_SEQ}"
        _ENDPOINTS[token] = store
    return token


def _resolve_store(spec: dict):
    kind = spec.get("kind")
    if kind == "endpoint":
        store = _ENDPOINTS.get(spec.get("token", ""))
        if store is None:
            raise ValueError(
                f"store={spec!r}: endpoint token is not registered in this "
                "process (register_endpoint the live store first)")
        return store
    if kind in ("fs", "tiered"):
        # Real deployments rebuild a store from config, exactly like the
        # subprocess worker; reuse its builder (metrics included).
        from repro.shuffle.worker_main import _build_store
        return _build_store(spec)
    raise ValueError(
        f"store={spec!r}: unknown store spec kind (expected endpoint, fs, "
        "or tiered)")


# ---------------------------------------------------------------------------
# Warm containers + the shared-host device lock
# ---------------------------------------------------------------------------

# Container memo: (worker, job-config JSON) -> the map-side SortMapOp,
# whose per-instance jax.jit is the expensive thing a warm sandbox
# amortizes. Per-WORKER key: real sandboxes are never shared across
# concurrent executors, so neither is this state (and each worker's
# phase loop is serial, so no locking beyond the dict's).
_CONTAINERS: dict[str, object] = {}
_CONTAINER_LOCK = threading.Lock()
_CONTAINER_CAP = 32

# Every in-process invocation shares ONE host device mesh; serialize the
# device legs like the thread fleet's shared WaveSorter lock does.
_DEVICE_LOCK = threading.Lock()


def _container_key(event: dict) -> str:
    cfg = {k: event.get(k) for k in
           ("worker", "bucket", "plan", "mesh_devices", "axis",
            "boundaries", "store")}
    return json.dumps(cfg, sort_keys=True)


def _map_op_for(event: dict):
    """The warm-start memo: reuse the worker-sandbox's compiled sorter
    across map invocations; build (and cache) on a cold start."""
    from repro.core.compat import make_mesh
    from repro.shuffle.sort import SortMapOp

    key = _container_key(event)
    with _CONTAINER_LOCK:
        op = _CONTAINERS.get(key)
    if op is not None:
        return op
    mesh = make_mesh((int(event["mesh_devices"]),), (event["axis"],))
    bounds = event.get("boundaries")
    op = SortMapOp(_plan_from(event), mesh, event["axis"],
                   boundaries=None if bounds is None else bounds)
    with _CONTAINER_LOCK:
        if len(_CONTAINERS) >= _CONTAINER_CAP:
            _CONTAINERS.clear()  # platform reaped idle sandboxes
        _CONTAINERS[key] = op
    return op


def _plan_from(event: dict):
    from repro.core.external_sort import ExternalSortPlan
    return ExternalSortPlan(**event["plan"])


# ---------------------------------------------------------------------------
# The handler
# ---------------------------------------------------------------------------


def invoke(event: dict, *, gate: Callable[[int], bool] | None = None,
           requeue: Callable[[int, BaseException], bool] | None = None) -> dict:
    """Run ONE task from a single JSON event; return the billing record.

    `gate`/`requeue` are the out-of-band control plane a platform would
    provide (a claim table the function consults): `gate(task) -> bool`
    is the speculation loser-abort predicate polled per fetched map
    chunk / merge window and immediately before the multipart commit;
    `requeue(task, exc) -> handled` reports a vanished reduce input.
    Everything else — store, plan, task — comes from the event alone.
    """
    from repro.shuffle import runtime as rt

    phase = event["phase"]
    task = int(event["task"])
    bucket = event["bucket"]
    plan = _plan_from(event)
    limit = int(event.get("memory_limit_bytes")
                or plan.reduce_memory_budget_bytes or 0)
    _require(limit > 0, "memory_limit_bytes", event.get("memory_limit_bytes"),
             "a function invocation needs a memory bound (set it in the "
             "event or via plan.reduce_memory_budget_bytes)")
    # Fresh per-invocation metrics over the endpoint's store: the
    # invocation's own retry-inflated request counts are its bill.
    store = MetricsMiddleware(_resolve_store(event["store"]))
    control = rt.JobControl()
    timeline = rt.PhaseTimeline(origin=time.perf_counter())
    committed: list[int] = []
    requeued: list[int] = []

    popped = [task]
    def pop_once():
        return popped.pop() if popped else None

    t0 = time.perf_counter()
    if phase == "map":
        map_op = _map_op_for(event)
        # Billed LIST per invocation: task planning state is rebuilt
        # from the store, never assumed warm.
        map_op.plan_tasks(store, bucket)
        _require(task < len(map_op.waves), "task", task,
                 f"map phase has {len(map_op.waves)} tasks")
        # The map working set is one wave's records — the number the
        # function's memory size must cover. Enforced up front: the
        # wave either fits the sandbox or the invocation must not start.
        peak_bytes = int(plan.records_per_wave) * int(plan.record_bytes)
        if event.get("memory_limit_bytes") and peak_bytes > limit:
            raise ValueError(
                f"memory_limit_bytes={limit}: one map wave is {peak_bytes} "
                "bytes (records_per_wave * record_bytes) — shrink the wave "
                "or raise the function's memory size")
        with _DEVICE_LOCK:
            rt.run_map_tasks(
                store, bucket, map_op, pop_once, plan=plan,
                timeline=timeline, control=control,
                tag_prefix=f"{event['worker']}/inv-{task}/",
                on_done=committed.append, commit_gate=gate)
    elif phase == "reduce":
        peak_bytes = _invoke_reduce(event, store, plan, limit, pop_once,
                                    timeline, control, committed, requeued,
                                    gate=gate, requeue=requeue)
    else:
        raise ValueError(f"phase={phase!r}: expected 'map' or 'reduce'")
    control.raise_first()
    if phase == "reduce" and peak_bytes > limit:
        raise ValueError(
            f"memory_limit_bytes={limit}: measured merge peak {peak_bytes} "
            "bytes exceeded the invocation's memory bound")
    return {
        "worker": event["worker"], "phase": phase, "task": task,
        "seconds": time.perf_counter() - t0,
        "peak_bytes": int(peak_bytes),
        "committed": bool(committed), "requeued": bool(requeued),
        "stats": dataclasses.asdict(store.stats_snapshot()),
    }


def _invoke_reduce(event, store, plan, limit, pop_once, timeline, control,
                   committed, requeued, *, gate, requeue):
    """One reduce partition, fully store-recovered: a FRESH map op's run
    offsets are reloaded from spill metadata (no shared offsets dict —
    the invocation may merge runs a long-dead executor spilled), the
    single reducer gets the WHOLE per-invocation memory budget, and
    peak merge bytes are measured against it."""
    import numpy as np

    from repro.core.compat import make_mesh
    from repro.shuffle import runtime as rt
    from repro.shuffle.sort import DeviceMergeReduceOp, MergeReduceOp, SortMapOp

    bucket = event["bucket"]
    bounds = event.get("boundaries")
    map_op = SortMapOp(plan, make_mesh((int(event["mesh_devices"]),),
                                       (event["axis"],)), event["axis"],
                       boundaries=None if bounds is None else bounds)
    num_tasks = map_op.plan_tasks(store, bucket)

    def refresh_offsets() -> None:
        for meta in store.list_objects(bucket, plan.spill_prefix):
            md = meta.metadata
            if {"wave", "worker", "reducer_offsets"} <= md.keys():
                map_op.spill_offsets[(int(md["wave"]), int(md["worker"]))] = (
                    np.asarray(md["reducer_offsets"], np.int64))

    refresh_offsets()
    device = getattr(plan, "reduce_merge_impl", "numpy") == "device"
    reduce_op = (DeviceMergeReduceOp if device else MergeReduceOp)(plan, map_op)

    class _StoreBackedSources:
        """Mirror of worker_main's proxy: a KeyError from the offsets
        dict means a spill this invocation hasn't seen — refresh from
        the store; truly gone means ObjectNotFound (requeue, not crash)."""

        def __getattr__(self, attr):
            return getattr(reduce_op, attr)

        def sources(self, r: int):
            try:
                return reduce_op.sources(r)
            except KeyError:
                refresh_offsets()
                try:
                    return reduce_op.sources(r)
                except KeyError as e:
                    raise ObjectNotFound(
                        f"spill run offsets missing for partition {r}: {e}")

    def on_requeue(r, exc) -> bool:
        handled = bool(requeue(r, exc)) if requeue is not None else False
        if handled:
            requeued.append(r)
        return handled

    governor = rt.AdaptiveBudgetGovernor(
        budget=limit, chunk_cap=plan.merge_chunk_bytes,
        record_bytes=plan.record_bytes, slots=1, partitions=1)
    peak = rt.PeakTracker()
    shared = rt.ReduceShared(
        plan=plan, bucket=bucket, reduce_op=_StoreBackedSources(),
        governor=governor, timeline=timeline, peak=peak, control=control)
    scheduler = rt.ReduceScheduler(
        store, shared, width=1, runs_hint=num_tasks,
        tag_prefix=f"{event['worker']}/inv-", fatal=(WorkerFailure,),
        requeue=(ObjectNotFound,), on_requeue=on_requeue,
        commit_gate=gate, gate_poll=True)
    if device:
        with _DEVICE_LOCK:
            scheduler.run(pop_once, on_done=committed.append)
    else:
        scheduler.run(pop_once, on_done=committed.append)
    return int(peak.peak)


# ---------------------------------------------------------------------------
# The Worker-protocol front: a loop of invocations
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class InvocationRecord:
    """One function invocation's billing record (cost_model inputs)."""

    worker: str
    phase: str
    task: int
    seconds: float  # billed handler duration (cold start excluded)
    cold_start_s: float  # injected init latency paid before the handler
    peak_bytes: int  # measured (reduce) / working-set (map) memory
    committed: bool  # the task's output durably committed
    requeued: bool  # the attempt aborted on vanished input
    stats: StoreStats  # this invocation's retry-inflated requests


class FunctionWorker(Worker):
    """A serverless executor behind the unchanged Worker protocol.

    Each popped task becomes exactly one `invoke()` with a fresh JSON
    event (round-tripped through json.dumps to enforce purity — nothing
    can leak into the handler except the event and the store). Fault
    injection mirrors executor.FaultyWorker: `die_after_invocations`
    kills the worker at the pop BEFORE that invocation
    (pre-commit-deterministic), `fail_after_requests` trips a kill
    switch mid-invocation so in-flight multipart sessions are left
    dangling for the driver's durable-commit recovery to clean up.
    `last_beat()` stays None: invocations fail synchronously, there is
    no warm process to go silent.
    """

    def __init__(self, name: str, *, store, bucket: str, plan,
                 mesh_devices: int = 8, axis: str = "w", boundaries=None,
                 cold_start_s: float = 0.0,
                 memory_limit_bytes: int | None = None,
                 die_after_invocations: int | None = None,
                 fail_after_requests: int | None = None):
        _require(cold_start_s >= 0.0, "cold_start_s", cold_start_s,
                 "injected init latency must be >= 0 seconds")
        _require(memory_limit_bytes is None or memory_limit_bytes > 0,
                 "memory_limit_bytes", memory_limit_bytes,
                 "the invocation memory bound must be positive")
        self.name = name
        self._kill = KillSwitchMiddleware(
            store,
            exc_factory=lambda: WorkerFailure(
                f"{self.name}: store unreachable (invocation killed)"),
            fail_after_requests=fail_after_requests)
        # The driver-facing view: per-worker attribution, severed by
        # fence(). Invocations resolve THIS view via the endpoint token,
        # so a fenced worker's in-flight invocation dies at its next
        # store request — a mid-invocation kill, not a polite drain.
        self.store = MetricsMiddleware(self._kill)
        self._token = register_endpoint(self.store)
        self.bucket = bucket
        self.plan = plan
        self.mesh_devices = int(mesh_devices)
        self.axis = axis
        self.boundaries = (None if boundaries is None
                           else [int(b) for b in np_asarray_1d(boundaries)])
        self.cold_start_s = float(cold_start_s)
        self.memory_limit_bytes = memory_limit_bytes
        self.invocations: list[InvocationRecord] = []
        self._lock = threading.Lock()
        self._die_after = die_after_invocations
        self._invoked = 0
        self._warm = False

    # -- event construction ---------------------------------------------

    def _event(self, phase: str, task: int) -> dict:
        event = {
            "version": 1,
            "worker": self.name,
            "phase": phase,
            "task": int(task),
            "bucket": self.bucket,
            "plan": dataclasses.asdict(self.plan),
            "mesh_devices": self.mesh_devices,
            "axis": self.axis,
            "boundaries": self.boundaries,
            "store": {"kind": "endpoint", "token": self._token},
            "memory_limit_bytes": self.memory_limit_bytes,
        }
        # The purity fence: the handler sees decoded JSON, nothing else.
        return json.loads(json.dumps(event))

    # -- the invocation loop ----------------------------------------------

    def _phase_loop(self, phase: str, ctx, pop_next, on_done) -> None:
        name = self.name
        if phase == "map":
            gate = (None if ctx.map_commit_gate is None
                    else (lambda g: ctx.map_commit_gate(name, g)))
            requeue_cb = None
        else:
            gate = (None if ctx.commit_gate is None
                    else (lambda r: ctx.commit_gate(name, r)))
            requeue_cb = (None if ctx.on_requeue is None
                          else (lambda r, e: ctx.on_requeue(name, r, e)))
        while True:
            with self._lock:
                if (self._die_after is not None
                        and self._invoked >= self._die_after):
                    # Injected platform failure at the pop, BEFORE any
                    # claim — pre-commit-deterministic, like
                    # FaultyWorker's task budget.
                    self._kill.trip()
                    raise WorkerFailure(
                        f"{name}: injected invocation budget exhausted")
            task = pop_next()
            if task is None:
                return
            cold = 0.0
            if not self._warm:
                cold = self.cold_start_s
                if cold:
                    time.sleep(cold)
                self._warm = True
            result = invoke(self._event(phase, task),
                            gate=gate, requeue=requeue_cb)
            with self._lock:
                self._invoked += 1
            self.invocations.append(InvocationRecord(
                worker=name, phase=phase, task=int(task),
                seconds=float(result["seconds"]), cold_start_s=cold,
                peak_bytes=int(result["peak_bytes"]),
                committed=bool(result["committed"]),
                requeued=bool(result["requeued"]),
                stats=StoreStats(**result["stats"])))
            if result["committed"]:
                on_done(task)

    def run_map_phase(self, ctx, pop_next, on_done):
        self._phase_loop("map", ctx, pop_next, on_done)

    def run_reduce_phase(self, ctx, pop_next, on_done):
        self._phase_loop("reduce", ctx, pop_next, on_done)

    def fence(self) -> None:
        self._kill.trip()


def np_asarray_1d(boundaries):
    import numpy as np
    return np.asarray(boundaries).reshape(-1)


# ---------------------------------------------------------------------------
# Fleet front end + accounting
# ---------------------------------------------------------------------------


class InvocationDriver:
    """Build a FunctionWorker fleet and run the sort as a serverless job.

    Composes the existing pieces unchanged: `sort_shuffle_job(...)
    .run(worker_list=fleet, fleet=FleetPlan(...))` — the elastic
    ClaimPool/driver provide claims, speculation, and death recovery;
    the functions provide nothing but invocations. `die_after_invocations`
    / `fail_after_requests` map worker index -> injected budget.
    """

    def __init__(self, store, bucket: str, *, plan, workers: int = 1,
                 mesh_devices: int = 8, axis: str = "w", boundaries=None,
                 fleet=None, tracer=None, cold_start_s: float = 0.0,
                 memory_limit_bytes: int | None = None,
                 die_after_invocations: Mapping[int, int] | None = None,
                 fail_after_requests: Mapping[int, int] | None = None):
        _require(workers >= 1, "workers", workers,
                 "a serverless fleet needs >= 1 concurrent function")
        self.store = store
        self.bucket = bucket
        self.plan = plan
        self.mesh_devices = int(mesh_devices)
        self.axis = axis
        self.boundaries = boundaries
        self.tracer = tracer
        self._fleet = fleet
        self.wall_seconds = 0.0
        self.report = None
        die = dict(die_after_invocations or {})
        failreq = dict(fail_after_requests or {})
        self.workers = [
            FunctionWorker(
                f"fn{i}", store=store, bucket=bucket, plan=plan,
                mesh_devices=mesh_devices, axis=axis, boundaries=boundaries,
                cold_start_s=cold_start_s,
                memory_limit_bytes=memory_limit_bytes,
                die_after_invocations=die.get(i),
                fail_after_requests=failreq.get(i))
            for i in range(int(workers))
        ]

    def run(self):
        from repro.core.compat import make_mesh
        from repro.shuffle.elastic import FleetPlan
        from repro.shuffle.sort import sort_shuffle_job

        job = sort_shuffle_job(
            self.store, self.bucket,
            mesh=make_mesh((self.mesh_devices,), (self.axis,)),
            axis_names=self.axis, plan=self.plan, tracer=self.tracer,
            boundaries=self.boundaries)
        t0 = time.perf_counter()
        # A function has no local spill tier to lose: its spills went to
        # the object store, which outlives every invocation. A dead
        # function therefore loses only its in-flight attempt — the
        # correlated-loss recovery (a VM taking its NVMe down with it)
        # stays off unless an explicit FleetPlan turns it on.
        self.report = job.run(
            worker_list=self.workers,
            fleet=self._fleet or FleetPlan(lose_spill_on_death=False))
        self.wall_seconds = time.perf_counter() - t0
        return self.report

    # -- accounting -------------------------------------------------------

    def invocations(self) -> list[InvocationRecord]:
        return [r for wk in self.workers for r in wk.invocations]

    def profiles(self):
        from repro.core.cost_model import InvocationProfile
        return [InvocationProfile(seconds=r.seconds, peak_bytes=r.peak_bytes)
                for r in self.invocations()]

    def request_stats(self) -> StoreStats:
        """The serverless billing view: the sum of every invocation's
        own retry-inflated request counters."""
        total = StoreStats()
        for r in self.invocations():
            total = total + r.stats
        return total

    def tco(self, *, data_bytes: int, job_hours: float | None = None,
            reduce_hours: float | None = None, params=None):
        """Measured serverless TCO for this run (see core/cost_model)."""
        from repro.core.cost_model import (ServerlessCostParams,
                                           measured_serverless_tco)
        if job_hours is None:
            job_hours = self.wall_seconds / 3600.0
        if reduce_hours is None:
            reduce_hours = sum(r.seconds for r in self.invocations()
                               if r.phase == "reduce") / 3600.0
        return measured_serverless_tco(
            self.profiles(), self.request_stats(),
            job_hours=job_hours, reduce_hours=reduce_hours,
            data_bytes=data_bytes,
            params=params or ServerlessCostParams())


__all__ = ["FunctionWorker", "InvocationDriver", "InvocationRecord",
           "invoke", "register_endpoint"]
