"""MetricsRegistry: labeled counters / gauges / histograms + renderers.

The aggregated side of the observability layer: where the EventLog keeps
individual occurrences, the registry keeps totals — request attempts vs
successes per (kind, outcome, tier), bytes per phase, retry-delay and
governor-grant histograms, re-executed task counts. One lock, plain-dict
snapshots, no dependencies — the report embeds `snapshot()` verbatim and
the benchmark artifacts are built from it.

`render()` / `render_report()` are the human-readable formatters the
examples print instead of hand-rolled f-strings.
"""
from __future__ import annotations

import threading

_Key = tuple  # (name, ((label, value), ...)) — hashable, sorted labels


def _key(name: str, labels: dict) -> _Key:
    return (name, tuple(sorted(labels.items())))


def _fmt(key: _Key) -> str:
    name, labels = key
    if not labels:
        return name
    inner = ",".join(f"{k}={v}" for k, v in labels)
    return f"{name}{{{inner}}}"


class MetricsRegistry:
    """Thread-safe labeled metrics: counters, gauges, histograms.

    Counters accumulate, gauges overwrite, histograms keep summary
    moments (count / sum / min / max) — enough for the report and the
    benchmark trajectory without unbounded storage.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict[_Key, float] = {}
        self._gauges: dict[_Key, float] = {}
        self._hists: dict[_Key, list[float]] = {}  # [count, sum, min, max]

    # -- writers -----------------------------------------------------------

    def counter(self, name: str, value: float = 1, **labels) -> None:
        k = _key(name, labels)
        with self._lock:
            self._counters[k] = self._counters.get(k, 0) + value

    def gauge(self, name: str, value: float, **labels) -> None:
        with self._lock:
            self._gauges[_key(name, labels)] = value

    def observe(self, name: str, value: float, **labels) -> None:
        k = _key(name, labels)
        with self._lock:
            h = self._hists.get(k)
            if h is None:
                self._hists[k] = [1, value, value, value]
            else:
                h[0] += 1
                h[1] += value
                h[2] = min(h[2], value)
                h[3] = max(h[3], value)

    # -- readers -----------------------------------------------------------

    def counter_value(self, name: str, **labels) -> float:
        """The exact (name, labels) counter, 0 when never incremented."""
        with self._lock:
            return self._counters.get(_key(name, labels), 0)

    def total(self, name: str, **labels) -> float:
        """Sum of every `name` counter whose labels include `labels`
        (subset match) — e.g. total("store.requests", kind="get") sums
        over outcomes and tiers."""
        want = set(labels.items())
        with self._lock:
            return sum(v for (n, lbls), v in self._counters.items()
                       if n == name and want.issubset(lbls))

    def snapshot(self) -> dict:
        """Plain-dict copy: {"counters": {...}, "gauges": {...},
        "histograms": {name: {count, sum, min, max, mean}}} with
        formatted `name{label=value,...}` keys, sorted for stable
        diffs/artifacts."""
        with self._lock:
            counters = {_fmt(k): v for k, v in self._counters.items()}
            gauges = {_fmt(k): v for k, v in self._gauges.items()}
            hists = {
                _fmt(k): {"count": h[0], "sum": h[1], "min": h[2],
                          "max": h[3], "mean": h[1] / h[0] if h[0] else 0.0}
                for k, h in self._hists.items()
            }
        return {"counters": dict(sorted(counters.items())),
                "gauges": dict(sorted(gauges.items())),
                "histograms": dict(sorted(hists.items()))}

    def render(self, prefix: str = "") -> list[str]:
        """Human-readable lines, optionally filtered by name prefix."""
        snap = self.snapshot()
        lines = []
        for section in ("counters", "gauges"):
            for name, v in snap[section].items():
                if name.startswith(prefix):
                    val = f"{v:g}" if isinstance(v, float) else str(v)
                    lines.append(f"{section[:-1]:<9s} {name:<56s} {val}")
        for name, h in snap["histograms"].items():
            if name.startswith(prefix):
                lines.append(
                    f"histogram {name:<56s} n={h['count']} "
                    f"mean={h['mean']:g} min={h['min']:g} max={h['max']:g}")
        return lines


def render_report(report) -> list[str]:
    """The standard end-of-run summary, formatted from any ShuffleReport
    or ClusterShuffleReport (duck-typed — no shuffle import). Replaces
    the hand-rolled [spans]/[requests]/per-tier f-strings the examples
    used to carry."""
    rep = getattr(report, "report", report)  # unwrap a cluster report
    lines = []

    ph = rep.phase_seconds or {}
    order = ("map.wait", "map.compute", "map.spill",
             "reduce.fetch", "reduce.merge", "reduce.upload")
    named = [n for n in order if n in ph] + sorted(set(ph) - set(order))
    if named:
        lines.append("[spans] " + "  ".join(
            f"{n}={ph[n]:.2f}s" for n in named))
    reduce_busy = sum(ph.get(k, 0.0) for k in
                      ("reduce.fetch", "reduce.merge", "reduce.upload"))
    if rep.reduce_seconds > 0 and reduce_busy > 0:
        lines.append(
            f"[spans] reduce concurrency: {reduce_busy:.2f}s of phase work "
            f"in {rep.reduce_seconds:.2f}s wall = "
            f"{reduce_busy / rep.reduce_seconds:.2f}x overlap")
    if rep.spans_dropped:
        lines.append(f"[spans] {rep.spans_dropped} spans beyond the "
                     "recorder cap were dropped (totals stay exact)")

    for tier, s in (rep.tier_stats or {}).items():
        lines.append(
            f"[{tier:>7s}] GET={s.get_requests} PUT={s.put_requests} "
            f"DEL={s.delete_requests} read={s.bytes_read / 1e6:.1f}MB "
            f"written={s.bytes_written / 1e6:.1f}MB throttled={s.throttled} "
            f"retries={s.retries} stall={s.stall_seconds:.2f}s")
    lines.append(
        f"[requests] total GET={rep.stats.get_requests} "
        f"PUT={rep.stats.put_requests} retries={rep.stats.retries} "
        f"throttled={rep.stats.throttled}")
    return lines


__all__ = ["MetricsRegistry", "render_report"]
