"""Unified observability: trace contexts, event log, metrics, exporters.

The paper's headline claims (5378 s, $97 for 100 TB) are *measured*
claims, and the repro measures the same quantities — but before this
package they lived in separate layers with no shared identity:
PhaseTimeline spans in the shuffle runtime, attempt-counting
MetricsMiddleware in the store stack, PeakTracker watermarks in the
reduce scheduler. This package supplies the shared identity and the two
export paths:

  context.py — TraceContext (job -> phase -> task -> worker), carried in
      a contextvars.ContextVar and explicitly re-bound across thread
      pools (contexts do NOT propagate to pool threads on their own).

  events.py  — the bounded, thread-safe EventLog plus the Tracer that
      every layer reports into: timeline spans, store request attempts,
      retries, governor grants, cluster round/death events.

  metrics.py — MetricsRegistry (counters / gauges / histograms keyed by
      name + labels) and the human-readable renderers the examples use
      for their end-of-run summaries.

  trace.py   — Chrome trace-event JSON export (perfetto /
      chrome://tracing loadable, workers as tracks).

Everything here is stdlib-only and import-cycle-free: io/ and shuffle/
import obs, never the reverse.
"""
from repro.obs.context import (TraceContext, bind_context, current_context,
                               use_context)
from repro.obs.events import EventLog, Tracer
from repro.obs.metrics import MetricsRegistry, render_report
from repro.obs.trace import chrome_trace, write_chrome_trace

__all__ = [
    "EventLog",
    "MetricsRegistry",
    "TraceContext",
    "Tracer",
    "bind_context",
    "chrome_trace",
    "current_context",
    "render_report",
    "use_context",
    "write_chrome_trace",
]
