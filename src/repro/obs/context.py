"""TraceContext: the job -> phase -> task -> worker identity chain.

One frozen dataclass rides a contextvars.ContextVar through the whole
execution: the job sets the root, the map loop / reduce scheduler narrow
it per task, and the store middleware reads it to attribute every
GET/PUT attempt to the task that issued it.

The one sharp edge is threads: a ContextVar set on thread A is invisible
on a pool thread B, so every hand-off into a thread pool must re-bind
explicitly. `bind_context(fn)` captures the caller's context at bind
time and restores it around `fn` wherever it eventually runs — the
staging AsyncWriter does this for every submitted write, and the map
loop binds each prefetched split load to its task's context.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Callable


@dataclasses.dataclass(frozen=True)
class TraceContext:
    """Where in the job the current code is running.

    `task` is the timeline tag convention: "g3" for map task 3, "r12"
    for reduce partition 12 (ints are accepted and normalized by the
    narrowing helpers' callers). `worker` is the cluster worker name
    ("w0"...) or "host" for the single-host driver.
    """

    job: str
    phase: str = ""  # "map" | "reduce" | "" (outside any phase)
    task: str | None = None
    worker: str = ""

    def with_phase(self, phase: str) -> "TraceContext":
        return dataclasses.replace(self, phase=phase)

    def with_task(self, task: "str | int | None") -> "TraceContext":
        return dataclasses.replace(
            self, task=task if task is None else str(task))

    def with_worker(self, worker: str) -> "TraceContext":
        return dataclasses.replace(self, worker=worker)


_CURRENT: contextvars.ContextVar[TraceContext | None] = contextvars.ContextVar(
    "repro_trace_context", default=None)


def current_context() -> TraceContext | None:
    """The TraceContext bound on this thread, or None outside any."""
    return _CURRENT.get()


@contextlib.contextmanager
def use_context(ctx: TraceContext | None):
    """Bind `ctx` for the duration of the with-block (no-op for None)."""
    if ctx is None:
        yield
        return
    token = _CURRENT.set(ctx)
    try:
        yield
    finally:
        _CURRENT.reset(token)


def bind_context(fn: Callable, ctx: TraceContext | None = None) -> Callable:
    """Wrap `fn` so it runs under `ctx` (default: the context bound on
    the *calling* thread right now) wherever it is later invoked — the
    explicit re-bind that carries attribution across thread pools."""
    if ctx is None:
        ctx = current_context()
    if ctx is None:
        return fn

    def bound(*args, **kwargs):
        with use_context(ctx):
            return fn(*args, **kwargs)

    return bound


__all__ = ["TraceContext", "bind_context", "current_context", "use_context"]
