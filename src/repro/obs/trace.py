"""Chrome trace-event JSON export: the job as tracks per worker.

Produces the `{"traceEvents": [...]}` format both chrome://tracing and
https://ui.perfetto.dev load directly. Each worker ("w0".."wN" in
cluster mode, "host" single-host) is one named thread track; spans are
"X" (complete) events in microseconds, instants (retries, worker
deaths, round barriers) are "i" events. Event args carry the structured
attribution — phase, task, outcome, bytes, tier — so a store GET can be
traced back to the reduce partition that issued it by clicking it.

See docs/OBSERVABILITY.md for how to read a failover run's trace.
"""
from __future__ import annotations

import json

from repro.obs.events import Tracer

_CORE = ("name", "t", "dur", "worker")


def chrome_trace(tracer: Tracer) -> dict:
    """Convert the tracer's event log to a Chrome trace-event dict.

    Track (tid) assignment is by sorted worker name, so the same fleet
    always gets the same track order — stable across runs and
    deterministic under fixed scheduling.
    """
    events = tracer.log.events()
    workers = sorted({e.get("worker") or "host" for e in events})
    tid = {w: i + 1 for i, w in enumerate(workers)}

    out: list[dict] = [{
        "ph": "M", "name": "process_name", "pid": 1, "tid": 0,
        "args": {"name": tracer.job},
    }]
    for w in workers:
        out.append({"ph": "M", "name": "thread_name", "pid": 1,
                    "tid": tid[w], "args": {"name": w}})
    for e in events:
        w = e.get("worker") or "host"
        args = {k: v for k, v in e.items()
                if k not in _CORE and v is not None and v != ""}
        rec = {"name": e["name"], "pid": 1, "tid": tid[w],
               "ts": round(e["t"] * 1e6, 3),
               "cat": e.get("phase") or "job", "args": args}
        if e["dur"] > 0:
            rec["ph"] = "X"
            rec["dur"] = round(e["dur"] * 1e6, 3)
        else:
            rec["ph"] = "i"
            rec["s"] = "t"  # thread-scoped instant marker
        out.append(rec)
    return {
        "traceEvents": out,
        "displayTimeUnit": "ms",
        "otherData": {"job": tracer.job,
                      "events_dropped": tracer.log.dropped},
    }


def write_chrome_trace(path: str, tracer: Tracer) -> dict:
    """Write the Chrome trace JSON to `path`; returns the dict too."""
    trace = chrome_trace(tracer)
    with open(path, "w", encoding="utf-8") as f:
        json.dump(trace, f)
    return trace


__all__ = ["chrome_trace", "write_chrome_trace"]
