"""The event spine: a bounded EventLog and the Tracer every layer feeds.

Events are flat structured dicts on one monotonic clock (the Tracer's
perf_counter origin), so a reduce task's merge span and the ranged GETs
it issued sort onto one timeline. The log is bounded and drop-counting
like shuffle/runtime.PhaseTimeline: a huge run cannot hoard memory, and
the export records how much was dropped instead of silently truncating.

Event schema (every exporter consumes exactly this):

    {"name":   "reduce.fetch" | "store.get" | "cluster.round" | ...,
     "t":      seconds since the tracer origin (float),
     "dur":    span length in seconds (0.0 for instant events),
     "phase":  "map" | "reduce" | "",
     "task":   "g3" | "r12" | None,
     "worker": "w0" | "host" | "",
     ...:      free-form attrs (outcome, nbytes, tier, attempt, ...)}
"""
from __future__ import annotations

import threading
import time
from typing import Callable

from repro.obs.context import TraceContext, current_context
from repro.obs.metrics import MetricsRegistry


class EventLog:
    """Bounded, thread-safe, append-only event buffer.

    Keeps the first `max_events` events (the PhaseTimeline convention:
    oldest kept, so the job's structure survives even when a long tail
    of store events overflows) and counts the rest in `dropped`.
    """

    def __init__(self, max_events: int = 65536):
        self._lock = threading.Lock()
        self._events: list[dict] = []
        self._max = int(max_events)
        self.dropped = 0

    def emit(self, event: dict) -> None:
        with self._lock:
            if len(self._events) < self._max:
                self._events.append(event)
            else:
                self.dropped += 1

    def events(self) -> list[dict]:
        with self._lock:
            return list(self._events)

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)


class Tracer:
    """One job's observability hub: EventLog + MetricsRegistry + clock.

    Created by ShuffleSession when the caller didn't bring one; passed
    explicitly (examples, benchmarks) when the same tracer should also
    see the store stack (io/middleware.TracingMiddleware) and span
    multiple jobs on one timeline. All event times are relative to
    `origin` — one perf_counter zero for spans and store attempts alike.
    """

    def __init__(self, job: str = "job", *, origin: float | None = None,
                 max_events: int = 65536,
                 registry: MetricsRegistry | None = None):
        self.job = job
        self.origin = time.perf_counter() if origin is None else float(origin)
        self.log = EventLog(max_events=max_events)
        self.registry = registry if registry is not None else MetricsRegistry()
        self.root = TraceContext(job=job)

    # -- emission ----------------------------------------------------------

    def event(self, name: str, start: float, end: float | None = None, *,
              ctx: TraceContext | None = None, **attrs) -> None:
        """Record one event; `start`/`end` are absolute perf_counter
        readings. Attribution comes from `ctx`, defaulting to the
        calling thread's bound context (then the job root)."""
        if ctx is None:
            ctx = current_context() or self.root
        ev = {"name": name, "t": start - self.origin,
              "dur": 0.0 if end is None else max(end - start, 0.0),
              "phase": ctx.phase, "task": ctx.task, "worker": ctx.worker}
        if attrs:
            ev.update(attrs)
        self.log.emit(ev)

    def instant(self, name: str, *, ctx: TraceContext | None = None,
                **attrs) -> None:
        self.event(name, time.perf_counter(), ctx=ctx, **attrs)

    # -- timeline bridge ---------------------------------------------------

    def timeline_sink(self) -> Callable[[str, float, float, str], None]:
        """A PhaseTimeline `sink`: forwards every recorded span as an
        event, deriving attribution from the timeline's tag convention
        ("w0/g3" = worker w0, map task g3; a bare "r12" is the
        single-host driver, worker "host")."""

        def sink(phase: str, start: float, end: float, tag: str) -> None:
            worker, _, task = tag.rpartition("/")
            ctx = TraceContext(
                job=self.job, phase=phase.split(".", 1)[0],
                task=task or None, worker=worker or "host")
            self.event(phase, start, end, ctx=ctx)

        return sink


__all__ = ["EventLog", "Tracer"]
