"""minicpm3-4b [dense/MLA]: 62L d_model=2560 40H d_ff=6400 vocab=73448 — MLA.
[hf:openbmb/MiniCPM3-4B; hf]. q_lora=768, kv_lora=256, qk_nope=64,
qk_rope=32, v_head=64. The latent KV cache is ~9x smaller than GQA at
these dims; attention is still full-context (long_500k skipped,
DESIGN.md §5)."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="minicpm3-4b",
    family="mla",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,  # MLA is MHA over latent
    d_ff=6400,
    vocab=73448,
    q_lora_rank=768,
    kv_lora_rank=256,
    qk_nope_dim=64,
    qk_rope_dim=32,
    v_head_dim=64,
    d_head=96,  # qk_nope + qk_rope
    rope_theta=10_000.0,
    train_microbatches=2,
    param_sharding="tp",
    # §Perf-proven sharding (EXPERIMENTS.md): 40 heads % 16 != 0 -> seq-parallel
    attn_sharding="qfull",
)
