"""llava-next-34b [vlm]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000 — anyres tiling. [hf:llava-hf/llava-v1.6-mistral-7b-hf scaled;
unverified]. The vision tower + anyres tile packing is a frontend STUB:
input_specs supplies 576 pre-projected patch embeddings (one base tile);
the backbone is the dense 34B decoder.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_head=128,
    d_ff=20480,
    vocab=64000,
    vlm_prefix=576,
    rope_theta=1e6,
    train_microbatches=8,
    param_sharding="fsdp",
    # §Perf-proven sharding (EXPERIMENTS.md): baseline="seq"
    attn_sharding="qfull",
)
