"""Smoke configuration for the group-by shuffle workload.

The group-by is the library's generality proof (shuffle/groupby.py): a
word-count-shaped keyed aggregation — skewed group keys, hash routing,
map-side combiner — running on the same tiered/faulty store stack as
CloudSort. These knobs size it for CPU smoke runs (tests, the example,
benchmarks/bench_groupby.py); scale `records`/`num_groups` up for real
measurements.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class GroupByConfig:
    """Dataset shape: how many records, how many distinct groups, and
    how skewed the group-frequency distribution is (skew > 1
    concentrates mass on low group ids — the word-frequency shape)."""

    records: int = 1 << 17
    records_per_partition: int = 1 << 13
    num_groups: int = 4096
    skew: float = 2.0
    value_range: int = 8
    num_partitions: int = 16  # R: output partitions (hash ranges)


SMOKE = GroupByConfig()


def groupby_smoke_plan():
    """The ShufflePlan for smoke-scale group-by runs: one value word per
    record, chunked streaming small enough that every partition pays
    several fetch cycles, 4 concurrent merges under a global budget.
    Lazily imported so configs stay importable without the library."""
    from repro.shuffle.api import ShufflePlan

    return ShufflePlan(
        payload_words=1,
        store_chunk_bytes=32 << 10,
        merge_chunk_bytes=4 << 10,
        output_part_records=1 << 10,
        parallel_reducers=4,
        reduce_memory_budget_bytes=256 << 10,
        part_upload_fanout=2,
    )
