"""moonshot-v1-16b-a3b [moe]: 48L d_model=2048 16H (kv=16) expert d_ff=1408
vocab=163840, MoE 64 routed top-6 + 2 shared (fused 2x1408=2816 wide) —
kimi/moonlight. [hf:moonshotai/Moonlight-16B-A3B; hf]. Exoshuffle sort
dispatch, as qwen2-moe."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_head=128,
    d_ff=0,
    vocab=163840,
    n_experts=64,
    top_k=6,
    d_ff_expert=1408,
    shared_d_ff=2816,
    dispatch_impl="sort",
    moe_capacity_factor=1.25,
    rope_theta=50_000.0,
    train_microbatches=4,
    param_sharding="fsdp",
    # §Perf-proven sharding (EXPERIMENTS.md): baseline="seq"
    attn_sharding="heads",
)
