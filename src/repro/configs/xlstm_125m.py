"""xlstm-125m [ssm]: 12L d_model=768 4H d_ff=0 vocab=50304 — sLSTM + mLSTM
blocks. [arXiv:2405.04517; unverified]. mLSTM (matrix memory) at 10 layers,
sLSTM at layers {5, 11} (the paper's ~7:1 mix); O(1) recurrent state makes
this a long_500k arch."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    ssm_state=16,
    chunk=256,
    param_sharding="tp",
)
