"""The paper's own benchmark configuration (Exoshuffle-CloudSort §2.1).

Scaled variants for CPU validation (`smoke`), pod-scale dry-run
(`pod256`/`pod512`), and the paper-parameter record (`paper` — 100 TB,
kept for the cost model; never materialized on this container).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class CloudSortConfig:
    total_records: int
    num_workers: int  # W
    reducers_per_worker: int  # R1
    num_rounds: int  # merge-controller rounds (streaming)
    payload_words: int = 23  # 92 B payload + 8 B header = 100 B records
    capacity_factor: float = 1.5
    impl: str = "pallas"

    @property
    def records_per_worker(self) -> int:
        return self.total_records // self.num_workers


# Paper parameters (§2.1): 100 TB = 10^12 records of 100 B; M=50k maps of
# 2 GB; W=40 workers; R=25k reducers (R1=625). Records here are 100 B too.
PAPER = CloudSortConfig(
    total_records=10**12,
    num_workers=64,  # nearest pow2 of the paper's 40 (merge tournament)
    reducers_per_worker=625,
    num_rounds=1250,  # M / W map tasks per worker, batched 10 per round
)

SMOKE = CloudSortConfig(
    total_records=1 << 17,
    num_workers=8,
    reducers_per_worker=4,
    num_rounds=4,
    impl="ref",
)

POD256 = CloudSortConfig(
    total_records=1 << 24,
    num_workers=256,
    reducers_per_worker=64,
    num_rounds=8,
    impl="ref",
)

POD512 = CloudSortConfig(
    total_records=1 << 25,
    num_workers=512,
    reducers_per_worker=64,
    num_rounds=8,
    impl="ref",
)


def ooc_smoke_plan():
    """Out-of-core smoke schedule (examples/cloudsort_oocore.py, tests).

    A 2^14-record wave working set against a >=4x larger store-resident
    dataset: 8 map waves at the default 2^17 records, each wave split into
    2 streaming rounds, 2 input partitions per wave, 64 KiB download
    chunks, 16 KiB reduce merge-chunk cap. The reduce scheduler runs 4
    streaming merges concurrently under a 128 KiB global memory budget —
    strictly below one output partition (~196 KiB at the default record
    size), the bound the example asserts — with per-partition part
    uploads fanned out 2-wide (out-of-order part-indexed multipart).
    R1=2 keeps output partitions wide enough that each run slice still
    takes several chunked fetches at smoke scale.
    Lazily imported so configs stay importable without jax.
    """
    from repro.core.external_sort import ExternalSortPlan

    return ExternalSortPlan(
        records_per_wave=1 << 14,
        num_rounds=2,
        reducers_per_worker=2,
        payload_words=4,
        impl="ref",
        input_records_per_partition=1 << 13,
        output_part_records=1 << 13,
        store_chunk_bytes=64 << 10,
        merge_chunk_bytes=16 << 10,
        parallel_reducers=4,
        reduce_memory_budget_bytes=128 << 10,
        part_upload_fanout=2,
    )


def cluster_smoke_plan(num_workers: int = 4, *, base=None, runs: int = 16):
    """(ExternalSortPlan, ClusterPlan) for cluster smoke runs.

    Takes an out-of-core plan (`base`, default ooc_smoke_plan()) and
    widens its reduce budget to the cluster-wide merge concurrency:
    num_workers x parallel_reducers scheduler slots all draw on one
    global budget, and the adaptive governor's feasibility floor is one
    record per spilled run per slot (`runs` = the job's wave count —
    callers that know their dataset pass the real value). Returns the
    widened plan plus a ClusterPlan partitioning it across `num_workers`
    emulated workers. Used by examples/cloudsort_oocore.py --workers;
    benchmarks/bench_cluster_scaling.py builds its own latency-injected
    variant. Lazily imported so configs stay importable without jax.
    """
    import dataclasses as _dc

    from repro.core.cluster import ClusterPlan

    plan = base if base is not None else ooc_smoke_plan()
    slots = num_workers * plan.parallel_reducers
    budget = max(plan.reduce_memory_budget_bytes,
                 slots * max(runs, 1) * plan.record_bytes)
    plan = _dc.replace(plan, reduce_memory_budget_bytes=budget)
    return plan, ClusterPlan(num_workers=num_workers)


def smoke_fault_profile():
    """Fault injection scaled for CPU smoke runs (io/middleware.FaultProfile).

    Proportions mirror S3 — per-request latency, per-connection bandwidth,
    and GET/PUT token buckets tight enough that a smoke-scale run provokes
    real 503 SlowDowns and retries — but absolute values are shrunk ~100x
    so the injected stall adds seconds, not hours, to a laptop run.
    """
    from repro.io.middleware import FaultProfile

    return FaultProfile(
        latency_s=0.0015,
        jitter_s=0.0005,
        bandwidth_bps=400e6,
        get_rate=60.0,
        put_rate=40.0,
        burst=12.0,
    )


def s3_fault_profile():
    """Realistic S3 parameters (the paper's us-west-2 regime): ~25 ms
    first-byte latency, ~90 MB/s per connection, 5500 GET/s and 3500
    PUT/s per prefix before 503 Slow Down. Use for full-scale dry runs
    and the fault benchmark's non-smoke mode, not for CPU smoke tests.
    """
    from repro.io.middleware import FaultProfile

    return FaultProfile(
        latency_s=0.025,
        jitter_s=0.010,
        bandwidth_bps=90e6,
        get_rate=5500.0,
        put_rate=3500.0,
        burst=512.0,
    )
