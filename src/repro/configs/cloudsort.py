"""The paper's own benchmark configuration (Exoshuffle-CloudSort §2.1).

Scaled variants for CPU validation (`smoke`), pod-scale dry-run
(`pod256`/`pod512`), and the paper-parameter record (`paper` — 100 TB,
kept for the cost model; never materialized on this container).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class CloudSortConfig:
    total_records: int
    num_workers: int  # W
    reducers_per_worker: int  # R1
    num_rounds: int  # merge-controller rounds (streaming)
    payload_words: int = 23  # 92 B payload + 8 B header = 100 B records
    capacity_factor: float = 1.5
    impl: str = "pallas"

    @property
    def records_per_worker(self) -> int:
        return self.total_records // self.num_workers


# Paper parameters (§2.1): 100 TB = 10^12 records of 100 B; M=50k maps of
# 2 GB; W=40 workers; R=25k reducers (R1=625). Records here are 100 B too.
PAPER = CloudSortConfig(
    total_records=10**12,
    num_workers=64,  # nearest pow2 of the paper's 40 (merge tournament)
    reducers_per_worker=625,
    num_rounds=1250,  # M / W map tasks per worker, batched 10 per round
)

SMOKE = CloudSortConfig(
    total_records=1 << 17,
    num_workers=8,
    reducers_per_worker=4,
    num_rounds=4,
    impl="ref",
)

POD256 = CloudSortConfig(
    total_records=1 << 24,
    num_workers=256,
    reducers_per_worker=64,
    num_rounds=8,
    impl="ref",
)

POD512 = CloudSortConfig(
    total_records=1 << 25,
    num_workers=512,
    reducers_per_worker=64,
    num_rounds=8,
    impl="ref",
)
