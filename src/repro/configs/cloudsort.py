"""The paper's own benchmark configuration (Exoshuffle-CloudSort §2.1).

Scaled variants for CPU validation (`smoke`), pod-scale dry-run
(`pod256`/`pod512`), and the paper-parameter record (`paper` — 100 TB,
kept for the cost model; never materialized on this container).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class CloudSortConfig:
    total_records: int
    num_workers: int  # W
    reducers_per_worker: int  # R1
    num_rounds: int  # merge-controller rounds (streaming)
    payload_words: int = 23  # 92 B payload + 8 B header = 100 B records
    capacity_factor: float = 1.5
    impl: str = "pallas"

    @property
    def records_per_worker(self) -> int:
        return self.total_records // self.num_workers


# Paper parameters (§2.1): 100 TB = 10^12 records of 100 B; M=50k maps of
# 2 GB; W=40 workers; R=25k reducers (R1=625). Records here are 100 B too.
PAPER = CloudSortConfig(
    total_records=10**12,
    num_workers=64,  # nearest pow2 of the paper's 40 (merge tournament)
    reducers_per_worker=625,
    num_rounds=1250,  # M / W map tasks per worker, batched 10 per round
)

SMOKE = CloudSortConfig(
    total_records=1 << 17,
    num_workers=8,
    reducers_per_worker=4,
    num_rounds=4,
    impl="ref",
)

POD256 = CloudSortConfig(
    total_records=1 << 24,
    num_workers=256,
    reducers_per_worker=64,
    num_rounds=8,
    impl="ref",
)

POD512 = CloudSortConfig(
    total_records=1 << 25,
    num_workers=512,
    reducers_per_worker=64,
    num_rounds=8,
    impl="ref",
)


def ooc_smoke_plan():
    """Out-of-core smoke schedule (examples/cloudsort_oocore.py, tests).

    A 2^14-record wave working set against a >=4x larger store-resident
    dataset: 8 map waves at the default 2^17 records, each wave split into
    2 streaming rounds, 2 input partitions per wave, 64 KiB download
    chunks. Lazily imported so configs stay importable without jax.
    """
    from repro.core.external_sort import ExternalSortPlan

    return ExternalSortPlan(
        records_per_wave=1 << 14,
        num_rounds=2,
        reducers_per_worker=4,
        payload_words=4,
        impl="ref",
        input_records_per_partition=1 << 13,
        output_part_records=1 << 13,
        store_chunk_bytes=64 << 10,
    )
