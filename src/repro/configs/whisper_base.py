"""whisper-base [audio]: 6L enc + 6L dec, d_model=512 8H d_ff=2048
vocab=51865 — enc-dec, conv frontend stubbed. [arXiv:2212.04356;
unverified]. input_specs supplies post-conv frame embeddings
(enc_len = seq//4); full attention both sides, so long_500k is skipped."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base",
    family="encdec",
    n_layers=6,
    enc_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_head=64,
    d_ff=2048,
    vocab=51865,
    param_sharding="tp",
)
