"""Architecture registry: one module per assigned arch + the paper's own
CloudSort config. `get(name)` returns the ArchConfig; `REGISTRY` lists all.
"""
from __future__ import annotations

import importlib

ARCH_IDS = [
    "llava_next_34b",
    "granite_3_8b",
    "mistral_nemo_12b",
    "minicpm3_4b",
    "tinyllama_1_1b",
    "qwen2_moe_a2_7b",
    "moonshot_v1_16b_a3b",
    "xlstm_125m",
    "whisper_base",
    "hymba_1_5b",
]

_ALIASES = {i.replace("_", "-"): i for i in ARCH_IDS}


def get(name: str):
    mod_name = name.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def all_configs():
    return {i: get(i) for i in ARCH_IDS}
