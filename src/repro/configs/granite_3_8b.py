"""granite-3-8b [dense]: 40L d_model=4096 32H (GQA kv=8) d_ff=12800
vocab=49155 — GQA. [hf:ibm-granite/granite-3.0-2b-base family; hf]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="granite-3-8b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=12800,
    vocab=49155,
    rope_theta=10_000.0,
    train_microbatches=4,
    param_sharding="fsdp",
    # §Perf-proven sharding (EXPERIMENTS.md): baseline="seq"
    attn_sharding="heads",
)
