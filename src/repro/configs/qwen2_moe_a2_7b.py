"""qwen2-moe-a2.7b [moe]: 24L d_model=2048 16H (kv=16) expert d_ff=1408
vocab=151936, MoE 60 routed top-4 + 4 shared (fused 4x1408=5632 wide).
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]. Routed dispatch = exoshuffle sort path
(DESIGN.md §4.2) — this arch is a primary carrier of the paper's technique.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_head=128,
    d_ff=0,
    vocab=151936,
    n_experts=60,
    top_k=4,
    d_ff_expert=1408,
    shared_d_ff=5632,
    dispatch_impl="sort",
    moe_capacity_factor=1.25,
    rope_theta=10_000.0,
    train_microbatches=4,
    param_sharding="fsdp",
    # §Perf-proven sharding (EXPERIMENTS.md): baseline="seq"
    attn_sharding="heads",
)
