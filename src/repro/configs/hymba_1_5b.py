"""hymba-1.5b [hybrid]: 32L d_model=1600 25H (GQA kv=5) d_ff=5504
ssm_state=16 — parallel attention + mamba heads per block, 128 meta tokens,
sliding window 1024 except 3 global full-attention layers.
[arXiv:2411.13676; hf]. SWA + O(1) SSM state -> runs long_500k."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_head=64,
    d_ff=5504,
    vocab=32001,
    ssm_state=16,
    window=1024,
    global_layers=(0, 15, 31),
    meta_tokens=128,
    chunk=256,
    param_sharding="tp",
    # §Perf-proven sharding (EXPERIMENTS.md): baseline="seq"
    attn_sharding="qfull",
    ssm_pad_heads=32,
)
