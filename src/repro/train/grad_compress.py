"""int8 gradient compression with error feedback for the cross-pod link.

In the 2x16x16 multi-pod mesh the gradient all-reduce crosses the DCN 'pod'
axis — the slowest link by an order of magnitude. This module compresses
that hop: per-tensor int8 quantization, all_gather of the int8 payloads
over 'pod' (1 byte/element on the wire instead of 4), local dequant-sum,
plus an error-feedback residual carried in the training state so the
quantization error is re-injected next step (Karimireddy et al. EF-SGD).

This is a beyond-paper distributed-optimization feature (DESIGN.md §9);
the paper's own system has no gradient stage at all.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import compat


def quantize_int8(x):
    """Per-tensor symmetric int8. Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_mean_shard(x, axis: str):
    """Per-device body: int8 all_gather over `axis`, local dequant mean."""
    q, scale = quantize_int8(x)
    qs = jax.lax.all_gather(q, axis)  # (n, ...) int8 on the wire
    scales = jax.lax.all_gather(scale, axis)  # (n,) f32 (negligible bytes)
    n = qs.shape[0]  # static axis size (jax.lax.axis_size is newer-jax-only)
    deq = qs.astype(jnp.float32) * scales.reshape((n,) + (1,) * x.ndim)
    return jnp.sum(deq, axis=0) / n


def compressed_pod_mean(grads, mesh, *, axis: str = "pod"):
    """Mean gradients across the pod axis with int8 wire format.

    grads: pytree whose leaves are already identical within a pod (the
    intra-pod reduction having been done at full precision by GSPMD). Leaves
    are replicated over `axis`? No — each pod holds its own partial mean;
    this exchanges them. Runs under shard_map with everything else
    replicated w.r.t. the pod axis.
    """
    if axis not in mesh.axis_names:
        return grads

    flat, treedef = jax.tree.flatten(grads)

    def body(*leaves):
        return tuple(compressed_mean_shard(l, axis) for l in leaves)

    specs = tuple(P(*([None] * l.ndim)) for l in flat)
    out = compat.shard_map(
        body, mesh=mesh, in_specs=specs, out_specs=specs, check_vma=False
    )(*flat)
    return treedef.unflatten(list(out))


def ef_compress_grads(grads, residual):
    """Error feedback: g' = Q(g + r); r' = (g + r) - g'. Pure local transform
    (simulates the end-to-end numerics of the compressed reduce for tests).
    """
    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        q, scale = quantize_int8(corrected)
        deq = dequantize(q, scale)
        return deq.astype(g.dtype), corrected - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))


def init_residual(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
