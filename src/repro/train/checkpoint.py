"""Checkpointing with manifests and checksums — the fault-tolerance layer.

Mirrors the paper's manifest discipline (§3.2: input/output manifest files
+ checksum gates):

  <dir>/step_<N>/
      manifest.json   — leaf paths, shapes, dtypes, crc32 per leaf, step
      <leaf>.npy      — one file per pytree leaf (full, unsharded arrays)

Writes are atomic (tmp dir + rename); a LATEST marker is updated last, so a
crash mid-save never corrupts the restore point (checkpoint/restart
recovery). `load` re-shards onto *any* mesh via NamedSharding device_put —
this is the checkpoint-resharding path (launch/reshard.py): one taken on
256 chips restores onto 512 or 8.

At real 100TB/1000-node scale the arrays would be written shard-wise by
each host; the manifest/checksum/atomic-rename protocol is the part that
carries over unchanged.
"""
from __future__ import annotations

import json
import os
import shutil
import zlib

import jax
import numpy as np


def _leaf_paths(tree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in leaves:
        name = "__".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path
        )
        out.append((name, leaf))
    return out


def save(state, ckpt_dir: str, step: int):
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "leaves": {}}
    for name, leaf in _leaf_paths(state):
        arr = np.asarray(leaf)
        np.save(os.path.join(tmp, name + ".npy"), arr)
        manifest["leaves"][name] = {
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "crc32": zlib.crc32(arr.tobytes()),
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    with open(os.path.join(ckpt_dir, "LATEST.tmp"), "w") as f:
        f.write(str(step))
    os.replace(os.path.join(ckpt_dir, "LATEST.tmp"),
               os.path.join(ckpt_dir, "LATEST"))
    return final


def latest_step(ckpt_dir: str):
    marker = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(marker):
        return None
    return int(open(marker).read().strip())


def load(target_tree, ckpt_dir: str, step: int | None = None, *,
         shardings=None, verify: bool = True):
    """Restore into the structure of `target_tree` (abstract ok).

    shardings: optional pytree of NamedSharding congruent with target —
    the elastic re-shard path: arrays are placed directly onto the new mesh.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        assert step is not None, f"no checkpoint under {ckpt_dir}"
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    manifest = json.load(open(os.path.join(d, "manifest.json")))

    names = [n for n, _ in _leaf_paths(target_tree)]
    shard_leaves = (
        jax.tree.leaves(shardings,
                        is_leaf=lambda x: isinstance(x, jax.sharding.Sharding))
        if shardings is not None
        else [None] * len(names)
    )
    loaded = []
    for name, sh in zip(names, shard_leaves):
        meta = manifest["leaves"][name]
        arr = np.load(os.path.join(d, name + ".npy"))
        if verify:
            crc = zlib.crc32(arr.tobytes())
            assert crc == meta["crc32"], f"checksum mismatch for {name}"
        loaded.append(jax.device_put(arr, sh) if sh is not None else arr)
    treedef = jax.tree.structure(target_tree)
    return treedef.unflatten(loaded), step
