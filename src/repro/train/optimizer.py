"""AdamW + cosine schedule + global-norm clipping, in pure JAX.

Optimizer state is a pytree congruent with params (mu, nu), sharded like
the params by default; under ZeRO-1 (`zero1=True` in the train step) the
state is additionally sharded over the data axes via its own PartitionSpecs.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def lr_at(step, cfg: OptConfig):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.peak_lr * warm * frac


def init_opt_state(params):
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"mu": zeros, "nu": jax.tree.map(jnp.copy, zeros),
            "step": jnp.zeros((), jnp.int32)}


def clip_by_global_norm(grads, max_norm):
    gn = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    )
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), gn


def adamw_update(params, grads, state, cfg: OptConfig):
    step = state["step"] + 1
    lr = lr_at(step, cfg)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32)
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        mhat = mu / bc1
        vhat = nu / bc2
        step_vec = mhat / (jnp.sqrt(vhat) + cfg.eps)
        newp = p.astype(jnp.float32) - lr * (step_vec + cfg.weight_decay * p.astype(jnp.float32))
        return newp.astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, {
        "lr": lr, "grad_norm": gnorm,
    }
