"""Training step: loss -> grad -> (optional EF-int8 pod reduce) -> AdamW.

Microbatching (gradient accumulation) runs as a `lax.scan` over microbatch
slices; remat is configured per-arch inside the model (scan-over-layers +
jax.checkpoint). Mixed precision: params f32 master, compute bf16 (cast in
the model), grads f32.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.train import grad_compress as gc
from repro.train.optimizer import OptConfig, adamw_update, init_opt_state


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: OptConfig = OptConfig()
    microbatches: int = 1  # grad-accumulation steps per train step
    pod_grad_compress: bool = False  # int8 EF reduce over the 'pod' axis


def init_train_state(model, key, tcfg: TrainConfig):
    params = model.init(key)
    state = {"params": params, "opt": init_opt_state(params)}
    if tcfg.pod_grad_compress:
        state["ef_residual"] = gc.init_residual(params)
    return state


def abstract_train_state(model, tcfg: TrainConfig):
    return jax.eval_shape(lambda k: init_train_state(model, k, tcfg),
                          jax.random.PRNGKey(0))


def make_train_step(model, tcfg: TrainConfig, *, mesh=None):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def grads_of(params, batch):
        if tcfg.microbatches <= 1:
            return jax.value_and_grad(model.loss)(params, batch)

        def micro(carry, mb):
            loss, acc = carry
            l, g = jax.value_and_grad(model.loss)(params, mb)
            acc = jax.tree.map(jnp.add, acc, g)
            return (loss + l, acc), None

        def split(x):
            b = x.shape[0]
            m = tcfg.microbatches
            return x.reshape((m, b // m) + x.shape[1:])

        mbs = jax.tree.map(split, batch)
        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                            params)
        (loss, gsum), _ = jax.lax.scan(micro, (jnp.float32(0.0), zero), mbs)
        inv = 1.0 / tcfg.microbatches
        return loss * inv, jax.tree.map(lambda g: g * inv, gsum)

    def train_step(state, batch):
        loss, grads = grads_of(state["params"], batch)
        new_state = dict(state)
        if tcfg.pod_grad_compress and "ef_residual" in state:
            grads, residual = gc.ef_compress_grads(grads, state["ef_residual"])
            if mesh is not None and "pod" in mesh.axis_names:
                grads = gc.compressed_pod_mean(grads, mesh)
            new_state["ef_residual"] = residual
        params, opt, info = adamw_update(state["params"], grads, state["opt"],
                                         tcfg.opt)
        new_state["params"] = params
        new_state["opt"] = opt
        metrics = {"loss": loss, **info}
        return new_state, metrics

    return train_step
