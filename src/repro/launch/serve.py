"""End-to-end serving driver.

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --reduced --batch 4 --prompt-len 32 --max-new 16

Runs the full serving stack: config -> model -> batched prefill ->
jit'd greedy/temperature decode loop with a KV cache
(serve/engine.py), printing tokens/s. `--reduced` uses the smoke-scale
config so the driver runs on CPU; on a real pod the same code path is
what the decode_32k / long_500k dry-run cells lower.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get
from repro.models import api as mapi
from repro.serve.engine import ServeConfig, generate


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config (CPU-sized)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get(args.arch)
    if args.reduced:
        cfg = cfg.reduced(dtype="float32", remat=False)
    model = mapi.build(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))

    key = jax.random.PRNGKey(args.seed + 1)
    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            key, (args.batch, cfg.vlm_prefix, cfg.d_model))
    if cfg.family == "encdec":
        from repro.models.whisper import enc_len_for
        batch["frames"] = jax.random.normal(
            key, (args.batch, enc_len_for(cfg, args.prompt_len), cfg.d_model))

    scfg = ServeConfig(max_new_tokens=args.max_new,
                       temperature=args.temperature)
    t0 = time.perf_counter()
    out, steps = generate(model, params, batch, scfg)
    out = jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    toks = int(steps) * args.batch
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"new={int(steps)}  {dt:.2f}s  {toks / dt:.1f} tok/s")
    print("first sequence:", jnp.asarray(out)[0].tolist())


if __name__ == "__main__":
    main()
