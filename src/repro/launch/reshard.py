"""Checkpoint resharding: resume a training checkpoint onto a new mesh.

The checkpoint stores full (unsharded) arrays + a manifest; restoring onto
a new mesh is a `device_put` with the new mesh's NamedShardings, derived
from the same sharding rules that built the original run
(launch/sharding.py). Shrinking DP, growing DP across pods, or moving from
the 16x16 to the 2x16x16 mesh are all the same operation.

This is the TRAINING stack's elastic-restart primitive (formerly
launch/elastic.py — renamed: the shuffle stack's elastic worker fleet
lives in shuffle/elastic.py and is a different machine entirely).

  PYTHONPATH=src python -m repro.launch.reshard --arch tinyllama-1.1b \
      --ckpt-dir /tmp/ck --verify
"""
from __future__ import annotations

import argparse

import jax
from jax.sharding import PartitionSpec as P

from repro.configs import get
from repro.launch import sharding as shd
from repro.models import api as mapi
from repro.train import checkpoint as ckpt
from repro.train.optimizer import init_opt_state


def reshard_state(arch: str, ckpt_dir: str, mesh, *, reduced: bool = False):
    cfg = get(arch)
    if reduced:
        cfg = cfg.reduced(dtype="float32", remat=False)
    model = mapi.build(cfg)
    abstract = jax.eval_shape(
        lambda k: {"params": model.init(k),
                   "opt": init_opt_state(model.init(k))},
        jax.random.PRNGKey(0),
    )
    p_specs = shd.param_pspecs(cfg, abstract["params"], mesh)
    state_specs = {"params": p_specs,
                   "opt": {"mu": p_specs, "nu": p_specs, "step": P()}}
    shardings = jax.tree.map(
        lambda s: jax.sharding.NamedSharding(mesh, s), state_specs,
        is_leaf=lambda x: isinstance(x, P),
    )
    state, step = ckpt.load(abstract, ckpt_dir, shardings=shardings)
    return state, step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--ckpt-dir", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mesh", default="auto",
                    help="'auto': all local devices as one data axis")
    args = ap.parse_args(argv)

    n = len(jax.devices())
    from repro.core.compat import make_mesh
    mesh = make_mesh((n, 1), ("data", "model"))
    state, step = reshard_state(args.arch, args.ckpt_dir, mesh,
                                reduced=args.reduced)
    n_leaves = len(jax.tree.leaves(state))
    print(f"resharded step-{step} checkpoint onto {n} devices "
          f"({n_leaves} arrays)")


if __name__ == "__main__":
    main()
