"""Production mesh construction (assignment: MULTI-POD DRY-RUN step 1).

Defined as functions (never module-level constants) so importing this
module never touches jax device state. Mesh construction goes through
core/compat.py so the same code runs on old (0.4.x) and current jax.
"""
from __future__ import annotations

from repro.core.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips with a leading
    'pod' axis (DCN-connected)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_test_mesh(shape=(2, 4), axes=("data", "model")):
    """Small host-device mesh for CPU integration tests."""
    return make_mesh(shape, axes)


def dp_axes_of(mesh) -> tuple[str, ...]:
    """Data-parallel axes: every mesh axis except 'model'."""
    return tuple(a for a in mesh.axis_names if a != "model")


def flat_axes_of(mesh) -> tuple[str, ...]:
    """All axes — the sort/shuffle treats every chip as a worker."""
    return tuple(mesh.axis_names)
