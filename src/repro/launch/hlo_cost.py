"""Trip-count-weighted cost analysis of optimized HLO text.

XLA's ``compiled.cost_analysis()`` visits every while-loop body ONCE —
a ``lax.scan`` over L layers reports the flops/bytes of a single layer.
All our models scan over layers (and the train step scans over
microbatches), so raw cost_analysis undercounts by the product of trip
counts, which breaks the roofline analysis (useful-flop ratios > 1).

This module re-derives the three roofline inputs from the optimized HLO
*text* with while bodies multiplied by their trip counts:

  flops             — dot/convolution flops (2 flops per MAC), weighted
  bytes             — per-instruction operands+output bytes at fusion
                      boundaries (XLA's bytes-accessed convention), weighted
  collective_bytes  — per-op operand-size tally for all-gather/all-reduce/
                      reduce-scatter/all-to-all/collective-permute, weighted

Trip counts are parsed from the loop condition: scan-lowered loops
compare an s32 induction variable (starting at 0, step 1) against a
constant bound, which survives into the optimized HLO either in the
condition computation or as a constant operand passed to it. Loops whose
bound cannot be found conservatively count as one iteration and are
reported in ``unknown_trip_loops``.

Validated against ``cost_analysis()`` on loop-free programs in
tests/test_hlo_cost.py.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3": 1,
    "f8e5m2fnuz": 1, "f8e4m3fnuz": 1, "s4": 1, "u4": 1,
}

COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# one array shape, e.g. f32[128,512]{1,0} or pred[] or s32[3]{0:T(256)}
_SHAPE = re.compile(r"([a-z0-9]+)\[([\d,]*)\](?:\{[^}]*\})?")
# instruction prefix: [ROOT] %name =
_INSTR_LHS = re.compile(r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*")
_OPCODE = re.compile(r"\s*([a-z][a-z0-9\-]*)\(")
# /*index=5*/ style comments inside long tuple types/operand lists
_COMMENT = re.compile(r"/\*.*?\*/")
# header param lists contain nested parens (tuple-typed params); only
# anchor on the name and the opening paren — the gate in parse_module
# (ends with '{', contains '->') rules out instruction lines.
_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")


def _shape_elems_bytes(sig: str) -> tuple[int, int]:
    """Total (elements, bytes) over every array in a (possibly tuple) sig."""
    elems = byts = 0
    for m in _SHAPE.finditer(sig):
        dt, dims = m.groups()
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        byts += n * DTYPE_BYTES[dt]
    return elems, byts


def _dims_of(sig: str) -> list[int]:
    m = _SHAPE.search(sig)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Instr:
    name: str
    out_sig: str
    opcode: str
    operands: list[str]
    attrs: str  # raw text after the closing paren of operands

    @property
    def out_bytes(self) -> int:
        return _shape_elems_bytes(self.out_sig)[1]


@dataclass
class Computation:
    name: str
    instrs: list[Instr] = field(default_factory=list)
    by_name: dict = field(default_factory=dict)


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    transcendentals: float = 0.0
    collective: dict[str, float] = field(default_factory=dict)

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.bytes += o.bytes
        self.transcendentals += o.transcendentals
        for k, v in o.collective.items():
            self.collective[k] = self.collective.get(k, 0.0) + v
        return self

    def scaled(self, k: float) -> "Cost":
        return Cost(self.flops * k, self.bytes * k, self.transcendentals * k,
                    {op: v * k for op, v in self.collective.items()})


def _split_operands(argstr: str) -> list[str]:
    """Split the operand list at depth 0 (shapes may contain commas)."""
    out, depth, cur = [], 0, []
    for ch in argstr:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    tail = "".join(cur).strip()
    if tail:
        out.append(tail)
    return out


def _match_paren(s: str, start: int) -> int:
    """Index just past the matching ')' for the '(' at s[start]."""
    depth = 0
    for i in range(start, len(s)):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                return i + 1
    return len(s)


def parse_module(text: str) -> tuple[dict[str, Computation], str | None]:
    """Parse optimized HLO text into computations; returns (comps, entry)."""
    comps: dict[str, Computation] = {}
    entry = None
    cur: Computation | None = None
    for raw in text.splitlines():
        line = _COMMENT.sub("", raw).strip()
        if not line or line.startswith("//"):
            continue
        if line.endswith("{") and ("->" in line) and ("=" not in
                                                      line.split("(")[0]):
            m = _COMP_HEADER.match(line)
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                if line.startswith("ENTRY"):
                    entry = cur.name
                continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_LHS.match(line)
        if not m:
            continue
        name = m.group(1)
        rest = line[m.end():]
        # output type: parenthesized tuple (match parens) or single token
        if rest.startswith("("):
            sig_end = _match_paren(rest, 0)
            out_sig = rest[:sig_end]
        else:
            sig_end = rest.find(" ")
            if sig_end < 0:
                continue
            out_sig = rest[:sig_end]
        mop = _OPCODE.match(rest[sig_end:])
        if not mop:
            continue
        opcode = mop.group(1)
        op_open = sig_end + mop.end() - 1
        op_close = _match_paren(rest, op_open)
        operands = _split_operands(rest[op_open + 1:op_close - 1])
        attrs = rest[op_close:]
        ins = Instr(name, out_sig.strip(), opcode, operands, attrs)
        cur.instrs.append(ins)
        cur.by_name[name] = ins
    return comps, entry


def _operand_sig(comp: Computation, operand: str) -> str:
    """Shape signature of an operand reference.

    Operands appear either as '%name' / 'name' (same-computation ref,
    shape from the def site) or as 'f32[2,2] %name' (inline shape).
    """
    operand = operand.strip()
    if _SHAPE.match(operand):
        return operand
    ref = operand.lstrip("%").split(" ")[0]
    ins = comp.by_name.get(ref)
    return ins.out_sig if ins is not None else ""


_CALLS = re.compile(r"calls=%?([\w.\-]+)")
_TO_APPLY = re.compile(r"to_apply=%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_BODY = re.compile(r"body=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")


class HloCostAnalyzer:
    def __init__(self, text: str):
        self.comps, self.entry = parse_module(text)
        self.unknown_trip_loops: list[str] = []
        self.while_trips: dict[str, int] = {}
        self._memo: dict[str, Cost] = {}

    # ---- per-instruction costs -------------------------------------
    def _dot_flops(self, comp: Computation, ins: Instr) -> float:
        out_elems, _ = _shape_elems_bytes(ins.out_sig)
        mcd = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.attrs)
        lhs_sig = _operand_sig(comp, ins.operands[0]) if ins.operands else ""
        lhs_dims = _dims_of(lhs_sig)
        k = 1
        if mcd and lhs_dims:
            for d in mcd.group(1).split(","):
                if d and int(d) < len(lhs_dims):
                    k *= lhs_dims[int(d)]
        return 2.0 * out_elems * k

    def _conv_flops(self, comp: Computation, ins: Instr) -> float:
        out_elems, _ = _shape_elems_bytes(ins.out_sig)
        # kernel operand: spatial dims x input features per output element
        rhs_sig = _operand_sig(comp, ins.operands[1]) if len(
            ins.operands) > 1 else ""
        rhs_dims = _dims_of(rhs_sig)
        if not rhs_dims:
            return 0.0
        # output feature dim contributes out_elems already; MACs per output
        # = prod(kernel dims) / output_features
        dnums = re.search(r"dim_labels=([\w?]+)_([\w?]+)->([\w?]+)", ins.attrs)
        k = 1
        for d in rhs_dims:
            k *= d
        if dnums:
            rhs_lab = dnums.group(2)  # e.g. io01
            if "o" in rhs_lab:
                k //= max(rhs_dims[rhs_lab.index("o")], 1)
        return 2.0 * out_elems * k

    def _instr_cost(self, comp: Computation, ins: Instr) -> Cost:
        op = ins.opcode
        c = Cost()
        if op in ("parameter", "constant", "get-tuple-element", "tuple",
                  "bitcast", "after-all", "partition-id", "replica-id"):
            return c
        if op == "while":
            body = _BODY.search(ins.attrs)
            cond = _COND.search(ins.attrs)
            trip = self._trip_count(comp, ins)
            self.while_trips[ins.name] = trip
            sub = Cost()
            if body:
                sub += self.comp_cost(body.group(1))
            if cond:
                sub += self.comp_cost(cond.group(1))
            return sub.scaled(trip)
        if op == "conditional":
            m = _BRANCHES.search(ins.attrs)
            if m:
                branches = [b.strip().lstrip("%") for b in
                            m.group(1).split(",")]
                costs = [self.comp_cost(b) for b in branches if b]
                if costs:
                    # worst-case branch
                    best = max(costs, key=lambda x: (x.flops, x.bytes))
                    c += best
            c.bytes += self._io_bytes(comp, ins)
            return c
        if op == "call":
            m = _TO_APPLY.search(ins.attrs)
            if m:
                c += self.comp_cost(m.group(1))
            return c
        if op == "fusion":
            m = _CALLS.search(ins.attrs)
            if m:
                inner = self.comp_cost(m.group(1))
                # inner traffic stays in registers: keep flops, drop bytes
                c.flops += inner.flops
                c.transcendentals += inner.transcendentals
                for k, v in inner.collective.items():
                    c.collective[k] = c.collective.get(k, 0.0) + v
                c.bytes += self._fusion_bytes(comp, ins, m.group(1))
            else:
                c.bytes += self._io_bytes(comp, ins)
            return c
        base = op.replace("-start", "").replace("-done", "").replace(
            "-update", "")
        if base in COLLECTIVES:
            if op.endswith("-done"):
                return c  # counted at -start
            opnd = sum(
                _shape_elems_bytes(_operand_sig(comp, o))[1]
                for o in ins.operands
            )
            c.collective[base] = c.collective.get(base, 0.0) + opnd
            c.bytes += self._io_bytes(comp, ins)
            return c
        # sliced reads/writes touch only the slice, not the whole operand
        # (XLA HloCostAnalysis convention; critical for scan bodies that
        # dynamic-slice per-layer params out of (L, ...) stacks).
        if op in ("dynamic-slice", "slice", "gather"):
            c.bytes += 2.0 * ins.out_bytes  # read slice + write out
            return c
        if op in ("dynamic-update-slice", "scatter"):
            upd = (_shape_elems_bytes(_operand_sig(comp, ins.operands[1]))[1]
                   if len(ins.operands) > 1 else ins.out_bytes)
            c.bytes += 2.0 * upd  # read update + write region (in place)
            return c
        if op in ("reshape", "iota", "broadcast", "rng",
                  "rng-bit-generator"):
            c.bytes += ins.out_bytes
            return c
        if op == "dot":
            c.flops += self._dot_flops(comp, ins)
        elif op == "convolution":
            c.flops += self._conv_flops(comp, ins)
        elif op in ("exponential", "log", "tanh", "logistic", "rsqrt",
                    "sqrt", "power", "sine", "cosine", "erf",
                    "exponential-minus-one", "log-plus-one", "cbrt"):
            c.transcendentals += _shape_elems_bytes(ins.out_sig)[0]
        elif op in ("add", "subtract", "multiply", "divide", "maximum",
                    "minimum", "compare", "select", "negate", "abs",
                    "floor", "ceil", "round-nearest-afz", "clamp", "and",
                    "or", "xor", "not", "shift-left", "shift-right-logical",
                    "shift-right-arithmetic", "remainder", "atan2"):
            c.flops += _shape_elems_bytes(ins.out_sig)[0]
        elif op == "reduce":
            # ~1 flop per reduced input element
            c.flops += sum(
                _shape_elems_bytes(_operand_sig(comp, o))[0]
                for o in ins.operands[: len(ins.operands) // 2]
            )
        c.bytes += self._io_bytes(comp, ins)
        return c

    def _io_bytes(self, comp: Computation, ins: Instr) -> float:
        b = float(ins.out_bytes)
        for o in ins.operands:
            b += _shape_elems_bytes(_operand_sig(comp, o))[1]
        return b

    def _fusion_bytes(self, comp: Computation, ins: Instr,
                      called: str) -> float:
        """Fusion boundary bytes with operand *utilization*.

        A fusion that dynamic-slices a big operand (the scan-body pattern:
        per-layer params sliced out of an (L, ...) stack) reads only the
        slice. For each fusion operand, if the corresponding parameter
        inside the fused computation feeds ONLY slicing ops
        (dynamic-slice / slice / gather), charge the slices' output bytes;
        otherwise charge the full operand.
        """
        fcomp = self.comps.get(called)
        b = float(ins.out_bytes)
        if fcomp is None:
            return b + sum(
                _shape_elems_bytes(_operand_sig(comp, o))[1]
                for o in ins.operands
            )
        # map param index -> set of consumer opcodes + sliced bytes
        params: dict[int, Instr] = {}
        for fi in fcomp.instrs:
            if fi.opcode == "parameter":
                m = re.match(r"(\d+)", fi.operands[0] if fi.operands else "")
                if m:
                    params[int(m.group(1))] = fi
        for idx, o in enumerate(ins.operands):
            full = _shape_elems_bytes(_operand_sig(comp, o))[1]
            pins = params.get(idx)
            if pins is None:
                b += full
                continue
            pname = pins.name
            sliced = 0.0
            only_slicing = True
            used = False
            for fi in fcomp.instrs:
                if fi.opcode == "parameter":
                    continue
                refs_first = any(
                    r.lstrip("%").split(" ")[-1].lstrip("%") == pname
                    or r.lstrip("%").split(" ")[0] == pname
                    for r in (fi.operands[:1] if fi.opcode in
                              ("dynamic-slice", "slice", "gather")
                              else [])
                )
                refs_any = any(
                    pname in {r.lstrip("%").split(" ")[-1].lstrip("%"),
                              r.lstrip("%").split(" ")[0]}
                    for r in fi.operands
                )
                if not refs_any:
                    continue
                used = True
                if fi.opcode in ("dynamic-slice", "slice",
                                 "gather") and refs_first:
                    sliced += fi.out_bytes
                else:
                    only_slicing = False
            if used and only_slicing and sliced > 0:
                b += min(sliced, full)
            else:
                b += full
        return b

    # ---- loop trip counts ------------------------------------------
    def _trip_count(self, comp: Computation, ins: Instr) -> int:
        # XLA annotates loops it has analyzed:
        #   backend_config={"known_trip_count":{"n":"22"},...}
        m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', ins.attrs)
        if m:
            return int(m.group(1))
        cond = _COND.search(ins.attrs)
        if not cond:
            self.unknown_trip_loops.append(ins.name)
            return 1
        ccomp = self.comps.get(cond.group(1))
        if ccomp is None:
            self.unknown_trip_loops.append(ins.name)
            return 1
        # scan-lowered loops: iv starts at 0, steps 1, compare LT bound.
        # The bound is an integer constant in the condition computation
        # (possibly behind a wrapped_compare fusion).
        consts = []
        for ci in ccomp.instrs:
            if ci.opcode != "constant":
                continue
            if not re.match(r"^[su](?:8|16|32|64)\[\]", ci.out_sig):
                continue
            # value lives in the operand slot: constant(22)
            for o in ci.operands:
                if re.fullmatch(r"\d+", o):
                    consts.append(int(o))
        if consts:
            return max(consts)
        # bound may be threaded through the carried tuple as a constant
        # in the caller: look at the while's init tuple for int consts
        init = ins.operands[0].lstrip("%") if ins.operands else ""
        tins = comp.by_name.get(init)
        if tins is not None:
            for o in tins.operands:
                ref = comp.by_name.get(o.lstrip("%").split(" ")[0])
                if ref is not None and ref.opcode == "constant":
                    for v in ref.operands:
                        if re.fullmatch(r"\d+", v):
                            consts.append(int(v))
            if consts:
                return max(consts)
        self.unknown_trip_loops.append(ins.name)
        return 1

    # ---- computation / module cost ----------------------------------
    def comp_cost(self, name: str) -> Cost:
        if name in self._memo:
            return self._memo[name]
        comp = self.comps.get(name)
        total = Cost()
        if comp is None:
            return total
        self._memo[name] = total  # break cycles defensively
        for ins in comp.instrs:
            total += self._instr_cost(comp, ins)
        self._memo[name] = total
        return total

    def module_cost(self) -> Cost:
        if self.entry is None:
            return Cost()
        return self.comp_cost(self.entry)


def analyze(text: str) -> dict:
    """Weighted roofline inputs for one optimized HLO module."""
    an = HloCostAnalyzer(text)
    cost = an.module_cost()
    coll = {k: v for k, v in cost.collective.items()}
    coll["total"] = sum(coll.values())
    return {
        "flops": cost.flops,
        "bytes": cost.bytes,
        "transcendentals": cost.transcendentals,
        "collective_bytes": coll,
        "while_trips": dict(an.while_trips),
        "unknown_trip_loops": list(an.unknown_trip_loops),
    }
