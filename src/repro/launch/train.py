"""End-to-end training driver.

  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --steps 200 --batch 8 --seq 128 --reduced --ckpt-dir /tmp/ck

Runs the full stack: config -> model -> sharded train step (on whatever
devices exist) -> exoshuffle-shuffled data pipeline -> checkpoints every
--ckpt-every steps -> automatic restart from the latest checkpoint.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models import api as mapi
from repro.train import checkpoint as ckpt
from repro.train.optimizer import OptConfig
from repro.train.train_step import (TrainConfig, init_train_state,
                                    make_train_step)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config (CPU-sized)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get(args.arch)
    if args.reduced:
        cfg = cfg.reduced(dtype="float32", remat=False)
    model = mapi.build(cfg)
    tcfg = TrainConfig(opt=OptConfig(peak_lr=args.lr, warmup_steps=10,
                                     total_steps=args.steps))

    data = TokenPipeline(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                    global_batch=args.batch,
                                    num_samples=args.batch * 1024))

    start = 0
    if args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        abstract = jax.eval_shape(
            lambda k: init_train_state(model, k, tcfg), jax.random.PRNGKey(0)
        )
        state, start = ckpt.load(abstract, args.ckpt_dir)
        print(f"restored checkpoint at step {start}")
    else:
        state = init_train_state(model, jax.random.PRNGKey(0), tcfg)

    step_fn = jax.jit(make_train_step(model, tcfg))
    t0 = time.time()
    for step in range(start, args.steps):
        batch = data.batch_at(step)
        state, metrics = step_fn(state, batch)
        if step % args.log_every == 0 or step == args.steps - 1:
            loss = float(metrics["loss"])
            tps = args.batch * args.seq * (step - start + 1) / (time.time() - t0)
            print(f"step {step:5d}  loss {loss:8.4f}  lr {float(metrics['lr']):.2e}"
                  f"  tok/s {tps:,.0f}")
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            ckpt.save(state, args.ckpt_dir, step + 1)
    if args.ckpt_dir:
        ckpt.save(state, args.ckpt_dir, args.steps)
    return float(metrics["loss"])


if __name__ == "__main__":
    main()
