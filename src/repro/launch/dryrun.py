import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this script:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. constructs the arch's train/prefill/decode step with full sharding
     (params, optimizer state, inputs, caches),
  3. jit(...).lower(ShapeDtypeStructs).compile()   — no allocation,
  4. records memory_analysis() (fits-per-device proof), cost_analysis()
     (FLOPs/bytes for §Roofline), and the collective-bytes tally parsed
     from the optimized HLO.

Results stream to a JSONL file consumed by benchmarks/roofline.py and
EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
      --shape train_4k [--multi-pod] [--all] [--out results/dryrun.jsonl]
"""
import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get
from repro.launch import hlo_cost
from repro.launch import sharding as shd
from repro.launch.mesh import dp_axes_of, make_production_mesh
from repro.models import api as mapi
from repro.train.optimizer import OptConfig
from repro.train.train_step import TrainConfig, make_train_step

COLLECTIVE_RE = re.compile(
    r"(\w[\w.\-]*)\s*=\s*((?:[a-z0-9_]+\s*)?)"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _bytes_of_shape(s: str) -> int:
    m = _SHAPE_RE.match(s.strip())
    if not m:
        return 0
    dt, dims = m.groups()
    if dt not in DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * DTYPE_BYTES[dt]


def collective_bytes(hlo_text: str) -> dict:
    """Sum operand bytes of every collective op in optimized HLO."""
    totals: dict[str, int] = {}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(
            r"^[%\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\],]+)\s*"
            r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
            r"collective-permute)(-start|-done)?\(",
            ls,
        )
        if not m:
            continue
        out_sig, op, phase = m.group(1), m.group(2), m.group(3)
        if phase == "-done":
            continue  # counted at -start
        if out_sig.startswith("("):
            shapes = out_sig[1:-1].split("),(")[0].split(", ")
            b = sum(_bytes_of_shape(s) for s in out_sig[1:-1].split(", "))
        else:
            b = _bytes_of_shape(out_sig)
        totals[op] = totals.get(op, 0) + b
    totals["total"] = sum(v for k, v in totals.items() if k != "total")
    return totals


def _train_cell(model, cfg, mesh, specs):
    """Lower a full train step (fwd + bwd + AdamW)."""
    tcfg = TrainConfig(opt=OptConfig(), microbatches=cfg.train_microbatches)
    train_step = make_train_step(model, tcfg, mesh=mesh)

    abstract_state = jax.eval_shape(
        lambda k: {"params": model.init(k)}, jax.random.PRNGKey(0)
    )
    p_specs = shd.param_pspecs(cfg, abstract_state["params"], mesh)
    state_specs = {
        "params": p_specs,
        "opt": {"mu": p_specs, "nu": p_specs, "step": P()},
    }
    batch_sp = shd.batch_pspecs(cfg, specs, mesh)

    from repro.train.optimizer import init_opt_state

    abstract_full = jax.eval_shape(
        lambda k: {
            "params": model.init(k),
            "opt": init_opt_state(model.init(k)),
        },
        jax.random.PRNGKey(0),
    )

    in_sh = (
        jax.tree.map(lambda s: NamedSharding(mesh, s), state_specs,
                     is_leaf=lambda x: isinstance(x, P)),
        jax.tree.map(lambda s: NamedSharding(mesh, s), batch_sp,
                     is_leaf=lambda x: isinstance(x, P)),
    )
    out_sh = (in_sh[0], None)
    fn = jax.jit(train_step, in_shardings=in_sh, out_shardings=out_sh,
                 donate_argnums=(0,))
    return fn.lower(abstract_full, specs)


def _prefill_cell(model, cfg, mesh, specs, shape_name):
    batch_sp = shd.batch_pspecs(cfg, specs, mesh)
    abstract_params = model.abstract_params()
    p_specs = shd.param_pspecs(cfg, abstract_params, mesh)
    b = mapi.SHAPES[shape_name]["batch"]
    s = mapi.SHAPES[shape_name]["seq"]

    def prefill_step(params, batch):
        logits, cache = model.prefill(params, batch)
        return logits, cache

    cache_abs = model.abstract_cache(b, s)
    cache_sp = shd.cache_pspecs(cfg, cache_abs, mesh, batch=b)

    in_sh = (
        jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs,
                     is_leaf=lambda x: isinstance(x, P)),
        jax.tree.map(lambda s: NamedSharding(mesh, s), batch_sp,
                     is_leaf=lambda x: isinstance(x, P)),
    )
    out_sh = (
        None,
        jax.tree.map(lambda s: NamedSharding(mesh, s), cache_sp,
                     is_leaf=lambda x: isinstance(x, P)),
    )
    fn = jax.jit(prefill_step, in_shardings=in_sh, out_shardings=out_sh)
    return fn.lower(abstract_params, specs)


def _decode_cell(model, cfg, mesh, specs):
    abstract_params = model.abstract_params()
    p_specs = shd.param_pspecs(cfg, abstract_params, mesh)
    cache_sp = shd.cache_pspecs(cfg, specs["cache"], mesh,
                                batch=specs["token"].shape[0])
    tok_sp = shd.batch_pspecs(cfg, {"token": specs["token"]}, mesh)["token"]

    def serve_step(params, cache, token, pos):
        return model.decode_step(params, cache, token, pos)

    cache_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), cache_sp,
                            is_leaf=lambda x: isinstance(x, P))
    in_sh = (
        jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs,
                     is_leaf=lambda x: isinstance(x, P)),
        cache_sh,
        NamedSharding(mesh, tok_sp),
        NamedSharding(mesh, P()),
    )
    fn = jax.jit(serve_step, in_shardings=in_sh, out_shardings=(None, cache_sh),
                 donate_argnums=(1,))
    return fn.lower(abstract_params, specs["cache"], specs["token"],
                    specs["pos"])


def block_specs_of(cfg, p_specs):
    """Per-layer param PartitionSpecs: the stacked specs minus the L axis."""
    def drop(s):
        return P(*tuple(s)[1:])

    def drop_tree(sub):
        return jax.tree.map(drop, sub, is_leaf=lambda x: isinstance(x, P))

    if cfg.family == "encdec":
        return {"enc": drop_tree(p_specs["enc_blocks"]),
                "dec": drop_tree(p_specs["dec_blocks"])}
    if isinstance(p_specs, dict) and "blocks" in p_specs and not isinstance(
        p_specs["blocks"], list
    ):
        return drop_tree(p_specs["blocks"])
    return None  # python-list models: params are first-class jit inputs


def lower_cell(arch_id: str, shape_name: str, *, multi_pod: bool = False,
               overrides: dict | None = None):
    """Returns (lowered, cfg, mesh). overrides patch ArchConfig fields."""
    import dataclasses as dc

    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = get(arch_id)
    if overrides:
        cfg = dc.replace(cfg, **overrides)
    model = mapi.build(cfg, mesh=mesh, dp_axes=dp_axes_of(mesh))
    if mapi.SHAPES[shape_name]["kind"] == "train":
        # rebuild with per-layer param constraints (keeps the backward
        # scan's grad accumulators sharded like the params)
        p_specs = shd.param_pspecs(cfg, model.abstract_params(), mesh)
        bspecs = block_specs_of(cfg, p_specs)
        model = mapi.build(cfg, mesh=mesh, dp_axes=dp_axes_of(mesh),
                           block_specs=bspecs)
    specs = model.input_specs(shape_name)
    kind = mapi.SHAPES[shape_name]["kind"]
    with mesh:
        if kind == "train":
            lowered = _train_cell(model, cfg, mesh, specs)
        elif kind == "prefill":
            lowered = _prefill_cell(model, cfg, mesh, specs, shape_name)
        else:
            lowered = _decode_cell(model, cfg, mesh, specs)
    return lowered, cfg, mesh


def run_cell(arch_id: str, shape_name: str, *, multi_pod: bool = False,
             out_path: str | None = None, overrides: dict | None = None,
             tag: str = "baseline"):
    t0 = time.time()
    record = {
        "arch": arch_id, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "tag": tag, "ok": False,
    }
    try:
        lowered, cfg, mesh = lower_cell(arch_id, shape_name,
                                        multi_pod=multi_pod,
                                        overrides=overrides)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        ma = compiled.memory_analysis()
        from repro.core import compat
        ca = compat.cost_analysis(compiled)
        txt = compiled.as_text()
        coll = collective_bytes(txt)
        # XLA's cost_analysis counts while bodies ONCE; every model here
        # scans over layers/microbatches, so re-derive trip-count-weighted
        # totals from the optimized HLO (launch/hlo_cost.py).
        w = hlo_cost.analyze(txt)
        n_dev = len(mesh.devices.reshape(-1))
        record.update(
            ok=True,
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            devices=n_dev,
            flops=w["flops"],
            bytes_accessed=w["bytes"],
            flops_xla_unweighted=ca.get("flops", 0.0),
            bytes_xla_unweighted=ca.get("bytes accessed", 0.0),
            while_trips=sorted(w["while_trips"].values(), reverse=True)[:8],
            unknown_trip_loops=len(w["unknown_trip_loops"]),
            arg_bytes_per_dev=ma.argument_size_in_bytes,
            out_bytes_per_dev=ma.output_size_in_bytes,
            temp_bytes_per_dev=ma.temp_size_in_bytes,
            peak_bytes_per_dev=(
                ma.argument_size_in_bytes + ma.temp_size_in_bytes
            ),
            collective_bytes=w["collective_bytes"],
            collective_bytes_unweighted=coll,
        )
    except Exception as e:  # noqa: BLE001 — report, don't crash the sweep
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-2000:]
    record["wall_s"] = round(time.time() - t0, 1)
    if out_path:
        with open(out_path, "a") as f:
            f.write(json.dumps(record) + "\n")
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every applicable (arch x shape) cell")
    ap.add_argument("--out", default="results/dryrun.jsonl")
    ap.add_argument("--tag", default="baseline")
    args = ap.parse_args()
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)

    cells = []
    if args.all:
        for aid in ARCH_IDS:
            cfg = get(aid)
            for shp in mapi.applicable_shapes(cfg):
                cells.append((aid, shp))
    else:
        assert args.arch and args.shape
        cells = [(args.arch, args.shape)]

    for aid, shp in cells:
        rec = run_cell(aid, shp, multi_pod=args.multi_pod, out_path=args.out,
                       tag=args.tag)
        status = "OK" if rec["ok"] else f"FAIL ({rec.get('error', '?')[:80]})"
        print(f"[{rec['mesh']}] {aid} x {shp}: {status}  "
              f"wall={rec['wall_s']}s peak/dev="
              f"{rec.get('peak_bytes_per_dev', 0)/2**30:.2f}GiB")


if __name__ == "__main__":
    main()
