"""Per-architecture PartitionSpec rules (DP / TP / FSDP / EP / SP).

Parameters are matched by tree path (joined with '/'):
  - attention projections, FFN and expert weights: TP over 'model';
    under `param_sharding == "fsdp"` the non-TP matmul dim is additionally
    sharded over the data axes (FSDP — XLA all-gathers per scanned layer).
  - expert stacks: expert axis over 'model' (EP).
  - embeddings: vocab over 'model'.
  - norms/gates/biases: replicated.
  - unknown leaves: generic fallback — last dim over 'model' when divisible,
    else replicated.

Activations: batch over the data axes; logits vocab over 'model'; decode
caches shard KV heads over 'model' and batch over data; batch-1 long-context
caches shard the *sequence* dim over data (SP).
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.config import ArchConfig


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _divisible(dim: int, mesh, axes) -> bool:
    if isinstance(axes, str):
        axes = (axes,)
    n = int(np.prod([mesh.shape[a] for a in axes]))
    return dim % n == 0


def _maybe(dim, mesh, axes):
    """axes if the dim divides evenly, else None. GSPMD can pad uneven
    shards, but even sharding avoids silent waste where possible."""
    return axes if _divisible(dim, mesh, axes) else None


def param_pspecs(cfg: ArchConfig, abstract_params, mesh) -> Any:
    """PartitionSpec pytree matching abstract_params."""
    dp = tuple(a for a in mesh.axis_names if a != "model")
    tp = "model"
    fsdp = cfg.param_sharding == "fsdp"

    def rule(path, leaf):
        name = _path_str(path)
        shape = leaf.shape
        nd = len(shape)

        def spec(*entries):
            # pad to rank with None
            entries = list(entries) + [None] * (nd - len(entries))
            return P(*entries)

        dpa = dp if fsdp else None

        if "embed/table" in name:
            # vocab over model even when uneven — GSPMD pads the last shard;
            # replicating a 100k x d table costs far more than the pad.
            return spec(tp, None)
        if name.endswith("meta"):
            return spec(None, None)
        # scanned blocks carry a leading L dim; python-list blocks do not.
        off = 1 if (name.startswith("blocks") and shape and
                    shape[0] == cfg.n_layers and nd >= 2) else 0
        if name.startswith(("enc_blocks", "dec_blocks")):
            off = 1

        def d(i):  # dim index after optional layer axis
            return shape[off + i]

        rank = nd - off
        if any(s in name for s in ("/wq", "/wk", "/wv", "/w_gate", "/w_up",
                                   "/w_z", "/w_in", "/w_bc", "/w_dq", "/w_uq",
                                   "/w_uk", "/w_uv", "/w1", "/w_gates")):
            if rank == 2:
                pre = [None] * off
                return P(*pre, _maybe(d(0), mesh, dp) if fsdp else None,
                         _maybe(d(1), mesh, tp))
            if rank == 3 and ("experts" in name or "/w_gate" in name or "/w_up" in name):
                # (E, d, fe): EP over model, fsdp over d
                pre = [None] * off
                return P(*pre, _maybe(d(0), mesh, tp),
                         _maybe(d(1), mesh, dp) if fsdp else None, None)
        if any(s in name for s in ("/wo", "/w_down", "/w_out", "/w2")):
            if rank == 2:
                pre = [None] * off
                return P(*pre, _maybe(d(0), mesh, tp),
                         _maybe(d(1), mesh, dp) if fsdp else None)
            if rank == 3:  # (E, fe, d) expert down-proj
                pre = [None] * off
                return P(*pre, _maybe(d(0), mesh, tp), None,
                         _maybe(d(1) if rank == 2 else shape[off + 2], mesh, dp)
                         if fsdp else None)
        if "/w_dkv" in name and rank == 2:
            pre = [None] * off
            return P(*pre, _maybe(d(0), mesh, dp) if fsdp else None, None)
        if "router" in name:
            return P(*([None] * nd))
        # fallback: replicate small leaves; shard last dim over model if big
        if nd >= 1 and shape[-1] >= 4096 and _divisible(shape[-1], mesh, tp):
            return P(*([None] * (nd - 1) + [tp]))
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(rule, abstract_params)


def batch_pspecs(cfg: ArchConfig, batch_specs, mesh) -> Any:
    """Input sharding: batch dim over all data axes."""
    dp = tuple(a for a in mesh.axis_names if a != "model")

    def rule(path, leaf):
        nd = len(leaf.shape)
        if leaf.shape and _divisible(leaf.shape[0], mesh, dp):
            return P(dp, *([None] * (nd - 1)))
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(rule, batch_specs)


def cache_pspecs(cfg: ArchConfig, abstract_cache, mesh, *, batch: int) -> Any:
    """Decode-cache sharding.

    batch >= dp: batch over data axes, KV heads over model.
    batch == 1 (long_500k): sequence dim over data (SP), heads over model.
    """
    dp = tuple(a for a in mesh.axis_names if a != "model")
    ndp = int(np.prod([mesh.shape[a] for a in dp]))
    seq_parallel = batch < ndp

    def rule(path, leaf):
        name = _path_str(path)
        shape = leaf.shape
        nd = len(shape)
        # transformer scanned cache: (L, B, S, KV, dh) / mla (L, B, S, r)
        # hymba/xlstm per-layer: (B, S, KV, dh) / states (B, H, ...)
        has_layer = shape and shape[0] == cfg.n_layers and nd >= 3
        off = 1 if has_layer else 0
        pre = [None] * off
        body = list(shape[off:])
        entries = [None] * len(body)
        if len(body) >= 2 and name.split("/")[-1] in ("k", "v", "xk", "xv", "c", "kr"):
            # (B, S, [KV, dh] | [r])
            seq_axes: list = []
            if not seq_parallel and _divisible(body[0], mesh, dp):
                entries[0] = dp
            elif seq_parallel and _divisible(body[1], mesh, dp):
                seq_axes.extend(dp)
            if len(body) >= 3 and _divisible(body[2], mesh, "model"):
                entries[2] = "model"
            elif _divisible(body[1], mesh, tuple(seq_axes) + ("model",)):
                # KV heads don't divide the TP degree (e.g. 8 kv over 16):
                # shard the cache *sequence* over 'model' instead — decode
                # attention becomes flash-decoding (partial softmax + small
                # cross-shard reduce).
                seq_axes.append("model")
            if seq_axes:
                entries[1] = tuple(seq_axes)
        else:
            # recurrent states (B, H, ...) — shard H over model if divisible
            if not seq_parallel and body and _divisible(body[0], mesh, dp):
                entries[0] = dp
            if len(body) >= 2 and _divisible(body[1], mesh, "model"):
                entries[1] = "model"
        return P(*pre, *entries)

    return jax.tree_util.tree_map_with_path(rule, abstract_cache)


def logits_pspec(cfg: ArchConfig, mesh):
    dp = tuple(a for a in mesh.axis_names if a != "model")
    return P(dp, None, "model")


def to_shardings(mesh, pspecs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                        is_leaf=lambda x: isinstance(x, P))
