"""Payload movement strategies for the distributed sort.

A gensort record is a 10-byte key + 90-byte payload (§2.2). The shuffle
kernels sort (key: u32, id: u32) headers; this module decides how the wide
payload bytes follow their header:

  - "through" (paper-faithful): the payload physically accompanies its
    record through the shuffle all_to_all, as in the paper where whole
    100-byte records flow map -> network -> merge -> disk -> reduce.

  - "late" (beyond-paper optimization, see EXPERIMENTS.md §Perf): the
    shuffle moves only the 8-byte headers; after the final merge each worker
    *fetches* the payloads of its output records from their producing
    workers with one extra all_to_all, keyed by global record id. Total
    network bytes are comparable, but payloads never traverse the merge
    tournament or the stage-1/stage-2 spill, cutting the memory-bound merge
    traffic by the payload/record ratio (~12.5x for 100-byte records).

Global record ids: records are numbered so that id // records_per_worker is
the producing worker (the data/gensort.py layout), making the late fetch a
static-capacity exchange under uniform output ranges.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import sortlib


def align_payload_to_merge(recv_ids, recv_payload, merged_ids):
    """Reorder received payload rows to follow the post-merge record order.

    recv_ids: (m,) u32 global ids in arrival (pre-merge) order;
    recv_payload: (m, pw) payload rows aligned with recv_ids;
    merged_ids: (m,) the same multiset of ids in post-merge order.
    Returns (m, pw) payload aligned with merged_ids.

    The merge network permutes (key, id) pairs; rather than dragging pw
    words through every compare-exchange, we re-derive the permutation by
    an id join: sort arrival ids once, binary-search each merged id.
    Pad ids (0xFFFFFFFF) join against pad rows, which is harmless.
    """
    perm = jnp.argsort(recv_ids)  # (m,)
    sids = recv_ids[perm]
    pos = jnp.searchsorted(sids, merged_ids)
    pos = jnp.clip(pos, 0, sids.shape[0] - 1)
    return recv_payload[perm[pos]]


def exchange_payload_blocks(block_payload, axis):
    """all_to_all of (W, C, pw) payload blocks — the 'through' mode wire hop."""
    return jax.lax.all_to_all(
        block_payload, axis, split_axis=0, concat_axis=0, tiled=True
    )


def late_fetch_payload(
    final_ids,
    local_payload,
    *,
    axis,
    num_workers: int,
    records_per_worker: int,
    capacity: int,
):
    """'late' mode: fetch payload rows for `final_ids` from their producers.

    Per-device code under shard_map.
    final_ids: (m,) u32 global record ids this worker's output needs (pads
      0xFFFFFFFF allowed); local_payload: (records_per_worker, pw) rows this
      worker produced (row r holds global id = my_rank*records_per_worker+r).
    capacity: static per-(requester, producer) request budget; with uniform
      keys m/W requests go to each producer (+ slack).
    Returns (m, pw) payload rows aligned with final_ids, and overflow flag.

    Implementation: route *requests* (the ids) to producers with the same
    fixed-capacity block protocol as the shuffle itself, gather rows there,
    and route the rows back by reversing the all_to_all.
    """
    m = final_ids.shape[0]
    # Producer of each id. Pad ids (lex-max sentinels past the valid prefix)
    # are spread round-robin so no producer's request block overflows; their
    # fetched rows are garbage and ignored by the caller (count-sliced).
    pos = jnp.arange(m, dtype=jnp.uint32)
    is_pad = final_ids == jnp.uint32(0xFFFFFFFF)
    prod = jnp.minimum(final_ids // jnp.uint32(records_per_worker),
                       jnp.uint32(num_workers - 1))
    prod = jnp.where(is_pad, pos % jnp.uint32(num_workers), prod)
    # Sort requests by producer so each producer's requests are contiguous.
    sprod, sids = jax.lax.sort((prod.astype(jnp.uint32), final_ids), num_keys=1)
    req_perm_src = jnp.argsort(prod.astype(jnp.uint32))  # position in sorted of each
    bounds = (jnp.arange(1, num_workers, dtype=jnp.uint32))
    starts, counts = sortlib.partition_sorted(sprod, bounds, impl="ref")
    req_blocks, _, overflow = sortlib.gather_range_blocks(
        sids, sids, starts, counts, capacity
    )  # (W, C) ids (key==val here; second copy unused)
    # Requests travel requester -> producer.
    recv_req = jax.lax.all_to_all(req_blocks, axis, 0, 0, tiled=True)  # (W, C)
    # Serve: local row index of each requested id (u32 math; foreign/pad ids
    # wrap and are clamped — their rows are never read by the requester).
    my = jax.lax.axis_index(axis).astype(jnp.uint32)
    diff = recv_req - my * jnp.uint32(records_per_worker)
    local_row = jnp.minimum(diff, jnp.uint32(records_per_worker - 1)).astype(jnp.int32)
    served = local_payload[local_row]  # (W, C, pw)
    # Rows travel producer -> requester (reverse exchange).
    back = jax.lax.all_to_all(served, axis, 0, 0, tiled=True)  # (W, C, pw)
    # Un-block: requester's row j of block w corresponds to sorted request
    # starts[w] + j.
    c = back.shape[1]
    j = jnp.arange(c, dtype=jnp.int32)[None, :]
    dest_sorted_pos = jnp.clip(starts[:, None] + j, 0, m - 1)  # (W, C)
    gathered_sorted = jnp.zeros((m, back.shape[-1]), back.dtype)
    gathered_sorted = gathered_sorted.at[dest_sorted_pos.reshape(-1)].set(
        back.reshape(-1, back.shape[-1])
    )
    # Invert the request sort back to final_ids order.
    inv = jnp.zeros((m,), jnp.int32).at[req_perm_src].set(
        jnp.arange(m, dtype=jnp.int32)
    )
    # req_perm_src maps sorted_pos -> original pos; we need original -> sorted.
    out = gathered_sorted[inv]
    return out, overflow
