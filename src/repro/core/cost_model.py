"""Total-cost-of-ownership model (paper §3.3.2, Table 2) + TPU variant.

Reproduces the paper's arithmetic exactly — compute $/hr x job hours, S3
storage-hours for input/output, and per-request GET/PUT fees — and provides
a TPU-pod re-parameterization for the adapted system so the benchmark
harness can report an apples-to-apples CloudSort TCO for our design.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Ec2CostParams:
    """Paper values (§3.3.2, November 2022, us-west-2 on-demand)."""

    master_hourly: float = 0.504  # r6i.2xlarge
    worker_hourly: float = 1.373  # i4i.4xlarge
    num_workers: int = 40
    ebs_gb: int = 40
    ebs_month_per_gb: float = 0.08
    hours_per_month: float = 365 * 24 / 12  # 730

    # S3 (first 50 TB / next 450 TB tiers averaged for a 100 TB dataset)
    s3_gb_month_tier1: float = 0.023
    s3_gb_month_tier2: float = 0.022
    get_per_1000: float = 0.0004
    put_per_1000: float = 0.005

    # Local-SSD spill tier (§2.3): i4i instance NVMe is bundled into
    # worker_hourly, so its marginal $/GB-month is 0 and spill requests
    # are free. Nonzero models an EBS-gp3-style attached-volume spill.
    ssd_gb_month: float = 0.0

    @property
    def ebs_hourly(self) -> float:
        # The paper rounds this intermediate to $0.0044 before Equation (1)
        # ("$0.08/730 x 40 = $0.0044"); match its arithmetic to the cent.
        return round(self.ebs_month_per_gb / self.hours_per_month
                     * self.ebs_gb, 4)

    @property
    def cluster_hourly(self) -> float:
        """Equation (1)."""
        return (
            self.master_hourly
            + self.worker_hourly * self.num_workers
            + self.ebs_hourly * (self.num_workers + 1)
        )

    def s3_hourly_per_100tb(self) -> float:
        avg_gb_month = (self.s3_gb_month_tier1 + self.s3_gb_month_tier2) / 2
        return avg_gb_month * 100_000 / self.hours_per_month


@dataclasses.dataclass(frozen=True)
class JobProfile:
    """Measured run profile (paper Table 1 averages)."""

    # The paper rounds to 4 decimals before multiplying; match it exactly.
    job_hours: float = 1.4939  # 5378 s
    reduce_hours: float = 0.5194  # 1870 s
    get_requests: int = 6_000_000  # 50k maps x 120 chunks
    put_requests: int = 1_000_000  # 25k reduces x 40 chunks


@dataclasses.dataclass(frozen=True)
class CostBreakdown:
    compute: float
    storage_input: float
    storage_output: float
    access_get: float
    access_put: float
    # Tiered-store leg (0 in the paper's Table 2: i4i NVMe spill is
    # bundled into the instance price). Populated by the tiered measured
    # path when ssd_gb_month is nonzero.
    storage_spill: float = 0.0

    @property
    def total(self) -> float:
        return (
            self.compute
            + self.storage_input
            + self.storage_output
            + self.storage_spill
            + self.access_get
            + self.access_put
        )

    def rows(self):
        return [
            ("compute_vm_cluster", self.compute),
            ("data_storage_input", self.storage_input),
            ("data_storage_output", self.storage_output),
            ("data_storage_spill_ssd", self.storage_spill),
            ("data_access_input_get", self.access_get),
            ("data_access_output_put", self.access_put),
            ("total", self.total),
        ]


def cloudsort_tco(
    params: Ec2CostParams = Ec2CostParams(),
    profile: JobProfile = JobProfile(),
    *,
    data_tb: float = 100.0,
) -> CostBreakdown:
    """Table 2. With default arguments returns the paper's $96.6728.

    `data_tb` scales the storage-hour legs for datasets other than the
    100 TB record run; the request legs are already absolute through the
    profile's counts.
    """
    s3_hr = params.s3_hourly_per_100tb() * (data_tb / 100.0)
    return CostBreakdown(
        compute=params.cluster_hourly * profile.job_hours,
        storage_input=s3_hr * profile.job_hours,
        storage_output=s3_hr * profile.reduce_hours,
        access_get=params.get_per_1000 * profile.get_requests / 1000,
        access_put=params.put_per_1000 * profile.put_requests / 1000,
    )


def measured_job_profile(stats, *, job_hours: float, reduce_hours: float) -> JobProfile:
    """JobProfile from *measured* store counters, not Table-1 constants.

    `stats` is duck-typed: anything with .get_requests / .put_requests —
    in practice io.backends.StoreStats deltas captured by
    core.external_sort (the store counts every chunked map GET, ranged
    reduce GET, spill PUT and multipart-upload part PUT it actually
    served). Under a fault-injected store the counters are retry-inflated
    by construction (io/middleware.MetricsMiddleware counts every issued
    attempt, throttled or not), so the access legs price the real request
    traffic a retrying client generates, not the logical operation count.
    """
    return JobProfile(
        job_hours=job_hours,
        reduce_hours=reduce_hours,
        get_requests=int(stats.get_requests),
        put_requests=int(stats.put_requests),
    )


def measured_cloudsort_tco(
    stats,
    *,
    job_hours: float,
    reduce_hours: float,
    data_bytes: float,
    params: Ec2CostParams = Ec2CostParams(),
) -> CostBreakdown:
    """Table 2 priced from an actual run: measured request counts and
    timings (core.external_sort.ExternalSortReport), storage legs scaled
    to the dataset actually sorted.

    Which Table-2 legs are MEASURED here and which are still ASSUMED:

      measured — data_access_input_get / data_access_output_put come
          from the run's StoreStats deltas: every chunked map GET,
          ranged reduce GET, spill PUT and multipart part PUT the store
          actually served, instead of the paper's 50k x 120 = 6M GET /
          25k x 40 = 1M PUT arithmetic. job_hours / reduce_hours (the
          storage-hour multipliers) are the run's wall clock.

      assumed — the EC2 price sheet (Ec2CostParams: $/hr for master/
          worker/EBS, S3 $/GB-month tiers, per-1000 request fees) is
          carried over from the paper's November-2022 us-west-2 rates;
          an emulated run can't measure prices. The storage-hour legs
          also assume the paper's layout: the dataset sits in S3 for the
          whole job (input leg) and output accretes over the reduce
          phase (output leg).

    Retry-inflated attempt counts are deliberately the billing basis:
    MetricsMiddleware counts every *issued* attempt — throttled 503s and
    backoff re-issues included — because S3 bills requests, not logical
    operations. A client that retries its way through a throttling
    regime pays for the retries; pricing the logical count would
    understate exactly the §3.3.2 cost the paper's request-fee analysis
    is about. (Cluster re-execution after a worker failure inflates the
    same way: a re-run task's requests are real, billed traffic.)
    """
    profile = measured_job_profile(stats, job_hours=job_hours, reduce_hours=reduce_hours)
    return cloudsort_tco(params, profile, data_tb=data_bytes / 1e12)


def measured_tiered_cloudsort_tco(
    tier_stats,
    *,
    job_hours: float,
    reduce_hours: float,
    data_bytes: float,
    params: Ec2CostParams = Ec2CostParams(),
) -> CostBreakdown:
    """Table 2 priced from a tiered run: only the DURABLE tier's requests
    hit the S3 access legs (the paper's 6M GET / 1M PUT arithmetic never
    included spill traffic — spill goes to local SSD, §2.3), while the
    SSD tier's bytes price the spill-storage leg at ssd_gb_month (0 for
    bundled instance NVMe, like the paper's i4i workers).

    Measured vs. assumed, on top of measured_cloudsort_tco's split: the
    durable/ssd request partition is measured (TieredStore routes by key
    prefix and meters each tier separately), and the durable counters
    are retry-inflated like a real bill — the right basis, since only
    durable attempts cost money while SSD attempts are free however
    often a retry or a re-executed cluster task re-issues them. Assumed:
    spill capacity is billed by bytes *written* over job_hours (an
    attached-volume upper bound; with the default ssd_gb_month=0 the leg
    is $0, matching Table 2's bundled i4i NVMe).

    `tier_stats` is core.external_sort.ExternalSortReport.tier_stats:
    a {"durable": StoreStats, "ssd": StoreStats} delta mapping from
    io.tiered.TieredStore.per_tier_stats().
    """
    durable = tier_stats["durable"]
    ssd = tier_stats.get("ssd")
    base = measured_cloudsort_tco(
        durable, job_hours=job_hours, reduce_hours=reduce_hours,
        data_bytes=data_bytes, params=params)
    spill = 0.0
    if ssd is not None and params.ssd_gb_month:
        spill_gb = ssd.bytes_written / 1e9
        spill = (params.ssd_gb_month / params.hours_per_month
                 * spill_gb * job_hours)
    return dataclasses.replace(base, storage_spill=spill)


# ---------------------------------------------------------------------------
# Serverless: the per-invocation GB-second pricing leg (ROADMAP item 2)
# ---------------------------------------------------------------------------


def _require(cond: bool, knob: str, value, why: str) -> None:
    if not cond:
        raise ValueError(f"{knob}={value!r}: {why}")


@dataclasses.dataclass(frozen=True)
class ServerlessCostParams:
    """Lambda-style function pricing (x86 on-demand, us-west-2, late 2022).

    The compute leg bills GB-seconds: billed duration (rounded UP to
    `duration_step_ms`) times the function's memory size (peak usage
    rounded UP to `memory_step_mib`, floored at `memory_floor_mib` — the
    smallest size the platform sells), plus a flat `per_invocation` fee.
    The object-store legs are unchanged from the paper's S3 model
    (`s3`): serverless-sort and BlobShuffle both show the request fees,
    not the compute meter, are where object-store shuffle cost lives.

    `equivalent_worker_memory_gb` / `invocations_per_100tb` parameterize
    the closed-form sweep (`serverless_tco_at`): a function fleet doing
    the paper's 100 TB job buys the same GB-hours the 40 i4i.4xlarge
    workers (128 GB each) held for `job_hours`, sliced into the paper's
    50k map + 25k reduce task invocations.
    """

    gb_second: float = 1.66667e-5  # $ per GB-second of billed duration
    per_invocation: float = 2e-7  # $0.20 per 1M requests
    memory_floor_mib: int = 128  # smallest purchasable function size
    memory_step_mib: int = 1  # memory-size granularity
    duration_step_ms: float = 1.0  # billed-duration granularity
    equivalent_worker_memory_gb: float = 128.0  # i4i.4xlarge
    invocations_per_100tb: int = 75_000  # 50k maps + 25k reduces
    s3: Ec2CostParams = Ec2CostParams()

    def __post_init__(self):
        _require(self.gb_second > 0, "gb_second", self.gb_second,
                 "the GB-second rate must be positive")
        _require(self.per_invocation >= 0, "per_invocation",
                 self.per_invocation, "the per-request fee must be >= 0")
        _require(self.memory_floor_mib > 0, "memory_floor_mib",
                 self.memory_floor_mib,
                 "the smallest function size must be positive")
        _require(self.memory_step_mib > 0, "memory_step_mib",
                 self.memory_step_mib,
                 "the memory-size granularity must be positive")
        _require(self.duration_step_ms > 0, "duration_step_ms",
                 self.duration_step_ms,
                 "the billed-duration granularity must be positive")
        _require(self.equivalent_worker_memory_gb > 0,
                 "equivalent_worker_memory_gb",
                 self.equivalent_worker_memory_gb,
                 "the per-worker memory equivalence must be positive")
        _require(self.invocations_per_100tb >= 0, "invocations_per_100tb",
                 self.invocations_per_100tb,
                 "the invocation count must be >= 0")


@dataclasses.dataclass(frozen=True)
class InvocationProfile:
    """One function invocation as the meter saw it: billed wall-clock
    and measured peak memory (cloud.function_worker.InvocationRecord
    carries the measurement; this is the pricing-facing slice)."""

    seconds: float
    peak_bytes: int

    def __post_init__(self):
        _require(self.seconds >= 0, "seconds", self.seconds,
                 "billed duration must be >= 0")
        _require(self.peak_bytes >= 0, "peak_bytes", self.peak_bytes,
                 "peak memory must be >= 0")


def billed_gb_seconds(profile: InvocationProfile,
                      params: ServerlessCostParams = ServerlessCostParams(),
                      ) -> float:
    """GB-seconds the platform bills for one invocation: measured peak
    memory rounded up to the size granularity (floored at the smallest
    purchasable size) times duration rounded up to the billing step —
    a minimum of one step, since a 0 ms invocation still bills one."""
    import math

    mib = profile.peak_bytes / float(1 << 20)
    step = params.memory_step_mib
    billed_mib = max(params.memory_floor_mib, math.ceil(mib / step) * step)
    # Epsilon guards the float division so exact multiples of the step
    # don't round up an extra step (2.0 s at a 1 ms step bills 2000 ms).
    steps = max(1, math.ceil(profile.seconds * 1000.0
                             / params.duration_step_ms - 1e-9))
    billed_s = steps * params.duration_step_ms / 1000.0
    return (billed_mib / 1024.0) * billed_s


def serverless_compute_cost(
    invocations,
    params: ServerlessCostParams = ServerlessCostParams(),
) -> float:
    """The serverless compute leg: sum of billed GB-seconds across the
    run's invocations at the GB-second rate, plus the flat request fee
    per invocation. Re-executed / speculated attempts appear as extra
    invocations and are billed — like VM re-execution traffic, retries
    are real, billed compute."""
    profiles = list(invocations)
    gbs = sum(billed_gb_seconds(p, params) for p in profiles)
    return gbs * params.gb_second + len(profiles) * params.per_invocation


def measured_serverless_tco(
    invocations,
    stats,
    *,
    job_hours: float,
    reduce_hours: float,
    data_bytes: float,
    params: ServerlessCostParams = ServerlessCostParams(),
) -> CostBreakdown:
    """Table 2 with the VM compute row replaced by the measured
    per-invocation GB-second leg.

    Measured vs. assumed follows measured_cloudsort_tco exactly for the
    storage/access legs (same arithmetic via `params.s3`, same
    retry-inflated attempt-count billing basis — `stats` is the sum of
    every invocation's own MetricsMiddleware counters, so a SlowDown'd
    and retried GET bills twice here too). The compute leg is measured
    from each invocation's wall-clock and peak memory; the price sheet
    (`ServerlessCostParams` rates) is assumed.
    """
    base = measured_cloudsort_tco(
        stats, job_hours=job_hours, reduce_hours=reduce_hours,
        data_bytes=data_bytes, params=params.s3)
    return dataclasses.replace(
        base, compute=serverless_compute_cost(invocations, params))


def cluster_tco_at(
    data_tb: float,
    *,
    params: Ec2CostParams = Ec2CostParams(),
    profile: JobProfile = JobProfile(),
    provision_hours: float = 1 / 12,
) -> CostBreakdown:
    """Closed-form VM-cluster TCO at an arbitrary dataset size, for the
    crossover sweep: job time and request counts scale linearly from the
    100 TB profile, but the compute leg has a PROVISIONING FLOOR — a
    cluster bills from boot, and nobody gets a 40-node fleet up, sorted,
    and torn down in under ~`provision_hours` (default 5 minutes)
    however small the dataset. The storage legs use the unfloored scaled
    hours: data sits in S3 for the data's time, not the idle VMs'."""
    _require(data_tb > 0, "data_tb", data_tb, "dataset size must be positive")
    _require(provision_hours >= 0, "provision_hours", provision_hours,
             "the cluster provisioning floor must be >= 0 hours")
    frac = data_tb / 100.0
    job_h = profile.job_hours * frac
    s3_hr = params.s3_hourly_per_100tb() * frac
    return CostBreakdown(
        compute=params.cluster_hourly * max(job_h, provision_hours),
        storage_input=s3_hr * job_h,
        storage_output=s3_hr * profile.reduce_hours * frac,
        access_get=params.get_per_1000 * profile.get_requests * frac / 1000,
        access_put=params.put_per_1000 * profile.put_requests * frac / 1000,
    )


def serverless_tco_at(
    data_tb: float,
    *,
    fn: ServerlessCostParams = ServerlessCostParams(),
    vm_profile: JobProfile = JobProfile(),
) -> CostBreakdown:
    """Closed-form serverless TCO at an arbitrary dataset size: the
    function fleet buys the same GB-hours the paper's VM cluster held
    for the (scaled) job, with NO provisioning floor — functions bill
    per invocation from the first millisecond, which is exactly why
    serverless wins small datasets and loses big ones (the per-GB-second
    rate is ~5.5x the amortized VM rate). Storage/access legs match
    cluster_tco_at so the crossover isolates the compute-meter shape."""
    _require(data_tb > 0, "data_tb", data_tb, "dataset size must be positive")
    frac = data_tb / 100.0
    gb_hours = (fn.equivalent_worker_memory_gb * fn.s3.num_workers
                * vm_profile.job_hours * frac)
    compute = (gb_hours * 3600.0 * fn.gb_second
               + fn.invocations_per_100tb * frac * fn.per_invocation)
    job_h = vm_profile.job_hours * frac
    s3_hr = fn.s3.s3_hourly_per_100tb() * frac
    return CostBreakdown(
        compute=compute,
        storage_input=s3_hr * job_h,
        storage_output=s3_hr * vm_profile.reduce_hours * frac,
        access_get=fn.s3.get_per_1000 * vm_profile.get_requests * frac / 1000,
        access_put=fn.s3.put_per_1000 * vm_profile.put_requests * frac / 1000,
    )


def serverless_crossover_tb(
    *,
    fn: ServerlessCostParams = ServerlessCostParams(),
    vm: Ec2CostParams = Ec2CostParams(),
    profile: JobProfile = JobProfile(),
    provision_hours: float = 1 / 12,
    lo_tb: float = 1e-3,
    hi_tb: float = 1e3,
) -> float:
    """Dataset size (TB) where serverless and cluster TCO cross.

    Below the crossover the cluster's provisioning floor dominates and
    per-invocation billing wins; above it the GB-second premium does.
    Bisection over [lo_tb, hi_tb]; raises ValueError if the gap doesn't
    change sign over the bracket (no crossover under these prices).
    With default parameters the crossover sits just above 1 TB.
    """

    def gap(tb: float) -> float:
        return (serverless_tco_at(tb, fn=fn, vm_profile=profile).total
                - cluster_tco_at(tb, params=vm, profile=profile,
                                 provision_hours=provision_hours).total)

    glo, ghi = gap(lo_tb), gap(hi_tb)
    _require(glo * ghi <= 0, "crossover_bracket", (lo_tb, hi_tb),
             "serverless-vs-cluster cost gap does not change sign over "
             "the bracket — no crossover under these prices")
    for _ in range(200):
        mid = (lo_tb + hi_tb) / 2.0
        if gap(mid) * glo <= 0:
            hi_tb = mid
        else:
            lo_tb = mid
    return (lo_tb + hi_tb) / 2.0


# ---------------------------------------------------------------------------
# TPU-pod re-parameterization (the adapted system of DESIGN.md §2)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TpuPodCostParams:
    """TPU v5e public on-demand pricing (us-west), per chip-hour."""

    chip_hourly: float = 1.20
    num_chips: int = 256
    ici_link_gbps: float = 50.0  # GB/s per link
    hbm_gbps: float = 819.0  # GB/s per chip
    # Object-store legs unchanged from the paper's S3 model.
    s3: Ec2CostParams = Ec2CostParams()


def tpu_sort_time_model(
    data_bytes: float,
    p: TpuPodCostParams = TpuPodCostParams(),
    *,
    payload_mode: str = "through",
    num_rounds: int = 8,
) -> dict:
    """Roofline-style job-time estimate for the TPU exoshuffle.

    Per-chip data share D = data_bytes / chips. Terms:
      network: the shuffle all_to_all moves ~D (1 - 1/W) ≈ D bytes per chip
               over ICI (bisection-limited at 1 link share per chip);
               "late" mode adds a second header+payload exchange but removes
               payload from merge traffic.
      memory : sort + merge tournament passes over the data in HBM —
               log2(W) merge rounds x 2 (read+write) x bytes in flight.
    The max of the two (they overlap via round pipelining) is the stage-1
    time; stage-2 reduce adds one more log2(rounds) merge sweep.
    """
    import math

    d = data_bytes / p.num_chips
    hdr_frac = 8.0 / 100.0  # header bytes per 100-byte record
    if payload_mode == "through":
        wire = d
        merge_bytes = d
    else:
        wire = d * hdr_frac + d  # header shuffle + late payload fetch
        merge_bytes = d * hdr_frac
    merge_rounds = math.log2(p.num_chips) + math.log2(max(num_rounds, 2))
    t_net = wire / (p.ici_link_gbps * 1e9)
    t_mem = merge_bytes * 2 * merge_rounds / (p.hbm_gbps * 1e9)
    t_stage1 = max(t_net, t_mem)
    io_time = data_bytes / p.num_chips / (p.ici_link_gbps * 1e9)  # S3 in+out legs
    total = t_stage1 + io_time
    return {
        "t_network_s": t_net,
        "t_memory_s": t_mem,
        "t_total_s": total,
        "job_hours": total / 3600,
    }


def tpu_cloudsort_tco(
    data_bytes: float = 100e12,
    p: TpuPodCostParams = TpuPodCostParams(),
    *,
    payload_mode: str = "through",
) -> CostBreakdown:
    t = tpu_sort_time_model(data_bytes, p, payload_mode=payload_mode)
    job_hours = t["job_hours"]
    s3_hr = p.s3.s3_hourly_per_100tb() * (data_bytes / 100e12)
    profile = JobProfile()
    return CostBreakdown(
        compute=p.chip_hourly * p.num_chips * job_hours,
        storage_input=s3_hr * job_hours,
        storage_output=s3_hr * job_hours * 0.35,  # reduce-phase fraction
        access_get=p.s3.get_per_1000 * profile.get_requests / 1000,
        access_put=p.s3.put_per_1000 * profile.put_requests / 1000,
    )
