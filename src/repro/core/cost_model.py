"""Total-cost-of-ownership model (paper §3.3.2, Table 2) + TPU variant.

Reproduces the paper's arithmetic exactly — compute $/hr x job hours, S3
storage-hours for input/output, and per-request GET/PUT fees — and provides
a TPU-pod re-parameterization for the adapted system so the benchmark
harness can report an apples-to-apples CloudSort TCO for our design.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Ec2CostParams:
    """Paper values (§3.3.2, November 2022, us-west-2 on-demand)."""

    master_hourly: float = 0.504  # r6i.2xlarge
    worker_hourly: float = 1.373  # i4i.4xlarge
    num_workers: int = 40
    ebs_gb: int = 40
    ebs_month_per_gb: float = 0.08
    hours_per_month: float = 365 * 24 / 12  # 730

    # S3 (first 50 TB / next 450 TB tiers averaged for a 100 TB dataset)
    s3_gb_month_tier1: float = 0.023
    s3_gb_month_tier2: float = 0.022
    get_per_1000: float = 0.0004
    put_per_1000: float = 0.005

    # Local-SSD spill tier (§2.3): i4i instance NVMe is bundled into
    # worker_hourly, so its marginal $/GB-month is 0 and spill requests
    # are free. Nonzero models an EBS-gp3-style attached-volume spill.
    ssd_gb_month: float = 0.0

    @property
    def ebs_hourly(self) -> float:
        # The paper rounds this intermediate to $0.0044 before Equation (1)
        # ("$0.08/730 x 40 = $0.0044"); match its arithmetic to the cent.
        return round(self.ebs_month_per_gb / self.hours_per_month
                     * self.ebs_gb, 4)

    @property
    def cluster_hourly(self) -> float:
        """Equation (1)."""
        return (
            self.master_hourly
            + self.worker_hourly * self.num_workers
            + self.ebs_hourly * (self.num_workers + 1)
        )

    def s3_hourly_per_100tb(self) -> float:
        avg_gb_month = (self.s3_gb_month_tier1 + self.s3_gb_month_tier2) / 2
        return avg_gb_month * 100_000 / self.hours_per_month


@dataclasses.dataclass(frozen=True)
class JobProfile:
    """Measured run profile (paper Table 1 averages)."""

    # The paper rounds to 4 decimals before multiplying; match it exactly.
    job_hours: float = 1.4939  # 5378 s
    reduce_hours: float = 0.5194  # 1870 s
    get_requests: int = 6_000_000  # 50k maps x 120 chunks
    put_requests: int = 1_000_000  # 25k reduces x 40 chunks


@dataclasses.dataclass(frozen=True)
class CostBreakdown:
    compute: float
    storage_input: float
    storage_output: float
    access_get: float
    access_put: float
    # Tiered-store leg (0 in the paper's Table 2: i4i NVMe spill is
    # bundled into the instance price). Populated by the tiered measured
    # path when ssd_gb_month is nonzero.
    storage_spill: float = 0.0

    @property
    def total(self) -> float:
        return (
            self.compute
            + self.storage_input
            + self.storage_output
            + self.storage_spill
            + self.access_get
            + self.access_put
        )

    def rows(self):
        return [
            ("compute_vm_cluster", self.compute),
            ("data_storage_input", self.storage_input),
            ("data_storage_output", self.storage_output),
            ("data_storage_spill_ssd", self.storage_spill),
            ("data_access_input_get", self.access_get),
            ("data_access_output_put", self.access_put),
            ("total", self.total),
        ]


def cloudsort_tco(
    params: Ec2CostParams = Ec2CostParams(),
    profile: JobProfile = JobProfile(),
    *,
    data_tb: float = 100.0,
) -> CostBreakdown:
    """Table 2. With default arguments returns the paper's $96.6728.

    `data_tb` scales the storage-hour legs for datasets other than the
    100 TB record run; the request legs are already absolute through the
    profile's counts.
    """
    s3_hr = params.s3_hourly_per_100tb() * (data_tb / 100.0)
    return CostBreakdown(
        compute=params.cluster_hourly * profile.job_hours,
        storage_input=s3_hr * profile.job_hours,
        storage_output=s3_hr * profile.reduce_hours,
        access_get=params.get_per_1000 * profile.get_requests / 1000,
        access_put=params.put_per_1000 * profile.put_requests / 1000,
    )


def measured_job_profile(stats, *, job_hours: float, reduce_hours: float) -> JobProfile:
    """JobProfile from *measured* store counters, not Table-1 constants.

    `stats` is duck-typed: anything with .get_requests / .put_requests —
    in practice io.backends.StoreStats deltas captured by
    core.external_sort (the store counts every chunked map GET, ranged
    reduce GET, spill PUT and multipart-upload part PUT it actually
    served). Under a fault-injected store the counters are retry-inflated
    by construction (io/middleware.MetricsMiddleware counts every issued
    attempt, throttled or not), so the access legs price the real request
    traffic a retrying client generates, not the logical operation count.
    """
    return JobProfile(
        job_hours=job_hours,
        reduce_hours=reduce_hours,
        get_requests=int(stats.get_requests),
        put_requests=int(stats.put_requests),
    )


def measured_cloudsort_tco(
    stats,
    *,
    job_hours: float,
    reduce_hours: float,
    data_bytes: float,
    params: Ec2CostParams = Ec2CostParams(),
) -> CostBreakdown:
    """Table 2 priced from an actual run: measured request counts and
    timings (core.external_sort.ExternalSortReport), storage legs scaled
    to the dataset actually sorted.

    Which Table-2 legs are MEASURED here and which are still ASSUMED:

      measured — data_access_input_get / data_access_output_put come
          from the run's StoreStats deltas: every chunked map GET,
          ranged reduce GET, spill PUT and multipart part PUT the store
          actually served, instead of the paper's 50k x 120 = 6M GET /
          25k x 40 = 1M PUT arithmetic. job_hours / reduce_hours (the
          storage-hour multipliers) are the run's wall clock.

      assumed — the EC2 price sheet (Ec2CostParams: $/hr for master/
          worker/EBS, S3 $/GB-month tiers, per-1000 request fees) is
          carried over from the paper's November-2022 us-west-2 rates;
          an emulated run can't measure prices. The storage-hour legs
          also assume the paper's layout: the dataset sits in S3 for the
          whole job (input leg) and output accretes over the reduce
          phase (output leg).

    Retry-inflated attempt counts are deliberately the billing basis:
    MetricsMiddleware counts every *issued* attempt — throttled 503s and
    backoff re-issues included — because S3 bills requests, not logical
    operations. A client that retries its way through a throttling
    regime pays for the retries; pricing the logical count would
    understate exactly the §3.3.2 cost the paper's request-fee analysis
    is about. (Cluster re-execution after a worker failure inflates the
    same way: a re-run task's requests are real, billed traffic.)
    """
    profile = measured_job_profile(stats, job_hours=job_hours, reduce_hours=reduce_hours)
    return cloudsort_tco(params, profile, data_tb=data_bytes / 1e12)


def measured_tiered_cloudsort_tco(
    tier_stats,
    *,
    job_hours: float,
    reduce_hours: float,
    data_bytes: float,
    params: Ec2CostParams = Ec2CostParams(),
) -> CostBreakdown:
    """Table 2 priced from a tiered run: only the DURABLE tier's requests
    hit the S3 access legs (the paper's 6M GET / 1M PUT arithmetic never
    included spill traffic — spill goes to local SSD, §2.3), while the
    SSD tier's bytes price the spill-storage leg at ssd_gb_month (0 for
    bundled instance NVMe, like the paper's i4i workers).

    Measured vs. assumed, on top of measured_cloudsort_tco's split: the
    durable/ssd request partition is measured (TieredStore routes by key
    prefix and meters each tier separately), and the durable counters
    are retry-inflated like a real bill — the right basis, since only
    durable attempts cost money while SSD attempts are free however
    often a retry or a re-executed cluster task re-issues them. Assumed:
    spill capacity is billed by bytes *written* over job_hours (an
    attached-volume upper bound; with the default ssd_gb_month=0 the leg
    is $0, matching Table 2's bundled i4i NVMe).

    `tier_stats` is core.external_sort.ExternalSortReport.tier_stats:
    a {"durable": StoreStats, "ssd": StoreStats} delta mapping from
    io.tiered.TieredStore.per_tier_stats().
    """
    durable = tier_stats["durable"]
    ssd = tier_stats.get("ssd")
    base = measured_cloudsort_tco(
        durable, job_hours=job_hours, reduce_hours=reduce_hours,
        data_bytes=data_bytes, params=params)
    spill = 0.0
    if ssd is not None and params.ssd_gb_month:
        spill_gb = ssd.bytes_written / 1e9
        spill = (params.ssd_gb_month / params.hours_per_month
                 * spill_gb * job_hours)
    return dataclasses.replace(base, storage_spill=spill)


# ---------------------------------------------------------------------------
# TPU-pod re-parameterization (the adapted system of DESIGN.md §2)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TpuPodCostParams:
    """TPU v5e public on-demand pricing (us-west), per chip-hour."""

    chip_hourly: float = 1.20
    num_chips: int = 256
    ici_link_gbps: float = 50.0  # GB/s per link
    hbm_gbps: float = 819.0  # GB/s per chip
    # Object-store legs unchanged from the paper's S3 model.
    s3: Ec2CostParams = Ec2CostParams()


def tpu_sort_time_model(
    data_bytes: float,
    p: TpuPodCostParams = TpuPodCostParams(),
    *,
    payload_mode: str = "through",
    num_rounds: int = 8,
) -> dict:
    """Roofline-style job-time estimate for the TPU exoshuffle.

    Per-chip data share D = data_bytes / chips. Terms:
      network: the shuffle all_to_all moves ~D (1 - 1/W) ≈ D bytes per chip
               over ICI (bisection-limited at 1 link share per chip);
               "late" mode adds a second header+payload exchange but removes
               payload from merge traffic.
      memory : sort + merge tournament passes over the data in HBM —
               log2(W) merge rounds x 2 (read+write) x bytes in flight.
    The max of the two (they overlap via round pipelining) is the stage-1
    time; stage-2 reduce adds one more log2(rounds) merge sweep.
    """
    import math

    d = data_bytes / p.num_chips
    hdr_frac = 8.0 / 100.0  # header bytes per 100-byte record
    if payload_mode == "through":
        wire = d
        merge_bytes = d
    else:
        wire = d * hdr_frac + d  # header shuffle + late payload fetch
        merge_bytes = d * hdr_frac
    merge_rounds = math.log2(p.num_chips) + math.log2(max(num_rounds, 2))
    t_net = wire / (p.ici_link_gbps * 1e9)
    t_mem = merge_bytes * 2 * merge_rounds / (p.hbm_gbps * 1e9)
    t_stage1 = max(t_net, t_mem)
    io_time = data_bytes / p.num_chips / (p.ici_link_gbps * 1e9)  # S3 in+out legs
    total = t_stage1 + io_time
    return {
        "t_network_s": t_net,
        "t_memory_s": t_mem,
        "t_total_s": total,
        "job_hours": total / 3600,
    }


def tpu_cloudsort_tco(
    data_bytes: float = 100e12,
    p: TpuPodCostParams = TpuPodCostParams(),
    *,
    payload_mode: str = "through",
) -> CostBreakdown:
    t = tpu_sort_time_model(data_bytes, p, payload_mode=payload_mode)
    job_hours = t["job_hours"]
    s3_hr = p.s3.s3_hourly_per_100tb() * (data_bytes / 100e12)
    profile = JobProfile()
    return CostBreakdown(
        compute=p.chip_hourly * p.num_chips * job_hours,
        storage_input=s3_hr * job_hours,
        storage_output=s3_hr * job_hours * 0.35,  # reduce-phase fraction
        access_get=p.s3.get_per_1000 * profile.get_requests / 1000,
        access_put=p.s3.put_per_1000 * profile.put_requests / 1000,
    )
