"""Single-device sort/merge primitives used by the distributed shuffle.

This is the analogue of the paper's ~300-line C++ component (§2.6): "sorting
and partitioning records, and merging sorted record arrays". Here each
primitive is backed by a Pallas TPU kernel (kernels/) with a pure-jnp
reference (kernels/ref.py); `impl` selects between them.

Records are (key: uint32, val: uint32) pairs; `val` is a rank into an
external payload table (the 90-byte gensort payload lives in data/gensort.py
and is gathered by rank after the keys settle).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops

PAD_KEY = ops.PAD_KEY
PAD_VAL = ops.PAD_VAL


def sort_records(keys, vals, *, impl: str = "pallas"):
    """Paper map-task step 1: sort a partition in memory."""
    return ops.sort_kv(keys, vals, impl=impl)


def merge_runs(keys, vals, *, impl: str = "pallas"):
    """Paper merge/reduce task: merge K sorted runs. keys/vals: (..., K, L)."""
    return ops.kway_merge(keys, vals, impl=impl)


def partition_sorted(sorted_keys, boundaries, *, impl: str = "pallas"):
    """Paper map-task step 2: slice a sorted partition at range boundaries.

    Returns (offsets, counts): offsets (..., P) int32 start of each of the
    P = len(boundaries)+1 ranges, counts (..., P) int32 sizes.
    """
    n = sorted_keys.shape[-1]
    off_internal = ops.partition_offsets(sorted_keys, boundaries, impl=impl)
    lead = off_internal.shape[:-1]
    zeros = jnp.zeros(lead + (1,), off_internal.dtype)
    ns = jnp.full(lead + (1,), n, off_internal.dtype)
    starts = jnp.concatenate([zeros, off_internal], axis=-1)
    ends = jnp.concatenate([off_internal, ns], axis=-1)
    return starts, ends - starts


def gather_range_blocks(sorted_keys, sorted_vals, starts, counts, capacity: int):
    """Pack each range slice into a fixed-capacity padded block.

    sorted_keys/vals: (n,). starts/counts: (P,). Returns
    (blocks_k, blocks_v): (P, capacity) with lex-max padding, and
    overflow: scalar bool, True if any count exceeded capacity.

    This is the paper's fixed-size block protocol: map output slices become
    equal-sized network blocks (required for a static all_to_all on TPU; the
    paper gets raggedness for free from Ray, we trade it for padding — see
    DESIGN.md §2).
    """
    n = sorted_keys.shape[-1]
    c = jnp.arange(capacity, dtype=jnp.int32)[None, :]  # (1, C)
    src = starts[:, None] + c  # (P, C)
    valid = c < counts[:, None]
    src = jnp.clip(src, 0, n - 1)
    bk = jnp.where(valid, sorted_keys[src], PAD_KEY)
    bv = jnp.where(valid, sorted_vals[src], PAD_VAL)
    overflow = jnp.any(counts > capacity)
    return bk, bv, overflow
