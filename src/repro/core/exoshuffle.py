"""Distributed two-stage external sort — the paper's algorithm on a TPU mesh.

Paper (§2.1): map tasks sort input partitions and push range-partitioned
slices to per-worker merge controllers; merge tasks merge accumulated blocks
and spill per-reducer runs; reduce tasks merge the spilled runs into final
output partitions.

TPU mapping (DESIGN.md §2): every mesh device is simultaneously a map
worker, a merge controller, and a reducer (W = #devices). One *shuffle
round* is:

  map     : local bitonic sort of the round's records          (Pallas)
  partition: searchsorted at the W worker boundaries            (Pallas)
  shuffle : a single tiled all_to_all of fixed-capacity blocks  (ICI)
  merge   : log2(W)-round bitonic merge tournament of the W
            received sorted blocks -> one sorted run            (Pallas)

`distributed_sort` is the one-round version (whole local shard in one
round). `core.streaming.streaming_sort` is the multi-round pipelined version
that reproduces the paper's bounded merge-controller buffer and two-stage
(map+shuffle+merge, then reduce) structure.

Raggedness: Ray gives the paper variable-sized blocks for free; a static
SPMD all_to_all needs fixed shapes, so blocks are padded to
capacity = next_pow2(n/W * capacity_factor) with lex-max records (the Indy
category's uniform keys keep the imbalance, and hence the padding waste,
small). `overflow` reports if any block exceeded capacity — the checksum
validation in data/valsort.py would also catch any dropped record, exactly
like the paper's valsort gate (§3.2).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import compat, sortlib
from repro.core.keyspace import KeySpace


@dataclasses.dataclass(frozen=True)
class ShuffleConfig:
    """Tunables of the distributed sort (paper §2.1 parameter list)."""

    num_workers: int  # W — product of the mesh axes the sort runs over
    reducers_per_worker: int = 1  # R1; R = W * R1
    capacity_factor: float = 1.5  # block slack over the uniform-key mean
    num_rounds: int = 1  # merge-controller rounds (streaming)
    impl: str = "pallas"  # "pallas" | "ref"
    # R-1 explicit reducer boundaries (sampled quantiles); None = equal
    # split. A tuple, not an array, so the frozen config stays hashable
    # for jit closure; KeySpace converts back to uint32.
    boundaries: tuple[int, ...] | None = None

    @property
    def keyspace(self) -> KeySpace:
        return KeySpace(
            num_reducers=self.num_workers * self.reducers_per_worker,
            num_workers=self.num_workers,
            boundaries=self.boundaries,
        )

    def block_capacity(self, records_per_round: int) -> int:
        """Fixed all_to_all block size for a round of n records/worker."""
        mean = records_per_round / self.num_workers
        cap = int(math.ceil(mean * self.capacity_factor))
        # Power of two so merge-network run lengths stay aligned.
        p = 1
        while p < cap:
            p *= 2
        return p


def _shuffle_round(keys, vals, *, cfg: ShuffleConfig, axis, capacity: int):
    """One map->partition->all_to_all->merge round. Per-device code.

    keys/vals: (n,) local records. Returns (run_k, run_v, counts, overflow):
    run_* (W*capacity,) lex-sorted with pads at the tail; counts (W,) int32
    records received from each source worker.
    """
    ks = cfg.keyspace
    # --- map: sort the local partition (paper §2.3 step 1) ---
    sk, sv = sortlib.sort_records(keys, vals, impl=cfg.impl)
    # --- partition at worker boundaries (paper §2.2) ---
    wb = ks.worker_boundaries()  # (W-1,)
    starts, counts = sortlib.partition_sorted(sk, wb, impl=cfg.impl)
    bk, bv, overflow = sortlib.gather_range_blocks(sk, sv, starts, counts, capacity)
    # --- shuffle: one tiled all_to_all replaces Ray's eager block push ---
    rk = jax.lax.all_to_all(bk, axis, split_axis=0, concat_axis=0, tiled=True)
    rv = jax.lax.all_to_all(bv, axis, split_axis=0, concat_axis=0, tiled=True)
    rcounts = jax.lax.all_to_all(counts, axis, split_axis=0, concat_axis=0, tiled=True)
    # --- merge: the merge task (paper §2.3), a bitonic tournament ---
    mk, mv = sortlib.merge_runs(rk, rv, impl=cfg.impl)
    return mk, mv, rcounts, overflow


def _sort_shard(keys, vals, *, cfg: ShuffleConfig, axis):
    """Whole-shard (single-round) sort. Per-device code under shard_map."""
    n = keys.shape[-1]
    capacity = cfg.block_capacity(n)
    mk, mv, rcounts, overflow = _shuffle_round(keys, vals, cfg=cfg, axis=axis, capacity=capacity)
    valid = jnp.sum(rcounts).astype(jnp.int32)
    any_overflow = jax.lax.pmax(overflow.astype(jnp.int32), axis) > 0
    return mk, mv, valid[None], any_overflow


def distributed_sort(
    keys: jax.Array,
    vals: jax.Array,
    *,
    mesh: jax.sharding.Mesh,
    axis_names: Sequence[str] | str,
    cfg: ShuffleConfig | None = None,
    impl: str = "pallas",
    capacity_factor: float = 1.5,
):
    """Globally sort (key, val) records sharded over `axis_names`.

    keys/vals: global (N,) uint32, N divisible by W = prod(mesh[a]).
    Returns (sorted_keys, sorted_vals, valid_counts, overflow):
      sorted_keys/vals: (W * W * capacity,) — device d's segment is its
        worker range, lex-sorted, valid prefix of length valid_counts[d];
      valid_counts: (W,) int32; overflow: bool.
    """
    axis = tuple([axis_names] if isinstance(axis_names, str) else axis_names)
    w = int(math.prod(mesh.shape[a] for a in axis))
    if cfg is None:
        cfg = ShuffleConfig(num_workers=w, impl=impl, capacity_factor=capacity_factor)
    assert cfg.num_workers == w, (cfg.num_workers, w)
    assert w & (w - 1) == 0, "worker count must be a power of two (merge tournament)"

    spec = P(axis)
    fn = compat.shard_map(
        lambda k, v: _sort_shard(k, v, cfg=cfg, axis=axis),
        mesh=mesh,
        in_specs=(spec, spec),
        out_specs=(spec, spec, spec, P()),
        check_vma=False,  # pallas_call out_shapes carry no vma info
    )
    return fn(keys, vals)


def _sort_shard_payload(keys, ids, payload, *, cfg: ShuffleConfig, axis, mode: str):
    """Per-device whole-record sort: headers through the merge network,
    payload via `mode` ("through" = paper-faithful, "late" = deferred fetch).
    """
    from repro.core import payload as pay

    n = keys.shape[-1]
    w = cfg.num_workers
    capacity = cfg.block_capacity(n)
    ks = cfg.keyspace

    # map + partition (as in _shuffle_round, but we keep the blocks around).
    sk, sv = sortlib.sort_records(keys, ids, impl=cfg.impl)
    wb = ks.worker_boundaries()
    starts, counts = sortlib.partition_sorted(sk, wb, impl=cfg.impl)
    bk, bv, overflow = sortlib.gather_range_blocks(sk, sv, starts, counts, capacity)

    rk = jax.lax.all_to_all(bk, axis, 0, 0, tiled=True)
    rv = jax.lax.all_to_all(bv, axis, 0, 0, tiled=True)
    rcounts = jax.lax.all_to_all(counts, axis, 0, 0, tiled=True)

    if mode == "through":
        # Payload rows ride the same wire hop, block-aligned with headers.
        my = jax.lax.axis_index(axis).astype(jnp.uint32)
        local_row = jnp.minimum(
            bv - my * jnp.uint32(n), jnp.uint32(n - 1)
        ).astype(jnp.int32)
        bp = payload[local_row]  # (W, C, pw)
        rp = pay.exchange_payload_blocks(bp, axis)

    mk, mv = sortlib.merge_runs(rk, rv, impl=cfg.impl)

    if mode == "through":
        pout = pay.align_payload_to_merge(
            rv.reshape(-1), rp.reshape(-1, rp.shape[-1]), mv
        )
        fetch_overflow = jnp.bool_(False)
    elif mode == "late":
        fetch_cap = cfg.block_capacity(mv.shape[0])
        pout, fetch_overflow = pay.late_fetch_payload(
            mv,
            payload,
            axis=axis,
            num_workers=w,
            records_per_worker=n,
            capacity=fetch_cap,
        )
    else:
        raise ValueError(f"unknown payload mode {mode!r}")

    valid = jnp.sum(rcounts).astype(jnp.int32)
    ovf = jax.lax.pmax(
        (overflow | fetch_overflow).astype(jnp.int32), axis
    ) > 0
    return mk, mv, pout, valid[None], ovf


def distributed_sort_payload(
    keys: jax.Array,
    ids: jax.Array,
    payload: jax.Array,
    *,
    mesh: jax.sharding.Mesh,
    axis_names: Sequence[str] | str,
    mode: str = "through",
    cfg: ShuffleConfig | None = None,
    impl: str = "pallas",
    capacity_factor: float = 1.5,
):
    """Sort whole records: (key, global id, payload row).

    keys/ids: (N,) uint32; ids must be globally unique with
    id // (N/W) == producing worker (the data/gensort.py layout).
    payload: (N, pw) uint32. Returns (sorted_keys, sorted_ids, payload_rows,
    valid_counts, overflow) — payload_rows[i] is the payload of the record
    at output position i.
    """
    axis = tuple([axis_names] if isinstance(axis_names, str) else axis_names)
    w = int(math.prod(mesh.shape[a] for a in axis))
    if cfg is None:
        cfg = ShuffleConfig(num_workers=w, impl=impl, capacity_factor=capacity_factor)
    assert cfg.num_workers == w, (cfg.num_workers, w)
    assert w & (w - 1) == 0

    spec = P(axis)
    pspec = P(axis, None)
    fn = compat.shard_map(
        lambda k, i, p: _sort_shard_payload(k, i, p, cfg=cfg, axis=axis, mode=mode),
        mesh=mesh,
        in_specs=(spec, spec, pspec),
        out_specs=(spec, spec, pspec, spec, P()),
        check_vma=False,
    )
    return fn(keys, ids, payload)


def reduce_partitions(sorted_keys: jax.Array, cfg: ShuffleConfig, worker_id: jax.Array):
    """Paper §2.4: split a worker's final sorted run into its R1 reducer ranges.

    Per-device helper: sorted_keys (m,) is this worker's lex-sorted output;
    returns (starts, counts) of shape (R1,) delimiting each output partition
    (the paper uploads each as one S3 object).
    """
    ks = cfg.keyspace
    if cfg.reducers_per_worker == 1:
        n = sorted_keys.shape[-1]
        return jnp.zeros((1,), jnp.int32), jnp.full((1,), n, jnp.int32)
    lrb = ks.local_reducer_boundaries()  # (W, R1-1) host constant
    mine = jax.lax.dynamic_index_in_dim(lrb, worker_id, axis=0, keepdims=False)
    starts, counts = sortlib.partition_sorted(sorted_keys, mine, impl=cfg.impl)
    return starts, counts
