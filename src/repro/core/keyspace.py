"""Range partitioner over the uint32 key space (paper §2.2).

The paper partitions the u64 key space [0, 2^64) into R = 25 000 equal
reducer ranges, grouped R1 = R/W = 625 per worker. We reproduce the same
construction over uint32 (see DESIGN.md §2 for the key-width adaptation):

  - R reducer ranges: range j covers [j * 2^32/R, (j+1) * 2^32/R).
  - W worker ranges: worker w owns reducer ranges [w*R1, (w+1)*R1), i.e.
    keys in [w * 2^32/W, (w+1) * 2^32/W).

Boundaries are *internal* (R-1 / W-1 values): the count of keys below the
last (2^32) boundary is always n, so it is implicit — this also avoids the
uint32-representability problem for 2^32 itself.

The Indy category assumes uniformly distributed keys, so equal key-space
ranges yield balanced partitions without sampling; `sampled_boundaries`
provides the Daytona-style fallback for skewed data.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

KEY_BITS = 32
KEY_SPACE = 1 << KEY_BITS


@dataclasses.dataclass(frozen=True)
class KeySpace:
    """The paper's (R, W) range partition of the sort key space.

    `boundaries`, when given, replaces the equal split with R-1 explicit
    ascending uint32 reducer boundaries (the Daytona-style sampled
    quantiles from `sampled_boundaries`): worker boundaries become every
    R1-th reducer boundary and key routing falls back from the
    power-of-two shift form to a searchsorted over the same values, so
    the device shuffle routes by the measured key distribution while
    staying bit-consistent with the host-side RangePartitioner.
    """

    num_reducers: int  # R
    num_workers: int  # W
    boundaries: tuple[int, ...] | None = None  # R-1 explicit reducer bounds

    def __post_init__(self):
        assert self.num_reducers % self.num_workers == 0, (
            "R must be a multiple of W (paper: R1 = R/W reducer ranges per worker)"
        )
        if self.boundaries is not None:
            # ValueError, not assert: sampled boundaries are data-derived
            # and must be rejected under python -O too.
            b = self.boundaries
            if len(b) != self.num_reducers - 1:
                raise ValueError(
                    f"boundaries={len(b)} values: must supply "
                    f"num_reducers-1 = {self.num_reducers - 1} internal "
                    "boundaries")
            if any(b[i + 1] < b[i] for i in range(len(b) - 1)):
                raise ValueError(
                    f"boundaries={b!r}: must be ascending "
                    "(non-overlapping ranges)")

    @property
    def reducers_per_worker(self) -> int:  # R1
        return self.num_reducers // self.num_workers

    def reducer_boundaries(self) -> jax.Array:
        """(R-1,) uint32 internal boundaries of the reducer ranges."""
        if self.boundaries is not None:
            return jnp.asarray(np.asarray(self.boundaries, np.uint32))
        return _equal_boundaries(self.num_reducers)

    def worker_boundaries(self) -> jax.Array:
        """(W-1,) uint32 internal boundaries of the worker ranges."""
        if self.boundaries is not None:
            # Worker w owns reducer ranges [w*R1, (w+1)*R1): its upper
            # boundary is reducer boundary (w+1)*R1 - 1, i.e. every
            # R1-th entry of the full reducer boundary vector.
            full = np.asarray(self.boundaries, np.uint32)
            return jnp.asarray(full[self.reducers_per_worker - 1
                                    ::self.reducers_per_worker])
        return _equal_boundaries(self.num_workers)

    def local_reducer_boundaries(self) -> jax.Array:
        """(W, R1-1) uint32: per-worker internal boundaries of its R1 ranges."""
        r = self.reducer_boundaries()  # (R-1,)
        # Worker w's internal boundaries are reducer boundaries w*R1 .. w*R1+R1-2.
        full = np.asarray(r).reshape(-1)
        out = np.stack(
            [
                full[w * self.reducers_per_worker : (w + 1) * self.reducers_per_worker - 1]
                for w in range(self.num_workers)
            ]
        )
        return jnp.asarray(out, jnp.uint32)

    def worker_of_key(self, keys: jax.Array) -> jax.Array:
        """Destination worker id for each key — the paper's routing function.

        Power-of-two W uses the exact shift form ((key * W) >> 32); other W
        fall back to a searchsorted over the floor boundaries so routing is
        always consistent with `partition_sorted` slicing.
        """
        w = self.num_workers
        if w == 1:
            return jnp.zeros(keys.shape, jnp.int32)
        if self.boundaries is None and w & (w - 1) == 0:
            # key >> (32 - log2(W)): pure-uint32 form of (key*W) >> 32.
            # (The multiply form needs uint64, which silently truncates
            # to uint32 under JAX's default x64-disabled mode.)
            shift = KEY_BITS - (w.bit_length() - 1)
            return (keys >> jnp.uint32(shift)).astype(jnp.int32)
        return jnp.searchsorted(
            self.worker_boundaries(), keys, side="right"
        ).astype(jnp.int32)

    def reducer_of_key(self, keys: jax.Array) -> jax.Array:
        r = self.num_reducers
        if r == 1:
            return jnp.zeros(keys.shape, jnp.int32)
        if self.boundaries is None and r & (r - 1) == 0:
            shift = KEY_BITS - (r.bit_length() - 1)
            return (keys >> jnp.uint32(shift)).astype(jnp.int32)
        return jnp.searchsorted(
            self.reducer_boundaries(), keys, side="right"
        ).astype(jnp.int32)


def _equal_boundaries(parts: int) -> jax.Array:
    """(parts-1,) uint32 internal boundaries of an equal split of [0, 2^32)."""
    js = np.arange(1, parts, dtype=np.uint64)
    bounds = (js * np.uint64(KEY_SPACE)) // np.uint64(parts)
    return jnp.asarray(bounds.astype(np.uint32))


def sampled_boundaries(sample_keys: jax.Array, parts: int) -> jax.Array:
    """Daytona-style splitter estimation: quantiles of a key sample.

    Returns (parts-1,) uint32 internal boundaries that approximately balance
    `parts` ranges for the sampled distribution. A one-key sample is legal
    (all boundaries collapse to that key); an empty sample is not.
    """
    srt = jnp.sort(sample_keys.reshape(-1))
    n = srt.shape[0]
    if n == 0:
        raise ValueError(
            f"sample_keys={n} keys: need at least one sampled key to "
            "estimate splitters")
    if parts < 1:
        raise ValueError(f"parts={parts}: must be >= 1")
    idx = (jnp.arange(1, parts) * n) // parts
    return srt[idx]
