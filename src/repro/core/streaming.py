"""Round-pipelined two-stage external sort — the paper's full structure.

Paper §2.3: the merge controller accumulates W map blocks (~2 GB), then
launches a merge task; when the in-flight merge count hits the parallelism
cap it withholds acks, back-pressuring the map scheduler so map, shuffle and
merge proceed in lockstep, and merged runs are spilled to SSD. §2.4: after
the map stage, reduce tasks k-way merge the spilled runs.

SPMD translation (DESIGN.md §2): backpressure is a *dynamic* mechanism for
bounding the in-memory working set; in a static SPMD program we get the same
bound by construction with fixed-size rounds:

  Stage 1 (map+shuffle+merge), `lax.scan` over `num_rounds` rounds:
      each round sorts 1/num_rounds of the local shard, all_to_alls the
      partitioned blocks, and merges the W received blocks into ONE sorted
      run, appended to a run buffer (the "spill": rounds live in HBM, the
      round working set is the merge-controller's 2 GB buffer analogue).
      XLA's async collectives overlap round r's all_to_all with round
      r±1's sort/merge compute — the paper's "pipelining for free" (§2.5),
      supplied here by the XLA latency-hiding scheduler instead of Ray.

  Stage 2 (reduce): a bitonic merge tournament over the num_rounds spilled
      runs yields the worker's final sorted output, sliceable into R1
      reducer partitions (core.exoshuffle.reduce_partitions).

The round count trades working-set size against collective efficiency
(fewer, larger all_to_alls) — exactly the paper's block-threshold knob.
"""
from __future__ import annotations

import math
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import compat, sortlib
from repro.core.exoshuffle import ShuffleConfig, _shuffle_round


def _streaming_sort_shard(keys, vals, *, cfg: ShuffleConfig, axis):
    """Per-device two-stage sort. keys/vals: (n,), n % num_rounds == 0."""
    n = keys.shape[-1]
    rounds = cfg.num_rounds
    assert n % rounds == 0
    per_round = n // rounds
    capacity = cfg.block_capacity(per_round)

    k_rounds = keys.reshape(rounds, per_round)
    v_rounds = vals.reshape(rounds, per_round)

    # ---- Stage 1: map + shuffle + merge, one round per scan step ----
    def round_body(carry_overflow, kv):
        rk, rv = kv
        mk, mv, rcounts, ovf = _shuffle_round(rk, rv, cfg=cfg, axis=axis, capacity=capacity)
        return carry_overflow | jnp.any(ovf), (mk, mv, jnp.sum(rcounts).astype(jnp.int32))

    overflow, (run_k, run_v, counts) = jax.lax.scan(
        round_body, jnp.bool_(False), (k_rounds, v_rounds)
    )
    # run_k/run_v: (rounds, W*capacity) — the spilled sorted runs.

    # ---- Stage 2: reduce — merge the spilled runs ----
    if rounds == 1:
        fk, fv = run_k[0], run_v[0]
    else:
        fk, fv = sortlib.merge_runs(run_k, run_v, impl=cfg.impl)

    valid = jnp.sum(counts).astype(jnp.int32)
    any_overflow = jax.lax.pmax(overflow.astype(jnp.int32), axis) > 0
    return fk, fv, valid[None], any_overflow


def streaming_sort(
    keys: jax.Array,
    vals: jax.Array,
    *,
    mesh: jax.sharding.Mesh,
    axis_names: Sequence[str] | str,
    num_rounds: int,
    cfg: ShuffleConfig | None = None,
    impl: str = "pallas",
    capacity_factor: float = 1.5,
):
    """Two-stage streaming distributed sort (see module docstring).

    Same contract as core.exoshuffle.distributed_sort, plus `num_rounds`.
    num_rounds must be a power of two (stage-2 merge tournament).
    """
    axis = tuple([axis_names] if isinstance(axis_names, str) else axis_names)
    w = int(math.prod(mesh.shape[a] for a in axis))
    if cfg is None:
        cfg = ShuffleConfig(
            num_workers=w,
            impl=impl,
            capacity_factor=capacity_factor,
            num_rounds=num_rounds,
        )
    assert w & (w - 1) == 0
    assert num_rounds & (num_rounds - 1) == 0, "rounds must be a power of two"

    spec = P(axis)
    fn = compat.shard_map(
        lambda k, v: _streaming_sort_shard(k, v, cfg=cfg, axis=axis),
        mesh=mesh,
        in_specs=(spec, spec),
        out_specs=(spec, spec, spec, P()),
        check_vma=False,  # pallas_call out_shapes carry no vma info
    )
    return fn(keys, vals)
