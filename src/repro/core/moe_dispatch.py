"""MoE token->expert dispatch as an exoshuffle (DESIGN.md §4.2).

Token routing in expert-parallel MoE *is* the paper's shuffle with
expert-id as the sort key: map (sort tokens by expert), partition (experts
are range-owned by EP shards), shuffle (all_to_all), merge (group per local
expert), compute, and an inverse shuffle home. Two implementations:

  - "sort"   : the exoshuffle pipeline above under shard_map. Dispatch cost
               is O(T log T) sort + O(T·d) gathers + one all_to_all of the
               selected activations. This is the paper's technique as a
               first-class framework feature.
  - "onehot" : GShard/Switch-style dense dispatch einsums with a (T, E, C)
               one-hot tensor; pure pjit/GSPMD (no shard_map). Cost is
               O(T·E·C) for mask construction plus O(T·E·C·d) for the
               dispatch/combine einsums — the classical baseline we compare
               against in EXPERIMENTS.md §Perf.

Both drop tokens over expert capacity (standard capacity-factor semantics).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import compat


@dataclasses.dataclass(frozen=True)
class MoeDispatchConfig:
    num_experts: int  # routed experts E (global)
    top_k: int
    capacity_factor: float = 1.25
    impl: str = "sort"  # "sort" | "onehot"
    ep_axis: str = "model"  # mesh axis experts are sharded over


def route_topk(gate_logits: jax.Array, top_k: int):
    """Softmax-then-topk router. gate_logits (..., T, E).

    Returns (weights (..., T, K) f32 normalized over K, ids (..., T, K) i32).
    """
    probs = jax.nn.softmax(gate_logits.astype(jnp.float32), axis=-1)
    weights, ids = jax.lax.top_k(probs, top_k)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    return weights, ids.astype(jnp.int32)


# ---------------------------------------------------------------------------
# "onehot" baseline (GShard-style, pure GSPMD)
# ---------------------------------------------------------------------------


def onehot_dispatch_combine(x, weights, ids, *, num_experts: int, capacity: int,
                            expert_fn):
    """x (T, d); weights/ids (T, K). Returns (T, d_out).

    expert_fn: (E, C, d) -> (E, C, d_out), batched over experts.
    """
    t, _ = x.shape
    k = ids.shape[-1]
    # Position of each (token, k) inside its expert queue, k-major priority.
    onehot = jax.nn.one_hot(ids, num_experts, dtype=jnp.int32)  # (T, K, E)
    flat = onehot.reshape(t * k, num_experts)
    pos = jnp.cumsum(flat, axis=0) - flat  # (T*K, E) position if routed
    pos = jnp.sum(pos * flat, axis=-1).reshape(t, k)  # (T, K)
    keep = pos < capacity
    w = weights * keep.astype(weights.dtype)
    # dispatch (T, E, C) one-hot — the classical dense formulation.
    pos_oh = jax.nn.one_hot(pos, capacity, dtype=x.dtype) * keep[..., None].astype(x.dtype)
    disp = jnp.einsum("tke,tkc->tec", onehot.astype(x.dtype), pos_oh)
    expert_in = jnp.einsum("tec,td->ecd", disp, x)  # (E, C, d)
    expert_out = expert_fn(expert_in)
    # combine: each (t,k) takes expert_out[id_k, pos_k] weighted by w[t,k].
    gathered = jnp.einsum("tkc,ecd,tke->tkd", pos_oh, expert_out,
                          onehot.astype(x.dtype))
    return jnp.sum(gathered * w[..., None].astype(gathered.dtype), axis=1)


# ---------------------------------------------------------------------------
# "sort" implementation (the exoshuffle pipeline)
# ---------------------------------------------------------------------------


def sort_dispatch_shard(
    x,
    weights,
    ids,
    expert_params,
    *,
    cfg: MoeDispatchConfig,
    ep_size: int,
    expert_fn,
):
    """Per-device dispatch under shard_map. The exoshuffle pipeline:

    map:      sort local (expert_id, slot) pairs by expert id
    partition: count pairs per owner shard (searchsorted at shard bounds)
    shuffle:  all_to_all fixed-capacity activation blocks over the EP axis
    merge:    regroup arrivals per local expert (second small sort)
    reduce:   batched expert FFN; then the whole pipeline reverses.

    x: (T, d) local tokens; weights/ids: (T, K); expert_params: pytree with
    leading axis E_local. Returns (T, d_out).
    """
    t, d = x.shape
    k = ids.shape[-1]
    e = cfg.num_experts
    e_local = e // ep_size
    tk = t * k
    axis = cfg.ep_axis

    # --- map: sort (expert, slot) pairs by expert id ------------------------
    flat_e = ids.reshape(tk).astype(jnp.uint32)
    slots = jnp.arange(tk, dtype=jnp.uint32)
    se, sslot = jax.lax.sort((flat_e, slots), num_keys=1)

    # --- partition at EP shard boundaries -----------------------------------
    shard_bounds = (jnp.arange(1, ep_size, dtype=jnp.uint32)) * jnp.uint32(e_local)
    starts = jnp.searchsorted(se, shard_bounds, side="left").astype(jnp.int32)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32), starts])
    ends = jnp.concatenate([starts[1:], jnp.full((1,), tk, jnp.int32)])
    counts = ends - starts  # (ep,)

    cap = int(_round_up(tk / ep_size * cfg.capacity_factor, 8))
    c = jnp.arange(cap, dtype=jnp.int32)[None, :]
    src = jnp.clip(starts[:, None] + c, 0, tk - 1)  # (ep, C)
    valid = c < counts[:, None]

    send_e = jnp.where(valid, se[src], jnp.uint32(e))  # sentinel expert = E
    send_slot = jnp.where(valid, sslot[src], jnp.uint32(0xFFFFFFFF))
    slot_clip = jnp.minimum(send_slot, jnp.uint32(tk - 1)).astype(jnp.int32)
    send_tok = slot_clip // k  # (ep, C) source token of each routed pair
    send_x = jnp.where(valid[..., None], x[send_tok], 0)  # (ep, C, d)
    send_w = jnp.where(valid, weights.reshape(tk)[slot_clip], 0.0)  # (ep, C)

    # --- shuffle -------------------------------------------------------------
    if ep_size > 1:
        a2a = functools.partial(
            jax.lax.all_to_all, axis_name=axis, split_axis=0, concat_axis=0,
            tiled=True,
        )
    else:  # single-shard ("dense") fallback: the exchange is the identity
        a2a = lambda t: t
    recv_e, recv_x = a2a(send_e), a2a(send_x)

    # --- merge: regroup arrivals per local expert ----------------------------
    m = ep_size * cap
    re = recv_e.reshape(m)
    rx = recv_x.reshape(m, d)
    arrival = jnp.arange(m, dtype=jnp.uint32)
    ge, gperm = jax.lax.sort((re, arrival), num_keys=1)

    my = (
        jax.lax.axis_index(axis).astype(jnp.uint32)
        if ep_size > 1
        else jnp.uint32(0)
    )
    first = my * jnp.uint32(e_local)
    local_bounds = first + jnp.arange(e_local, dtype=jnp.uint32)
    estarts = jnp.searchsorted(ge, local_bounds, side="left").astype(jnp.int32)
    # rank of each sorted arrival within its expert queue
    local_eid = jnp.clip(ge - first, 0, e_local - 1).astype(jnp.int32)
    rank = jnp.arange(m, dtype=jnp.int32) - estarts[local_eid]

    # Mean pairs per local expert is tk/e_local (every device receives ~tk
    # pairs back); the capacity factor absorbs routing imbalance.
    ecap = max(int(_round_up(tk / e_local * cfg.capacity_factor, 8)), 8)
    keep = (rank < ecap) & (ge < jnp.uint32(e))  # drop overflow + sentinels

    # scatter into (E_local, ecap, d); dropped entries get an out-of-bounds
    # rank and are discarded by mode="drop" (no collision with real slots).
    exp_in = jnp.zeros((e_local, ecap, d), x.dtype)
    sel_rank = jnp.where(keep, rank, ecap)
    exp_in = exp_in.at[local_eid, sel_rank].set(rx[gperm], mode="drop")

    # --- reduce: batched expert computation ----------------------------------
    exp_out = expert_fn(expert_params, exp_in)  # (E_local, ecap, d_out)
    d_out = exp_out.shape[-1]

    # --- inverse pipeline -----------------------------------------------------
    y_sorted = jnp.where(
        keep[:, None], exp_out[local_eid, jnp.minimum(sel_rank, ecap - 1)], 0
    )  # (m, d_out) in sorted-arrival order
    y_arrival = jnp.zeros((m, d_out), y_sorted.dtype).at[gperm].set(y_sorted)
    y_back = a2a(y_arrival.reshape(ep_size, cap, d_out))  # home shuffle

    # combine at source: out[tok] += w * y  for each of this device's sent pairs
    y_flat = y_back.reshape(ep_size * cap, d_out)
    w_flat = send_w.reshape(-1)[:, None].astype(y_flat.dtype)
    tok_flat = send_tok.reshape(-1)
    out = jnp.zeros((t, d_out), y_flat.dtype)
    out = out.at[tok_flat].add(y_flat * w_flat, mode="drop")
    return out


def _round_up(x: float, m: int) -> int:
    import math

    return int(math.ceil(x / m) * m)


def make_sort_dispatch(mesh, cfg: MoeDispatchConfig, expert_fn, *, token_spec,
                       param_spec):
    """Wrap sort_dispatch_shard in shard_map over the full mesh.

    token_spec: PartitionSpec of (T_global, d) token arrays (usually
    P(("data",), None) with the EP all_to_all over cfg.ep_axis).
    """
    from jax.sharding import PartitionSpec as P

    ep_size = mesh.shape[cfg.ep_axis]
    w_spec = P(token_spec[0], None)

    def fn(x, weights, ids, expert_params):
        return sort_dispatch_shard(
            x, weights, ids, expert_params, cfg=cfg, ep_size=ep_size,
            expert_fn=expert_fn,
        )

    return compat.shard_map(
        fn,
        mesh=mesh,
        in_specs=(token_spec, w_spec, w_spec, param_spec),
        out_specs=token_spec,
        check_vma=False,
    )


# ---------------------------------------------------------------------------
# decode-time EP dispatch: tokens replicated over the EP axis
# ---------------------------------------------------------------------------


def ep_replicated_shard(x, weights, ids, expert_params, *, cfg, ep_size,
                        expert_fn):
    """Per-device decode dispatch under shard_map.

    At decode the token count (B) is far below the mesh size, so the
    all_to_all pipeline has nothing to shard. Instead every EP shard sees
    ALL tokens (replicated over the EP axis), masks the routing weights to
    the experts it owns, runs its local expert bank, and the partial
    outputs are psum'd over the EP axis — the standard small-batch EP
    pattern (an all_to_all degenerates to broadcast + reduce at T << ep).

    x (T, d) — identical on every shard of cfg.ep_axis; weights/ids (T, K);
    expert_params: pytree with leading axis E_local. Returns (T, d_out),
    summed over shards by the caller-visible psum.
    """
    e = cfg.num_experts
    e_local = e // ep_size
    my = jax.lax.axis_index(cfg.ep_axis).astype(jnp.int32)
    lo = my * e_local
    local = (ids >= lo) & (ids < lo + e_local)
    w_local = jnp.where(local, weights, 0.0)
    # Non-local routes are clipped into the local id range as weight-0
    # "ghosts"; capacity = T*K makes every queue large enough that ghosts
    # can never displace a real token (exact, and trivially cheap at
    # decode's tiny T).
    ids_local = jnp.clip(ids - lo, 0, e_local - 1)
    t = x.shape[0]
    cap = t * ids.shape[-1]
    out = onehot_dispatch_combine(
        x, w_local, ids_local, num_experts=e_local, capacity=cap,
        expert_fn=lambda xin: expert_fn(expert_params, xin),
    )
    return jax.lax.psum(out, cfg.ep_axis)
