"""Out-of-core external sort: the dataset lives in the object store, not HBM.

This is the driver that lets the reproduction actually *pose* the CloudSort
problem (paper §2.3–§2.5): total dataset size is bounded by object-store
capacity, while device memory holds only one map wave's working set.

Paper mapping:

  map waves (§2.3, §2.5): input partitions stream from the store in ranged
      chunks (io/backends.get_chunks — one GET per chunk, the paper's
      "120 chunks" map download), double-buffered against device compute
      (io/staging.prefetch, retry-aware against transient store stalls).
      Each wave runs the in-memory two-stage streaming exoshuffle
      (core/streaming.py), after which every worker holds one globally
      range-partitioned sorted run.

  spill (§2.3): each worker's merged run is written back under
      plan.spill_prefix as one sorted run object. Against a TieredStore
      (io/tiered.py) that prefix routes to the local-SSD tier — the
      paper's actual spill target — while input/output keys stay on the
      durable (S3-like, throttled, billed) tier. Per-reducer offsets into
      the run are recorded in the object's manifest metadata; writes are
      write-behind via io/staging.AsyncWriter so upload overlaps the next
      wave's sort.

  reduce (§2.4): output partition r streaming-merges its slice of every
      spilled run with *bounded* memory: each run slice is fetched in
      plan.merge_chunk_bytes ranged chunks (all empty cursors refill
      concurrently, so an emit cycle pays ~one request stall, not one per
      run), buffered records are merged up to the smallest last-loaded
      key over still-active runs (so nothing can arrive later that sorts
      before what is emitted), and merged bytes stream straight into an
      incremental multipart upload (one PUT per part, the paper's "40
      chunks" reduce upload) through a per-partition ordered write-behind
      queue — up to max_inflight_writes partitions upload concurrently
      while later partitions merge. Reduce host memory is therefore
      ∝ runs × merge_chunk_bytes — NOT partition size — and the measured
      peak is reported (reduce_peak_merge_bytes).

Every store interaction is request-accounted, so the Table-2 TCO can be
computed from *measured* GET/PUT counts (core/cost_model.measured_cloudsort_tco,
or .measured_tiered_cloudsort_tco for per-tier legs) instead of the
paper's hardcoded 6M/1M constants.
"""
from __future__ import annotations

import collections
import dataclasses
import math
import sys
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.exoshuffle import ShuffleConfig
from repro.core.streaming import streaming_sort
from repro.io import records as rec
from repro.io import staging
from repro.io.backends import RetryableError, StoreBackend, StoreStats


@dataclasses.dataclass(frozen=True)
class ExternalSortPlan:
    """Out-of-core schedule: what fits in HBM and how the store is laid out.

    records_per_wave is the device-resident working set — the analogue of
    the paper's (map tasks in flight) x (2 GB block) bound.
    merge_chunk_bytes is the reduce-side counterpart: the per-run fetch
    granularity of the streaming merge, so reduce host memory is bounded
    by runs x merge_chunk_bytes instead of a whole output partition.
    """

    records_per_wave: int  # device working set (records, across the mesh)
    num_rounds: int = 2  # streaming_sort rounds within a wave
    reducers_per_worker: int = 1  # R1; R = W * R1 output partitions
    payload_words: int = 4  # u32 payload words per record
    impl: str = "ref"  # kernel implementation ("ref" | "pallas")
    capacity_factor: float = 1.5
    input_prefix: str = "input/"
    spill_prefix: str = "spill/"
    output_prefix: str = "output/"
    input_records_per_partition: int = 1 << 13  # gensort object size
    output_part_records: int = 1 << 13  # multipart-upload part size
    store_chunk_bytes: int = 256 << 10  # map download GET granularity
    merge_chunk_bytes: int = 64 << 10  # reduce per-run fetch granularity
    prefetch_depth: int = 2  # double buffering
    max_inflight_writes: int = 2  # spill/upload backpressure
    io_retries: int = 2  # staging-level re-reads of a failed wave load

    @property
    def record_bytes(self) -> int:
        return rec.record_bytes(self.payload_words)


@dataclasses.dataclass
class ExternalSortReport:
    """What happened: sizes, timings, and *measured* store traffic."""

    total_records: int
    num_waves: int
    num_workers: int
    num_reducers: int
    spill_objects: int
    output_objects: int
    map_seconds: float
    reduce_seconds: float
    working_set_records: int
    stats: StoreStats  # delta over the sort (map + reduce), all tiers
    runs_per_reducer: int = 0  # k of the streaming k-way merge
    merge_chunk_bytes: int = 0  # the plan's reduce fetch granularity
    reduce_peak_merge_bytes: int = 0  # measured max of buffered run bytes
    tier_stats: dict[str, StoreStats] | None = None  # per-tier deltas

    @property
    def oversubscription(self) -> float:
        """Dataset size / per-wave device working set (>1 = out-of-core)."""
        return self.total_records / self.working_set_records

    @property
    def reduce_memory_bound_bytes(self) -> int:
        """The streaming-merge guarantee: peak merge memory never exceeds
        runs x merge_chunk_bytes (+ one record of rounding per run)."""
        return self.runs_per_reducer * self.merge_chunk_bytes

    @property
    def job_hours(self) -> float:
        return (self.map_seconds + self.reduce_seconds) / 3600.0

    @property
    def reduce_hours(self) -> float:
        return self.reduce_seconds / 3600.0


def _spill_key(plan: ExternalSortPlan, wave: int, worker: int) -> str:
    return f"{plan.spill_prefix}wave-{wave:04d}/w-{worker:03d}"


def _output_key(plan: ExternalSortPlan, reducer: int) -> str:
    return f"{plan.output_prefix}part-{reducer:05d}"


def _group_waves(inputs, counts, records_per_wave: int):
    """Tile the key-ordered input objects into equal-record waves."""
    waves, cur, acc = [], [], 0
    for meta, c in zip(inputs, counts):
        cur.append(meta)
        acc += c
        assert acc <= records_per_wave, (
            "input partitions must tile records_per_wave exactly "
            f"(partition {meta.key} overflows the wave)"
        )
        if acc == records_per_wave:
            waves.append(cur)
            cur, acc = [], 0
    assert not cur, "total records must be a multiple of records_per_wave"
    return waves


class _RunCursor:
    """Bounded window over one spilled run's reducer slice.

    Holds at most `chunk_records` decoded records at a time; `refill`
    issues one ranged GET for the next chunk, `take_upto` consumes the
    buffered prefix that is safe to emit (every record <= bound).
    """

    __slots__ = ("_store", "_bucket", "_key", "_hi", "_next", "_chunk",
                 "_pw", "k64", "keys", "ids", "payload")

    def __init__(self, store, bucket, key, lo, hi, payload_words, chunk_records):
        self._store = store
        self._bucket = bucket
        self._key = key
        self._next = int(lo)
        self._hi = int(hi)
        self._chunk = int(chunk_records)
        self._pw = int(payload_words)
        self.keys = np.empty((0,), np.uint32)
        self.ids = np.empty((0,), np.uint32)
        self.payload = None
        self.k64 = np.empty((0,), np.uint64)

    @property
    def has_more_remote(self) -> bool:
        return self._next < self._hi

    @property
    def exhausted(self) -> bool:
        return not self.has_more_remote and self.k64.size == 0

    @property
    def buffered_bytes(self) -> int:
        return self.k64.size * rec.record_bytes(self._pw)

    def refill(self) -> None:
        n = min(self._chunk, self._hi - self._next)
        start, length = rec.body_range(self._next, n, self._pw)
        body = self._store.get_range(self._bucket, self._key, start, length)
        self._next += n
        k, i, p = rec.decode_body(body, self._pw)
        self.keys, self.ids, self.payload = k, i, p
        self.k64 = k.astype(np.uint64) << np.uint64(32) | i.astype(np.uint64)

    def take_upto(self, bound):
        """Consume and return the (keys, ids, payload, k64) prefix with
        k64 <= bound; bound=None consumes everything buffered."""
        cut = self.k64.size if bound is None else int(
            np.searchsorted(self.k64, bound, side="right"))
        out = (self.keys[:cut], self.ids[:cut],
               None if self.payload is None else self.payload[:cut],
               self.k64[:cut])
        self.keys, self.ids = self.keys[cut:], self.ids[cut:]
        self.payload = None if self.payload is None else self.payload[cut:]
        self.k64 = self.k64[cut:]
        return out


def _merge_fragments(frags, payload_words: int):
    """Merge already-sorted fragments (one per run) into one sorted batch.

    Fragment keys are globally unique (key<<32|id with unique ids), so a
    plain stable argsort over the concatenated packed keys is an exact
    k-way merge of the emit window — small (≤ runs x chunk records) by
    construction, which is the whole point of the streaming reduce.
    """
    frags = [f for f in frags if f[3].size]
    if not frags:
        empty = np.empty((0,), np.uint32)
        pw = int(payload_words)
        return empty, empty, (np.empty((0, pw), np.uint32) if pw else None)
    if len(frags) == 1:
        k, i, p, _ = frags[0]
        return k, i, p
    k64 = np.concatenate([f[3] for f in frags])
    order = np.argsort(k64, kind="stable")
    keys = np.concatenate([f[0] for f in frags])[order]
    ids = np.concatenate([f[1] for f in frags])[order]
    payload = None
    if payload_words:
        payload = np.concatenate([f[2] for f in frags])[order]
    return keys, ids, payload


def external_sort(
    store: StoreBackend,
    bucket: str,
    *,
    mesh: jax.sharding.Mesh,
    axis_names: Sequence[str] | str,
    plan: ExternalSortPlan,
) -> ExternalSortReport:
    """Sort every record under plan.input_prefix into plan.output_prefix.

    `store` is any io/backends.StoreBackend — the plain ObjectStore, a
    fault-injected middleware stack, or a TieredStore (in which case the
    report carries per-tier request deltas). Input objects must be
    io/records-encoded with plan.payload_words words of payload and
    globally unique ids (data/gensort.write_to_store's layout). Returns
    the run report; validate the output with data/valsort.validate_from_store.
    """
    axis = tuple([axis_names] if isinstance(axis_names, str) else axis_names)
    w = int(math.prod(mesh.shape[a] for a in axis))
    pw = plan.payload_words
    r1 = plan.reducers_per_worker
    cfg = ShuffleConfig(
        num_workers=w,
        reducers_per_worker=r1,
        capacity_factor=plan.capacity_factor,
        num_rounds=plan.num_rounds,
        impl=plan.impl,
    )
    assert plan.records_per_wave % (w * plan.num_rounds) == 0, (
        "records_per_wave must divide evenly into per-worker rounds"
    )

    inputs = store.list_objects(bucket, plan.input_prefix)
    assert inputs, f"no input objects under {plan.input_prefix!r}"
    counts = [(m.size - rec.HEADER_BYTES) // plan.record_bytes for m in inputs]
    total = sum(counts)
    waves = _group_waves(inputs, counts, plan.records_per_wave)
    # Overwrite semantics: clear stale spill/output objects from any prior
    # run so the reduce pass and downstream validation see only this run.
    for prefix in (plan.spill_prefix, plan.output_prefix):
        for meta in store.list_objects(bucket, prefix):
            store.delete(bucket, meta.key)
    base_stats = store.stats_snapshot()
    tier_base = (store.per_tier_stats()
                 if hasattr(store, "per_tier_stats") else None)

    sort_wave = jax.jit(
        lambda k, i: streaming_sort(
            k, i, mesh=mesh, axis_names=axis_names,
            num_rounds=plan.num_rounds, cfg=cfg,
        )
    )

    # ---- map waves: stream in -> sort -> spill runs -------------------
    def load_wave(objs):
        ks, ids, ps = [], [], []
        for m in objs:
            data = b"".join(store.get_chunks(bucket, m.key, plan.store_chunk_bytes))
            k, i, p = rec.decode_records(data)
            ks.append(k)
            ids.append(i)
            if pw:
                ps.append(p)
        return (
            np.concatenate(ks),
            np.concatenate(ids),
            np.concatenate(ps) if pw else None,
        )

    local_bounds = (
        np.asarray(cfg.keyspace.local_reducer_boundaries()) if r1 > 1 else None
    )  # (W, R1-1)
    spill_offsets: dict[tuple[int, int], np.ndarray] = {}
    t0 = time.perf_counter()
    with staging.AsyncWriter(plan.max_inflight_writes) as spiller:
        wave_loads = (lambda objs=objs: load_wave(objs) for objs in waves)
        for g, (keys, ids, payload) in enumerate(
            staging.prefetch(wave_loads, depth=plan.prefetch_depth,
                             retries=plan.io_retries,
                             retry_on=(RetryableError,))
        ):
            sk, si, vcounts, ovf = sort_wave(jnp.asarray(keys), jnp.asarray(ids))
            sk, si, vcounts = np.asarray(sk), np.asarray(si), np.asarray(vcounts)
            if bool(np.asarray(ovf)):
                raise RuntimeError(
                    "shuffle block overflow — raise capacity_factor"
                )
            # id -> wave row, for gathering payload of shuffled records.
            order = np.argsort(ids)
            sorted_ids = ids[order]
            seg = sk.shape[0] // w
            for wid in range(w):
                n = int(vcounts[wid])
                run_k = sk[wid * seg : wid * seg + n]
                run_i = si[wid * seg : wid * seg + n]
                run_p = None
                if pw:
                    rows = order[np.searchsorted(sorted_ids, run_i)]
                    run_p = payload[rows]
                if local_bounds is not None:
                    internal = np.searchsorted(run_k, local_bounds[wid], side="left")
                else:
                    internal = np.empty((0,), np.int64)
                offsets = np.concatenate(([0], internal, [n])).astype(np.int64)
                spill_offsets[(g, wid)] = offsets
                spiller.submit(
                    store.put,
                    bucket,
                    _spill_key(plan, g, wid),
                    rec.encode_records(run_k, run_i, run_p),
                    metadata={
                        "records": n,
                        "wave": g,
                        "worker": wid,
                        "reducer_offsets": [int(o) for o in offsets],
                    },
                )
    map_seconds = time.perf_counter() - t0

    # ---- reduce: streaming k-way merge, bounded chunks per run --------
    # Memory contract: each of the (≤ num_waves) run cursors buffers at
    # most merge_chunk_bytes of decoded records, the emit window is merged
    # and encoded immediately, and completed output parts stream through
    # write-behind queues. Overlap: all empty cursors of an emit cycle
    # refill CONCURRENTLY (one stall per cycle, not one per run), and each
    # reducer gets its own single-thread uploader (sequential put_part
    # calls of one multipart session stay ordered) while up to
    # max_inflight_writes reducers' uploads run concurrently — so upload
    # stalls of partition r overlap the merge of partitions r+1....
    num_waves = len(waves)
    num_reducers = w * r1
    if plan.merge_chunk_bytes < plan.record_bytes:
        raise ValueError(
            f"merge_chunk_bytes={plan.merge_chunk_bytes} must hold at least "
            f"one {plan.record_bytes}-byte record, else the runs x "
            "merge_chunk_bytes reduce-memory bound cannot be met"
        )
    chunk_records = plan.merge_chunk_bytes // plan.record_bytes
    part_bytes = plan.output_part_records * plan.record_bytes
    peak_merge_bytes = 0

    def run_cursors(r: int) -> tuple[list[_RunCursor], int]:
        wid, j = divmod(r, r1)
        cursors, n_total = [], 0
        for g in range(num_waves):
            offs = spill_offsets[(g, wid)]
            lo, hi = int(offs[j]), int(offs[j + 1])
            if hi > lo:
                cursors.append(_RunCursor(
                    store, bucket, _spill_key(plan, g, wid),
                    lo, hi, pw, chunk_records))
                n_total += hi - lo
        return cursors, n_total

    def _finish_session(uploader: staging.AsyncWriter, mp) -> None:
        """Queued after a session's part uploads on its single-thread
        writer: by the time it runs, every part either succeeded or set
        the writer's failure flag — commit only a fully-uploaded object
        (a truncated commit would carry a self-consistent CRC etag that
        IntegrityError can never catch)."""
        if uploader.failed:
            mp.abort()
        else:
            mp.complete()

    t0 = time.perf_counter()
    live_uploaders: collections.deque[staging.AsyncWriter] = collections.deque()
    refill_pool = ThreadPoolExecutor(
        max_workers=min(16, max(2, num_waves)),
        thread_name_prefix="reduce-refill")
    try:
        for r in range(num_reducers):
            cursors, n_total = run_cursors(r)
            mp = store.multipart(bucket, _output_key(plan, r),
                                 metadata={"records": n_total, "reducer": r})
            uploader = staging.AsyncWriter(plan.max_inflight_writes,
                                           max_workers=1)
            live_uploaders.append(uploader)
            try:
                # Record count is known up front (sum of run-slice
                # lengths), so the header streams first, body chunks follow.
                outbuf = bytearray(rec.encode_header(n_total, pw))
                while cursors:
                    need = [c for c in cursors
                            if c.k64.size == 0 and c.has_more_remote]
                    if len(need) == 1:
                        need[0].refill()
                    elif need:  # concurrent ranged GETs: one RTT per cycle
                        list(refill_pool.map(_RunCursor.refill, need))
                    buffered = sum(c.buffered_bytes for c in cursors)
                    peak_merge_bytes = max(peak_merge_bytes, buffered)
                    # Safe emit bound: the smallest last-buffered key among
                    # runs that still have un-fetched records — nothing
                    # later can sort below it. When no run has remote data
                    # left, everything buffered is emittable.
                    remote_tails = [c.k64[-1] for c in cursors
                                    if c.has_more_remote]
                    bound = min(remote_tails) if remote_tails else None
                    frags = [c.take_upto(bound) for c in cursors]
                    cursors = [c for c in cursors if not c.exhausted]
                    mk, mi, mpay = _merge_fragments(frags, pw)
                    if mk.size:
                        outbuf += rec.encode_body(mk, mi, mpay)
                    while len(outbuf) >= part_bytes:
                        uploader.submit(mp.put_part, bytes(outbuf[:part_bytes]))
                        del outbuf[:part_bytes]
                # >= 1 part always: an empty partition still has its header.
                if outbuf or n_total == 0:
                    uploader.submit(mp.put_part, bytes(outbuf))
            except BaseException:
                # Merge died mid-session: discard the partial upload after
                # any in-flight parts finish (never commit it).
                uploader.submit(mp.abort)
                raise
            uploader.submit(_finish_session, uploader, mp)
            # Retire the oldest uploads once enough sessions are in flight;
            # close() re-raises that session's first failure.
            while len(live_uploaders) > plan.max_inflight_writes:
                live_uploaders.popleft().close()
    finally:
        refill_pool.shutdown(wait=True)
        in_flight = sys.exc_info()[1]
        first_exc = None
        while live_uploaders:
            try:
                live_uploaders.popleft().close()
            except BaseException as e:  # close every session before raising
                if first_exc is None:
                    first_exc = e
        # Surface a background upload failure — unless the merge loop is
        # already unwinding with its own exception (don't mask it).
        if first_exc is not None and in_flight is None:
            raise first_exc
    reduce_seconds = time.perf_counter() - t0

    tier_stats = None
    if tier_base is not None:
        tier_now = store.per_tier_stats()
        tier_stats = {name: tier_now[name] - tier_base[name]
                      for name in tier_now}
    return ExternalSortReport(
        total_records=total,
        num_waves=num_waves,
        num_workers=w,
        num_reducers=num_reducers,
        spill_objects=num_waves * w,
        output_objects=num_reducers,
        map_seconds=map_seconds,
        reduce_seconds=reduce_seconds,
        working_set_records=plan.records_per_wave,
        stats=store.stats_snapshot() - base_stats,
        runs_per_reducer=num_waves,
        merge_chunk_bytes=plan.merge_chunk_bytes,
        reduce_peak_merge_bytes=peak_merge_bytes,
        tier_stats=tier_stats,
    )
