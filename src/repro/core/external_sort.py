"""Out-of-core external sort: the dataset lives in the object store, not HBM.

This is the driver that lets the reproduction actually *pose* the CloudSort
problem (paper §2.3–§2.5): total dataset size is bounded by object-store
capacity, while device memory holds only one map wave's working set. The
building blocks (WaveSorter, ReduceScheduler) are shared with the
multi-worker cluster executor (core/cluster.py), which partitions the same
schedule across N emulated workers with failure recovery (§2.6).

Paper mapping:

  map waves (§2.3, §2.5): input partitions stream from the store in ranged
      chunks (io/backends.get_chunks — one GET per chunk, the paper's
      "120 chunks" map download), double-buffered against device compute
      (io/staging.prefetch, retry-aware against transient store stalls).
      Wave assembly is zero-copy: each chunk decodes straight into one
      preallocated interleaved-row buffer (io/records.StreamDecoder), so
      a wave's bytes are copied once off the wire instead of through
      b"".join + np.concatenate staging copies. Each wave runs the
      in-memory two-stage streaming exoshuffle (core/streaming.py), after
      which every worker holds one globally range-partitioned sorted run;
      shuffled payload rows are located by O(1) id-offset arithmetic
      (gensort ids are contiguous per wave) instead of a per-wave argsort.

  spill (§2.3): each worker's merged run is written back under
      plan.spill_prefix as one sorted run object. Against a TieredStore
      (io/tiered.py) that prefix routes to the local-SSD tier — the
      paper's actual spill target — while input/output keys stay on the
      durable (S3-like, throttled, billed) tier. Per-reducer offsets into
      the run are recorded in the object's manifest metadata; writes are
      write-behind via io/staging.AsyncWriter so upload overlaps the next
      wave's sort.

  reduce (§2.4): a scheduler runs up to plan.parallel_reducers streaming
      k-way merges CONCURRENTLY on a worker pool — the paper's "all
      output partitions at once" reduce stage, the scheduling freedom
      shuffle-as-a-library buys (Exoshuffle §4). Each active reducer
      fetches its slice of every spilled run in bounded ranged chunks
      (all empty cursors refill concurrently, so an emit cycle pays ~one
      request stall, not one per run), merges buffered records up to the
      smallest last-loaded key over still-active runs, and streams merged
      bytes into an incremental multipart upload fanned out over
      plan.part_upload_fanout threads per partition.

Plan knobs and their invariants (the reduce-side memory/throughput
contract; see ExternalSortPlan for the map-side knobs):

  parallel_reducers — number of streaming k-way merges one scheduler runs
      concurrently. Output bytes are schedule-independent: partitions are
      independent objects and part payloads are sliced at fixed
      output_part_records boundaries, so ANY parallelism (and any cluster
      worker count) yields byte- and etag-identical partitions.

  part_upload_fanout — out-of-order part-indexed multipart uploads in
      flight per partition (S3 UploadPart semantics; assembly order is
      decided by part index at complete(), never by wire order).

  merge_chunk_bytes — hard CAP on the per-run fetch granularity of the
      streaming merge. Without a budget every cursor buffers at most this
      many decoded bytes, so per-merge peak <= runs x merge_chunk_bytes.

  reduce_memory_budget_bytes — global decoded-merge-buffer budget across
      ALL concurrently active reducers (0 = uncapped). Apportionment is
      ADAPTIVE (AdaptiveBudgetGovernor): each registering reducer starts
      from the static fair share budget/slots, and as reducers retire
      their share is re-apportioned to still-active merges — chunk sizes
      grow mid-merge (up to merge_chunk_bytes), so tail stragglers fetch
      bigger chunks instead of leaving freed budget idle. The invariant
      is provable, not just measured: grants only move between a free
      pool and live reducers under one lock, a live reducer's chunk never
      shrinks, and the measured all-reducer peak of decoded merge-buffer
      bytes (reduce_peak_merge_bytes) never exceeds the budget. Encoded
      output parts being sliced/uploaded sit on top, ~
      (1 + max_inflight_writes) x part bytes per active reducer.

Every phase records wall-clock spans (map wait/compute/spill, reduce
fetch/merge/upload) into the report's span timeline, so map/reduce
overlap is measured, not asserted. Every store interaction is
request-accounted, so the Table-2 TCO can be computed from *measured*
GET/PUT counts (core/cost_model.measured_cloudsort_tco, or
.measured_tiered_cloudsort_tco for per-tier legs) instead of the paper's
hardcoded 6M/1M constants.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import math
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.exoshuffle import ShuffleConfig
from repro.core.streaming import streaming_sort
from repro.io import records as rec
from repro.io import staging
from repro.io.backends import RetryableError, StoreBackend, StoreStats


@dataclasses.dataclass(frozen=True)
class ExternalSortPlan:
    """Out-of-core schedule: what fits in HBM and how the store is laid out.

    records_per_wave is the device-resident working set — the analogue of
    the paper's (map tasks in flight) x (2 GB block) bound.
    merge_chunk_bytes is the reduce-side counterpart: the per-run fetch
    granularity cap of the streaming merge. parallel_reducers streaming
    merges run concurrently; with reduce_memory_budget_bytes set, the
    global budget is apportioned across them by the adaptive governor
    (initial per-run chunk = budget / (slots x runs), capped at
    merge_chunk_bytes, growing as reducers retire), so the summed decoded
    merge-buffer bytes across all active reducers stay within the budget
    — not parallelism x partition size. (The budget governs the merge
    *buffers*; each active reducer additionally holds up to ~one encoded
    output part being sliced plus max_inflight_writes parts awaiting
    upload.)
    """

    records_per_wave: int  # device working set (records, across the mesh)
    num_rounds: int = 2  # streaming_sort rounds within a wave
    reducers_per_worker: int = 1  # R1; R = W * R1 output partitions
    payload_words: int = 4  # u32 payload words per record
    impl: str = "ref"  # kernel implementation ("ref" | "pallas")
    capacity_factor: float = 1.5
    input_prefix: str = "input/"
    spill_prefix: str = "spill/"
    output_prefix: str = "output/"
    input_records_per_partition: int = 1 << 13  # gensort object size
    output_part_records: int = 1 << 13  # multipart-upload part size
    store_chunk_bytes: int = 256 << 10  # map download GET granularity
    merge_chunk_bytes: int = 64 << 10  # reduce per-run fetch granularity (cap)
    prefetch_depth: int = 2  # double buffering
    max_inflight_writes: int = 2  # spill/per-partition part backpressure
    io_retries: int = 2  # staging-level re-reads of a failed wave load
    parallel_reducers: int = 4  # concurrent streaming merges (reduce pool)
    reduce_memory_budget_bytes: int = 0  # global merge budget; 0 = uncapped
    part_upload_fanout: int = 2  # out-of-order part uploads per partition

    @property
    def record_bytes(self) -> int:
        return rec.record_bytes(self.payload_words)


@dataclasses.dataclass(frozen=True)
class Span:
    """One recorded phase interval, seconds relative to the sort start."""

    phase: str  # e.g. "map.compute", "reduce.upload"
    start: float
    end: float
    worker: str = ""  # "w3" map wave / "r12" reducer tag

    @property
    def seconds(self) -> float:
        return self.end - self.start


class PhaseTimeline:
    """Thread-safe span recorder for the per-phase timeline.

    Aggregate per-phase totals are exact; the raw span list is capped at
    `max_spans` (oldest kept) so a huge run cannot hoard memory — the
    report's `spans_dropped` says how many were dropped. Because spans from overlapping
    threads both count wall time, a phase total larger than the enclosing
    stage's wall time is *measured overlap*, which is the point.
    """

    def __init__(self, origin: float, *, max_spans: int = 4096):
        self._origin = origin
        self._lock = threading.Lock()
        self._totals: dict[str, float] = {}
        self._spans: list[Span] = []
        self._max = int(max_spans)
        self.dropped = 0

    def add(self, phase: str, start: float, end: float | None = None,
            *, worker: str = "") -> None:
        end = time.perf_counter() if end is None else end
        span = Span(phase, start - self._origin, end - self._origin, worker)
        with self._lock:
            self._totals[phase] = self._totals.get(phase, 0.0) + span.seconds
            if len(self._spans) < self._max:
                self._spans.append(span)
            else:
                self.dropped += 1

    @contextlib.contextmanager
    def span(self, phase: str, worker: str = ""):
        t = time.perf_counter()
        try:
            yield
        finally:
            self.add(phase, t, worker=worker)

    def totals(self) -> dict[str, float]:
        with self._lock:
            return dict(self._totals)

    def spans(self) -> list[Span]:
        with self._lock:
            return list(self._spans)


class _PeakTracker:
    """Thread-safe global peak of summed per-reducer buffered merge bytes —
    the measurement behind the reduce_memory_budget_bytes guarantee."""

    def __init__(self):
        self._lock = threading.Lock()
        self._per: dict[int, int] = {}
        self._total = 0
        self.peak = 0

    def update(self, rid: int, nbytes: int) -> None:
        with self._lock:
            self._total += nbytes - self._per.get(rid, 0)
            self._per[rid] = nbytes
            if self._total > self.peak:
                self.peak = self._total

    def clear(self, rid: int) -> None:
        with self._lock:
            self._total -= self._per.pop(rid, 0)


class JobControl:
    """Job-wide cancellation + first-failure collection.

    Shared by every scheduler (and, in cluster mode, every worker) of one
    sort: a real failure anywhere cancels the whole job, and the
    chronologically first exception is what the driver re-raises.
    """

    def __init__(self):
        self.cancel = threading.Event()
        self._lock = threading.Lock()
        self._first: list[BaseException] = []

    def fail(self, e: BaseException) -> None:
        with self._lock:
            if not self._first:
                self._first.append(e)
        self.cancel.set()

    @property
    def failed(self) -> bool:
        with self._lock:
            return bool(self._first)

    def raise_first(self) -> None:
        with self._lock:
            if self._first:
                raise self._first[0]


class AdaptiveBudgetGovernor:
    """Adaptive apportionment of the global reduce memory budget.

    Replaces the static active-count split: every registering reducer is
    granted the static fair share S0 = budget // slots (the floor
    _reduce_chunking validates up front), and on every emit cycle it may
    `grow` its grant out of budget freed by retired reducers — so the
    tail of the reduce phase runs with bigger per-run chunks instead of
    leaving freed budget idle ("chunk sizes grow mid-merge").

    The budget bound is provable, not just measured:

      * bytes only move between the free pool and live grants under one
        lock, and the free pool never goes negative — so the sum of live
        grants never exceeds the budget;
      * a live reducer's grant (hence chunk) never shrinks — growth only
        draws from `free` beyond a reservation of S0 per not-yet-started
        partition (up to the slot count), so a late registrant never
        needs to claw back granted bytes;
      * each reducer buffers at most runs x chunk <= grant decoded bytes,
        so the measured all-reducer peak (reduce_peak_merge_bytes) is
        under the budget at every instant.

    With budget == 0 the governor is inert: every cursor just uses the
    merge_chunk_bytes cap.
    """

    def __init__(self, *, budget: int, chunk_cap: int, record_bytes: int,
                 slots: int, partitions: int):
        self.budget = int(budget)
        self.chunk_cap = int(chunk_cap)
        self.record_bytes = int(record_bytes)
        self.slots = max(int(slots), 1)
        self._cond = threading.Condition()
        self._free = self.budget
        self._live: dict[int, tuple[int, int]] = {}  # rid -> (runs, grant)
        # Completed rids as a SET, not a counter: a partition whose merge
        # retired but whose async commit later died (cluster worker
        # failure) is re-executed and retires AGAIN — dedup keeps the
        # unstarted-partition reservation from under-counting.
        self._done_rids: set[int] = set()
        self._partitions = int(partitions)
        self._base = self.budget // self.slots if self.budget else 0
        self.max_chunk_bytes = 0 if self.budget else self.chunk_cap

    def _chunk_of(self, runs: int, grant: int) -> int:
        return min(self.chunk_cap, grant // max(runs, 1))

    def register(self, rid: int, runs: int,
                 abort: Callable[[], bool] | None = None) -> int | None:
        """Reserve an initial grant; returns the per-run chunk in bytes.

        Blocks while the free pool cannot cover even one record per run
        (only possible transiently, while grown siblings hold surplus
        that their retirement will release). Returns None if `abort`
        turns true while waiting.
        """
        if not self.budget:
            return self.chunk_cap
        min_need = max(runs, 1) * self.record_bytes
        with self._cond:
            while self._free < min_need:
                if abort is not None and abort():
                    return None
                self._cond.wait(timeout=0.05)
            grant = max(min(self._base, runs * self.chunk_cap, self._free),
                        min_need)
            self._live[rid] = (runs, grant)
            self._free -= grant
            chunk = self._chunk_of(runs, grant)
            self.max_chunk_bytes = max(self.max_chunk_bytes, chunk)
            return chunk

    def chunk_bytes(self, rid: int) -> int:
        if not self.budget:
            return self.chunk_cap
        with self._cond:
            runs, grant = self._live[rid]
            return self._chunk_of(runs, grant)

    def grow(self, rid: int) -> int:
        """Re-apportion freed budget into this reducer's grant (monotone);
        returns the current per-run chunk in bytes."""
        if not self.budget:
            return self.chunk_cap
        with self._cond:
            runs, grant = self._live[rid]
            target = runs * self.chunk_cap
            if grant < target:
                # Keep S0 reserved for every partition that still has to
                # start (bounded by the free scheduler slots), so future
                # registrants are never starved by growth.
                unstarted = (self._partitions - len(self._done_rids)
                             - len(self._live))
                reserve = self._base * max(
                    0, min(self.slots - len(self._live), unstarted))
                avail = self._free - reserve
                extra = min(target - grant, avail // max(len(self._live), 1))
                if extra > 0:
                    grant += extra
                    self._live[rid] = (runs, grant)
                    self._free -= extra
            chunk = self._chunk_of(runs, grant)
            self.max_chunk_bytes = max(self.max_chunk_bytes, chunk)
            return chunk

    def retire(self, rid: int, *, completed: bool = True) -> None:
        """Release the grant back to the free pool (waking any waiting
        registrant); `completed=False` marks a failed reducer whose
        partition will be re-executed (cluster failure recovery)."""
        if not self.budget:
            return
        with self._cond:
            entry = self._live.pop(rid, None)
            if entry is not None:
                self._free += entry[1]
            if completed:
                self._done_rids.add(rid)
            self._cond.notify_all()


@dataclasses.dataclass
class ExternalSortReport:
    """What happened: sizes, timings, and *measured* store traffic."""

    total_records: int
    num_waves: int
    num_workers: int
    num_reducers: int
    spill_objects: int
    output_objects: int
    map_seconds: float
    reduce_seconds: float
    working_set_records: int
    stats: StoreStats  # delta over the sort (map + reduce), all tiers
    runs_per_reducer: int = 0  # k of the streaming k-way merge
    merge_chunk_bytes: int = 0  # the plan's per-run fetch cap
    reduce_chunk_bytes: int = 0  # initial per-run chunk (budget-governed)
    reduce_chunk_bytes_max: int = 0  # largest chunk the governor granted
    reduce_peak_merge_bytes: int = 0  # measured max across ALL active merges
    parallel_reducers: int = 1  # concurrent merges the scheduler(s) ran
    reduce_memory_budget_bytes: int = 0  # the global governor (0 = none)
    tier_stats: dict[str, StoreStats] | None = None  # per-tier deltas
    spans: list[Span] = dataclasses.field(default_factory=list)
    spans_dropped: int = 0  # spans beyond the recorder cap (totals stay exact)
    phase_seconds: dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def oversubscription(self) -> float:
        """Dataset size / per-wave device working set (>1 = out-of-core)."""
        return self.total_records / self.working_set_records

    @property
    def reduce_memory_bound_bytes(self) -> int:
        """The scheduler's memory guarantee: the global budget when one is
        set, else parallel_reducers x runs x effective chunk (+ one record
        of rounding per run) — reduce_peak_merge_bytes never exceeds it."""
        if self.reduce_memory_budget_bytes:
            return self.reduce_memory_budget_bytes
        chunk = self.reduce_chunk_bytes or self.merge_chunk_bytes
        return self.parallel_reducers * self.runs_per_reducer * chunk

    @property
    def job_hours(self) -> float:
        return (self.map_seconds + self.reduce_seconds) / 3600.0

    @property
    def reduce_hours(self) -> float:
        return self.reduce_seconds / 3600.0


def _spill_key(plan: ExternalSortPlan, wave: int, worker: int) -> str:
    return f"{plan.spill_prefix}wave-{wave:04d}/w-{worker:03d}"


def _output_key(plan: ExternalSortPlan, reducer: int) -> str:
    return f"{plan.output_prefix}part-{reducer:05d}"


def _group_waves(inputs, counts, records_per_wave: int):
    """Tile the key-ordered input objects into equal-record waves.

    ValueError, not assert: the tiling contract must survive python -O —
    a silently mis-tiled wave would sort fine and fail only at valsort.
    """
    waves, cur, acc = [], [], 0
    for meta, c in zip(inputs, counts):
        cur.append(meta)
        acc += c
        if acc > records_per_wave:
            raise ValueError(
                "input partitions must tile records_per_wave exactly "
                f"(partition {meta.key} overflows the wave)"
            )
        if acc == records_per_wave:
            waves.append(cur)
            cur, acc = [], 0
    if cur:
        raise ValueError("total records must be a multiple of records_per_wave")
    return waves


def _contiguous_id_base(ids: np.ndarray) -> int | None:
    """Base id when a wave's ids are exactly arange(base, base + n).

    gensort assigns ids sequentially across key-ordered input partitions
    (data/gensort.write_to_store), so every wave decodes to a contiguous
    ascending id range: the payload row of shuffled record id is then
    just (id - base) — O(1) index arithmetic per record instead of the
    argsort + searchsorted gather (O(n log n), random access). One
    vectorized equality pass verifies the assumption; any other id layout
    falls back to the general gather.
    """
    n = ids.size
    if n == 0:
        return None
    base = int(ids[0])
    # Unwrapped comparison on purpose: a range wrapping past 2^32 would
    # break the (id - base) gather below, so it must take the fallback.
    if base + n - 1 != int(ids[-1]):
        return None
    expect = np.uint32(base) + np.arange(n, dtype=np.uint32)
    if not bool(np.array_equal(np.asarray(ids, dtype=np.uint32), expect)):
        return None
    return base


class _RunCursor:
    """Bounded window over one spilled run's reducer slice.

    Holds at most `chunk_records` decoded records at a time; `refill`
    issues one ranged GET for the next chunk, `take_upto` consumes the
    buffered prefix that is safe to emit (every record <= bound). The
    chunk size may be raised mid-stream (`set_chunk`) when the adaptive
    governor re-apportions budget freed by retired reducers.
    """

    __slots__ = ("_store", "_bucket", "_key", "_hi", "_next", "_chunk",
                 "_pw", "k64", "keys", "ids", "payload")

    def __init__(self, store, bucket, key, lo, hi, payload_words, chunk_records):
        self._store = store
        self._bucket = bucket
        self._key = key
        self._next = int(lo)
        self._hi = int(hi)
        self._chunk = int(chunk_records)
        self._pw = int(payload_words)
        self.keys = np.empty((0,), np.uint32)
        self.ids = np.empty((0,), np.uint32)
        self.payload = None
        self.k64 = np.empty((0,), np.uint64)

    @property
    def has_more_remote(self) -> bool:
        return self._next < self._hi

    @property
    def exhausted(self) -> bool:
        return not self.has_more_remote and self.k64.size == 0

    @property
    def buffered_bytes(self) -> int:
        return self.k64.size * rec.record_bytes(self._pw)

    def set_chunk(self, chunk_records: int) -> None:
        self._chunk = int(chunk_records)

    def refill(self) -> None:
        n = min(self._chunk, self._hi - self._next)
        start, length = rec.body_range(self._next, n, self._pw)
        body = self._store.get_range(self._bucket, self._key, start, length)
        self._next += n
        k, i, p = rec.decode_body(body, self._pw)
        self.keys, self.ids, self.payload = k, i, p
        self.k64 = k.astype(np.uint64) << np.uint64(32) | i.astype(np.uint64)

    def take_upto(self, bound):
        """Consume and return the (keys, ids, payload, k64) prefix with
        k64 <= bound; bound=None consumes everything buffered."""
        cut = self.k64.size if bound is None else int(
            np.searchsorted(self.k64, bound, side="right"))
        out = (self.keys[:cut], self.ids[:cut],
               None if self.payload is None else self.payload[:cut],
               self.k64[:cut])
        self.keys, self.ids = self.keys[cut:], self.ids[cut:]
        self.payload = None if self.payload is None else self.payload[cut:]
        self.k64 = self.k64[cut:]
        return out


def _merge_fragments(frags, payload_words: int):
    """Merge already-sorted fragments (one per run) into one sorted batch.

    Fragment keys are globally unique (key<<32|id with unique ids), so a
    plain stable argsort over the concatenated packed keys is an exact
    k-way merge of the emit window — small (≤ runs x chunk records) by
    construction, which is the whole point of the streaming reduce.
    """
    frags = [f for f in frags if f[3].size]
    if not frags:
        empty = np.empty((0,), np.uint32)
        pw = int(payload_words)
        return empty, empty, (np.empty((0, pw), np.uint32) if pw else None)
    if len(frags) == 1:
        k, i, p, _ = frags[0]
        return k, i, p
    k64 = np.concatenate([f[3] for f in frags])
    order = np.argsort(k64, kind="stable")
    keys = np.concatenate([f[0] for f in frags])[order]
    ids = np.concatenate([f[1] for f in frags])[order]
    payload = None
    if payload_words:
        payload = np.concatenate([f[2] for f in frags])[order]
    return keys, ids, payload


class _SiblingFailed(Exception):
    """Internal: this reducer was cancelled because another one failed."""


def _reduce_chunking(plan: ExternalSortPlan, runs: int,
                     active: int) -> tuple[int, int]:
    """(chunk_records, chunk_bytes) per run under the global budget.

    This is the STATIC fair split — the governor's starting point and the
    up-front feasibility check: with a budget, each of the `active`
    concurrent reducers gets an equal share, split over its `runs`
    cursors and capped at merge_chunk_bytes; the all-reducer total
    active x runs x chunk therefore never exceeds the budget. Without
    one, every cursor buffers merge_chunk_bytes. At runtime the adaptive
    governor only ever grants MORE than this (never less), drawing on
    budget freed by retired reducers.
    """
    rb = plan.record_bytes
    if plan.merge_chunk_bytes < rb:
        raise ValueError(
            f"merge_chunk_bytes={plan.merge_chunk_bytes} must hold at least "
            f"one {rb}-byte record, else the reduce-memory bound cannot be met"
        )
    chunk_bytes = plan.merge_chunk_bytes
    if plan.reduce_memory_budget_bytes:
        share = plan.reduce_memory_budget_bytes // max(active, 1)
        chunk_bytes = min(chunk_bytes, share // max(runs, 1))
        if chunk_bytes < rb:
            raise ValueError(
                f"reduce_memory_budget_bytes={plan.reduce_memory_budget_bytes}"
                f" cannot give each of {active} concurrent reducers one "
                f"{rb}-byte record per run ({runs} runs each) — raise the "
                "budget or lower parallel_reducers"
            )
    return chunk_bytes // rb, chunk_bytes


def _validate_plan(plan: ExternalSortPlan, w: int) -> None:
    """Plan validation shared by the single-host and cluster drivers.

    ValueError, not assert: must survive python -O.
    """
    if plan.records_per_wave % (w * plan.num_rounds) != 0:
        raise ValueError(
            "records_per_wave must divide evenly into per-worker rounds"
        )
    if plan.parallel_reducers < 1:
        raise ValueError(f"parallel_reducers must be >= 1, "
                         f"got {plan.parallel_reducers}")
    if plan.part_upload_fanout < 1:
        raise ValueError(f"part_upload_fanout must be >= 1, "
                         f"got {plan.part_upload_fanout}")


def _timed_part(timeline: PhaseTimeline, tag: str, mp, index: int,
                data: bytes) -> None:
    """Background part upload, recorded as a reduce.upload span."""
    t = time.perf_counter()
    mp.put_part(index, data)
    timeline.add("reduce.upload", t, worker=tag)


def _finalize_session(timeline: PhaseTimeline, tag: str,
                      uploader: staging.AsyncWriter, mp,
                      on_done: Callable[[], None] | None = None) -> None:
    """Background session finisher: wait for the partition's in-flight
    parts, then commit — or abort on any failure (a truncated commit
    would carry a self-consistent CRC etag IntegrityError can't catch).
    Running this off the merge thread is what lets a reducer's scheduler
    slot free while its tail uploads still stream (partition r's uploads
    overlap partition r+active's merge even at parallel_reducers=1).
    `on_done` fires only after the commit succeeds — the durability
    confirmation the cluster driver uses to decide what a dead worker
    still owed."""
    t = time.perf_counter()
    try:
        uploader.close()  # waits all parts; re-raises the first failure
    except BaseException:
        mp.abort()
        raise
    try:
        mp.complete()
    except BaseException:
        mp.abort()
        raise
    finally:
        timeline.add("reduce.upload_wait", t, worker=tag)
    if on_done is not None:
        on_done()


def _timed_spill(timeline: PhaseTimeline, tag: str, store, bucket: str,
                 key: str, data: bytes, metadata: dict) -> None:
    """Background spill put, recorded as a map.spill span."""
    t = time.perf_counter()
    store.put(bucket, key, data, metadata=metadata)
    timeline.add("map.spill", t, worker=tag)


class WaveSorter:
    """Map-side building block: load a wave zero-copy, sort it across the
    mesh, spill per-mesh-worker runs.

    Shared by the single-host driver below and by every cluster worker
    (core/cluster.py). Deterministic by construction: the spilled run
    bytes and reducer offsets depend only on (wave contents, plan, mesh
    width) — never on which scheduler or emulated worker executes the
    wave — which is what keeps cluster output byte-identical to the
    single-host run at any worker count and under re-execution.
    """

    def __init__(self, plan: ExternalSortPlan, mesh: jax.sharding.Mesh,
                 axis_names: Sequence[str] | str):
        axis = tuple([axis_names] if isinstance(axis_names, str)
                     else axis_names)
        self.plan = plan
        self.w = int(math.prod(mesh.shape[a] for a in axis))
        self.r1 = plan.reducers_per_worker
        self.pw = plan.payload_words
        _validate_plan(plan, self.w)
        self.cfg = ShuffleConfig(
            num_workers=self.w,
            reducers_per_worker=self.r1,
            capacity_factor=plan.capacity_factor,
            num_rounds=plan.num_rounds,
            impl=plan.impl,
        )
        self._sort = jax.jit(
            lambda k, i: streaming_sort(
                k, i, mesh=mesh, axis_names=axis_names,
                num_rounds=plan.num_rounds, cfg=self.cfg,
            )
        )
        self._local_bounds = (
            np.asarray(self.cfg.keyspace.local_reducer_boundaries())
            if self.r1 > 1 else None
        )  # (W, R1-1)
        # The device mesh is ONE shared resource: concurrent executions of
        # the same multi-device collective program interleave their
        # per-device participant threads into XLA's rendezvous and
        # deadlock (and a real accelerator would serialize them anyway).
        # Emulated cluster workers therefore queue on this lock for the
        # sort step, and overlap on everything else — load, spill, reduce
        # — which is where worker-count scaling pays on a latency-bound
        # store.
        self._device_lock = threading.Lock()

    def load_wave(self, store: StoreBackend, bucket: str, objs):
        """Chunked-GET a wave's input objects into one preallocated
        interleaved-row buffer (zero-copy decode); returns (keys, ids,
        payload)."""
        plan = self.plan
        n_wave = sum(
            (m.size - rec.HEADER_BYTES) // plan.record_bytes for m in objs)
        rows = rec.alloc_rows(n_wave, self.pw)
        at = 0
        for m in objs:
            dec = rec.StreamDecoder(rows, at, what=m.key)
            for chunk in store.get_chunks(bucket, m.key, plan.store_chunk_bytes):
                dec.feed(chunk)
            at += dec.finish()
        return rec.split_rows(rows)

    def compute_and_spill(self, store: StoreBackend, bucket: str, g: int,
                          keys, ids, payload, *, spiller: staging.AsyncWriter,
                          timeline: PhaseTimeline, tag: str,
                          offsets_out: dict) -> None:
        """Sort wave g on the mesh and spill each mesh-worker's run.

        Writes per-reducer offsets for every spilled run into
        `offsets_out[(g, wid)]` (they are also persisted in the spill
        object's manifest metadata, so a process-backed worker could
        recover them from the store alone).
        """
        plan, w, pw = self.plan, self.w, self.pw
        t_comp = time.perf_counter()
        with self._device_lock:
            sk, si, vcounts, ovf = self._sort(jnp.asarray(keys),
                                              jnp.asarray(ids))
            sk, si, vcounts = (np.asarray(sk), np.asarray(si),
                               np.asarray(vcounts))
        if bool(np.asarray(ovf)):
            raise RuntimeError(
                "shuffle block overflow — raise capacity_factor"
            )
        # id -> wave row for gathering payload of shuffled records:
        # O(1) offset arithmetic when the wave's ids are contiguous
        # (the gensort layout), argsort gather otherwise.
        id_base = _contiguous_id_base(ids) if pw else None
        order = sorted_ids = None
        if pw and id_base is None:
            order = np.argsort(ids)
            sorted_ids = ids[order]
        seg = sk.shape[0] // w
        for wid in range(w):
            n = int(vcounts[wid])
            run_k = sk[wid * seg : wid * seg + n]
            run_i = si[wid * seg : wid * seg + n]
            run_p = None
            if pw:
                if id_base is not None:
                    sel = run_i.astype(np.int64) - id_base
                else:
                    sel = order[np.searchsorted(sorted_ids, run_i)]
                run_p = payload[sel]
            if self._local_bounds is not None:
                internal = np.searchsorted(
                    run_k, self._local_bounds[wid], side="left")
            else:
                internal = np.empty((0,), np.int64)
            offsets = np.concatenate(([0], internal, [n])).astype(np.int64)
            offsets_out[(g, wid)] = offsets
            data = rec.encode_records(run_k, run_i, run_p)
            # Submit each encoded run immediately: the AsyncWriter
            # backpressure bound (at most max_inflight encoded runs
            # in host memory) only holds if we never batch them.
            timeline.add("map.compute", t_comp, worker=tag)
            t_spill = time.perf_counter()
            spiller.submit(_timed_spill, timeline, tag, store, bucket,
                           _spill_key(plan, g, wid), data, {
                               "records": n,
                               "wave": g,
                               "worker": wid,
                               "reducer_offsets": [int(o) for o in offsets],
                           })
            timeline.add("map.spill_wait", t_spill, worker=tag)
            t_comp = time.perf_counter()
        timeline.add("map.compute", t_comp, worker=tag)


@dataclasses.dataclass
class JobSetup:
    """Shared preflight for the single-host and cluster drivers: the
    validated wave grouping, budget feasibility + governor, and baseline
    store counters (captured after stale-prefix cleanup) — one source of
    truth so the two drivers cannot drift."""

    sorter: WaveSorter
    total: int
    waves: list
    num_waves: int
    num_reducers: int
    slots: int  # cluster-wide concurrent merge ceiling (governor S0 basis)
    chunk_bytes: int  # the static fair-share chunk (reported + floor)
    governor: AdaptiveBudgetGovernor
    base_stats: StoreStats
    tier_base: dict | None


def prepare_job(store: StoreBackend, bucket: str, plan: ExternalSortPlan,
                mesh, axis_names, *, schedulers: int = 1) -> JobSetup:
    """Validate the plan, group waves, check budget feasibility, and clear
    stale spill/output prefixes — before any wave is fetched (and billed).

    `schedulers` is how many reduce schedulers will run concurrently
    (1 single-host; the worker count for core/cluster.py): the governor's
    slot count — and therefore the static fair share every reducer is
    guaranteed — is schedulers x plan.parallel_reducers, capped at the
    partition count.
    """
    sorter = WaveSorter(plan, mesh, axis_names)
    inputs = store.list_objects(bucket, plan.input_prefix)
    if not inputs:
        raise ValueError(f"no input objects under {plan.input_prefix!r}")
    counts = [(m.size - rec.HEADER_BYTES) // plan.record_bytes
              for m in inputs]
    waves = _group_waves(inputs, counts, plan.records_per_wave)
    num_reducers = sorter.w * sorter.r1
    slots = min(max(int(schedulers), 1) * plan.parallel_reducers,
                num_reducers)
    _, chunk_bytes = _reduce_chunking(plan, len(waves), slots)
    governor = AdaptiveBudgetGovernor(
        budget=plan.reduce_memory_budget_bytes,
        chunk_cap=plan.merge_chunk_bytes,
        record_bytes=plan.record_bytes,
        slots=slots,
        partitions=num_reducers,
    )
    # Overwrite semantics: clear stale spill/output objects from any prior
    # run so the reduce pass and downstream validation see only this run.
    for prefix in (plan.spill_prefix, plan.output_prefix):
        for meta in store.list_objects(bucket, prefix):
            store.delete(bucket, meta.key)
    return JobSetup(
        sorter=sorter,
        total=sum(counts),
        waves=waves,
        num_waves=len(waves),
        num_reducers=num_reducers,
        slots=slots,
        chunk_bytes=chunk_bytes,
        governor=governor,
        base_stats=store.stats_snapshot(),
        tier_base=(store.per_tier_stats()
                   if hasattr(store, "per_tier_stats") else None),
    )


def build_report(setup: JobSetup, store: StoreBackend,
                 plan: ExternalSortPlan, *, map_seconds: float,
                 reduce_seconds: float, peak: _PeakTracker,
                 timeline: PhaseTimeline) -> ExternalSortReport:
    """Assemble the run report from the shared setup + measured state —
    the one place the report contract is populated, for both drivers."""
    tier_stats = None
    if setup.tier_base is not None:
        tier_now = store.per_tier_stats()
        tier_stats = {name: tier_now[name] - setup.tier_base[name]
                      for name in tier_now}
    return ExternalSortReport(
        total_records=setup.total,
        num_waves=setup.num_waves,
        num_workers=setup.sorter.w,
        num_reducers=setup.num_reducers,
        spill_objects=setup.num_waves * setup.sorter.w,
        output_objects=setup.num_reducers,
        map_seconds=map_seconds,
        reduce_seconds=reduce_seconds,
        working_set_records=plan.records_per_wave,
        stats=store.stats_snapshot() - setup.base_stats,
        runs_per_reducer=setup.num_waves,
        merge_chunk_bytes=plan.merge_chunk_bytes,
        reduce_chunk_bytes=setup.chunk_bytes,
        reduce_chunk_bytes_max=setup.governor.max_chunk_bytes,
        reduce_peak_merge_bytes=peak.peak,
        parallel_reducers=setup.slots,
        reduce_memory_budget_bytes=plan.reduce_memory_budget_bytes,
        tier_stats=tier_stats,
        spans=timeline.spans(),
        spans_dropped=timeline.dropped,
        phase_seconds=timeline.totals(),
    )


@dataclasses.dataclass
class ReduceShared:
    """Job-level shared state for one sort's reduce pass — shared across
    every ReduceScheduler (one on a single host, one per cluster worker),
    so the budget governor, peak accounting, cancellation, and timeline
    stay global while the schedulers stay per-worker."""

    plan: ExternalSortPlan
    bucket: str
    num_waves: int
    r1: int  # reducers per mesh worker (partition -> run-slice mapping)
    spill_offsets: dict
    governor: AdaptiveBudgetGovernor
    timeline: PhaseTimeline
    peak: _PeakTracker
    control: JobControl


class ReduceScheduler:
    """One host's (or one emulated cluster worker's) reduce scheduler.

    Pulls partition ids from `pop_next` and runs up to `width` streaming
    k-way merges concurrently against `store`, sharing the job-level
    governor/peak/cancellation through `shared`. Failure taxonomy:

      * exceptions of a type in `fatal` mean THIS scheduler's worker died
        (core/cluster.WorkerFailure): the scheduler unwinds and re-raises
        so the cluster driver can re-execute unconfirmed partitions on
        survivors — the job keeps going;
      * any other exception is a job failure: it is recorded on
        shared.control (which cancels every scheduler) and the driver
        re-raises it after the barrier.

    A partition only counts as done (`on_done`) after its multipart
    session COMMITS — merge completion is not durability.
    """

    def __init__(self, store: StoreBackend, shared: ReduceShared, *,
                 width: int, fatal: tuple = (), tag_prefix: str = ""):
        self.store = store
        self.shared = shared
        self.width = max(int(width), 1)
        self.fatal = tuple(fatal)
        self.tag_prefix = tag_prefix

    def run(self, pop_next: Callable[[], int | None],
            on_done: Callable[[int], None] | None = None) -> None:
        """Drain partitions until the queue is empty, the job is
        cancelled, or this scheduler's worker dies (re-raised)."""
        shared = self.shared
        plan = shared.plan
        refill_pool = ThreadPoolExecutor(
            max_workers=min(16, max(2, shared.num_waves * self.width)),
            thread_name_prefix="reduce-refill")
        finishers = staging.AsyncWriter(
            max(plan.max_inflight_writes, self.width), max_workers=self.width,
            thread_name_prefix="reduce-finish")
        dead_lock = threading.Lock()
        dead: list[BaseException] = []
        dead_evt = threading.Event()

        def loop() -> None:
            while not (shared.control.cancel.is_set() or dead_evt.is_set()):
                try:
                    r = pop_next()
                except self.fatal as e:  # the worker died at the queue
                    with dead_lock:
                        dead.append(e)
                    dead_evt.set()
                    return
                if r is None:
                    return
                try:
                    self._reduce_one(r, refill_pool, finishers, on_done)
                except _SiblingFailed:
                    pass  # aborted cleanly; the root cause is recorded
                except self.fatal as e:  # worker death: stop this scheduler
                    with dead_lock:
                        dead.append(e)
                    dead_evt.set()
                    return
                except BaseException as e:  # real failure: cancel the job
                    shared.control.fail(e)
                    return

        threads = [threading.Thread(target=loop, name=f"reduce-merge-{i}")
                   for i in range(self.width)]
        try:
            for t in threads:
                t.start()
        finally:
            for t in threads:
                t.join()
            refill_pool.shutdown(wait=True)
            try:
                finishers.close()  # re-raises the first finisher failure
            except self.fatal as e:
                # Death during commit: those partitions never confirmed,
                # so the cluster driver will re-execute them.
                with dead_lock:
                    dead.append(e)
            except BaseException as e:
                shared.control.fail(e)
        if dead:
            raise dead[0]

    # -- internals ---------------------------------------------------------

    def _run_slices(self, r: int):
        """[(spill key, lo, hi)] of partition r's non-empty run slices."""
        shared = self.shared
        wid, j = divmod(r, shared.r1)
        slices, n_total = [], 0
        for g in range(shared.num_waves):
            offs = shared.spill_offsets[(g, wid)]
            lo, hi = int(offs[j]), int(offs[j + 1])
            if hi > lo:
                slices.append((_spill_key(shared.plan, g, wid), lo, hi))
                n_total += hi - lo
        return slices, n_total

    def _reduce_one(self, r: int, refill_pool, finishers,
                    on_done: Callable[[int], None] | None) -> None:
        shared = self.shared
        plan = shared.plan
        store = self.store
        timeline = shared.timeline
        governor = shared.governor
        pw = plan.payload_words
        rb = plan.record_bytes
        part_bytes = plan.output_part_records * rb
        tag = f"{self.tag_prefix}r{r}"
        slices, n_total = self._run_slices(r)
        registered = bool(slices)
        chunk_records = 0
        if registered:
            chunk = governor.register(
                r, len(slices), abort=shared.control.cancel.is_set)
            if chunk is None:
                raise _SiblingFailed()
            chunk_records = chunk // rb
        cursors = [
            _RunCursor(store, shared.bucket, key, lo, hi, pw, chunk_records)
            for key, lo, hi in slices
        ]
        mp = store.multipart(shared.bucket, _output_key(plan, r),
                             metadata={"records": n_total, "reducer": r})
        # max_inflight >= fanout, or the backpressure semaphore would
        # silently cap concurrent part uploads below the fan-out width.
        uploader = staging.AsyncWriter(
            max(plan.max_inflight_writes, plan.part_upload_fanout),
            max_workers=plan.part_upload_fanout)
        next_part = 0

        def submit_part(data: bytes) -> None:
            nonlocal next_part
            idx, next_part = next_part, next_part + 1
            t = time.perf_counter()  # blocks under upload backpressure
            uploader.submit(_timed_part, timeline, tag, mp, idx, data)
            timeline.add("reduce.upload_wait", t, worker=tag)

        try:
            # Record count is known up front (sum of run-slice
            # lengths), so the header streams first, body follows.
            outbuf = bytearray(rec.encode_header(n_total, pw))
            while cursors:
                if shared.control.cancel.is_set():
                    raise _SiblingFailed()
                if registered:
                    # Adaptive governor: soak up budget freed by retired
                    # reducers — the per-run chunk can only grow.
                    grown = governor.grow(r) // rb
                    if grown != chunk_records:
                        chunk_records = grown
                        for c in cursors:
                            c.set_chunk(grown)
                need = [c for c in cursors
                        if c.k64.size == 0 and c.has_more_remote]
                if need:
                    t = time.perf_counter()
                    if len(need) == 1:
                        need[0].refill()
                    else:  # concurrent ranged GETs: one RTT per cycle
                        list(refill_pool.map(_RunCursor.refill, need))
                    timeline.add("reduce.fetch", t, worker=tag)
                shared.peak.update(r, sum(c.buffered_bytes for c in cursors))
                t = time.perf_counter()
                # Safe emit bound: the smallest last-buffered key among
                # runs that still have un-fetched records — nothing
                # later can sort below it. When no run has remote data
                # left, everything buffered is emittable.
                remote_tails = [c.k64[-1] for c in cursors
                                if c.has_more_remote]
                bound = min(remote_tails) if remote_tails else None
                frags = [c.take_upto(bound) for c in cursors]
                cursors = [c for c in cursors if not c.exhausted]
                mk, mi, mpay = _merge_fragments(frags, pw)
                if mk.size:
                    outbuf += rec.encode_body(mk, mi, mpay)
                timeline.add("reduce.merge", t, worker=tag)
                while len(outbuf) >= part_bytes:
                    submit_part(bytes(outbuf[:part_bytes]))
                    del outbuf[:part_bytes]
            # >= 1 part always: an empty partition still has a header.
            if outbuf or n_total == 0:
                submit_part(bytes(outbuf))
        except BaseException:
            # Merge or upload died mid-session: let in-flight parts
            # settle, then discard the session — never commit it.
            try:
                uploader.drain()
            except BaseException:
                pass
            try:
                mp.abort()
            except BaseException:
                pass  # a dead worker's abort fails too; parts are orphaned
            finally:
                shared.peak.clear(r)
                if registered:
                    governor.retire(r, completed=False)
                uploader.close()
            raise
        # Success: hand drain + complete to the finisher queue so this
        # scheduler slot frees while the tail parts still upload —
        # finishers.submit blocks once max(max_inflight_writes, width)
        # sessions await completion (cross-partition upload backpressure).
        shared.peak.clear(r)
        if registered:
            governor.retire(r)
        confirm = None if on_done is None else (lambda: on_done(r))
        finishers.submit(_finalize_session, timeline, tag, uploader, mp,
                         confirm)


def external_sort(
    store: StoreBackend,
    bucket: str,
    *,
    mesh: jax.sharding.Mesh,
    axis_names: Sequence[str] | str,
    plan: ExternalSortPlan,
) -> ExternalSortReport:
    """Sort every record under plan.input_prefix into plan.output_prefix.

    `store` is any io/backends.StoreBackend — the plain ObjectStore, a
    fault-injected middleware stack, or a TieredStore (in which case the
    report carries per-tier request deltas). Input objects must be
    io/records-encoded with plan.payload_words words of payload and
    globally unique ids (data/gensort.write_to_store's layout). Returns
    the run report; validate the output with data/valsort.validate_from_store.

    This is the single-host driver; core/cluster.ClusterExecutor runs the
    same schedule partitioned across N emulated workers with failure
    recovery, and produces byte-identical output.
    """
    # Budget feasibility is pure plan validation — prepare_job fails
    # before any map wave is fetched/sorted/spilled (and billed).
    setup = prepare_job(store, bucket, plan, mesh, axis_names)
    sorter = setup.sorter

    # ---- map waves: stream in (zero-copy) -> sort -> spill runs -------
    spill_offsets: dict[tuple[int, int], np.ndarray] = {}
    t0 = time.perf_counter()
    timeline = PhaseTimeline(origin=t0)
    control = JobControl()
    with staging.AsyncWriter(plan.max_inflight_writes) as spiller:
        wave_loads = (lambda objs=objs: sorter.load_wave(store, bucket, objs)
                      for objs in setup.waves)
        wave_iter = iter(staging.prefetch(
            wave_loads, depth=plan.prefetch_depth,
            retries=plan.io_retries, retry_on=(RetryableError,)))
        g = 0
        while True:
            t_wait = time.perf_counter()
            try:
                keys, ids, payload = next(wave_iter)
            except StopIteration:
                break
            tag = f"g{g}"
            timeline.add("map.wait", t_wait, worker=tag)
            sorter.compute_and_spill(
                store, bucket, g, keys, ids, payload, spiller=spiller,
                timeline=timeline, tag=tag, offsets_out=spill_offsets)
            g += 1
    map_seconds = time.perf_counter() - t0

    # ---- reduce: scheduler of streaming k-way merges ------------------
    # Memory contract: `slots` merges run concurrently, each of their
    # (≤ num_waves) run cursors buffering at most the governor-granted
    # chunk of decoded records; grants are apportioned from the global
    # reduce_memory_budget_bytes when one is set and re-apportioned as
    # reducers retire (AdaptiveBudgetGovernor). Output bytes are
    # independent of the schedule — see the module docstring.
    peak = _PeakTracker()
    shared = ReduceShared(
        plan=plan, bucket=bucket, num_waves=setup.num_waves, r1=sorter.r1,
        spill_offsets=spill_offsets, governor=setup.governor,
        timeline=timeline, peak=peak, control=control,
    )
    pending = collections.deque(range(setup.num_reducers))
    pop_lock = threading.Lock()

    def pop_next() -> int | None:
        with pop_lock:
            return pending.popleft() if pending else None

    t0 = time.perf_counter()
    ReduceScheduler(store, shared, width=setup.slots).run(pop_next)
    control.raise_first()
    reduce_seconds = time.perf_counter() - t0

    return build_report(setup, store, plan, map_seconds=map_seconds,
                        reduce_seconds=reduce_seconds, peak=peak,
                        timeline=timeline)
