"""Out-of-core external sort: the dataset lives in the object store, not HBM.

This is the driver that lets the reproduction actually *pose* the CloudSort
problem (paper §2.3–§2.5): total dataset size is bounded by object-store
capacity, while device memory holds only one map wave's working set.

Paper mapping:

  map waves (§2.3, §2.5): input partitions stream from the store in ranged
      chunks (io/backends.get_chunks — one GET per chunk, the paper's
      "120 chunks" map download), double-buffered against device compute
      (io/staging.prefetch, retry-aware against transient store stalls).
      Wave assembly is zero-copy: each chunk decodes straight into one
      preallocated interleaved-row buffer (io/records.StreamDecoder), so
      a wave's bytes are copied once off the wire instead of through
      b"".join + np.concatenate staging copies. Each wave runs the
      in-memory two-stage streaming exoshuffle (core/streaming.py), after
      which every worker holds one globally range-partitioned sorted run;
      shuffled payload rows are located by O(1) id-offset arithmetic
      (gensort ids are contiguous per wave) instead of a per-wave argsort.

  spill (§2.3): each worker's merged run is written back under
      plan.spill_prefix as one sorted run object. Against a TieredStore
      (io/tiered.py) that prefix routes to the local-SSD tier — the
      paper's actual spill target — while input/output keys stay on the
      durable (S3-like, throttled, billed) tier. Per-reducer offsets into
      the run are recorded in the object's manifest metadata; writes are
      write-behind via io/staging.AsyncWriter so upload overlaps the next
      wave's sort.

  reduce (§2.4): a scheduler runs up to plan.parallel_reducers streaming
      k-way merges CONCURRENTLY on a worker pool — the paper's "all
      output partitions at once" reduce stage, the scheduling freedom
      shuffle-as-a-library buys (Exoshuffle §4). Each active reducer
      fetches its slice of every spilled run in bounded ranged chunks
      (all empty cursors refill concurrently, so an emit cycle pays ~one
      request stall, not one per run), merges buffered records up to the
      smallest last-loaded key over still-active runs, and streams merged
      bytes into an incremental multipart upload. Part uploads are
      part-indexed (io/backends.put_part(index, data)) and fan out over
      plan.part_upload_fanout threads per partition, so one partition's
      parts upload out of order and in parallel — S3's UploadPart
      contract — while the object assembles (and CRC-etags) in part
      order at complete(). Reduce merge memory is governed globally:
      with plan.reduce_memory_budget_bytes set, the budget is
      apportioned across the active reducers into per-run chunk sizes,
      and the measured all-reducer peak of decoded merge-buffer bytes
      (reduce_peak_merge_bytes, thread-safe accounting) never exceeds
      it — encoded output parts being sliced/uploaded sit on top, ~
      (1 + max_inflight_writes) x part bytes per active reducer. Output
      bytes are identical at any parallelism (the merge result does not
      depend on the schedule).

Every phase records wall-clock spans (map wait/compute/spill, reduce
fetch/merge/upload) into the report's span timeline, so map/reduce
overlap is measured, not asserted. Every store interaction is
request-accounted, so the Table-2 TCO can be computed from *measured*
GET/PUT counts (core/cost_model.measured_cloudsort_tco, or
.measured_tiered_cloudsort_tco for per-tier legs) instead of the paper's
hardcoded 6M/1M constants.
"""
from __future__ import annotations

import contextlib
import dataclasses
import math
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.exoshuffle import ShuffleConfig
from repro.core.streaming import streaming_sort
from repro.io import records as rec
from repro.io import staging
from repro.io.backends import RetryableError, StoreBackend, StoreStats


@dataclasses.dataclass(frozen=True)
class ExternalSortPlan:
    """Out-of-core schedule: what fits in HBM and how the store is laid out.

    records_per_wave is the device-resident working set — the analogue of
    the paper's (map tasks in flight) x (2 GB block) bound.
    merge_chunk_bytes is the reduce-side counterpart: the per-run fetch
    granularity cap of the streaming merge. parallel_reducers streaming
    merges run concurrently; with reduce_memory_budget_bytes set, the
    global budget is split across them (per-run chunk = budget /
    (parallel_reducers x runs), capped at merge_chunk_bytes), so the
    summed decoded merge-buffer bytes across all active reducers stay
    within the budget — not parallelism x partition size. (The budget
    governs the merge *buffers*; each active reducer additionally holds
    up to ~one encoded output part being sliced plus max_inflight_writes
    parts awaiting upload.)
    """

    records_per_wave: int  # device working set (records, across the mesh)
    num_rounds: int = 2  # streaming_sort rounds within a wave
    reducers_per_worker: int = 1  # R1; R = W * R1 output partitions
    payload_words: int = 4  # u32 payload words per record
    impl: str = "ref"  # kernel implementation ("ref" | "pallas")
    capacity_factor: float = 1.5
    input_prefix: str = "input/"
    spill_prefix: str = "spill/"
    output_prefix: str = "output/"
    input_records_per_partition: int = 1 << 13  # gensort object size
    output_part_records: int = 1 << 13  # multipart-upload part size
    store_chunk_bytes: int = 256 << 10  # map download GET granularity
    merge_chunk_bytes: int = 64 << 10  # reduce per-run fetch granularity (cap)
    prefetch_depth: int = 2  # double buffering
    max_inflight_writes: int = 2  # spill/per-partition part backpressure
    io_retries: int = 2  # staging-level re-reads of a failed wave load
    parallel_reducers: int = 4  # concurrent streaming merges (reduce pool)
    reduce_memory_budget_bytes: int = 0  # global merge budget; 0 = uncapped
    part_upload_fanout: int = 2  # out-of-order part uploads per partition

    @property
    def record_bytes(self) -> int:
        return rec.record_bytes(self.payload_words)


@dataclasses.dataclass(frozen=True)
class Span:
    """One recorded phase interval, seconds relative to the sort start."""

    phase: str  # e.g. "map.compute", "reduce.upload"
    start: float
    end: float
    worker: str = ""  # "w3" map wave / "r12" reducer tag

    @property
    def seconds(self) -> float:
        return self.end - self.start


class PhaseTimeline:
    """Thread-safe span recorder for the per-phase timeline.

    Aggregate per-phase totals are exact; the raw span list is capped at
    `max_spans` (oldest kept) so a huge run cannot hoard memory — the
    report's `spans_dropped` says how many were dropped. Because spans from overlapping
    threads both count wall time, a phase total larger than the enclosing
    stage's wall time is *measured overlap*, which is the point.
    """

    def __init__(self, origin: float, *, max_spans: int = 4096):
        self._origin = origin
        self._lock = threading.Lock()
        self._totals: dict[str, float] = {}
        self._spans: list[Span] = []
        self._max = int(max_spans)
        self.dropped = 0

    def add(self, phase: str, start: float, end: float | None = None,
            *, worker: str = "") -> None:
        end = time.perf_counter() if end is None else end
        span = Span(phase, start - self._origin, end - self._origin, worker)
        with self._lock:
            self._totals[phase] = self._totals.get(phase, 0.0) + span.seconds
            if len(self._spans) < self._max:
                self._spans.append(span)
            else:
                self.dropped += 1

    @contextlib.contextmanager
    def span(self, phase: str, worker: str = ""):
        t = time.perf_counter()
        try:
            yield
        finally:
            self.add(phase, t, worker=worker)

    def totals(self) -> dict[str, float]:
        with self._lock:
            return dict(self._totals)

    def spans(self) -> list[Span]:
        with self._lock:
            return list(self._spans)


class _PeakTracker:
    """Thread-safe global peak of summed per-reducer buffered merge bytes —
    the measurement behind the reduce_memory_budget_bytes guarantee."""

    def __init__(self):
        self._lock = threading.Lock()
        self._per: dict[int, int] = {}
        self._total = 0
        self.peak = 0

    def update(self, rid: int, nbytes: int) -> None:
        with self._lock:
            self._total += nbytes - self._per.get(rid, 0)
            self._per[rid] = nbytes
            if self._total > self.peak:
                self.peak = self._total

    def clear(self, rid: int) -> None:
        with self._lock:
            self._total -= self._per.pop(rid, 0)


@dataclasses.dataclass
class ExternalSortReport:
    """What happened: sizes, timings, and *measured* store traffic."""

    total_records: int
    num_waves: int
    num_workers: int
    num_reducers: int
    spill_objects: int
    output_objects: int
    map_seconds: float
    reduce_seconds: float
    working_set_records: int
    stats: StoreStats  # delta over the sort (map + reduce), all tiers
    runs_per_reducer: int = 0  # k of the streaming k-way merge
    merge_chunk_bytes: int = 0  # the plan's per-run fetch cap
    reduce_chunk_bytes: int = 0  # effective per-run chunk (budget-governed)
    reduce_peak_merge_bytes: int = 0  # measured max across ALL active merges
    parallel_reducers: int = 1  # concurrent merges the scheduler ran
    reduce_memory_budget_bytes: int = 0  # the global governor (0 = none)
    tier_stats: dict[str, StoreStats] | None = None  # per-tier deltas
    spans: list[Span] = dataclasses.field(default_factory=list)
    spans_dropped: int = 0  # spans beyond the recorder cap (totals stay exact)
    phase_seconds: dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def oversubscription(self) -> float:
        """Dataset size / per-wave device working set (>1 = out-of-core)."""
        return self.total_records / self.working_set_records

    @property
    def reduce_memory_bound_bytes(self) -> int:
        """The scheduler's memory guarantee: the global budget when one is
        set, else parallel_reducers x runs x effective chunk (+ one record
        of rounding per run) — reduce_peak_merge_bytes never exceeds it."""
        if self.reduce_memory_budget_bytes:
            return self.reduce_memory_budget_bytes
        chunk = self.reduce_chunk_bytes or self.merge_chunk_bytes
        return self.parallel_reducers * self.runs_per_reducer * chunk

    @property
    def job_hours(self) -> float:
        return (self.map_seconds + self.reduce_seconds) / 3600.0

    @property
    def reduce_hours(self) -> float:
        return self.reduce_seconds / 3600.0


def _spill_key(plan: ExternalSortPlan, wave: int, worker: int) -> str:
    return f"{plan.spill_prefix}wave-{wave:04d}/w-{worker:03d}"


def _output_key(plan: ExternalSortPlan, reducer: int) -> str:
    return f"{plan.output_prefix}part-{reducer:05d}"


def _group_waves(inputs, counts, records_per_wave: int):
    """Tile the key-ordered input objects into equal-record waves.

    ValueError, not assert: the tiling contract must survive python -O —
    a silently mis-tiled wave would sort fine and fail only at valsort.
    """
    waves, cur, acc = [], [], 0
    for meta, c in zip(inputs, counts):
        cur.append(meta)
        acc += c
        if acc > records_per_wave:
            raise ValueError(
                "input partitions must tile records_per_wave exactly "
                f"(partition {meta.key} overflows the wave)"
            )
        if acc == records_per_wave:
            waves.append(cur)
            cur, acc = [], 0
    if cur:
        raise ValueError("total records must be a multiple of records_per_wave")
    return waves


def _contiguous_id_base(ids: np.ndarray) -> int | None:
    """Base id when a wave's ids are exactly arange(base, base + n).

    gensort assigns ids sequentially across key-ordered input partitions
    (data/gensort.write_to_store), so every wave decodes to a contiguous
    ascending id range: the payload row of shuffled record id is then
    just (id - base) — O(1) index arithmetic per record instead of the
    argsort + searchsorted gather (O(n log n), random access). One
    vectorized equality pass verifies the assumption; any other id layout
    falls back to the general gather.
    """
    n = ids.size
    if n == 0:
        return None
    base = int(ids[0])
    # Unwrapped comparison on purpose: a range wrapping past 2^32 would
    # break the (id - base) gather below, so it must take the fallback.
    if base + n - 1 != int(ids[-1]):
        return None
    expect = np.uint32(base) + np.arange(n, dtype=np.uint32)
    if not bool(np.array_equal(np.asarray(ids, dtype=np.uint32), expect)):
        return None
    return base


class _RunCursor:
    """Bounded window over one spilled run's reducer slice.

    Holds at most `chunk_records` decoded records at a time; `refill`
    issues one ranged GET for the next chunk, `take_upto` consumes the
    buffered prefix that is safe to emit (every record <= bound).
    """

    __slots__ = ("_store", "_bucket", "_key", "_hi", "_next", "_chunk",
                 "_pw", "k64", "keys", "ids", "payload")

    def __init__(self, store, bucket, key, lo, hi, payload_words, chunk_records):
        self._store = store
        self._bucket = bucket
        self._key = key
        self._next = int(lo)
        self._hi = int(hi)
        self._chunk = int(chunk_records)
        self._pw = int(payload_words)
        self.keys = np.empty((0,), np.uint32)
        self.ids = np.empty((0,), np.uint32)
        self.payload = None
        self.k64 = np.empty((0,), np.uint64)

    @property
    def has_more_remote(self) -> bool:
        return self._next < self._hi

    @property
    def exhausted(self) -> bool:
        return not self.has_more_remote and self.k64.size == 0

    @property
    def buffered_bytes(self) -> int:
        return self.k64.size * rec.record_bytes(self._pw)

    def refill(self) -> None:
        n = min(self._chunk, self._hi - self._next)
        start, length = rec.body_range(self._next, n, self._pw)
        body = self._store.get_range(self._bucket, self._key, start, length)
        self._next += n
        k, i, p = rec.decode_body(body, self._pw)
        self.keys, self.ids, self.payload = k, i, p
        self.k64 = k.astype(np.uint64) << np.uint64(32) | i.astype(np.uint64)

    def take_upto(self, bound):
        """Consume and return the (keys, ids, payload, k64) prefix with
        k64 <= bound; bound=None consumes everything buffered."""
        cut = self.k64.size if bound is None else int(
            np.searchsorted(self.k64, bound, side="right"))
        out = (self.keys[:cut], self.ids[:cut],
               None if self.payload is None else self.payload[:cut],
               self.k64[:cut])
        self.keys, self.ids = self.keys[cut:], self.ids[cut:]
        self.payload = None if self.payload is None else self.payload[cut:]
        self.k64 = self.k64[cut:]
        return out


def _merge_fragments(frags, payload_words: int):
    """Merge already-sorted fragments (one per run) into one sorted batch.

    Fragment keys are globally unique (key<<32|id with unique ids), so a
    plain stable argsort over the concatenated packed keys is an exact
    k-way merge of the emit window — small (≤ runs x chunk records) by
    construction, which is the whole point of the streaming reduce.
    """
    frags = [f for f in frags if f[3].size]
    if not frags:
        empty = np.empty((0,), np.uint32)
        pw = int(payload_words)
        return empty, empty, (np.empty((0, pw), np.uint32) if pw else None)
    if len(frags) == 1:
        k, i, p, _ = frags[0]
        return k, i, p
    k64 = np.concatenate([f[3] for f in frags])
    order = np.argsort(k64, kind="stable")
    keys = np.concatenate([f[0] for f in frags])[order]
    ids = np.concatenate([f[1] for f in frags])[order]
    payload = None
    if payload_words:
        payload = np.concatenate([f[2] for f in frags])[order]
    return keys, ids, payload


class _SiblingFailed(Exception):
    """Internal: this reducer was cancelled because another one failed."""


def _reduce_chunking(plan: ExternalSortPlan, runs: int,
                     active: int) -> tuple[int, int]:
    """(chunk_records, chunk_bytes) per run under the global budget.

    With a budget, each of the `active` concurrent reducers gets an equal
    share, split over its `runs` cursors and capped at merge_chunk_bytes;
    the all-reducer total active x runs x chunk therefore never exceeds
    the budget. Without one, every cursor buffers merge_chunk_bytes.
    """
    rb = plan.record_bytes
    if plan.merge_chunk_bytes < rb:
        raise ValueError(
            f"merge_chunk_bytes={plan.merge_chunk_bytes} must hold at least "
            f"one {rb}-byte record, else the reduce-memory bound cannot be met"
        )
    chunk_bytes = plan.merge_chunk_bytes
    if plan.reduce_memory_budget_bytes:
        share = plan.reduce_memory_budget_bytes // max(active, 1)
        chunk_bytes = min(chunk_bytes, share // max(runs, 1))
        if chunk_bytes < rb:
            raise ValueError(
                f"reduce_memory_budget_bytes={plan.reduce_memory_budget_bytes}"
                f" cannot give each of {active} concurrent reducers one "
                f"{rb}-byte record per run ({runs} runs each) — raise the "
                "budget or lower parallel_reducers"
            )
    return chunk_bytes // rb, chunk_bytes


def _timed_part(timeline: PhaseTimeline, tag: str, mp, index: int,
                data: bytes) -> None:
    """Background part upload, recorded as a reduce.upload span."""
    t = time.perf_counter()
    mp.put_part(index, data)
    timeline.add("reduce.upload", t, worker=tag)


def _finalize_session(timeline: PhaseTimeline, tag: str,
                      uploader: staging.AsyncWriter, mp) -> None:
    """Background session finisher: wait for the partition's in-flight
    parts, then commit — or abort on any failure (a truncated commit
    would carry a self-consistent CRC etag IntegrityError can't catch).
    Running this off the merge thread is what lets a reducer's scheduler
    slot free while its tail uploads still stream (partition r's uploads
    overlap partition r+active's merge even at parallel_reducers=1)."""
    t = time.perf_counter()
    try:
        uploader.close()  # waits all parts; re-raises the first failure
    except BaseException:
        mp.abort()
        raise
    try:
        mp.complete()
    except BaseException:
        mp.abort()
        raise
    finally:
        timeline.add("reduce.upload_wait", t, worker=tag)


def _timed_spill(timeline: PhaseTimeline, tag: str, store, bucket: str,
                 key: str, data: bytes, metadata: dict) -> None:
    """Background spill put, recorded as a map.spill span."""
    t = time.perf_counter()
    store.put(bucket, key, data, metadata=metadata)
    timeline.add("map.spill", t, worker=tag)


def external_sort(
    store: StoreBackend,
    bucket: str,
    *,
    mesh: jax.sharding.Mesh,
    axis_names: Sequence[str] | str,
    plan: ExternalSortPlan,
) -> ExternalSortReport:
    """Sort every record under plan.input_prefix into plan.output_prefix.

    `store` is any io/backends.StoreBackend — the plain ObjectStore, a
    fault-injected middleware stack, or a TieredStore (in which case the
    report carries per-tier request deltas). Input objects must be
    io/records-encoded with plan.payload_words words of payload and
    globally unique ids (data/gensort.write_to_store's layout). Returns
    the run report; validate the output with data/valsort.validate_from_store.
    """
    axis = tuple([axis_names] if isinstance(axis_names, str) else axis_names)
    w = int(math.prod(mesh.shape[a] for a in axis))
    pw = plan.payload_words
    r1 = plan.reducers_per_worker
    cfg = ShuffleConfig(
        num_workers=w,
        reducers_per_worker=r1,
        capacity_factor=plan.capacity_factor,
        num_rounds=plan.num_rounds,
        impl=plan.impl,
    )
    if plan.records_per_wave % (w * plan.num_rounds) != 0:
        # ValueError, not assert: plan validation must survive python -O.
        raise ValueError(
            "records_per_wave must divide evenly into per-worker rounds"
        )
    if plan.parallel_reducers < 1:
        raise ValueError(f"parallel_reducers must be >= 1, "
                         f"got {plan.parallel_reducers}")
    if plan.part_upload_fanout < 1:
        raise ValueError(f"part_upload_fanout must be >= 1, "
                         f"got {plan.part_upload_fanout}")

    inputs = store.list_objects(bucket, plan.input_prefix)
    if not inputs:
        raise ValueError(f"no input objects under {plan.input_prefix!r}")
    counts = [(m.size - rec.HEADER_BYTES) // plan.record_bytes for m in inputs]
    total = sum(counts)
    waves = _group_waves(inputs, counts, plan.records_per_wave)
    num_waves = len(waves)
    num_reducers = w * r1
    active = min(plan.parallel_reducers, num_reducers)
    # Budget feasibility is pure plan validation — fail here, before any
    # map wave is fetched/sorted/spilled (and billed), not after.
    chunk_records, chunk_bytes = _reduce_chunking(plan, num_waves, active)
    # Overwrite semantics: clear stale spill/output objects from any prior
    # run so the reduce pass and downstream validation see only this run.
    for prefix in (plan.spill_prefix, plan.output_prefix):
        for meta in store.list_objects(bucket, prefix):
            store.delete(bucket, meta.key)
    base_stats = store.stats_snapshot()
    tier_base = (store.per_tier_stats()
                 if hasattr(store, "per_tier_stats") else None)

    sort_wave = jax.jit(
        lambda k, i: streaming_sort(
            k, i, mesh=mesh, axis_names=axis_names,
            num_rounds=plan.num_rounds, cfg=cfg,
        )
    )

    # ---- map waves: stream in (zero-copy) -> sort -> spill runs -------
    def load_wave(objs):
        # One preallocated rows buffer for the whole wave; every chunk is
        # copied exactly once, into its final interleaved position.
        n_wave = sum(
            (m.size - rec.HEADER_BYTES) // plan.record_bytes for m in objs)
        rows = rec.alloc_rows(n_wave, pw)
        at = 0
        for m in objs:
            dec = rec.StreamDecoder(rows, at, what=m.key)
            for chunk in store.get_chunks(bucket, m.key, plan.store_chunk_bytes):
                dec.feed(chunk)
            at += dec.finish()
        return rec.split_rows(rows)

    local_bounds = (
        np.asarray(cfg.keyspace.local_reducer_boundaries()) if r1 > 1 else None
    )  # (W, R1-1)
    spill_offsets: dict[tuple[int, int], np.ndarray] = {}
    t0 = time.perf_counter()
    timeline = PhaseTimeline(origin=t0)
    with staging.AsyncWriter(plan.max_inflight_writes) as spiller:
        wave_loads = (lambda objs=objs: load_wave(objs) for objs in waves)
        wave_iter = iter(staging.prefetch(
            wave_loads, depth=plan.prefetch_depth,
            retries=plan.io_retries, retry_on=(RetryableError,)))
        g = 0
        while True:
            t_wait = time.perf_counter()
            try:
                keys, ids, payload = next(wave_iter)
            except StopIteration:
                break
            tag = f"g{g}"
            timeline.add("map.wait", t_wait, worker=tag)
            t_comp = time.perf_counter()
            sk, si, vcounts, ovf = sort_wave(jnp.asarray(keys), jnp.asarray(ids))
            sk, si, vcounts = np.asarray(sk), np.asarray(si), np.asarray(vcounts)
            if bool(np.asarray(ovf)):
                raise RuntimeError(
                    "shuffle block overflow — raise capacity_factor"
                )
            # id -> wave row for gathering payload of shuffled records:
            # O(1) offset arithmetic when the wave's ids are contiguous
            # (the gensort layout), argsort gather otherwise.
            id_base = _contiguous_id_base(ids) if pw else None
            order = sorted_ids = None
            if pw and id_base is None:
                order = np.argsort(ids)
                sorted_ids = ids[order]
            seg = sk.shape[0] // w
            for wid in range(w):
                n = int(vcounts[wid])
                run_k = sk[wid * seg : wid * seg + n]
                run_i = si[wid * seg : wid * seg + n]
                run_p = None
                if pw:
                    if id_base is not None:
                        sel = run_i.astype(np.int64) - id_base
                    else:
                        sel = order[np.searchsorted(sorted_ids, run_i)]
                    run_p = payload[sel]
                if local_bounds is not None:
                    internal = np.searchsorted(run_k, local_bounds[wid], side="left")
                else:
                    internal = np.empty((0,), np.int64)
                offsets = np.concatenate(([0], internal, [n])).astype(np.int64)
                spill_offsets[(g, wid)] = offsets
                data = rec.encode_records(run_k, run_i, run_p)
                # Submit each encoded run immediately: the AsyncWriter
                # backpressure bound (at most max_inflight encoded runs
                # in host memory) only holds if we never batch them.
                timeline.add("map.compute", t_comp, worker=tag)
                t_spill = time.perf_counter()
                spiller.submit(_timed_spill, timeline, tag, store, bucket,
                               _spill_key(plan, g, wid), data, {
                                   "records": n,
                                   "wave": g,
                                   "worker": wid,
                                   "reducer_offsets": [int(o) for o in offsets],
                               })
                timeline.add("map.spill_wait", t_spill, worker=tag)
                t_comp = time.perf_counter()
            timeline.add("map.compute", t_comp, worker=tag)
            g += 1
    map_seconds = time.perf_counter() - t0

    # ---- reduce: parallel scheduler over streaming k-way merges -------
    # Memory contract: parallel_reducers merges run concurrently, each of
    # their (≤ num_waves) run cursors buffering at most chunk_bytes of
    # decoded records, where chunk_bytes is apportioned from the global
    # reduce_memory_budget_bytes when one is set (see _reduce_chunking).
    # The emit window is merged and encoded immediately; completed output
    # parts fan out over part_upload_fanout threads per partition as
    # part-indexed out-of-order uploads. Output bytes are independent of
    # the schedule — partitions are independent objects and part payloads
    # are sliced at fixed output_part_records boundaries — so any
    # parallelism yields byte-identical (and etag-identical) partitions.
    # (num_waves / active / chunk_records were derived up front, with the
    # other plan validation.)
    part_bytes = plan.output_part_records * plan.record_bytes
    peak = _PeakTracker()
    cancel = threading.Event()
    fail_lock = threading.Lock()
    first_fail: list[BaseException] = []

    def run_cursors(r: int) -> tuple[list[_RunCursor], int]:
        wid, j = divmod(r, r1)
        cursors, n_total = [], 0
        for g in range(num_waves):
            offs = spill_offsets[(g, wid)]
            lo, hi = int(offs[j]), int(offs[j + 1])
            if hi > lo:
                cursors.append(_RunCursor(
                    store, bucket, _spill_key(plan, g, wid),
                    lo, hi, pw, chunk_records))
                n_total += hi - lo
        return cursors, n_total

    def reduce_one(r: int) -> None:
        tag = f"r{r}"
        cursors, n_total = run_cursors(r)
        mp = store.multipart(bucket, _output_key(plan, r),
                             metadata={"records": n_total, "reducer": r})
        # max_inflight >= fanout, or the backpressure semaphore would
        # silently cap concurrent part uploads below the fan-out width.
        uploader = staging.AsyncWriter(
            max(plan.max_inflight_writes, plan.part_upload_fanout),
            max_workers=plan.part_upload_fanout)
        next_part = 0

        def submit_part(data: bytes) -> None:
            nonlocal next_part
            idx, next_part = next_part, next_part + 1
            t = time.perf_counter()  # blocks under upload backpressure
            uploader.submit(_timed_part, timeline, tag, mp, idx, data)
            timeline.add("reduce.upload_wait", t, worker=tag)

        try:
            # Record count is known up front (sum of run-slice
            # lengths), so the header streams first, body follows.
            outbuf = bytearray(rec.encode_header(n_total, pw))
            while cursors:
                if cancel.is_set():
                    raise _SiblingFailed()
                need = [c for c in cursors
                        if c.k64.size == 0 and c.has_more_remote]
                if need:
                    t = time.perf_counter()
                    if len(need) == 1:
                        need[0].refill()
                    else:  # concurrent ranged GETs: one RTT per cycle
                        list(refill_pool.map(_RunCursor.refill, need))
                    timeline.add("reduce.fetch", t, worker=tag)
                peak.update(r, sum(c.buffered_bytes for c in cursors))
                t = time.perf_counter()
                # Safe emit bound: the smallest last-buffered key among
                # runs that still have un-fetched records — nothing
                # later can sort below it. When no run has remote data
                # left, everything buffered is emittable.
                remote_tails = [c.k64[-1] for c in cursors
                                if c.has_more_remote]
                bound = min(remote_tails) if remote_tails else None
                frags = [c.take_upto(bound) for c in cursors]
                cursors = [c for c in cursors if not c.exhausted]
                mk, mi, mpay = _merge_fragments(frags, pw)
                if mk.size:
                    outbuf += rec.encode_body(mk, mi, mpay)
                timeline.add("reduce.merge", t, worker=tag)
                while len(outbuf) >= part_bytes:
                    submit_part(bytes(outbuf[:part_bytes]))
                    del outbuf[:part_bytes]
            # >= 1 part always: an empty partition still has a header.
            if outbuf or n_total == 0:
                submit_part(bytes(outbuf))
        except BaseException:
            # Merge or upload died mid-session: let in-flight parts
            # settle, then discard the session — never commit it.
            try:
                uploader.drain()
            except BaseException:
                pass
            try:
                mp.abort()
            finally:
                peak.clear(r)
                uploader.close()
            raise
        # Success: hand drain + complete to the finisher queue so this
        # scheduler slot frees while the tail parts still upload —
        # finishers.submit blocks once max(max_inflight_writes, active)
        # sessions await completion (cross-partition upload backpressure).
        peak.clear(r)
        finishers.submit(_finalize_session, timeline, tag, uploader, mp)

    def run_reducer(r: int) -> None:
        if cancel.is_set():
            return
        try:
            reduce_one(r)
        except _SiblingFailed:
            pass  # this partition was aborted cleanly; root cause is queued
        except BaseException as e:
            with fail_lock:
                if not first_fail:
                    first_fail.append(e)
            cancel.set()

    t0 = time.perf_counter()
    refill_pool = ThreadPoolExecutor(
        max_workers=min(16, max(2, num_waves * active)),
        thread_name_prefix="reduce-refill")
    finishers = staging.AsyncWriter(
        max(plan.max_inflight_writes, active), max_workers=active,
        thread_name_prefix="reduce-finish")
    try:
        with ThreadPoolExecutor(max_workers=active,
                                thread_name_prefix="reduce-merge") as sched:
            for f in [sched.submit(run_reducer, r) for r in range(num_reducers)]:
                f.result()  # never raises: run_reducer records failures
    finally:
        refill_pool.shutdown(wait=True)
        try:
            finishers.close()  # re-raises the first finisher failure
        except BaseException as e:
            with fail_lock:
                if not first_fail:
                    first_fail.append(e)
    if first_fail:
        raise first_fail[0]
    reduce_seconds = time.perf_counter() - t0

    tier_stats = None
    if tier_base is not None:
        tier_now = store.per_tier_stats()
        tier_stats = {name: tier_now[name] - tier_base[name]
                      for name in tier_now}
    return ExternalSortReport(
        total_records=total,
        num_waves=num_waves,
        num_workers=w,
        num_reducers=num_reducers,
        spill_objects=num_waves * w,
        output_objects=num_reducers,
        map_seconds=map_seconds,
        reduce_seconds=reduce_seconds,
        working_set_records=plan.records_per_wave,
        stats=store.stats_snapshot() - base_stats,
        runs_per_reducer=num_waves,
        merge_chunk_bytes=plan.merge_chunk_bytes,
        reduce_chunk_bytes=chunk_bytes,
        reduce_peak_merge_bytes=peak.peak,
        parallel_reducers=active,
        reduce_memory_budget_bytes=plan.reduce_memory_budget_bytes,
        tier_stats=tier_stats,
        spans=timeline.spans(),
        spans_dropped=timeline.dropped,
        phase_seconds=timeline.totals(),
    )
