"""Out-of-core external sort: the dataset lives in the object store, not HBM.

This is the driver that lets the reproduction actually *pose* the CloudSort
problem (paper §2.3–§2.5): total dataset size is bounded by object-store
capacity, while device memory holds only one map wave's working set.

Paper mapping:

  map waves (§2.3, §2.5): input partitions stream from the store in ranged
      chunks (io/object_store.get_chunks — one GET per chunk, the paper's
      "120 chunks" map download), double-buffered against device compute
      (io/staging.prefetch). Each wave runs the in-memory two-stage
      streaming exoshuffle (core/streaming.py), after which every worker
      holds one globally range-partitioned sorted run.

  spill (§2.3): each worker's merged run is written back to the store as
      one sorted run object — the paper spills to local SSD; we spill to
      the store so the spill survives worker death and is addressable by
      the reduce pass. Per-reducer offsets into the run are recorded in
      the object's manifest metadata, write-behind via io/staging.AsyncWriter
      so upload overlaps the next wave's sort.

  reduce (§2.4): output partition r k-way merges its slice of every
      spilled run. Each slice is fetched with ONE ranged GET (the
      interleaved record layout of io/records makes a record range a byte
      range), merged with kernels/merge_sorted via ops.kway_merge, and
      uploaded as a multipart object (one PUT per part — the paper's "40
      chunks" reduce upload). Fetch of partition r+1 overlaps the merge of
      partition r.

Every store interaction is request-accounted, so the Table-2 TCO can be
computed from *measured* GET/PUT counts (core/cost_model.measured_cloudsort_tco)
instead of the paper's hardcoded 6M/1M constants.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import payload as pay
from repro.core.exoshuffle import ShuffleConfig
from repro.core.streaming import streaming_sort
from repro.io import records as rec
from repro.io import staging
from repro.io.object_store import ObjectStore, StoreStats
from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class ExternalSortPlan:
    """Out-of-core schedule: what fits in HBM and how the store is laid out.

    records_per_wave is the device-resident working set — the analogue of
    the paper's (map tasks in flight) x (2 GB block) bound. Total dataset
    size / records_per_wave = the out-of-core oversubscription factor.
    """

    records_per_wave: int  # device working set (records, across the mesh)
    num_rounds: int = 2  # streaming_sort rounds within a wave
    reducers_per_worker: int = 1  # R1; R = W * R1 output partitions
    payload_words: int = 4  # u32 payload words per record
    impl: str = "ref"  # kernel implementation ("ref" | "pallas")
    capacity_factor: float = 1.5
    input_prefix: str = "input/"
    spill_prefix: str = "spill/"
    output_prefix: str = "output/"
    input_records_per_partition: int = 1 << 13  # gensort object size
    output_part_records: int = 1 << 13  # multipart-upload part size
    store_chunk_bytes: int = 256 << 10  # map download GET granularity
    prefetch_depth: int = 2  # double buffering
    max_inflight_writes: int = 2  # spill/upload backpressure

    @property
    def record_bytes(self) -> int:
        return rec.record_bytes(self.payload_words)


@dataclasses.dataclass
class ExternalSortReport:
    """What happened: sizes, timings, and *measured* store traffic."""

    total_records: int
    num_waves: int
    num_workers: int
    num_reducers: int
    spill_objects: int
    output_objects: int
    map_seconds: float
    reduce_seconds: float
    working_set_records: int
    stats: StoreStats  # delta over the sort (map + reduce)

    @property
    def oversubscription(self) -> float:
        """Dataset size / per-wave device working set (>1 = out-of-core)."""
        return self.total_records / self.working_set_records

    @property
    def job_hours(self) -> float:
        return (self.map_seconds + self.reduce_seconds) / 3600.0

    @property
    def reduce_hours(self) -> float:
        return self.reduce_seconds / 3600.0


def _spill_key(plan: ExternalSortPlan, wave: int, worker: int) -> str:
    return f"{plan.spill_prefix}wave-{wave:04d}/w-{worker:03d}"


def _output_key(plan: ExternalSortPlan, reducer: int) -> str:
    return f"{plan.output_prefix}part-{reducer:05d}"


def _group_waves(inputs, counts, records_per_wave: int):
    """Tile the key-ordered input objects into equal-record waves."""
    waves, cur, acc = [], [], 0
    for meta, c in zip(inputs, counts):
        cur.append(meta)
        acc += c
        assert acc <= records_per_wave, (
            "input partitions must tile records_per_wave exactly "
            f"(partition {meta.key} overflows the wave)"
        )
        if acc == records_per_wave:
            waves.append(cur)
            cur, acc = [], 0
    assert not cur, "total records must be a multiple of records_per_wave"
    return waves


def _merge_spilled_runs(runs, payload_words: int, impl: str):
    """k-way merge sorted runs [(keys, ids, payload), ...] -> valid arrays.

    Runs are padded to a (K, L) power-of-two grid of lex-max records and
    merged with the same kernels/merge_sorted tournament the in-memory
    reduce uses; payload rows are re-aligned by id join afterwards
    (core/payload.align_payload_to_merge) instead of riding through every
    compare-exchange.
    """
    pw = int(payload_words)
    if not runs:
        empty = np.empty((0,), np.uint32)
        return empty, empty, (np.empty((0, pw), np.uint32) if pw else None)
    k_grid = ops.next_pow2(len(runs))
    run_len = max(ops.next_pow2(max(len(r[0]) for r in runs)), 1)
    kk = np.full((k_grid, run_len), 0xFFFFFFFF, np.uint32)
    ii = np.full((k_grid, run_len), 0xFFFFFFFF, np.uint32)
    pp = np.zeros((k_grid, run_len, pw), np.uint32) if pw else None
    valid = 0
    for t, (k, i, p) in enumerate(runs):
        kk[t, : len(k)] = k
        ii[t, : len(k)] = i
        if pw:
            pp[t, : len(k)] = p
        valid += len(k)
    mk, mv = ops.kway_merge(jnp.asarray(kk), jnp.asarray(ii), impl=impl)
    out_p = None
    if pw:
        aligned = pay.align_payload_to_merge(
            jnp.asarray(ii.reshape(-1)), jnp.asarray(pp.reshape(-1, pw)), mv
        )
        out_p = np.asarray(aligned[:valid])
    return np.asarray(mk[:valid]), np.asarray(mv[:valid]), out_p


def external_sort(
    store: ObjectStore,
    bucket: str,
    *,
    mesh: jax.sharding.Mesh,
    axis_names: Sequence[str] | str,
    plan: ExternalSortPlan,
) -> ExternalSortReport:
    """Sort every record under plan.input_prefix into plan.output_prefix.

    Input objects must be io/records-encoded with plan.payload_words words
    of payload and globally unique ids (data/gensort.write_to_store's
    layout). Returns the run report; validate the output with
    data/valsort.validate_from_store.
    """
    axis = tuple([axis_names] if isinstance(axis_names, str) else axis_names)
    w = int(math.prod(mesh.shape[a] for a in axis))
    pw = plan.payload_words
    r1 = plan.reducers_per_worker
    cfg = ShuffleConfig(
        num_workers=w,
        reducers_per_worker=r1,
        capacity_factor=plan.capacity_factor,
        num_rounds=plan.num_rounds,
        impl=plan.impl,
    )
    assert plan.records_per_wave % (w * plan.num_rounds) == 0, (
        "records_per_wave must divide evenly into per-worker rounds"
    )

    inputs = store.list_objects(bucket, plan.input_prefix)
    assert inputs, f"no input objects under {plan.input_prefix!r}"
    counts = [(m.size - rec.HEADER_BYTES) // plan.record_bytes for m in inputs]
    total = sum(counts)
    waves = _group_waves(inputs, counts, plan.records_per_wave)
    # Overwrite semantics: clear stale spill/output objects from any prior
    # run so the reduce pass and downstream validation see only this run.
    for prefix in (plan.spill_prefix, plan.output_prefix):
        for meta in store.list_objects(bucket, prefix):
            store.delete(bucket, meta.key)
    base_stats = store.stats_snapshot()

    sort_wave = jax.jit(
        lambda k, i: streaming_sort(
            k, i, mesh=mesh, axis_names=axis_names,
            num_rounds=plan.num_rounds, cfg=cfg,
        )
    )

    # ---- map waves: stream in -> sort -> spill runs -------------------
    def load_wave(objs):
        ks, ids, ps = [], [], []
        for m in objs:
            data = b"".join(store.get_chunks(bucket, m.key, plan.store_chunk_bytes))
            k, i, p = rec.decode_records(data)
            ks.append(k)
            ids.append(i)
            if pw:
                ps.append(p)
        return (
            np.concatenate(ks),
            np.concatenate(ids),
            np.concatenate(ps) if pw else None,
        )

    local_bounds = (
        np.asarray(cfg.keyspace.local_reducer_boundaries()) if r1 > 1 else None
    )  # (W, R1-1)
    spill_offsets: dict[tuple[int, int], np.ndarray] = {}
    t0 = time.perf_counter()
    with staging.AsyncWriter(plan.max_inflight_writes) as spiller:
        wave_loads = (lambda objs=objs: load_wave(objs) for objs in waves)
        for g, (keys, ids, payload) in enumerate(
            staging.prefetch(wave_loads, depth=plan.prefetch_depth)
        ):
            sk, si, vcounts, ovf = sort_wave(jnp.asarray(keys), jnp.asarray(ids))
            sk, si, vcounts = np.asarray(sk), np.asarray(si), np.asarray(vcounts)
            if bool(np.asarray(ovf)):
                raise RuntimeError(
                    "shuffle block overflow — raise capacity_factor"
                )
            # id -> wave row, for gathering payload of shuffled records.
            order = np.argsort(ids)
            sorted_ids = ids[order]
            seg = sk.shape[0] // w
            for wid in range(w):
                n = int(vcounts[wid])
                run_k = sk[wid * seg : wid * seg + n]
                run_i = si[wid * seg : wid * seg + n]
                run_p = None
                if pw:
                    rows = order[np.searchsorted(sorted_ids, run_i)]
                    run_p = payload[rows]
                if local_bounds is not None:
                    internal = np.searchsorted(run_k, local_bounds[wid], side="left")
                else:
                    internal = np.empty((0,), np.int64)
                offsets = np.concatenate(([0], internal, [n])).astype(np.int64)
                spill_offsets[(g, wid)] = offsets
                spiller.submit(
                    store.put,
                    bucket,
                    _spill_key(plan, g, wid),
                    rec.encode_records(run_k, run_i, run_p),
                    metadata={
                        "records": n,
                        "wave": g,
                        "worker": wid,
                        "reducer_offsets": [int(o) for o in offsets],
                    },
                )
    map_seconds = time.perf_counter() - t0

    # ---- reduce: ranged-GET run slices -> k-way merge -> multipart up --
    num_waves = len(waves)
    num_reducers = w * r1

    def fetch_reducer(r: int):
        wid, j = divmod(r, r1)
        runs = []
        for g in range(num_waves):
            offs = spill_offsets[(g, wid)]
            lo, hi = int(offs[j]), int(offs[j + 1])
            if hi > lo:
                start, length = rec.body_range(lo, hi - lo, pw)
                body = store.get_range(bucket, _spill_key(plan, g, wid), start, length)
                runs.append(rec.decode_body(body, pw))
        return runs

    part_bytes = plan.output_part_records * plan.record_bytes
    t0 = time.perf_counter()
    with staging.AsyncWriter(plan.max_inflight_writes) as uploader:
        fetches = (lambda r=r: fetch_reducer(r) for r in range(num_reducers))
        for r, runs in enumerate(staging.prefetch(fetches, depth=plan.prefetch_depth)):
            mk, mi, mp = _merge_spilled_runs(runs, pw, plan.impl)
            data = rec.encode_records(mk, mi, mp)
            # >= 1 part always: even an empty partition has the 16-B header.
            parts = [data[o : o + part_bytes] for o in range(0, len(data), part_bytes)]
            uploader.submit(
                store.put_multipart,
                bucket,
                _output_key(plan, r),
                parts,
                metadata={"records": len(mk), "reducer": r},
            )
    reduce_seconds = time.perf_counter() - t0

    return ExternalSortReport(
        total_records=total,
        num_waves=num_waves,
        num_workers=w,
        num_reducers=num_reducers,
        spill_objects=num_waves * w,
        output_objects=num_reducers,
        map_seconds=map_seconds,
        reduce_seconds=reduce_seconds,
        working_set_records=plan.records_per_wave,
        stats=store.stats_snapshot() - base_stats,
    )
