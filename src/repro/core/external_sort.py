"""Out-of-core external sort: the dataset lives in the object store, not HBM.

This module is the CloudSort *workload*: the wave/spill layout, the
device-mesh map body (WaveSorter), and the ExternalSortPlan schedule.
Since the shuffle-as-a-library refactor, the generic machinery that used
to live here — span timelines, job control, the AdaptiveBudgetGovernor,
streaming run cursors, the reduce scheduler, the staged map loop, the
single-host/cluster drivers — is the library (src/repro/shuffle/), and
the sort is one instantiation of it:

    from repro.shuffle.sort import sort_shuffle_job
    report = sort_shuffle_job(store, bucket, mesh=mesh, axis_names="w",
                              plan=plan).run(workers=N)

`external_sort()` below is kept as a thin deprecated shim over exactly
that call (workers=0), byte- and etag-identical to the pre-refactor
driver; core/cluster.cluster_external_sort is the cluster-mode shim.

Paper mapping (unchanged by the refactor):

  map waves (§2.3, §2.5): input partitions stream from the store in ranged
      chunks (io/backends.get_chunks — one GET per chunk, the paper's
      "120 chunks" map download), double-buffered against device compute
      (io/staging.prefetch, retry-aware against transient store stalls).
      Wave assembly is zero-copy: each chunk decodes straight into one
      preallocated interleaved-row buffer (io/records.StreamDecoder), so
      a wave's bytes are copied once off the wire instead of through
      b"".join + np.concatenate staging copies. Each wave runs the
      in-memory two-stage streaming exoshuffle (core/streaming.py), after
      which every worker holds one globally range-partitioned sorted run;
      shuffled payload rows are located by O(1) id-offset arithmetic
      (gensort ids are contiguous per wave) instead of a per-wave argsort.

  spill (§2.3): each worker's merged run is written back under
      plan.spill_prefix as one sorted run object. Against a TieredStore
      (io/tiered.py) that prefix routes to the local-SSD tier — the
      paper's actual spill target — while input/output keys stay on the
      durable (S3-like, throttled, billed) tier. Per-reducer offsets into
      the run are recorded in the object's manifest metadata; writes are
      write-behind via io/staging.AsyncWriter so upload overlaps the next
      wave's sort.

  reduce (§2.4): a scheduler runs up to plan.parallel_reducers streaming
      k-way merges CONCURRENTLY per worker (shuffle/runtime.ReduceScheduler
      driving shuffle/sort.MergeReduceOp) — the paper's "all output
      partitions at once" reduce stage, the scheduling freedom
      shuffle-as-a-library buys (Exoshuffle §4). Each active reducer
      fetches its slice of every spilled run in bounded ranged chunks,
      merges buffered records up to the smallest last-loaded key over
      still-active runs, and streams merged bytes into an incremental
      multipart upload fanned out over plan.part_upload_fanout threads.

Plan knobs and their invariants (the reduce-side memory/throughput
contract; see ExternalSortPlan for the map-side knobs):

  parallel_reducers — number of streaming k-way merges one scheduler runs
      concurrently. Output bytes are schedule-independent: partitions are
      independent objects and part payloads are sliced at fixed
      output_part_records boundaries, so ANY parallelism (and any cluster
      worker count) yields byte- and etag-identical partitions.

  part_upload_fanout — out-of-order part-indexed multipart uploads in
      flight per partition (S3 UploadPart semantics; assembly order is
      decided by part index at complete(), never by wire order).

  merge_chunk_bytes — hard CAP on the per-run fetch granularity of the
      streaming merge. Without a budget every cursor buffers at most this
      many decoded bytes, so per-merge peak <= runs x merge_chunk_bytes.

  reduce_memory_budget_bytes — global decoded-merge-buffer budget across
      ALL concurrently active reducers (0 = uncapped). Apportionment is
      ADAPTIVE (shuffle/runtime.AdaptiveBudgetGovernor): each registering
      reducer starts from the static fair share budget/slots, and as
      reducers retire their share is re-apportioned to still-active
      merges — chunk sizes grow mid-merge (up to merge_chunk_bytes), so
      tail stragglers fetch bigger chunks instead of leaving freed budget
      idle. The invariant is provable, not just measured — see the
      governor's docstring — and the measured all-reducer peak
      (reduce_peak_merge_bytes) never exceeds the budget.

Every phase records wall-clock spans (map wait/compute/spill, reduce
fetch/merge/upload) into the report's span timeline, so map/reduce
overlap is measured, not asserted. Every store interaction is
request-accounted, so the Table-2 TCO can be computed from *measured*
GET/PUT counts (core/cost_model.measured_cloudsort_tco, or
.measured_tiered_cloudsort_tco for per-tier legs) instead of the paper's
hardcoded 6M/1M constants.
"""
from __future__ import annotations

import dataclasses
import math
import threading
import time
import warnings
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.exoshuffle import ShuffleConfig
from repro.core.streaming import streaming_sort
from repro.io import records as rec
from repro.io import staging
from repro.io.backends import StoreBackend
from repro.shuffle import runtime as _rt
from repro.shuffle.api import (ShuffleReport, require,
                               validate_dataflow_plan)

# Backwards-compatible re-exports: this machinery moved to the shuffle
# library (shuffle/runtime.py) when the generic dataflow API was carved
# out; the old names keep working for existing callers.
Span = _rt.Span
PhaseTimeline = _rt.PhaseTimeline
JobControl = _rt.JobControl
AdaptiveBudgetGovernor = _rt.AdaptiveBudgetGovernor
ReduceShared = _rt.ReduceShared
ReduceScheduler = _rt.ReduceScheduler
_PeakTracker = _rt.PeakTracker
_RunCursor = _rt.RunCursor
_SiblingFailed = _rt.SiblingFailed
_reduce_chunking = _rt.reduce_chunking
_merge_fragments = _rt.merge_fragments
_timed_part = _rt.timed_part
_timed_spill = _rt.timed_put
_finalize_session = _rt.finalize_session

#: The run report (renamed ShuffleReport when the library was carved
#: out — same fields, every workload reports through it).
ExternalSortReport = ShuffleReport


@dataclasses.dataclass(frozen=True)
class ExternalSortPlan:
    """Out-of-core schedule: what fits in HBM and how the store is laid out.

    records_per_wave is the device-resident working set — the analogue of
    the paper's (map tasks in flight) x (2 GB block) bound.
    merge_chunk_bytes is the reduce-side counterpart: the per-run fetch
    granularity cap of the streaming merge. parallel_reducers streaming
    merges run concurrently; with reduce_memory_budget_bytes set, the
    global budget is apportioned across them by the adaptive governor
    (initial per-run chunk = budget / (slots x runs), capped at
    merge_chunk_bytes, growing as reducers retire), so the summed decoded
    merge-buffer bytes across all active reducers stay within the budget
    — not parallelism x partition size. (The budget governs the merge
    *buffers*; each active reducer additionally holds up to ~one encoded
    output part being sliced plus max_inflight_writes parts awaiting
    upload.)

    map_pipeline overlaps each wave's host decode, device sort, and
    spill encode across tasks (shuffle/runtime's staged map executor);
    spill bytes and offsets are identical either way — the knob only
    changes wall-clock concurrency. reduce_merge_impl selects the
    emit-window merge backend: "numpy" is the reference host argsort
    merge; "device" runs the kernels/kway_merge bitonic tournament on a
    one-thread merge stage, double-buffered so window i's merge+encode
    overlaps window i+1's ranged-GET fetches (byte/etag-identical
    output, one extra in-flight window of decoded fragments on top of
    the governor's accounting). The device merge's kernel lowering
    follows `impl` ("pallas" = the Pallas network, jit-compiled on CPU;
    "ref" = the lax.sort oracle).
    """

    records_per_wave: int  # device working set (records, across the mesh)
    num_rounds: int = 2  # streaming_sort rounds within a wave
    reducers_per_worker: int = 1  # R1; R = W * R1 output partitions
    payload_words: int = 4  # u32 payload words per record
    impl: str = "ref"  # kernel implementation ("ref" | "pallas")
    capacity_factor: float = 1.5
    input_prefix: str = "input/"
    spill_prefix: str = "spill/"
    output_prefix: str = "output/"
    input_records_per_partition: int = 1 << 13  # gensort object size
    output_part_records: int = 1 << 13  # multipart-upload part size
    store_chunk_bytes: int = 256 << 10  # map download GET granularity
    merge_chunk_bytes: int = 64 << 10  # reduce per-run fetch granularity (cap)
    prefetch_depth: int = 2  # double buffering
    max_inflight_writes: int = 2  # spill/per-partition part backpressure
    io_retries: int = 2  # staging-level re-reads of a failed wave load
    parallel_reducers: int = 4  # concurrent streaming merges (reduce pool)
    reduce_memory_budget_bytes: int = 0  # global merge budget; 0 = uncapped
    part_upload_fanout: int = 2  # out-of-order part uploads per partition
    map_pipeline: bool = True  # overlap decode/device-sort/encode across waves
    reduce_merge_impl: str = "numpy"  # emit-window merge ("numpy" | "device")
    # Skew-adaptive knobs, consumed by shuffle/recursive.recursive_sort:
    # sample_fraction > 0 runs a sampling pre-pass (ranged GETs over that
    # fraction of input records, traced/billed as phase "sample") whose
    # quantiles become the partition boundaries; max_rounds > 1 allows
    # partitions whose merged size exceeds reduce_memory_budget_bytes to
    # be re-shuffled by the next key bits as composed child ShuffleJobs.
    sample_fraction: float = 0.0  # fraction of input records to sample
    max_rounds: int = 1  # recursive shuffle depth (1 = single pass)

    @property
    def record_bytes(self) -> int:
        return rec.record_bytes(self.payload_words)

    def validate(self) -> None:
        """Mesh-independent plan validation (ValueError with the knob
        name and value — never an assert). The mesh-dependent checks
        (wave/round divisibility) run in WaveSorter, which knows the
        mesh width."""
        validate_dataflow_plan(self)
        require(self.records_per_wave >= 1, "records_per_wave",
                self.records_per_wave, "must hold >= 1 record per wave")
        require(self.num_rounds >= 1, "num_rounds", self.num_rounds,
                "must run >= 1 streaming round per wave")
        require(self.reducers_per_worker >= 1, "reducers_per_worker",
                self.reducers_per_worker, "must be >= 1 (R1)")
        require(self.input_records_per_partition >= 1,
                "input_records_per_partition",
                self.input_records_per_partition, "must be >= 1")
        require(self.capacity_factor > 0, "capacity_factor",
                self.capacity_factor, "must be > 0")
        require(self.reduce_merge_impl in ("numpy", "device"),
                "reduce_merge_impl", self.reduce_merge_impl,
                'must be "numpy" (host argsort merge) or "device" '
                "(kernels/kway_merge tournament, double-buffered)")
        require(0.0 <= self.sample_fraction <= 1.0, "sample_fraction",
                self.sample_fraction,
                "must be a fraction of input records in [0, 1]")
        require(self.max_rounds >= 1, "max_rounds", self.max_rounds,
                "must allow >= 1 shuffle round")
        require(self.max_rounds == 1 or self.reduce_memory_budget_bytes > 0,
                "max_rounds", self.max_rounds,
                "recursive rounds need reduce_memory_budget_bytes > 0 — "
                "the budget is the oversize criterion that triggers a "
                "re-shuffle")


def _spill_key(plan: ExternalSortPlan, wave: int, worker: int) -> str:
    return f"{plan.spill_prefix}wave-{wave:04d}/w-{worker:03d}"


def _output_key(plan: ExternalSortPlan, reducer: int) -> str:
    return f"{plan.output_prefix}part-{reducer:05d}"


def _group_waves(inputs, counts, records_per_wave: int):
    """Tile the key-ordered input objects into equal-record waves.

    ValueError, not assert: the tiling contract must survive python -O —
    a silently mis-tiled wave would sort fine and fail only at valsort.
    """
    waves, cur, acc = [], [], 0
    for meta, c in zip(inputs, counts):
        cur.append(meta)
        acc += c
        require(acc <= records_per_wave, "records_per_wave",
                records_per_wave,
                "input partitions must tile it exactly "
                f"(partition {meta.key} overflows the wave)")
        if acc == records_per_wave:
            waves.append(cur)
            cur, acc = [], 0
    require(not cur, "records_per_wave", records_per_wave,
            "total input records must be a multiple of it")
    return waves


def _contiguous_id_base(ids: np.ndarray) -> int | None:
    """Base id when a wave's ids are exactly arange(base, base + n).

    gensort assigns ids sequentially across key-ordered input partitions
    (data/gensort.write_to_store), so every wave decodes to a contiguous
    ascending id range: the payload row of shuffled record id is then
    just (id - base) — O(1) index arithmetic per record instead of the
    argsort + searchsorted gather (O(n log n), random access). One
    vectorized equality pass verifies the assumption; any other id layout
    falls back to the general gather.
    """
    n = ids.size
    if n == 0:
        return None
    base = int(ids[0])
    # Unwrapped comparison on purpose: a range wrapping past 2^32 would
    # break the (id - base) gather below, so it must take the fallback.
    if base + n - 1 != int(ids[-1]):
        return None
    expect = np.uint32(base) + np.arange(n, dtype=np.uint32)
    if not bool(np.array_equal(np.asarray(ids, dtype=np.uint32), expect)):
        return None
    return base


def _validate_plan(plan: ExternalSortPlan, w: int) -> None:
    """Plan validation shared by every sort entry point, including the
    mesh-dependent divisibility check. ValueError, not assert: must
    survive python -O.
    """
    plan.validate()
    require(plan.records_per_wave % (w * plan.num_rounds) == 0,
            "records_per_wave", plan.records_per_wave,
            f"must divide evenly into {w} mesh workers x "
            f"{plan.num_rounds} rounds")


class WaveSorter:
    """Map-side building block: load a wave zero-copy, sort it across the
    mesh, spill per-mesh-worker runs.

    Wrapped by shuffle/sort.SortMapOp, which is how the single-host and
    cluster drivers reach it. Deterministic by construction: the spilled
    run bytes and reducer offsets depend only on (wave contents, plan,
    mesh width) — never on which scheduler or emulated worker executes
    the wave — which is what keeps cluster output byte-identical to the
    single-host run at any worker count and under re-execution.
    """

    def __init__(self, plan: ExternalSortPlan, mesh: jax.sharding.Mesh,
                 axis_names: Sequence[str] | str,
                 boundaries: Sequence[int] | np.ndarray | None = None):
        axis = tuple([axis_names] if isinstance(axis_names, str)
                     else axis_names)
        self.plan = plan
        self.w = int(math.prod(mesh.shape[a] for a in axis))
        self.r1 = plan.reducers_per_worker
        self.pw = plan.payload_words
        _validate_plan(plan, self.w)
        # Explicit (sampled) reducer boundaries replace the equal split in
        # BOTH device routing (worker boundaries = every R1-th entry, via
        # the keyspace) and the host-side reducer_offsets searchsorted
        # below, so spill offsets stay bit-consistent with routing.
        self.cfg = ShuffleConfig(
            num_workers=self.w,
            reducers_per_worker=self.r1,
            capacity_factor=plan.capacity_factor,
            num_rounds=plan.num_rounds,
            impl=plan.impl,
            boundaries=(None if boundaries is None
                        else tuple(int(b) for b in np.asarray(boundaries))),
        )
        self._sort = jax.jit(
            lambda k, i: streaming_sort(
                k, i, mesh=mesh, axis_names=axis_names,
                num_rounds=plan.num_rounds, cfg=self.cfg,
            )
        )
        self._local_bounds = (
            np.asarray(self.cfg.keyspace.local_reducer_boundaries())
            if self.r1 > 1 else None
        )  # (W, R1-1)
        # The device mesh is ONE shared resource: concurrent executions of
        # the same multi-device collective program interleave their
        # per-device participant threads into XLA's rendezvous and
        # deadlock (and a real accelerator would serialize them anyway).
        # Emulated cluster workers therefore queue on this lock for the
        # sort step, and overlap on everything else — load, spill, reduce
        # — which is where worker-count scaling pays on a latency-bound
        # store.
        self._device_lock = threading.Lock()

    def load_wave(self, store: StoreBackend, bucket: str, objs):
        """Chunked-GET a wave's input objects into one preallocated
        interleaved-row buffer (zero-copy decode); returns (keys, ids,
        payload)."""
        plan = self.plan
        n_wave = sum(
            (m.size - rec.HEADER_BYTES) // plan.record_bytes for m in objs)
        rows = rec.alloc_rows(n_wave, self.pw)
        at = 0
        for m in objs:
            dec = rec.StreamDecoder(rows, at, what=m.key)
            for chunk in store.get_chunks(bucket, m.key, plan.store_chunk_bytes):
                dec.feed(chunk)
            at += dec.finish()
        return rec.split_rows(rows)

    def device_sort(self, keys, ids, *, timeline: PhaseTimeline | None = None,
                    tag: str = ""):
        """Stage 1 of the map body: the mesh sort (serialized on the
        device lock), returned as host copies (sk, si, vcounts).

        With a timeline, the interval is recorded under BOTH
        map.device_sort (the per-stage span, docs/OBSERVABILITY.md) and
        map.compute (the long-standing device-time total every report
        and test reads).
        """
        t = time.perf_counter()
        with self._device_lock:
            sk, si, vcounts, ovf = self._sort(jnp.asarray(keys),
                                              jnp.asarray(ids))
            sk, si, vcounts = (np.asarray(sk), np.asarray(si),
                               np.asarray(vcounts))
        if bool(np.asarray(ovf)):
            raise RuntimeError(
                "shuffle block overflow — raise capacity_factor"
            )
        if timeline is not None:
            timeline.add("map.device_sort", t, worker=tag)
            timeline.add("map.compute", t, worker=tag)
        return sk, si, vcounts

    def encode_and_spill(self, store: StoreBackend, bucket: str, g: int,
                         sk, si, vcounts, ids, payload, *,
                         spiller: staging.AsyncWriter,
                         timeline: PhaseTimeline, tag: str,
                         offsets_out: dict, span: str = "map.encode",
                         t0: float | None = None) -> None:
        """Stage 2 of the map body: slice each mesh worker's run out of
        the sorted wave, gather payload rows, encode, and spill.

        Writes per-reducer offsets for every spilled run into
        `offsets_out[(g, wid)]` (they are also persisted in the spill
        object's manifest metadata, so a process-backed worker could
        recover them from the store alone). `span` names the recorded
        compute segments — "map.encode" as a pipeline stage,
        "map.compute" from the monolithic compute_and_spill.
        """
        plan, w, pw = self.plan, self.w, self.pw
        t_comp = time.perf_counter() if t0 is None else t0
        # id -> wave row for gathering payload of shuffled records:
        # O(1) offset arithmetic when the wave's ids are contiguous
        # (the gensort layout), argsort gather otherwise.
        id_base = _contiguous_id_base(ids) if pw else None
        order = sorted_ids = None
        if pw and id_base is None:
            order = np.argsort(ids)
            sorted_ids = ids[order]
        seg = sk.shape[0] // w
        for wid in range(w):
            n = int(vcounts[wid])
            run_k = sk[wid * seg : wid * seg + n]
            run_i = si[wid * seg : wid * seg + n]
            run_p = None
            if pw:
                if id_base is not None:
                    sel = run_i.astype(np.int64) - id_base
                else:
                    sel = order[np.searchsorted(sorted_ids, run_i)]
                run_p = payload[sel]
            if self._local_bounds is not None:
                internal = np.searchsorted(
                    run_k, self._local_bounds[wid], side="left")
            else:
                internal = np.empty((0,), np.int64)
            offsets = np.concatenate(([0], internal, [n])).astype(np.int64)
            offsets_out[(g, wid)] = offsets
            data = rec.encode_records(run_k, run_i, run_p)
            # Submit each encoded run immediately: the AsyncWriter
            # backpressure bound (at most max_inflight encoded runs
            # in host memory) only holds if we never batch them.
            timeline.add(span, t_comp, worker=tag)
            t_spill = time.perf_counter()
            spiller.submit(_timed_spill, timeline, tag, store, bucket,
                           _spill_key(plan, g, wid), data, {
                               "records": n,
                               "wave": g,
                               "worker": wid,
                               "reducer_offsets": [int(o) for o in offsets],
                           })
            timeline.add("map.spill_wait", t_spill, worker=tag)
            t_comp = time.perf_counter()
        timeline.add(span, t_comp, worker=tag)

    def compute_and_spill(self, store: StoreBackend, bucket: str, g: int,
                          keys, ids, payload, *, spiller: staging.AsyncWriter,
                          timeline: PhaseTimeline, tag: str,
                          offsets_out: dict) -> None:
        """Sort wave g on the mesh and spill each mesh-worker's run —
        the monolithic (non-pipelined) map body: device_sort +
        encode_and_spill back to back on the calling thread, with the
        original map.compute/map.spill_wait span structure."""
        t_comp = time.perf_counter()
        sk, si, vcounts = self.device_sort(keys, ids)
        self.encode_and_spill(store, bucket, g, sk, si, vcounts, ids,
                              payload, spiller=spiller, timeline=timeline,
                              tag=tag, offsets_out=offsets_out,
                              span="map.compute", t0=t_comp)


def external_sort(
    store: StoreBackend,
    bucket: str,
    *,
    mesh: jax.sharding.Mesh,
    axis_names: Sequence[str] | str,
    plan: ExternalSortPlan,
    tracer=None,
) -> ExternalSortReport:
    """Sort every record under plan.input_prefix into plan.output_prefix.

    DEPRECATED shim (kept byte- and etag-identical to the pre-refactor
    driver): build the job through the library instead —

        from repro.shuffle.sort import sort_shuffle_job
        sort_shuffle_job(store, bucket, mesh=mesh, axis_names=axis_names,
                         plan=plan).run(workers=0)

    `store` is any io/backends.StoreBackend — the plain ObjectStore, a
    fault-injected middleware stack, or a TieredStore (in which case the
    report carries per-tier request deltas). Input objects must be
    io/records-encoded with plan.payload_words words of payload and
    globally unique ids (data/gensort.write_to_store's layout). Returns
    the run report; validate the output with data/valsort.validate_from_store.
    """
    warnings.warn(
        "external_sort() is a deprecated shim; use "
        "repro.shuffle.sort.sort_shuffle_job(...).run(workers=0)",
        DeprecationWarning, stacklevel=2)
    from repro.shuffle.sort import sort_shuffle_job

    return sort_shuffle_job(store, bucket, mesh=mesh, axis_names=axis_names,
                            plan=plan, tracer=tracer).run(workers=0)
