"""jax API compatibility shims.

The repo is written against the current jax API — ``jax.shard_map`` with
``check_vma``, ``jax.make_mesh`` with ``axis_types`` — but the baked
toolchain on some containers ships jax 0.4.x, where the same functionality
lives under ``jax.experimental.shard_map`` (``check_rep``) and ``make_mesh``
has no axis typing. Every mesh/shard_map construction in this repo goes
through these two wrappers so the whole system (distributed sort, MoE
dispatch, out-of-core driver, examples, tests) runs on both.
"""
from __future__ import annotations

import jax


def shard_map(fn, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` where it exists; the 0.4.x experimental fallback
    otherwise (``check_vma`` maps onto the old ``check_rep``)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=bool(check_vma),
    )


def cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` as a flat dict on every jax version
    (0.4.x wraps the per-program properties in a single-element list)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    axis_shapes = tuple(axis_shapes)
    axis_names = tuple(axis_names)
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(axis_shapes, axis_names)
    return jax.make_mesh(
        axis_shapes, axis_names, axis_types=(axis_type.Auto,) * len(axis_names)
    )
