"""Multi-worker cluster executor: the paper's 40-worker job, emulated.

Exoshuffle's headline CloudSort run is a 40-worker cluster whose
straggler/failure tolerance comes from the application re-scheduling its
own map/reduce tasks (paper §2.4, §2.6 — the freedom shuffle-as-a-library
buys). Until this module, the reproduction's executor was a thread pool on
one host; ClusterExecutor partitions the same job across N *emulated*
workers, each with its own schedule, store view, and failure domain:

  tasks        — the job decomposes exactly as the single-host driver
      does: one MAP task per wave (load -> mesh sort -> spill runs) and
      one REDUCE task per output partition (streaming k-way merge ->
      multipart upload). Task bodies are the shared building blocks
      (core/external_sort.WaveSorter / ReduceScheduler), so the bytes a
      task produces depend only on (task id, plan, input) — never on
      which worker runs it, or how many times.

  workers      — `Worker` is the narrow protocol (a name, a store view,
      two phase entry points); `ThreadWorker` backs it with host threads
      that share the device mesh (emulated workers partition the
      *schedule*, not the per-wave device working set). The protocol is
      deliberately store-recoverable — spill offsets are persisted in
      the spill objects' manifest metadata — so a process-backed worker
      could implement it against the store alone.

  scheduling   — each worker prefers its contiguous assigned range of
      waves / partitions and steals from the longest surviving queue
      when its own drains (§2.4's dynamic placement; also what
      automatically redistributes a dead worker's queued tasks). Within
      a worker, the reduce phase runs its own ReduceScheduler with
      plan.parallel_reducers concurrent merges, all drawing chunk grants
      from the job-global AdaptiveBudgetGovernor — so cluster-wide
      reduce memory stays under plan.reduce_memory_budget_bytes no
      matter how many workers run.

  failure      — `FaultyWorker` wraps any worker in the spirit of the
      PR-2 store middleware: after a task budget (or, via
      io/middleware.KillSwitchMiddleware, a request budget) the worker
      dies — every later task pop raises WorkerFailure AND its store
      view starts refusing requests, so sibling merges die mid-flight,
      leaving partial multipart sessions and undrained spills behind.
      The driver detects the death at the phase barrier: a task only
      counts as done once its output is durably committed (spills
      drained; multipart COMPLETE returned), so everything a dead worker
      still owed is re-executed on survivors in the next round. Because
      task bodies are deterministic and commits are atomic (manifest
      replace), re-execution is idempotent: output is byte- and
      etag-identical to the single-host run at any worker count and
      under any single-worker (indeed any non-total) failure.

The cost model sees cluster runs unchanged: all workers share one
underlying store, so measured GET/PUT counts (retry- and re-execution-
inflated, like a real bill) flow into measured_cloudsort_tco exactly as
before, while per-worker MetricsMiddleware views break traffic out by
worker in the report.
"""
from __future__ import annotations

import abc
import collections
import dataclasses
import threading
import time
from typing import Callable, Mapping, Sequence

import jax
import numpy as np

from repro.core import external_sort as xs
from repro.io import staging
from repro.io.backends import RetryableError, StoreBackend, StoreStats
from repro.io.middleware import KillSwitchMiddleware, MetricsMiddleware


class WorkerFailure(RuntimeError):
    """An emulated worker died. Deliberately NOT a RetryableError: store
    retries cannot resurrect a host, only the driver's re-execution can."""


class ClusterFailure(RuntimeError):
    """The job cannot make progress (e.g. every worker died)."""


@dataclasses.dataclass(frozen=True)
class ClusterPlan:
    """How the job is partitioned across emulated workers.

    `fail_after_tasks[i]` / `fail_after_requests[i]` inject a death into
    worker i (wrapping it in FaultyWorker): the worker completes that
    many tasks / store requests, then dies. Used by the fault-tolerance
    tests and benchmarks; production runs leave them empty.
    """

    num_workers: int = 2
    fail_after_tasks: Mapping[int, int] = dataclasses.field(
        default_factory=dict)
    fail_after_requests: Mapping[int, int] = dataclasses.field(
        default_factory=dict)

    def __post_init__(self):
        if self.num_workers < 1:
            raise ValueError(
                f"num_workers must be >= 1, got {self.num_workers}")


@dataclasses.dataclass
class ClusterContext:
    """Everything a worker needs to execute tasks for one job."""

    plan: xs.ExternalSortPlan
    bucket: str
    sorter: xs.WaveSorter
    waves: list  # wave index -> list[ObjectMeta] of its input objects
    timeline: xs.PhaseTimeline
    control: xs.JobControl
    spill_offsets: dict
    reduce_shared: xs.ReduceShared


class Worker(abc.ABC):
    """One emulated cluster worker.

    The protocol is two phase entry points plus a store view. A phase
    entry point drains tasks from `pop_next` (returning None ends the
    phase) and calls `on_done(task_id)` only once the task's output is
    DURABLE in the shared store — that confirmation, not the call
    returning, is what the driver's failure recovery trusts. A dying
    worker raises WorkerFailure; any other exception is a job error.
    """

    name: str
    store: StoreBackend

    @abc.abstractmethod
    def run_map_phase(self, ctx: ClusterContext,
                      pop_next: Callable[[], int | None],
                      on_done: Callable[[int], None]) -> None: ...

    @abc.abstractmethod
    def run_reduce_phase(self, ctx: ClusterContext,
                         pop_next: Callable[[], int | None],
                         on_done: Callable[[int], None]) -> None: ...


class ThreadWorker(Worker):
    """Thread-backed emulated worker with its own metrics-wrapped view of
    the shared store (per-worker request attribution in the report; the
    shared store underneath still counts the global, billed traffic)."""

    def __init__(self, name: str, store: StoreBackend, *,
                 metrics: bool = True):
        self.name = name
        self.store = MetricsMiddleware(store) if metrics else store

    # -- map: one wave per task, compute sequential within the worker ----
    # (records_per_wave is the device working set; a worker never SORTS
    # more than one wave at a time, exactly like the single-host driver —
    # but like it, the next wave's chunked GETs prefetch while the
    # current wave sorts/spills, via the same staging.prefetch pipeline.)

    def run_map_phase(self, ctx, pop_next, on_done):
        plan = ctx.plan
        popped: collections.deque[int] = collections.deque()

        def wave_loads():
            # Pulled from inside the prefetch pipeline on this worker's
            # thread: each pull claims the next task (up to prefetch_depth
            # ahead of the sort). A claimed-but-unconfirmed task at death
            # is simply re-executed by the driver's next round.
            while not ctx.control.cancel.is_set():
                g = pop_next()
                if g is None:
                    return
                popped.append(g)
                yield lambda g=g: ctx.sorter.load_wave(
                    self.store, ctx.bucket, ctx.waves[g])

        with staging.AsyncWriter(plan.max_inflight_writes) as spiller:
            wave_iter = iter(staging.prefetch(
                wave_loads(), depth=plan.prefetch_depth,
                retries=plan.io_retries, retry_on=(RetryableError,)))
            while True:
                t_wait = time.perf_counter()
                try:
                    keys, ids, payload = next(wave_iter)
                except StopIteration:
                    return
                g = popped.popleft()
                tag = f"{self.name}/g{g}"
                ctx.timeline.add("map.wait", t_wait, worker=tag)
                ctx.sorter.compute_and_spill(
                    self.store, ctx.bucket, g, keys, ids, payload,
                    spiller=spiller, timeline=ctx.timeline, tag=tag,
                    offsets_out=ctx.spill_offsets)
                # The task is only done once its runs are durable: drain
                # the write-behind queue before confirming, so a worker
                # that dies with spills in flight leaves the wave
                # unconfirmed (and re-executed) rather than half-spilled.
                spiller.drain()
                on_done(g)

    # -- reduce: the worker's own scheduler over its partition range -----

    def run_reduce_phase(self, ctx, pop_next, on_done):
        xs.ReduceScheduler(
            self.store, ctx.reduce_shared,
            width=ctx.plan.parallel_reducers,
            fatal=(WorkerFailure,),
            tag_prefix=f"{self.name}/",
        ).run(pop_next, on_done=on_done)


class FaultyWorker(Worker):
    """Failure-injecting wrapper — the worker-level analogue of the PR-2
    store fault middleware.

    The wrapped worker completes `fail_after_tasks` tasks (and/or its
    store view serves `fail_after_requests` requests) and then dies:
    subsequent task pops raise WorkerFailure, and the store view's kill
    switch makes every in-flight sibling request fail too — so partial
    multipart sessions and undrained spills are left behind exactly as a
    host crash would leave them, for the driver to re-execute elsewhere.
    """

    def __init__(self, inner: Worker, *, fail_after_tasks: int | None = None,
                 fail_after_requests: int | None = None):
        self.inner = inner
        self.name = inner.name
        self._kill = KillSwitchMiddleware(
            inner.store,
            exc_factory=lambda: WorkerFailure(
                f"{self.name}: store unreachable (worker dead)"),
            fail_after_requests=fail_after_requests,
        )
        # The inner worker now talks through the kill switch, so tripping
        # it severs the whole worker, not just new tasks.
        self.store = inner.store = self._kill
        self._lock = threading.Lock()
        self._remaining = fail_after_tasks

    def _gated(self, pop_next):
        def pop():
            with self._lock:
                if self._remaining is not None and self._remaining <= 0:
                    self._kill.trip()
                    raise WorkerFailure(f"{self.name}: injected worker death")
            task = pop_next()
            if task is None:
                return None
            with self._lock:
                if self._remaining is not None:
                    self._remaining -= 1
            return task
        return pop

    def run_map_phase(self, ctx, pop_next, on_done):
        self.inner.run_map_phase(ctx, self._gated(pop_next), on_done)

    def run_reduce_phase(self, ctx, pop_next, on_done):
        self.inner.run_reduce_phase(ctx, self._gated(pop_next), on_done)


class _TaskPool:
    """Range-partitioned shared task queue with stealing.

    Each worker prefers its own contiguous slice (the "assigned partition
    range"); when it drains, it steals from the tail of the longest
    surviving queue — dynamic load balancing, and the mechanism that
    hands a dead worker's queued tasks to survivors without any special
    casing.
    """

    def __init__(self, tasks: Sequence[int], worker_names: Sequence[str]):
        self._lock = threading.Lock()
        self._q: dict[str, collections.deque[int]] = {
            name: collections.deque() for name in worker_names}
        names = list(worker_names)
        n, k = len(tasks), len(names)
        bounds = [round(i * n / k) for i in range(k + 1)]
        for i, name in enumerate(names):
            self._q[name].extend(tasks[bounds[i]:bounds[i + 1]])

    def popper(self, name: str) -> Callable[[], int | None]:
        def pop() -> int | None:
            with self._lock:
                own = self._q[name]
                if own:
                    return own.popleft()
                donor = max((q for q in self._q.values() if q),
                            key=len, default=None)
                if donor is not None:
                    return donor.pop()  # steal from the tail
                return None
        return pop


@dataclasses.dataclass
class ClusterSortReport:
    """A cluster run's report: the familiar single-host report plus the
    cluster-level story (who died, what was re-executed, who did what)."""

    sort: xs.ExternalSortReport
    num_cluster_workers: int
    failed_workers: list[str]
    reexecuted_map_tasks: int
    reexecuted_reduce_tasks: int
    map_tasks: int
    reduce_tasks: int
    per_worker_stats: dict[str, StoreStats]
    per_worker_tasks: dict[str, int]

    @property
    def reexecuted_tasks(self) -> int:
        return self.reexecuted_map_tasks + self.reexecuted_reduce_tasks

    @property
    def records_per_second(self) -> float:
        secs = self.sort.map_seconds + self.sort.reduce_seconds
        return self.sort.total_records / secs if secs > 0 else 0.0


class ClusterExecutor:
    """Partition one external sort across N emulated workers with failure
    recovery; output is byte-identical to the single-host driver.

    Tasks run in two barriered phases (every reduce merge needs every
    wave's spilled run, so the barrier is inherent to the dataflow, not a
    scheduling choice). Within a phase the driver runs ROUNDS: it
    launches every surviving worker on the pending task pool, joins them,
    marks workers that raised WorkerFailure as dead, and re-runs the
    phase with whatever tasks were never durably confirmed — the
    re-executed tasks the report counts. A real (non-WorkerFailure)
    exception anywhere cancels the job and re-raises.
    """

    def __init__(self, store: StoreBackend, bucket: str, *,
                 mesh: jax.sharding.Mesh, axis_names: Sequence[str] | str,
                 plan: xs.ExternalSortPlan,
                 cluster: ClusterPlan = ClusterPlan(),
                 workers: Sequence[Worker] | None = None):
        self.store = store
        self.bucket = bucket
        self.mesh = mesh
        self.axis_names = axis_names
        self.plan = plan
        self.cluster = cluster
        if workers is None:
            workers = []
            for i in range(cluster.num_workers):
                wk: Worker = ThreadWorker(f"w{i}", store)
                tasks_budget = cluster.fail_after_tasks.get(i)
                reqs_budget = cluster.fail_after_requests.get(i)
                if tasks_budget is not None or reqs_budget is not None:
                    wk = FaultyWorker(wk, fail_after_tasks=tasks_budget,
                                      fail_after_requests=reqs_budget)
                workers.append(wk)
        self.workers = list(workers)
        self._lock = threading.Lock()
        self._dead: set[str] = set()
        self.failed_workers: list[str] = []

    # -- phase driver ------------------------------------------------------

    def _drive(self, worker: Worker, entry: Callable[[Worker], None],
               control: xs.JobControl) -> None:
        try:
            entry(worker)
        except WorkerFailure:
            with self._lock:
                if worker.name not in self._dead:
                    self._dead.add(worker.name)
                    self.failed_workers.append(worker.name)
        except BaseException as e:
            control.fail(e)

    def _run_phase(self, phase: str, tasks: Sequence[int],
                   entry: Callable[[Worker, Callable, Callable], None],
                   control: xs.JobControl,
                   per_worker_tasks: dict[str, int]) -> int:
        """Run `tasks` to durable completion; returns re-executions."""
        done: set[int] = set()
        done_lock = threading.Lock()
        pending = list(tasks)
        reexecuted = 0
        first_round = True
        while pending:
            with self._lock:
                alive = [wk for wk in self.workers
                         if wk.name not in self._dead]
            if not alive:
                raise ClusterFailure(
                    f"all {len(self.workers)} workers dead during {phase} "
                    f"phase with {len(pending)} tasks unfinished")
            if not first_round:
                reexecuted += len(pending)
            first_round = False
            pool = _TaskPool(pending, [wk.name for wk in alive])

            def on_done_for(wk: Worker):
                def on_done(task: int) -> None:
                    with done_lock:
                        done.add(task)
                        per_worker_tasks[wk.name] = (
                            per_worker_tasks.get(wk.name, 0) + 1)
                return on_done

            threads = [
                threading.Thread(
                    target=self._drive,
                    args=(wk, lambda w, p=pool.popper(wk.name),
                          d=on_done_for(wk): entry(w, p, d), control),
                    name=f"cluster-{wk.name}-{phase}")
                for wk in alive
            ]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            control.raise_first()
            with done_lock:
                pending = [t for t in tasks if t not in done]
        return reexecuted

    # -- the job -----------------------------------------------------------

    def sort(self) -> ClusterSortReport:
        plan, store, bucket = self.plan, self.store, self.bucket
        # Shared preflight with the single-host driver (one source of
        # truth for validation, wave grouping, budget feasibility). The
        # governor's slot count is the cluster-wide merge concurrency:
        # every worker's scheduler draws from the same global budget.
        setup = xs.prepare_job(store, bucket, plan, self.mesh,
                               self.axis_names,
                               schedulers=len(self.workers))

        t_origin = time.perf_counter()
        timeline = xs.PhaseTimeline(origin=t_origin)
        control = xs.JobControl()
        spill_offsets: dict[tuple[int, int], np.ndarray] = {}
        peak = xs._PeakTracker()
        ctx = ClusterContext(
            plan=plan, bucket=bucket, sorter=setup.sorter,
            waves=setup.waves, timeline=timeline, control=control,
            spill_offsets=spill_offsets,
            reduce_shared=xs.ReduceShared(
                plan=plan, bucket=bucket, num_waves=setup.num_waves,
                r1=setup.sorter.r1, spill_offsets=spill_offsets,
                governor=setup.governor, timeline=timeline, peak=peak,
                control=control,
            ),
        )
        per_worker_tasks: dict[str, int] = {}

        # ---- map phase (barrier: reduce needs every wave's runs) -------
        reexec_map = self._run_phase(
            "map", list(range(setup.num_waves)),
            lambda wk, pop, on_done: wk.run_map_phase(ctx, pop, on_done),
            control, per_worker_tasks)
        map_seconds = time.perf_counter() - t_origin

        # ---- reduce phase ----------------------------------------------
        t_reduce = time.perf_counter()
        reexec_reduce = self._run_phase(
            "reduce", list(range(setup.num_reducers)),
            lambda wk, pop, on_done: wk.run_reduce_phase(ctx, pop, on_done),
            control, per_worker_tasks)
        reduce_seconds = time.perf_counter() - t_reduce

        per_worker_stats = {
            wk.name: wk.store.stats_snapshot()
            for wk in self.workers
            if hasattr(wk.store, "stats_snapshot")
        }
        return ClusterSortReport(
            sort=xs.build_report(setup, store, plan,
                                 map_seconds=map_seconds,
                                 reduce_seconds=reduce_seconds,
                                 peak=peak, timeline=timeline),
            num_cluster_workers=len(self.workers),
            failed_workers=list(self.failed_workers),
            reexecuted_map_tasks=reexec_map,
            reexecuted_reduce_tasks=reexec_reduce,
            map_tasks=setup.num_waves,
            reduce_tasks=setup.num_reducers,
            per_worker_stats=per_worker_stats,
            per_worker_tasks=dict(per_worker_tasks),
        )


def cluster_external_sort(
    store: StoreBackend,
    bucket: str,
    *,
    mesh: jax.sharding.Mesh,
    axis_names: Sequence[str] | str,
    plan: xs.ExternalSortPlan,
    cluster: ClusterPlan = ClusterPlan(),
    workers: Sequence[Worker] | None = None,
) -> ClusterSortReport:
    """Convenience wrapper: build a ClusterExecutor and run the sort."""
    return ClusterExecutor(
        store, bucket, mesh=mesh, axis_names=axis_names, plan=plan,
        cluster=cluster, workers=workers,
    ).sort()
