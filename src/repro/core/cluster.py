"""Multi-worker cluster executor: the paper's 40-worker job, emulated.

Exoshuffle's headline CloudSort run is a 40-worker cluster whose
straggler/failure tolerance comes from the application re-scheduling its
own map/reduce tasks (paper §2.4, §2.6 — the freedom shuffle-as-a-library
buys). Since the library refactor the machinery lives in
src/repro/shuffle/ and is workload-agnostic:

  * the Worker protocol, ThreadWorker, FaultyWorker, the stealing
    TaskPool, and the durable-confirmation phase driver are
    shuffle/executor.py (re-exported here under their old names);
  * the CloudSort task bodies are shuffle/sort.SortMapOp /
    MergeReduceOp, wrapping core/external_sort.WaveSorter and the
    streaming k-way merge;
  * single-host vs. cluster execution is one
    `ShuffleJob.run(workers=N)` call (shuffle/job.py).

`ClusterExecutor` / `cluster_external_sort` below are thin deprecated
shims over that call — byte- and etag-identical to the pre-refactor
driver at any worker count and under any non-total failure, which
tests/test_cluster.py asserts. See shuffle/executor.py's docstrings for
the semantics (tasks, scheduling, failure recovery, re-execution); they
are unchanged.
"""
from __future__ import annotations

import warnings
from typing import Sequence

import jax

from repro.core import external_sort as xs
from repro.io.backends import StoreBackend
from repro.shuffle.api import ClusterShuffleReport
from repro.shuffle.executor import (ClusterFailure, ClusterPlan,
                                    FaultyWorker, TaskPool, ThreadWorker,
                                    Worker, WorkerFailure, build_workers)

# Backwards-compatible aliases (the classes moved to the shuffle library).
_TaskPool = TaskPool

#: A cluster run's report (renamed when the library was carved out; the
#: legacy `.sort` accessor still reads the inner report).
ClusterSortReport = ClusterShuffleReport


class ClusterExecutor:
    """DEPRECATED shim: partition one external sort across N emulated
    workers with failure recovery; output is byte-identical to the
    single-host driver. Build the job through the library instead —

        from repro.shuffle.sort import sort_shuffle_job
        sort_shuffle_job(store, bucket, mesh=mesh, axis_names=axis_names,
                         plan=plan).run(cluster=cluster)

    The constructor keeps its historical signature: `cluster` (a
    shuffle/executor.ClusterPlan) sizes the default ThreadWorker fleet
    and injects FaultyWorker deaths; `workers` supplies a hand-built
    fleet instead.
    """

    def __init__(self, store: StoreBackend, bucket: str, *,
                 mesh: jax.sharding.Mesh, axis_names: Sequence[str] | str,
                 plan: xs.ExternalSortPlan,
                 cluster: ClusterPlan = ClusterPlan(),
                 workers: Sequence[Worker] | None = None,
                 tracer=None):
        warnings.warn(
            "ClusterExecutor is a deprecated shim; use "
            "repro.shuffle.sort.sort_shuffle_job(...).run(workers=N) or "
            ".run(cluster=ClusterPlan(...))",
            DeprecationWarning, stacklevel=2)
        self.store = store
        self.bucket = bucket
        self.mesh = mesh
        self.axis_names = axis_names
        self.plan = plan
        self.cluster = cluster
        self.workers = (list(workers) if workers is not None
                        else build_workers(store, cluster))
        self.tracer = tracer

    def sort(self) -> ClusterSortReport:
        from repro.shuffle.sort import sort_shuffle_job

        job = sort_shuffle_job(self.store, self.bucket, mesh=self.mesh,
                               axis_names=self.axis_names, plan=self.plan,
                               tracer=self.tracer)
        return job.run(worker_list=self.workers)


def cluster_external_sort(
    store: StoreBackend,
    bucket: str,
    *,
    mesh: jax.sharding.Mesh,
    axis_names: Sequence[str] | str,
    plan: xs.ExternalSortPlan,
    cluster: ClusterPlan = ClusterPlan(),
    workers: Sequence[Worker] | None = None,
    tracer=None,
) -> ClusterSortReport:
    """DEPRECATED shim: build a ClusterExecutor and run the sort. Use
    `repro.shuffle.sort.sort_shuffle_job(...).run(cluster=...)`."""
    warnings.warn(
        "cluster_external_sort() is a deprecated shim; use "
        "repro.shuffle.sort.sort_shuffle_job(...).run(workers=N) or "
        ".run(cluster=ClusterPlan(...))",
        DeprecationWarning, stacklevel=2)
    return ClusterExecutor(
        store, bucket, mesh=mesh, axis_names=axis_names, plan=plan,
        cluster=cluster, workers=workers, tracer=tracer,
    ).sort()


__all__ = [
    "ClusterExecutor",
    "ClusterFailure",
    "ClusterPlan",
    "ClusterSortReport",
    "FaultyWorker",
    "ThreadWorker",
    "Worker",
    "WorkerFailure",
    "cluster_external_sort",
]
