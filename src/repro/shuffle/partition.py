"""Pluggable partitioners: range and hash routing over uint32 keys.

The sort needs order-preserving ranges (output partition j holds keys
below partition j+1's — CloudSort's contract); a group-by only needs
*stable, balanced* routing, and its key distribution is usually skewed
(word frequencies), so it hashes first. Both are the same construction —
`num_partitions - 1` internal boundaries over a routed uint32 domain —
differing only in the routing function, which is what makes the
partitioner contract small enough to test property-style
(tests/test_shuffle.py: exhaustive, non-overlapping coverage for every
implementation).

RangePartitioner's equal split reproduces core/keyspace.KeySpace's
reducer boundaries bit-for-bit (floor((j * 2^32) / P)) — the device-side
shuffle kernels and the host-side library route identically, which the
test suite asserts so the two can never drift.
"""
from __future__ import annotations

import numpy as np

from repro.shuffle.api import Partitioner, require

KEY_BITS = 32
KEY_SPACE = 1 << KEY_BITS


def equal_boundaries(parts: int) -> np.ndarray:
    """(parts-1,) uint32 internal boundaries of an equal split of
    [0, 2^32) — floor((j * 2^32) / parts), the core/keyspace construction
    (host-side, no jax)."""
    js = np.arange(1, parts, dtype=np.uint64)
    return ((js * np.uint64(KEY_SPACE)) // np.uint64(parts)).astype(np.uint32)


def quantile_boundaries(sample: np.ndarray, parts: int) -> np.ndarray:
    """(parts-1,) uint32 internal boundaries from a routed-key sample —
    the host-side twin of core/keyspace.sampled_boundaries, bit-for-bit
    (sort, then take srt[(j * n) // parts]). A one-value sample is legal
    (all boundaries collapse); an empty sample is not.
    """
    srt = np.sort(np.asarray(sample, dtype=np.uint32).reshape(-1))
    n = srt.shape[0]
    require(n >= 1, "sample", n,
            "need at least one sampled key to estimate splitters")
    require(parts >= 1, "parts", parts, "must be >= 1")
    idx = (np.arange(1, parts, dtype=np.int64) * n) // parts
    return srt[idx]


def _splitmix32(x: np.ndarray) -> np.ndarray:
    """The gensort avalanche hash (data/gensort.splitmix32), host-side."""
    x = np.asarray(x, dtype=np.uint32)
    x = (x ^ (x >> np.uint32(16))) * np.uint32(0x85EBCA6B)
    x = (x ^ (x >> np.uint32(13))) * np.uint32(0xC2B2AE35)
    return x ^ (x >> np.uint32(16))


class RangePartitioner(Partitioner):
    """Order-preserving key ranges: equal split by default, or explicit
    boundaries (e.g. core/keyspace.sampled_boundaries quantiles for the
    Daytona-style skew fallback)."""

    def __init__(self, num_partitions: int,
                 boundaries: np.ndarray | None = None):
        require(num_partitions >= 1, "num_partitions", num_partitions,
                "must be >= 1")
        self.num_partitions = int(num_partitions)
        if boundaries is None:
            bounds = equal_boundaries(self.num_partitions)
        else:
            bounds = np.asarray(boundaries, dtype=np.uint32).reshape(-1)
            require(bounds.shape[0] == self.num_partitions - 1,
                    "boundaries", bounds.shape[0],
                    f"must supply num_partitions-1 = "
                    f"{self.num_partitions - 1} internal boundaries")
            require(bool(np.all(bounds[1:] >= bounds[:-1])),
                    "boundaries", bounds.tolist(),
                    "must be ascending (non-overlapping ranges)")
        self._bounds = bounds

    def boundaries(self) -> np.ndarray:
        return self._bounds


class HashPartitioner(Partitioner):
    """Uniform routing for skewed key sets: route through splitmix32,
    then equal ranges over the hashed domain. Not order-preserving in
    the raw key domain — use for keyed aggregation, not for sorting."""

    def __init__(self, num_partitions: int):
        require(num_partitions >= 1, "num_partitions", num_partitions,
                "must be >= 1")
        self.num_partitions = int(num_partitions)
        self._bounds = equal_boundaries(self.num_partitions)

    def boundaries(self) -> np.ndarray:
        return self._bounds

    def route(self, keys: np.ndarray) -> np.ndarray:
        return _splitmix32(keys)


__all__ = ["HashPartitioner", "RangePartitioner", "equal_boundaries",
           "quantile_boundaries"]
