"""Multi-worker shuffle execution: workers, stealing, failure recovery.

The cluster machinery of core/cluster.py, carved free of the sort: a
`Worker` is a name, a store view, and two phase entry points; the phase
driver runs rounds of surviving workers over a stealing task pool and
re-executes whatever a dead worker never durably confirmed. Nothing here
knows what a map task or a reduce partition *contains* — that arrives
through the WorkerContext's MapOp / ReduceShared, so the same executor
(and the same FaultyWorker / KillSwitchMiddleware failure injection)
drives CloudSort and the group-by aggregation alike. See
core/cluster.py's module docstring for the §2.4/§2.6 paper mapping; the
semantics are unchanged.
"""
from __future__ import annotations

import abc
import collections
import dataclasses
import threading
from typing import Callable, Mapping, Sequence

from repro.io.backends import StoreBackend
from repro.io.middleware import KillSwitchMiddleware, MetricsMiddleware
from repro.obs.context import TraceContext, use_context

from repro.shuffle import runtime as rt
from repro.shuffle.api import MapOp, require


class WorkerFailure(RuntimeError):
    """An emulated worker died. Deliberately NOT a RetryableError: store
    retries cannot resurrect a host, only the driver's re-execution can."""


class ClusterFailure(RuntimeError):
    """The job cannot make progress (e.g. every worker died)."""


@dataclasses.dataclass(frozen=True)
class ClusterPlan:
    """How the job is partitioned across emulated workers.

    `fail_after_tasks[i]` / `fail_after_requests[i]` inject a death into
    worker i (wrapping it in FaultyWorker): the worker completes that
    many tasks / store requests, then dies. Used by the fault-tolerance
    tests and benchmarks; production runs leave them empty.
    """

    num_workers: int = 2
    fail_after_tasks: Mapping[int, int] = dataclasses.field(
        default_factory=dict)
    fail_after_requests: Mapping[int, int] = dataclasses.field(
        default_factory=dict)

    def __post_init__(self):
        require(self.num_workers >= 1, "num_workers", self.num_workers,
                "must partition the job across >= 1 worker")
        for knob in ("fail_after_tasks", "fail_after_requests"):
            for i, budget in getattr(self, knob).items():
                require(0 <= i < self.num_workers, knob, {i: budget},
                        f"names worker {i}, outside 0..{self.num_workers - 1}")
                require(budget >= 0, knob, {i: budget},
                        "injected budgets must be >= 0")


@dataclasses.dataclass
class WorkerContext:
    """Everything a worker needs to execute one job's tasks. The
    workload enters only through `map_op` / `reduce_shared.reduce_op`."""

    plan: "object"  # any dataflow plan (api.validate_dataflow_plan)
    bucket: str
    map_op: MapOp
    reduce_shared: rt.ReduceShared
    timeline: rt.PhaseTimeline
    control: rt.JobControl
    num_map_tasks: int = 0  # refill-pool sizing hint (runs per partition)
    # Elastic-driver hooks (shuffle/elastic.py); None/empty under the
    # round-barriered PhaseDriver. All take the worker NAME first so one
    # context serves the whole fleet. `commit_gate(worker, r)` is asked
    # immediately before a reduce partition's multipart commit (the
    # speculation loser-abort) and, for in-thread workers, polled
    # between merge windows so a losing attempt abandons mid-merge;
    # `map_commit_gate(worker, g)` is the map-phase analogue, polled
    # per fetched chunk through the read-gated store view; `requeue_on`
    # exception types mean a reduce input vanished (correlated spill
    # loss) and are routed to `on_requeue(worker, r, exc) -> handled`
    # instead of failing the job.
    commit_gate: Callable[[str, int], bool] | None = None
    map_commit_gate: Callable[[str, int], bool] | None = None
    requeue_on: tuple = ()
    on_requeue: Callable[[str, int, BaseException], bool] | None = None


class Worker(abc.ABC):
    """One emulated cluster worker.

    The protocol is two phase entry points plus a store view. A phase
    entry point drains tasks from `pop_next` (returning None ends the
    phase) and calls `on_done(task_id)` only once the task's output is
    DURABLE in the shared store — that confirmation, not the call
    returning, is what the driver's failure recovery trusts. A dying
    worker raises WorkerFailure; any other exception is a job error.
    """

    name: str
    store: StoreBackend

    @abc.abstractmethod
    def run_map_phase(self, ctx: WorkerContext,
                      pop_next: Callable[[], int | None],
                      on_done: Callable[[int], None]) -> None: ...

    @abc.abstractmethod
    def run_reduce_phase(self, ctx: WorkerContext,
                         pop_next: Callable[[], int | None],
                         on_done: Callable[[int], None]) -> None: ...

    # -- elastic-fleet extensions (optional; shuffle/elastic.py) ---------

    def last_beat(self) -> float | None:
        """Monotonic timestamp of the last sign of life, or None if this
        worker kind has no out-of-band heartbeat (in-thread workers fail
        synchronously, so the driver never needs to detect them)."""
        return None

    def fence(self) -> None:
        """Sever the worker after it is declared dead: its store view
        must refuse further requests so an in-flight laggard can never
        durably commit after the driver re-planned its claims."""


class ThreadWorker(Worker):
    """Thread-backed emulated worker with its own metrics-wrapped view of
    the shared store (per-worker request attribution in the report; the
    shared store underneath still counts the global, billed traffic)."""

    def __init__(self, name: str, store: StoreBackend, *,
                 metrics: bool = True):
        self.name = name
        self.store = MetricsMiddleware(store) if metrics else store

    # -- map: one split per task, processing sequential within the worker
    # (the working set is the split; a worker never PROCESSES more than
    # one split at a time — but the next split's chunked GETs prefetch
    # while the current one processes/spills, via the same
    # staging.prefetch pipeline the single-host path uses).

    def run_map_phase(self, ctx, pop_next, on_done):
        name = self.name
        rt.run_map_tasks(
            self.store, ctx.bucket, ctx.map_op, pop_next, plan=ctx.plan,
            timeline=ctx.timeline, control=ctx.control,
            tag_prefix=f"{name}/", on_done=on_done,
            commit_gate=(None if ctx.map_commit_gate is None
                         else (lambda g: ctx.map_commit_gate(name, g))))

    # -- reduce: the worker's own scheduler over its partition range -----

    def run_reduce_phase(self, ctx, pop_next, on_done):
        name = self.name
        rt.ReduceScheduler(
            self.store, ctx.reduce_shared,
            width=ctx.plan.parallel_reducers,
            runs_hint=ctx.num_map_tasks,
            fatal=(WorkerFailure,),
            tag_prefix=f"{name}/",
            requeue=ctx.requeue_on,
            on_requeue=(None if ctx.on_requeue is None
                        else (lambda r, e: ctx.on_requeue(name, r, e))),
            commit_gate=(None if ctx.commit_gate is None
                         else (lambda r: ctx.commit_gate(name, r))),
            # In-thread gates are cheap predicates: poll them mid-merge
            # so a speculation loser abandons instead of streaming its
            # whole partition before losing at the final gate.
            gate_poll=True,
        ).run(pop_next, on_done=on_done)


class FaultyWorker(Worker):
    """Failure-injecting wrapper — the worker-level analogue of the
    store fault middleware (io/middleware.py).

    The wrapped worker completes `fail_after_tasks` tasks (and/or its
    store view serves `fail_after_requests` requests) and then dies:
    subsequent task pops raise WorkerFailure, and the store view's kill
    switch makes every in-flight sibling request fail too — so partial
    multipart sessions and undrained spills are left behind exactly as a
    host crash would leave them, for the driver to re-execute elsewhere.
    """

    def __init__(self, inner: Worker, *, fail_after_tasks: int | None = None,
                 fail_after_requests: int | None = None):
        self.inner = inner
        self.name = inner.name
        self._kill = KillSwitchMiddleware(
            inner.store,
            exc_factory=lambda: WorkerFailure(
                f"{self.name}: store unreachable (worker dead)"),
            fail_after_requests=fail_after_requests,
        )
        # The inner worker now talks through the kill switch, so tripping
        # it severs the whole worker, not just new tasks.
        self.store = inner.store = self._kill
        self._lock = threading.Lock()
        self._remaining = fail_after_tasks

    def _gated(self, pop_next):
        def pop():
            with self._lock:
                if self._remaining is not None and self._remaining <= 0:
                    self._kill.trip()
                    raise WorkerFailure(f"{self.name}: injected worker death")
            task = pop_next()
            if task is None:
                return None
            with self._lock:
                if self._remaining is not None:
                    self._remaining -= 1
            return task
        return pop

    def run_map_phase(self, ctx, pop_next, on_done):
        self.inner.run_map_phase(ctx, self._gated(pop_next), on_done)

    def run_reduce_phase(self, ctx, pop_next, on_done):
        self.inner.run_reduce_phase(ctx, self._gated(pop_next), on_done)

    def last_beat(self) -> float | None:
        return self.inner.last_beat()

    def fence(self) -> None:
        self._kill.trip()


def build_workers(store: StoreBackend,
                  cluster: ClusterPlan) -> list[Worker]:
    """The default worker fleet: one ThreadWorker per cluster slot, each
    wrapped in FaultyWorker where the plan injects a death."""
    workers: list[Worker] = []
    for i in range(cluster.num_workers):
        wk: Worker = ThreadWorker(f"w{i}", store)
        tasks_budget = cluster.fail_after_tasks.get(i)
        reqs_budget = cluster.fail_after_requests.get(i)
        if tasks_budget is not None or reqs_budget is not None:
            wk = FaultyWorker(wk, fail_after_tasks=tasks_budget,
                              fail_after_requests=reqs_budget)
        workers.append(wk)
    return workers


class TaskPool:
    """Range-partitioned shared task queue with stealing.

    Each worker prefers its own contiguous slice (the "assigned partition
    range"); when it drains, it steals from the tail of the longest
    surviving queue — dynamic load balancing, and the mechanism that
    hands a dead worker's queued tasks to survivors without any special
    casing.
    """

    def __init__(self, tasks: Sequence[int], worker_names: Sequence[str]):
        self._lock = threading.Lock()
        self._q: dict[str, collections.deque[int]] = {
            name: collections.deque() for name in worker_names}
        names = list(worker_names)
        n, k = len(tasks), len(names)
        bounds = [round(i * n / k) for i in range(k + 1)]
        for i, name in enumerate(names):
            self._q[name].extend(tasks[bounds[i]:bounds[i + 1]])

    def popper(self, name: str) -> Callable[[], int | None]:
        def pop() -> int | None:
            with self._lock:
                own = self._q[name]
                if own:
                    return own.popleft()
                donor = max((q for q in self._q.values() if q),
                            key=len, default=None)
                if donor is not None:
                    return donor.pop()  # steal from the tail
                return None
        return pop


class PhaseDriver:
    """Run phases of tasks over a worker fleet with failure recovery.

    Tasks run in barriered phases (every reduce partition needs every
    map task's spilled run, so the barrier is inherent to the dataflow,
    not a scheduling choice). Within a phase the driver runs ROUNDS: it
    launches every surviving worker on the pending task pool, joins
    them, marks workers that raised WorkerFailure as dead, and re-runs
    the phase with whatever tasks were never durably confirmed — the
    re-executed tasks the report counts. A real (non-WorkerFailure)
    exception anywhere cancels the job and re-raises.
    """

    def __init__(self, workers: Sequence[Worker], *, tracer=None):
        self.workers = list(workers)
        self.tracer = tracer  # obs Tracer: rounds, deaths, re-executions
        self._lock = threading.Lock()
        self._dead: set[str] = set()
        self.failed_workers: list[str] = []
        self.per_worker_tasks: dict[str, int] = {}

    def _drive(self, worker: Worker, entry: Callable[[Worker], None],
               control: rt.JobControl) -> None:
        # Worker threads start context-free (ContextVars don't cross
        # threads): seed the job/worker identity so task contexts built
        # inside the phase bodies inherit the right job name.
        ctx = None
        if self.tracer is not None:
            ctx = TraceContext(job=self.tracer.job, worker=worker.name)
        try:
            with use_context(ctx):
                entry(worker)
        except WorkerFailure:
            with self._lock:
                if worker.name not in self._dead:
                    self._dead.add(worker.name)
                    self.failed_workers.append(worker.name)
                    if self.tracer is not None:
                        self.tracer.instant(
                            "cluster.worker_dead",
                            ctx=TraceContext(job=self.tracer.job,
                                             worker=worker.name))
                        self.tracer.registry.counter("cluster.workers_dead")
        except BaseException as e:
            control.fail(e)

    def run_phase(self, phase: str, tasks: Sequence[int],
                  entry: Callable[[Worker, Callable, Callable], None],
                  control: rt.JobControl) -> int:
        """Run `tasks` to durable completion; returns re-executions."""
        done: set[int] = set()
        done_lock = threading.Lock()
        pending = list(tasks)
        reexecuted = 0
        first_round = True
        while pending:
            with self._lock:
                alive = [wk for wk in self.workers
                         if wk.name not in self._dead]
            if not alive:
                raise ClusterFailure(
                    f"all {len(self.workers)} workers dead during {phase} "
                    f"phase with {len(pending)} tasks unfinished")
            if not first_round:
                reexecuted += len(pending)
                if self.tracer is not None:
                    self.tracer.registry.counter(
                        "cluster.tasks_reexecuted", len(pending), phase=phase)
            if self.tracer is not None:
                self.tracer.instant(
                    "cluster.round", phase=phase,
                    first=first_round, pending=len(pending),
                    alive=len(alive))
            first_round = False
            pool = TaskPool(pending, [wk.name for wk in alive])

            def on_done_for(wk: Worker):
                def on_done(task: int) -> None:
                    with done_lock:
                        done.add(task)
                        self.per_worker_tasks[wk.name] = (
                            self.per_worker_tasks.get(wk.name, 0) + 1)
                return on_done

            threads = [
                threading.Thread(
                    target=self._drive,
                    args=(wk, lambda w, p=pool.popper(wk.name),
                          d=on_done_for(wk): entry(w, p, d), control),
                    name=f"cluster-{wk.name}-{phase}")
                for wk in alive
            ]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            control.raise_first()
            with done_lock:
                pending = [t for t in tasks if t not in done]
        return reexecuted

    def per_worker_stats(self) -> dict:
        return {
            wk.name: wk.store.stats_snapshot()
            for wk in self.workers
            if hasattr(wk.store, "stats_snapshot")
        }


__all__ = [
    "ClusterFailure",
    "ClusterPlan",
    "FaultyWorker",
    "PhaseDriver",
    "TaskPool",
    "ThreadWorker",
    "Worker",
    "WorkerContext",
    "WorkerFailure",
    "build_workers",
]
