"""CloudSort as a ShuffleJob: the sort, re-expressed as one instantiation.

The paper's 100 TB sort is, in library terms, nothing special: a MapOp
that loads a wave of input objects and mesh-sorts it into range-
partitioned spill runs (wrapping core/external_sort.WaveSorter — the
device kernels, zero-copy load, and spill layout are unchanged), and a
ReduceOp whose PartitionReducer is a pure streaming k-way merge (the
identical runtime.merge_fragments body the monolithic driver used).
Output bytes are byte- and etag-identical to the pre-refactor drivers at
any parallelism, worker count, and under worker kills — asserted by
tests/test_cluster.py and tests/test_shuffle.py.

The partitioner is the order-preserving RangePartitioner whose equal
boundaries reproduce core/keyspace.KeySpace's reducer boundaries
bit-for-bit; the actual map-side routing runs on the device inside
streaming_sort, and the test suite pins the two constructions together
so they can never drift.
"""
from __future__ import annotations

import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from repro.io import records as rec
from repro.io.backends import StoreBackend

from repro.shuffle.api import MapOp, PartitionReducer, ReduceOp
from repro.shuffle.job import ShuffleJob
from repro.shuffle.partition import RangePartitioner
from repro.shuffle.runtime import merge_fragments


class SortMapOp(MapOp):
    """Map side of CloudSort: load one wave zero-copy, sort it across
    the device mesh, spill one range-partitioned run per mesh worker
    (with per-reducer offsets in the spill metadata)."""

    def __init__(self, plan, mesh, axis_names, boundaries=None):
        from repro.core import external_sort as xs

        self.plan = plan
        self.sorter = xs.WaveSorter(plan, mesh, axis_names,
                                    boundaries=boundaries)
        self.num_mesh_workers = self.sorter.w
        self.spill_objects_per_task = self.sorter.w
        self.spill_offsets: dict[tuple[int, int], np.ndarray] = {}
        self.waves: list = []

    def plan_tasks(self, store: StoreBackend, bucket: str) -> int:
        from repro.core import external_sort as xs

        plan = self.plan
        inputs = store.list_objects(bucket, plan.input_prefix)
        if not inputs:
            raise ValueError(
                f"input_prefix={plan.input_prefix!r}: no input objects")
        counts = [(m.size - rec.HEADER_BYTES) // plan.record_bytes
                  for m in inputs]
        self.waves = xs._group_waves(inputs, counts, plan.records_per_wave)
        self.total_records = sum(counts)
        self.working_set_records = plan.records_per_wave
        return len(self.waves)

    def load(self, store: StoreBackend, bucket: str, task: int):
        return self.sorter.load_wave(store, bucket, self.waves[task])

    def spill_keys(self, task: int) -> list[str]:
        from repro.core import external_sort as xs

        return [xs._spill_key(self.plan, task, wid)
                for wid in range(self.sorter.w)]

    def process(self, store: StoreBackend, bucket: str, task: int, data, *,
                spiller, timeline, tag) -> None:
        keys, ids, payload = data
        self.sorter.compute_and_spill(
            store, bucket, task, keys, ids, payload, spiller=spiller,
            timeline=timeline, tag=tag, offsets_out=self.spill_offsets)

    # Staged map interface (shuffle/runtime's pipelined executor, active
    # when plan.map_pipeline is true): the same body as process(), split
    # at the device boundary so wave N's sort overlaps wave N-1's encode.
    def device_step(self, task: int, data, *, timeline, tag):
        keys, ids, payload = data
        return data, self.sorter.device_sort(keys, ids, timeline=timeline,
                                             tag=tag)

    def encode_step(self, store: StoreBackend, bucket: str, task: int,
                    staged, *, spiller, timeline, tag) -> None:
        (keys, ids, payload), (sk, si, vcounts) = staged
        self.sorter.encode_and_spill(
            store, bucket, task, sk, si, vcounts, ids, payload,
            spiller=spiller, timeline=timeline, tag=tag,
            offsets_out=self.spill_offsets)


class _SortMergeSink(PartitionReducer):
    """Streaming k-way merge: the record count is known up front (sum of
    run-slice lengths), so the header streams first and sorted body
    chunks follow — exactly the monolithic reduce body, hence exactly
    its bytes."""

    deferred_part0 = False

    def __init__(self, n_total: int, payload_words: int):
        self._n = int(n_total)
        self._pw = int(payload_words)

    def begin(self) -> bytes:
        return rec.encode_header(self._n, self._pw)

    def consume(self, frags, *, final: bool) -> bytes:
        mk, mi, mp = merge_fragments(frags, self._pw)
        return rec.encode_body(mk, mi, mp) if mk.size else b""


class _DeviceMergeSink(PartitionReducer):
    """Device-resident k-way merge, double-buffered.

    Same byte STREAM as _SortMergeSink, shifted one cycle: consume()
    hands the emit window to a one-thread merge+encode stage
    (kernels/kway_merge.merge_fragments_device — bit-identical to the
    numpy merge, see that module's docstring) and returns the PREVIOUS
    window's encoded bytes, so window i's merge overlaps window i+1's
    ranged-GET fetches; finalize() flushes the last window. Because the
    scheduler slices parts from the concatenated stream at fixed record
    boundaries, parts and etags are identical to the numpy backend at
    any parallelism — pinned by tests/test_device_merge.py.

    Memory: one extra in-flight window (<= runs x chunk decoded bytes)
    rides on top of the budget governor's per-reducer accounting while
    the stage thread drains it.

    A merge failure surfaces on the next consume()/finalize() — the
    scheduler's normal error path (abort the multipart session, retire
    the grant). The stage pool is shut down on finalize and on the first
    error; a reducer abandoned mid-stream (worker death elsewhere)
    releases its idle thread when the sink is collected.
    """

    deferred_part0 = False

    def __init__(self, n_total: int, payload_words: int, *,
                 impl: str = "pallas"):
        self._n = int(n_total)
        self._pw = int(payload_words)
        self._impl = impl
        self._timeline = None
        self._tag = ""
        self._pool = ThreadPoolExecutor(max_workers=1,
                                        thread_name_prefix="device-merge")
        self._pending = None

    def bind_exec(self, *, timeline, tag: str) -> None:
        # Optional sink hook the ReduceScheduler calls right after
        # open(): stage-thread work records reduce.device_merge spans
        # with this partition's tag.
        self._timeline = timeline
        self._tag = tag

    def begin(self) -> bytes:
        return rec.encode_header(self._n, self._pw)

    def _merge_encode(self, frags) -> bytes:
        from repro.kernels.kway_merge import merge_fragments_device

        t = time.perf_counter()
        mk, mi, mp = merge_fragments_device(frags, self._pw,
                                            impl=self._impl)
        body = rec.encode_body(mk, mi, mp) if mk.size else b""
        if self._timeline is not None:
            self._timeline.add("reduce.device_merge", t, worker=self._tag)
        return body

    def consume(self, frags, *, final: bool) -> bytes:
        job = self._pool.submit(self._merge_encode, frags)
        prev, self._pending = self._pending, job
        if prev is None:
            return b""
        try:
            return prev.result()
        except BaseException:
            self._pool.shutdown(wait=False)
            raise

    def finalize(self):
        try:
            tail = b"" if self._pending is None else self._pending.result()
        finally:
            self._pool.shutdown(wait=True)
        return tail, None


class MergeReduceOp(ReduceOp):
    """Reduce side of CloudSort: partition r streams its slice of every
    spilled run (located by the offsets the map side recorded) through
    a k-way merge into a multipart-uploaded output partition."""

    def __init__(self, plan, map_op: SortMapOp):
        self.plan = plan
        self.map_op = map_op
        self.payload_words = plan.payload_words

    def sources(self, r: int) -> tuple[list[tuple[str, int, int]], int]:
        from repro.core import external_sort as xs

        plan, map_op = self.plan, self.map_op
        wid, j = divmod(r, map_op.sorter.r1)
        slices, n_total = [], 0
        for g in range(len(map_op.waves)):
            offs = map_op.spill_offsets[(g, wid)]
            lo, hi = int(offs[j]), int(offs[j + 1])
            if hi > lo:
                slices.append((xs._spill_key(plan, g, wid), lo, hi))
                n_total += hi - lo
        return slices, n_total

    def output_key(self, r: int) -> str:
        from repro.core import external_sort as xs

        return xs._output_key(self.plan, r)

    def output_metadata(self, r: int, n_total: int) -> dict:
        return {"records": n_total, "reducer": r}

    def open(self, r: int, n_total: int) -> PartitionReducer:
        return _SortMergeSink(n_total, self.payload_words)


class DeviceMergeReduceOp(MergeReduceOp):
    """MergeReduceOp with the device-resident, double-buffered merge
    sink (_DeviceMergeSink) — selected by
    ExternalSortPlan.reduce_merge_impl="device". Sources, output keys,
    chunk sizing (the AdaptiveBudgetGovernor), and output bytes are all
    identical to the numpy backend; only where (and when) the window
    merge runs changes.

    Lowering: plan.impl="ref" selects the CPU reference MAP sorter, but
    for the merge stage the lax.sort oracle it would pick is ~5x slower
    than the tournament network — so "ref" maps to the kernel's "pallas"
    auto-lowering (pallas_call on accelerators, the jit'd network on
    CPU; all three are pinned bit-identical in tests/test_kernels.py).
    An explicit pallas/network plan.impl is honored as-is."""

    def open(self, r: int, n_total: int) -> PartitionReducer:
        impl = "pallas" if self.plan.impl == "ref" else self.plan.impl
        return _DeviceMergeSink(n_total, self.payload_words, impl=impl)


def sort_shuffle_job(store: StoreBackend, bucket: str, *, mesh, axis_names,
                     plan, tracer=None, boundaries=None) -> ShuffleJob:
    """Build the CloudSort ShuffleJob: SortMapOp + MergeReduceOp (or
    DeviceMergeReduceOp, per plan.reduce_merge_impl) over an
    order-preserving range partitioner. `plan` is a
    core/external_sort.ExternalSortPlan; run with
    `job.run(workers=N[, cluster=ClusterPlan(...)])`. `tracer` is an
    optional obs/events.Tracer the run records into (share it with the
    store stack to get request-level child spans). `boundaries` replaces
    the equal key split with W*R1-1 explicit reducer boundaries (the
    sampling pre-pass quantiles — shuffle/job.sample_boundaries); the
    SAME values feed both the host RangePartitioner and the device
    keyspace routing so the two stay bit-consistent."""
    map_op = SortMapOp(plan, mesh, axis_names, boundaries=boundaries)
    if getattr(plan, "reduce_merge_impl", "numpy") == "device":
        reduce_op: MergeReduceOp = DeviceMergeReduceOp(plan, map_op)
    else:
        reduce_op = MergeReduceOp(plan, map_op)
    partitioner = RangePartitioner(map_op.sorter.w * map_op.sorter.r1,
                                   boundaries=boundaries)
    return ShuffleJob(store, bucket, plan=plan, map_op=map_op,
                      reduce_op=reduce_op, partitioner=partitioner,
                      tracer=tracer)


__all__ = ["DeviceMergeReduceOp", "MergeReduceOp", "SortMapOp",
           "sort_shuffle_job"]
