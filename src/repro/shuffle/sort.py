"""CloudSort as a ShuffleJob: the sort, re-expressed as one instantiation.

The paper's 100 TB sort is, in library terms, nothing special: a MapOp
that loads a wave of input objects and mesh-sorts it into range-
partitioned spill runs (wrapping core/external_sort.WaveSorter — the
device kernels, zero-copy load, and spill layout are unchanged), and a
ReduceOp whose PartitionReducer is a pure streaming k-way merge (the
identical runtime.merge_fragments body the monolithic driver used).
Output bytes are byte- and etag-identical to the pre-refactor drivers at
any parallelism, worker count, and under worker kills — asserted by
tests/test_cluster.py and tests/test_shuffle.py.

The partitioner is the order-preserving RangePartitioner whose equal
boundaries reproduce core/keyspace.KeySpace's reducer boundaries
bit-for-bit; the actual map-side routing runs on the device inside
streaming_sort, and the test suite pins the two constructions together
so they can never drift.
"""
from __future__ import annotations

import numpy as np

from repro.io import records as rec
from repro.io.backends import StoreBackend

from repro.shuffle.api import MapOp, PartitionReducer, ReduceOp
from repro.shuffle.job import ShuffleJob
from repro.shuffle.partition import RangePartitioner
from repro.shuffle.runtime import merge_fragments


class SortMapOp(MapOp):
    """Map side of CloudSort: load one wave zero-copy, sort it across
    the device mesh, spill one range-partitioned run per mesh worker
    (with per-reducer offsets in the spill metadata)."""

    def __init__(self, plan, mesh, axis_names):
        from repro.core import external_sort as xs

        self.plan = plan
        self.sorter = xs.WaveSorter(plan, mesh, axis_names)
        self.num_mesh_workers = self.sorter.w
        self.spill_objects_per_task = self.sorter.w
        self.spill_offsets: dict[tuple[int, int], np.ndarray] = {}
        self.waves: list = []

    def plan_tasks(self, store: StoreBackend, bucket: str) -> int:
        from repro.core import external_sort as xs

        plan = self.plan
        inputs = store.list_objects(bucket, plan.input_prefix)
        if not inputs:
            raise ValueError(
                f"input_prefix={plan.input_prefix!r}: no input objects")
        counts = [(m.size - rec.HEADER_BYTES) // plan.record_bytes
                  for m in inputs]
        self.waves = xs._group_waves(inputs, counts, plan.records_per_wave)
        self.total_records = sum(counts)
        self.working_set_records = plan.records_per_wave
        return len(self.waves)

    def load(self, store: StoreBackend, bucket: str, task: int):
        return self.sorter.load_wave(store, bucket, self.waves[task])

    def process(self, store: StoreBackend, bucket: str, task: int, data, *,
                spiller, timeline, tag) -> None:
        keys, ids, payload = data
        self.sorter.compute_and_spill(
            store, bucket, task, keys, ids, payload, spiller=spiller,
            timeline=timeline, tag=tag, offsets_out=self.spill_offsets)


class _SortMergeSink(PartitionReducer):
    """Streaming k-way merge: the record count is known up front (sum of
    run-slice lengths), so the header streams first and sorted body
    chunks follow — exactly the monolithic reduce body, hence exactly
    its bytes."""

    deferred_part0 = False

    def __init__(self, n_total: int, payload_words: int):
        self._n = int(n_total)
        self._pw = int(payload_words)

    def begin(self) -> bytes:
        return rec.encode_header(self._n, self._pw)

    def consume(self, frags, *, final: bool) -> bytes:
        mk, mi, mp = merge_fragments(frags, self._pw)
        return rec.encode_body(mk, mi, mp) if mk.size else b""


class MergeReduceOp(ReduceOp):
    """Reduce side of CloudSort: partition r streams its slice of every
    spilled run (located by the offsets the map side recorded) through
    a k-way merge into a multipart-uploaded output partition."""

    def __init__(self, plan, map_op: SortMapOp):
        self.plan = plan
        self.map_op = map_op
        self.payload_words = plan.payload_words

    def sources(self, r: int) -> tuple[list[tuple[str, int, int]], int]:
        from repro.core import external_sort as xs

        plan, map_op = self.plan, self.map_op
        wid, j = divmod(r, map_op.sorter.r1)
        slices, n_total = [], 0
        for g in range(len(map_op.waves)):
            offs = map_op.spill_offsets[(g, wid)]
            lo, hi = int(offs[j]), int(offs[j + 1])
            if hi > lo:
                slices.append((xs._spill_key(plan, g, wid), lo, hi))
                n_total += hi - lo
        return slices, n_total

    def output_key(self, r: int) -> str:
        from repro.core import external_sort as xs

        return xs._output_key(self.plan, r)

    def output_metadata(self, r: int, n_total: int) -> dict:
        return {"records": n_total, "reducer": r}

    def open(self, r: int, n_total: int) -> PartitionReducer:
        return _SortMergeSink(n_total, self.payload_words)


def sort_shuffle_job(store: StoreBackend, bucket: str, *, mesh, axis_names,
                     plan, tracer=None) -> ShuffleJob:
    """Build the CloudSort ShuffleJob: SortMapOp + MergeReduceOp over an
    order-preserving range partitioner. `plan` is a
    core/external_sort.ExternalSortPlan; run with
    `job.run(workers=N[, cluster=ClusterPlan(...)])`. `tracer` is an
    optional obs/events.Tracer the run records into (share it with the
    store stack to get request-level child spans)."""
    map_op = SortMapOp(plan, mesh, axis_names)
    reduce_op = MergeReduceOp(plan, map_op)
    partitioner = RangePartitioner(map_op.sorter.w * map_op.sorter.r1)
    return ShuffleJob(store, bucket, plan=plan, map_op=map_op,
                      reduce_op=reduce_op, partitioner=partitioner,
                      tracer=tracer)


__all__ = ["MergeReduceOp", "SortMapOp", "sort_shuffle_job"]
