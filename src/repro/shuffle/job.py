"""ShuffleJob / ShuffleSession: the library front end.

A ShuffleJob is a workload description — store + bucket + plan + the
three operators and a partitioner. One `job.run(workers=N)` call owns
everything the drivers used to hand-roll per workload:

  * plan validation (api.validate_dataflow_plan + the plan's own
    `validate`) and operator preflight, before any input byte is billed;
  * wave/split enumeration via MapOp.plan_tasks, budget feasibility
    (runtime.reduce_chunking) and the AdaptiveBudgetGovernor, sized to
    the cluster-wide merge concurrency;
  * stale spill/output prefix cleanup and baseline store counters, so
    the report's measured traffic is this run's alone;
  * the span timeline and job-wide cancellation;
  * execution: inline single-host (workers=0) or the multi-worker phase
    driver with durable-confirmation failure recovery (workers>=1, or an
    explicit Worker fleet for failure injection).

The sort and group-by instantiations (shuffle/sort.py,
shuffle/groupby.py) differ only in the operators they pass here — which
is the paper's point.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Callable, Sequence

import numpy as np

from repro.io import records as rec
from repro.io.backends import StoreBackend, StoreStats
from repro.obs.context import use_context
from repro.obs.events import Tracer

from repro.shuffle import executor as ex
from repro.shuffle import runtime as rt
from repro.shuffle.api import (ClusterShuffleReport, MapOp, Partitioner,
                               ReduceOp, ShuffleReport, require,
                               validate_dataflow_plan)
from repro.shuffle.partition import quantile_boundaries


@dataclasses.dataclass
class KeySample:
    """Result of the sampling pre-pass (`sample_boundaries`): the
    splitter quantiles plus enough of the sampled distribution to
    predict per-partition sizes (the recursive driver's oversize
    criterion)."""

    boundaries: np.ndarray  # (parts-1,) uint32 routed-domain quantiles
    sample: np.ndarray  # sorted routed sample values (uint32)
    records_total: int  # records under the sampled prefix
    records_sampled: int
    get_requests: int  # ranged GETs the pre-pass issued (billed)
    seconds: float

    def partition_records(self) -> np.ndarray:
        """Estimated records per partition under `boundaries`: the
        sample's per-partition counts scaled to the full input (ceil —
        an overestimate errs toward re-shuffling, never toward an
        oversized merge)."""
        dest = np.searchsorted(self.boundaries, self.sample, side="right")
        counts = np.bincount(dest, minlength=self.boundaries.size + 1)
        scale = self.records_total / max(self.sample.size, 1)
        return np.ceil(counts * scale).astype(np.int64)


def sample_boundaries(store: StoreBackend, bucket: str, *, input_prefix: str,
                      payload_words: int, sample_fraction: float, parts: int,
                      tracer: Tracer | None = None,
                      route: Callable[[np.ndarray, np.ndarray], np.ndarray]
                      | None = None,
                      block_records: int = 256) -> KeySample:
    """The sampling pre-pass: Daytona-style splitter estimation over the
    real store, billed and traced like any other phase.

    Reads ~`sample_fraction` of every input object's records through
    evenly spaced ranged GETs (contiguous blocks of up to
    `block_records`, positions pure arithmetic — no RNG, so the
    resulting boundaries are deterministic for a given input + knobs)
    and returns the `parts`-way quantile splitters of the sampled keys.
    Runs under TraceContext phase="sample": a tracing store stack
    attributes the GETs/bytes to the sample phase, each fetch records a
    `sample.fetch` span, and a `phase.seconds{phase=sample}` gauge lands
    next to the map/reduce phase gauges.

    `route` optionally maps (keys, ids) -> routed uint32 values before
    the quantiles are taken — the recursive driver passes the
    next-key-bits routing of a sub-range so child boundaries live in the
    child's routed domain.
    """
    require(0.0 < sample_fraction <= 1.0, "sample_fraction", sample_fraction,
            "the sampling pre-pass needs a fraction in (0, 1]")
    require(parts >= 1, "parts", parts, "must split into >= 1 partition")
    require(block_records >= 1, "block_records", block_records,
            "must fetch >= 1 record per ranged GET")
    rb = rec.record_bytes(payload_words)
    tracer = tracer if tracer is not None else Tracer(job="shuffle")
    ctx = tracer.root.with_phase("sample").with_worker("host")
    t_start = time.perf_counter()
    gets = 0
    total = 0
    sampled_k: list[np.ndarray] = []
    sampled_i: list[np.ndarray] = []
    with use_context(ctx):
        inputs = store.list_objects(bucket, input_prefix)
        require(bool(inputs), "input_prefix", input_prefix,
                "no input objects to sample")
        for meta in inputs:
            n = (meta.size - rec.HEADER_BYTES) // rb
            total += n
            if n == 0:
                continue
            m = max(1, int(round(n * sample_fraction)))
            nblocks = -(-m // block_records)
            base, extra = divmod(m, nblocks)
            for b in range(nblocks):
                take = base + (1 if b < extra else 0)
                start_rec = min((b * n) // nblocks, n - take)
                off, length = rec.body_range(start_rec, take, payload_words)
                t0 = time.perf_counter()
                body = store.get_range(bucket, meta.key, off, length)
                gets += 1
                k, i, _ = rec.decode_body(body, payload_words)
                tracer.event("sample.fetch", t0, ctx=ctx, key=meta.key,
                             records=take, nbytes=length)
                sampled_k.append(k)
                sampled_i.append(i)
    keys = (np.concatenate(sampled_k) if sampled_k
            else np.empty((0,), np.uint32))
    ids = (np.concatenate(sampled_i) if sampled_i
           else np.empty((0,), np.uint32))
    require(keys.size >= 1, "input_prefix", input_prefix,
            "sampled zero records — every input object is empty")
    routed = keys if route is None else route(keys, ids)
    routed = np.sort(np.asarray(routed, np.uint32).reshape(-1))
    bounds = quantile_boundaries(routed, parts)
    seconds = time.perf_counter() - t_start
    tracer.event("sample.boundaries", t_start, ctx=ctx, parts=parts,
                 records_sampled=int(keys.size), records_total=int(total),
                 get_requests=gets)
    tracer.registry.gauge("phase.seconds", seconds, phase="sample")
    return KeySample(boundaries=bounds, sample=routed,
                     records_total=int(total),
                     records_sampled=int(keys.size), get_requests=gets,
                     seconds=seconds)


class ShuffleSession:
    """One prepared run of a ShuffleJob: validated plan, enumerated map
    tasks, feasibility-checked budget governor, cleared prefixes, and
    baseline store counters. Create via ShuffleJob.prepare()/run() —
    a session is single-use (the governor and operator state are one
    run's)."""

    def __init__(self, job: "ShuffleJob", *, schedulers: int):
        store, bucket, plan = job.store, job.bucket, job.plan
        self.job = job
        # Validation first: fail before any input byte is fetched/billed.
        if hasattr(plan, "validate"):
            plan.validate()
        else:
            validate_dataflow_plan(plan)
        self.num_tasks = job.map_op.plan_tasks(store, bucket)
        require(self.num_tasks >= 1, "input_prefix", plan.input_prefix,
                "MapOp.plan_tasks found no input splits")
        self.num_partitions = job.partitioner.num_partitions
        # Governor slots = the cluster-wide concurrent-merge ceiling:
        # every scheduler (one per worker) draws on one global budget.
        self.slots = min(max(int(schedulers), 1) * plan.parallel_reducers,
                         self.num_partitions)
        # One Tracer per run unless the job brought its own (examples
        # pass the same tracer to the store stack so request attempts
        # land on the same timeline as the spans).
        self.tracer = (job.tracer if job.tracer is not None
                       else Tracer(job="shuffle"))
        # Budget feasibility is pure plan validation (each partition
        # streams at most one run per map task). A ReduceOp that drains
        # every partition sequentially (shuffle/recursive's redirected
        # partitions pull one run at a time) reports its smaller
        # worst-case fan-in through the optional feasibility_runs hook.
        feas = getattr(job.reduce_op, "feasibility_runs", None)
        feas_runs = (max(1, int(feas(self.num_tasks))) if callable(feas)
                     else self.num_tasks)
        _, self.chunk_bytes = rt.reduce_chunking(plan, feas_runs, self.slots)
        self.governor = rt.AdaptiveBudgetGovernor(
            budget=plan.reduce_memory_budget_bytes,
            chunk_cap=plan.merge_chunk_bytes,
            record_bytes=plan.record_bytes,
            slots=self.slots,
            partitions=self.num_partitions,
            tracer=self.tracer,
        )
        # Overwrite semantics: clear stale spill/output objects from any
        # prior run so the reduce pass and downstream validation see only
        # this run.
        for prefix in (plan.spill_prefix, plan.output_prefix):
            for meta in store.list_objects(bucket, prefix):
                store.delete(bucket, meta.key)
        # Bare data planes (no MetricsMiddleware anywhere) still run;
        # their reports just carry zeroed counters.
        self.base_stats = (store.stats_snapshot()
                           if hasattr(store, "stats_snapshot")
                           else StoreStats())
        self.tier_base = (store.per_tier_stats()
                          if hasattr(store, "per_tier_stats") else None)
        # Run-scoped execution state. The timeline mirrors every span
        # into the tracer (absolute times; the tracer normalises to its
        # own origin), so the Chrome trace sees exactly what the report
        # sees.
        self.timeline = rt.PhaseTimeline(origin=time.perf_counter(),
                                         sink=self.tracer.timeline_sink())
        self.control = rt.JobControl()
        self.peak = rt.PeakTracker()
        self.shared = rt.ReduceShared(
            plan=plan, bucket=bucket, reduce_op=job.reduce_op,
            governor=self.governor, timeline=self.timeline, peak=self.peak,
            control=self.control,
        )

    # -- execution ---------------------------------------------------------

    def run_single_host(self) -> ShuffleReport:
        """The inline driver: one staged map loop, one reduce scheduler
        running `slots` streaming merges."""
        job = self.job
        store, bucket, plan = job.store, job.bucket, job.plan
        t0 = time.perf_counter()
        pending = collections.deque(range(self.num_tasks))
        pop_lock = threading.Lock()

        def pop_task() -> int | None:
            with pop_lock:
                return pending.popleft() if pending else None

        with use_context(self.tracer.root):
            rt.run_map_tasks(store, bucket, job.map_op, pop_task, plan=plan,
                             timeline=self.timeline, control=self.control)
        map_seconds = time.perf_counter() - t0

        parts = collections.deque(range(self.num_partitions))

        def pop_partition() -> int | None:
            with pop_lock:
                return parts.popleft() if parts else None

        t0 = time.perf_counter()
        with use_context(self.tracer.root):
            rt.ReduceScheduler(store, self.shared, width=self.slots,
                               runs_hint=self.num_tasks).run(pop_partition)
        self.control.raise_first()
        reduce_seconds = time.perf_counter() - t0
        return self.build_report(map_seconds=map_seconds,
                                 reduce_seconds=reduce_seconds)

    def run_cluster(self,
                    workers: Sequence[ex.Worker]) -> ClusterShuffleReport:
        """The multi-worker driver: two barriered phases of rounds over
        the surviving fleet, re-executing whatever a dead worker never
        durably confirmed (see shuffle/executor.PhaseDriver)."""
        job = self.job
        ctx = ex.WorkerContext(
            plan=job.plan, bucket=job.bucket, map_op=job.map_op,
            reduce_shared=self.shared, timeline=self.timeline,
            control=self.control, num_map_tasks=self.num_tasks,
        )
        driver = ex.PhaseDriver(workers, tracer=self.tracer)

        t_origin = time.perf_counter()
        reexec_map = driver.run_phase(
            "map", list(range(self.num_tasks)),
            lambda wk, pop, done: wk.run_map_phase(ctx, pop, done),
            self.control)
        map_seconds = time.perf_counter() - t_origin

        t_reduce = time.perf_counter()
        reexec_reduce = driver.run_phase(
            "reduce", list(range(self.num_partitions)),
            lambda wk, pop, done: wk.run_reduce_phase(ctx, pop, done),
            self.control)
        reduce_seconds = time.perf_counter() - t_reduce

        return ClusterShuffleReport(
            report=self.build_report(map_seconds=map_seconds,
                                     reduce_seconds=reduce_seconds),
            num_cluster_workers=len(driver.workers),
            failed_workers=list(driver.failed_workers),
            reexecuted_map_tasks=reexec_map,
            reexecuted_reduce_tasks=reexec_reduce,
            map_tasks=self.num_tasks,
            reduce_tasks=self.num_partitions,
            per_worker_stats=driver.per_worker_stats(),
            per_worker_tasks=dict(driver.per_worker_tasks),
        )

    def run_elastic(self, workers: Sequence[ex.Worker],
                    fleet) -> ClusterShuffleReport:
        """The elastic driver: membership + heartbeats, in-phase claim
        release, straggler speculation with loser-abort commits, and
        correlated spill-tier loss with lineage-tracked map re-execution
        (see shuffle/elastic.ElasticPhaseDriver). `fleet` is a
        shuffle/elastic.FleetPlan. Returns the driver too — callers that
        admit/retire workers mid-job grab it via `session.driver`."""
        from repro.shuffle.elastic import ElasticPhaseDriver

        job = self.job
        ctx = ex.WorkerContext(
            plan=job.plan, bucket=job.bucket, map_op=job.map_op,
            reduce_shared=self.shared, timeline=self.timeline,
            control=self.control, num_map_tasks=self.num_tasks,
        )
        driver = self.driver = ElasticPhaseDriver(
            workers, fleet=fleet, store=job.store, bucket=job.bucket,
            tracer=self.tracer)
        driver.run_job(ctx, num_map_tasks=self.num_tasks,
                       num_partitions=self.num_partitions)
        self.control.raise_first()
        counters = driver.pool_counters()
        return ClusterShuffleReport(
            report=self.build_report(map_seconds=driver.map_seconds,
                                     reduce_seconds=driver.reduce_seconds),
            num_cluster_workers=len(driver.workers),
            failed_workers=list(driver.failed_workers),
            map_tasks=self.num_tasks,
            reduce_tasks=self.num_partitions,
            per_worker_stats=driver.per_worker_stats(),
            per_worker_tasks=dict(driver.per_worker_tasks),
            heartbeat_misses=driver.heartbeat_misses,
            spill_lost_map_tasks=driver.spill_lost_map_tasks,
            requeued_reduce_tasks=driver.requeued_reduce_tasks,
            workers_admitted=driver.workers_admitted,
            workers_retired=driver.workers_retired,
            recovery_rounds=driver.recovery_rounds,
            **counters,
        )

    # -- reporting ---------------------------------------------------------

    def build_report(self, *, map_seconds: float,
                     reduce_seconds: float) -> ShuffleReport:
        """Assemble the run report from the session + measured state —
        the one place the report contract is populated, for every
        workload and both execution modes."""
        job = self.job
        store, plan, map_op = job.store, job.plan, job.map_op
        tier_stats = None
        if self.tier_base is not None:
            tier_now = store.per_tier_stats()
            tier_stats = {name: tier_now[name] - self.tier_base[name]
                          for name in tier_now}
        reg = self.tracer.registry
        reg.gauge("phase.seconds", map_seconds, phase="map")
        reg.gauge("phase.seconds", reduce_seconds, phase="reduce")
        # Derive bytes/s gauges from the phase-labelled byte counters the
        # TracingMiddleware maintains (zero counters = no tracing store
        # wired in; skip rather than emit misleading zero rates).
        for phase, seconds, metric in (
                ("map", map_seconds, "store.bytes_read"),
                ("map", map_seconds, "store.bytes_written"),
                ("reduce", reduce_seconds, "store.bytes_read"),
                ("reduce", reduce_seconds, "store.bytes_written")):
            nbytes = reg.total(metric, phase=phase)
            if nbytes and seconds > 0:
                reg.gauge(metric + "_per_s", nbytes / seconds, phase=phase)
        return ShuffleReport(
            total_records=map_op.total_records,
            num_waves=self.num_tasks,
            num_workers=map_op.num_mesh_workers,
            num_reducers=self.num_partitions,
            spill_objects=self.num_tasks * map_op.spill_objects_per_task,
            output_objects=self.num_partitions,
            map_seconds=map_seconds,
            reduce_seconds=reduce_seconds,
            working_set_records=map_op.working_set_records,
            stats=(store.stats_snapshot() - self.base_stats
                   if hasattr(store, "stats_snapshot") else StoreStats()),
            runs_per_reducer=self.num_tasks,
            merge_chunk_bytes=plan.merge_chunk_bytes,
            reduce_chunk_bytes=self.chunk_bytes,
            reduce_chunk_bytes_max=self.governor.max_chunk_bytes,
            reduce_peak_merge_bytes=self.peak.peak,
            parallel_reducers=self.slots,
            reduce_memory_budget_bytes=plan.reduce_memory_budget_bytes,
            tier_stats=tier_stats,
            spans=self.timeline.spans(),
            spans_dropped=self.timeline.dropped,
            phase_seconds=self.timeline.totals(),
            metrics=reg.snapshot(),
        )


class ShuffleJob:
    """A shuffle workload: operators + partitioner + plan over one store.

    The public entry point of the library. `run(workers=N)` executes the
    whole dataflow — N=0 inline on the calling host, N>=1 across N
    emulated workers with application-level failure recovery; pass
    `cluster=` (a shuffle/executor.ClusterPlan) to inject worker deaths,
    or `worker_list=` to bring a hand-built Worker fleet.
    """

    def __init__(self, store: StoreBackend, bucket: str, *, plan,
                 map_op: MapOp, reduce_op: ReduceOp,
                 partitioner: Partitioner, tracer: Tracer | None = None):
        self.store = store
        self.bucket = bucket
        self.plan = plan
        self.map_op = map_op
        self.reduce_op = reduce_op
        self.partitioner = partitioner
        self.tracer = tracer

    def prepare(self, *, schedulers: int = 1) -> ShuffleSession:
        """Preflight one run (validation, task enumeration, governor,
        prefix cleanup) without executing it. `schedulers` is how many
        reduce schedulers will draw on the global budget (1 single-host;
        the worker count in cluster mode)."""
        return ShuffleSession(self, schedulers=schedulers)

    def run(self, workers: int = 0, *,
            cluster: ex.ClusterPlan | None = None,
            worker_list: Sequence[ex.Worker] | None = None,
            fleet=None):
        """Execute the job; returns a ShuffleReport (single-host) or a
        ClusterShuffleReport (cluster mode). Passing `fleet` (a
        shuffle/elastic.FleetPlan) with a `worker_list` selects the
        elastic driver — heartbeats, speculation, spill-loss recovery —
        instead of the round-barriered PhaseDriver."""
        if worker_list is not None:
            crew: Sequence[ex.Worker] | None = list(worker_list)
        elif cluster is not None:
            crew = ex.build_workers(self.store, cluster)
        elif workers >= 1:
            crew = ex.build_workers(self.store,
                                    ex.ClusterPlan(num_workers=workers))
        else:
            crew = None
        if crew is None:
            require(fleet is None, "fleet", fleet,
                    "the elastic driver needs a worker_list")
            return self.prepare(schedulers=1).run_single_host()
        require(len(crew) >= 1, "worker_list", len(crew),
                "must supply >= 1 worker")
        session = self.prepare(schedulers=len(crew))
        if fleet is not None:
            return session.run_elastic(crew, fleet)
        return session.run_cluster(crew)


__all__ = ["KeySample", "ShuffleJob", "ShuffleSession", "sample_boundaries"]
