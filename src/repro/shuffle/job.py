"""ShuffleJob / ShuffleSession: the library front end.

A ShuffleJob is a workload description — store + bucket + plan + the
three operators and a partitioner. One `job.run(workers=N)` call owns
everything the drivers used to hand-roll per workload:

  * plan validation (api.validate_dataflow_plan + the plan's own
    `validate`) and operator preflight, before any input byte is billed;
  * wave/split enumeration via MapOp.plan_tasks, budget feasibility
    (runtime.reduce_chunking) and the AdaptiveBudgetGovernor, sized to
    the cluster-wide merge concurrency;
  * stale spill/output prefix cleanup and baseline store counters, so
    the report's measured traffic is this run's alone;
  * the span timeline and job-wide cancellation;
  * execution: inline single-host (workers=0) or the multi-worker phase
    driver with durable-confirmation failure recovery (workers>=1, or an
    explicit Worker fleet for failure injection).

The sort and group-by instantiations (shuffle/sort.py,
shuffle/groupby.py) differ only in the operators they pass here — which
is the paper's point.
"""
from __future__ import annotations

import collections
import threading
import time
from typing import Sequence

from repro.io.backends import StoreBackend, StoreStats
from repro.obs.context import use_context
from repro.obs.events import Tracer

from repro.shuffle import executor as ex
from repro.shuffle import runtime as rt
from repro.shuffle.api import (ClusterShuffleReport, MapOp, Partitioner,
                               ReduceOp, ShuffleReport, require,
                               validate_dataflow_plan)


class ShuffleSession:
    """One prepared run of a ShuffleJob: validated plan, enumerated map
    tasks, feasibility-checked budget governor, cleared prefixes, and
    baseline store counters. Create via ShuffleJob.prepare()/run() —
    a session is single-use (the governor and operator state are one
    run's)."""

    def __init__(self, job: "ShuffleJob", *, schedulers: int):
        store, bucket, plan = job.store, job.bucket, job.plan
        self.job = job
        # Validation first: fail before any input byte is fetched/billed.
        if hasattr(plan, "validate"):
            plan.validate()
        else:
            validate_dataflow_plan(plan)
        self.num_tasks = job.map_op.plan_tasks(store, bucket)
        require(self.num_tasks >= 1, "input_prefix", plan.input_prefix,
                "MapOp.plan_tasks found no input splits")
        self.num_partitions = job.partitioner.num_partitions
        # Governor slots = the cluster-wide concurrent-merge ceiling:
        # every scheduler (one per worker) draws on one global budget.
        self.slots = min(max(int(schedulers), 1) * plan.parallel_reducers,
                         self.num_partitions)
        # One Tracer per run unless the job brought its own (examples
        # pass the same tracer to the store stack so request attempts
        # land on the same timeline as the spans).
        self.tracer = (job.tracer if job.tracer is not None
                       else Tracer(job="shuffle"))
        # Budget feasibility is pure plan validation (each partition
        # streams at most one run per map task).
        _, self.chunk_bytes = rt.reduce_chunking(
            plan, self.num_tasks, self.slots)
        self.governor = rt.AdaptiveBudgetGovernor(
            budget=plan.reduce_memory_budget_bytes,
            chunk_cap=plan.merge_chunk_bytes,
            record_bytes=plan.record_bytes,
            slots=self.slots,
            partitions=self.num_partitions,
            tracer=self.tracer,
        )
        # Overwrite semantics: clear stale spill/output objects from any
        # prior run so the reduce pass and downstream validation see only
        # this run.
        for prefix in (plan.spill_prefix, plan.output_prefix):
            for meta in store.list_objects(bucket, prefix):
                store.delete(bucket, meta.key)
        # Bare data planes (no MetricsMiddleware anywhere) still run;
        # their reports just carry zeroed counters.
        self.base_stats = (store.stats_snapshot()
                           if hasattr(store, "stats_snapshot")
                           else StoreStats())
        self.tier_base = (store.per_tier_stats()
                          if hasattr(store, "per_tier_stats") else None)
        # Run-scoped execution state. The timeline mirrors every span
        # into the tracer (absolute times; the tracer normalises to its
        # own origin), so the Chrome trace sees exactly what the report
        # sees.
        self.timeline = rt.PhaseTimeline(origin=time.perf_counter(),
                                         sink=self.tracer.timeline_sink())
        self.control = rt.JobControl()
        self.peak = rt.PeakTracker()
        self.shared = rt.ReduceShared(
            plan=plan, bucket=bucket, reduce_op=job.reduce_op,
            governor=self.governor, timeline=self.timeline, peak=self.peak,
            control=self.control,
        )

    # -- execution ---------------------------------------------------------

    def run_single_host(self) -> ShuffleReport:
        """The inline driver: one staged map loop, one reduce scheduler
        running `slots` streaming merges."""
        job = self.job
        store, bucket, plan = job.store, job.bucket, job.plan
        t0 = time.perf_counter()
        pending = collections.deque(range(self.num_tasks))
        pop_lock = threading.Lock()

        def pop_task() -> int | None:
            with pop_lock:
                return pending.popleft() if pending else None

        with use_context(self.tracer.root):
            rt.run_map_tasks(store, bucket, job.map_op, pop_task, plan=plan,
                             timeline=self.timeline, control=self.control)
        map_seconds = time.perf_counter() - t0

        parts = collections.deque(range(self.num_partitions))

        def pop_partition() -> int | None:
            with pop_lock:
                return parts.popleft() if parts else None

        t0 = time.perf_counter()
        with use_context(self.tracer.root):
            rt.ReduceScheduler(store, self.shared, width=self.slots,
                               runs_hint=self.num_tasks).run(pop_partition)
        self.control.raise_first()
        reduce_seconds = time.perf_counter() - t0
        return self.build_report(map_seconds=map_seconds,
                                 reduce_seconds=reduce_seconds)

    def run_cluster(self,
                    workers: Sequence[ex.Worker]) -> ClusterShuffleReport:
        """The multi-worker driver: two barriered phases of rounds over
        the surviving fleet, re-executing whatever a dead worker never
        durably confirmed (see shuffle/executor.PhaseDriver)."""
        job = self.job
        ctx = ex.WorkerContext(
            plan=job.plan, bucket=job.bucket, map_op=job.map_op,
            reduce_shared=self.shared, timeline=self.timeline,
            control=self.control, num_map_tasks=self.num_tasks,
        )
        driver = ex.PhaseDriver(workers, tracer=self.tracer)

        t_origin = time.perf_counter()
        reexec_map = driver.run_phase(
            "map", list(range(self.num_tasks)),
            lambda wk, pop, done: wk.run_map_phase(ctx, pop, done),
            self.control)
        map_seconds = time.perf_counter() - t_origin

        t_reduce = time.perf_counter()
        reexec_reduce = driver.run_phase(
            "reduce", list(range(self.num_partitions)),
            lambda wk, pop, done: wk.run_reduce_phase(ctx, pop, done),
            self.control)
        reduce_seconds = time.perf_counter() - t_reduce

        return ClusterShuffleReport(
            report=self.build_report(map_seconds=map_seconds,
                                     reduce_seconds=reduce_seconds),
            num_cluster_workers=len(driver.workers),
            failed_workers=list(driver.failed_workers),
            reexecuted_map_tasks=reexec_map,
            reexecuted_reduce_tasks=reexec_reduce,
            map_tasks=self.num_tasks,
            reduce_tasks=self.num_partitions,
            per_worker_stats=driver.per_worker_stats(),
            per_worker_tasks=dict(driver.per_worker_tasks),
        )

    def run_elastic(self, workers: Sequence[ex.Worker],
                    fleet) -> ClusterShuffleReport:
        """The elastic driver: membership + heartbeats, in-phase claim
        release, straggler speculation with loser-abort commits, and
        correlated spill-tier loss with lineage-tracked map re-execution
        (see shuffle/elastic.ElasticPhaseDriver). `fleet` is a
        shuffle/elastic.FleetPlan. Returns the driver too — callers that
        admit/retire workers mid-job grab it via `session.driver`."""
        from repro.shuffle.elastic import ElasticPhaseDriver

        job = self.job
        ctx = ex.WorkerContext(
            plan=job.plan, bucket=job.bucket, map_op=job.map_op,
            reduce_shared=self.shared, timeline=self.timeline,
            control=self.control, num_map_tasks=self.num_tasks,
        )
        driver = self.driver = ElasticPhaseDriver(
            workers, fleet=fleet, store=job.store, bucket=job.bucket,
            tracer=self.tracer)
        driver.run_job(ctx, num_map_tasks=self.num_tasks,
                       num_partitions=self.num_partitions)
        self.control.raise_first()
        counters = driver.pool_counters()
        return ClusterShuffleReport(
            report=self.build_report(map_seconds=driver.map_seconds,
                                     reduce_seconds=driver.reduce_seconds),
            num_cluster_workers=len(driver.workers),
            failed_workers=list(driver.failed_workers),
            map_tasks=self.num_tasks,
            reduce_tasks=self.num_partitions,
            per_worker_stats=driver.per_worker_stats(),
            per_worker_tasks=dict(driver.per_worker_tasks),
            heartbeat_misses=driver.heartbeat_misses,
            spill_lost_map_tasks=driver.spill_lost_map_tasks,
            requeued_reduce_tasks=driver.requeued_reduce_tasks,
            workers_admitted=driver.workers_admitted,
            workers_retired=driver.workers_retired,
            recovery_rounds=driver.recovery_rounds,
            **counters,
        )

    # -- reporting ---------------------------------------------------------

    def build_report(self, *, map_seconds: float,
                     reduce_seconds: float) -> ShuffleReport:
        """Assemble the run report from the session + measured state —
        the one place the report contract is populated, for every
        workload and both execution modes."""
        job = self.job
        store, plan, map_op = job.store, job.plan, job.map_op
        tier_stats = None
        if self.tier_base is not None:
            tier_now = store.per_tier_stats()
            tier_stats = {name: tier_now[name] - self.tier_base[name]
                          for name in tier_now}
        reg = self.tracer.registry
        reg.gauge("phase.seconds", map_seconds, phase="map")
        reg.gauge("phase.seconds", reduce_seconds, phase="reduce")
        # Derive bytes/s gauges from the phase-labelled byte counters the
        # TracingMiddleware maintains (zero counters = no tracing store
        # wired in; skip rather than emit misleading zero rates).
        for phase, seconds, metric in (
                ("map", map_seconds, "store.bytes_read"),
                ("map", map_seconds, "store.bytes_written"),
                ("reduce", reduce_seconds, "store.bytes_read"),
                ("reduce", reduce_seconds, "store.bytes_written")):
            nbytes = reg.total(metric, phase=phase)
            if nbytes and seconds > 0:
                reg.gauge(metric + "_per_s", nbytes / seconds, phase=phase)
        return ShuffleReport(
            total_records=map_op.total_records,
            num_waves=self.num_tasks,
            num_workers=map_op.num_mesh_workers,
            num_reducers=self.num_partitions,
            spill_objects=self.num_tasks * map_op.spill_objects_per_task,
            output_objects=self.num_partitions,
            map_seconds=map_seconds,
            reduce_seconds=reduce_seconds,
            working_set_records=map_op.working_set_records,
            stats=(store.stats_snapshot() - self.base_stats
                   if hasattr(store, "stats_snapshot") else StoreStats()),
            runs_per_reducer=self.num_tasks,
            merge_chunk_bytes=plan.merge_chunk_bytes,
            reduce_chunk_bytes=self.chunk_bytes,
            reduce_chunk_bytes_max=self.governor.max_chunk_bytes,
            reduce_peak_merge_bytes=self.peak.peak,
            parallel_reducers=self.slots,
            reduce_memory_budget_bytes=plan.reduce_memory_budget_bytes,
            tier_stats=tier_stats,
            spans=self.timeline.spans(),
            spans_dropped=self.timeline.dropped,
            phase_seconds=self.timeline.totals(),
            metrics=reg.snapshot(),
        )


class ShuffleJob:
    """A shuffle workload: operators + partitioner + plan over one store.

    The public entry point of the library. `run(workers=N)` executes the
    whole dataflow — N=0 inline on the calling host, N>=1 across N
    emulated workers with application-level failure recovery; pass
    `cluster=` (a shuffle/executor.ClusterPlan) to inject worker deaths,
    or `worker_list=` to bring a hand-built Worker fleet.
    """

    def __init__(self, store: StoreBackend, bucket: str, *, plan,
                 map_op: MapOp, reduce_op: ReduceOp,
                 partitioner: Partitioner, tracer: Tracer | None = None):
        self.store = store
        self.bucket = bucket
        self.plan = plan
        self.map_op = map_op
        self.reduce_op = reduce_op
        self.partitioner = partitioner
        self.tracer = tracer

    def prepare(self, *, schedulers: int = 1) -> ShuffleSession:
        """Preflight one run (validation, task enumeration, governor,
        prefix cleanup) without executing it. `schedulers` is how many
        reduce schedulers will draw on the global budget (1 single-host;
        the worker count in cluster mode)."""
        return ShuffleSession(self, schedulers=schedulers)

    def run(self, workers: int = 0, *,
            cluster: ex.ClusterPlan | None = None,
            worker_list: Sequence[ex.Worker] | None = None,
            fleet=None):
        """Execute the job; returns a ShuffleReport (single-host) or a
        ClusterShuffleReport (cluster mode). Passing `fleet` (a
        shuffle/elastic.FleetPlan) with a `worker_list` selects the
        elastic driver — heartbeats, speculation, spill-loss recovery —
        instead of the round-barriered PhaseDriver."""
        if worker_list is not None:
            crew: Sequence[ex.Worker] | None = list(worker_list)
        elif cluster is not None:
            crew = ex.build_workers(self.store, cluster)
        elif workers >= 1:
            crew = ex.build_workers(self.store,
                                    ex.ClusterPlan(num_workers=workers))
        else:
            crew = None
        if crew is None:
            require(fleet is None, "fleet", fleet,
                    "the elastic driver needs a worker_list")
            return self.prepare(schedulers=1).run_single_host()
        require(len(crew) >= 1, "worker_list", len(crew),
                "must supply >= 1 worker")
        session = self.prepare(schedulers=len(crew))
        if fleet is not None:
            return session.run_elastic(crew, fleet)
        return session.run_cluster(crew)


__all__ = ["ShuffleJob", "ShuffleSession"]
