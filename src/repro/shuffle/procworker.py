"""ProcessWorker: a cluster worker backed by a real subprocess.

ThreadWorkers emulate the fleet inside one JAX runtime, which means one
device mesh and one GIL: every wave sort serializes on the single
device lock, so thread fleets show request-level parallelism but no
COMPUTE parallelism. A ProcessWorker spawns `repro.shuffle.worker_main`
with its own interpreter and its own JAX runtime (the child env pins
`XLA_FLAGS=--xla_force_host_platform_device_count=N` before the first
jax import), talking line-JSON over stdin/stdout — so a W=4 process
fleet sorts four waves concurrently for real, which is exactly what
benchmarks/bench_elastic.py measures against the thread fleet.

The parent half implements the same `Worker` protocol the drivers
already speak — `run_map_phase` / `run_reduce_phase` drain the driver's
pop/confirm callbacks — plus the elastic extensions:

  * `last_beat()` — monotonic timestamp of the last protocol message
    (every message counts; the child also heartbeats on an interval),
    feeding the ElasticPhaseDriver's miss detector;
  * `fence()` — SIGKILL. After the driver declares this worker dead, no
    in-flight laggard in the child can ever reach a durable commit.

Threading layout (the part that must not deadlock): one reader thread
owns stdout and handles quick events inline — heartbeats, `done`
confirmations, `commit` gate checks, `requeue` routing (all lock-bound
pool operations) — while `need` tokens are handed to a dedicated pop
server thread, because `pop_next()` may legitimately block for seconds
waiting for releasable work. A blocked pop therefore never stops the
reader from serving the commit gate of a finisher that is about to win
a speculative race.

The store config travels as a JSON spec (`store_spec_for` builds one
from a live filesystem-backed store), optionally carrying a per-worker
FaultProfile — the chaos harness uses that to make one PROCESS a
straggler while the shared data stays untouched.
"""
from __future__ import annotations

import json
import os
import queue
import subprocess
import sys
import threading
import time

from repro.io.backends import StoreStats
from repro.shuffle.executor import Worker, WorkerContext, WorkerFailure


def store_spec_for(store, *, fault: dict | None = None,
                   chunk_size: int | None = None) -> dict:
    """Serialize a filesystem-backed store (ObjectStore / bare
    FilesystemBackend / TieredStore over two of them, possibly
    middleware-wrapped — anything exposing `.root`, or `.durable`/`.ssd`
    that do) into the spec a child process rebuilds its own handle from.
    `fault` is an optional io/middleware.FaultProfile field dict applied
    in the CHILD only (per-worker straggler injection)."""
    durable = getattr(store, "durable", None)
    ssd = getattr(store, "ssd", None)
    if durable is not None and ssd is not None:
        spec = {"kind": "tiered", "durable_root": durable.root,
                "ssd_root": ssd.root,
                "ssd_prefixes": list(getattr(store, "ssd_prefixes",
                                             ("spill/",)))}
    else:
        root = getattr(store, "root", None)
        if root is None:
            raise ValueError(
                f"{type(store).__name__} has no filesystem root; process "
                "workers need a store both sides can open (MemoryBackend "
                "cannot cross a process boundary)")
        spec = {"kind": "fs", "root": root}
    spec["chunk_size"] = int(chunk_size if chunk_size is not None
                             else getattr(store, "chunk_size", 4 << 20))
    if fault:
        spec["fault"] = dict(fault)
    return spec


class _RemoteStats:
    """Parent-side stand-in for the worker's store view: the child ships
    a stats snapshot at every phase end; the driver's
    `per_worker_stats()` reads the latest one here."""

    def __init__(self):
        self._lock = threading.Lock()
        self._latest = StoreStats()

    def update(self, fields: dict) -> None:
        with self._lock:
            self._latest = StoreStats(**fields)

    def stats_snapshot(self) -> StoreStats:
        with self._lock:
            return self._latest


class ProcessWorker(Worker):
    """Subprocess-backed Worker (see module docstring).

    `die_after_tasks` injects a pre-commit-deterministic process death
    at the N+1-th task pop (chaos harness). `fault` is a FaultProfile
    dict applied to the child's store view (straggler injection).
    """

    def __init__(self, name: str, *, store, bucket: str, plan,
                 mesh_devices: int = 8, axis: str = "w",
                 heartbeat_interval_s: float = 0.2,
                 die_after_tasks: int | None = None,
                 fault: dict | None = None,
                 ready_timeout_s: float = 180.0):
        import dataclasses

        import repro

        self.name = name
        self.store = _RemoteStats()
        self._beat: float | None = None
        self._dead = False
        self._wlock = threading.Lock()
        self._need: queue.Queue = queue.Queue()
        self._phase_lock = threading.Lock()
        self._state_lock = threading.Lock()
        self._state: dict | None = None
        self._ready = threading.Event()

        spec = {
            "name": name,
            "store": store_spec_for(store, fault=fault),
            "bucket": bucket,
            "plan": dataclasses.asdict(plan),
            "mesh_devices": int(mesh_devices),
            "axis": axis,
            "heartbeat_interval_s": float(heartbeat_interval_s),
        }
        if die_after_tasks is not None:
            spec["die_after_tasks"] = int(die_after_tasks)

        # repro may be a namespace package (__file__ is None): derive the
        # import root from its search path instead.
        src_dir = os.path.dirname(os.path.abspath(list(repro.__path__)[0]))
        env = dict(os.environ)
        env["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={mesh_devices}")
        env["PYTHONPATH"] = src_dir + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
        self._proc = subprocess.Popen(
            [sys.executable, "-u", "-m", "repro.shuffle.worker_main"],
            stdin=subprocess.PIPE, stdout=subprocess.PIPE, stderr=None,
            text=True, bufsize=1, env=env)
        self._send(spec)
        self._reader = threading.Thread(target=self._read_loop, daemon=True,
                                        name=f"procworker-{name}-reader")
        self._reader.start()
        if not self._ready.wait(ready_timeout_s):
            self.fence()
            raise WorkerFailure(
                f"{name}: child not ready after {ready_timeout_s}s")

    # -- plumbing ---------------------------------------------------------

    def _send(self, msg: dict) -> None:
        try:
            with self._wlock:
                self._proc.stdin.write(json.dumps(msg) + "\n")
                self._proc.stdin.flush()
        except (BrokenPipeError, OSError, ValueError):
            # Child gone; the reader's EOF handling owns the fallout.
            pass

    def _read_loop(self) -> None:
        try:
            for line in self._proc.stdout:
                self._beat = time.monotonic()
                if not line.strip():
                    continue
                self._handle(json.loads(line))
        finally:
            self._dead = True
            self._finish_phase(WorkerFailure(
                f"{self.name}: worker process exited "
                f"(rc={self._proc.poll()})"))

    def _handle(self, msg: dict) -> None:
        ev = msg.get("ev")
        if ev == "ready":
            self._ready.set()
        elif ev == "hb":
            pass  # the timestamp update above is the whole point
        elif ev == "need":
            self._need.put(True)
        elif ev == "done":
            with self._state_lock:
                st = self._state
            if st is not None:
                st["on_done"](msg["task"])
        elif ev == "commit":
            with self._state_lock:
                st = self._state
            gate = st.get("commit_gate") if st else None
            ok = True if gate is None else bool(gate(self.name, msg["task"]))
            self._send({"cmd": "commit", "task": msg["task"], "ok": ok})
        elif ev == "requeue":
            with self._state_lock:
                st = self._state
            on_rq = st.get("on_requeue") if st else None
            from repro.io.backends import ObjectNotFound
            exc = ObjectNotFound(msg.get("error", "input lost"))
            handled = (bool(on_rq(self.name, msg["task"], exc))
                       if on_rq is not None else False)
            self._send({"cmd": "requeue_ack", "task": msg["task"],
                        "ok": handled})
        elif ev == "phase_end":
            self.store.update(msg.get("stats", {}))
            self._finish_phase(None)
        elif ev == "error":
            self._finish_phase(RuntimeError(
                f"{self.name}: worker process phase failed:\n"
                f"{msg.get('detail', '')}"))

    def _finish_phase(self, error: BaseException | None) -> None:
        with self._state_lock:
            st = self._state
            if st is None:
                return
            if error is not None and st["error"] is None:
                st["error"] = error
            self._state = None
        self._need.put(None)  # unblock the pop server
        st["event"].set()

    def _pop_server(self, st: dict, pop_next) -> None:
        while True:
            token = self._need.get()
            if token is None:
                return
            try:
                task = pop_next()
            except BaseException as e:
                self._finish_phase(e)
                return
            self._send({"cmd": "task", "task": task})

    def _run_phase(self, phase: str, ctx: WorkerContext, pop_next,
                   on_done) -> None:
        with self._phase_lock:
            if self._dead:
                raise WorkerFailure(f"{self.name}: worker process is dead")
            st = {
                "event": threading.Event(), "error": None,
                "on_done": on_done,
                # Map attempts gate on the speculation claim pool too
                # (the child polls the commit RPC per fetched chunk);
                # the reduce gate additionally covers requeue routing.
                "commit_gate": (ctx.commit_gate if phase == "reduce"
                                else ctx.map_commit_gate),
                "on_requeue": ctx.on_requeue if phase == "reduce" else None,
            }
            with self._state_lock:
                self._state = st
            server = threading.Thread(
                target=self._pop_server, args=(st, pop_next), daemon=True,
                name=f"procworker-{self.name}-pop")
            server.start()
            self._send({"cmd": "phase", "phase": phase,
                        "gated": phase == "map"
                        and ctx.map_commit_gate is not None})
            st["event"].wait()
            self._need.put(None)
            server.join()
            # Drain stale sentinels so the next phase starts clean.
            while True:
                try:
                    self._need.get_nowait()
                except queue.Empty:
                    break
            if st["error"] is not None:
                raise st["error"]

    # -- Worker protocol --------------------------------------------------

    def run_map_phase(self, ctx, pop_next, on_done):
        self._run_phase("map", ctx, pop_next, on_done)

    def run_reduce_phase(self, ctx, pop_next, on_done):
        self._run_phase("reduce", ctx, pop_next, on_done)

    def last_beat(self) -> float | None:
        return self._beat

    def fence(self) -> None:
        """SIGKILL: after the driver declares this worker dead, nothing
        in the child may reach a durable commit."""
        self._dead = True
        try:
            self._proc.kill()
        except OSError:
            pass

    def close(self) -> None:
        """Graceful shutdown (idempotent); escalates to SIGKILL."""
        if self._proc.poll() is None and not self._dead:
            self._send({"cmd": "shutdown"})
            try:
                self._proc.wait(timeout=10)
            except subprocess.TimeoutExpired:
                self._proc.kill()
        elif self._proc.poll() is None:
            self._proc.kill()
        try:
            self._proc.wait(timeout=10)
        except subprocess.TimeoutExpired:
            pass
        self._reader.join(timeout=5)
        with self._wlock:
            try:
                self._proc.stdin.close()
            except OSError:
                pass


__all__ = ["ProcessWorker", "store_spec_for"]
