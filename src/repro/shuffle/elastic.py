"""Elastic fleet driver: membership, heartbeats, speculation, spill loss.

The static PhaseDriver (shuffle/executor.py) runs barriered ROUNDS over
a fixed worker list: deaths are only observed when a phase round joins,
re-execution waits for the round barrier, and a "dead" worker's spill
runs conveniently survive in the shared store. This module is the
elastic replacement the paper's §2.6 story actually needs:

  * **Membership** — workers join (`admit`) and leave (`retire`)
    mid-phase; a heartbeat monitor declares silent workers dead
    (`cluster.heartbeat_miss`) without waiting for them to fail a
    store request.
  * **Claims, not ranges** — `ClaimPool` replaces the range-partitioned
    TaskPool: workers pull claims from one shared pool, a dead worker's
    unconfirmed claims are released immediately (survivors pick them up
    inside the SAME phase, no round barrier), and duplicate claims are
    legal.
  * **Speculation** — once enough task durations are observed, an idle
    worker may duplicate an in-flight laggard that has run past a
    quantile deadline (`cluster.speculate`). First durable multipart
    commit wins: `ClaimPool.confirm` is the dedup point and
    `ClaimPool.may_commit` is the loser-abort gate consulted by
    runtime.finalize_session immediately before CompleteMultipartUpload.
    Both outcomes are byte-identical because spill/output bytes are
    deterministic functions of (task, plan, input).
  * **Correlated spill loss** — a dying worker takes its local spill
    tier with it (`FleetPlan.lose_spill_on_death`): the driver deletes
    the spill runs of every map task the dead worker had confirmed
    (lineage via `MapOp.spill_keys`), unconfirms those map tasks, parks
    reduce partitions that can no longer read their inputs
    (`cluster.spill_lost`), re-runs the lost map waves on survivors,
    and only then resumes the reduce phase. In-flight reducers that
    trip over a vanished run raise ObjectNotFound, which the scheduler
    routes back here as a requeue instead of a job failure.

Everything rides the existing durability contract: `on_done` fires only
after a multipart COMMIT, commits are atomic + idempotent, and spill
bytes depend only on (task id, plan, input) — so output stays
byte/etag-identical under kills, scale-up/down, stragglers, and loss.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Callable, Sequence

from repro.io.backends import ObjectNotFound
from repro.obs.context import TraceContext, use_context

from repro.shuffle.api import require
from repro.shuffle.executor import (ClusterFailure, Worker, WorkerContext,
                                    WorkerFailure)


@dataclasses.dataclass(frozen=True)
class FleetPlan:
    """Elastic-fleet policy knobs (the cluster analogue of ShufflePlan).

    `heartbeat_timeout_s` is how long a worker may stay silent before
    the monitor declares it dead; workers whose `last_beat()` is None
    (plain ThreadWorkers) are exempt — they fail synchronously instead.
    Speculation fires only after `speculation_min_samples` confirmed
    durations: a task is a laggard once its oldest live claim is older
    than max(quantile(durations) * speculation_factor, speculation_min_s).
    """

    heartbeat_timeout_s: float = 2.0
    monitor_interval_s: float = 0.05
    speculation: bool = False
    speculation_quantile: float = 0.5
    speculation_factor: float = 2.0
    speculation_min_s: float = 0.2
    speculation_min_samples: int = 3
    max_duplicates: int = 2
    lose_spill_on_death: bool = True

    def __post_init__(self):
        require(self.heartbeat_timeout_s > 0, "heartbeat_timeout_s",
                self.heartbeat_timeout_s, "must be positive seconds")
        require(self.monitor_interval_s > 0, "monitor_interval_s",
                self.monitor_interval_s, "must be positive seconds")
        require(0.0 <= self.speculation_quantile <= 1.0,
                "speculation_quantile", self.speculation_quantile,
                "is a quantile in [0, 1]")
        require(self.speculation_factor >= 1.0, "speculation_factor",
                self.speculation_factor,
                "< 1 would speculate on on-pace tasks")
        require(self.speculation_min_samples >= 1, "speculation_min_samples",
                self.speculation_min_samples, "needs >= 1 observed duration")
        require(self.max_duplicates >= 2, "max_duplicates",
                self.max_duplicates,
                "must allow the original plus >= 1 duplicate")


class ClaimPool:
    """Shared task pool with claims, releases, speculation, and parking.

    States of a task: *pending* (in the deque), *claimed* (>= 1 live
    in-flight attempts), *blocked* (parked until lost lineage is
    regenerated), *confirmed* (a durable commit landed — terminal).
    `pop` blocks while nothing is servable but progress elsewhere could
    still create work for this worker (a death releasing claims, a
    laggard crossing the speculation deadline); it returns None — ending
    the worker's phase — only when every unconfirmed task is blocked,
    the job is cancelled, or the worker itself retired.
    """

    def __init__(self, tasks: Sequence[int], *, plan: FleetPlan,
                 phase: str, tracer=None, cancel=None,
                 clock: Callable[[], float] = time.monotonic):
        self._tasks = list(tasks)
        self._plan = plan
        self._phase = phase
        self._tracer = tracer
        self._cancel = cancel  # threading.Event: job-wide cancellation
        self._clock = clock
        self._cond = threading.Condition()
        self._pending: collections.deque[int] = collections.deque(tasks)
        self._claims: dict[int, list[str]] = {}  # live in-flight claimants
        self._started: dict[int, float] = {}  # oldest live claim's start
        self._first_claimant: dict[int, str] = {}
        self._ever_claimed: set[int] = set()
        self._speculated_tasks: set[int] = set()
        self._confirmed: dict[int, str] = {}  # task -> winning worker
        self._blocked: set[int] = set()
        self._dead: set[str] = set()
        self._retired: set[str] = set()
        # Confirmed attempt durations, feeding the speculation deadline.
        self._durations: list[float] = []
        # Counters (read under the cond lock via snapshot()):
        self.reexecutions = 0  # claims of previously-claimed tasks
        self.speculated = 0  # duplicate attempts launched
        self.spec_wins = 0  # confirmed by a non-first claimant
        self.spec_losses = 0  # attempts beaten to the commit

    # -- worker-facing ----------------------------------------------------

    def popper(self, worker: str, *,
               yield_when_busy: bool = False) -> Callable[[], int | None]:
        return lambda: self.pop(worker, yield_when_busy=yield_when_busy)

    def pop(self, worker: str, *, yield_when_busy: bool = False) -> int | None:
        """Claim the next task. `yield_when_busy` is for pull-ahead
        callers (the map pipeline's prefetch fill loop runs on the same
        thread that PROCESSES tasks): instead of blocking while the
        worker still holds unconfirmed claims, return None so the caller
        drains its in-flight work — blocking there would deadlock the
        whole fleet at the queue tail. The phase driver relaunches the
        worker, and a relaunched idle worker blocks here safely."""
        with self._cond:
            while True:
                if worker in self._dead:
                    raise WorkerFailure(
                        f"{worker}: fenced (declared dead by the driver)")
                if self._cancel is not None and self._cancel.is_set():
                    return None
                if worker in self._retired:
                    return None
                if self.all_confirmed():
                    return None
                task = self._claim_pending(worker)
                if task is None:
                    task = self._claim_speculative(worker)
                if task is not None:
                    return task
                if not self._servable_later():
                    return None  # everything left is parked on recovery
                if yield_when_busy and self._worker_inflight(worker):
                    return None
                self._cond.wait(0.05)

    def confirm(self, task: int, worker: str) -> bool:
        """Record a durable commit; False means another attempt won."""
        with self._cond:
            if task in self._confirmed:
                if task in self._speculated_tasks:
                    self.spec_losses += 1
                return False
            self._confirmed[task] = worker
            self._blocked.discard(task)  # a straggler attempt may land
            started = self._started.pop(task, None)
            if started is not None:
                self._durations.append(self._clock() - started)
            if (task in self._speculated_tasks
                    and self._first_claimant.get(task) != worker):
                self.spec_wins += 1
            self._cond.notify_all()
            return True

    def may_commit(self, task: int, worker: str) -> bool:
        """The loser-abort gate: False once another attempt committed."""
        with self._cond:
            owner = self._confirmed.get(task)
            return owner is None or owner == worker

    # -- driver-facing ----------------------------------------------------

    def release_worker(self, worker: str) -> list[int]:
        """Declare `worker` dead: drop its claims and re-pend tasks with
        no surviving live attempt (front of the queue — recovery work
        beats fresh work). Its next pop raises WorkerFailure."""
        freed = []
        with self._cond:
            self._dead.add(worker)
            for task, claims in self._claims.items():
                if worker not in claims:
                    continue
                claims[:] = [c for c in claims if c != worker]
                if (not claims and task not in self._confirmed
                        and task not in self._blocked
                        and task not in self._pending):
                    self._pending.appendleft(task)
                    self._started.pop(task, None)
                    freed.append(task)
            self._cond.notify_all()
        return freed

    def retire_worker(self, worker: str) -> None:
        """Graceful drain: the worker keeps its in-flight attempts but is
        handed no further tasks."""
        with self._cond:
            self._retired.add(worker)
            self._cond.notify_all()

    def release_claim(self, task: int, worker: str, *,
                      block: bool) -> None:
        """An attempt aborted cleanly (requeue): drop the claim, and
        either park the task (its input is gone until recovery) or
        re-pend it immediately."""
        with self._cond:
            claims = self._claims.get(task)
            if claims and worker in claims:
                claims.remove(worker)
            if task in self._confirmed:
                return
            if block:
                self._blocked.add(task)
                self._started.pop(task, None)
            elif (not (claims or []) and task not in self._pending
                    and task not in self._blocked):
                self._pending.appendleft(task)
                self._started.pop(task, None)
            self._cond.notify_all()

    def block_unconfirmed(self) -> int:
        """Park every unconfirmed task (correlated input loss: nothing
        can safely start until the lineage is regenerated)."""
        with self._cond:
            n = 0
            for task in self._tasks:
                if task not in self._confirmed and task not in self._blocked:
                    self._blocked.add(task)
                    n += 1
            self._pending.clear()
            self._cond.notify_all()
            return n

    def unblock_all(self) -> int:
        """Recovery finished: re-pend parked tasks without live claims
        (a parked task whose old attempt is still running keeps it —
        that attempt either commits or requeues)."""
        with self._cond:
            n = 0
            for task in sorted(self._blocked):
                if task in self._confirmed or task in self._pending:
                    continue
                if self._claims.get(task):
                    continue
                self._pending.append(task)
                n += 1
            self._blocked.clear()
            self._cond.notify_all()
            return n

    def unconfirm(self, tasks: Sequence[int]) -> list[int]:
        """Roll back confirmations whose durable OUTPUT was destroyed
        (spill-tier loss): those tasks must run again."""
        rolled = []
        with self._cond:
            for task in tasks:
                if self._confirmed.pop(task, None) is None:
                    continue
                self._claims.pop(task, None)
                self._started.pop(task, None)
                if task not in self._pending:
                    self._pending.append(task)
                rolled.append(task)
            self._cond.notify_all()
        return rolled

    # -- introspection ----------------------------------------------------

    def all_confirmed(self) -> bool:
        return len(self._confirmed) == len(self._tasks)

    def servable(self) -> bool:
        """Could a (re)launched worker still find or wait for work here?
        False once everything unconfirmed is parked on recovery — the
        phase should wind down and let the driver regenerate lineage."""
        with self._cond:
            return self._servable_later()

    def unconfirmed(self) -> list[int]:
        with self._cond:
            return [t for t in self._tasks if t not in self._confirmed]

    def blocked(self) -> set[int]:
        with self._cond:
            return set(self._blocked)

    def confirmed_by(self, worker: str) -> list[int]:
        with self._cond:
            return [t for t, w in self._confirmed.items() if w == worker]

    # -- internals (self._cond held) --------------------------------------

    def _worker_inflight(self, worker: str) -> bool:
        return any(worker in claims and task not in self._confirmed
                   for task, claims in self._claims.items())

    def _servable_later(self) -> bool:
        """Could waiting produce work for SOME worker? True while any
        unconfirmed task is unblocked: it is pending, or in flight (a
        death may release it; a laggard may cross the speculation
        deadline). Once everything left is parked (blocked), only the
        driver's recovery pass can make progress — pops return None."""
        return any(t not in self._confirmed and t not in self._blocked
                   for t in self._tasks)

    def _claim_pending(self, worker: str) -> int | None:
        while self._pending:
            task = self._pending.popleft()
            if task in self._confirmed or task in self._blocked:
                continue
            claims = self._claims.setdefault(task, [])
            if not claims:
                self._started[task] = self._clock()
            claims.append(worker)
            self._first_claimant.setdefault(task, worker)
            if task in self._ever_claimed:
                self.reexecutions += 1
            self._ever_claimed.add(task)
            return task
        return None

    def _claim_speculative(self, worker: str) -> int | None:
        plan = self._plan
        if not plan.speculation:
            return None
        if len(self._durations) < plan.speculation_min_samples:
            return None
        ordered = sorted(self._durations)
        idx = min(int(len(ordered) * plan.speculation_quantile),
                  len(ordered) - 1)
        deadline = max(ordered[idx] * plan.speculation_factor,
                       plan.speculation_min_s)
        now = self._clock()
        for task in self._tasks:
            if task in self._confirmed or task in self._blocked:
                continue
            claims = self._claims.get(task)
            if not claims or worker in claims:
                continue
            live = [c for c in claims if c not in self._dead]
            if not live or len(live) >= plan.max_duplicates:
                continue
            started = self._started.get(task)
            if started is None or now - started <= deadline:
                continue
            claims.append(worker)
            self.speculated += 1
            self._speculated_tasks.add(task)
            self._ever_claimed.add(task)
            if self._tracer is not None:
                self._tracer.instant(
                    "cluster.speculate", phase=self._phase, task=task,
                    worker=worker, laggards=live,
                    waited_s=round(now - started, 4),
                    deadline_s=round(deadline, 4))
                self._tracer.registry.counter("cluster.tasks_speculated",
                                              phase=self._phase)
            return task
        return None


class ElasticPhaseDriver:
    """Drives an elastic fleet through map + reduce with live recovery.

    Differences from executor.PhaseDriver: no rounds (releases happen
    inside the phase), a heartbeat monitor, mid-phase admission /
    retirement, speculation via ClaimPool, and correlated spill-tier
    loss with lineage-tracked map re-execution.
    """

    def __init__(self, workers: Sequence[Worker], *, fleet: FleetPlan,
                 store, bucket: str, tracer=None):
        require(len(list(workers)) >= 1, "workers", len(list(workers)),
                "an elastic fleet still needs an initial worker")
        self.workers: list[Worker] = list(workers)
        self.fleet = fleet
        self.store = store  # the SHARED store: spill loss is driver-side
        self.bucket = bucket
        self.tracer = tracer
        self._lock = threading.Lock()
        self._dead: set[str] = set()
        self._retired: set[str] = set()
        self.failed_workers: list[str] = []
        self.per_worker_tasks: dict[str, int] = {}
        self._requeues_by_task: dict[int, int] = {}
        self.heartbeat_misses = 0
        self.spill_lost_map_tasks = 0
        self.requeued_reduce_tasks = 0
        self.workers_admitted = 0
        self.workers_retired = 0
        self.recovery_rounds = 0
        self.map_seconds = 0.0
        self.reduce_seconds = 0.0
        self._map_pool: ClaimPool | None = None
        self._reduce_pool: ClaimPool | None = None
        self._active_pool: ClaimPool | None = None
        self._ctx: WorkerContext | None = None

    # -- membership -------------------------------------------------------

    def admit(self, worker: Worker) -> None:
        """Join a worker mid-job: the running phase launches it as soon
        as its launcher loop next looks (<= ~50 ms)."""
        with self._lock:
            self.workers.append(worker)
            self.workers_admitted += 1
        if self.tracer is not None:
            self.tracer.instant("cluster.worker_admitted", worker=worker.name)
            self.tracer.registry.counter("cluster.workers_admitted")

    def retire(self, name: str) -> None:
        """Gracefully drain a worker: it finishes in-flight claims, is
        handed nothing new, and skips future phases."""
        with self._lock:
            self._retired.add(name)
            self.workers_retired += 1
            pool = self._active_pool
        if pool is not None:
            pool.retire_worker(name)
        if self.tracer is not None:
            self.tracer.instant("cluster.worker_retired", worker=name)
            self.tracer.registry.counter("cluster.workers_retired")

    def _alive(self) -> list[Worker]:
        with self._lock:
            return [wk for wk in self.workers
                    if wk.name not in self._dead
                    and wk.name not in self._retired]

    # -- the job ----------------------------------------------------------

    def run_job(self, ctx: WorkerContext, *, num_map_tasks: int,
                num_partitions: int) -> None:
        """Map to full confirmation, then reduce — re-running lost map
        lineage between reduce attempts until every partition commits."""
        fleet = self.fleet
        self._ctx = ctx
        map_pool = ClaimPool(range(num_map_tasks), plan=fleet, phase="map",
                             tracer=self.tracer, cancel=ctx.control.cancel)
        reduce_pool = ClaimPool(range(num_partitions), plan=fleet,
                                phase="reduce", tracer=self.tracer,
                                cancel=ctx.control.cancel)
        self._map_pool, self._reduce_pool = map_pool, reduce_pool
        # Speculation loser-abort gates, one per phase. The context's
        # gate convention is (worker, task) — the pool's is
        # (task, worker), so adapt explicitly; the same predicate is
        # both the commit-time refusal and the mid-attempt abandonment
        # poll (reduce merge windows, map chunk fetches). Plus the
        # lost-input requeue route (ObjectNotFound = a spill run this
        # driver deleted out from under an in-flight merge).
        ctx.commit_gate = lambda worker, r: reduce_pool.may_commit(r, worker)
        ctx.map_commit_gate = lambda worker, g: map_pool.may_commit(g, worker)
        ctx.requeue_on = (ObjectNotFound,)
        ctx.on_requeue = self._on_requeue

        def map_entry(wk, pop, done):
            wk.run_map_phase(ctx, pop, done)

        def reduce_entry(wk, pop, done):
            wk.run_reduce_phase(ctx, pop, done)

        t0 = time.perf_counter()
        self._phase_to_completion("map", map_pool, map_entry, ctx.control)
        self.map_seconds = time.perf_counter() - t0

        t0 = time.perf_counter()
        while True:
            self._run_phase("reduce", reduce_pool, reduce_entry, ctx.control)
            if reduce_pool.all_confirmed():
                break
            self._require_alive("reduce", reduce_pool)
            # Partitions are parked on lost map lineage: regenerate the
            # missing spill runs (deterministic bytes — the re-executed
            # wave rewrites exactly what was lost), then resume.
            if not map_pool.all_confirmed():
                self.recovery_rounds += 1
                if self.tracer is not None:
                    self.tracer.instant(
                        "cluster.round", phase="map-recovery",
                        pending=len(map_pool.unconfirmed()),
                        alive=len(self._alive()))
                self._phase_to_completion("map", map_pool, map_entry,
                                          ctx.control)
            if not reduce_pool.unblock_all() and not reduce_pool.blocked():
                # No parked work was released and nothing is parked:
                # the phase ended with unconfirmed, unblocked tasks —
                # only possible when the fleet died under it.
                self._require_alive("reduce", reduce_pool)
        self.reduce_seconds = time.perf_counter() - t0

    def per_worker_stats(self) -> dict:
        return {
            wk.name: wk.store.stats_snapshot()
            for wk in self.workers
            if hasattr(wk.store, "stats_snapshot")
        }

    def pool_counters(self) -> dict:
        mp_, rp = self._map_pool, self._reduce_pool
        return {
            "reexecuted_map_tasks": mp_.reexecutions if mp_ else 0,
            "reexecuted_reduce_tasks": rp.reexecutions if rp else 0,
            "speculated_tasks": ((mp_.speculated if mp_ else 0)
                                 + (rp.speculated if rp else 0)),
            "speculation_wins": ((mp_.spec_wins if mp_ else 0)
                                 + (rp.spec_wins if rp else 0)),
        }

    # -- phase machinery --------------------------------------------------

    def _phase_to_completion(self, phase, pool, entry, control):
        while not pool.all_confirmed():
            self._require_alive(phase, pool)
            self._run_phase(phase, pool, entry, control)
            control.raise_first()

    def _require_alive(self, phase, pool):
        if not self._alive():
            raise ClusterFailure(
                f"all {len(self.workers)} workers dead during {phase} "
                f"phase with {len(pool.unconfirmed())} tasks unfinished")

    def _run_phase(self, phase, pool, entry, control):
        """One pass: launch every eligible worker (including ones
        admitted while the phase runs), monitor heartbeats, join all.

        Workers are RELAUNCHED within the pass: a map entry legitimately
        returns while the phase is still open — its yield-when-busy pops
        hand back None whenever the worker holds unconfirmed in-flight
        claims, so it drains a wave and exits (see ClaimPool.pop). If
        the driver only relaunched between passes, every fast worker
        would sit out the straggler's tail: an idle worker must be BACK
        in the pool, blocked in pop, for the speculation deadline to
        ever hand it a duplicate of the laggard's task. Relaunch happens
        while unparked unconfirmed work remains; once everything left is
        blocked on recovery (or the job is cancelled/complete), exited
        workers stay down and the pass winds up."""
        with self._lock:
            self._active_pool = pool
        stop = threading.Event()
        spawned: list[threading.Thread] = []
        current: dict[str, threading.Thread] = {}

        def launch(wk: Worker) -> None:
            t = threading.Thread(
                target=self._drive, args=(wk, phase, pool, entry, control),
                name=f"elastic-{wk.name}-{phase}")
            spawned.append(t)
            current[wk.name] = t
            t.start()

        monitor = threading.Thread(
            target=self._monitor, args=(pool, stop),
            name=f"elastic-monitor-{phase}", daemon=True)
        monitor.start()
        try:
            for wk in self._alive():
                launch(wk)
            while True:
                for t in list(current.values()):
                    t.join(timeout=0.02)
                launches = []
                if (not pool.all_confirmed() and pool.servable()
                        and not control.cancel.is_set()):
                    launches = [wk for wk in self._alive()
                                if not current.get(wk.name)
                                or not current[wk.name].is_alive()]
                for wk in launches:
                    launch(wk)
                if not launches and all(not t.is_alive()
                                        for t in current.values()):
                    break
        finally:
            stop.set()
            monitor.join()
            for t in spawned:
                t.join()
            with self._lock:
                self._active_pool = None
        control.raise_first()

    def _drive(self, wk, phase, pool, entry, control):
        ctx = None
        if self.tracer is not None:
            ctx = TraceContext(job=self.tracer.job, worker=wk.name)

        def on_done(task: int) -> None:
            if pool.confirm(task, wk.name):
                with self._lock:
                    self.per_worker_tasks[wk.name] = (
                        self.per_worker_tasks.get(wk.name, 0) + 1)

        # Map entries pull tasks from inside the prefetch pipeline on the
        # processing thread itself, so their pops must never block while
        # the worker holds in-flight claims (see ClaimPool.pop); reduce
        # schedulers pop from dedicated threads and can block freely.
        pop = pool.popper(wk.name, yield_when_busy=(phase == "map"))
        try:
            with use_context(ctx):
                entry(wk, pop, on_done)
        except WorkerFailure:
            self._on_worker_death(wk, pool, reason="failure")
        except BaseException as e:
            control.fail(e)

    # -- failure handling -------------------------------------------------

    def _monitor(self, pool, stop):
        timeout = self.fleet.heartbeat_timeout_s
        while not stop.wait(self.fleet.monitor_interval_s):
            now = time.monotonic()
            for wk in self._alive():
                beat = wk.last_beat()
                if beat is None or now - beat <= timeout:
                    continue
                with self._lock:
                    self.heartbeat_misses += 1
                if self.tracer is not None:
                    self.tracer.instant(
                        "cluster.heartbeat_miss", worker=wk.name,
                        silent_s=round(now - beat, 3))
                    self.tracer.registry.counter("cluster.heartbeat_misses")
                self._on_worker_death(wk, pool, reason="heartbeat")

    def _on_worker_death(self, wk, pool, *, reason):
        with self._lock:
            if wk.name in self._dead:
                return
            self._dead.add(wk.name)
            self.failed_workers.append(wk.name)
        if self.tracer is not None:
            self.tracer.instant(
                "cluster.worker_dead", reason=reason,
                ctx=TraceContext(job=self.tracer.job, worker=wk.name))
            self.tracer.registry.counter("cluster.workers_dead")
        try:
            wk.fence()  # sever the store view / kill the process
        except BaseException:
            pass
        pool.release_worker(wk.name)
        # Release in BOTH pools: a death during reduce must also fence
        # the worker out of any later map-recovery pass.
        for other in (self._map_pool, self._reduce_pool):
            if other is not None and other is not pool:
                other.release_worker(wk.name)
        if self.fleet.lose_spill_on_death:
            self._lose_spill_tier(wk.name)

    def _lose_spill_tier(self, name: str) -> None:
        """The dead worker's local spill tier dies with it: destroy the
        runs of every map task it had confirmed, roll those tasks back,
        and park reduce partitions until the lineage is regenerated.

        Ordering matters in both phases. While REDUCE is live, park the
        reducers and roll the map confirmations back BEFORE deleting:
        the instant a surviving merge trips over a deleted run, the
        requeue must already look recoverable (map not all confirmed),
        or the job would mistake the injected loss for real data loss.
        While MAP is live the hazard inverts: rolling back first would
        re-pend the task, and a fast survivor could re-spill a run
        concurrently with our deletes — destroying the FRESH copy with
        the task marked confirmed. No reducer reads during map, so
        delete-then-unconfirm is safe there."""
        map_pool, reduce_pool = self._map_pool, self._reduce_pool
        if map_pool is None or self._ctx is None:
            return
        owned = map_pool.confirmed_by(name)
        if not owned:
            return
        lost_keys = []
        for task in owned:
            lost_keys.extend(self._ctx.map_op.spill_keys(task))
        with self._lock:
            reduce_live = self._active_pool is reduce_pool

        def destroy() -> int:
            deleted = 0
            for key in lost_keys:
                try:
                    self.store.delete(self.bucket, key)
                    deleted += 1
                except KeyError:  # ObjectNotFound: never drained, or raced
                    pass
            return deleted

        if reduce_live:
            reduce_pool.block_unconfirmed()
            rolled = map_pool.unconfirm(owned)
            deleted = destroy()
        else:
            deleted = destroy()
            rolled = map_pool.unconfirm(owned)
        with self._lock:
            self.spill_lost_map_tasks += len(rolled)
        if self.tracer is not None:
            self.tracer.instant(
                "cluster.spill_lost", worker=name, map_tasks=len(rolled),
                objects=deleted)
            self.tracer.registry.counter("cluster.spill_lost_tasks",
                                         len(rolled))

    # A reduce task may legitimately requeue a few times (loss, recovery,
    # a second loss); past this budget the missing input is not an
    # injected spill loss but real, unrecoverable data loss.
    MAX_REQUEUES_PER_TASK = 8

    def _on_requeue(self, worker: str, task: int, exc: BaseException) -> bool:
        """A reduce attempt hit ObjectNotFound mid-merge. Recoverable iff
        spill loss is actually in play — otherwise the store really lost
        data and the job must fail."""
        map_pool, reduce_pool = self._map_pool, self._reduce_pool
        if map_pool is None or reduce_pool is None:
            return False
        with self._lock:
            n = self._requeues_by_task[task] = (
                self._requeues_by_task.get(task, 0) + 1)
            loss_seen = self.spill_lost_map_tasks > 0
        plausible = (not map_pool.all_confirmed() or reduce_pool.blocked()
                     or loss_seen)
        if not plausible or n > self.MAX_REQUEUES_PER_TASK:
            return False
        reduce_pool.release_claim(task, worker, block=True)
        with self._lock:
            self.requeued_reduce_tasks += 1
        if self.tracer is not None:
            self.tracer.instant("cluster.reduce_requeued", worker=worker,
                                task=task, error=type(exc).__name__)
        return True


__all__ = ["ClaimPool", "ElasticPhaseDriver", "FleetPlan"]
