"""Streaming group-by aggregation: the second workload, proving the API.

Word-count in CloudSort clothing: input objects hold (group key, id,
value) records; the job aggregates per-group contribution counts and
value sums. Everything sort-specific is absent from these operators —
no device mesh, no gensort layout, no k-way-merge-into-sorted-partitions
contract — yet the workload runs on the identical staging, tiered/faulty
store, budget-governor, and fault-recovery machinery, because those live
in the library (shuffle/runtime.py, shuffle/executor.py), not in the
workload. That is the Exoshuffle claim, made executable.

Dataflow:

  map     — one task per input object: route keys through a
      HashPartitioner (group keys are usually skewed — word
      frequencies — so uniform routing needs a hash), sort the split by
      (partition, key), normalize every record to (key, count, sum) =
      (key, 1, value), optionally collapse equal keys map-side
      (SumCombineOp — the combiner; repeated keys then cost one spilled
      record instead of many), and spill ONE run per task whose
      partition offsets ride in the object metadata (store-recoverable,
      like the sort's spill contract).

  reduce  — partition r streams its slice of every task's run through
      the library's bounded cursors; runs are key-sorted within a
      partition slice, so the scheduler's merge windows arrive in key
      order and the sink aggregates contiguous equal keys with a
      carry for groups straddling window boundaries. Output records are
      unique keys in ascending order: (key, total count, total sum).
      The record count is only known at the end, so the sink defers the
      16-byte header to multipart part 0 and streams body parts from
      index 1 — the out-of-order part-indexed upload contract at work.

Determinism: aggregation is commutative/associative (u32 wrap-around
included), records route by key alone, and output keys are emitted in
sorted unique order — so output bytes are identical at any parallelism,
any worker count, under worker kills, and with the combiner on or off
(only the *spill* bytes shrink). tests/test_shuffle.py asserts all four.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.io import records as rec
from repro.io.backends import StoreBackend

from repro.shuffle.api import (CombineOp, MapOp, Partitioner,
                               PartitionReducer, ReduceOp, ShufflePlan,
                               require)
from repro.shuffle.job import ShuffleJob
from repro.shuffle.partition import HashPartitioner, _splitmix32
from repro.shuffle.runtime import merge_fragments, timed_put

_U32 = np.uint64(0xFFFFFFFF)


def _group_starts(keys: np.ndarray) -> np.ndarray:
    """Start index of each contiguous equal-key group."""
    if keys.size == 0:
        return np.empty((0,), np.int64)
    return np.flatnonzero(
        np.concatenate(([True], keys[1:] != keys[:-1]))).astype(np.int64)


class SumCombineOp(CombineOp):
    """The word-count combiner: collapse contiguous equal keys, summing
    contribution counts (the id field) and values (payload word 0) with
    u32 wrap-around — the same arithmetic the reduce side applies, so
    combining is invisible in the output bytes."""

    def combine(self, keys: np.ndarray, ids: np.ndarray,
                payload: np.ndarray | None):
        starts = _group_starts(keys)
        if starts.size == keys.size:  # nothing to collapse
            return keys, ids, payload
        uk = keys[starts]
        counts = np.add.reduceat(ids.astype(np.uint64), starts)
        sums = np.add.reduceat(payload[:, 0].astype(np.uint64), starts)
        return (uk,
                (counts & _U32).astype(np.uint32),
                (sums & _U32).astype(np.uint32).reshape(-1, 1))


class GroupByMapOp(MapOp):
    """One map task per input object: route, sort, normalize, combine,
    spill one partition-offset-indexed run."""

    num_mesh_workers = 1  # pure host/numpy workload
    spill_objects_per_task = 1

    def __init__(self, plan: ShufflePlan, partitioner: Partitioner,
                 combiner: CombineOp | None = None):
        require(plan.payload_words == 1, "payload_words", plan.payload_words,
                "group-by records carry exactly one value word "
                "(payload[0] = the aggregated sum)")
        self.plan = plan
        self.partitioner = partitioner
        self.combiner = combiner
        self.partition_offsets: dict[int, np.ndarray] = {}
        self._objs: list = []

    def run_key(self, task: int) -> str:
        return f"{self.plan.spill_prefix}run-{task:05d}"

    def spill_keys(self, task: int) -> list[str]:
        return [self.run_key(task)]  # lineage for elastic spill loss

    def plan_tasks(self, store: StoreBackend, bucket: str) -> int:
        plan = self.plan
        inputs = store.list_objects(bucket, plan.input_prefix)
        if not inputs:
            raise ValueError(
                f"input_prefix={plan.input_prefix!r}: no input objects")
        counts = [(m.size - rec.HEADER_BYTES) // plan.record_bytes
                  for m in inputs]
        self._objs = inputs
        self.total_records = sum(counts)
        self.working_set_records = max(counts)
        return len(inputs)

    def load(self, store: StoreBackend, bucket: str, task: int):
        plan = self.plan
        meta = self._objs[task]
        n = (meta.size - rec.HEADER_BYTES) // plan.record_bytes
        rows = rec.alloc_rows(n, plan.payload_words)
        dec = rec.StreamDecoder(rows, 0, what=meta.key)
        for chunk in store.get_chunks(bucket, meta.key,
                                      plan.store_chunk_bytes):
            dec.feed(chunk)
        dec.finish()
        return rec.split_rows(rows)

    def process(self, store: StoreBackend, bucket: str, task: int, data, *,
                spiller, timeline, tag) -> None:
        keys, _ids, payload = data  # raw ids die at normalization below
        t_comp = time.perf_counter()
        parts = self.partitioner.partition_of(keys)
        order = np.lexsort((keys, parts))
        sk = np.ascontiguousarray(keys[order])
        svals = np.ascontiguousarray(payload[order])
        # Normalize to (key, count, sum): every raw record contributes
        # count 1; the combiner (if any) then collapses equal keys so
        # repeated keys cost one spilled record, not many.
        scounts = np.ones(sk.shape, np.uint32)
        sparts = parts[order]  # already routed — don't re-hash the split
        if self.combiner is not None:
            n_before = sk.shape[0]
            sk, scounts, svals = self.combiner.combine(sk, scounts, svals)
            if sk.shape[0] != n_before:
                # A pluggable combiner only promises collapsed records,
                # not index correspondence — re-route the (much smaller)
                # collapsed span.
                sparts = self.partitioner.partition_of(sk)
        offsets = np.searchsorted(
            sparts, np.arange(self.partitioner.num_partitions + 1),
            side="left").astype(np.int64)
        self.partition_offsets[task] = offsets
        encoded = rec.encode_records(sk, scounts, svals)
        timeline.add("map.compute", t_comp, worker=tag)
        t_spill = time.perf_counter()
        spiller.submit(timed_put, timeline, tag, store, bucket,
                       self.run_key(task), encoded, {
                           "records": int(sk.shape[0]),
                           "task": task,
                           "partition_offsets": [int(o) for o in offsets],
                       })
        timeline.add("map.spill_wait", t_spill, worker=tag)


class _GroupAggSink(PartitionReducer):
    """Streaming aggregation of key-sorted merge windows.

    Equal keys are contiguous within a window (the scheduler merges
    fragments by packed key) but one group may straddle windows, so the
    last group of every non-final window is carried into the next. The
    output record count is unknown until the carry flushes, hence the
    deferred part-0 header.
    """

    deferred_part0 = True

    def __init__(self, payload_words: int):
        self._pw = int(payload_words)
        self._carry: tuple[int, int, int] | None = None  # (key, count, sum)
        self._emitted = 0

    def begin(self) -> bytes:
        return b""

    def _aggregate(self, keys, counts, sums, *, final: bool):
        starts = _group_starts(keys)
        uk = keys[starts].astype(np.uint64)
        uc = np.add.reduceat(counts.astype(np.uint64), starts) \
            if starts.size else np.empty((0,), np.uint64)
        us = np.add.reduceat(sums.astype(np.uint64), starts) \
            if starts.size else np.empty((0,), np.uint64)
        if self._carry is not None:
            ck, cc, cs = self._carry
            if uk.size and int(uk[0]) == ck:
                uc[0] += cc
                us[0] += cs
            else:
                # Explicit uint64 operands: a bare [int] + uint64-array
                # concatenate promotes to float64, silently rounding
                # accumulators above 2^53.
                uk = np.concatenate((np.array([ck], np.uint64), uk))
                uc = np.concatenate((np.array([cc], np.uint64), uc))
                us = np.concatenate((np.array([cs], np.uint64), us))
            self._carry = None
        if not final and uk.size:
            self._carry = (int(uk[-1]), int(uc[-1]), int(us[-1]))
            uk, uc, us = uk[:-1], uc[:-1], us[:-1]
        if not uk.size:
            return b""
        self._emitted += int(uk.size)
        return rec.encode_body(
            uk.astype(np.uint32),
            (uc & _U32).astype(np.uint32),
            (us & _U32).astype(np.uint32).reshape(-1, 1))

    def consume(self, frags, *, final: bool) -> bytes:
        mk, mi, mp = merge_fragments(frags, self._pw)
        sums = mp[:, 0] if mk.size else np.empty((0,), np.uint32)
        return self._aggregate(mk, mi, sums, final=final)

    def finalize(self) -> tuple[bytes, bytes | None]:
        tail = b""
        if self._carry is not None:  # defensive: final consume flushes it
            ck, cc, cs = self._carry
            self._carry = None
            self._emitted += 1
            tail = rec.encode_body(
                np.array([ck], np.uint32),
                np.array([cc & 0xFFFFFFFF], np.uint32),
                np.array([[cs & 0xFFFFFFFF]], np.uint32))
        return tail, rec.encode_header(self._emitted, self._pw)


class GroupByReduceOp(ReduceOp):
    """Partition r streams its slice of every task's run into one
    aggregated, key-sorted output object."""

    def __init__(self, plan: ShufflePlan, map_op: GroupByMapOp):
        self.plan = plan
        self.map_op = map_op
        self.payload_words = plan.payload_words

    def sources(self, r: int) -> tuple[list[tuple[str, int, int]], int]:
        map_op = self.map_op
        slices, n_total = [], 0
        for g in range(len(map_op._objs)):
            offs = map_op.partition_offsets[g]
            lo, hi = int(offs[r]), int(offs[r + 1])
            if hi > lo:
                slices.append((map_op.run_key(g), lo, hi))
                n_total += hi - lo
        return slices, n_total

    def output_key(self, r: int) -> str:
        return f"{self.plan.output_prefix}agg-{r:05d}"

    def output_metadata(self, r: int, n_total: int) -> dict:
        return {"partition": r, "input_records": n_total}

    def open(self, r: int, n_total: int) -> PartitionReducer:
        return _GroupAggSink(self.payload_words)


def groupby_job(store: StoreBackend, bucket: str, *, plan: ShufflePlan,
                num_partitions: int, combine: bool = True,
                tracer=None) -> ShuffleJob:
    """Build the group-by ShuffleJob: hash-routed keyed aggregation with
    an optional map-side combiner. `tracer` as in sort_shuffle_job."""
    partitioner = HashPartitioner(num_partitions)
    map_op = GroupByMapOp(plan, partitioner,
                          combiner=SumCombineOp() if combine else None)
    reduce_op = GroupByReduceOp(plan, map_op)
    return ShuffleJob(store, bucket, plan=plan, map_op=map_op,
                      reduce_op=reduce_op, partitioner=partitioner,
                      tracer=tracer)


# ---------------------------------------------------------------------------
# Synthetic skewed input + streaming validation (the workload's gensort
# and valsort analogues).
# ---------------------------------------------------------------------------

_VALUE_SALT = np.uint32(0x7F4A7C15)


def write_groupby_input(store: StoreBackend, bucket: str, prefix: str,
                        total_records: int, records_per_partition: int, *,
                        num_groups: int, skew: float = 1.0,
                        value_range: int = 8):
    """Deterministic skewed keyed input, written through the store.

    Record i: group key = floor(num_groups * u^skew) with
    u = splitmix32(i) / 2^32 (skew > 1 concentrates mass on low group
    ids — the word-frequency shape), value = splitmix32(i ^ salt) in
    [1, value_range]. Reproducible from the parameters alone, like
    gensort. Returns (expected_counts, expected_sums) uint64 arrays of
    length num_groups — the reference the streaming validator checks
    against (mod 2^32, the output's wrap-around arithmetic).
    """
    require(total_records % records_per_partition == 0, "total_records",
            total_records, "must tile records_per_partition exactly")
    require(num_groups >= 1, "num_groups", num_groups, "must be >= 1")
    require(skew > 0, "skew", skew, "must be > 0")
    for meta in store.list_objects(bucket, prefix):
        store.delete(bucket, meta.key)
    expected_counts = np.zeros(num_groups, np.uint64)
    expected_sums = np.zeros(num_groups, np.uint64)
    num_parts = total_records // records_per_partition
    for p in range(num_parts):
        ids = np.arange(p * records_per_partition,
                        (p + 1) * records_per_partition, dtype=np.uint32)
        u = _splitmix32(ids).astype(np.float64) / float(1 << 32)
        groups = np.minimum(
            (num_groups * np.power(u, skew)).astype(np.int64),
            num_groups - 1)
        values = _splitmix32(ids ^ _VALUE_SALT) % np.uint32(value_range) \
            + np.uint32(1)
        np.add.at(expected_counts, groups, 1)
        np.add.at(expected_sums, groups, values.astype(np.uint64))
        data = rec.encode_records(groups.astype(np.uint32), ids,
                                  values.reshape(-1, 1))
        store.put(bucket, f"{prefix}part-{p:05d}", data,
                  metadata={"records": records_per_partition})
    return expected_counts, expected_sums


@dataclasses.dataclass
class GroupByValidation:
    """The three group-by gates: sorted unique keys, correct routing,
    and exact aggregates (counts and sums, mod 2^32)."""

    total_groups: int
    input_records: int  # sum of output counts (mod 2^32)
    keys_sorted_unique: bool
    routing_ok: bool
    counts_match: bool
    sums_match: bool

    @property
    def ok(self) -> bool:
        return (self.keys_sorted_unique and self.routing_ok
                and self.counts_match and self.sums_match)


def validate_groupby_from_store(store: StoreBackend, bucket: str,
                                prefix: str, partitioner: Partitioner,
                                expected_counts: np.ndarray,
                                expected_sums: np.ndarray, *,
                                chunk_records: int = 1 << 13
                                ) -> GroupByValidation:
    """Stream the aggregated output back out of the store and check it
    against the generation-time reference — never holding more than
    `chunk_records` decoded records (the valsort discipline)."""
    num_groups = int(expected_counts.shape[0])
    got_counts = np.zeros(num_groups, np.uint64)
    got_sums = np.zeros(num_groups, np.uint64)
    keys_sorted_unique = True
    routing_ok = True
    total_groups = 0
    for meta in store.list_objects(bucket, prefix):
        r = int(meta.key.rsplit("-", 1)[1])
        n, pw = rec.decode_header(
            store.get_range(bucket, meta.key, 0, rec.HEADER_BYTES))
        prev_last = None
        for lo in range(0, n, chunk_records):
            cnt = min(chunk_records, n - lo)
            start, length = rec.body_range(lo, cnt, pw)
            k, c, s = rec.decode_body(
                store.get_range(bucket, meta.key, start, length), pw)
            if k.size:
                if not bool(np.all(k[1:] > k[:-1])):
                    keys_sorted_unique = False
                if prev_last is not None and int(k[0]) <= prev_last:
                    keys_sorted_unique = False
                prev_last = int(k[-1])
                if not bool(np.all(partitioner.partition_of(k) == r)):
                    routing_ok = False
                if int(k.max()) >= num_groups:
                    routing_ok = False
                    continue
            np.add.at(got_counts, k.astype(np.int64), c.astype(np.uint64))
            np.add.at(got_sums, k.astype(np.int64),
                      s[:, 0].astype(np.uint64))
            total_groups += int(k.size)
    counts_match = bool(np.array_equal(got_counts & _U32,
                                       expected_counts & _U32))
    sums_match = bool(np.array_equal(got_sums & _U32,
                                     expected_sums & _U32))
    return GroupByValidation(
        total_groups=total_groups,
        input_records=int(got_counts.sum() & _U32),
        keys_sorted_unique=keys_sorted_unique,
        routing_ok=routing_ok,
        counts_match=counts_match,
        sums_match=sums_match,
    )


__all__ = [
    "GroupByMapOp",
    "GroupByReduceOp",
    "GroupByValidation",
    "SumCombineOp",
    "groupby_job",
    "validate_groupby_from_store",
    "write_groupby_input",
]
