"""Shuffle-as-a-library: the generic dataflow API carved out of the
sort-specific drivers (Exoshuffle's thesis, applied to this repo).

The paper argues shuffle belongs in an application-level library, not a
monolithic engine — the application brings its operators, the library
brings staging, scheduling, memory governance, and fault recovery. This
package is that library:

  api.py        — operator protocols (MapOp / CombineOp / ReduceOp /
                  Partitioner / PartitionReducer), the generic
                  ShufflePlan, unified plan validation (`require`), and
                  the ShuffleReport / ClusterShuffleReport contracts.
  partition.py  — pluggable partitioners: RangePartitioner (equal or
                  sampled key ranges) and HashPartitioner (uniform
                  routing for skewed key sets).
  runtime.py    — the engine room: span timeline, job control, the
                  AdaptiveBudgetGovernor, streaming run cursors, the
                  generic ReduceScheduler, and the staged map loop.
  executor.py   — multi-worker execution: the Worker protocol,
                  ThreadWorker / FaultyWorker, task stealing, and the
                  phase driver with durable-confirmation re-execution.
  job.py        — the front end: ShuffleJob / ShuffleSession owning
                  plan validation, staging, the budget governor, span
                  timelines, and single-host vs. cluster execution
                  behind one `job.run(workers=N)` call.
  sort.py       — CloudSort as one instantiation: SortMapOp /
                  MergeReduceOp wrapping core/external_sort's
                  WaveSorter and streaming-merge bodies.
  groupby.py    — a second workload, proving generality: streaming
                  group-by aggregation (word-count-style keyed reduce
                  with a map-side combiner) on the same store stack.

Workload modules import lazily where they need jax, so group-by (pure
numpy) never pays for the device toolchain.
"""
from repro.shuffle.api import (ClusterShuffleReport, CombineOp, MapOp,
                               Partitioner, PartitionReducer, ReduceOp,
                               ShufflePlan, ShuffleReport, require,
                               validate_dataflow_plan)
from repro.shuffle.executor import (ClusterFailure, ClusterPlan, FaultyWorker,
                                    ThreadWorker, Worker, WorkerFailure)
from repro.shuffle.job import ShuffleJob, ShuffleSession
from repro.shuffle.partition import HashPartitioner, RangePartitioner

__all__ = [
    "ClusterFailure",
    "ClusterPlan",
    "ClusterShuffleReport",
    "CombineOp",
    "FaultyWorker",
    "HashPartitioner",
    "MapOp",
    "Partitioner",
    "PartitionReducer",
    "RangePartitioner",
    "ReduceOp",
    "ShuffleJob",
    "ShufflePlan",
    "ShuffleReport",
    "ShuffleSession",
    "ThreadWorker",
    "Worker",
    "WorkerFailure",
    "require",
    "validate_dataflow_plan",
]
