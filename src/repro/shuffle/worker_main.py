"""Subprocess worker entry point: `python -m repro.shuffle.worker_main`.

The child half of shuffle/procworker.ProcessWorker. The parent writes
one JSON spec line to stdin; the child rebuilds its world from it — a
store handle over the SHARED filesystem root (its own middleware stack:
metrics, plus an optional latency/bandwidth fault profile to make this
one worker a straggler), its own JAX runtime (XLA_FLAGS from the parent
env pins the host device count BEFORE the first jax import), the sort
plan and mesh — then speaks a line-delimited JSON protocol:

  child -> parent                      parent -> child
  {"ev":"ready"}                       {"cmd":"phase","phase":"map"}
  {"ev":"hb"}                          {"cmd":"task","task":3|null}
  {"ev":"need"}                        {"cmd":"commit","task":7,"ok":true}
  {"ev":"done","task":3}               {"cmd":"requeue_ack","task":7,
  {"ev":"commit","task":7}                                  "ok":true}
  {"ev":"requeue","task":7}            {"cmd":"shutdown"}
  {"ev":"phase_end","phase":...,
   "stats":{...}}
  {"ev":"error","detail":"..."}

Pop ("need"/"task") and commit ("commit") round trips are serialized by
SEPARATE child-side locks: a pop may block parent-side for seconds (the
elastic ClaimPool waits for work), and a finisher's commit gate must
never queue behind it — that ordering freedom is what makes the
loser-abort path deadlock-free. "done" is fire-and-forget and is sent
only after the durable multipart commit (the same confirmation contract
every Worker obeys).

Durability recovery hinges on state the STORE holds, not the process:
reduce-side run offsets are reloaded from spill-object metadata
(`reducer_offsets`, written by the map side) at every reduce phase
start, so this child can merge runs that a different — possibly dead —
worker spilled. A missing offset or vanished run surfaces as
ObjectNotFound and is routed to the parent as a requeue, not a crash.

Fault injection: `die_after_tasks` N makes the child `os._exit(3)` at
its N+1-th task pop — before any claim, never between a commit and its
confirmation — so injected process deaths are pre-commit-deterministic
exactly like executor.FaultyWorker's task budget.
"""
from __future__ import annotations

import dataclasses
import json
import os
import queue
import sys
import threading
import time
import traceback


def _build_store(spec: dict):
    from repro.io.backends import FilesystemBackend
    from repro.io.middleware import (FaultProfile, LatencyBandwidthMiddleware,
                                     MetricsMiddleware)
    from repro.io.tiered import TieredStore

    chunk = int(spec.get("chunk_size", 4 << 20))

    def fs(root):
        return FilesystemBackend(root, chunk_size=chunk)

    if spec["kind"] == "tiered":
        store = TieredStore(fs(spec["durable_root"]), fs(spec["ssd_root"]),
                            ssd_prefixes=tuple(spec.get("ssd_prefixes",
                                                        ("spill/",))))
    else:
        store = fs(spec["root"])
    fault = spec.get("fault")
    if fault:
        # Per-worker injected slowness: this is how a chaos schedule
        # makes ONE process a straggler without touching the shared data.
        store = LatencyBandwidthMiddleware(store, FaultProfile(**fault))
    return MetricsMiddleware(store)


class _Protocol:
    """Line-JSON duplex with routed replies (see module docstring)."""

    def __init__(self, out):
        self._out = out
        self._wlock = threading.Lock()
        self.cmds: queue.Queue = queue.Queue()  # phase / shutdown
        self.tasks: queue.Queue = queue.Queue()  # "task" replies
        self.commits: queue.Queue = queue.Queue()  # "commit" replies
        self.requeues: queue.Queue = queue.Queue()  # "requeue_ack" replies
        self.pop_lock = threading.Lock()
        self.commit_lock = threading.Lock()
        self.requeue_lock = threading.Lock()

    def send(self, msg: dict) -> None:
        data = json.dumps(msg)
        with self._wlock:
            self._out.write(data + "\n")
            self._out.flush()

    def reader(self) -> None:
        routes = {"task": self.tasks, "commit": self.commits,
                  "requeue_ack": self.requeues}
        for line in sys.stdin:
            if not line.strip():
                continue
            msg = json.loads(line)
            routes.get(msg.get("cmd"), self.cmds).put(msg)
        # Parent gone: a worker with no driver has no reason to live.
        self.cmds.put({"cmd": "shutdown"})
        for q in routes.values():
            q.put(None)


def main() -> int:
    proto = _Protocol(sys.stdout)
    # Stray prints (library chatter) must not corrupt the protocol pipe.
    sys.stdout = sys.stderr
    spec = json.loads(sys.stdin.readline())
    name = spec["name"]

    import numpy as np

    from repro.core.compat import make_mesh
    from repro.core.external_sort import ExternalSortPlan
    from repro.io.backends import ObjectNotFound
    from repro.shuffle import runtime as rt
    from repro.shuffle.sort import DeviceMergeReduceOp, MergeReduceOp, SortMapOp

    store = _build_store(spec["store"])
    bucket = spec["bucket"]
    plan = ExternalSortPlan(**spec["plan"])
    mesh = make_mesh((int(spec["mesh_devices"]),), (spec.get("axis", "w"),))
    map_op = SortMapOp(plan, mesh, spec.get("axis", "w"))
    num_tasks = map_op.plan_tasks(store, bucket)
    num_partitions = map_op.sorter.w * map_op.sorter.r1
    if getattr(plan, "reduce_merge_impl", "numpy") == "device":
        reduce_op = DeviceMergeReduceOp(plan, map_op)
    else:
        reduce_op = MergeReduceOp(plan, map_op)

    def refresh_offsets() -> None:
        """Rebuild run offsets from spill metadata in the shared store —
        the process-worker substitute for the in-process offsets dict a
        thread fleet shares. Runs another worker spilled (or re-spilled
        after a loss) become mergeable here."""
        for meta in store.list_objects(bucket, plan.spill_prefix):
            md = meta.metadata
            if {"wave", "worker", "reducer_offsets"} <= md.keys():
                map_op.spill_offsets[(int(md["wave"]), int(md["worker"]))] = (
                    np.asarray(md["reducer_offsets"], np.int64))

    class _StoreBackedSources:
        """reduce_op proxy: a KeyError from the offsets dict means this
        child never saw that wave's spill — refresh from the store, and
        if the run truly is gone (correlated spill loss), surface it as
        ObjectNotFound so the scheduler requeues instead of crashing."""

        def __getattr__(self, attr):
            return getattr(reduce_op, attr)

        def sources(self, r: int):
            try:
                return reduce_op.sources(r)
            except KeyError:
                refresh_offsets()
                try:
                    return reduce_op.sources(r)
                except KeyError as e:
                    raise ObjectNotFound(
                        f"spill run offsets missing for partition {r}: {e}")

    # Warm the compiled sort BEFORE declaring ready: the first
    # device_sort triggers XLA compilation, and W children compiling
    # inside the measured region would charge the process fleet W
    # compiles where the thread fleet (one shared WaveSorter) pays one.
    # Uniform random keys (the gensort distribution) keep every
    # partition under capacity so the overflow check stays quiet —
    # evenly STRIDED keys would pin the round-routing bits and
    # overflow one block.
    n_warm = int(plan.records_per_wave)
    warm_keys = np.random.default_rng(0).integers(
        0, 1 << 32, n_warm, dtype=np.uint64).astype("<u4")
    map_op.sorter.device_sort(warm_keys, np.zeros(n_warm, "<u4"))

    die_after = spec.get("die_after_tasks")
    popped = 0

    def rpc_pop():
        nonlocal popped
        with proto.pop_lock:
            if die_after is not None and popped >= die_after:
                # Injected process death: at pop time, pre-commit, like
                # FaultyWorker's task budget — the local spill tier dies
                # with the process.
                os._exit(3)
            proto.send({"ev": "need"})
            msg = proto.tasks.get()
            if msg is None:
                return None
            task = msg["task"]
            if task is not None:
                popped += 1
            return task

    def rpc_done(task: int) -> None:
        proto.send({"ev": "done", "task": int(task)})

    def rpc_commit(r: int) -> bool:
        with proto.commit_lock:
            proto.send({"ev": "commit", "task": int(r)})
            msg = proto.commits.get()
        if msg is None:
            return False  # parent gone: never commit into the void
        assert msg["task"] == r, (msg, r)
        return bool(msg["ok"])

    def rpc_requeue(r: int, exc: BaseException) -> bool:
        with proto.requeue_lock:
            proto.send({"ev": "requeue", "task": int(r),
                        "error": type(exc).__name__})
            msg = proto.requeues.get()
        return bool(msg and msg["ok"])

    def heartbeat(stop: threading.Event) -> None:
        interval = float(spec.get("heartbeat_interval_s", 0.2))
        while not stop.wait(interval):
            proto.send({"ev": "hb"})

    def run_phase(phase: str, gated: bool) -> None:
        control = rt.JobControl()
        timeline = rt.PhaseTimeline(origin=time.perf_counter())
        if phase == "map":
            # `gated` means the parent runs a speculation claim pool for
            # this phase: poll the commit RPC per fetched map chunk (and
            # at commit) so a beaten attempt aborts at its next chunk
            # instead of loading the whole wave — the process-fleet
            # mirror of the reduce side's _AbandonGatedReads.
            rt.run_map_tasks(store, bucket, map_op, rpc_pop, plan=plan,
                             timeline=timeline, control=control,
                             tag_prefix=f"{name}/", on_done=rpc_done,
                             commit_gate=rpc_commit if gated else None)
        else:
            refresh_offsets()
            slots = min(plan.parallel_reducers, num_partitions)
            governor = rt.AdaptiveBudgetGovernor(
                budget=plan.reduce_memory_budget_bytes,
                chunk_cap=plan.merge_chunk_bytes,
                record_bytes=plan.record_bytes,
                slots=slots, partitions=num_partitions)
            shared = rt.ReduceShared(
                plan=plan, bucket=bucket, reduce_op=_StoreBackedSources(),
                governor=governor, timeline=timeline,
                peak=rt.PeakTracker(), control=control)
            rt.ReduceScheduler(
                store, shared, width=slots, runs_hint=num_tasks,
                tag_prefix=f"{name}/", requeue=(ObjectNotFound,),
                on_requeue=rpc_requeue, commit_gate=rpc_commit,
            ).run(rpc_pop, on_done=rpc_done)
        control.raise_first()

    reader = threading.Thread(target=proto.reader, daemon=True,
                              name="proto-reader")
    reader.start()
    hb_stop = threading.Event()
    hb = threading.Thread(target=heartbeat, args=(hb_stop,), daemon=True,
                          name="heartbeat")
    hb.start()
    proto.send({"ev": "ready", "tasks": num_tasks,
                "partitions": num_partitions})
    try:
        while True:
            cmd = proto.cmds.get()
            if cmd["cmd"] == "shutdown":
                return 0
            phase = cmd["phase"]
            try:
                run_phase(phase, bool(cmd.get("gated", False)))
            except BaseException:
                proto.send({"ev": "error", "phase": phase,
                            "detail": traceback.format_exc(limit=20)})
            else:
                proto.send({"ev": "phase_end", "phase": phase,
                            "stats": dataclasses.asdict(
                                store.stats_snapshot())})
    finally:
        hb_stop.set()


if __name__ == "__main__":
    sys.exit(main())
