"""Operator protocols and plan/report contracts of the shuffle library.

Exoshuffle's claim is that a shuffle is three application-supplied
operators plus a partitioner, and everything else — staging, scheduling,
memory governance, fault recovery — is reusable library machinery. This
module is the contract between the two halves:

  MapOp        — turns one input split (a "map task") into partitioned
      spill runs in the store. The library owns prefetching splits ahead
      of compute and write-behind spilling; the op owns what a split is,
      how it is loaded, and how its records are routed/combined/encoded.

  CombineOp    — optional map-side pre-aggregation: applied to a
      partition-and-key-sorted record span before it is spilled, so
      repeated keys collapse at the mapper and the shuffle moves less
      data (the word-count combiner).

  ReduceOp     — streams one output partition's spill-run slices into
      output parts. The library owns the streaming cursors, the chunk
      budget, multipart upload fan-out, and durability confirmation; the
      op owns which (run, lo, hi) slices feed partition r and how
      buffered sorted fragments become output bytes (PartitionReducer).

  Partitioner  — the pluggable routing function (shuffle/partition.py):
      an ordered set of internal boundaries over a routed uint32 domain.
      The contract (tested property-style in tests/test_shuffle.py) is
      exhaustive, non-overlapping coverage: every routed key falls in
      exactly one of num_partitions ranges.

All plan validation on this surface raises ValueError with the offending
knob name and value (`require`) — never a bare assert, so the contract
survives `python -O`.
"""
from __future__ import annotations

import abc
import dataclasses
from typing import TYPE_CHECKING

import numpy as np

from repro.io import records as rec
from repro.io.backends import StoreBackend, StoreStats

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.io.staging import AsyncWriter
    from repro.shuffle.runtime import PhaseTimeline, Span


def require(condition: bool, knob: str, value, why: str) -> None:
    """Unified plan/operator validation: ValueError naming the offending
    knob and its value, consistently across ExternalSortPlan, ClusterPlan,
    and the shuffle plans. Never an assert — must survive python -O."""
    if not condition:
        raise ValueError(f"{knob}={value!r}: {why}")


def validate_dataflow_plan(plan) -> None:
    """Validate the generic dataflow knobs any shuffle plan must carry.

    Structural, not nominal: ShufflePlan and ExternalSortPlan both
    satisfy it. Workload plans add their own checks on top (e.g.
    WaveSorter's wave/mesh divisibility) — this is the shared floor the
    session enforces before any input byte is fetched (and billed).
    """
    require(plan.payload_words >= 0, "payload_words", plan.payload_words,
            "must be >= 0")
    rb = rec.record_bytes(plan.payload_words)
    require(plan.store_chunk_bytes >= 1, "store_chunk_bytes",
            plan.store_chunk_bytes, "must be >= 1 byte per map-download GET")
    require(plan.merge_chunk_bytes >= rb, "merge_chunk_bytes",
            plan.merge_chunk_bytes,
            f"must hold at least one {rb}-byte record, else the "
            "reduce-memory bound cannot be met")
    require(plan.output_part_records >= 1, "output_part_records",
            plan.output_part_records, "must be >= 1 record per output part")
    require(plan.prefetch_depth >= 1, "prefetch_depth", plan.prefetch_depth,
            "must keep >= 1 load in flight")
    require(plan.max_inflight_writes >= 1, "max_inflight_writes",
            plan.max_inflight_writes, "must allow >= 1 pending write")
    require(plan.io_retries >= 0, "io_retries", plan.io_retries,
            "must be >= 0")
    require(plan.parallel_reducers >= 1, "parallel_reducers",
            plan.parallel_reducers, "must run >= 1 streaming merge")
    require(plan.part_upload_fanout >= 1, "part_upload_fanout",
            plan.part_upload_fanout, "must allow >= 1 in-flight part upload")
    require(plan.reduce_memory_budget_bytes >= 0,
            "reduce_memory_budget_bytes", plan.reduce_memory_budget_bytes,
            "must be >= 0 (0 = uncapped)")
    for knob in ("input_prefix", "spill_prefix", "output_prefix"):
        require(bool(getattr(plan, knob)), knob, getattr(plan, knob),
                "must be a non-empty key prefix")
    # The three prefixes must be mutually non-overlapping (neither may be
    # a prefix of another): session preflight DELETES everything under
    # spill_prefix and output_prefix, so an overlap with input_prefix
    # would destroy the input before the map phase ever runs.
    names = ("input_prefix", "spill_prefix", "output_prefix")
    for i, a in enumerate(names):
        for b in names[i + 1:]:
            va, vb = getattr(plan, a), getattr(plan, b)
            require(not va.startswith(vb) and not vb.startswith(va),
                    b, vb,
                    f"overlaps {a}={va!r} — prefixes must be disjoint "
                    "(the session clears spill/output prefixes between "
                    "runs)")


@dataclasses.dataclass(frozen=True)
class ShufflePlan:
    """The generic dataflow schedule: store layout + streaming knobs.

    This is ExternalSortPlan minus everything sort-specific (mesh rounds,
    wave tiling, capacity factors): what any shuffle workload needs to
    say about prefixes, chunk granularities, concurrency, and the global
    reduce memory budget. See core/external_sort.ExternalSortPlan for
    the knob-by-knob invariants — they are identical here because the
    same runtime enforces them.
    """

    input_prefix: str = "input/"
    spill_prefix: str = "spill/"
    output_prefix: str = "output/"
    payload_words: int = 1  # u32 payload words per record
    store_chunk_bytes: int = 256 << 10  # map download GET granularity
    merge_chunk_bytes: int = 64 << 10  # reduce per-run fetch cap
    output_part_records: int = 1 << 13  # multipart-upload part size
    prefetch_depth: int = 2  # map split double buffering
    max_inflight_writes: int = 2  # spill / part-upload backpressure
    io_retries: int = 2  # staging-level re-reads of a failed split load
    parallel_reducers: int = 4  # concurrent streaming merges per scheduler
    reduce_memory_budget_bytes: int = 0  # global merge budget; 0 = uncapped
    part_upload_fanout: int = 2  # out-of-order part uploads per partition

    @property
    def record_bytes(self) -> int:
        return rec.record_bytes(self.payload_words)

    def validate(self) -> None:
        validate_dataflow_plan(self)


class Partitioner(abc.ABC):
    """Pluggable partition routing over a uint32 key domain.

    A partitioner is an ordered set of `num_partitions - 1` internal
    boundaries over a *routed* domain (identity for range partitioning,
    a hash for hash partitioning): key k belongs to partition
    `searchsorted(boundaries, route(k), side="right")`. The ranges are
    exhaustive and non-overlapping by construction — the property the
    partitioner test suite checks on every implementation.
    """

    num_partitions: int

    @abc.abstractmethod
    def boundaries(self) -> np.ndarray:
        """(num_partitions - 1,) ascending uint32 internal boundaries in
        the routed domain. A routed value v belongs to partition j iff
        boundaries[j-1] <= v < boundaries[j] (with the implicit outer
        bounds 0 and 2^32)."""

    def route(self, keys: np.ndarray) -> np.ndarray:
        """Map raw keys into the routed domain (identity by default)."""
        return np.asarray(keys, dtype=np.uint32)

    def partition_of(self, keys: np.ndarray) -> np.ndarray:
        """(n,) int64 destination partition per key."""
        routed = self.route(keys)
        return np.searchsorted(
            self.boundaries(), routed, side="right").astype(np.int64)


class MapOp(abc.ABC):
    """Turn one input split into partitioned spill runs.

    One instance is stateful for one job: `plan_tasks` fixes the split
    list (and the `total_records` / `working_set_records` accounting the
    report carries), `load` fetches one split (called on the staging
    pipeline's prefetch threads, possibly `io_retries` times), and
    `process` routes/sorts/combines/spills it through the library's
    write-behind `spiller`. Spill determinism is the load-bearing
    contract: the run bytes `process(task)` writes must depend only on
    (task id, plan, input) — never on which worker executes the task or
    how many times (cluster re-execution replays it verbatim).
    """

    total_records: int = 0  # set by plan_tasks
    working_set_records: int = 0  # largest split (report.oversubscription)
    num_mesh_workers: int = 1  # device-mesh width (1 for host-only ops)
    spill_objects_per_task: int = 1  # report accounting

    @abc.abstractmethod
    def plan_tasks(self, store: StoreBackend, bucket: str) -> int:
        """Enumerate input splits; returns the map-task count. Raises
        ValueError when there is no input under plan.input_prefix."""

    @abc.abstractmethod
    def load(self, store: StoreBackend, bucket: str, task: int):
        """Fetch split `task` (runs on a prefetch thread)."""

    @abc.abstractmethod
    def process(self, store: StoreBackend, bucket: str, task: int, data, *,
                spiller: "AsyncWriter", timeline: "PhaseTimeline",
                tag: str) -> None:
        """Partition + spill split `task` (loaded as `data`), submitting
        run puts through `spiller` and recording map.* spans."""

    def spill_keys(self, task: int) -> list[str]:
        """Lineage: every spill-run key `process(task)` writes. The
        elastic driver (shuffle/elastic.py) uses this to model correlated
        spill-tier loss — deleting a dead worker's runs and re-executing
        exactly the map tasks that produced them. Ops that don't support
        elastic spill loss may keep the default."""
        raise NotImplementedError(
            f"{type(self).__name__} does not expose spill lineage "
            "(required for FleetPlan.lose_spill_on_death)")

    # -- optional staged interface (pipelined map executor) --------------
    #
    # An op may additionally split `process` at the device boundary by
    # defining BOTH:
    #
    #   device_step(task, data, *, timeline, tag) -> staged
    #       The device-bound portion (sort/compute). Runs on a dedicated
    #       single-thread stage; must not touch the store. Records
    #       map.device_sort (and map.compute, for phase-total
    #       compatibility) spans.
    #
    #   encode_step(store, bucket, task, staged, *, spiller, timeline,
    #               tag) -> None
    #       The host-bound encode + spill portion. Runs on a second
    #       single-thread stage; receives `staged` from device_step and
    #       records map.encode / map.spill_wait spans.
    #
    # When the plan sets `map_pipeline` (see ExternalSortPlan) and both
    # methods exist, runtime.run_map_tasks software-pipelines the waves:
    # wave N's host decode (`load`) overlaps wave N-1's device_step and
    # wave N-2's encode_step. The two stages are each single-threaded
    # and consumed in task order, so spill bytes — and therefore the
    # whole shuffle output — are unchanged from the monolithic path.
    # Ops that only define `process` always run monolithically.


class CombineOp(abc.ABC):
    """Map-side pre-aggregation over a partition-and-key-sorted span.

    `combine` receives records already sorted so equal keys are
    contiguous (and never straddle a partition boundary, since equal
    keys route identically); it returns the collapsed span in the same
    order. The shuffle then spills and moves only the combined bytes.
    """

    @abc.abstractmethod
    def combine(self, keys: np.ndarray, ids: np.ndarray,
                payload: np.ndarray | None):
        """(keys, ids, payload) -> collapsed (keys, ids, payload)."""


class PartitionReducer(abc.ABC):
    """Per-partition streaming consumer: sorted fragments in, output
    bytes out. Created by ReduceOp.open(r); driven by the scheduler's
    emit cycles, which guarantee fragments arrive in ascending
    (key << 32 | id) order across calls and that `final=True` marks the
    cycle after which no more records exist."""

    #: True when part 0 is reserved for bytes only known at the end
    #: (e.g. a record-count header after aggregation): body parts are
    #: then indexed from 1 and `finalize` must return the part-0 bytes —
    #: the out-of-order multipart contract makes the upload order legal.
    deferred_part0: bool = False

    # -- optional execution-context hook ---------------------------------
    #
    # A reducer may define
    #
    #   bind_exec(*, timeline, tag) -> None
    #
    # and the scheduler calls it once, right after ReduceOp.open(),
    # before `begin`. It hands the sink the run's PhaseTimeline and this
    # partition's worker tag so sinks that do work off the scheduler
    # thread (e.g. the device merge's staged encode) can attribute their
    # spans. Purely observational: sinks without the hook behave
    # identically.

    @abc.abstractmethod
    def begin(self) -> bytes:
        """Bytes the part stream starts with (b"" when deferred)."""

    @abc.abstractmethod
    def consume(self, frags, *, final: bool) -> bytes:
        """Fold one emit cycle's per-run fragments (each a (keys, ids,
        payload, k64) tuple of sorted arrays) into output body bytes."""

    def finalize(self) -> tuple[bytes, bytes | None]:
        """(tail body bytes, deferred part-0 bytes or None). Called once
        after the last consume; the part-0 element must be non-None iff
        `deferred_part0`."""
        return b"", None


class ReduceOp(abc.ABC):
    """Stream one output partition's spill runs into output parts.

    The scheduler owns cursors, budget grants, uploads, and durability;
    the op owns the data: which byte slices of which run objects feed
    partition r (`sources`), where the output goes (`output_key`), and
    how sorted fragments become bytes (`open` -> PartitionReducer).

    Optional hooks (duck-typed, for ops that bypass the k-way merge —
    shuffle/recursive's redirected partitions):

      sequential_partition(r) -> bool — True makes the scheduler drain
          partition r's run cursors ONE AT A TIME (source order, runs=1
          budget grant) instead of merging them; the sink must accept
          unmerged fragments (a concatenator, not a merger). This is
          what removes the reduce fan-in ceiling for partitions headed
          into another shuffle round.
      feasibility_runs(num_tasks) -> int — the worst-case concurrent
          run fan-in for the session's budget preflight
          (runtime.reduce_chunking); defaults to num_tasks when absent.
    """

    payload_words: int = 0  # decode width of the spilled run records

    @abc.abstractmethod
    def sources(self, r: int) -> tuple[list[tuple[str, int, int]], int]:
        """([(run key, lo record, hi record)], total records) feeding
        output partition r — empty list for an empty partition."""

    @abc.abstractmethod
    def output_key(self, r: int) -> str:
        """Store key of partition r's output object."""

    def output_metadata(self, r: int, n_total: int) -> dict:
        return {"records": n_total, "partition": r}

    @abc.abstractmethod
    def open(self, r: int, n_total: int) -> PartitionReducer:
        """Create the streaming consumer for partition r."""


@dataclasses.dataclass
class ShuffleReport:
    """What happened: sizes, timings, and *measured* store traffic.

    Field names keep their CloudSort heritage (this class *is*
    core/external_sort.ExternalSortReport — the sort was the first
    instantiation): `num_waves` counts map tasks, `num_reducers` output
    partitions, `runs_per_reducer` the k of the streaming k-way merge.
    The generic aliases below read better for non-sort workloads.
    """

    total_records: int
    num_waves: int
    num_workers: int
    num_reducers: int
    spill_objects: int
    output_objects: int
    map_seconds: float
    reduce_seconds: float
    working_set_records: int
    stats: StoreStats  # delta over the job (map + reduce), all tiers
    runs_per_reducer: int = 0  # k of the streaming k-way merge
    merge_chunk_bytes: int = 0  # the plan's per-run fetch cap
    reduce_chunk_bytes: int = 0  # initial per-run chunk (budget-governed)
    reduce_chunk_bytes_max: int = 0  # largest chunk the governor granted
    reduce_peak_merge_bytes: int = 0  # measured max across ALL active merges
    parallel_reducers: int = 1  # concurrent merges the scheduler(s) ran
    reduce_memory_budget_bytes: int = 0  # the global governor (0 = none)
    tier_stats: dict[str, StoreStats] | None = None  # per-tier deltas
    spans: list["Span"] = dataclasses.field(default_factory=list)
    spans_dropped: int = 0  # spans beyond the recorder cap (totals stay exact)
    phase_seconds: dict[str, float] = dataclasses.field(default_factory=dict)
    metrics: dict = dataclasses.field(default_factory=dict)  # MetricsRegistry.snapshot()

    # -- generic aliases over the legacy sort-flavoured names ------------

    @property
    def num_map_tasks(self) -> int:
        return self.num_waves

    @property
    def num_partitions(self) -> int:
        return self.num_reducers

    @property
    def runs_per_partition(self) -> int:
        return self.runs_per_reducer

    @property
    def oversubscription(self) -> float:
        """Dataset size / per-split working set (>1 = out-of-core)."""
        return self.total_records / self.working_set_records

    @property
    def reduce_memory_bound_bytes(self) -> int:
        """The scheduler's memory guarantee: the global budget when one is
        set, else parallel_reducers x runs x effective chunk (+ one record
        of rounding per run) — reduce_peak_merge_bytes never exceeds it."""
        if self.reduce_memory_budget_bytes:
            return self.reduce_memory_budget_bytes
        chunk = self.reduce_chunk_bytes or self.merge_chunk_bytes
        return self.parallel_reducers * self.runs_per_reducer * chunk

    @property
    def job_hours(self) -> float:
        return (self.map_seconds + self.reduce_seconds) / 3600.0

    @property
    def reduce_hours(self) -> float:
        return self.reduce_seconds / 3600.0


@dataclasses.dataclass
class ClusterShuffleReport:
    """A cluster run's report: the single-host report plus the cluster
    story (who died, what was re-executed, who did what)."""

    report: ShuffleReport
    num_cluster_workers: int
    failed_workers: list[str]
    reexecuted_map_tasks: int
    reexecuted_reduce_tasks: int
    map_tasks: int
    reduce_tasks: int
    per_worker_stats: dict[str, StoreStats]
    per_worker_tasks: dict[str, int]
    # Elastic-fleet extras (shuffle/elastic.py); zero under the static
    # PhaseDriver so existing constructor call sites stay valid.
    speculated_tasks: int = 0  # duplicate attempts launched
    speculation_wins: int = 0  # duplicates that committed first
    heartbeat_misses: int = 0  # workers declared dead by silence
    spill_lost_map_tasks: int = 0  # map tasks re-run for lost spill runs
    requeued_reduce_tasks: int = 0  # reduce attempts parked on lost input
    workers_admitted: int = 0  # joined mid-job
    workers_retired: int = 0  # gracefully drained mid-job
    recovery_rounds: int = 0  # map-recovery passes after spill loss

    @property
    def sort(self) -> ShuffleReport:
        """Legacy alias: core/cluster.ClusterSortReport named the inner
        report `sort` back when sorting was the only workload."""
        return self.report

    @property
    def reexecuted_tasks(self) -> int:
        return self.reexecuted_map_tasks + self.reexecuted_reduce_tasks

    @property
    def spans_dropped(self) -> int:
        """Spans beyond the recorder cap (see runtime.PhaseTimeline
        `max_spans`); phase totals stay exact regardless."""
        return self.report.spans_dropped

    @property
    def metrics(self) -> dict:
        """The run's MetricsRegistry snapshot (see obs/metrics.py)."""
        return self.report.metrics

    @property
    def records_per_second(self) -> float:
        secs = self.report.map_seconds + self.report.reduce_seconds
        return self.report.total_records / secs if secs > 0 else 0.0


__all__ = [
    "ClusterShuffleReport",
    "CombineOp",
    "MapOp",
    "Partitioner",
    "PartitionReducer",
    "ReduceOp",
    "ShufflePlan",
    "ShuffleReport",
    "require",
    "validate_dataflow_plan",
]
